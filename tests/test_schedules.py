"""T_v / T_u policy behaviour (paper §6 policies)."""
import jax.numpy as jnp
import numpy as np

from repro.core import schedules as S


def _roll(policy, steps, is_var=False, intervals=None):
    st = policy.init()
    fires = []
    for t in range(steps):
        if is_var:
            iv = intervals[t] if intervals is not None else 1
            f, st = policy.step(st, jnp.int32(t), jnp.int32(iv))
        else:
            f, st, _ = policy.step(st, jnp.int32(t))
        fires.append(bool(f))
    return fires


def test_adaptive_freeze_exponential_gaps():
    pol = S.AdaptiveFreezePolicy(kappa=2)
    fires = _roll(pol, 40, is_var=True)
    idx = [i for i, f in enumerate(fires) if f]
    gaps = np.diff(idx)
    # k_{j+1}-k_j = 2^{floor(j/2)}: 1,1,2,2,4,4,8,8...
    expect = [2 ** (j // 2) for j in range(len(gaps))]
    assert list(gaps) == expect[:len(gaps)]


def test_freeze_stops_when_local_steps_begin():
    pol = S.AdaptiveFreezePolicy(kappa=16)
    st = pol.init()
    fired_after = []
    for t in range(20):
        iv = 1 if t < 10 else 2   # local stepping starts at t=10
        f, st = pol.step(st, jnp.int32(t), jnp.int32(iv))
        if t >= 10:
            fired_after.append(bool(f))
    assert not any(fired_after)  # paper: stop v updates once interval > 1


def test_fixed_warmup_is_onebit_adam_stage():
    pol = S.FixedWarmupPolicy(t0=5)
    fires = _roll(pol, 10, is_var=True)
    assert fires == [True] * 5 + [False] * 5


def test_lr_proportional_sync_doubles_and_clips():
    pol = S.LrProportionalSyncPolicy(warmup_steps=4, double_every=4,
                                     max_interval=4)
    fires = _roll(pol, 32)
    idx = [i for i, f in enumerate(fires) if f]
    gaps = list(np.diff(idx))
    # every step through warmup, then 1,1.. doubling to clip at 4
    assert gaps[:4] == [1, 1, 1, 1]
    assert max(gaps) == 4
    assert gaps[-1] == 4  # clipped steady state
    # monotone non-decreasing intervals
    assert all(b >= a for a, b in zip(gaps, gaps[1:]))


def test_interval_matches_assumption_H():
    pol = S.LrProportionalSyncPolicy(warmup_steps=2, double_every=2,
                                     max_interval=16)
    st = pol.init()
    max_gap, last = 0, 0
    for t in range(200):
        f, st, _ = pol.step(st, jnp.int32(t))
        if bool(f):
            max_gap = max(max_gap, t - last)
            last = t
    assert max_gap <= 16  # Assumption 5: H bound


def test_default_sync_policy_pins_lr_half_life():
    """Regression for the 32678 typo: the paper's BERT recipe doubles the
    sync interval every 2^15 = 32768 steps (the lr half-life)."""
    from repro.core import OptimizerConfig
    pol = OptimizerConfig().sync_policy
    assert pol.double_every == 32768 == 2 ** 15
    assert pol.warmup_steps == 12500 and pol.max_interval == 16
    w = pol.warmup_steps
    assert int(pol.interval(w)) == 1
    assert int(pol.interval(w + 2 ** 15 - 1)) == 1
    assert int(pol.interval(w + 2 ** 15)) == 2
    assert int(pol.interval(w + 2 * 2 ** 15)) == 4
    assert int(pol.interval(w + 10 * 2 ** 15)) == 16  # clipped at H


def test_lr_schedules_shapes():
    lr1 = S.LinearWarmupExpDecay(4e-4, 10, decay=0.5, decay_period=10)
    assert float(lr1(0)) < float(lr1(9))
    assert abs(float(lr1(10)) - 4e-4) < 1e-9
    assert float(lr1(20)) < float(lr1(10))
    lr2 = S.LinearWarmupCosine(1e-3, 5, 100)
    assert float(lr2(100)) <= float(lr2(50)) <= float(lr2(5)) + 1e-9
