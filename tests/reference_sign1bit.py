"""FROZEN pre-refactor copy of the sign-1-bit EF exchange (regression pin).

This module is a verbatim snapshot of ``repro.core.onebit_allreduce`` as it
stood BEFORE the pluggable-codec refactor (PR 4): the worker/server phases
hardwire packed sign bits + L1 scales. tests/test_codecs.py runs it side by
side with the refactored, codec-parameterized exchange and asserts that
``codec="sign1bit"`` (and the identity codec vs the old ``quantize=False``
branch) reproduces this trajectory BITWISE — outputs and EF state — across
flat / pallas / hierarchy configurations.

Do not "fix" or modernize this file; its value is that it does not change.
The only edits vs the original are this docstring and the imports of
``EFState``/``OneBitConfig`` (re-used from the live module so state pytrees
are interchangeable).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core import compressor as C
from repro.core.comm import Comm, Hierarchy  # noqa: F401 (signature compat)
from repro.core.onebit_allreduce import EFState, OneBitConfig  # noqa: F401


def onebit_allreduce_view(comm: Comm, z_view: jnp.ndarray, ef: EFState,
                          layout: C.LeafLayout, cfg: OneBitConfig,
                          vspec=None, worker_index=None):
    """Algorithm 2 over one leaf's comm view. Returns (mean estimate, EFState).

    ``z_view``: this worker's buffer in view shape (n, A/n, *rest).
    ``vspec``: tensor-parallel PartitionSpec entries of the view — threaded
    through every shape-changing op so the compressed pipeline stays
    model-sharded (see compressor.constrain).
    The returned value estimates ``mean_i z_view^{(i)}`` in view shape.

    With ``cfg.hierarchy`` set the same estimate is produced by the
    topology-aware two-level schedule (:func:`_hier_allreduce_view`); the
    flat code below is its exact ``n_inner == 1`` degenerate case.
    """
    if cfg.hierarchy is not None:
        assert layout.n_inner == cfg.hierarchy.inner, (layout, cfg.hierarchy)
        return _hier_allreduce_view(comm, z_view, ef, layout, cfg, vspec)
    cst = lambda x: C.constrain(x, vspec)
    if not cfg.quantize:
        # Identity compressor: the exact same collective schedule exchanging
        # uncompressed values. Used for the degenerate-equivalence tests and
        # the "no compression" ablation.
        recv = cst(comm.all_to_all(z_view, split_axis=0, concat_axis=0))
        avg = recv.mean(axis=0)
        out = cst(comm.all_gather(avg[None], axis=0, tiled=True))
        return out.astype(cfg.compute_dtype), ef

    mask = C.pad_mask(layout, dtype=z_view.dtype)
    # Kernel dispatch: GSPMD-auto-sharded views stay on the constrained jnp
    # path (dispatch.kernel_safe), as does the server side of
    # row-granularity on 2-D (flatten) views, which degenerates to
    # per-element scales (see dispatch.server_compress_view).
    use_k = cfg.use_pallas
    if use_k:
        from repro.kernels import dispatch as K
        use_k = K.kernel_safe(vspec)
    k_server = use_k and not (cfg.scale_mode == "row"
                              and len(layout.view_shape) == 2)
    # --- worker side -------------------------------------------------------
    if use_k:
        packed, scales, err_w = K.ef_compress_view(
            cst(z_view), ef.err_worker.astype(z_view.dtype), layout,
            cfg.scale_mode, cfg.model_axes)
    else:
        zw = cst(z_view + ef.err_worker.astype(z_view.dtype))
        packed, scales, err_w = C.ef_compress(zw, layout, cfg.scale_mode,
                                              mask, cfg.model_axes)
    packed, err_w = cst(packed), cst(err_w)

    # --- scatter: worker j collects chunk j from everyone ------------------
    # packed: (n, A/n, ..., C/8) uint8 -> rows become sender index.
    recv = cst(comm.all_to_all(packed, split_axis=0, concat_axis=0))
    # scales need the same routing; broadcast "tensor" scales to chunk rows
    # first so each receiver gets the proper per-sender magnitude.
    bscales = jnp.broadcast_to(
        scales, (layout.n,) + scales.shape[1:]).astype(jnp.float32)
    rscales = comm.all_to_all(bscales, split_axis=0, concat_axis=0)

    # --- server side (this worker serves its chunk) -------------------------
    if use_k:
        vals = cst(K.decompress_view(recv, rscales, layout,
                                     cfg.compute_dtype))
    else:
        vals = cst(C.unpack_signs(recv, layout.pack_count,
                                  cfg.compute_dtype))
        vals = vals * rscales.astype(cfg.compute_dtype)
    avg = vals.mean(axis=0)                                   # (A/n, *rest)
    widx = comm.index() if worker_index is None else worker_index
    # Server-side compression shares the leaf layout but acts on one chunk;
    # reuse the chunk-level granularity of the configured mode.
    if k_server:
        packed_s, scales_s, err_s = K.server_compress_view(
            cst(avg[None]), ef.err_server.astype(cfg.compute_dtype)[None],
            layout, cfg.scale_mode, widx, cfg.model_axes)
    else:
        y = avg + ef.err_server.astype(cfg.compute_dtype)
        y_exp = cst(y[None])                                  # (1, A/n, *rest)
        s_mask = None if mask is None else mask[widx][None]
        packed_s, scales_s, err_s = _server_compress(
            y_exp, layout, cfg.scale_mode, s_mask, cfg.model_axes)
    packed_s = cst(packed_s)
    err_s = cst(err_s)[0]

    # --- gather: broadcast compressed chunk results -------------------------
    gpacked = cst(comm.all_gather(packed_s, axis=0, tiled=True))
    gscales = comm.all_gather(
        scales_s.astype(jnp.float32), axis=0, tiled=True)
    if k_server:
        out = cst(K.decompress_view(gpacked, gscales, layout,
                                    cfg.compute_dtype))
    else:
        out = cst(C.unpack_signs(gpacked, layout.pack_count,
                                 cfg.compute_dtype))
        out = out * gscales.astype(cfg.compute_dtype)
    return out, EFState(err_worker=err_w.astype(ef.err_worker.dtype),
                        err_server=err_s.astype(ef.err_server.dtype))


def _hier_allreduce_view(comm: Comm, z_view: jnp.ndarray, ef: EFState,
                         layout: C.LeafLayout, cfg: OneBitConfig,
                         vspec=None):
    """Topology-aware two-level AllReduce (intra-pod × inter-pod).

    Schedule, per worker (inner index j, outer index k):

      1. **intra-pod reduce-scatter** (uncompressed, wire dtype): all_to_all
         over the fast inner axes of the view reshaped (n_inner, n_outer,
         A/n, *rest); the mean over senders leaves this worker owning the
         pod-mean of slice j.
      2. **inter-pod Algorithm 2** on the owned slice: EF-compress (worker
         error), all_to_all the packed bits across pods, server-average +
         EF-compress the chunk this pod serves (server error), all_gather
         the compressed results. Identical to the flat path with n→n_outer.
      3. **intra-pod all_gather** of the decompressed slice rebuilds the
         full view.

    Only step 2 crosses the slow inter-pod links — at 1 bit/element — while
    the bulky uncompressed traffic of steps 1/3 stays inside the pod. With
    ``n_inner == 1`` steps 1/3 are skipped entirely and step 2 *is* the flat
    path (bitwise, including scale denominators), which the degenerate-
    equivalence tests pin down.
    """
    h = cfg.hierarchy
    ni, no = layout.n_inner, layout.n_outer
    vs = layout.view_shape
    cst = lambda x: C.constrain(x, vspec)
    outer, inner = comm.split(h.outer_axes, h.inner_axes)

    # --- 1: intra-pod reduce-scatter (slice j <- contiguous view rows) -----
    zr = z_view.reshape((ni, no) + vs[1:])
    if ni > 1:
        recv = inner.all_to_all(zr.astype(cfg.comm_dtype),
                                split_axis=0, concat_axis=0)
        own = recv.astype(jnp.float32).mean(axis=0)        # (no, A/n, *rest)
        j = inner.index()
    else:
        own = zr[0]
        j = jnp.zeros((), jnp.int32)
    own = cst(own.astype(cfg.compute_dtype))

    if not cfg.quantize:
        # Identity compressor: the exact two-level collective schedule
        # exchanging uncompressed values (degenerate-equivalence/ablation).
        recv = cst(outer.all_to_all(own, split_axis=0, concat_axis=0))
        avg = recv.mean(axis=0)
        out_slice = cst(outer.all_gather(avg[None], axis=0, tiled=True))
        new_ef = ef
    else:
        mask_full = C.pad_mask(layout, dtype=own.dtype)
        if mask_full is not None:
            m_slice = jnp.take(
                mask_full.reshape((ni, no) + mask_full.shape[1:]), j, axis=0)
        else:
            m_slice = None
        use_k = cfg.use_pallas
        if use_k:
            from repro.kernels import dispatch as K
            use_k = K.kernel_safe(vspec)
        k_server = use_k and not (cfg.scale_mode == "row" and len(vs) == 2)

        # --- 2a: worker-side EF-compress of the owned slice ----------------
        if use_k:
            packed, scales, err_w = K.ef_compress_view(
                own, ef.err_worker.astype(own.dtype), layout,
                cfg.scale_mode, cfg.model_axes, inner_index=j)
        else:
            zw = cst(own + ef.err_worker.astype(own.dtype))
            packed, scales, err_w = C.ef_compress_slice(
                zw, layout, cfg.scale_mode, m_slice, j, cfg.model_axes)
        packed, err_w = cst(packed), cst(err_w)

        # --- 2b: inter-pod scatter: pod k collects sub-chunk k -------------
        recv = cst(outer.all_to_all(packed, split_axis=0, concat_axis=0))
        bscales = jnp.broadcast_to(
            scales, (no,) + scales.shape[1:]).astype(jnp.float32)
        rscales = outer.all_to_all(bscales, split_axis=0, concat_axis=0)

        # --- 2c: server side (this pod serves full-view chunk j*no+k) ------
        if use_k:
            vals = cst(K.decompress_view(recv, rscales, layout,
                                         cfg.compute_dtype))
        else:
            vals = cst(C.unpack_signs(recv, layout.pack_count,
                                      cfg.compute_dtype))
            vals = vals * rscales.astype(cfg.compute_dtype)
        avg = vals.mean(axis=0)                            # (A/n, *rest)
        k_idx = outer.index()
        widx = j * no + k_idx
        if k_server:
            packed_s, scales_s, err_s = K.server_compress_view(
                cst(avg[None]), ef.err_server.astype(cfg.compute_dtype)[None],
                layout, cfg.scale_mode, widx, cfg.model_axes)
        else:
            y = avg + ef.err_server.astype(cfg.compute_dtype)
            y_exp = cst(y[None])
            s_mask = None if mask_full is None else mask_full[widx][None]
            packed_s, scales_s, err_s = _server_compress(
                y_exp, layout, cfg.scale_mode, s_mask, cfg.model_axes)
        packed_s = cst(packed_s)
        err_s = cst(err_s)[0]

        # --- 2d: inter-pod gather of the compressed chunk results ----------
        gpacked = cst(outer.all_gather(packed_s, axis=0, tiled=True))
        gscales = outer.all_gather(
            scales_s.astype(jnp.float32), axis=0, tiled=True)
        if k_server:
            out_slice = cst(K.decompress_view(gpacked, gscales, layout,
                                              cfg.compute_dtype))
        else:
            out_slice = cst(C.unpack_signs(gpacked, layout.pack_count,
                                           cfg.compute_dtype))
            out_slice = out_slice * gscales.astype(cfg.compute_dtype)
        new_ef = EFState(err_worker=err_w.astype(ef.err_worker.dtype),
                         err_server=err_s.astype(ef.err_server.dtype))

    # --- 3: intra-pod all_gather rebuilds the full view --------------------
    if ni > 1:
        out = inner.all_gather(out_slice.astype(cfg.comm_dtype)[None],
                               axis=0, tiled=True).reshape(vs)
    else:
        out = out_slice.reshape(vs)
    return cst(out).astype(cfg.compute_dtype), new_ef


def _server_compress(y, layout, mode, mask, model_axes=()):
    """EF-compress one server chunk (leading dim 1)."""
    from repro.core.compressor import _psum_model
    az = jnp.abs(y)
    if mask is not None:
        az = az * mask
    rest = layout.rest_factor
    for s in y.shape[2:]:
        rest *= s
    if mode == "row":
        axes = tuple(range(2, y.ndim))
        cnt = max(rest, 1)
        s = (_psum_model(az.sum(axis=axes), model_axes) / cnt
             if y.ndim > 2 else az)
        scales = s.reshape(y.shape[:2] + (1,) * (y.ndim - 2))
    else:  # tensor / chunk -> one scale for this chunk
        denom = (az.size * layout.rest_factor if mask is None
                 else jnp.maximum(mask.sum() * rest, 1.0))
        denom = jnp.asarray(denom, y.dtype)
        scales = (_psum_model(az.sum(), model_axes)
                  / denom).reshape((1,) * y.ndim)
    packed = C.pack_signs(y)
    signs = jnp.where(y >= 0, 1.0, -1.0).astype(y.dtype)
    err = y - signs * scales.astype(y.dtype)
    if mask is not None:
        err = err * mask.astype(err.dtype)
    return packed, scales, err


def fullprec_allreduce_view(comm: Comm, z_view: jnp.ndarray,
                            comm_dtype=jnp.bfloat16,
                            vspec=None, hierarchy: Optional[Hierarchy] = None,
                            layout: Optional[C.LeafLayout] = None
                            ) -> jnp.ndarray:
    """Full-precision mean over workers (used on T_v steps) at the wire
    dtype, as the paper does with fp16 training.

    Implemented as the chunked scatter-mean/all-gather (reduce-scatter +
    all-gather decomposition of a ring AllReduce: identical per-device
    traffic, ~2·d bytes). Besides matching the 1-bit path's transport, this
    sidesteps an XLA CPU-backend crash on bf16 ``all-reduce`` inside
    partial-manual shard_map (bf16 a2a/all-gather are fine; TPU unaffected).

    With ``hierarchy`` (and its ``layout``) the same mean runs the two-level
    schedule: intra-pod reduce-scatter, inter-pod exchange of the owned
    slice (1/n_inner of the traffic crosses the slow links), intra-pod
    all_gather — mirroring the 1-bit path's transport level for level.
    """
    acc = z_view.dtype
    cst = lambda x: C.constrain(x, vspec)
    if hierarchy is not None and layout is not None and layout.n_inner > 1:
        ni, no = layout.n_inner, layout.n_outer
        outer, inner = comm.split(hierarchy.outer_axes, hierarchy.inner_axes)
        zr = z_view.astype(comm_dtype).reshape((ni, no) + layout.chunk_shape)
        recv = inner.all_to_all(zr, split_axis=0, concat_axis=0)
        own = recv.astype(jnp.float32).mean(axis=0).astype(comm_dtype)
        recv2 = cst(outer.all_to_all(own, split_axis=0, concat_axis=0))
        avg = recv2.astype(jnp.float32).mean(axis=0).astype(comm_dtype)
        g1 = cst(outer.all_gather(avg[None], axis=0, tiled=True))
        out = inner.all_gather(g1[None], axis=0, tiled=True)
        return out.reshape(z_view.shape).astype(acc)
    zc = cst(z_view.astype(comm_dtype))
    recv = cst(comm.all_to_all(zc, split_axis=0, concat_axis=0))
    avg = recv.astype(jnp.float32).mean(axis=0).astype(comm_dtype)
    out = cst(comm.all_gather(avg[None], axis=0, tiled=True))
    return out.astype(acc)
