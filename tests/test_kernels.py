"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + property fuzz.

``hypothesis`` is an optional test dependency (requirements-test.txt).
Instead of a module-level ``pytest.importorskip`` — which would also skip
the deterministic oracle sweeps below — the property tests degrade to a
fixed-seed parametrized sweep when hypothesis is absent, so the suite
collects and keeps its coverage either way.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.kernels import ops, ref


@pytest.mark.parametrize("R,C", [(8, 128), (16, 256), (8, 1024), (32, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ef_compress_matches_ref(R, C, dtype):
    key = jax.random.PRNGKey(R * C)
    z = jax.random.normal(key, (R, C)).astype(dtype)
    e = (jax.random.normal(jax.random.fold_in(key, 1), (R, C)) * 0.3
         ).astype(dtype)
    p1, s1, e1 = ops.ef_compress(z, e)
    p2, s2, e2 = ref.ef_compress_ref(z, e)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(e1, np.float32),
                               np.asarray(e2, np.float32),
                               rtol=1e-2, atol=1e-2)


@pytest.mark.parametrize("R,C", [(8, 128), (4, 64)])
def test_ef_compress_mask_aware_scales(R, C):
    """Padded tails must not dilute the per-row L1-mean scales."""
    key = jax.random.PRNGKey(11)
    z = jax.random.normal(key, (R, C))
    e = jax.random.normal(jax.random.fold_in(key, 1), (R, C)) * 0.3
    counts = jnp.asarray([C, C // 2, 0, C // 4] * (R // 4), jnp.int32)
    p1, s1, e1 = ops.ef_compress(z, e, counts, block_rows=4)
    p2, s2, e2 = ref.ef_compress_ref(z, e, counts)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2),
                               rtol=1e-5, atol=1e-6)
    # padded positions carry zero error feedback
    zw = np.asarray(z + e)
    m = np.arange(C)[None, :] < np.asarray(counts)[:, None]
    assert (np.asarray(e1)[~m] == 0).all()
    # hand-check one masked scale
    np.testing.assert_allclose(
        float(s1[1]), np.abs(zw[1, :C // 2]).mean(), rtol=1e-6)
    assert float(s1[2]) == 0.0


@pytest.mark.parametrize("R,C", [(8, 128), (16, 512), (8, 24)])
def test_abs_rowsum_matches_ref(R, C):
    key = jax.random.PRNGKey(R + C)
    z = jax.random.normal(key, (R, C))
    e = jax.random.normal(jax.random.fold_in(key, 1), (R, C))
    counts = jnp.asarray((np.arange(R) * C // max(R - 1, 1)), jnp.int32)
    for cnt in (None, counts):
        r1 = ops.abs_rowsum(z, e, cnt)
        r2 = ref.abs_rowsum_ref(z, e, cnt)
        np.testing.assert_allclose(np.asarray(r1), np.asarray(r2),
                                   rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("R,C", [(8, 128), (4, 64)])
def test_ef_quantize_matches_ref(R, C):
    key = jax.random.PRNGKey(5)
    z = jax.random.normal(key, (R, C))
    e = jax.random.normal(jax.random.fold_in(key, 1), (R, C)) * 0.3
    scales = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (R,)))
    counts = jnp.asarray([C] * (R - 1) + [C // 2], jnp.int32)
    for cnt in (None, counts):
        p1, e1 = ops.ef_quantize(z, e, scales, cnt, block_rows=4)
        p2, e2 = ref.ef_quantize_ref(z, e, scales, cnt)
        np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
        np.testing.assert_allclose(np.asarray(e1), np.asarray(e2),
                                   rtol=1e-5, atol=1e-6)


def test_two_pass_agrees_with_single_pass():
    """abs_rowsum + per-row combine + ef_quantize == fused ef_compress."""
    key = jax.random.PRNGKey(9)
    z = jax.random.normal(key, (8, 256))
    e = jax.random.normal(jax.random.fold_in(key, 1), (8, 256)) * 0.1
    counts = jnp.asarray([256, 200, 256, 0, 256, 8, 256, 128], jnp.int32)
    p1, s1, e1 = ops.ef_compress(z, e, counts)
    rs = ops.abs_rowsum(z, e, counts)
    s2 = rs / jnp.maximum(counts.astype(jnp.float32), 1.0)
    p2, e2 = ops.ef_quantize(z, e, s2, counts)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("R,C", [(8, 128), (16, 512)])
def test_decompress_matches_ref(R, C):
    key = jax.random.PRNGKey(3)
    packed = jax.random.randint(key, (R, C // 8), 0, 256).astype(jnp.uint8)
    scales = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (R,)))
    v1 = ops.decompress(packed, scales)
    v2 = ref.decompress_ref(packed, scales)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)


def test_compress_decompress_roundtrip_signs():
    z = jax.random.normal(jax.random.PRNGKey(0), (8, 256))
    e = jnp.zeros_like(z)
    p, s, _ = ops.ef_compress(z, e)
    v = ops.decompress(p, s)
    np.testing.assert_array_equal(np.sign(np.asarray(v)),
                                  np.where(np.asarray(z) >= 0, 1.0, -1.0))


def _check_fused_local_step(seed, lr, beta1):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    g, m, u = (jax.random.normal(k, (8, 256)) for k in ks[:3])
    v = jnp.abs(jax.random.normal(ks[3], (8, 256))) + 1e-3
    o1 = ops.fused_local_step(g, m, u, v, lr, beta1)
    o2 = ref.fused_local_step_ref(g, m, u, v, lr, beta1)
    for a, b in zip(o1, o2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           lr=st.floats(1e-5, 1e-1), beta1=st.floats(0.0, 0.99))
    def test_fused_local_step_matches_ref(seed, lr, beta1):
        _check_fused_local_step(seed, lr, beta1)
else:
    @pytest.mark.parametrize("seed,lr,beta1", [
        (0, 1e-3, 0.9), (1, 1e-2, 0.0), (2, 1e-1, 0.99),
        (3, 1e-5, 0.5), (4, 3e-3, 0.9)])
    def test_fused_local_step_matches_ref(seed, lr, beta1):
        _check_fused_local_step(seed, lr, beta1)


@pytest.mark.parametrize("block", [(8, 128), (8, 256), (4, 512)])
def test_fused_block_shapes(block):
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 512))
    m = jnp.zeros_like(g)
    u = jnp.zeros_like(g)
    v = jnp.ones_like(g)
    o1 = ops.fused_local_step(g, m, u, v, 0.01, 0.9, block=block)
    o2 = ref.fused_local_step_ref(g, m, u, v, 0.01, 0.9)
    for a, b in zip(o1, o2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5)
