"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


@pytest.mark.parametrize("R,C", [(8, 128), (16, 256), (8, 1024), (32, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ef_compress_matches_ref(R, C, dtype):
    key = jax.random.PRNGKey(R * C)
    z = jax.random.normal(key, (R, C)).astype(dtype)
    e = (jax.random.normal(jax.random.fold_in(key, 1), (R, C)) * 0.3
         ).astype(dtype)
    p1, s1, e1 = ops.ef_compress(z, e)
    p2, s2, e2 = ref.ef_compress_ref(z, e)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(e1, np.float32),
                               np.asarray(e2, np.float32),
                               rtol=1e-2, atol=1e-2)


@pytest.mark.parametrize("R,C", [(8, 128), (16, 512)])
def test_decompress_matches_ref(R, C):
    key = jax.random.PRNGKey(3)
    packed = jax.random.randint(key, (R, C // 8), 0, 256).astype(jnp.uint8)
    scales = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (R,)))
    v1 = ops.decompress(packed, scales)
    v2 = ref.decompress_ref(packed, scales)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)


def test_compress_decompress_roundtrip_signs():
    z = jax.random.normal(jax.random.PRNGKey(0), (8, 256))
    e = jnp.zeros_like(z)
    p, s, _ = ops.ef_compress(z, e)
    v = ops.decompress(p, s)
    np.testing.assert_array_equal(np.sign(np.asarray(v)),
                                  np.where(np.asarray(z) >= 0, 1.0, -1.0))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       lr=st.floats(1e-5, 1e-1), beta1=st.floats(0.0, 0.99))
def test_fused_local_step_matches_ref(seed, lr, beta1):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    g, m, u = (jax.random.normal(k, (8, 256)) for k in ks[:3])
    v = jnp.abs(jax.random.normal(ks[3], (8, 256))) + 1e-3
    o1 = ops.fused_local_step(g, m, u, v, lr, beta1)
    o2 = ref.fused_local_step_ref(g, m, u, v, lr, beta1)
    for a, b in zip(o1, o2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("block", [(8, 128), (8, 256), (4, 512)])
def test_fused_block_shapes(block):
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 512))
    m = jnp.zeros_like(g)
    u = jnp.zeros_like(g)
    v = jnp.ones_like(g)
    o1 = ops.fused_local_step(g, m, u, v, 0.01, 0.9, block=block)
    o2 = ref.fused_local_step_ref(g, m, u, v, 0.01, 0.9)
    for a, b in zip(o1, o2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5)
