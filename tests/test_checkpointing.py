"""Checkpoint manifest validation + save->restore->resume round trips for
full optimizer state, on both the legacy reference classes and the
composed path."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing import io as ckpt_io
from repro.configs import get
from repro.core import OptimizerConfig, sim_comm, schedules as S
from repro.core.zero_one_adam import ZeroOneAdam
from repro.data import DataConfig, SyntheticLM
from repro.train import Trainer

N = 4
OPT = OptimizerConfig(
    name="zero_one_adam",
    lr=S.LinearWarmupExpDecay(peak_lr=2e-3, warmup_steps=4, decay=0.97,
                              decay_period=20),
    var_policy=S.AdaptiveFreezePolicy(kappa=2),
    sync_policy=S.LrProportionalSyncPolicy(warmup_steps=2, double_every=3,
                                           max_interval=2))


# --------------------------------------------------------------------- #
# manifest validation
# --------------------------------------------------------------------- #

def test_manifest_carries_version_and_paths(tmp_path):
    path = os.path.join(tmp_path, "ck.npz")
    tree = {"a": jnp.ones((2, 3)), "b": {"c": jnp.zeros((4,))}}
    ckpt_io.save(path, tree, step=3)
    with np.load(path, allow_pickle=False) as z:
        man = json.loads(str(z["__manifest__"]))
    assert man["version"] == ckpt_io.FORMAT_VERSION
    assert man["n_leaves"] == 2
    assert man["leaf_paths"] == ["['a']", "['b']['c']"]
    assert man["leaf_shapes"] == [[2, 3], [4]]


def test_restore_rejects_leaf_count_mismatch(tmp_path):
    path = os.path.join(tmp_path, "ck.npz")
    ckpt_io.save(path, {"a": jnp.ones((2,)), "b": jnp.ones((2,))})
    with pytest.raises(ValueError, match="2 leaves, expected 1"):
        ckpt_io.restore(path, {"a": jnp.ones((2,))})


def test_restore_names_first_mismatched_leaf_shape(tmp_path):
    path = os.path.join(tmp_path, "ck.npz")
    ckpt_io.save(path, {"a": jnp.ones((2, 3)), "b": {"c": jnp.zeros((4,))}})
    like = {"a": jnp.ones((2, 3)), "b": {"c": jnp.zeros((5,))}}
    with pytest.raises(ValueError, match=r"\['b'\]\['c'\].*\(4,\).*\(5,\)"):
        ckpt_io.restore(path, like)


def test_restore_names_diverged_tree_path(tmp_path):
    path = os.path.join(tmp_path, "ck.npz")
    ckpt_io.save(path, {"a": jnp.ones((2,)), "b": jnp.ones((3,))})
    like = {"a": jnp.ones((2,)), "z": jnp.ones((3,))}
    with pytest.raises(ValueError, match=r"\['b'\].*\['z'\]"):
        ckpt_io.restore(path, like)


def test_restore_reads_version1_checkpoints(tmp_path):
    """Pre-version-field checkpoints (count+shape manifest only) stay
    readable, with the same shape validation."""
    path = os.path.join(tmp_path, "v1.npz")
    tree = {"a": jnp.arange(6.0).reshape(2, 3)}
    leaves, treedef = jax.tree.flatten(tree)
    payload = {"step": 11, "meta": {"arch": "x"}, "treedef": str(treedef),
               "n_leaves": len(leaves)}
    with open(path, "wb") as f:
        np.savez(f, __manifest__=json.dumps(payload),
                 **{f"leaf_{i}": np.asarray(l)
                    for i, l in enumerate(leaves)})
    restored, step, meta = ckpt_io.restore(path, tree)
    assert step == 11 and meta["arch"] == "x"
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    with pytest.raises(ValueError, match="shape"):
        ckpt_io.restore(path, {"a": jnp.ones((3, 3))})


def test_restore_rejects_future_version(tmp_path):
    path = os.path.join(tmp_path, "vN.npz")
    payload = {"version": ckpt_io.FORMAT_VERSION + 1, "step": 0, "meta": {},
               "treedef": "", "n_leaves": 1}
    with open(path, "wb") as f:
        np.savez(f, __manifest__=json.dumps(payload),
                 leaf_0=np.zeros((1,)))
    with pytest.raises(ValueError, match="format version"):
        ckpt_io.restore(path, {"a": jnp.zeros((1,))})


# --------------------------------------------------------------------- #
# save -> restore -> resume round trips (full optimizer state)
# --------------------------------------------------------------------- #

def _trainer_roundtrip(tmp_path, opt_cfg):
    cfg = get("gpt2").smoke
    tr = Trainer(cfg, opt_cfg, n_workers=N)
    params, state = tr.sim_init(jax.random.PRNGKey(0))
    fn = tr.sim_step_fn()
    data = SyntheticLM(DataConfig(vocab=64, seq_len=16, global_batch=8,
                                  seed=5))
    for t in range(3):
        params, state, _ = fn(params, state, data.batch(t))

    path = os.path.join(tmp_path, "resume.npz")
    ckpt_io.save(path, {"params": params, "state": state}, step=3)
    like = {"params": jax.tree.map(jnp.zeros_like, params),
            "state": jax.tree.map(jnp.zeros_like, state)}
    restored, step, _ = ckpt_io.restore(path, like)
    assert step == 3

    # resume both the live and the restored copies: bitwise-identical run
    p_live, s_live = params, state
    p_res, s_res = restored["params"], restored["state"]
    for t in range(3, 5):
        b = data.batch(t)
        p_live, s_live, _ = fn(p_live, s_live, b)
        p_res, s_res, _ = fn(p_res, s_res, b)
    for a, b in zip(jax.tree.leaves((p_live, s_live)),
                    jax.tree.leaves((p_res, s_res))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_composed_full_state_roundtrip_resume(tmp_path):
    """Composed path (slots-dict state): save mid-run, restore, resume —
    bitwise identical to the uninterrupted trajectory."""
    _trainer_roundtrip(tmp_path, OPT)


def test_composed_sgd_state_roundtrip_resume(tmp_path):
    import dataclasses
    _trainer_roundtrip(tmp_path, dataclasses.replace(OPT,
                                                     name="zero_one_sgd"))


def test_bucketed_state_roundtrip_resume(tmp_path):
    """Bucketed layouts (per-bucket EF state + anchors) survive a
    save->restore->resume mid-schedule bitwise — the save lands between
    syncs, so EF/anchor buffers are live, not zeros."""
    import dataclasses
    _trainer_roundtrip(tmp_path, dataclasses.replace(OPT, bucket_mb=0.5))


def test_bucketed_state_roundtrip_resume_hierarchical(tmp_path):
    import dataclasses
    from repro.core import Hierarchy
    _trainer_roundtrip(tmp_path, dataclasses.replace(
        OPT, bucket_mb=0.5, hierarchy=Hierarchy(inner=2)))


def test_per_leaf_checkpoint_into_bucketed_config_clear_error(tmp_path):
    """Restoring a per-leaf checkpoint into a bucketed config (or the
    reverse) must fail with an error that names the bucket_mb layout
    mismatch, not just a bare count."""
    import dataclasses
    cfg = get("gpt2").smoke
    tr = Trainer(cfg, OPT, n_workers=N)
    params, state = tr.sim_init(jax.random.PRNGKey(0))
    path = os.path.join(tmp_path, "perleaf.npz")
    ckpt_io.save(path, {"params": params, "state": state}, step=1)

    trb = Trainer(cfg, dataclasses.replace(OPT, bucket_mb=4.0), n_workers=N)
    pb, sb = trb.sim_init(jax.random.PRNGKey(0))
    like = {"params": jax.tree.map(jnp.zeros_like, pb),
            "state": jax.tree.map(jnp.zeros_like, sb)}
    with pytest.raises(ValueError, match="bucket_mb"):
        ckpt_io.restore(path, like)
    # and the reverse direction: bucketed checkpoint, per-leaf config
    pathb = os.path.join(tmp_path, "bucketed.npz")
    ckpt_io.save(pathb, {"params": pb, "state": sb}, step=1)
    like2 = {"params": jax.tree.map(jnp.zeros_like, params),
             "state": jax.tree.map(jnp.zeros_like, state)}
    with pytest.raises(ValueError, match="bucket_mb"):
        ckpt_io.restore(pathb, like2)


def test_legacy_state_roundtrip(tmp_path):
    """Old-path (legacy ZeroOneAdam NamedTuple) optimizer state survives a
    save/restore unchanged, leaf for leaf."""
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (6, 16)),
              "b": jnp.zeros((5,))}
    opt = ZeroOneAdam(OPT, params, jax.tree.map(lambda _: None, params),
                      jax.tree.map(lambda _: True, params), N)
    comm = sim_comm("w")
    state = jax.vmap(lambda _: opt.init(params))(jnp.arange(N))
    xs = jax.tree.map(lambda x: jnp.broadcast_to(x, (N,) + x.shape) + 0,
                      params)
    key = jax.random.PRNGKey(2)
    for _ in range(4):
        key, sk = jax.random.split(key)
        ks = jax.random.split(sk, N)
        grads = jax.vmap(lambda kk, x: jax.tree.map(
            lambda l: jax.random.normal(jax.random.fold_in(kk, 7), l.shape),
            x))(ks, xs)
        xs, state, _ = jax.vmap(
            lambda x, g, s: opt.step(comm, x, g, s),
            axis_name="w")(xs, grads, state)
    path = os.path.join(tmp_path, "legacy.npz")
    ckpt_io.save(path, {"params": xs, "state": state}, step=4)
    restored, step, _ = ckpt_io.restore(
        path, {"params": jax.tree.map(jnp.zeros_like, xs),
               "state": jax.tree.map(jnp.zeros_like, state)})
    assert step == 4
    for a, b in zip(jax.tree.leaves(restored),
                    jax.tree.leaves({"params": xs, "state": state})):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
