"""Weight publishing: bitwise identity snapshots, bounded non-accumulating
delta error with anchor resync, loud manifest mismatches, file transport."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.models import transformer as T
from repro.models.layers import abstract_params, init_params
from repro.serve import (Publisher, PublishConfig, Subscriber,
                         load_update, save_update)


def small_tree(seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return {"emb": jax.random.normal(ks[0], (64, 16)),
            "w": jax.random.normal(ks[1], (37, 8)),
            "b": jax.random.normal(ks[2], (5,))}


def perturb(tree, seed, scale=1e-3):
    leaves, treedef = jax.tree.flatten(tree)
    ks = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    return jax.tree.unflatten(treedef, [
        x + scale * jax.random.normal(k, x.shape, x.dtype)
        for x, k in zip(leaves, ks)])


def assert_bitwise(got, want):
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def max_err(got, want):
    return max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree.leaves(got),
                               jax.tree.leaves(want)))


# --------------------------------------------------------------------- #
# identity codec: bitwise round-trip, flat and bucketed layouts
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("bucket_mb", [None, 1.0],
                         ids=["flat_per_leaf", "bucketed"])
def test_identity_roundtrip_bitwise(bucket_mb):
    params = small_tree()
    pc = PublishConfig(codec="identity", bucket_mb=bucket_mb, n_chunks=4)
    pub, sub = Publisher(params, pc), Subscriber(params, pc)
    for seed in range(3):           # identity is exact: every publish is
        got = sub.apply(pub.publish(params, step=seed))  # a snapshot
        assert_bitwise(got, params)
        params = perturb(params, seed)


def test_identity_roundtrip_bitwise_real_model():
    cfg = get("gpt2").smoke
    params = init_params(T.model_template(cfg), jax.random.PRNGKey(0))
    pc = PublishConfig(codec="identity", bucket_mb=4.0)
    pub, sub = Publisher(params, pc), Subscriber(params, pc)
    got = sub.apply(pub.publish(params, step=0))
    assert_bitwise(got, params)
    assert jax.tree.structure(got) == jax.tree.structure(params)


# --------------------------------------------------------------------- #
# delta publishing: bounded, non-accumulating, resynced by snapshots
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("codec,bound", [("qint8", 2e-3), ("qint4", 2e-2)])
@pytest.mark.parametrize("bucket_mb", [None, 1.0],
                         ids=["flat_per_leaf", "bucketed"])
def test_delta_error_bounded_nonaccumulating(codec, bound, bucket_mb):
    params = small_tree()
    pc = PublishConfig(codec=codec, bucket_mb=bucket_mb, n_chunks=4,
                       snapshot_every=5)
    pub, sub = Publisher(params, pc), Subscriber(params, pc)
    p, errs, kinds = params, [], []
    for t in range(12):             # >= 10 publish cycles, 2 resyncs
        u = pub.publish(p, step=t)
        got = sub.apply(u)
        errs.append(max_err(got, p))
        kinds.append(u.kind)
        p = perturb(p, t)
    assert kinds[0] == "snapshot" and "delta" in kinds
    assert kinds[5] == "snapshot" and kinds[10] == "snapshot"
    # snapshots resync exactly; deltas stay within one quantization step
    # of the per-cycle drift scale — and the LAST delta is as tight as the
    # first (the EF anchor keeps error from compounding across cycles)
    for e, k in zip(errs, kinds):
        if k == "snapshot":
            assert e == 0.0
        else:
            assert e < bound
    deltas = [e for e, k in zip(errs, kinds) if k == "delta"]
    assert deltas[-1] < 3 * max(deltas[0], 1e-6)


def test_publisher_subscriber_anchor_lockstep():
    """Publisher advances its anchor by the decoded payload — after many
    deltas the subscriber's reconstruction equals the publisher's anchor
    bitwise (the discipline that keeps the two sides from drifting)."""
    params = small_tree()
    pc = PublishConfig(codec="qint8", bucket_mb=None, n_chunks=4,
                       snapshot_every=100)
    pub, sub = Publisher(params, pc), Subscriber(params, pc)
    p = params
    for t in range(6):
        sub.apply(pub.publish(p, step=t))
        p = perturb(p, t)
    for a, b in zip(pub._anchor, sub._anchor):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------- #
# manifest validation: mismatches fail loudly, naming the field
# --------------------------------------------------------------------- #

def test_mismatched_codec_names_field():
    params = small_tree()
    pub = Publisher(params, PublishConfig(codec="qint8"))
    sub = Subscriber(params, PublishConfig(codec="qint4"))
    with pytest.raises(ValueError, match="'codec'"):
        sub.apply(pub.publish(params))


def test_mismatched_layout_names_field():
    params = small_tree()
    pub = Publisher(params, PublishConfig(n_chunks=4))
    sub = Subscriber(params, PublishConfig(n_chunks=8))
    with pytest.raises(ValueError, match="'n_chunks'"):
        sub.apply(pub.publish(params))


def test_mismatched_tree_names_leaf():
    params = small_tree()
    other = dict(params)
    other["extra"] = jnp.zeros((3, 3))
    pub = Publisher(params, PublishConfig())
    sub = Subscriber(other, PublishConfig())
    with pytest.raises(ValueError, match="leaf_paths"):
        sub.apply(pub.publish(params))


def test_mismatched_leaf_shape_names_leaf_path():
    params = small_tree()
    other = dict(params)
    other["w"] = jnp.zeros((37, 9))
    pub = Publisher(params, PublishConfig(bucket_mb=None))
    sub = Subscriber(other, PublishConfig(bucket_mb=None))
    with pytest.raises(ValueError, match=r"leaf_shapes.*'w'"):
        sub.apply(pub.publish(params))


def test_out_of_order_delta_rejected():
    params = small_tree()
    pc = PublishConfig(codec="qint8", snapshot_every=100)
    pub, sub = Publisher(params, pc), Subscriber(params, pc)
    sub.apply(pub.publish(params, step=0))            # snapshot, seq 0
    pub.publish(perturb(params, 1), step=1)           # delta, dropped
    u2 = pub.publish(perturb(params, 2), step=2)      # delta, seq 2
    with pytest.raises(ValueError, match="'anchor_seq'"):
        sub.apply(u2)


def test_delta_before_snapshot_rejected():
    params = small_tree()
    pc = PublishConfig(codec="qint8", snapshot_every=100)
    pub = Publisher(params, pc)
    pub.publish(params, step=0)                       # snapshot, not sent
    u1 = pub.publish(perturb(params, 1), step=1)      # delta
    sub = Subscriber(params, pc)
    with pytest.raises(ValueError, match="anchor"):
        sub.apply(u1)


def test_truncated_payload_rejected():
    params = small_tree()
    pc = PublishConfig(codec="qint8")
    pub, sub = Publisher(params, pc), Subscriber(params, pc)
    u = pub.publish(params)
    u.payloads[0] = {k: v[:-1] for k, v in u.payloads[0].items()}
    with pytest.raises(ValueError, match="'payload_bytes'"):
        sub.apply(u)


# --------------------------------------------------------------------- #
# wire accounting + file transport
# --------------------------------------------------------------------- #

def test_payload_bytes_match_codec_accounting():
    params = small_tree()
    for codec in ("identity", "qint8", "qint4"):
        pc = PublishConfig(codec=codec, bucket_mb=1.0, n_chunks=4,
                           snapshot_every=100)
        pub = Publisher(params, pc)
        for t in range(2):          # one snapshot, one delta
            u = pub.publish(perturb(params, t), step=t)
            assert u.nbytes() == u.manifest["payload_bytes"]


def test_qint8_delta_at_most_third_of_full_f32():
    """Acceptance: a qint8 delta refresh of the gpt2-smoke tree moves
    <= 1/3 of the bytes of a full-f32 push (wire accounting only — no
    parameters materialized)."""
    abstract = abstract_params(T.model_template(get("gpt2").smoke),
                               jnp.float32)
    wire = Publisher(abstract, PublishConfig(codec="qint8")).wire
    assert wire.wire_bytes("delta") * 3 <= wire.full_f32_bytes()


def test_save_load_roundtrip(tmp_path):
    params = small_tree()
    pc = PublishConfig(codec="qint8", snapshot_every=100)
    pub, sub = Publisher(params, pc), Subscriber(params, pc)
    sub.apply(pub.publish(params, step=0))
    p1 = perturb(params, 1)
    u = pub.publish(p1, step=1)
    path = str(tmp_path / "update.npz")
    save_update(path, u)
    u2 = load_update(path)
    assert u2.manifest == u.manifest
    got = sub.apply(u2)
    assert max_err(got, p1) < 2e-3


def test_publish_config_validation():
    with pytest.raises(ValueError):
        PublishConfig(codec="nope")
    with pytest.raises(ValueError):
        PublishConfig(n_chunks=0)
    with pytest.raises(ValueError):
        PublishConfig(bucket_mb=-1.0)
    with pytest.raises(ValueError):
        PublishConfig(snapshot_every=0)
