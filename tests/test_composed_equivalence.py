"""The api_redesign acceptance gate: the composed ``compressed_dp`` path
reproduces the legacy optimizer classes EXACTLY (bitwise, sim mode).

``compressed_dp(adam_base(...), style="accumulate")`` vs ``ZeroOneAdam``
and ``style="gradient"`` vs ``OneBitAdam`` across: flat topology,
``use_pallas=True``, a two-level hierarchy (nested-vmap sim), anchor-free
mode, and scale modes — plus the mean-style composition vs the legacy
``Adam``. The legacy classes are retained exactly so these tests can pin
the refactor as behavior-preserving.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Comm, Hierarchy, OptimizerConfig, build_optimizer,
                        sim_comm, schedules as S)
from repro.core.adam import Adam
from repro.core.one_bit_adam import OneBitAdam
from repro.core.zero_one_adam import ZeroOneAdam

N = 4

PARAMS = {"w": jax.random.normal(jax.random.PRNGKey(0), (6, 16)),
          "b": jnp.zeros((5,)),
          "deep": {"k": jax.random.normal(jax.random.PRNGKey(1), (3, 8, 8))}}
NONE_T = jax.tree.map(lambda _: None, PARAMS)
TRUE_T = jax.tree.map(lambda _: True, PARAMS)

POLICIES = dict(lr=S.ConstantLr(1e-2),
                var_policy=S.AdaptiveFreezePolicy(kappa=2),
                sync_policy=S.LrProportionalSyncPolicy(
                    warmup_steps=2, double_every=3, max_interval=4))


def _rep(tree):
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (N,) + x.shape) + 0,
                        tree)


def _grads(xs, k):
    ks = jax.random.split(k, N)
    return jax.vmap(lambda kk, x: jax.tree.map(
        lambda l: jax.random.normal(jax.random.fold_in(kk, 7), l.shape),
        x))(ks, xs)


def run_flat(opt, steps=8):
    comm = sim_comm("w")
    state = jax.vmap(lambda _: opt.init(PARAMS))(jnp.arange(N))
    xs = _rep(PARAMS)
    key = jax.random.PRNGKey(3)

    @jax.jit
    def one(xs, state, k):
        return jax.vmap(lambda x, g, s: opt.step(comm, x, g, s),
                        axis_name="w")(xs, _grads(xs, k), state)

    for _ in range(steps):
        key, sk = jax.random.split(key)
        xs, state, met = one(xs, state, sk)
    return xs, state


def run_hier(opt, steps=8, inner=2):
    """Two-level sim: workers materialized as nested vmap axes
    ("pod" outer x "data" inner), exactly like Trainer.sim_step_fn."""
    comm = Comm(("pod", "data"))
    state = jax.vmap(lambda _: opt.init(PARAMS))(jnp.arange(N))
    xs = _rep(PARAMS)
    key = jax.random.PRNGKey(3)
    no = N // inner

    def lead(x):
        return x.reshape((no, inner) + x.shape[1:])

    def unlead(x):
        return x.reshape((N,) + x.shape[2:])

    mapped = jax.vmap(jax.vmap(lambda x, g, s: opt.step(comm, x, g, s),
                               axis_name="data"), axis_name="pod")

    @jax.jit
    def one(xs, state, k):
        g = _grads(xs, k)
        nx, ns, met = mapped(jax.tree.map(lead, xs), jax.tree.map(lead, g),
                             jax.tree.map(lead, state))
        return jax.tree.map(unlead, nx), jax.tree.map(unlead, ns), met

    for _ in range(steps):
        key, sk = jax.random.split(key)
        xs, state, met = one(xs, state, sk)
    return xs, state


def assert_bitwise(a, b, what=""):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), what
    for l0, l1 in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1),
                                      err_msg=what)


def _legacy_state_tuple(s):
    return (s.m, s.v, s.u, s.err_w, s.err_s, s.anchor)


def _composed_state_tuple(s):
    return (s.slots["m"], s.slots["v"], s.u, s.err_w, s.err_s, s.anchor)


@pytest.mark.parametrize("extra", [
    {},                                     # flat, paper defaults
    {"use_pallas": True},                   # fused-kernel hot path
    {"use_pallas": True, "scale_mode": "row"},
    {"store_anchor": False},                # anchor recovered from u
    {"quantize": False},                    # identity compressor
])
def test_zero_one_adam_bitwise_flat(extra):
    cfg = OptimizerConfig(name="zero_one_adam", **POLICIES, **extra)
    legacy = ZeroOneAdam(cfg, PARAMS, NONE_T, TRUE_T, N)
    composed = build_optimizer(cfg, PARAMS, n_workers=N)
    xl, sl = run_flat(legacy)
    xc, sc = run_flat(composed)
    assert_bitwise(xl, xc, f"params {extra}")
    assert_bitwise(_legacy_state_tuple(sl), _composed_state_tuple(sc),
                   f"state {extra}")


@pytest.mark.parametrize("extra", [
    {},
    {"use_pallas": True},
])
def test_zero_one_adam_bitwise_hierarchy(extra):
    cfg = OptimizerConfig(name="zero_one_adam",
                          hierarchy=Hierarchy(inner=2), **POLICIES, **extra)
    legacy = ZeroOneAdam(cfg, PARAMS, NONE_T, TRUE_T, N)
    composed = build_optimizer(cfg, PARAMS, n_workers=N)
    xl, sl = run_hier(legacy)
    xc, sc = run_hier(composed)
    assert_bitwise(xl, xc, f"params hier {extra}")
    assert_bitwise(_legacy_state_tuple(sl), _composed_state_tuple(sc),
                   f"state hier {extra}")


@pytest.mark.parametrize("extra", [
    {},
    {"use_pallas": True},
    {"hierarchy": Hierarchy(inner=2)},
])
def test_one_bit_adam_bitwise(extra):
    hier = "hierarchy" in extra
    cfg = OptimizerConfig(name="one_bit_adam", lr=S.ConstantLr(1e-2),
                          onebit_warmup=3, **extra)
    legacy = OneBitAdam(cfg, PARAMS, NONE_T, TRUE_T, N)
    composed = build_optimizer(cfg, PARAMS, n_workers=N)
    run = run_hier if hier else run_flat
    xl, sl = run(legacy, steps=6)
    xc, sc = run(composed, steps=6)
    assert_bitwise(xl, xc, f"params {extra}")
    assert_bitwise((sl.m, sl.v, sl.err_w, sl.err_s),
                   (sc.slots["m"], sc.slots["v"], sc.err_w, sc.err_s),
                   f"state {extra}")


def test_adam_mean_style_bitwise():
    """The mean-style composition is the distributed Adam baseline; state
    moves to comm-view shape but the parameter trajectory is unchanged
    bitwise (elementwise math commutes with the view reshape/pad)."""
    cfg = OptimizerConfig(name="adam", lr=S.ConstantLr(1e-2),
                          comm_dtype=jnp.float32, weight_decay=0.01)
    legacy = Adam(cfg, PARAMS, NONE_T, TRUE_T, N)
    composed = build_optimizer(cfg, PARAMS, n_workers=N)
    xl, _ = run_flat(legacy, steps=6)
    xc, _ = run_flat(composed, steps=6)
    assert_bitwise(xl, xc, "adam params (incl. weight decay)")


def test_composed_ep_leaves_stay_local():
    """dp_mask=False leaves must not communicate under the composed path."""
    params = {"dense": jnp.ones((8, 8)), "expert": jnp.ones((4, 8))}
    cfg = OptimizerConfig(name="zero_one_adam", lr=S.ConstantLr(1e-2),
                          var_policy=S.EveryStepVariancePolicy(),
                          sync_policy=S.EveryStepSyncPolicy())
    opt = build_optimizer(cfg, params,
                          dp_mask={"dense": True, "expert": False},
                          n_workers=N)
    comm = sim_comm("w")
    state = jax.vmap(lambda _: opt.init(params))(jnp.arange(N))
    xs = _rep(params)
    key = jax.random.PRNGKey(0)

    @jax.jit
    def one(xs, state, k):
        ks = jax.random.split(k, N)
        grads = jax.vmap(lambda kk, x: jax.tree.map(
            lambda l: jax.random.normal(jax.random.fold_in(kk, 3), l.shape),
            x))(ks, xs)
        return jax.vmap(lambda x, g, s: opt.step(comm, x, g, s),
                        axis_name="w")(xs, grads, state)

    for _ in range(5):
        key, sk = jax.random.split(key)
        xs, state, _ = one(xs, state, sk)
    dense, expert = np.asarray(xs["dense"]), np.asarray(xs["expert"])
    assert (dense == dense[:1]).all()
    assert not (expert == expert[:1]).all()
