"""Sharded fused buckets + shard-native kernel dispatch.

The contract under test (this PR's tentpole):

* TP-local flatten shards with the canonical manual-TP vspec fuse into
  *sharded* fused buckets — same-dtype, same-``rest_factor``, same-vspec
  members pack per-shard-contiguously, the bucket layout keeps the
  members' ``rest_factor`` (global scale denominators) and carries spec
  ``P(ax)``; ``scatter ∘ gather`` is the identity and true elements are
  conserved across shard boundaries;
* ``dispatch.kernel_safe`` is explicit about vspec/mesh consistency:
  model-sharded views under an ambient GSPMD-auto mesh stay on the kernel
  path exactly when ``shard_context`` can derive a per-shard plan, and a
  non-trivially sharded vspec on a *meshless* trace is only safe when the
  layout is shard-global (``rest_factor == 1``);
* the per-shard Pallas dispatch (``shard_map`` partitioning rule) is
  bitwise vs the jnp fallback on the same sharded views — asserted on a
  forced 8-host-device mesh in a subprocess (same pattern as
  test_cross_regime_parity);
* the two dispatch-path bugfix regressions: ``_scales_to_rows`` rejects
  non-divisible scale/row combinations instead of silently truncating,
  and ``make_bucket_plan`` resolves member dtypes strictly (dtype-less
  leaves fail loudly; mixed dtypes never fuse).
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import bucketing as BK
from repro.core import compressor as C
from repro.core import leafwise
from repro.kernels import dispatch as K

N = 4
TP = {"model": 2}


def _tp_plan(n=N, hierarchy=None):
    """A mixed tree of TP-local shards (canonical (None, 'model') vspec)
    and unsharded leaves, as the fully-manual regime plans it: leaf shapes
    are shard-LOCAL, ``model_axis_sizes`` sets the rest factor."""
    shapes = {
        "wq": jax.ShapeDtypeStruct((16, 64), jnp.float32),
        "wk": jax.ShapeDtypeStruct((16, 64), jnp.float32),
        "wv": jax.ShapeDtypeStruct((16, 64), jnp.float32),
        "bias": jax.ShapeDtypeStruct((24,), jnp.float32),
        "emb": jax.ShapeDtypeStruct((8, 16), jnp.float32),
    }
    specs = {"wq": P(None, "model"), "wk": P(None, "model"),
             "wv": P(None, "model"), "bias": P(), "emb": None}
    return leafwise.make_plan(shapes, specs, None, n,
                              model_axis_sizes=TP, hierarchy=hierarchy)


# ---------------------------------------------------------------------------
# bucket formation
# ---------------------------------------------------------------------------

def test_tp_shards_fuse_into_sharded_bucket():
    plan = _tp_plan()
    bp = BK.make_bucket_plan(plan, bucket_mb=4.0)
    sharded = [b for b in bp.buckets
               if b.fused and b.layout.rest_factor > 1]
    assert sharded, "TP-local shards must fuse, not bail to singletons"
    multi = [b for b in sharded if len(b.members) > 1]
    assert multi, "same-vspec TP shards must share one fused bucket"
    b = multi[0]
    # the bucket keeps the members' rest factor and the canonical TP spec
    assert b.layout.rest_factor == TP["model"]
    assert tuple(b.spec) == ("model",)
    assert tuple(b.vspec) == (None, "model")
    assert b.layout.flatten
    # all three same-vspec TP leaves landed in it (dict order: wk, wq, wv)
    names = sorted(plan.treedef.unflatten(range(5)).items())
    tp_idx = {i for (k, i) in names if k in ("wq", "wk", "wv")}
    assert set(b.members) == tp_idx


def test_sharded_and_unsharded_never_mix():
    plan = _tp_plan()
    bp = BK.make_bucket_plan(plan, bucket_mb=4.0)
    for b in bp.buckets:
        rfs = {plan.layouts[i].rest_factor for i in b.members}
        assert len(rfs) == 1, "one rest_factor per bucket"
        if b.fused and len(b.members) > 1:
            vss = {tuple(plan.vspecs[i]) for i in b.members}
            assert len(vss) == 1, "one vspec per fused bucket"


def test_fusable_vspec_rules():
    lo_tp = C.make_layout((16, 64), P(None, "model"), N, rest_factor=2,
                          force_flatten=True)
    assert BK.fusable(lo_tp, (None, "model"))
    # non-canonical sharded vspecs stay singletons
    assert not BK.fusable(lo_tp, ("model", None))
    assert not BK.fusable(lo_tp, (None, None, "model"))
    assert not BK.fusable(lo_tp, None)
    # structured (non-flatten) views never fuse
    lo_st = C.make_layout((16, 40), P(None, "model"), N)
    assert not lo_st.flatten
    assert not BK.fusable(lo_st, (None, None, "model"))
    # unsharded flatten leaves need a trivial vspec
    lo_flat = C.make_layout((37,), None, N)
    assert BK.fusable(lo_flat, (None, None))
    assert not BK.fusable(lo_flat, (None, "model"))


# ---------------------------------------------------------------------------
# transport properties over TP shards
# ---------------------------------------------------------------------------

def _bucket_views(plan, bucket, seed=0):
    key = jax.random.PRNGKey(seed)
    views = []
    for j, i in enumerate(bucket.members):
        lo = plan.layouts[i]
        v = jax.random.normal(jax.random.fold_in(key, j), lo.view_shape)
        m = C.pad_mask(lo)
        views.append(v * m if m is not None else v)
    return views


def test_scatter_gather_identity_over_tp_shards():
    plan = _tp_plan()
    bp = BK.make_bucket_plan(plan, bucket_mb=4.0)
    for b in bp.buckets:
        if not b.fused:
            continue
        views = _bucket_views(plan, b, seed=len(b.members))
        buf = BK.gather_views(b, views)
        assert buf.shape == b.layout.view_shape
        back = BK.scatter_views(b, buf,
                                [plan.layouts[i] for i in b.members])
        for v, r in zip(views, back):
            np.testing.assert_array_equal(np.asarray(r), np.asarray(v))


def test_true_element_conservation_across_shards():
    plan = _tp_plan()
    bp = BK.make_bucket_plan(plan, bucket_mb=4.0)
    acc = BK.bucket_accounting(bp)
    leaf_true = sum(int(np.prod(plan.layouts[i].shape))
                    for i, dp in enumerate(plan.dp_mask) if dp)
    assert acc["true_elems"] == leaf_true
    # per-shard local counts x rest_factor = global element conservation
    glob = sum(b.true_elems * b.layout.rest_factor for b in bp.buckets)
    leaf_glob = sum(int(np.prod(plan.layouts[i].shape))
                    * plan.layouts[i].rest_factor
                    for i, dp in enumerate(plan.dp_mask) if dp)
    assert glob == leaf_glob
    # every real element of a sharded bucket lands in exactly one slot
    for b in bp.buckets:
        if not b.fused or len(b.members) < 2:
            continue
        views = [C.to_view(jnp.arange(off, off + s, dtype=jnp.float32)
                           .reshape(plan.layouts[i].shape),
                           plan.layouts[i])
                 for i, off, s in zip(b.members, b.offsets, b.sizes)]
        flat = np.asarray(BK.gather_views(b, views)).reshape(-1)
        np.testing.assert_array_equal(flat[:b.true_elems],
                                      np.arange(b.true_elems))
        assert (flat[b.true_elems:] == 0).all()


def test_sharded_bucket_hierarchical_layout():
    from repro.core.comm import Hierarchy
    plan = _tp_plan(hierarchy=Hierarchy(inner=2))
    bp = BK.make_bucket_plan(plan, bucket_mb=4.0)
    sharded = [b for b in bp.buckets if b.fused and b.layout.rest_factor > 1]
    assert sharded and all(b.layout.n_inner == 2 for b in sharded), \
        "sharded fused buckets must inherit the plan's hierarchy"


# ---------------------------------------------------------------------------
# bugfix regressions
# ---------------------------------------------------------------------------

def test_scales_to_rows_rejects_non_divisible():
    # 3 scale rows cannot spread over an 8-row kernel frame: loud error,
    # never the old silent element-wise truncation
    scales = jnp.ones((3, 1), jnp.float32)
    with pytest.raises(ValueError, match="scale rows"):
        K._scales_to_rows(scales, (3,), 8)
    # zero scale rows likewise (the modulus would divide by zero)
    with pytest.raises(ValueError, match="scale rows"):
        K._scales_to_rows(jnp.ones((0, 1)), (0,), 8)
    # a layout passed through is named in the message for diagnosis
    lo = C.make_layout((37,), None, 4)
    with pytest.raises(ValueError, match="layout"):
        K._scales_to_rows(scales, (3,), 8, lo)
    # divisible combinations spread by exact repetition
    out = K._scales_to_rows(jnp.ones((2, 1), jnp.float32), (2,), 8)
    assert out.shape[0] == 8
    np.testing.assert_array_equal(np.asarray(out), np.ones(8))


def test_bucket_plan_dtype_strict():
    shapes = [jax.ShapeDtypeStruct((64,), jnp.float32),
              jax.ShapeDtypeStruct((64,), jnp.bfloat16),
              jax.ShapeDtypeStruct((64,), jnp.float32)]
    plan = leafwise.make_plan(shapes, None, None, N)
    bp = BK.make_bucket_plan(plan, bucket_mb=4.0)
    for b in bp.buckets:
        dts = {np.dtype(plan.leaves[i].dtype) for i in b.members}
        assert len(dts) == 1, "mixed-dtype leaves must never fuse"
    # f32 leaves 0 and 2 are separated by the bf16 leaf -> 3 buckets
    # (greedy in-order packing; the dtype break closes the open bucket)
    assert len(bp.buckets) == 3

    class NoDtype:
        shape = (64,)

    plan2 = leafwise.make_plan([NoDtype(), NoDtype()], None, None, N)
    with pytest.raises(ValueError, match="dtype"):
        BK.make_bucket_plan(plan2, bucket_mb=4.0)


def test_kernel_safe_vspec_mesh_consistency():
    lo_g = C.make_layout((37,), None, N)                     # rest_factor 1
    lo_l = C.make_layout((16, 64), P(None, "model"), N,      # TP-local
                         rest_factor=2, force_flatten=True)
    # trivial vspecs are always safe
    assert K.kernel_safe(None)
    assert K.kernel_safe((None, None), lo_g)
    # manual-TP axes are safe (the kernel path psums over them itself)
    assert K.kernel_safe((None, "model"), lo_l, ("model",))
    # meshless trace + sharded vspec: only shard-GLOBAL layouts are safe;
    # a shard-local layout (rest_factor > 1) would silently skip its
    # model psums on the jnp path too, so it must not claim kernel-safety
    assert K.kernel_safe((None, "model"), lo_g, ())
    assert not K.kernel_safe((None, "model"), lo_l, ())
    # without a layout a meshless trace keeps the global-view convention
    assert K.kernel_safe((None, None, "model"), None, ())


# ---------------------------------------------------------------------------
# shard_map dispatch parity on a forced 8-device mesh (subprocess, same
# pattern as test_cross_regime_parity: the forced host device count must
# not leak into other tests)
# ---------------------------------------------------------------------------

MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.core import compressor as C
    from repro.core import compat
    from repro.core import onebit_allreduce as AR
    from repro.kernels import dispatch as K

    mesh = compat.make_mesh((2, 4), ("data", "model"))
    spec = P(None, "model")
    lo = C.make_layout((16, 256), spec, 4)
    vspec = C.view_spec_entries(lo, spec)
    sh = NamedSharding(mesh, P(*vspec))
    key = jax.random.PRNGKey(0)
    z = jax.random.normal(key, lo.view_shape)
    err = jax.random.normal(jax.random.fold_in(key, 1), lo.view_shape) * .3

    # the partitioning rule engages: under the ambient auto mesh this
    # layout/vspec has a per-shard plan and kernel_safe keeps the kernels
    with mesh:
        engaged = jax.jit(lambda a: jnp.float32(
            K.kernel_safe(vspec, lo, ())))(z)
    assert float(engaged) == 1.0, "kernel_safe must keep the fused path"
    assert K.shard_context(lo, vspec) is None  # meshless: no ambient mesh

    for mode in ("tensor", "chunk", "row"):
        p_ref, s_ref, e_ref = C.ef_compress(z + err, lo, mode, None)
        with mesh:
            fn = jax.jit(lambda a, b: K.ef_compress_view(
                a, b, lo, mode, vspec=vspec))
            p_k, s_k, e_k = fn(jax.device_put(z, sh),
                               jax.device_put(err, sh))
        np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_ref))
        np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_ref),
                                   rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(np.asarray(e_k), np.asarray(e_ref),
                                   rtol=1e-5, atol=1e-6)
        v_ref = C.decompress(p_ref, s_ref, lo.pack_count)
        with mesh:
            v_k = jax.jit(lambda p, s: K.decompress_view(
                p, s, lo, vspec=vspec))(p_k, s_k)
        np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_ref),
                                   rtol=1e-6, atol=1e-7)

        widx = 2
        avg = jax.random.normal(jax.random.PRNGKey(7), lo.chunk_shape)
        es = jax.random.normal(jax.random.PRNGKey(8), lo.chunk_shape) * .2
        p_ref, s_ref, e_ref = AR._server_compress((avg + es)[None], lo,
                                                  mode, None)
        with mesh:
            fn = jax.jit(lambda a, b, w: K.server_compress_view(
                a, b, lo, mode, w, vspec=vspec))
            p_k, s_k, e_k = fn(jax.device_put(avg[None], sh),
                               jax.device_put(es[None], sh), widx)
        np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_ref))
        np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_ref),
                                   rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(np.asarray(e_k), np.asarray(e_ref),
                                   rtol=1e-5, atol=1e-6)
        print("MODE_OK", mode)

    # fused local step (adam kind), elementwise per shard
    ks = jax.random.split(jax.random.PRNGKey(23), 4)
    g, m, u = (jax.random.normal(k, lo.view_shape) for k in ks[:3])
    v = jnp.abs(jax.random.normal(ks[3], lo.view_shape)) + 1e-3
    lr, b1, eps = jnp.float32(3e-3), 0.9, 1e-8
    with mesh:
        fn = jax.jit(lambda g_, m_, u_, v_, lr_: K.fused_local_step_view(
            g_, m_, u_, v_, lr_, b1, eps, lo, vspec=vspec))
        mh_k, u_k, d_k = fn(jax.device_put(g, sh), jax.device_put(m, sh),
                            jax.device_put(u, sh), jax.device_put(v, sh),
                            lr)
    mh = b1 * m + (1 - b1) * g
    np.testing.assert_allclose(np.asarray(mh_k), np.asarray(mh),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(u_k), np.asarray(u + lr * mh),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(d_k),
                               np.asarray(lr * mh / jnp.sqrt(v + eps)),
                               rtol=1e-6, atol=1e-6)
    print("FUSED_STEP_OK")

    # trainer-realistic nesting: the wrapper under vmap(axis_name='w')
    W = 4
    zw = jax.random.normal(key, (W,) + lo.view_shape)
    ew = jax.random.normal(jax.random.fold_in(key, 9),
                           (W,) + lo.view_shape) * .3
    p_ref, s_ref, e_ref = jax.vmap(
        lambda a, b: C.ef_compress(a + b, lo, "tensor", None),
        axis_name="w")(zw, ew)
    shw = NamedSharding(mesh, P(None, None, None, "model"))
    with mesh:
        fn = jax.jit(jax.vmap(lambda a, b: K.ef_compress_view(
            a, b, lo, "tensor", vspec=vspec), axis_name="w"))
        p_k, s_k, e_k = fn(jax.device_put(zw, shw),
                           jax.device_put(ew, shw))
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_ref))
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_ref),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(e_k), np.asarray(e_ref),
                               rtol=1e-5, atol=1e-6)
    print("VMAP_OK")

    # non-divisible local columns (40 / 4 devices = 10, not % 8): no shard
    # plan, kernel_safe routes to the constrained jnp path instead
    lo2 = C.make_layout((16, 40), spec, 4)
    vs2 = C.view_spec_entries(lo2, spec)
    with mesh:
        safe = jax.jit(lambda a: jnp.float32(
            K.kernel_safe(vs2, lo2, ())))(z)
    assert float(safe) == 0.0, "indivisible shard must fall back"
    print("FALLBACK_OK")
""")


@pytest.mark.slow
def test_sharded_dispatch_bitwise_on_mesh():
    r = subprocess.run([sys.executable, "-c", MESH_SCRIPT],
                       capture_output=True, text=True, timeout=1200,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    out = r.stdout
    assert r.returncode == 0, out[-2000:] + r.stderr[-3000:]
    assert out.count("MODE_OK") == 3, out
    for tag in ("FUSED_STEP_OK", "VMAP_OK", "FALLBACK_OK"):
        assert tag in out, out
