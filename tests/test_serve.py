"""Serving engine: prefill->decode greedy loop equals teacher-forced
forward; window-cache (ring buffer) decode equals full-cache decode."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.models import transformer as T
from repro.models.layers import init_params
from repro.serve import Server


def test_engine_prefill_decode_matches_forward():
    cfg = get("granite-3-8b").smoke
    params = init_params(T.model_template(cfg), jax.random.PRNGKey(0))
    B, PROMPT, GEN = 2, 10, 4
    srv = Server(cfg, batch=B, max_seq=32, cache_dtype=jnp.float32)
    prefill, decode = srv.prefill_fn(), srv.decode_fn()
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, PROMPT + GEN), 0,
                              cfg.vocab)
    cache = T.init_cache(cfg, B, 32, dtype=jnp.float32)
    lg, cache = prefill(params, {"tokens": toks[:, :PROMPT]}, cache)
    got = [np.asarray(lg[:, 0])]
    for i in range(GEN - 1):
        lg, cache = decode(params, cache, toks[:, PROMPT + i:PROMPT + i + 1],
                           jnp.int32(PROMPT + i))
        got.append(np.asarray(lg[:, 0]))
    full, _ = T.forward(params, cfg, {"tokens": toks, "labels": toks})
    for i, g in enumerate(got):
        np.testing.assert_allclose(
            g, np.asarray(full[:, PROMPT - 1 + i]), rtol=2e-4, atol=2e-4)


def test_window_cache_ring_decode_equals_full_cache():
    cfg = get("gemma3-12b").smoke   # sliding_window=8, global_every=6
    params = init_params(T.model_template(cfg), jax.random.PRNGKey(0))
    wcfg = dataclasses.replace(cfg, window_cache=True)
    B, STEPS = 2, 24                # > 2x window to exercise wraparound
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, STEPS), 0,
                              cfg.vocab)
    full = T.init_cache(cfg, B, 32, dtype=jnp.float32)
    ring = T.init_cache(wcfg, B, 32, dtype=jnp.float32)
    # ring cache is the whole point: much smaller local stacks
    assert ring["local"]["k"].shape[2] == cfg.sliding_window
    assert ring["global"]["k"].shape[0] == cfg.n_global_layers
    for i in range(STEPS):
        t = toks[:, i:i + 1]
        lf, full = T.decode(params, cfg, t, full, jnp.int32(i))
        lr_, ring = T.decode(params, wcfg, t, ring, jnp.int32(i))
        np.testing.assert_allclose(np.asarray(lr_), np.asarray(lf),
                                   rtol=2e-4, atol=2e-4)
