"""Serving engine: prefill->decode greedy loop equals teacher-forced
forward; window-cache (ring buffer) decode equals full-cache decode;
continuous-batching scheduler equals the unbatched path per request."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.models import transformer as T
from repro.models.layers import init_params
from repro.serve import (Publisher, PublishConfig, Request, Scheduler,
                         Server, Subscriber)


def test_engine_prefill_decode_matches_forward():
    cfg = get("granite-3-8b").smoke
    params = init_params(T.model_template(cfg), jax.random.PRNGKey(0))
    B, PROMPT, GEN = 2, 10, 4
    srv = Server(cfg, batch=B, max_seq=32, cache_dtype=jnp.float32)
    prefill, decode = srv.prefill_fn(), srv.decode_fn()
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, PROMPT + GEN), 0,
                              cfg.vocab)
    cache = T.init_cache(cfg, B, 32, dtype=jnp.float32)
    lg, cache = prefill(params, {"tokens": toks[:, :PROMPT]}, cache)
    got = [np.asarray(lg[:, 0])]
    for i in range(GEN - 1):
        lg, cache = decode(params, cache, toks[:, PROMPT + i:PROMPT + i + 1],
                           jnp.int32(PROMPT + i))
        got.append(np.asarray(lg[:, 0]))
    full, _ = T.forward(params, cfg, {"tokens": toks, "labels": toks})
    for i, g in enumerate(got):
        np.testing.assert_allclose(
            g, np.asarray(full[:, PROMPT - 1 + i]), rtol=2e-4, atol=2e-4)


def test_window_cache_ring_decode_equals_full_cache():
    cfg = get("gemma3-12b").smoke   # sliding_window=8, global_every=6
    params = init_params(T.model_template(cfg), jax.random.PRNGKey(0))
    wcfg = dataclasses.replace(cfg, window_cache=True)
    B, STEPS = 2, 24                # > 2x window to exercise wraparound
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, STEPS), 0,
                              cfg.vocab)
    full = T.init_cache(cfg, B, 32, dtype=jnp.float32)
    ring = T.init_cache(wcfg, B, 32, dtype=jnp.float32)
    # ring cache is the whole point: much smaller local stacks
    assert ring["local"]["k"].shape[2] == cfg.sliding_window
    assert ring["global"]["k"].shape[0] == cfg.n_global_layers
    for i in range(STEPS):
        t = toks[:, i:i + 1]
        lf, full = T.decode(params, cfg, t, full, jnp.int32(i))
        lr_, ring = T.decode(params, wcfg, t, ring, jnp.int32(i))
        np.testing.assert_allclose(np.asarray(lr_), np.asarray(lf),
                                   rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------------ #
# continuous-batching scheduler
# ------------------------------------------------------------------ #

def _mk(cfg, params, seed, n, base_prompt=5, base_gen=3):
    """Staggered request mix: varying prompt lengths and budgets."""
    key = jax.random.PRNGKey(seed)
    return [Request(rid=i,
                    prompt=np.asarray(jax.random.randint(
                        jax.random.fold_in(key, i),
                        (base_prompt + 2 * i,), 0, cfg.vocab)).tolist(),
                    max_new_tokens=base_gen + i)
            for i in range(n)]


def _unbatched_reference(cfg, params, prompt, gen, max_seq=64):
    cache = T.init_cache(cfg, 1, max_seq, dtype=jnp.float32)
    lg, cache = T.prefill(
        params, cfg, {"tokens": jnp.asarray(prompt, jnp.int32)[None]},
        cache)
    tok = int(jnp.argmax(lg[0, -1, :cfg.vocab]))
    out, pos = [tok], len(prompt)
    for _ in range(gen - 1):
        lg, cache = T.decode(params, cfg, jnp.asarray([[tok]], jnp.int32),
                             cache, jnp.int32(pos))
        tok = int(jnp.argmax(lg[0, 0, :cfg.vocab]))
        out.append(tok)
        pos += 1
    return out


def test_scheduler_matches_unbatched_decode():
    """Acceptance: N concurrent requests through the slot scheduler give
    per-request token ids identical to the unbatched prefill/decode loop
    (slot reuse exercised: more requests than slots, staggered lengths)."""
    cfg = get("gpt2").smoke
    params = init_params(T.model_template(cfg), jax.random.PRNGKey(0))
    srv = Server(cfg, batch=3, max_seq=64, cache_dtype=jnp.float32)
    sch = Scheduler(srv, params)
    reqs = _mk(cfg, params, seed=7, n=5)
    sch.run(reqs)
    for r in reqs:
        assert r.done
        assert r.output == _unbatched_reference(cfg, params, r.prompt,
                                                r.max_new_tokens)


def test_scheduler_slot_admit_evict_invariants():
    cfg = get("gpt2").smoke
    params = init_params(T.model_template(cfg), jax.random.PRNGKey(0))
    srv = Server(cfg, batch=2, max_seq=64, cache_dtype=jnp.float32)
    sch = Scheduler(srv, params)
    reqs = _mk(cfg, params, seed=3, n=5, base_gen=2)
    for r in reqs:
        sch.submit(r)
    seen_active = 0
    for _ in range(200):
        if sch.idle:
            break
        sch.tick()
        assert sch.active <= sch.n_slots
        seen_active = max(seen_active, sch.active)
        for r in reqs:
            assert len(r.output) <= r.max_new_tokens
            if r.done:                       # evicted on completion
                assert r not in sch.slots
        in_flight = ([r for r in sch.slots if r is not None]
                     + list(sch.queue))
        assert len(in_flight) + sum(r.done for r in reqs) == len(reqs)
    assert sch.idle
    assert seen_active == sch.n_slots        # batching actually happened
    assert all(r.done and len(r.output) == r.max_new_tokens
               for r in reqs)
    assert sch.stats["prefills"] == len(reqs)


def test_scheduler_weight_swap_transparent_and_counted():
    """A mid-serve identity-codec publish of the SAME params must not
    change any output token (the swap happens at a tick boundary and the
    decoded tree is bitwise the served tree); the swap is counted."""
    cfg = get("gpt2").smoke
    params = init_params(T.model_template(cfg), jax.random.PRNGKey(0))

    def run(with_swap):
        srv = Server(cfg, batch=2, max_seq=64, cache_dtype=jnp.float32)
        sub = None
        if with_swap:
            pc = PublishConfig(codec="identity", bucket_mb=4.0)
            pub, sub = Publisher(params, pc), Subscriber(params, pc)
        sch = Scheduler(srv, params, subscriber=sub)
        reqs = _mk(cfg, params, seed=11, n=3, base_gen=4)
        for r in reqs:
            sch.submit(r)
        ticks = 0
        while not sch.idle:
            if with_swap and ticks == 2:
                sub.push(pub.publish(params, step=1))
            sch.tick()
            ticks += 1
        return [r.output for r in reqs], sch.stats["weight_swaps"]

    base, swaps0 = run(with_swap=False)
    swapped, swaps1 = run(with_swap=True)
    assert swaps0 == 0 and swaps1 >= 1
    assert base == swapped


def test_scheduler_kv_quant_pages():
    cfg = get("gpt2").smoke
    params = init_params(T.model_template(cfg), jax.random.PRNGKey(0))
    srv = Server(cfg, batch=2, max_seq=32, cache_dtype=jnp.float32)
    sch = Scheduler(srv, params, kv_quant="qint8", kv_page=8)
    reqs = [Request(rid=i, prompt=list(range(2, 12)), max_new_tokens=12)
            for i in range(2)]
    sch.run(reqs)
    assert all(r.done and len(r.output) == 12 for r in reqs)
    # each slot reaches pos 21 -> floor(21/8) = 2 completed pages
    assert sch.stats["pages_quantized"] == 4


def test_scheduler_rejects_oversized_and_encoder():
    cfg = get("gpt2").smoke
    params = init_params(T.model_template(cfg), jax.random.PRNGKey(0))
    srv = Server(cfg, batch=1, max_seq=16, cache_dtype=jnp.float32)
    sch = Scheduler(srv, params)
    with pytest.raises(ValueError, match="max_seq"):
        sch.submit(Request(rid=0, prompt=list(range(12)),
                           max_new_tokens=8))
    enc_cfg = get("whisper-large-v3").smoke
    enc_srv = Server(enc_cfg, batch=1, max_seq=16)
    with pytest.raises(ValueError, match="encoder"):
        Scheduler(enc_srv, params)
