"""Cross-regime parity: a full ZeroOneAdam trainer step executed under the
mesh regime (multi-device GSPMD lowering of the worker axes on a debug
mesh) must match the sim regime (single-device vmap) to <= 1e-6 per leaf,
for flat and hierarchical topologies, with and without the Pallas kernels.

This is the end-to-end guarantee behind every sim-mode convergence result:
whatever the tests prove under vmap is what the partitioned multi-device
program computes. Runs in a subprocess so the forced host device count
never leaks into other tests (same pattern as test_dryrun_small).

On jax 0.4.x the mesh regime lowers through GSPMD + vmap-over-workers (see
Trainer.mesh_step_fn); the two regimes then share a trace but compile to
different partitioned programs, so the comparison still exercises the
multi-device lowering. On newer jax the mesh regime is the partial-manual
shard_map path with fully-manual (flattened) optimizer layouts, whose
state layout differs from sim's — the state-layout guard below reports
that combination as SKIP instead of silently comparing mismatched trees.
"""
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get
    from repro.core import Hierarchy, OptimizerConfig, schedules as S
    from repro.data import DataConfig, SyntheticLM
    from repro.launch.mesh import make_debug_mesh
    from repro.train import Trainer, TrainerConfig

    def opt_cfg(h, pallas, name, bucket_mb=None):
        return OptimizerConfig(
            name=name,
            lr=S.LinearWarmupExpDecay(peak_lr=2e-3, warmup_steps=10,
                                      decay=0.97, decay_period=20),
            var_policy=S.AdaptiveFreezePolicy(kappa=2),
            sync_policy=S.LrProportionalSyncPolicy(
                warmup_steps=10, double_every=20, max_interval=4),
            hierarchy=h, use_pallas=pallas, bucket_mb=bucket_mb,
            comm_dtype=jnp.float32)   # exact wire: parity at 1e-6

    import sys
    arch = sys.argv[3] if len(sys.argv) > 3 else "gpt2"
    mb_arg = float(sys.argv[4]) if len(sys.argv) > 4 else 0.25
    cfg = get(arch).smoke
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=16,
                                  global_batch=8, seed=3))
    mesh = make_debug_mesh(pod=2, data=2, model=2)
    W = ("pod", "data")

    def fdiff(a, b):
        out = 0.0
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            x, y = np.asarray(x), np.asarray(y)
            if np.issubdtype(x.dtype, np.floating):
                out = max(out, float(np.abs(x.astype(np.float64)
                                            - y.astype(np.float64)).max()))
        return out

    parts = sys.argv[1].split("-")
    topology, kernels = parts[0], parts[1]
    bucketed = "bucketed" in parts[2:]
    opt_name = sys.argv[2] if len(sys.argv) > 2 else "zero_one_adam"
    COMBOS = [(sys.argv[1],
               Hierarchy(inner=2) if topology == "hier" else None,
               kernels == "pallas")]
    for tag, h, pallas in COMBOS:
        oc = opt_cfg(h, pallas, opt_name,
                     bucket_mb=mb_arg if bucketed else None)
        tr_sim = Trainer(cfg, oc, n_workers=4)
        p, s = tr_sim.sim_init(jax.random.PRNGKey(0))
        tr_mesh = Trainer(cfg, oc, mesh=mesh,
                          trainer_cfg=TrainerConfig(worker_axes=W,
                                                    donate=False))
        fn_sim = tr_sim.sim_step_fn()
        fn_mesh, _ = tr_mesh.mesh_step_fn()
        # mesh state layout: per-worker leaves keep the stacked axis,
        # shared scalars drop it
        sf, _ = tr_mesh.tree_specs.state_specs()
        def to_mesh(x, spec):
            ent = tuple(spec)
            stacked = bool(ent) and ent[0] == W
            return x if stacked else x[0]
        s_mesh = jax.tree.map(to_mesh, s, sf)
        _, s_abs, _ = tr_mesh.abstract_inputs(8, 16)
        shapes_ok = all(
            tuple(a.shape) == tuple(np.shape(b))
            for a, b in zip(jax.tree.leaves(s_abs), jax.tree.leaves(s_mesh)))
        if not shapes_ok:
            print("SKIP", tag, "state layouts differ between regimes")
            continue
        p_sim, s_sim = p, s
        p_mesh = p
        # 2 steps cover every branch: warmup syncs fire each step, the
        # variance refresh at step 0, local-only updates in between
        for step in range(2):
            b = data.batch(step)
            p_sim, s_sim, met_s = fn_sim(p_sim, s_sim, b)
            p_mesh, s_mesh, met_m = fn_mesh(p_mesh, s_mesh, b)
        dp = fdiff(p_sim, p_mesh)
        dm = fdiff(s_sim.m, s_mesh.m)
        dv = fdiff(s_sim.v, s_mesh.v)
        dw = fdiff(s_sim.err_w, s_mesh.err_w)
        dl = abs(float(np.asarray(met_s["loss"]).reshape(-1)[0])
                 - float(np.asarray(met_m["loss"]).reshape(-1)[0]))
        worst = max(dp, dm, dv, dw, dl)
        assert worst <= 1e-6, (tag, dp, dm, dv, dw, dl)
        print(f"PARITY_OK {tag} params={dp:.2e} m={dm:.2e} v={dv:.2e} "
              f"err_w={dw:.2e} loss={dl:.2e}")
""")


def _run_combo(combo, opt_name, arch="gpt2", bucket_mb=0.25):
    r = subprocess.run([sys.executable, "-c", SCRIPT, combo, opt_name,
                        arch, str(bucket_mb)],
                       capture_output=True, text=True, timeout=1200,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    out = r.stdout
    assert r.returncode == 0, out[-2000:] + r.stderr[-3000:]
    done = out.count("PARITY_OK") + out.count("SKIP")
    assert done == 1, out[-2000:] + r.stderr[-2000:]
    # NOTE a SKIP (future-jax state-layout divergence, see module
    # docstring) is accepted per combo; the jnp combos always compare on
    # the supported platforms, keeping the test non-vacuous


@pytest.mark.slow
@pytest.mark.parametrize("combo", ["flat-jnp", "hier-jnp",
                                   "flat-pallas", "hier-pallas"])
def test_mesh_matches_sim_zero_one_adam(combo):
    _run_combo(combo, "zero_one_adam")


@pytest.mark.slow
def test_mesh_matches_sim_zero_one_lamb():
    """0/1-LAMB carries per-leaf trust scalars (state kind "leaf_scalar");
    this pins their mesh-regime sharding/stacking against sim."""
    _run_combo("flat-jnp", "zero_one_lamb")


@pytest.mark.slow
def test_mesh_matches_sim_bucketed_hier_pallas():
    """Bucketed exchange x hierarchy x pallas: the bucket-shaped state
    kinds (bucket_view/bucket_chunk) must shard/stack identically in the
    mesh regime — this is the combination that exercises every new layer
    of the bucketing path at once."""
    _run_combo("hier-pallas-bucketed", "zero_one_adam")


@pytest.mark.slow
def test_mesh_matches_sim_deepseek_pallas_bucketed():
    """deepseek-smoke (MoE + MLA, a first-class fused workload): the
    Pallas-dispatched bucketed exchange (--use-pallas --bucket-mb 4) must
    lower identically under the model-sharded debug mesh and the sim
    regime — the TP leaves' views and the fused buckets take the exact
    same kernel-vs-jnp dispatch decisions in both."""
    _run_combo("flat-pallas-bucketed", "zero_one_adam",
               arch="deepseek-v2-236b", bucket_mb=4.0)
