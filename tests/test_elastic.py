"""Elastic data-parallelism: reshard correctness, fault-sim parity, and
width-agnostic checkpoint restore.

Property suite (hypothesis where available, deterministic sweep fallback
as in test_bucketing.py) for the chunk remap at the heart of
``repro.elastic.reshard``:

  * the remap is a permutation of the true (unpadded) elements — the
    natural leaf read back from the m-width view is bitwise the source;
  * per-leaf true-element counts are conserved n -> m -> n and the clean
    round trip is bitwise the identity;
  * garbage written into pad positions of the source view never crosses
    the remap (destination pads land exactly zero).

Plus the PR's acceptance gates: bitwise m = n round trips of the full
(params, state) trees across flat/hierarchical x per-leaf/bucketed,
EF-residual mass conservation under shrink/grow, pod-alignment
validation, n-worker checkpoints restored into m-worker trainers, and
the (slow) kill -> shrink -> rejoin FleetSim run inside the
bench_convergence parity tolerance.
"""
import os
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from jax.sharding import PartitionSpec as P

from repro.checkpointing import io as ckpt_io
from repro.configs import get
from repro.core import Hierarchy, OptimizerConfig, schedules as S
from repro.core import compressor as C
from repro.data import DataConfig, SyntheticLM
from repro.elastic import (FleetSim, ResizeEvent, parity_gap, reshard_report,
                           reshard_trainer, restore_resharded, worker_origin)
import importlib

# the package re-exports the `reshard` *function* under the same name as
# the submodule; go through importlib for the module's private helpers
R = importlib.import_module("repro.elastic.reshard")
from repro.train import Trainer

CFG = get("gpt2").smoke
SEQ, BATCH = 16, 8

OPT_BASE = dict(
    name="zero_one_adam", lr=S.ConstantLr(1e-3),
    var_policy=S.AdaptiveFreezePolicy(kappa=2),
    sync_policy=S.LrProportionalSyncPolicy(warmup_steps=2, double_every=3,
                                           max_interval=2))

VARIANTS = {
    "flat": {},
    "flat_bucketed": dict(bucket_mb=0.25),
    "hier": dict(hierarchy=Hierarchy(inner=2)),
    "hier_bucketed": dict(hierarchy=Hierarchy(inner=2), bucket_mb=0.25),
}


# --------------------------------------------------------------------- #
# chunk-remap properties
# --------------------------------------------------------------------- #

def _check_remap(shape, spec, n, m, seed, n_inner=1, m_inner=1):
    lo_n = C.make_layout(shape, spec, n, n_inner=n_inner)
    lo_m = C.make_layout(shape, spec, m, n_inner=m_inner)
    size = int(np.prod(shape))
    rng = np.random.default_rng(seed)
    x = (rng.permutation(size) + 1.0).astype(np.float32).reshape(shape)
    v = C.to_view(jnp.asarray(x), lo_n)
    mask = C.pad_mask(lo_n)
    clean = v if mask is None else v * mask
    dirty = v if mask is None else clean + 1e9 * (1 - mask)

    fwd = R._remap_fn(lo_n, lo_m)
    if lo_n == lo_m:
        # the identity short-circuit: bitwise, pads and all
        np.testing.assert_array_equal(np.asarray(fwd(dirty)),
                                      np.asarray(dirty))
        return
    v_m = fwd(dirty)
    # permutation of true elements: the natural leaf reads back bitwise
    np.testing.assert_array_equal(np.asarray(C.from_view(v_m, lo_m)), x)
    # pad garbage never crosses: destination pads land exactly zero
    mask_m = C.pad_mask(lo_m)
    if mask_m is not None:
        assert (np.asarray(v_m * (1 - mask_m)) == 0).all()
    # true-count conservation across the widths
    tot_n, per_n = C.true_counts(lo_n)
    tot_m, per_m = C.true_counts(lo_m)
    assert tot_n == tot_m == size
    assert per_n.sum() == per_m.sum() == size
    # n -> m -> n is bitwise the identity on clean views
    v_back = R._remap_fn(lo_m, lo_n)(fwd(clean))
    np.testing.assert_array_equal(np.asarray(v_back), np.asarray(clean))


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(size=st.integers(1, 700),
           n=st.sampled_from([1, 2, 4, 8]),
           m=st.sampled_from([1, 2, 4, 8]),
           seed=st.integers(0, 2**31 - 1))
    def test_remap_properties(size, n, m, seed):
        _check_remap((size,), None, n, m, seed)
else:
    @pytest.mark.parametrize("size,n,m,seed", [
        (5, 4, 2, 0),
        (700, 4, 8, 1),
        (37, 4, 4, 2),     # identity short-circuit
        (64, 2, 4, 3),
        (1, 1, 4, 4),
        (513, 8, 2, 5),
    ])
    def test_remap_properties(size, n, m, seed):
        _check_remap((size,), None, n, m, seed)


@pytest.mark.parametrize("shape,spec,n,m,ni,mi", [
    ((13, 40), P(None, "model"), 4, 2, 1, 1),   # structured, padded rows
    ((6, 4, 24), P(None, None, "model"), 2, 4, 1, 1),
    ((37,), None, 4, 4, 2, 2),                  # hier identity
    ((200,), None, 4, 2, 2, 2),                 # hier shrink
    ((200,), None, 4, 2, 2, 1),                 # hier -> flat
])
def test_remap_structured_and_hierarchical(shape, spec, n, m, ni, mi):
    _check_remap(shape, spec, n, m, seed=7, n_inner=ni, m_inner=mi)


# --------------------------------------------------------------------- #
# origin maps
# --------------------------------------------------------------------- #

def test_worker_origin_marks_joiners():
    assert worker_origin(2, 4) == (0, 1, -1, -1)
    assert worker_origin(4, 2) == (0, 1)
    assert worker_origin(4, 2, survivors=(0, 2)) == (0, 2)
    assert worker_origin(4, 4, survivors=(3, 1)) == (3, 1, -1, -1)


def test_worker_origin_validates():
    with pytest.raises(ValueError, match="duplicates"):
        worker_origin(4, 4, survivors=(0, 0))
    with pytest.raises(ValueError, match="not a worker"):
        worker_origin(4, 4, survivors=(5,))
    with pytest.raises(ValueError, match="do not fit"):
        worker_origin(4, 2, survivors=(0, 1, 2))


# --------------------------------------------------------------------- #
# trained-state round trips (the tentpole acceptance)
# --------------------------------------------------------------------- #

_TRAINED = {}


def _trained(variant, n=4, steps=6):
    """One trained (trainer, params, state) per variant, cached — every
    test reads it, none mutates it (jax arrays are immutable)."""
    key = (variant, n, steps)
    if key not in _TRAINED:
        opt_cfg = OptimizerConfig(**OPT_BASE, **VARIANTS[variant])
        tr = Trainer(CFG, opt_cfg, n_workers=n)
        params, state = tr.sim_init(jax.random.PRNGKey(5))
        fn = tr.sim_step_fn()
        data = SyntheticLM(DataConfig(vocab=CFG.vocab, seq_len=SEQ,
                                      global_batch=BATCH, seed=5))
        for t in range(steps):
            params, state, _ = fn(params, state, data.batch(t))
        _TRAINED[key] = (tr, params, state)
    return _TRAINED[key]


def _assert_trees_bitwise(t0, t1):
    l0, l1 = jax.tree.leaves(t0), jax.tree.leaves(t1)
    assert len(l0) == len(l1)
    for a, b in zip(l0, l1):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("variant", list(VARIANTS))
def test_reshard_roundtrip_bitwise_at_same_width(variant):
    """m = n resharding is the identity, bitwise, for params + EF state +
    anchors — across flat/hierarchical x per-leaf/bucketed."""
    tr, params, state = _trained(variant)
    dst = Trainer(CFG, tr.opt_cfg, n_workers=tr.n_workers)
    p2, s2 = reshard_trainer(tr, dst, params, state)
    _assert_trees_bitwise(params, p2)
    _assert_trees_bitwise(state, s2)


def test_shrink_conserves_ef_mass_and_err_s_content():
    """4 -> 2 with a killed worker: the total pending worker-side
    correction (1/n)*sum(err_w) is conserved, and the server-side
    residual's true elements move positionally (bitwise through the
    natural leaf)."""
    tr, params, state = _trained("flat")
    dst = Trainer(CFG, tr.opt_cfg, n_workers=2)
    p2, s2 = reshard_trainer(tr, dst, params, state, survivors=(0, 2))

    saw_nonzero = False
    for i, (ew, ew2) in enumerate(zip(state.err_w, s2.err_w)):
        if ew is None:
            assert ew2 is None
            continue
        m_src = float(np.asarray(ew, np.float64).sum()) / tr.n_workers
        m_dst = float(np.asarray(ew2, np.float64).sum()) / 2
        np.testing.assert_allclose(m_dst, m_src, rtol=1e-5, atol=1e-7)
        saw_nonzero |= bool(np.abs(np.asarray(ew)).sum() > 0)
    assert saw_nonzero, "run too short: EF residuals never became nonzero"

    for i, (es, es2) in enumerate(zip(state.err_s, s2.err_s)):
        if es is None:
            assert es2 is None
            continue
        lo_s, lo_d = tr.opt.layouts[i], dst.opt.layouts[i]
        nat_src = C.from_view(es[R._owner_of_rows(lo_s.n, lo_s.n_inner)],
                              lo_s)
        nat_dst = C.from_view(es2[R._owner_of_rows(lo_d.n, lo_d.n_inner)],
                              lo_d)
        np.testing.assert_array_equal(np.asarray(nat_src),
                                      np.asarray(nat_dst))

    rep = reshard_report(tr.opt, dst.opt, survivors=(0, 2))
    assert rep["n_from"] == 4 and rep["n_to"] == 2
    assert rep["carried_entities"] == 2 and rep["dead_entities"] == 2
    assert rep["joiner_workers"] == 0 and rep["ef_fold"] is True


def test_grow_zeroes_joiner_u_and_clones_params():
    """2 -> 4 rejoin: joiners start with zero local accumulation, clone a
    survivor's params/momentum, and residual mass is conserved through
    the fold (alpha = m_e/n_e)."""
    tr, params, state = _trained("flat", n=2)
    dst = Trainer(CFG, tr.opt_cfg, n_workers=4)
    p4, s4 = reshard_trainer(tr, dst, params, state)

    for x in jax.tree.leaves(p4):
        np.testing.assert_array_equal(np.asarray(x[2]), np.asarray(x[0]))
        np.testing.assert_array_equal(np.asarray(x[3]), np.asarray(x[0]))
    for u in s4.u:
        if u is None:
            continue
        assert (np.asarray(u[2:]) == 0).all(), "joiner u must start at zero"
    for ew, ew4 in zip(state.err_w, s4.err_w):
        if ew is None:
            continue
        m_src = float(np.asarray(ew, np.float64).sum()) / 2
        m_dst = float(np.asarray(ew4, np.float64).sum()) / 4
        np.testing.assert_allclose(m_dst, m_src, rtol=1e-5, atol=1e-7)
    np.testing.assert_array_equal(np.asarray(s4.step),
                                  np.full((4,), np.asarray(state.step)[0]))

    rep = reshard_report(tr.opt, dst.opt)
    assert rep["joiner_workers"] == 2 and rep["dead_entities"] == 0
    assert rep["ef_fold"] is True  # entity count changed: 2 -> 4


def test_hierarchical_survivors_must_be_pod_aligned():
    tr, _, _ = _trained("hier")
    dst = Trainer(CFG, tr.opt_cfg, n_workers=2)
    with pytest.raises(ValueError, match="pod-aligned"):
        reshard_report(tr.opt, dst.opt, survivors=(0, 2))
    # pod-mates kept together is fine
    rep = reshard_report(tr.opt, dst.opt, survivors=(2, 3))
    assert rep["carried_entities"] == 1 and rep["dead_entities"] == 1


def test_duplicated_pod_carry_raises():
    """Hier (inner=2) -> flat: two destination entities drawing from one
    source pod would duplicate its EF residual."""
    tr, _, _ = _trained("hier")
    flat_cfg = OptimizerConfig(**OPT_BASE)
    dst = Trainer(CFG, flat_cfg, n_workers=2)
    with pytest.raises(ValueError, match="several destination"):
        reshard_report(tr.opt, dst.opt, survivors=(0, 1))


def test_hierarchical_pod_shrink_roundtrip_bitwise():
    """Kill a whole pod (4 -> 2, inner=2), rejoin it (2 -> 4): surviving
    pod's params/EF state come back bitwise; the resized state trains."""
    tr, params, state = _trained("hier")
    mid = Trainer(CFG, tr.opt_cfg, n_workers=2)
    p2, s2 = reshard_trainer(tr, mid, params, state, survivors=(0, 1))
    back = Trainer(CFG, tr.opt_cfg, n_workers=4)
    p4, s4 = reshard_trainer(mid, back, p2, s2)
    for x, x4 in zip(jax.tree.leaves(params), jax.tree.leaves(p4)):
        np.testing.assert_array_equal(np.asarray(x[:2]),
                                      np.asarray(x4[:2]))
    fn = back.sim_step_fn()
    data = SyntheticLM(DataConfig(vocab=CFG.vocab, seq_len=SEQ,
                                  global_batch=BATCH, seed=11))
    _, _, met = fn(p4, s4, data.batch(0))
    assert np.isfinite(float(np.asarray(met["loss"]).reshape(-1)[0]))


# --------------------------------------------------------------------- #
# width-agnostic checkpoint restore
# --------------------------------------------------------------------- #

def _save_trained(tmp_path, variant="flat", n=4):
    tr, params, state = _trained(variant, n=n)
    path = os.path.join(tmp_path, "ck.npz")
    ckpt_io.save(path, {"params": params, "state": state}, step=6,
                 meta={"arch": CFG.name, "n_workers": n})
    return path, tr, params, state


def test_restore_resharded_same_width_is_bitwise(tmp_path):
    path, tr, params, state = _save_trained(tmp_path)
    dst = Trainer(CFG, tr.opt_cfg, n_workers=4)
    p, s, step, meta = restore_resharded(path, dst)
    assert step == 6 and meta["n_workers"] == 4
    _assert_trees_bitwise(params, p)
    _assert_trees_bitwise(state, s)


def test_restore_resharded_into_narrower_trainer(tmp_path):
    path, tr, _, _ = _save_trained(tmp_path)
    dst = Trainer(CFG, tr.opt_cfg, n_workers=2)
    p, s, step, _ = restore_resharded(path, dst, survivors=(0, 2))
    assert step == 6
    assert tuple(s.step.shape) == (2,)
    for x in jax.tree.leaves(p):
        assert x.shape[0] == 2
    # the resharded tree is live: one more training step runs
    fn = dst.sim_step_fn()
    data = SyntheticLM(DataConfig(vocab=CFG.vocab, seq_len=SEQ,
                                  global_batch=BATCH, seed=7))
    _, _, met = fn(p, s, data.batch(0))
    assert np.isfinite(float(np.asarray(met["loss"]).reshape(-1)[0]))


def test_direct_width_mismatch_restore_points_at_elastic(tmp_path):
    path, tr, _, _ = _save_trained(tmp_path)
    dst = Trainer(CFG, tr.opt_cfg, n_workers=2)
    params, state = jax.eval_shape(dst.sim_init, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match=r"n=4.*m=2.*repro\.elastic"):
        ckpt_io.restore(path, {"params": params, "state": state})


def test_restore_missing_width_meta_requires_override(tmp_path):
    tr, params, state = _trained("flat")
    path = os.path.join(tmp_path, "ck.npz")
    ckpt_io.save(path, {"params": params, "state": state}, step=6)
    dst = Trainer(CFG, tr.opt_cfg, n_workers=2)
    with pytest.raises(ValueError, match="n_workers"):
        restore_resharded(path, dst)
    p, s, _, _ = restore_resharded(path, dst, src_workers=4)
    assert tuple(s.step.shape) == (2,)


def test_restore_rejects_dtype_mismatch(tmp_path):
    path = os.path.join(tmp_path, "ck.npz")
    ckpt_io.save(path, {"a": jnp.ones((3,), jnp.float32)})
    like = {"a": jnp.ones((3,), jnp.int32)}
    with pytest.raises(ValueError, match="dtype float32 != expected int32"):
        ckpt_io.restore(path, like)


# --------------------------------------------------------------------- #
# fault-injected fleet runs
# --------------------------------------------------------------------- #

def test_fleet_sim_validates_schedule():
    fleet = FleetSim(CFG, OptimizerConfig(**OPT_BASE), 4)
    with pytest.raises(ValueError, match="outside"):
        fleet.run(4, events=[ResizeEvent(step=9, workers=2)])
    with pytest.raises(ValueError, match="two resizes"):
        fleet.run(4, events=[ResizeEvent(step=1, workers=2),
                             ResizeEvent(step=1, workers=4)])
    with pytest.raises(ValueError, match="divide"):
        fleet.run(4, global_batch=8, events=[ResizeEvent(step=1, workers=3)])


@pytest.mark.slow
def test_fleet_kill_shrink_rejoin_within_parity_tol():
    """Kill worker 1 at step 10 (4 -> 2, survivors keep their slots),
    rejoin at step 20 (2 -> 4): the interrupted run's tail loss stays
    within the bench_convergence parity gate of the uninterrupted
    baseline."""
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]
                           / "benchmarks"))
    from bench_convergence import PARITY_TOL

    opt_cfg = OptimizerConfig(**OPT_BASE)
    steps = 30
    base = FleetSim(CFG, opt_cfg, 4, seed=3).run(
        steps, global_batch=BATCH, seq=SEQ)
    el = FleetSim(CFG, opt_cfg, 4, seed=3).run(
        steps, global_batch=BATCH, seq=SEQ,
        events=[ResizeEvent(step=10, workers=2, survivors=(0, 2)),
                ResizeEvent(step=20, workers=4)])
    assert len(el["resizes"]) == 2
    shrink, grow = el["resizes"]
    assert (shrink["n_from"], shrink["n_to"]) == (4, 2)
    assert shrink["dead_entities"] == 2 and shrink["ef_fold"] is True
    assert (grow["n_from"], grow["n_to"]) == (2, 4)
    assert grow["joiner_workers"] == 2
    assert el["trainer"].n_workers == 4
    gap = parity_gap(el["losses"], base["losses"])
    assert gap <= PARITY_TOL, (
        f"elastic run diverged: tail-loss gap {gap:.3f} nats > "
        f"{PARITY_TOL} vs the uninterrupted baseline")
