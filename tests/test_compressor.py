"""Unit + property tests for the 1-bit EF compressor and comm views."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.core import compressor as C


@pytest.mark.parametrize("shape,spec,n", [
    ((13,), None, 4),
    ((28, 96), None, 4),
    ((28, 96), P(None, "model"), 4),
    ((3, 50, 16), P(None, None, "model"), 8),
    ((), None, 4),
    ((100,), None, 16),
])
def test_view_roundtrip(shape, spec, n):
    lo = C.make_layout(shape, spec, n)
    x = jnp.arange(int(np.prod(shape)) if shape else 1,
                   dtype=jnp.float32).reshape(shape)
    v = C.to_view(x, lo)
    assert v.shape == lo.view_shape
    back = C.from_view(v, lo)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_force_flatten_small_shards():
    # model-local shards too small to bit-pack structurally must flatten
    lo = C.make_layout((2, 4), P(None, "model"), 4, rest_factor=16,
                       force_flatten=True)
    assert lo.flatten and lo.rest_factor == 16
    ents = C.view_spec_entries(lo, P(None, "model"))
    assert ents == (None, "model")


def test_pack_unpack_roundtrip():
    x = jnp.asarray(np.random.RandomState(0).randn(4, 64), jnp.float32)
    p = C.pack_signs(x)
    s = C.unpack_signs(p, 64)
    np.testing.assert_array_equal(np.asarray(s), np.sign(
        np.asarray(x)) + (np.asarray(x) == 0))


@settings(max_examples=30, deadline=None)
@given(rows=st.integers(1, 6), cols=st.sampled_from([8, 16, 64, 128]),
       seed=st.integers(0, 2**31 - 1),
       mode=st.sampled_from(["tensor", "chunk", "row"]))
def test_ef_compress_properties(rows, cols, seed, mode):
    rng = np.random.RandomState(seed)
    lo = C.make_layout((rows * cols,), None, rows)
    z = C.to_view(jnp.asarray(rng.randn(rows * cols), jnp.float32), lo)
    mask = C.pad_mask(lo)
    packed, scales, err = C.ef_compress(z, lo, mode, mask)
    vals = C.decompress(packed, scales, lo.pack_count)
    # EF identity: z == C[z] + err (on unpadded positions)
    recon = vals + err
    m = mask if mask is not None else 1.0
    np.testing.assert_allclose(np.asarray(recon * m), np.asarray(z * m),
                               rtol=1e-5, atol=1e-5)
    # scales are nonnegative L1 means
    assert (np.asarray(scales) >= 0).all()
    # compression error bounded: |err| <= |z| + scale
    assert np.all(np.abs(np.asarray(err)) <=
                  np.abs(np.asarray(z)) + np.asarray(scales).max() + 1e-6)


def test_scale_is_l1_mean_tensor_mode():
    lo = C.make_layout((32,), None, 4)
    z = C.to_view(jnp.arange(32, dtype=jnp.float32) - 16, lo)
    _, scales, _ = C.ef_compress(z, lo, "tensor", C.pad_mask(lo))
    expect = np.abs(np.arange(32, dtype=np.float32) - 16).mean()
    np.testing.assert_allclose(float(scales.reshape(-1)[0]), expect,
                               rtol=1e-6)


def test_compressed_bytes_32x_reduction():
    lo = C.make_layout((1024, 1024), None, 8)
    comp = C.compressed_bytes(lo, "tensor")
    full_bf16 = 2 * 1024 * 1024 * 2
    assert comp < full_bf16 / 12  # ~16x vs bf16, 32x vs fp32
