"""Unit + property tests for the 1-bit EF compressor and comm views.

``hypothesis`` is an optional test dependency (requirements-test.txt).
Instead of a module-level ``pytest.importorskip`` — which would also skip
the deterministic layout tests — the property test degrades to a fixed-seed
parametrized sweep when hypothesis is absent, so the suite collects and
keeps its coverage either way.
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from jax.sharding import PartitionSpec as P

from repro.core import compressor as C


@pytest.mark.parametrize("shape,spec,n", [
    ((13,), None, 4),
    ((28, 96), None, 4),
    ((28, 96), P(None, "model"), 4),
    ((3, 50, 16), P(None, None, "model"), 8),
    ((), None, 4),
    ((100,), None, 16),
])
def test_view_roundtrip(shape, spec, n):
    lo = C.make_layout(shape, spec, n)
    x = jnp.arange(int(np.prod(shape)) if shape else 1,
                   dtype=jnp.float32).reshape(shape)
    v = C.to_view(x, lo)
    assert v.shape == lo.view_shape
    back = C.from_view(v, lo)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


@pytest.mark.parametrize("shape,spec,n", [
    ((13,), None, 4),
    ((64,), None, 4),
    ((28, 96), P(None, "model"), 4),
    ((3, 50, 16), P(None, None, "model"), 8),
    ((), None, 4),
])
def test_view_2d_adapter_roundtrip_and_counts(shape, spec, n):
    """The kernels' (rows, cols) frame: pure reshape + pad-exact row counts."""
    lo = C.make_layout(shape, spec, n)
    rows, cols = C.view_rows_cols(lo)
    assert rows * cols == int(np.prod(lo.view_shape))
    assert cols % 8 == 0
    x = jnp.arange(int(np.prod(shape)) if shape else 1,
                   dtype=jnp.float32).reshape(shape)
    v = C.to_view(x, lo)
    a2 = C.view_to_2d(v, lo)
    assert a2.shape == (rows, cols)
    np.testing.assert_array_equal(np.asarray(C.view_from_2d(a2, lo)),
                                  np.asarray(v))
    # row counts agree with the broadcast pad mask, row-summed
    cnt = C.view_row_counts(lo)
    assert cnt.shape == (rows,) and cnt.sum() == (int(np.prod(shape)) or 1)
    mask = C.pad_mask(lo)
    m = (np.ones(lo.view_shape, np.float32) if mask is None
         else np.broadcast_to(np.asarray(mask), lo.view_shape))
    np.testing.assert_array_equal(cnt, m.reshape(rows, cols).sum(axis=1))
    # per-chunk regrouping used by the server-side kernels
    np.testing.assert_array_equal(C.chunk_row_counts(lo).reshape(-1), cnt)


def test_frame_caps_cols_for_wide_flatten_views():
    """Wide flatten views fold into more rows so kernel tiles fit VMEM."""
    lo = C.make_layout((1024 * 1024,), None, 4)   # view (4, 262144)
    rows, cols = C.view_rows_cols(lo)
    assert cols <= C.FRAME_MAX_COLS and cols % 8 == 0
    assert rows * cols == int(np.prod(lo.view_shape))
    assert rows % lo.n == 0   # chunks stay contiguous equal row blocks
    # counts still tail-exact under the fold
    lo2 = C.make_layout((100003,), None, 4)
    r2, c2 = C.view_rows_cols(lo2)
    cnt = C.view_row_counts(lo2)
    assert c2 <= C.FRAME_MAX_COLS and cnt.sum() == 100003
    # folded frames stay 128-lane aligned (flatten pads to an n*128 quantum)
    assert cols % 128 == 0 and c2 % 128 == 0
    v = C.to_view(jnp.arange(100003, dtype=jnp.float32), lo2)
    np.testing.assert_array_equal(
        np.asarray(C.view_from_2d(C.view_to_2d(v, lo2), lo2)), np.asarray(v))


def test_force_flatten_small_shards():
    # model-local shards too small to bit-pack structurally must flatten
    lo = C.make_layout((2, 4), P(None, "model"), 4, rest_factor=16,
                       force_flatten=True)
    assert lo.flatten and lo.rest_factor == 16
    ents = C.view_spec_entries(lo, P(None, "model"))
    assert ents == (None, "model")


def test_pack_unpack_roundtrip():
    x = jnp.asarray(np.random.RandomState(0).randn(4, 64), jnp.float32)
    p = C.pack_signs(x)
    s = C.unpack_signs(p, 64)
    np.testing.assert_array_equal(np.asarray(s), np.sign(
        np.asarray(x)) + (np.asarray(x) == 0))


def _check_ef_compress_properties(rows, cols, seed, mode):
    rng = np.random.RandomState(seed)
    lo = C.make_layout((rows * cols,), None, rows)
    z = C.to_view(jnp.asarray(rng.randn(rows * cols), jnp.float32), lo)
    mask = C.pad_mask(lo)
    packed, scales, err = C.ef_compress(z, lo, mode, mask)
    vals = C.decompress(packed, scales, lo.pack_count)
    # EF identity: z == C[z] + err (on unpadded positions)
    recon = vals + err
    m = mask if mask is not None else 1.0
    np.testing.assert_allclose(np.asarray(recon * m), np.asarray(z * m),
                               rtol=1e-5, atol=1e-5)
    # scales are nonnegative L1 means
    assert (np.asarray(scales) >= 0).all()
    # compression error bounded: |err| <= |z| + scale
    assert np.all(np.abs(np.asarray(err)) <=
                  np.abs(np.asarray(z)) + np.asarray(scales).max() + 1e-6)


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(rows=st.integers(1, 6), cols=st.sampled_from([8, 16, 64, 128]),
           seed=st.integers(0, 2**31 - 1),
           mode=st.sampled_from(["tensor", "chunk", "row"]))
    def test_ef_compress_properties(rows, cols, seed, mode):
        _check_ef_compress_properties(rows, cols, seed, mode)
else:
    @pytest.mark.parametrize("mode", ["tensor", "chunk", "row"])
    @pytest.mark.parametrize("rows,cols,seed", [
        (1, 8, 0), (3, 16, 1), (4, 64, 2), (6, 128, 3), (5, 8, 4)])
    def test_ef_compress_properties(rows, cols, seed, mode):
        _check_ef_compress_properties(rows, cols, seed, mode)


def test_scale_is_l1_mean_tensor_mode():
    lo = C.make_layout((32,), None, 4)
    z = C.to_view(jnp.arange(32, dtype=jnp.float32) - 16, lo)
    _, scales, _ = C.ef_compress(z, lo, "tensor", C.pad_mask(lo))
    expect = np.abs(np.arange(32, dtype=np.float32) - 16).mean()
    np.testing.assert_allclose(float(scales.reshape(-1)[0]), expect,
                               rtol=1e-6)


def test_compressed_bytes_32x_reduction():
    lo = C.make_layout((1024, 1024), None, 8)
    comp = C.compressed_bytes(lo, "tensor")
    full_bf16 = 2 * 1024 * 1024 * 2
    assert comp < full_bf16 / 12  # ~16x vs bf16, 32x vs fp32


def test_compressed_bytes_charges_n_minus_1_chunks():
    """Regression: each a2a/gather phase moves (n-1) chunks per worker,
    not the full packed view (the old formula double-charged the view)."""
    n = 8
    lo = C.make_layout((1024, 1024), None, n)
    chunk_packed = int(np.prod(lo.chunk_shape)) // 8
    assert C.compressed_bytes(lo, "tensor") == \
        (n - 1) * (2 * chunk_packed + 4 * 2)
    assert C.compressed_bytes(lo, "chunk") == \
        (n - 1) * (2 * chunk_packed + 4 * 2)
    # strictly below the old double-charge of the full packed view
    assert C.compressed_bytes(lo, "tensor") < 2 * n * chunk_packed
    # row granularity on a structured view: one scale per view row and phase
    los = C.make_layout((128, 96), P(None, "model"), 4)
    sp = int(np.prod(los.chunk_shape)) // 8
    assert C.compressed_bytes(los, "row") == \
        (4 - 1) * (2 * sp + 4 * 2 * los.view_shape[1])
    # ~2 bits/param/sync once scales amortize (paper's 32x claim vs fp32)
    bits = 8.0 * C.compressed_bytes(lo, "tensor") / (1024 * 1024)
    assert bits < 2.0
