"""Kernel-path vs jnp-path parity: `use_pallas=True` must be a drop-in.

Deterministic (no hypothesis) property-style sweeps asserting that the
fused Pallas dispatch (repro.kernels.dispatch) reproduces the unfused
compressor / optimizer math bit-for-bit in f32:

  * worker-side EF-compress + decompress per leaf layout x scale mode,
    padded and unpadded, flatten and structured views;
  * server-side chunk compression for every worker index;
  * the fused local half-step kernel vs the three-sweep XLA chain;
  * a full multi-worker (vmap-simulated) `ZeroOneAdam.step` / `OneBitAdam`
    run with syncs and variance rounds, params + state compared at 1e-6.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import (OptimizerConfig, make_optimizer, sim_comm,
                        schedules as S)
from repro.core import compressor as C
from repro.core import onebit_allreduce as AR
from repro.kernels import dispatch as K

N = 4
COMM = sim_comm("w")

LAYOUT_CASES = [
    ((37,), None, 4),            # flatten, padded
    ((64,), None, 4),            # flatten, exact
    ((), None, 4),               # scalar leaf
    ((100003,), None, 4),        # flatten wider than FRAME_MAX_COLS (folds)
    ((13, 40), P(None, "model"), 4),          # structured, padded rows
    ((16, 40), P(None, "model"), 4),          # structured, exact
    ((6, 4, 24), P(None, None, "model"), 4),  # structured, trailing dims
]
MODES = ["tensor", "chunk", "row"]


def _view_pair(lo, seed=0):
    key = jax.random.PRNGKey(seed)
    shape = lo.view_shape
    z = jax.random.normal(key, shape)
    err = jax.random.normal(jax.random.fold_in(key, 1), shape) * 0.3
    mask = C.pad_mask(lo)
    if mask is not None:  # EF state is zero at padded positions
        z, err = z * mask, err * mask
    return z, err, mask


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("shape,spec,n", LAYOUT_CASES)
def test_ef_compress_view_matches_compressor(shape, spec, n, mode):
    lo = C.make_layout(shape, spec, n)
    z, err, mask = _view_pair(
        lo, seed=31 * (len(shape) + int(np.prod(shape or (1,))))
        + MODES.index(mode))
    p_ref, s_ref, e_ref = C.ef_compress(z + err, lo, mode, mask)
    p_k, s_k, e_k = K.ef_compress_view(z, err, lo, mode)
    assert p_k.shape == p_ref.shape and s_k.shape == s_ref.shape
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_ref))
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_ref),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(e_k), np.asarray(e_ref),
                               rtol=1e-5, atol=1e-6)
    # decompress parity on the same payload
    v_ref = C.decompress(p_ref, s_ref, lo.pack_count)
    v_k = K.decompress_view(p_k, s_k, lo)
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_ref),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("widx", [0, N - 1])
@pytest.mark.parametrize("shape,spec,n", [
    ((37,), None, 4),
    ((13, 40), P(None, "model"), 4),
    ((6, 4, 24), P(None, None, "model"), 4),
])
def test_server_compress_view_matches_jnp(shape, spec, n, mode, widx):
    lo = C.make_layout(shape, spec, n)
    key = jax.random.PRNGKey(widx + 17)
    avg = jax.random.normal(key, lo.chunk_shape)
    es = jax.random.normal(jax.random.fold_in(key, 1), lo.chunk_shape) * 0.2
    mask = C.pad_mask(lo)
    s_mask = None if mask is None else mask[widx][None]
    if s_mask is not None:
        es = es * s_mask[0]
    p_ref, s_ref, e_ref = AR._server_compress((avg + es)[None], lo, mode,
                                              s_mask)
    if mode == "row" and len(lo.view_shape) == 2:
        # no fused server kernel exists for row granularity on flatten
        # (2-D) views — the server side degenerates to per-element scales
        # there and dispatch.server_compress_view asserts the case away.
        # The capability lives one level up: Sign1BitCodec.encode_server
        # must route this case to the jnp path even under use_pallas=True
        # and reproduce the reference exactly. Pin that routing instead of
        # skipping.
        from repro.core.codecs import Sign1BitCodec
        payload, e_c = Sign1BitCodec().encode_server(
            avg, es, lo, mode, s_mask, widx, use_pallas=True)
        np.testing.assert_array_equal(np.asarray(payload["packed"]),
                                      np.asarray(p_ref))
        np.testing.assert_allclose(np.asarray(payload["scales"]),
                                   np.asarray(s_ref), rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(np.asarray(e_c),
                                   np.asarray(e_ref)[0],
                                   rtol=1e-5, atol=1e-6)
        return
    p_k, s_k, e_k = K.server_compress_view(avg[None], es[None], lo, mode,
                                           widx)
    assert s_k.shape == s_ref.shape
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_ref))
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_ref),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(e_k), np.asarray(e_ref),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("shape,spec,n", LAYOUT_CASES)
def test_fused_local_step_view_matches_unfused(shape, spec, n):
    lo = C.make_layout(shape, spec, n)
    key = jax.random.PRNGKey(23)
    ks = jax.random.split(key, 4)
    g, m, u = (jax.random.normal(k, lo.view_shape) for k in ks[:3])
    v = jnp.abs(jax.random.normal(ks[3], lo.view_shape)) + 1e-3
    lr, beta1, eps = jnp.float32(3e-3), 0.9, 1e-8
    mh_k, u_k, d_k = K.fused_local_step_view(g, m, u, v, lr, beta1, eps, lo)
    mh = beta1 * m + (1 - beta1) * g
    delta = lr * mh / jnp.sqrt(v + eps)
    # the f32-parity contract is <= 1e-6 (XLA may or may not contract the
    # β₁·m + (1-β₁)·g chain into an fma, a 1-ulp difference)
    np.testing.assert_allclose(np.asarray(mh_k), np.asarray(mh),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(u_k), np.asarray(u + lr * mh),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(delta),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Full optimizer step parity under n simulated workers
# ---------------------------------------------------------------------------

PARAMS = {"w": jax.random.normal(jax.random.PRNGKey(0), (6, 16)),
          "b": jnp.zeros((5,)),
          "deep": {"k": jax.random.normal(jax.random.PRNGKey(1), (3, 8, 8))}}


def _rep(tree):
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (N,) + x.shape) + 0,
                        tree)


def _noise_grads(xs, k):
    ks = jax.random.split(k, N)
    return jax.vmap(lambda kk, x: jax.tree.map(
        lambda l: jax.random.normal(jax.random.fold_in(kk, 7), l.shape),
        x))(ks, xs)


def _run(cfg, steps=8):
    opt = make_optimizer(cfg, PARAMS, n_workers=N)
    state = jax.vmap(lambda _: opt.init(PARAMS))(jnp.arange(N))
    xs = _rep(PARAMS)
    key = jax.random.PRNGKey(3)

    @jax.jit
    def one(xs, state, k):
        grads = _noise_grads(xs, k)
        return jax.vmap(lambda x, g, s: opt.step(COMM, x, g, s),
                        axis_name="w")(xs, grads, state)

    n_syncs = 0
    for _ in range(steps):
        key, sk = jax.random.split(key)
        xs, state, met = one(xs, state, sk)
        n_syncs += int(np.asarray(met["synced"])[0])
    return xs, state, n_syncs


def _assert_tree_close(t0, t1, tol=1e-6):
    for l0, l1 in zip(jax.tree.leaves(t0), jax.tree.leaves(t1)):
        np.testing.assert_allclose(np.asarray(l0, np.float32),
                                   np.asarray(l1, np.float32),
                                   rtol=tol, atol=tol)


@pytest.mark.parametrize("mode", ["tensor", "row"])
def test_zero_one_adam_step_parity(mode):
    """Acceptance: ZeroOneAdam.step with use_pallas=True is f32-identical
    (<= 1e-6) to the unfused path on a multi-worker vmap-simulated run."""
    base = dict(name="zero_one_adam", lr=S.ConstantLr(1e-2),
                var_policy=S.AdaptiveFreezePolicy(kappa=2),
                sync_policy=S.LrProportionalSyncPolicy(
                    warmup_steps=2, double_every=3, max_interval=4),
                scale_mode=mode)
    x0, s0, syncs0 = _run(OptimizerConfig(use_pallas=False, **base))
    x1, s1, syncs1 = _run(OptimizerConfig(use_pallas=True, **base))
    assert syncs0 == syncs1 and syncs0 >= 3  # compression actually exercised
    _assert_tree_close(x0, x1)
    _assert_tree_close(s0, s1)


def test_one_bit_adam_step_parity():
    base = dict(name="one_bit_adam", lr=S.ConstantLr(1e-2),
                onebit_warmup=2, scale_mode="tensor")
    x0, s0, _ = _run(OptimizerConfig(use_pallas=False, **base), steps=6)
    x1, s1, _ = _run(OptimizerConfig(use_pallas=True, **base), steps=6)
    _assert_tree_close(x0, x1)
    _assert_tree_close(s0, s1)


def test_pallas_workers_keep_bitwise_consensus():
    """Anchor-mode consensus survives the kernel path: all workers hold
    identical params after every sync."""
    cfg = OptimizerConfig(
        name="zero_one_adam", lr=S.ConstantLr(1e-2), use_pallas=True,
        var_policy=S.AdaptiveFreezePolicy(kappa=2),
        sync_policy=S.LrProportionalSyncPolicy(warmup_steps=3,
                                               double_every=3,
                                               max_interval=2))
    opt = make_optimizer(cfg, PARAMS, n_workers=N)
    state = jax.vmap(lambda _: opt.init(PARAMS))(jnp.arange(N))
    xs = _rep(PARAMS)
    key = jax.random.PRNGKey(5)

    @jax.jit
    def one(xs, state, k):
        grads = _noise_grads(xs, k)
        return jax.vmap(lambda x, g, s: opt.step(COMM, x, g, s),
                        axis_name="w")(xs, grads, state)

    saw = 0
    for _ in range(8):
        key, sk = jax.random.split(key)
        xs, state, met = one(xs, state, sk)
        if bool(np.asarray(met["synced"])[0]):
            for leaf in jax.tree.leaves(xs):
                arr = np.asarray(leaf)
                assert (arr == arr[:1]).all(), "workers diverged at sync"
            saw += 1
    assert saw >= 2
