"""Golden-trajectory regression suite.

The parity tests elsewhere in this suite are *relative*: they compare two
implementations of the same math against each other, so a refactor that
changes the numerics of BOTH paths in the same way passes them silently
("parity by construction"). This suite pins short sim trajectories against
arrays frozen on disk (``tests/golden/*.npz``), so any numeric drift in the
optimizer pipeline — local half-steps, EF compression, the Algorithm-2
exchange, policy machines — fails loudly against the committed bits.

Pinned per optimizer (``zero_one_adam``, ``one_bit_adam``,
``zero_one_lamb``): the full parameter arrays after 8 sim steps, the final
worker/server error-feedback state, and a per-step float64 parameter-sum
trace (the trace localizes *when* a divergence started; the arrays prove
bitwise equality at the end).

The trajectories deliberately avoid model matmuls: gradients are an
elementwise deterministic function of the parameters (plus a fixed
pseudo-random per-worker perturbation), so the goldens do not depend on
BLAS kernel choice — only on the optimizer pipeline itself and on jax's
(stable) threefry PRNG.

Regenerate (only after an INTENTIONAL numeric change, in the same commit
that explains why):

    PYTHONPATH=src:tests python tests/test_golden_trajectories.py --regen
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import OptimizerConfig, build_optimizer, sim_comm
from repro.core import schedules as S

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")

N = 4
STEPS = 8

# Odd sizes on purpose: every leaf exercises the pad-exact masks/counts.
PARAMS = {
    "w": jax.random.normal(jax.random.PRNGKey(0), (6, 16)),
    "b": jnp.zeros((5,)),
    "deep": {"k": jax.random.normal(jax.random.PRNGKey(1), (3, 8, 8))},
}

# Dense schedules so 8 steps cover syncs, local steps, and variance
# refreshes for every optimizer.
CONFIGS = {
    "zero_one_adam": OptimizerConfig(
        name="zero_one_adam", lr=S.ConstantLr(1e-2),
        var_policy=S.AdaptiveFreezePolicy(kappa=2),
        sync_policy=S.LrProportionalSyncPolicy(warmup_steps=2,
                                               double_every=3,
                                               max_interval=4)),
    "one_bit_adam": OptimizerConfig(
        name="one_bit_adam", lr=S.ConstantLr(1e-2), onebit_warmup=3),
    "zero_one_lamb": OptimizerConfig(
        name="zero_one_lamb", lr=S.ConstantLr(1e-2),
        var_policy=S.AdaptiveFreezePolicy(kappa=2),
        sync_policy=S.LrProportionalSyncPolicy(warmup_steps=2,
                                               double_every=3,
                                               max_interval=4)),
}


def _grads(xs, t):
    """Deterministic per-worker gradients: elementwise pull toward a fixed
    target plus a frozen pseudo-random perturbation (no matmuls)."""
    def leaf(path_seed, x):
        k = jax.random.fold_in(jax.random.PRNGKey(11), path_seed)
        k = jax.random.fold_in(k, t)
        ks = jax.random.split(k, N)
        noise = jax.vmap(lambda kk: jax.random.normal(
            kk, x.shape[1:]))(ks)
        return 0.1 * (x - 0.5) + noise

    leaves, treedef = jax.tree.flatten(xs)
    return jax.tree.unflatten(
        treedef, [leaf(i, x) for i, x in enumerate(leaves)])


def run_trajectory(name):
    opt = build_optimizer(CONFIGS[name], PARAMS, n_workers=N)
    comm = sim_comm("w")
    state = jax.vmap(lambda _: opt.init(PARAMS))(jnp.arange(N))
    xs = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (N,) + x.shape) + 0, PARAMS)

    @jax.jit
    def one(xs, state, t):
        return jax.vmap(lambda x, g, s: opt.step(comm, x, g, s),
                        axis_name="w")(xs, _grads(xs, t), state)

    trace = []
    for t in range(STEPS):
        xs, state, _ = one(xs, state, t)
        trace.append(float(np.sum(
            [np.asarray(l, np.float64).sum() for l in jax.tree.leaves(xs)])))
    return xs, state, np.asarray(trace, np.float64)


def _pack(xs, state, trace):
    out = {"trace": trace}
    for i, l in enumerate(jax.tree.leaves(xs)):
        out[f"param_{i}"] = np.asarray(l)
    for i, l in enumerate(jax.tree.leaves((state.err_w, state.err_s))):
        out[f"ef_{i}"] = np.asarray(l)
    return out


def _flat_arrays(name):
    return _pack(*run_trajectory(name))


def golden_path(name):
    return os.path.join(GOLDEN_DIR, f"{name}.npz")


@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_golden_trajectory(name):
    path = golden_path(name)
    assert os.path.exists(path), (
        f"missing golden file {path}; generate it with "
        f"PYTHONPATH=src:tests python tests/test_golden_trajectories.py "
        f"--regen")
    got = _flat_arrays(name)
    with np.load(path) as z:
        want = {k: z[k] for k in z.files}
    assert sorted(got) == sorted(want), (
        f"{name}: golden array set changed: {sorted(got)} vs "
        f"{sorted(want)}")
    # The trace pinpoints the first drifted step before the array diff.
    np.testing.assert_allclose(
        got["trace"], want["trace"], rtol=0, atol=0,
        err_msg=(f"{name}: parameter-sum trace drifted — first bad step "
                 f"index {int(np.argmax(got['trace'] != want['trace']))}"))
    for k in sorted(want):
        np.testing.assert_array_equal(
            got[k], want[k],
            err_msg=(f"{name}: {k} drifted from the committed golden. If "
                     f"the numeric change is INTENTIONAL, regenerate via "
                     f"--regen and justify it in the commit message."))


# --------------------------------------------------------------------- #
# Microbatched (gradient-accumulation) golden. The file on disk is
# generated with ``peel=False`` — the sequential all-scanned accumulation,
# i.e. the pre-overlap code path — while the test asserts the default
# peeled path (``peel=True``). Bitwise equality against the committed bits
# IS the proof that peeling the last microbatch out of the scan (the
# overlap enabler in repro.train.step) changed nothing numerically.
# --------------------------------------------------------------------- #

MB = 2
MB_NAME = "zero_one_adam_mb2"
ROWS = 4      # per-worker batch rows: 2 per microbatch


def _mb_batches(t):
    """(N, ROWS) per-worker batch scalars, deterministic per step."""
    return jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(23), t),
                             (N, ROWS))


def _mb_loss(params, batch):
    """Elementwise quadratic pull toward a batch-dependent target — no
    matmuls, so the golden stays BLAS-portable. The target differs per
    microbatch, so the accumulation (and its association order) is
    actually exercised."""
    tgt = 0.01 * jnp.mean(batch) + 0.5
    loss = sum(jnp.sum((x - tgt) ** 2) for x in jax.tree.leaves(params))
    return loss, ()


def run_mb_trajectory(peel):
    from repro.train.step import accumulate_grads
    opt = build_optimizer(CONFIGS["zero_one_adam"], PARAMS, n_workers=N)
    comm = sim_comm("w")
    state = jax.vmap(lambda _: opt.init(PARAMS))(jnp.arange(N))
    xs = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (N,) + x.shape) + 0, PARAMS)

    @jax.jit
    def one(xs, state, b):
        def worker(x, s, b_):
            _, g = accumulate_grads(_mb_loss, x, b_, MB, peel=peel)
            return opt.step(comm, x, g, s)

        return jax.vmap(worker, axis_name="w")(xs, state, b)

    trace = []
    for t in range(STEPS):
        xs, state, _ = one(xs, state, _mb_batches(t))
        trace.append(float(np.sum(
            [np.asarray(l, np.float64).sum()
             for l in jax.tree.leaves(xs)])))
    return xs, state, np.asarray(trace, np.float64)


def test_golden_trajectory_mb2_peeled_bitwise():
    path = golden_path(MB_NAME)
    assert os.path.exists(path), (
        f"missing golden file {path}; generate it with "
        f"PYTHONPATH=src:tests python tests/test_golden_trajectories.py "
        f"--regen {MB_NAME}")
    got = _pack(*run_mb_trajectory(peel=True))
    with np.load(path) as z:
        want = {k: z[k] for k in z.files}
    assert sorted(got) == sorted(want)
    np.testing.assert_allclose(
        got["trace"], want["trace"], rtol=0, atol=0,
        err_msg=(f"{MB_NAME}: peeled accumulation drifted from the "
                 f"sequential-scan golden — first bad step index "
                 f"{int(np.argmax(got['trace'] != want['trace']))}"))
    for k in sorted(want):
        np.testing.assert_array_equal(
            got[k], want[k],
            err_msg=(f"{MB_NAME}: {k} drifted from the committed golden "
                     f"(generated with peel=False). The peeled path must "
                     f"stay bitwise-identical to the sequential scan."))


if __name__ == "__main__":
    import sys
    if "--regen" not in sys.argv:
        sys.exit("usage: python tests/test_golden_trajectories.py --regen "
                 "[name ...]")
    only = [a for a in sys.argv[1:] if a != "--regen"]
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    for name in sorted(CONFIGS):
        if only and name not in only:
            continue
        arrays = _flat_arrays(name)
        np.savez(golden_path(name), **arrays)
        print(f"wrote {golden_path(name)}: "
              f"{sorted(arrays)[:4]}... trace={arrays['trace'][-1]:.6f}")
    if not only or MB_NAME in only:
        # the microbatched golden is DELIBERATELY generated through the
        # sequential (peel=False) accumulation; the test replays it with
        # peel=True to pin the peeled path bitwise
        arrays = _pack(*run_mb_trajectory(peel=False))
        np.savez(golden_path(MB_NAME), **arrays)
        print(f"wrote {golden_path(MB_NAME)} (peel=False): "
              f"trace={arrays['trace'][-1]:.6f}")
