"""Pluggable-codec API tests.

Three layers of coverage:

1. **Bitwise regression** — the refactored, codec-parameterized exchange
   with ``codec="sign1bit"`` (and ``identity`` vs the old
   ``quantize=False`` branch) must reproduce the FROZEN pre-refactor
   implementation (tests/reference_sign1bit.py, a verbatim snapshot)
   bit-for-bit — outputs and EF state — across flat / pallas / hierarchy
   configs and all scale granularities.
2. **Per-codec properties** (hypothesis when available, fixed-seed sweep
   otherwise): decode∘encode + err reconstructs the input, the EF residual
   contracts, payload byte sizes match ``codec.wire_bytes``, and padded
   positions contribute exactly zero (payloads/scales invariant to pad
   garbage, errors zero at pads).
3. **Config plumbing** — build-time validation of ``scale_mode`` / codec
   names / codec args, the ``quantize=False`` deprecation shim, the
   ``build_optimizer(..., codec=...)`` override, and full-pipeline
   quadratic convergence of ``zero_one_adam`` over every codec.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

import reference_sign1bit as REF
from repro.core import (Comm, Hierarchy, OptimizerConfig, build_optimizer,
                        comm_accounting, compressed_dp, make_codec,
                        sim_comm, schedules as S)
from repro.core import compressor as C
from repro.core import onebit_allreduce as AR
from repro.core.base_steps import adam_base
from repro.core.codecs import CODEC_NAMES

N = 4


# --------------------------------------------------------------------- #
# harness: run the exchange for several EF steps, flat or hierarchical
# --------------------------------------------------------------------- #

def _run_exchange(mod, cfg, layout, steps=4, seed=0, hier=False):
    key = jax.random.PRNGKey(seed)
    z0 = jax.random.normal(key, (N,) + layout.view_shape)
    ef = jax.vmap(lambda _: AR.init_ef_state(layout))(jnp.arange(N))
    if hier:
        ni = layout.n_inner
        no = N // ni
        lead = lambda x: x.reshape((no, ni) + x.shape[1:])
        unlead = lambda x: x.reshape((N,) + x.shape[2:])
        comm = Comm(("pod", "data"))

        @jax.jit
        def step(z, ef):
            f = jax.vmap(jax.vmap(
                lambda zz, e: mod.onebit_allreduce_view(comm, zz, e, layout,
                                                        cfg),
                axis_name="data"), axis_name="pod")
            o, ne = f(jax.tree.map(lead, z), jax.tree.map(lead, ef))
            return jax.tree.map(unlead, o), jax.tree.map(unlead, ne)
    else:
        comm = sim_comm("w")

        @jax.jit
        def step(z, ef):
            return jax.vmap(
                lambda zz, e: mod.onebit_allreduce_view(comm, zz, e, layout,
                                                        cfg),
                axis_name="w")(z, ef)

    outs, z = [], z0
    for t in range(steps):
        o, ef = step(z, ef)
        outs.append(o)
        z = z0 * (0.5 + 0.1 * t)      # fresh buffers, EF carried across
    return outs, ef


def _assert_trees_bitwise(a, b, msg=""):
    for l0, l1 in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1),
                                      err_msg=msg)


# --------------------------------------------------------------------- #
# 1. bitwise regression vs the frozen pre-refactor exchange
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("shape,mode,use_pallas,hier", [
    ((13,), "tensor", False, False),
    ((13,), "tensor", True, False),
    ((13,), "row", False, False),     # row degenerates on 2-D views
    ((13,), "row", True, False),      # ... incl. the k_server jnp fallback
    ((13,), "chunk", True, True),
    ((13,), "tensor", False, True),
    ((13,), "tensor", True, True),
    ((28, 96), "row", False, False),
    ((28, 96), "row", True, False),
    ((28, 96), "tensor", True, True),
    ((28, 96), "row", True, True),
])
def test_sign1bit_bitwise_vs_prerefactor(shape, mode, use_pallas, hier):
    layout = C.make_layout(shape, None, N, n_inner=2 if hier else 1)
    cfg = AR.OneBitConfig(scale_mode=mode, use_pallas=use_pallas,
                          hierarchy=Hierarchy(inner=2) if hier else None)
    assert cfg.codec.name == "sign1bit"
    o_new, ef_new = _run_exchange(AR, cfg, layout, hier=hier)
    o_ref, ef_ref = _run_exchange(REF, cfg, layout, hier=hier)
    _assert_trees_bitwise(o_new, o_ref,
                          f"outputs {shape} {mode} pallas={use_pallas} "
                          f"hier={hier}")
    _assert_trees_bitwise(ef_new, ef_ref, "EF state")


@pytest.mark.parametrize("shape,hier", [((13,), False), ((28, 96), True)])
def test_identity_bitwise_vs_prerefactor_quantize_false(shape, hier):
    """codec="identity" == the old quantize=False exact-mean branch."""
    layout = C.make_layout(shape, None, N, n_inner=2 if hier else 1)
    h = Hierarchy(inner=2) if hier else None
    cfg = AR.OneBitConfig(quantize=False, hierarchy=h)
    assert cfg.codec.name == "identity"
    cfg_id = AR.OneBitConfig(codec="identity", hierarchy=h)
    o_ref, ef_ref = _run_exchange(REF, cfg, layout, hier=hier)
    for c in (cfg, cfg_id):
        o_new, ef_new = _run_exchange(AR, c, layout, hier=hier)
        _assert_trees_bitwise(o_new, o_ref, "identity outputs")
        _assert_trees_bitwise(ef_new, ef_ref, "identity EF untouched")


# --------------------------------------------------------------------- #
# 2. per-codec properties
# --------------------------------------------------------------------- #

_PROP_CODECS = [("sign1bit", None), ("topk", 0.25), ("topk", 0.03),
                ("qint8", None), ("qint4", None)]
_PROP_LAYOUTS = [((13,), 4), ((28, 96), 4), ((200,), 8)]


def _codec_roundtrip_case(cname, arg, shape, n, seed):
    codec = make_codec(cname, arg)
    layout = C.make_layout(shape, None, n)
    mask = C.pad_mask(layout)
    key = jax.random.PRNGKey(seed)
    z = jax.random.normal(key, layout.view_shape)
    zm = z if mask is None else z * mask
    err0 = jnp.zeros(layout.ef_worker_shape)

    payload, err = codec.encode_worker(z, err0, layout, "tensor", mask)
    dense = codec.decode(payload, layout)

    # (a) EF identity on real elements: decode + err == masked input
    rec = np.asarray(dense + err)
    if mask is not None:
        rec = rec * np.asarray(mask)
    np.testing.assert_allclose(rec, np.asarray(zm), atol=1e-5, rtol=1e-5)

    # (b) the residual contracts (EF-absorbable): ||err|| <= ||z||
    ne, nz = float(jnp.linalg.norm(err)), float(jnp.linalg.norm(zm))
    assert ne <= nz * (1.0 + 1e-6), (cname, ne, nz)
    if cname.startswith("qint"):
        # elementwise: at most one quantization step of the chunk scale
        s = np.asarray(payload["scale"]).reshape(-1, 1)
        ef = np.abs(np.asarray(err)).reshape(s.shape[0], -1)
        assert (ef <= s + 1e-7).all()

    # (c) payload bytes match the static wire accounting
    wb = codec.wire_bytes(layout, "tensor")
    per_chunk = sum(np.asarray(l).nbytes for l in
                    jax.tree.leaves(payload)) / layout.n
    assert per_chunk == wb["scatter"], (cname, per_chunk, wb)
    avg = jax.random.normal(jax.random.fold_in(key, 11), layout.chunk_shape)
    pl_s, _ = codec.encode_server(avg, jnp.zeros(layout.chunk_shape),
                                  layout, "tensor", None if mask is None
                                  else mask[0][None], 0)
    srv_bytes = sum(np.asarray(l).nbytes for l in jax.tree.leaves(pl_s))
    assert srv_bytes == wb["gather"], (cname, srv_bytes, wb)

    # (d) pads contribute zero: errors vanish there, and the payload is
    # invariant to pad garbage (scales for sign1bit: its packed bits cover
    # pad slots, but they are scale- and EF-inert and dropped by from_view)
    if layout.pad and mask is not None:
        pad_pos = np.asarray(mask) == 0
        np.testing.assert_array_equal(
            np.asarray(err)[np.broadcast_to(pad_pos, err.shape)], 0.0)
        garbage = z + 1e3 * (1 - mask)
        pg, eg = codec.encode_worker(garbage, err0, layout, "tensor", mask)
        if cname == "sign1bit":
            np.testing.assert_array_equal(np.asarray(pg["scales"]),
                                          np.asarray(payload["scales"]))
        else:
            _assert_trees_bitwise(pg, payload, f"{cname} pad invariance")
        np.testing.assert_array_equal(
            np.asarray(eg)[np.broadcast_to(pad_pos, err.shape)], 0.0)


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(codec=st.sampled_from(_PROP_CODECS),
           lay=st.sampled_from(_PROP_LAYOUTS),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_codec_roundtrip_properties(codec, lay, seed):
        _codec_roundtrip_case(codec[0], codec[1], lay[0], lay[1], seed)
else:
    @pytest.mark.parametrize("cname,arg", _PROP_CODECS)
    @pytest.mark.parametrize("shape,n", _PROP_LAYOUTS)
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_codec_roundtrip_properties(cname, arg, shape, n, seed):
        _codec_roundtrip_case(cname, arg, shape, n, seed)


@pytest.mark.parametrize("cname,arg", [("topk", 0.25), ("qint8", None)])
def test_use_pallas_falls_back_for_kernel_less_codecs(cname, arg):
    """Only sign1bit has fused kernels; use_pallas=True with any other
    codec must route through the identical jnp path (dispatch.kernel_codec
    gates it), not crash or change numerics."""
    from repro.kernels import dispatch as K
    codec = make_codec(cname, arg)
    assert not K.kernel_codec(codec) and K.kernel_codec(
        make_codec("sign1bit"))
    layout = C.make_layout((13,), None, N)
    o_k, ef_k = _run_exchange(AR, AR.OneBitConfig(codec=codec,
                                                  use_pallas=True), layout)
    o_j, ef_j = _run_exchange(AR, AR.OneBitConfig(codec=codec,
                                                  use_pallas=False), layout)
    _assert_trees_bitwise(o_k, o_j, f"{cname} pallas fallback")
    _assert_trees_bitwise(ef_k, ef_j, f"{cname} pallas fallback EF")


def test_identity_codec_is_exact():
    layout = C.make_layout((24,), None, N)
    codec = make_codec("identity")
    z = jax.random.normal(jax.random.PRNGKey(0), layout.view_shape)
    payload, err = codec.encode_worker(z, None, layout, "tensor", None)
    assert err is None
    np.testing.assert_array_equal(np.asarray(codec.decode(payload, layout)),
                                  np.asarray(z))
    wb = codec.wire_bytes(layout, "tensor")
    assert wb["scatter"] == int(np.prod(layout.chunk_shape)) * 4


def test_topk_density_controls_k_and_bytes():
    layout = C.make_layout((100, 128), None, N)
    ce = int(np.prod(layout.chunk_shape))
    for d in (0.01, 0.1, 1.0):
        codec = make_codec("topk", d)
        k = codec.k_for(layout)
        assert k == max(1, min(ce, int(np.ceil(d * ce))))
        assert codec.wire_bytes(layout, "tensor")["scatter"] == 8 * k


def test_ef_loop_residual_stays_bounded():
    """Iterating EF against a fixed buffer must not blow up the residual
    (the codec error is absorbed, not accumulated)."""
    layout = C.make_layout((64,), None, N)
    z = jax.random.normal(jax.random.PRNGKey(3), layout.view_shape)
    for cname, arg in _PROP_CODECS:
        codec = make_codec(cname, arg)
        err = jnp.zeros(layout.ef_worker_shape)
        znorm = float(jnp.linalg.norm(z))
        for _ in range(25):
            _, err = codec.encode_worker(z, err, layout, "tensor",
                                         C.pad_mask(layout))
            assert float(jnp.linalg.norm(err)) <= 2.0 * znorm, cname


# --------------------------------------------------------------------- #
# 3. config plumbing, validation, and full-pipeline convergence
# --------------------------------------------------------------------- #

PARAMS = {"w": jax.random.normal(jax.random.PRNGKey(1), (8, 8)) * 3}


def test_scale_mode_validated_at_config_build_time():
    with pytest.raises(ValueError, match="tensor.*chunk.*row"):
        OptimizerConfig(name="zero_one_adam", scale_mode="rows")
    with pytest.raises(ValueError, match="tensor.*chunk.*row"):
        AR.OneBitConfig(scale_mode="per_tensor")
    with pytest.raises(ValueError, match="tensor.*chunk.*row"):
        compressed_dp(adam_base(), scale_mode="Row")


def test_codec_name_and_arg_validated():
    with pytest.raises(ValueError, match="unknown codec.*sign1bit"):
        OptimizerConfig(name="zero_one_adam", codec="top_k")
    with pytest.raises(ValueError, match="takes no codec_arg"):
        OptimizerConfig(name="zero_one_adam", codec="qint8", codec_arg=3)
    with pytest.raises(ValueError, match="density"):
        make_codec("topk", 1.5)
    assert set(CODEC_NAMES) == {"sign1bit", "topk", "qint8", "qint4",
                                "identity"}


def test_quantize_false_deprecation_shim():
    with pytest.warns(DeprecationWarning, match="identity"):
        opt = build_optimizer(
            OptimizerConfig(name="zero_one_adam", quantize=False),
            PARAMS, n_workers=N)
    assert opt.codec.name == "identity"
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        opt = build_optimizer(OptimizerConfig(name="zero_one_adam"),
                              PARAMS, n_workers=N)   # default: silent
    assert opt.codec.name == "sign1bit"


def test_explicit_codec_wins_over_deprecated_quantize_false():
    """quantize=False only rewrites the *default* codec; an explicitly
    requested codec (new API) must not be silently downgraded."""
    with pytest.warns(DeprecationWarning):
        opt = build_optimizer(
            OptimizerConfig(name="zero_one_adam", quantize=False),
            PARAMS, n_workers=N, codec="qint8")
    assert opt.codec.name == "qint8"
    with pytest.warns(DeprecationWarning):
        opt = build_optimizer(
            OptimizerConfig(name="zero_one_adam", quantize=False,
                            codec="topk", codec_arg=0.1),
            PARAMS, n_workers=N)
    assert opt.codec.name == "topk" and opt.codec.density == 0.1
    # a build_optimizer override is unambiguously explicit, so even
    # "sign1bit" beats the deprecated flag there (a config *field*
    # "sign1bit" is indistinguishable from the default and maps to
    # identity — string or instance spelling alike)
    with pytest.warns(DeprecationWarning):
        opt = build_optimizer(
            OptimizerConfig(name="zero_one_adam", quantize=False),
            PARAMS, n_workers=N, codec="sign1bit")
    assert opt.codec.name == "sign1bit"
    from repro.core.codecs import Sign1BitCodec
    with pytest.warns(DeprecationWarning):
        opt = build_optimizer(
            OptimizerConfig(name="zero_one_adam", quantize=False,
                            codec=Sign1BitCodec()),
            PARAMS, n_workers=N)
    assert opt.codec.name == "identity"


def test_legacy_classes_honor_codec_arg():
    """The legacy reference classes resolve (codec, codec_arg) through the
    same make_ar_cfg path — the arg must not be silently dropped."""
    from repro.core.zero_one_adam import ZeroOneAdam
    none_t = jax.tree.map(lambda _: None, PARAMS)
    true_t = jax.tree.map(lambda _: True, PARAMS)
    cfg = OptimizerConfig(name="zero_one_adam", codec="topk", codec_arg=0.5)
    legacy = ZeroOneAdam(cfg, PARAMS, none_t, true_t, N)
    assert legacy.ar_cfg.codec.name == "topk"
    assert legacy.ar_cfg.codec.density == 0.5


def test_codec_arg_only_override_reparameterizes():
    """A codec_arg alone re-parameterizes the configured codec; overriding
    with the same codec name keeps the stored arg; switching codecs resets
    it to that codec's default."""
    cfg = OptimizerConfig(name="zero_one_adam", codec="topk", codec_arg=0.5)
    opt = build_optimizer(cfg, PARAMS, n_workers=N, codec_arg=0.25)
    assert opt.codec.density == 0.25
    opt = build_optimizer(cfg, PARAMS, n_workers=N, codec="topk")
    assert opt.codec.density == 0.5
    opt = build_optimizer(cfg, PARAMS, n_workers=N, codec="qint4")
    assert opt.codec.name == "qint4"
    tr = compressed_dp(adam_base(), codec="topk", codec_arg=0.2)
    opt = build_optimizer(tr, PARAMS, n_workers=N, codec_arg=0.4)
    assert opt.codec.density == 0.4
    # same-name override on a transform whose codec is already a resolved
    # instance must keep the stored arg, not reset it to the default
    opt = build_optimizer(tr, PARAMS, n_workers=N, codec="topk")
    assert opt.codec.density == 0.2


def test_make_codec_instance_plus_arg_reparameterizes():
    """An instance plus a codec_arg must apply the arg (or raise for
    codecs that take none) — never silently ignore it."""
    from repro.core.codecs import Sign1BitCodec, TopKCodec
    assert make_codec(TopKCodec(), 0.5).density == 0.5
    with pytest.raises(ValueError, match="takes no codec_arg"):
        make_codec(Sign1BitCodec(), 0.5)
    tr = compressed_dp(adam_base(), codec=TopKCodec(), codec_arg=0.5)
    opt = build_optimizer(tr, PARAMS, n_workers=N)
    assert opt.codec.density == 0.5


def test_build_optimizer_codec_override():
    cfg = OptimizerConfig(name="zero_one_adam")
    opt = build_optimizer(cfg, PARAMS, n_workers=N, codec="topk",
                          codec_arg=0.05)
    assert opt.codec.name == "topk" and opt.codec.density == 0.05
    tr = compressed_dp(adam_base(), codec="qint4")
    opt = build_optimizer(tr, PARAMS, n_workers=N)
    assert opt.codec.name == "qint4"
    assert comm_accounting(opt)["codec"] == "qint4"


def test_accounting_orders_codecs_by_volume():
    cfg = OptimizerConfig(name="zero_one_adam")
    bits = {}
    for name, arg in [("topk", 0.01), ("qint4", None), ("qint8", None),
                      ("sign1bit", None), ("identity", None)]:
        opt = build_optimizer(cfg, {"w": jnp.zeros((512, 512))},
                              n_workers=N, codec=name, codec_arg=arg)
        bits[name] = comm_accounting(opt)["bits_per_param_sync"]
    assert bits["topk"] < bits["qint4"] < bits["qint8"] < bits["identity"]
    assert bits["sign1bit"] < bits["qint4"]


_TEST_LR = S.LinearWarmupExpDecay(peak_lr=1e-2, warmup_steps=30,
                                  decay=0.9, decay_period=50)
_TARGET = {"w": jnp.ones((8, 8))}
COMM = sim_comm("w")


def _quadratic_run(codec, arg, steps=300):
    cfg = OptimizerConfig(
        name="zero_one_adam", lr=_TEST_LR,
        var_policy=S.AdaptiveFreezePolicy(kappa=4),
        sync_policy=S.LrProportionalSyncPolicy(warmup_steps=20,
                                               double_every=40,
                                               max_interval=4),
        codec=codec, codec_arg=arg)
    opt = build_optimizer(cfg, PARAMS, n_workers=N)
    state = jax.vmap(lambda _: opt.init(PARAMS))(jnp.arange(N))
    xs = jax.tree.map(lambda x: jnp.broadcast_to(x, (N,) + x.shape) + 0,
                      PARAMS)
    key = jax.random.PRNGKey(7)

    @jax.jit
    def one(xs, state, k):
        ks = jax.random.split(k, N)
        grads = jax.vmap(lambda kk, x: jax.tree.map(
            lambda l, t: (l - t) + 0.3 * jax.random.normal(
                jax.random.fold_in(kk, 3), l.shape), x, _TARGET))(ks, xs)
        return jax.vmap(lambda x, g, s: opt.step(COMM, x, g, s),
                        axis_name="w")(xs, grads, state)

    for _ in range(steps):
        key, sk = jax.random.split(key)
        xs, state, _ = one(xs, state, sk)
    return float(jnp.abs(xs["w"][0] - 1.0).mean())


# observed errors ~0.02 for the faithful codecs (identity reaches 0.023);
# bounds leave CI margin. sign1bit's 1-bit noise floor is covered by the
# established registry suite (bound 0.8 there).
@pytest.mark.parametrize("codec,arg,bound", [
    ("topk", 0.25, 0.3),
    ("qint8", None, 0.3),
    ("qint4", None, 0.3),
    ("identity", None, 0.3),
])
def test_zero_one_adam_quadratic_convergence_per_codec(codec, arg, bound):
    err = _quadratic_run(codec, arg)
    assert err < bound, f"codec={codec} failed to approach optimum: {err}"


@pytest.mark.parametrize("codec,arg", [("topk", 0.25), ("qint8", None),
                                       ("qint4", None)])
def test_hierarchical_worker_consensus_per_codec(codec, arg):
    """Anchor-mode syncs must keep workers bitwise-identical for any codec
    (the re-anchored x is a function of replicated quantities only) — and
    this drives every dense-EF codec through the two-level exchange
    (slice-shaped EF state, m_slice masking)."""
    cfg = OptimizerConfig(
        name="zero_one_adam", lr=S.ConstantLr(1e-2),
        var_policy=S.AdaptiveFreezePolicy(kappa=2),
        sync_policy=S.EveryStepSyncPolicy(),
        codec=codec, codec_arg=arg, hierarchy=Hierarchy(inner=2))
    opt = build_optimizer(cfg, PARAMS, n_workers=N)
    comm = Comm(("pod", "data"))
    no = N // 2
    lead = lambda x: x.reshape((no, 2) + x.shape[1:])
    unlead = lambda x: x.reshape((N,) + x.shape[2:])
    state = jax.vmap(lambda _: opt.init(PARAMS))(jnp.arange(N))
    xs = jax.tree.map(lambda x: jnp.broadcast_to(x, (N,) + x.shape) + 0,
                      PARAMS)
    mapped = jax.vmap(jax.vmap(lambda x, g, s: opt.step(comm, x, g, s),
                               axis_name="data"), axis_name="pod")
    key = jax.random.PRNGKey(5)

    @jax.jit
    def one(xs, state, k):
        ks = jax.random.split(k, N)
        g = jax.vmap(lambda kk, x: jax.tree.map(
            lambda l: jax.random.normal(jax.random.fold_in(kk, 3), l.shape),
            x))(ks, xs)
        nx, ns, met = mapped(jax.tree.map(lead, xs), jax.tree.map(lead, g),
                             jax.tree.map(lead, state))
        return jax.tree.map(unlead, nx), jax.tree.map(unlead, ns), met

    for _ in range(4):
        key, sk = jax.random.split(key)
        xs, state, _ = one(xs, state, sk)
    w = np.asarray(xs["w"])
    np.testing.assert_array_equal(w, np.broadcast_to(w[:1], w.shape))
