"""Hierarchical (intra-pod / inter-pod) 1-bit AllReduce semantics.

Pins down the tentpole contracts:
  * the flat path is the exact degenerate case: ``n_inner == 1`` under the
    two-level schedule is bitwise-identical to today's single-level code;
  * the identity-compressor two-level schedule computes the exact mean (up
    to the bf16 wire of the intra-pod phases);
  * workers reach bitwise consensus after every hierarchical sync;
  * per-level error feedback stays bounded under iteration (Lemma 1
    behaviour at each compressed level);
  * ``compressed_bytes`` splits per level and the flat accounting is
    unchanged (hypothesis-based where available, deterministic sweep
    fallback as in test_compressor.py).

Workers are simulated with a nested vmap — outer axis "pod", inner axis
"data" — the same axis names the production mesh uses, so ``Comm.split``
runs identically in both regimes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from jax.sharding import PartitionSpec as P

from repro.core import compressor as C
from repro.core import onebit_allreduce as AR
from repro.core.comm import Comm, Hierarchy


def _views(shape, n, seed=0):
    lo = C.make_layout(shape, None, n)
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,) + shape)
    return jax.vmap(lambda a: C.to_view(a, lo))(x)


def _run_flat(views, lo, cfg, ef=None):
    comm = Comm(("w",))

    def f(v, e):
        return AR.onebit_allreduce_view(comm, v, e, lo, cfg)

    if ef is None:
        ef = jax.vmap(lambda _: AR.init_ef_state(lo))(
            jnp.arange(views.shape[0]))
    return jax.vmap(f, axis_name="w")(views, ef)


def _run_hier(views, lo, cfg, ef=None, n_pods=None):
    n = views.shape[0]
    ni = lo.n_inner if cfg.hierarchy is None else cfg.hierarchy.inner
    npod = n // ni
    comm = Comm(("pod", "data"))

    def f(v, e):
        return AR.onebit_allreduce_view(comm, v, e, lo, cfg)

    if ef is None:
        ef = jax.vmap(lambda _: AR.init_ef_state(lo))(jnp.arange(n))
    fold = lambda a: a.reshape((npod, ni) + a.shape[1:])
    unfold = lambda a: a.reshape((n,) + a.shape[2:])
    out = jax.vmap(jax.vmap(f, axis_name="data"), axis_name="pod")(
        jax.tree.map(fold, views), jax.tree.map(fold, ef))
    return jax.tree.map(unfold, out)


CASES = [
    ((13, 9), 8),       # flatten view with a padded tail
    ((64, 40), 8),      # flatten view, multi-row chunks
    ((257,), 4),        # 1-D with padding
]


@pytest.mark.parametrize("shape,n", CASES)
@pytest.mark.parametrize("mode", ["tensor", "chunk", "row"])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_degenerate_single_inner_is_bitwise_flat(shape, n, mode,
                                                 use_pallas):
    """hierarchy with one-worker pods (n_inner=1) == today's flat path,
    bitwise — outputs and both EF errors."""
    lo = C.make_layout(shape, None, n)          # n_inner = 1
    views = _views(shape, n)
    cfg_f = AR.OneBitConfig(scale_mode=mode, use_pallas=use_pallas)
    cfg_h = AR.OneBitConfig(
        scale_mode=mode, use_pallas=use_pallas,
        hierarchy=Hierarchy(inner=1, outer_axes=("pod", "data"),
                            inner_axes=()))
    of, eff = _run_flat(views, lo, cfg_f)
    oh, efh = _run_hier(views, lo, cfg_h)
    np.testing.assert_array_equal(np.asarray(of), np.asarray(oh))
    np.testing.assert_array_equal(np.asarray(eff.err_worker),
                                  np.asarray(efh.err_worker))
    np.testing.assert_array_equal(np.asarray(eff.err_server),
                                  np.asarray(efh.err_server))


@pytest.mark.parametrize("shape,n", CASES)
def test_hier_identity_compressor_is_exact_mean(shape, n):
    """quantize=False two-level schedule == the exact worker mean up to the
    bf16 wire of the intra-pod phases."""
    ni = 2
    lo = C.make_layout(shape, None, n, n_inner=ni)
    views = _views(shape, n)
    cfg = AR.OneBitConfig(quantize=False, hierarchy=Hierarchy(inner=ni))
    out, _ = _run_hier(views, lo, cfg)
    exact = np.asarray(views.mean(axis=0))
    np.testing.assert_allclose(np.asarray(out[0]), exact,
                               atol=2e-2, rtol=2e-2)


@pytest.mark.parametrize("mode", ["tensor", "chunk", "row"])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_hier_bitwise_consensus_and_kernel_parity(mode, use_pallas):
    """Every worker decodes the identical result (consensus is what lets
    0/1 Adam sync parameters bitwise), and the Pallas slice kernels agree
    with the jnp path to the bit."""
    shape, n, ni = (64, 40), 8, 4
    lo = C.make_layout(shape, None, n, n_inner=ni)
    views = _views(shape, n)
    cfg = AR.OneBitConfig(scale_mode=mode, use_pallas=use_pallas,
                          hierarchy=Hierarchy(inner=ni))
    out, ef = _run_hier(views, lo, cfg)
    o = np.asarray(out)
    assert (o == o[:1]).all(), "workers diverged after hierarchical sync"
    assert np.isfinite(o).all()
    if use_pallas:
        cfg_j = AR.OneBitConfig(scale_mode=mode,
                                hierarchy=Hierarchy(inner=ni))
        oj, efj = _run_hier(views, lo, cfg_j)
        np.testing.assert_array_equal(o, np.asarray(oj))
        np.testing.assert_array_equal(np.asarray(ef.err_worker),
                                      np.asarray(efj.err_worker))
        np.testing.assert_array_equal(np.asarray(ef.err_server),
                                      np.asarray(efj.err_server))


def test_hier_structured_view_consensus():
    """Non-flatten (GSPMD-auto structured) views run the same two-level
    schedule: model-sharded leaf, chunk split on a replicated axis."""
    shape, n, ni = (3, 48, 16), 8, 2
    lo = C.make_layout(shape, P(None, None, "model"), n, n_inner=ni)
    assert not lo.flatten
    x = jax.random.normal(jax.random.PRNGKey(3), (n,) + shape)
    views = jax.vmap(lambda a: C.to_view(a, lo))(x)
    for mode in ("tensor", "chunk", "row"):
        cfg = AR.OneBitConfig(scale_mode=mode, hierarchy=Hierarchy(inner=ni))
        out, ef = _run_hier(views, lo, cfg)
        o = np.asarray(out)
        assert (o == o[:1]).all() and np.isfinite(o).all()
        assert ef.err_worker.shape[1:] == lo.ef_worker_shape


def test_ef_error_bounded_per_level():
    """Iterated hierarchical syncs keep both levels' EF errors bounded
    (the no-blow-up half of Lemma 1, per compressed level)."""
    shape, n, ni = (32, 24), 8, 4
    lo = C.make_layout(shape, None, n, n_inner=ni)
    cfg = AR.OneBitConfig(scale_mode="tensor", hierarchy=Hierarchy(inner=ni))
    ef = jax.vmap(lambda _: AR.init_ef_state(lo))(jnp.arange(n))
    for t in range(30):
        views = _views(shape, n, seed=t)
        _, ef = _run_hier(views, lo, cfg, ef=ef)
    assert float(jnp.abs(ef.err_worker).max()) < 10.0
    assert float(jnp.abs(ef.err_server).max()) < 10.0


# ---------------------------------------------------------------------------
# per-level bytes accounting
# ---------------------------------------------------------------------------

def _check_levels(shape, n, ni, mode):
    lo = C.make_layout(shape, None, n, n_inner=ni)
    lv = C.compressed_bytes_levels(lo, mode, inner_itemsize=2)
    no = n // ni
    elems = int(np.prod(lo.view_shape))
    chunk = int(np.prod(lo.chunk_shape))
    # inner: RS + AG of (ni-1)/ni of the view at the 2-byte wire dtype
    assert lv["inner"] == 2 * (ni - 1) * (elems // ni) * 2
    # outer: the flat formula at pod granularity
    if mode in ("tensor", "chunk"):
        sc = gc = 1
    elif len(lo.view_shape) == 2:
        sc, gc = 1, lo.view_shape[1]
    else:
        sc = gc = lo.view_shape[1]
    assert lv["outer"] == (no - 1) * (2 * (chunk // 8) + 4 * (sc + gc))
    assert C.compressed_bytes(lo, mode) == lv["inner"] + lv["outer"]
    if ni == 1:
        assert lv["inner"] == 0
    # the headline property: sign bits vs f32 across the slow links
    fp = C.fullprec_bytes_levels(lo, 4)
    if mode == "tensor" and no > 1:
        ratio = lv["outer"] / fp["outer"]
        assert abs(ratio - 1 / 32) < 0.01, ratio


DET_CASES = [((13, 9), 8, 1), ((13, 9), 8, 2), ((64, 40), 8, 4),
             ((257,), 4, 2), ((1024,), 16, 4), ((33, 8), 8, 8)]


@pytest.mark.parametrize("shape,n,ni", DET_CASES)
@pytest.mark.parametrize("mode", ["tensor", "chunk", "row"])
def test_compressed_bytes_levels_sweep(shape, n, ni, mode):
    _check_levels(shape, n, ni, mode)


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(st.integers(1, 2000), st.sampled_from([2, 4, 8, 16]),
           st.integers(0, 4), st.sampled_from(["tensor", "chunk", "row"]))
    def test_compressed_bytes_levels_property(total, n, log_ni, mode):
        ni = 2 ** log_ni
        if ni > n:
            ni = n
        _check_levels((total,), n, ni, mode)
else:
    @pytest.mark.parametrize("seed", range(24))
    def test_compressed_bytes_levels_property(seed):
        rng = np.random.RandomState(seed)
        total = int(rng.randint(1, 2000))
        n = int(rng.choice([2, 4, 8, 16]))
        ni = int(min(2 ** rng.randint(0, 5), n))
        mode = str(rng.choice(["tensor", "chunk", "row"]))
        _check_levels((total,), n, ni, mode)


def test_flat_accounting_unchanged():
    """n_inner=1 keeps the historical flat numbers byte-for-byte."""
    for shape, n in [((13, 9), 4), ((100,), 16)]:
        lo = C.make_layout(shape, None, n)
        chunk_packed = int(np.prod(lo.chunk_shape)) // 8
        expect = (n - 1) * (2 * chunk_packed + 4 * 2)
        assert C.compressed_bytes(lo, "tensor") == expect
