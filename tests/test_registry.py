"""Registry + deprecation-shim coverage, and convergence of the new
composed variants (0/1-LAMB, 0/1-SGD) that the combinator unlocks."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LEGACY_NAMES, OptimizerConfig, REGISTRY_NAMES,
                        build_optimizer, compressed_dp, lamb_base,
                        make_optimizer, momentum_sgd_base, sim_comm,
                        schedules as S)
from repro.core.compressed import ComposedOptimizer

N = 4
COMM = sim_comm("w")
PARAMS = {"w": jax.random.normal(jax.random.PRNGKey(0), (6, 16)),
          "b": jnp.zeros((5,))}


# --------------------------------------------------------------------- #
# deprecation shim
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("name", list(LEGACY_NAMES))
def test_legacy_names_warn_and_return_composed(name):
    cfg = OptimizerConfig(name=name, lr=S.ConstantLr(1e-2))
    with pytest.warns(DeprecationWarning, match="compressed_dp"):
        opt = make_optimizer(cfg, PARAMS, n_workers=N)
    assert isinstance(opt, ComposedOptimizer)
    # ... and the composed equivalent actually steps
    grads = jax.tree.map(jnp.ones_like, PARAMS)

    def one(x, g, s):
        return opt.step(COMM, x, g, s)

    xs, state, met = jax.vmap(one, axis_name="w")(
        jax.tree.map(lambda x: jnp.broadcast_to(x, (N,) + x.shape) + 0,
                     PARAMS),
        jax.tree.map(lambda x: jnp.broadcast_to(x, (N,) + x.shape) + 0,
                     grads),
        jax.vmap(lambda _: opt.init(PARAMS))(jnp.arange(N)))
    assert np.isfinite(np.asarray(jax.tree.leaves(xs)[0])).all()


@pytest.mark.parametrize("name", ["zero_one_lamb", "zero_one_sgd",
                                  "one_bit_lamb", "lamb", "momentum_sgd"])
def test_new_names_do_not_warn(name):
    cfg = OptimizerConfig(name=name, lr=S.ConstantLr(1e-2))
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        opt = make_optimizer(cfg, PARAMS, n_workers=N)
    assert isinstance(opt, ComposedOptimizer)


def test_build_optimizer_never_warns():
    for name in REGISTRY_NAMES:
        cfg = OptimizerConfig(name=name, lr=S.ConstantLr(1e-2))
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            build_optimizer(cfg, PARAMS, n_workers=N)


def test_unknown_name_error_lists_full_registry():
    cfg = OptimizerConfig(name="adamw_8bit")
    with pytest.raises(ValueError) as ei:
        make_optimizer(cfg, PARAMS, n_workers=N)
    msg = str(ei.value)
    for name in REGISTRY_NAMES:
        assert name in msg, f"{name} missing from the unknown-name error"
    assert "zero_one_lamb" in msg and "zero_one_sgd" in msg


def test_make_optimizer_accepts_unbound_transform():
    t = compressed_dp(momentum_sgd_base(), lr=S.ConstantLr(1e-2),
                      sync_policy=S.EveryStepSyncPolicy())
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        opt = make_optimizer(t, PARAMS, n_workers=N)
    assert isinstance(opt, ComposedOptimizer)


def test_lamb_requires_anchor_in_accumulate_style():
    with pytest.raises(ValueError, match="store_anchor"):
        compressed_dp(lamb_base(), store_anchor=False)


def test_accumulate_style_rejects_weight_decay():
    """A decay term breaks the u-linearization the 0/1 sync relies on;
    the combinator must refuse it loudly instead of silently ignoring it
    (which is what the legacy class did)."""
    from repro.core import adam_base
    with pytest.raises(ValueError, match="weight_decay"):
        compressed_dp(adam_base(), weight_decay=0.01)
    with pytest.raises(ValueError, match="weight_decay"):
        build_optimizer(OptimizerConfig(name="zero_one_adam",
                                        weight_decay=0.01),
                        PARAMS, n_workers=N)
    # gradient / mean styles support it
    compressed_dp(adam_base(), style="mean", weight_decay=0.01)
    build_optimizer(OptimizerConfig(name="adam", weight_decay=0.01),
                    PARAMS, n_workers=N)


# --------------------------------------------------------------------- #
# the new variants actually optimize
# --------------------------------------------------------------------- #

_TEST_LR = S.LinearWarmupExpDecay(peak_lr=1e-2, warmup_steps=30,
                                  decay=0.9, decay_period=50)


def _quadratic_grads(target):
    def g(xs, k):
        ks = jax.random.split(k, N)

        def per(kk, x):
            return jax.tree.map(
                lambda l, t: (l - t) + 0.3 * jax.random.normal(
                    jax.random.fold_in(kk, 3), l.shape),
                x, target)
        return jax.vmap(per)(ks, xs)
    return g


def _run_steps(opt, params, grad_fn, steps, key):
    state = jax.vmap(lambda _: opt.init(params))(jnp.arange(N))
    xs = jax.tree.map(lambda x: jnp.broadcast_to(x, (N,) + x.shape) + 0,
                      params)

    @jax.jit
    def one(xs, state, k):
        grads = grad_fn(xs, k)
        return jax.vmap(lambda x, g, s: opt.step(COMM, x, g, s),
                        axis_name="w")(xs, grads, state)

    for _ in range(steps):
        key, sk = jax.random.split(key)
        xs, state, met = one(xs, state, sk)
    return xs, state, met


# LAMB takes norm-proportional steps (||dx|| = lr·||x|| per sync), ~3x
# Adam's effective step on this single-tensor toy, so the 1-bit direction
# noise floor sits proportionally higher — row-granular scales and a wider
# contraction bound reflect that. (With quantize=False it reaches 0.07;
# the LM-scale parity evidence lives in benchmarks/bench_convergence.py.)
_VARIANTS = [("zero_one_lamb", "row", 1.2), ("zero_one_sgd", "tensor", 0.8),
             ("one_bit_lamb", "tensor", 0.8), ("lamb", "tensor", 0.8),
             ("momentum_sgd", "tensor", 0.8)]


@pytest.mark.parametrize("opt_name,scale_mode,bound", _VARIANTS)
def test_new_variant_quadratic_convergence(opt_name, scale_mode, bound):
    key = jax.random.PRNGKey(1)
    params = {"w": jax.random.normal(key, (8, 8)) * 3}
    target = {"w": jnp.ones((8, 8))}
    cfg = OptimizerConfig(
        name=opt_name, lr=_TEST_LR, scale_mode=scale_mode,
        var_policy=S.AdaptiveFreezePolicy(kappa=4),
        sync_policy=S.LrProportionalSyncPolicy(warmup_steps=20,
                                               double_every=40,
                                               max_interval=4),
        onebit_warmup=20)
    opt = build_optimizer(cfg, params, n_workers=N)
    xs, _, _ = _run_steps(opt, params, _quadratic_grads(target), 300,
                          jax.random.PRNGKey(7))
    err = float(jnp.abs(xs["w"][0] - 1.0).mean())
    # initial distance ~2.5; every variant must contract substantially
    assert err < bound, f"{opt_name} failed to approach optimum: {err}"


def test_zero_one_sgd_skips_variance_rounds():
    """momentum_sgd_base has no second moment: T_v must never fire and the
    state must carry no variance slot at all."""
    cfg = OptimizerConfig(name="zero_one_sgd", lr=S.ConstantLr(1e-2),
                          sync_policy=S.EveryStepSyncPolicy())
    opt = build_optimizer(cfg, PARAMS, n_workers=N)
    state = opt.init(PARAMS)
    assert "v" not in state.slots
    xs, state, met = _run_steps(
        opt, PARAMS, lambda xs, k: jax.vmap(lambda x: jax.tree.map(
            jnp.ones_like, x))(xs), 3, jax.random.PRNGKey(0))
    assert not bool(np.asarray(met["var_round"]).reshape(-1)[0])


def test_zero_one_lamb_consensus_at_syncs():
    """0/1-LAMB inherits the anchor-mode bitwise consensus guarantee: the
    trust ratio is refreshed from replicated quantities only."""
    cfg = OptimizerConfig(
        name="zero_one_lamb", lr=S.ConstantLr(1e-2),
        var_policy=S.AdaptiveFreezePolicy(kappa=2),
        sync_policy=S.LrProportionalSyncPolicy(warmup_steps=3,
                                               double_every=3,
                                               max_interval=2))
    opt = build_optimizer(cfg, PARAMS, n_workers=N)
    state = jax.vmap(lambda _: opt.init(PARAMS))(jnp.arange(N))
    xs = jax.tree.map(lambda x: jnp.broadcast_to(x, (N,) + x.shape) + 0,
                      PARAMS)
    key = jax.random.PRNGKey(5)

    @jax.jit
    def one(xs, state, k):
        ks = jax.random.split(k, N)
        grads = jax.vmap(lambda kk, x: jax.tree.map(
            lambda l: jax.random.normal(jax.random.fold_in(kk, 7), l.shape),
            x))(ks, xs)
        return jax.vmap(lambda x, g, s: opt.step(COMM, x, g, s),
                        axis_name="w")(xs, grads, state)

    saw = 0
    for _ in range(10):
        key, sk = jax.random.split(key)
        xs, state, met = one(xs, state, sk)
        if bool(np.asarray(met["synced"])[0]):
            for leaf in jax.tree.leaves(xs):
                arr = np.asarray(leaf)
                assert (arr == arr[:1]).all(), "workers diverged at sync"
            saw += 1
    assert saw >= 3
