"""Bucketed-exchange tests: bucket assembly properties, pack-order
(readiness) permutation invariants, and bucketed-vs-per-leaf trajectory
equivalence.

The contract under test (repro.core.bucketing):

* assembly is a permutation — every true leaf element maps into exactly one
  bucket slot, ``scatter ∘ gather`` is the identity, and pad garbage in
  member views can never reach the bucket buffer (so never the wire);
* true-element accounting is conserved leaf-sum vs bucket-sum, and fusing
  never inflates the wire volume;
* ``pack_order="reverse_backward"`` is a pure permutation of the flat
  issue order: per-leaf trajectories are bitwise unchanged (exchanges are
  independent), bucketed ones are bitwise under the exact ``identity``
  codec, and the declared sync schedule follows the reversed order;
* with one leaf per bucket the full optimizer trajectory is BITWISE the
  per-leaf path's, across every codec × flat/hierarchy × pallas on/off
  (0/1-LAMB's trust norms are reduction-order sensitive at 1 ulp — see
  the lamb test); multi-leaf buckets are bitwise under the exact
  ``identity`` codec (well within the 1e-6 budget) and stay bounded under
  sign1bit, whose per-bucket scales are the documented semantic change.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from jax.sharding import PartitionSpec as P

from repro.core import (Comm, Hierarchy, OptimizerConfig, build_optimizer,
                        comm_accounting, make_codec, sim_comm,
                        schedules as S)
from repro.core import bucketing as BK
from repro.core import compressor as C
from repro.core import leafwise
from repro.core import onebit_allreduce as AR
from repro.core.codecs import CODEC_NAMES

N = 4

PARAMS = {"w": jax.random.normal(jax.random.PRNGKey(0), (6, 16)),
          "b": jnp.zeros((5,)),
          "deep": {"k": jax.random.normal(jax.random.PRNGKey(1), (3, 8, 8))}}
POLICIES = dict(lr=S.ConstantLr(1e-2),
                var_policy=S.AdaptiveFreezePolicy(kappa=2),
                sync_policy=S.LrProportionalSyncPolicy(
                    warmup_steps=2, double_every=3, max_interval=4))


def _plan(shapes, n=N, hierarchy=None, specs=None):
    tree = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return leafwise.make_plan(tree, specs, None, n, hierarchy=hierarchy)


# --------------------------------------------------------------------- #
# bucket assembly properties
# --------------------------------------------------------------------- #

def _check_assembly(sizes, bucket_mb, n, seed):
    shapes = [(s,) if s else () for s in sizes]
    plan = _plan(shapes, n=n)
    bp = BK.make_bucket_plan(plan, bucket_mb)

    # every DP leaf is assigned to exactly one bucket, members partition
    # the leaf set
    assigned = [i for b in bp.buckets for i in b.members]
    assert sorted(assigned) == list(range(len(shapes)))
    for i, bi in enumerate(bp.leaf_bucket):
        assert i in bp.buckets[bi].members

    # permutation: distinct sentinel values per element; every sentinel
    # appears exactly once in the bucket buffers, pads are exactly zero
    rng = np.random.default_rng(seed)
    total = sum(max(s, 1) for s in sizes)
    sent = rng.permutation(total).astype(np.float64) + 1.0   # all nonzero
    leaves, off = [], 0
    for s in sizes:
        k = max(s, 1)
        leaves.append(jnp.asarray(sent[off:off + k],
                                  jnp.float32).reshape((s,) if s else ()))
        off += k
    views = [C.to_view(x, lo) for x, lo in zip(leaves, plan.layouts)]
    seen = []
    for b in bp.buckets:
        buf = np.asarray(BK.gather_views(b, [views[i] for i in b.members]))
        flat = buf.reshape(-1)
        assert buf.shape == b.layout.view_shape
        assert (flat[b.true_elems:] == 0).all(), "bucket pad tail not zero"
        seen.append(flat[:b.true_elems])
        # scatter ∘ gather is the identity on the member views' true
        # elements (and re-zeroes their pads)
        back = BK.scatter_views(b, jnp.asarray(buf),
                                [plan.layouts[i] for i in b.members])
        for i, v in zip(b.members, back):
            got = np.asarray(C.from_view(v, plan.layouts[i]))
            np.testing.assert_array_equal(got, np.asarray(leaves[i]))
    got_all = np.sort(np.concatenate(seen))
    np.testing.assert_array_equal(got_all, np.sort(sent))

    # true-element accounting is conserved leaf-sum vs bucket-sum
    acct = BK.bucket_accounting(bp)
    leaf_true = sum(C.true_counts(lo)[0] for lo in plan.layouts)
    assert acct["true_elems"] == leaf_true
    # fusion never inflates the wire: one bucket's padded footprint is at
    # most the sum of its members' padded footprints
    for b in bp.buckets:
        assert b.layout.padded <= sum(plan.layouts[i].padded
                                      for i in b.members)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(sizes=st.lists(st.integers(0, 700), min_size=1, max_size=9),
           bucket_mb=st.sampled_from([1e-6, 1e-3, 2e-3, 64.0]),
           n=st.sampled_from([1, 2, 4]),
           seed=st.integers(0, 2**31 - 1))
    def test_assembly_properties(sizes, bucket_mb, n, seed):
        _check_assembly(sizes, bucket_mb, n, seed)
else:
    @pytest.mark.parametrize("sizes,bucket_mb,n,seed", [
        ([5], 1e-6, 4, 0),
        ([5, 192, 96], 64.0, 4, 1),
        ([0, 700, 3, 3], 2e-3, 4, 2),
        ([130, 130, 130], 1e-3, 2, 3),
        ([1, 1, 1, 1, 1, 1, 1], 64.0, 1, 4),
        ([513, 5, 600, 2], 2e-3, 4, 5),
    ])
    def test_assembly_properties(sizes, bucket_mb, n, seed):
        _check_assembly(sizes, bucket_mb, n, seed)


def test_pad_garbage_never_leaks():
    """Garbage written into member-view pad positions must not change the
    bucket buffer, the codec payload/scales, or the decoded output."""
    plan = _plan([(5,), (192,), (96,)])
    bp = BK.make_bucket_plan(plan, 64.0)
    (b,) = bp.buckets
    key = jax.random.PRNGKey(0)
    leaves = [jax.random.normal(jax.random.fold_in(key, i), lo.shape)
              for i, lo in enumerate(plan.layouts)]
    clean = [C.to_view(x, lo) for x, lo in zip(leaves, plan.layouts)]
    dirty = []
    for v, lo in zip(clean, plan.layouts):
        m = C.pad_mask(lo)
        if m is None:
            dirty.append(v)
            continue
        g = 1e9 * jnp.ones_like(v)
        dirty.append(v * m + g * (1 - m))
    buf_c = BK.gather_views(b, clean)
    buf_d = BK.gather_views(b, dirty)
    np.testing.assert_array_equal(np.asarray(buf_c), np.asarray(buf_d))

    codec = make_codec("sign1bit")
    mask = C.pad_mask(b.layout)
    for mode in ("tensor", "chunk", "row"):
        pc, ec = codec.encode_worker(buf_c, jnp.zeros_like(buf_c),
                                     b.layout, mode, mask)
        pd_, ed = codec.encode_worker(buf_d, jnp.zeros_like(buf_d),
                                      b.layout, mode, mask)
        for k in pc:
            np.testing.assert_array_equal(np.asarray(pc[k]),
                                          np.asarray(pd_[k]))
        np.testing.assert_array_equal(np.asarray(ec), np.asarray(ed))


def test_budget_and_eligibility():
    """Budget bounds fusion (never splits a leaf), ineligible leaves become
    singleton buckets with their own layout."""
    # 0.002 MiB budget = 524 f32 elements
    plan = _plan([(100,), (100,), (400,), (600,), (8,)])
    bp = BK.make_bucket_plan(plan, 0.002)
    assert [b.members for b in bp.buckets] == [(0, 1), (2,), (3,), (4,)]
    assert all(b.fused for b in bp.buckets)
    # oversized leaf keeps its own bucket rather than being split
    assert bp.buckets[2].true_elems == 600

    # a GSPMD-structured (spec-sharded) leaf is not repackable: singleton
    # bucket carrying the leaf's own structured layout and spec
    specs = [P(None, "model"), None]
    plan2 = _plan([(28, 96), (40,)], specs=specs)
    assert not plan2.layouts[0].flatten
    bp2 = BK.make_bucket_plan(plan2, 64.0)
    kinds = {b.members: b.fused for b in bp2.buckets}
    assert kinds == {(0,): False, (1,): True}
    b0 = [b for b in bp2.buckets if not b.fused][0]
    assert b0.layout is plan2.layouts[0]
    assert b0.vspec == plan2.vspecs[0]

    with pytest.raises(ValueError, match="bucket_mb"):
        BK.make_bucket_plan(plan, 0.0)
    with pytest.raises(ValueError, match="bucket_mb"):
        OptimizerConfig(name="zero_one_adam", bucket_mb=-1.0)


def test_wire_bytes_conserved_leaf_vs_bucket():
    """codec.wire_bytes over buckets accounts every true element exactly
    once and never exceeds the per-leaf sum (padding can only shrink when
    leaves fuse; scale overhead amortizes)."""
    plan = _plan([(5,), (192,), (96,), (700,)])
    bp = BK.make_bucket_plan(plan, 64.0)
    codec = make_codec("sign1bit")
    for mode in ("tensor", "chunk", "row"):
        leaf_sum = sum(sum(codec.wire_bytes(lo, mode).values())
                       for lo in plan.layouts)
        bucket_sum = sum(sum(codec.wire_bytes(b.layout, mode).values())
                         for b in bp.buckets)
        assert bucket_sum <= leaf_sum, (mode, bucket_sum, leaf_sum)
    assert (sum(b.true_elems for b in bp.buckets)
            == sum(C.true_counts(lo)[0] for lo in plan.layouts))


# --------------------------------------------------------------------- #
# pack_order: readiness-ordered (reverse_backward) unit issue
# --------------------------------------------------------------------- #

def test_pack_order_validated():
    plan = _plan([(64,), (32,)])
    with pytest.raises(ValueError, match="pack_order"):
        BK.make_bucket_plan(plan, 64.0, pack_order="bogus")
    with pytest.raises(ValueError, match="pack_order"):
        BK.exchange_units(plan, pack_order="forward")


def test_reverse_backward_unit_order():
    """reverse_backward reverses the per-leaf issue order and the bucket
    assembly order, and the declared sync schedule follows it (unit
    ordinals still count up in issue order — that is what the IR auditor
    matches region-by-region)."""
    plan = _plan([(64,), (32,), (96,)])
    flat = BK.exchange_units(plan, pack_order="flat")
    rev = BK.exchange_units(plan, pack_order="reverse_backward")
    assert [l for _, _, l in rev] == [l for _, _, l in flat][::-1]

    # bucketed: packing iterates leaves in reverse, so a single fused
    # bucket's member order is the reversed flat order
    bp = BK.make_bucket_plan(plan, 64.0, pack_order="reverse_backward")
    assert len(bp.buckets) == 1
    assert bp.buckets[0].members == (2, 1, 0)

    cfg = AR.OneBitConfig(codec="sign1bit")

    def first_labels(sched):
        out = []
        for e in sched:
            if not out or out[-1] != e.unit_label:
                out.append(e.unit_label)
        return out

    sf = BK.expected_sync_schedule(plan, cfg)
    sr = BK.expected_sync_schedule(plan, cfg,
                                   pack_order="reverse_backward")
    assert first_labels(sr) == first_labels(sf)[::-1]
    assert [e.unit for e in sr] == sorted(e.unit for e in sr)
    ff = BK.expected_fullprec_schedule(plan, cfg)
    fr = BK.expected_fullprec_schedule(plan, cfg,
                                       pack_order="reverse_backward")
    assert first_labels(fr) == first_labels(ff)[::-1]


@pytest.mark.parametrize("hier", [False, True])
def test_reverse_backward_per_leaf_bitwise(hier):
    """Per-leaf exchanges are independent, so reversing the issue order
    must not change a single bit of the trajectory."""
    cfg = OptimizerConfig(name="zero_one_adam",
                          hierarchy=Hierarchy(inner=2) if hier else None,
                          **POLICIES)
    xa, _ = _run(build_optimizer(cfg, PARAMS, n_workers=N), hier=hier)
    xb, _ = _run(build_optimizer(
        dataclasses.replace(cfg, pack_order="reverse_backward"),
        PARAMS, n_workers=N), hier=hier)
    for a, b in zip(jax.tree.leaves(xa), jax.tree.leaves(xb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_reverse_backward_bucketed_identity_exact():
    """Reverse packing recomposes the multi-leaf bucket (different member
    order), but the identity codec's transport is elementwise-exact, so
    the trajectory is bitwise the flat packing's."""
    cfg = OptimizerConfig(name="zero_one_adam", codec="identity",
                          bucket_mb=64.0, **POLICIES)
    a = build_optimizer(cfg, PARAMS, n_workers=N)
    b = build_optimizer(
        dataclasses.replace(cfg, pack_order="reverse_backward"),
        PARAMS, n_workers=N)
    assert ([bk.members for bk in b.bucket_plan.buckets]
            != [bk.members for bk in a.bucket_plan.buckets])
    xa, _ = _run(a)
    xb, _ = _run(b)
    for l, r in zip(jax.tree.leaves(xa), jax.tree.leaves(xb)):
        np.testing.assert_array_equal(np.asarray(l), np.asarray(r))


# --------------------------------------------------------------------- #
# trajectory equivalence: bucketed vs per-leaf
# --------------------------------------------------------------------- #

def _run(opt, steps=8, hier=False):
    key = jax.random.PRNGKey(3)
    xs = jax.tree.map(lambda x: jnp.broadcast_to(x, (N,) + x.shape) + 0,
                      PARAMS)
    state = jax.vmap(lambda _: opt.init(PARAMS))(jnp.arange(N))

    def step(x, g, s):
        return opt.step(sim_comm("w") if not hier
                        else Comm(("pod", "data")), x, g, s)

    if hier:
        lead = lambda x: x.reshape((2, 2) + x.shape[1:])
        unlead = lambda x: x.reshape((N,) + x.shape[2:])
        mapped = jax.vmap(jax.vmap(step, axis_name="data"),
                          axis_name="pod")

        @jax.jit
        def one(xs, state, k):
            ks = jax.random.split(k, N)
            g = jax.vmap(lambda kk, x: jax.tree.map(
                lambda l: jax.random.normal(jax.random.fold_in(kk, 7),
                                            l.shape), x))(ks, xs)
            nx, ns, _ = mapped(jax.tree.map(lead, xs),
                               jax.tree.map(lead, g),
                               jax.tree.map(lead, state))
            return jax.tree.map(unlead, nx), jax.tree.map(unlead, ns)
    else:
        mapped = jax.vmap(step, axis_name="w")

        @jax.jit
        def one(xs, state, k):
            ks = jax.random.split(k, N)
            g = jax.vmap(lambda kk, x: jax.tree.map(
                lambda l: jax.random.normal(jax.random.fold_in(kk, 7),
                                            l.shape), x))(ks, xs)
            nx, ns, _ = mapped(xs, g, state)
            return nx, ns

    for _ in range(steps):
        key, sk = jax.random.split(key)
        xs, state = one(xs, state, sk)
    return xs, state


def _max_diff(a, b):
    return max(float(np.abs(np.asarray(x, np.float64)
                            - np.asarray(y, np.float64)).max())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.mark.parametrize("pallas", [False, True])
@pytest.mark.parametrize("hier", [False, True])
@pytest.mark.parametrize("codec", sorted(CODEC_NAMES))
def test_one_leaf_per_bucket_bitwise(codec, hier, pallas):
    """bucket_mb below every leaf size -> one bucket per leaf -> the
    bucketed path must be BITWISE the per-leaf path, for every codec,
    both topologies, kernels on and off."""
    cfg = OptimizerConfig(name="zero_one_adam", codec=codec,
                          use_pallas=pallas,
                          hierarchy=Hierarchy(inner=2) if hier else None,
                          **POLICIES)
    per_leaf = build_optimizer(cfg, PARAMS, n_workers=N)
    bucketed = build_optimizer(dataclasses.replace(cfg, bucket_mb=1e-6),
                               PARAMS, n_workers=N)
    assert len(bucketed.bucket_plan.buckets) == 3
    xa, _ = _run(per_leaf, hier=hier)
    xb, _ = _run(bucketed, hier=hier)
    for a, b in zip(jax.tree.leaves(xa), jax.tree.leaves(xb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_one_leaf_per_bucket_bitwise_one_bit_adam():
    cfg = OptimizerConfig(name="one_bit_adam", lr=S.ConstantLr(1e-2),
                          onebit_warmup=3)
    xa, sa = _run(build_optimizer(cfg, PARAMS, n_workers=N))
    xb, sb = _run(build_optimizer(dataclasses.replace(cfg, bucket_mb=1e-6),
                                  PARAMS, n_workers=N))
    for a, b in zip(jax.tree.leaves(xa), jax.tree.leaves(xb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_one_leaf_per_bucket_lamb_ulp():
    """0/1-LAMB computes global trust norms whose XLA reduction fuses
    differently around the bucket gather/scatter ops — 1-ulp trust
    wobble, so the contract for lamb is <= 1e-6 rather than bitwise."""
    cfg = OptimizerConfig(name="zero_one_lamb", **POLICIES)
    xa, _ = _run(build_optimizer(cfg, PARAMS, n_workers=N))
    xb, _ = _run(build_optimizer(dataclasses.replace(cfg, bucket_mb=1e-6),
                                 PARAMS, n_workers=N))
    assert _max_diff(xa, xb) <= 1e-6


@pytest.mark.parametrize("hier", [False, True])
def test_multi_leaf_bucket_identity_codec_exact(hier):
    """Multi-leaf fusion with the exact (identity) codec: the transport is
    elementwise, so the 8-step trajectory must stay within 1e-6 of the
    per-leaf path — it is in fact bitwise."""
    cfg = OptimizerConfig(name="zero_one_adam", codec="identity",
                          hierarchy=Hierarchy(inner=2) if hier else None,
                          **POLICIES)
    xa, _ = _run(build_optimizer(cfg, PARAMS, n_workers=N), hier=hier)
    big = build_optimizer(dataclasses.replace(cfg, bucket_mb=64.0),
                          PARAMS, n_workers=N)
    assert len(big.bucket_plan.buckets) == 1
    xb, _ = _run(big, hier=hier)
    assert _max_diff(xa, xb) <= 1e-6
    for a, b in zip(jax.tree.leaves(xa), jax.tree.leaves(xb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_multi_leaf_bucket_sign1bit_bounded():
    """Multi-leaf fusion under sign1bit changes the scale granularity to
    per-bucket (the documented semantic change): the trajectories are no
    longer bitwise, but stay bounded and the workers stay in consensus."""
    cfg = OptimizerConfig(name="zero_one_adam", **POLICIES)
    xa, _ = _run(build_optimizer(cfg, PARAMS, n_workers=N))
    big = build_optimizer(dataclasses.replace(cfg, bucket_mb=64.0),
                          PARAMS, n_workers=N)
    xb, _ = _run(big)
    # bounded drift (EF keeps both calibrated) + exact worker consensus
    assert _max_diff(xa, xb) < 50.0
    for leaf in jax.tree.leaves(xb):
        arr = np.asarray(leaf)
        np.testing.assert_array_equal(arr, np.broadcast_to(arr[:1],
                                                           arr.shape))


def test_full_state_bucket_shapes_and_accounting():
    """EF state and anchors live per bucket; accounting reports the
    dispatch-count reduction."""
    cfg = OptimizerConfig(name="zero_one_adam", bucket_mb=64.0, **POLICIES)
    opt = build_optimizer(cfg, PARAMS, n_workers=N)
    bp = opt.bucket_plan
    assert len(bp.buckets) == 1
    state = opt.init(PARAMS)
    assert len(state.err_w) == 1
    assert state.err_w[0].shape == bp.buckets[0].layout.ef_worker_shape
    assert state.err_s[0].shape == bp.buckets[0].layout.chunk_shape
    assert state.anchor[0].shape == bp.buckets[0].layout.view_shape
    kinds = opt.state_kinds()
    assert kinds.err_w[0].tag == "bucket_view"
    assert kinds.err_s[0].tag == "bucket_chunk"
    assert kinds.anchor[0].tag == "bucket_view"

    acct = comm_accounting(opt)
    per_leaf = comm_accounting(build_optimizer(
        dataclasses.replace(cfg, bucket_mb=None), PARAMS, n_workers=N))
    assert acct["exchange_units"] == 1.0
    assert per_leaf["exchange_units"] == 3.0
    assert acct["collectives_per_sync"] == 2.0
    assert per_leaf["collectives_per_sync"] == 6.0
    assert acct["dp_params"] == per_leaf["dp_params"]
    assert acct["compressed_bytes_per_sync"] \
        <= per_leaf["compressed_bytes_per_sync"]
