"""AST repo-invariant lints: the repo itself is clean, and each rule
fires on a seeded offending file (including the waiver escape hatch)."""
import textwrap

from repro.analysis.lints import run_lints


def test_repo_is_clean():
    findings = run_lints()
    assert findings == [], "\n".join(str(f) for f in findings)


def _lint_snippet(tmp_path, code, name="offender.py"):
    f = tmp_path / name
    f.write_text(textwrap.dedent(code))
    return run_lints([str(f)])


def test_raw_collective_rule(tmp_path):
    findings = _lint_snippet(tmp_path, """
        import jax

        def bad(x):
            return jax.lax.psum(x, "data")
    """)
    assert [f.rule for f in findings] == ["raw-collective"]
    assert "psum" in findings[0].message


def test_raw_collective_waiver(tmp_path):
    findings = _lint_snippet(tmp_path, """
        import jax

        def ok(x):
            return jax.lax.psum(x, "data")  # audit-ok: raw-collective
    """)
    assert findings == []


def test_raw_collective_allowed_in_comm(tmp_path):
    comm_dir = tmp_path / "core"
    comm_dir.mkdir()
    f = comm_dir / "comm.py"
    f.write_text("import jax\n\ndef psum(x):\n"
                 "    return jax.lax.psum(x, 'data')\n")
    assert run_lints([str(f)]) == []


def test_comm_view_reshape_rule(tmp_path):
    findings = _lint_snippet(tmp_path, """
        def bad(x, layout):
            return x.reshape(layout.view_shape)
    """)
    assert [f.rule for f in findings] == ["comm-view-reshape"]
    assert "view_shape" in findings[0].message


def test_statekind_registry_rule(tmp_path):
    findings = _lint_snippet(tmp_path, """
        from repro.core.compressed import StateKind

        def bad():
            return StateKind(tag="dp", leaf=0)
    """)
    assert [f.rule for f in findings] == ["statekind-registry"]


def test_float64_literal_rule(tmp_path):
    findings = _lint_snippet(tmp_path, """
        import jax.numpy as jnp

        def bad(x):
            return x.astype(jnp.float64)
    """)
    assert [f.rule for f in findings] == ["float64-literal"]
    # host-side numpy f64 (counting helpers) is allowed
    assert _lint_snippet(tmp_path, """
        import numpy as np

        def ok(x):
            return x.astype(np.float64)
    """, name="ok64.py") == []


def test_cli_exit_codes(tmp_path):
    from repro.analysis.lints import main
    f = tmp_path / "bad.py"
    f.write_text("import jax\nx = jax.lax.pmean(0.0, 'data')\n")
    assert main([str(f)]) == 1
    g = tmp_path / "good.py"
    g.write_text("x = 1\n")
    assert main([str(g)]) == 0
