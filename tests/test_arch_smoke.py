"""Per-architecture smoke tests: REDUCED variant of each assigned family
(2 layers, d_model <= 512, <= 4 experts) runs one forward + one train step
on CPU; output shapes asserted, no NaNs. Decode-capable archs also check
prefill/decode logits consistency against the training forward.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get, list_archs
from repro.core import OptimizerConfig, schedules as S
from repro.models import transformer as T
from repro.train import Trainer

OPT = OptimizerConfig(
    name="zero_one_adam", lr=S.ConstantLr(1e-3),
    var_policy=S.AdaptiveFreezePolicy(kappa=2),
    sync_policy=S.LrProportionalSyncPolicy(warmup_steps=2, double_every=4,
                                           max_interval=4))

PAPER_OWN = ["bert-base", "bert-large", "gpt2"]


def _batch(cfg, B, S_):
    b = {"tokens": jnp.ones((B, S_), jnp.int32) * 3,
         "labels": jnp.ones((B, S_), jnp.int32) * 5}
    if cfg.enc_layers:
        b["frames"] = jnp.ones((B, cfg.enc_frames, cfg.d_model)) * 0.1
    if cfg.vision_tokens:
        b["vision_embeds"] = jnp.ones((B, cfg.vision_tokens,
                                       cfg.d_model)) * 0.1
    if not cfg.causal:
        b["loss_mask"] = jnp.ones((B, S_), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ASSIGNED + PAPER_OWN)
def test_smoke_one_train_step(arch):
    spec = get(arch)
    cfg = spec.smoke
    assert cfg.n_layers <= 6 and cfg.d_model <= 512
    assert cfg.n_experts <= 4
    tr = Trainer(cfg, OPT, n_workers=1)
    params, state = tr.single_init(jax.random.PRNGKey(0))
    fn = tr.single_step_fn()
    B, S_ = 2, 16
    batch = _batch(cfg, B, S_)
    for _ in range(2):
        params, state, met = fn(params, state, batch)
    assert np.isfinite(float(met["loss"]))
    for leaf in jax.tree.leaves(params):
        assert leaf.shape[0] >= 1
        assert not bool(jnp.isnan(leaf).any()), f"NaN in {arch} params"
    # loss decreases on a repeated batch within a few steps
    l0 = float(met["loss"])
    for _ in range(4):
        params, state, met = fn(params, state, batch)
    assert float(met["loss"]) < l0


@pytest.mark.parametrize("arch", [a for a in ASSIGNED
                                  if "decode_32k" in get(a).shapes])
def test_smoke_decode_consistency(arch):
    cfg = get(arch).smoke
    if cfg.n_experts:
        # capacity-based MoE drops depend on the token count per call;
        # a no-drop capacity factor makes decode/prefill/forward agree
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    tmpl = T.model_template(cfg)
    from repro.models.layers import init_params
    params = init_params(tmpl, jax.random.PRNGKey(0))
    B = 2
    S_ = 12 if cfg.family not in ("ssm", "hybrid") else 17
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S_), 0, cfg.vocab)
    batch = _batch(cfg, B, S_)
    batch["tokens"] = toks
    pre_len = S_ - 1
    if cfg.family in ("ssm", "hybrid"):
        assert pre_len % cfg.ssm_chunk == 0
    cache = T.init_cache(cfg, B, 32, dtype=jnp.float32)
    pre_batch = dict(batch)
    pre_batch["tokens"] = toks[:, :pre_len]
    enc_out = (T.encode(params, cfg, batch["frames"])
               if cfg.enc_layers else None)
    if enc_out is not None:
        pre_batch["enc_out"] = enc_out
    lg_pre, cache = T.prefill(params, cfg, pre_batch, cache)
    lg_dec, cache = T.decode(params, cfg, toks[:, pre_len:pre_len + 1],
                             cache, jnp.int32(pre_len), enc_out=enc_out)
    assert lg_dec.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(lg_dec).all())
    # consistency against the full forward (chunk-compatible cfg)
    full_cfg = (dataclasses.replace(cfg, ssm_chunk=S_)
                if cfg.family in ("ssm", "hybrid") else cfg)
    lg_full, _ = T.forward(params, full_cfg, batch)
    np.testing.assert_allclose(np.asarray(lg_dec[:, 0]),
                               np.asarray(lg_full[:, pre_len]),
                               rtol=2e-3, atol=2e-3)


def test_all_assigned_archs_registered():
    archs = list_archs()
    for a in ASSIGNED:
        assert a in archs
    assert len(ASSIGNED) == 10
