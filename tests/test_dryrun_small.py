"""Multi-device lowering proof in CI: a reduced mesh dry-run in a
subprocess so the forced device count never leaks into other tests.
Covers: train step (shard_map, compressed optimizer), serve decode, and a
multi-pod (3-axis) variant — the same machinery launch/dryrun.py runs at
(2,16,16) scale.
"""
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import dataclasses
    import jax, jax.numpy as jnp
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.dryrun import default_opt_cfg, collective_bytes
    from repro.train import Trainer, TrainerConfig
    from repro.serve import Server
    from repro.configs import get

    # ---- train step on (data=2, model=4) + multi-pod (2,2,4) ----
    for mesh, W in ((make_debug_mesh(data=2, model=4), ("data",)),
                    (make_debug_mesh(pod=2, data=2, model=4),
                     ("pod", "data"))):
        cfg = dataclasses.replace(get("chatglm3-6b").smoke,
                                  param_dtype=jnp.bfloat16,
                                  compute_dtype=jnp.bfloat16)
        tr = Trainer(cfg, default_opt_cfg(), mesh=mesh,
                     trainer_cfg=TrainerConfig(micro_batches=2,
                                               worker_axes=W))
        fn, _ = tr.mesh_step_fn()
        params, state, batch = tr.abstract_inputs(8, 16)
        co = fn.lower(params, state, batch).compile()
        cb, cc = collective_bytes(co.as_text())
        assert cb["all-to-all"] > 0 or cb["all-gather"] > 0, cb
        print("TRAIN_OK", mesh.shape, sum(cb.values()))

    # ---- MoE train (EP dispatch) ----
    mesh = make_debug_mesh(data=4, model=2)
    cfgm = dataclasses.replace(get("llama4-scout-17b-a16e").smoke,
                               param_dtype=jnp.bfloat16,
                               compute_dtype=jnp.bfloat16)
    tr = Trainer(cfgm, default_opt_cfg(), mesh=mesh,
                 trainer_cfg=TrainerConfig(worker_axes=("data",)))
    assert tr.ep_degree == 4, tr.ep_degree
    fn, _ = tr.mesh_step_fn()
    params, state, batch = tr.abstract_inputs(8, 16)
    fn.lower(params, state, batch).compile()
    print("MOE_TRAIN_OK")

    # ---- serve decode (auto path) ----
    mesh = make_debug_mesh(data=2, model=4)
    cfg = dataclasses.replace(get("gemma3-12b").smoke,
                              param_dtype=jnp.bfloat16,
                              compute_dtype=jnp.bfloat16)
    srv = Server(cfg, mesh=mesh, worker_axes=("data",), batch=4, max_seq=64)
    co = srv.decode_fn().lower(
        srv.abstract_params(), srv.abstract_cache(),
        jax.ShapeDtypeStruct((4, 1), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32)).compile()
    print("SERVE_OK")
""")


SYNTH_HLO = textwrap.dedent("""\
    HloModule synth

    %body.1 (arg.1: (s32[], f32[4])) -> (s32[], f32[4]) {
      %arg.1 = (s32[], f32[4]) parameter(0)
      ROOT %tup.1 = (s32[], f32[4]) tuple(%arg.1)
    }

    %cond.1 (arg.2: (s32[], f32[4])) -> pred[] {
      %arg.2 = (s32[], f32[4]) parameter(0)
      %gte.0 = s32[] get-tuple-element(%arg.2), index=0
      %big.0 = s32[] constant(32768)
      %noise.0 = pred[] compare(%gte.0, %big.0), direction=NE
      %bound.0 = s32[] constant(4)
      ROOT %cmp.0 = pred[] compare(%gte.0, %bound.0), direction=LT
    }

    %body.2 (arg.3: (s32[], f32[4])) -> (s32[], f32[4]) {
      %arg.3 = (s32[], f32[4]) parameter(0)
      ROOT %tup.2 = (s32[], f32[4]) tuple(%arg.3)
    }

    %cond.2 (arg.4: (s32[], f32[4])) -> pred[] {
      %arg.4 = (s32[], f32[4]) parameter(0)
      %gte.1 = s32[] get-tuple-element(%arg.4), index=0
      %bound.1 = s32[] constant(5)
      ROOT %cmp.1 = pred[] compare(%gte.1, %bound.1), direction=LE
    }

    %body.3 (arg.5: (s32[], f32[4])) -> (s32[], f32[4]) {
      %arg.5 = (s32[], f32[4]) parameter(0)
      ROOT %tup.3 = (s32[], f32[4]) tuple(%arg.5)
    }

    %cond.3 (arg.6: (s32[], f32[4])) -> pred[] {
      %arg.6 = (s32[], f32[4]) parameter(0)
      %odd.0 = s32[] constant(7)
      ROOT %root.3 = pred[] custom-call(%arg.6), custom_call_target="opaque"
    }

    ENTRY %main.1 (p.0: (s32[], f32[4])) -> (s32[], f32[4]) {
      %p.0 = (s32[], f32[4]) parameter(0)
      %w.1 = (s32[], f32[4]) while(%p.0), condition=%cond.1, body=%body.1
      %w.2 = (s32[], f32[4]) while(%w.1), condition=%cond.2, body=%body.2
      ROOT %w.3 = (s32[], f32[4]) while(%w.2), condition=%cond.3, body=%body.3
    }
""")


def test_loop_multiplier_reads_compare_bound():
    """The trip count comes from the loop-bound compare, not the largest
    integer constant in the condition block: a microbatch scan whose cond
    also materializes an unrelated schedule literal (constant(32768))
    must scale its body 4x, not 32768x. LE bounds add one; conditions
    with no parseable compare fall back to the legacy heuristic."""
    from repro.launch.dryrun import _computation_blocks, _loop_multipliers
    blocks = _computation_blocks(SYNTH_HLO)
    assert {"body.1", "cond.1", "body.2", "cond.2", "body.3", "cond.3",
            "main.1"} <= set(blocks)
    mult = _loop_multipliers(SYNTH_HLO, blocks)
    assert mult["body.1"] == 4       # direction=LT -> the bound itself
    assert mult["body.2"] == 6       # direction=LE -> bound + 1
    assert mult["body.3"] == 7       # no compare -> legacy max heuristic


@pytest.mark.slow
def test_reduced_mesh_dryrun():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=900,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert "TRAIN_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]
    assert "MOE_TRAIN_OK" in r.stdout, r.stderr[-3000:]
    assert "SERVE_OK" in r.stdout, r.stderr[-3000:]
