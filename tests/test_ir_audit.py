"""IR communication audit: clean passes across the shipped config matrix,
and guaranteed detection of seeded violations (smuggled inter-pod psum,
reordered schedule, codec payload-dtype lie) with errors naming the
offending collective/bucket/dtype."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (audit_trainer, build_manifests, check_schedule,
                            concretize_manifest, trace_collectives)
from repro.configs import get
from repro.core import codecs as CD
from repro.core.api import OptimizerConfig
from repro.core.bucketing import (exchange_units, expected_fullprec_schedule,
                                  expected_sync_schedule)
from repro.core.comm import Hierarchy
from repro.kernels.dispatch import frame_precheck
from repro.train.step import Trainer, TrainerConfig


def _trainer(codec="sign1bit", hierarchy_inner=0, bucket_mb=None,
             optimizer="zero_one_adam", workers=4, **kw):
    ocfg = OptimizerConfig(
        name=optimizer, codec=codec, bucket_mb=bucket_mb,
        hierarchy=Hierarchy(inner=hierarchy_inner) if hierarchy_inner
        else None, **kw)
    return Trainer(get("gpt2").smoke, ocfg, n_workers=workers,
                   trainer_cfg=TrainerConfig(micro_batches=1))


# ------------------------------------------------------------------ #
# clean passes: the ISSUE's acceptance matrix
# ------------------------------------------------------------------ #

@pytest.mark.parametrize("codec", ["sign1bit", "qint8", "identity"])
@pytest.mark.parametrize("hierarchy_inner", [0, 2])
@pytest.mark.parametrize("bucket_mb", [None, 4.0])
def test_clean_matrix(codec, hierarchy_inner, bucket_mb):
    rep = audit_trainer(_trainer(codec=codec,
                                 hierarchy_inner=hierarchy_inner,
                                 bucket_mb=bucket_mb))
    assert rep.ok, [str(v.message) for v in rep.violations[:3]]
    assert rep.summary["sync_collectives_declared"] > 0
    if hierarchy_inner:
        assert rep.summary["interpod_sync_bytes"] > 0


@pytest.mark.parametrize("codec", ["topk", "qint4"])
def test_clean_remaining_codecs(codec):
    rep = audit_trainer(_trainer(codec=codec))
    assert rep.ok, [str(v.message) for v in rep.violations[:3]]


@pytest.mark.parametrize("optimizer", ["one_bit_adam", "adam"])
def test_clean_other_styles(optimizer):
    rep = audit_trainer(_trainer(optimizer=optimizer))
    assert rep.ok, [str(v.message) for v in rep.violations[:3]]
    if optimizer == "adam":   # mean style: full-precision only, no sync
        assert rep.summary["sync_collectives_declared"] == 0
        assert rep.summary["fullprec_collectives_declared"] > 0


# ------------------------------------------------------------------ #
# seeded violations — each must be caught, naming the offender
# ------------------------------------------------------------------ #

def test_smuggled_interpod_psum_is_caught():
    tr = _trainer(hierarchy_inner=2)

    def wrap(one):
        def evil(params, state, batch):
            leak = jax.lax.psum(jnp.zeros((1024,), jnp.float32), "pod")
            p, s, met = one(params, state, batch)
            met = dict(met)
            met["leak"] = leak.sum()
            return p, s, met
        return evil

    rep = audit_trainer(tr, wrap_step=wrap)
    assert not rep.ok
    codes = [v.code for v in rep.violations]
    assert "interpod-bytes" in codes, codes
    msg = next(v.message for v in rep.violations
               if v.code == "interpod-bytes")
    # names the op, the axes it crossed, the dtype, and the eqn position
    assert "psum" in msg and "pod" in msg and "float32" in msg
    assert "eqn #" in msg


def test_reordered_schedule_is_caught():
    tr = _trainer(hierarchy_inner=2)
    trace = trace_collectives(tr)
    sync_m, fp_m = build_manifests(tr.opt)
    sync_c = concretize_manifest(sync_m, tr)
    fp_c = concretize_manifest(fp_m, tr)
    # control: the unmodified manifests match
    assert check_schedule(trace, sync_c, fp_c, tr) == []
    bad = list(sync_c)
    bad[2], bad[3] = bad[3], bad[2]
    vs = check_schedule(trace, bad, fp_c, tr)
    assert vs and vs[0].code == "schedule"
    # names the position, the expected entry's unit/leaf, and the found eqn
    assert "position 2" in vs[0].message
    assert "leaf[0]" in vs[0].message or "bucket[0]" in vs[0].message
    assert "eqn #" in vs[0].message


def test_payload_dtype_lie_is_caught():
    class LyingSign1Bit(CD.Sign1BitCodec):
        def payload_spec(self, layout):
            leaves = (("packed", jnp.uint8), ("scales", jnp.float16))
            return {"scatter": leaves, "gather": leaves}

    rep = audit_trainer(_trainer(codec=LyingSign1Bit()))
    assert not rep.ok
    assert any(v.code == "payload-dtype" for v in rep.violations)
    msg = next(v.message for v in rep.violations
               if v.code == "payload-dtype")
    # names the declared vs lowered dtype and the payload leaf
    assert "float16" in msg and "float32" in msg and "scales" in msg


# ------------------------------------------------------------------ #
# declared-manifest internals
# ------------------------------------------------------------------ #

def test_payload_spec_matches_wire_bytes():
    """Every shipped codec's declared payload dtypes reproduce its
    wire_bytes accounting on a real layout (per-chunk scale broadcast
    degeneracies aside)."""
    tr = _trainer()
    plan, ar_cfg = tr.opt.plan, tr.opt.ar_cfg
    sched = expected_sync_schedule(plan, ar_cfg, tr.opt.bucket_plan)
    for u, (lo, _, label) in enumerate(exchange_units(plan,
                                                      tr.opt.bucket_plan)):
        wire = ar_cfg.codec.wire_bytes(lo, ar_cfg.scale_mode)
        for phase, lead in (("scatter", lo.n), ("gather", 1)):
            got = sum(e.nbytes for e in sched
                      if e.unit == u and e.phase == phase)
            assert abs(got - lead * wire[phase]) <= 4 * lead, (
                label, phase, got, lead * wire[phase])


def test_mean_style_has_no_sync_manifest():
    tr = _trainer(optimizer="adam")
    sync, fullprec = build_manifests(tr.opt)
    assert sync == []
    assert len(fullprec) > 0
    assert all(e.round == "fullprec" for e in fullprec)


def test_fullprec_schedule_counts():
    tr = _trainer(hierarchy_inner=2)
    fp = expected_fullprec_schedule(tr.opt.plan, tr.opt.ar_cfg,
                                    tr.opt.bucket_plan)
    units = exchange_units(tr.opt.plan, tr.opt.bucket_plan)
    # hierarchical: 4 collectives per unit (iRS, oA2A, oAG, iAG)
    assert len(fp) == 4 * len(units)


# ------------------------------------------------------------------ #
# static Pallas frame pre-check
# ------------------------------------------------------------------ #

def test_frame_precheck_clean_on_shipped_layouts():
    for bucket_mb in (None, 4.0):
        tr = _trainer(bucket_mb=bucket_mb)
        for lo, _, label in exchange_units(tr.opt.plan, tr.opt.bucket_plan):
            assert frame_precheck(lo) == [], label


def test_frame_precheck_flags_bad_frames():
    from repro.core import compressor as C
    # flatten layouts pad to the n*128 quantum -> always clean
    assert frame_precheck(C.make_layout((4096,), None, 4)) == []
    # structured (non-flatten) view with a 96-wide last axis: breaks the
    # 128-lane tile
    lo = C.LeafLayout(shape=(8, 96), n=4, flatten=False, split_axis=0,
                      padded=8, view_shape=(4, 2, 96))
    issues = frame_precheck(lo)
    assert any("128" in i for i in issues), issues
    # enormous unfolded cols: blows both FRAME_MAX_COLS and the VMEM budget
    wide = C.LeafLayout(shape=(8, 128 * 8192), n=4, flatten=False,
                        split_axis=0, padded=8,
                        view_shape=(4, 2, 128 * 8192))
    issues = frame_precheck(wide)
    assert any("VMEM" in i for i in issues), issues
    assert any("FRAME_MAX_COLS" in i for i in issues), issues


# ------------------------------------------------------------------ #
# CLI plumbing
# ------------------------------------------------------------------ #

def test_audit_cli_exit_codes(capsys):
    from repro.launch.audit import main
    assert main(["--config", "gpt2", "--workers", "2"]) == 0
    out = capsys.readouterr().out
    assert "audit OK" in out


def test_dryrun_audit_fails_loudly(monkeypatch, capsys):
    """--audit must exit non-zero and print the first violation, not just
    write JSON (run_one stubbed: the real mesh lowering is the slow-marked
    dry-run test's job)."""
    import sys

    import repro.launch.dryrun as DR

    rec = {"arch": "gpt2", "shape": "train_4k", "status": "ok",
           "audit": {"ok": False, "violations": [
               {"code": "interpod-bytes",
                "message": "psum over ('pod',) float32(1024,)"}]}}
    monkeypatch.setattr(DR, "run_one", lambda *a, **k: dict(rec))
    monkeypatch.setattr(sys, "argv", ["dryrun", "--arch", "gpt2",
                                      "--shape", "train_4k", "--audit"])
    assert DR.main() == 1
    out = capsys.readouterr().out
    assert "AUDIT FAILED" in out
    assert "interpod-bytes" in out and "float32" in out

    ok = {"arch": "gpt2", "shape": "train_4k", "status": "ok",
          "audit": {"ok": True, "violations": []}}
    monkeypatch.setattr(DR, "run_one", lambda *a, **k: dict(ok))
    assert DR.main() == 0
