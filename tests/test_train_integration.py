"""End-to-end trainer integration: sim-mode 0/1 Adam on a real tiny LM
(the paper's Fig. 2 setup at unit scale), microbatching equivalence,
peeled (overlapped) vs sequential accumulation parity, checkpoint
roundtrip, data determinism.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing import io as ckpt_io
from repro.configs import get
from repro.core import Hierarchy, OptimizerConfig, schedules as S
from repro.data import DataConfig, SyntheticLM, worker_shard
from repro.train import Trainer, TrainerConfig
from repro.train.step import accumulate_grads

OPT = OptimizerConfig(
    name="zero_one_adam",
    lr=S.LinearWarmupExpDecay(peak_lr=2e-3, warmup_steps=10, decay=0.97,
                              decay_period=20),
    var_policy=S.AdaptiveFreezePolicy(kappa=4),
    sync_policy=S.LrProportionalSyncPolicy(warmup_steps=10, double_every=20,
                                           max_interval=4))


def test_sim_training_loss_decreases_and_consensus():
    cfg = get("gpt2").smoke
    tr = Trainer(cfg, OPT, n_workers=4)
    params, state = tr.sim_init(jax.random.PRNGKey(0))
    fn = tr.sim_step_fn()
    # stream over a sub-vocabulary: the model learns the support quickly,
    # giving clear loss signal within CI budget
    data = SyntheticLM(DataConfig(vocab=64, seq_len=32,
                                  global_batch=8, seed=5))
    losses = []
    for step in range(40):
        params, state, met = fn(params, state, data.batch(step))
        losses.append(float(np.asarray(met["loss"]).reshape(-1)[0]))
        if bool(np.asarray(met["synced"]).reshape(-1)[0]):
            for leaf in jax.tree.leaves(params):
                arr = np.asarray(leaf)
                assert (arr == arr[:1]).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1
    assert np.isfinite(losses).all()


def test_microbatch_grad_equivalence():
    cfg = get("granite-3-8b").smoke
    tr1 = Trainer(cfg, OPT, n_workers=1,
                  trainer_cfg=TrainerConfig(micro_batches=1))
    tr4 = Trainer(cfg, OPT, n_workers=1,
                  trainer_cfg=TrainerConfig(micro_batches=4))
    p1, s1 = tr1.single_init(jax.random.PRNGKey(0))
    p4, s4 = tr4.single_init(jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=16,
                                  global_batch=8, seed=3))
    batch = data.batch(0)
    p1n, _, m1 = tr1.single_step_fn()(p1, s1, batch)
    p4n, _, m4 = tr4.single_step_fn()(p4, s4, batch)
    for a, b in zip(jax.tree.leaves(p1n), jax.tree.leaves(p4n)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-5)


# Dense policies so a handful of steps cover local steps, compressed
# syncs, and variance refreshes (same cadence as the golden suite).
DENSE_OPT = OptimizerConfig(
    name="zero_one_adam",
    lr=S.ConstantLr(1e-2),
    var_policy=S.AdaptiveFreezePolicy(kappa=2),
    sync_policy=S.LrProportionalSyncPolicy(warmup_steps=2, double_every=3,
                                           max_interval=4))


def _sim_run(ocfg, peel, steps=6):
    cfg = get("gpt2").smoke
    tr = Trainer(cfg, ocfg, n_workers=4,
                 trainer_cfg=TrainerConfig(micro_batches=2,
                                           peel_last_microbatch=peel))
    params, state = tr.sim_init(jax.random.PRNGKey(0))
    fn = tr.sim_step_fn()
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=16,
                                  global_batch=8, seed=11))
    losses = []
    for step in range(steps):
        params, state, met = fn(params, state, data.batch(step))
        losses.append(float(np.asarray(met["loss"]).reshape(-1)[0]))
    return params, losses


@pytest.mark.parametrize("pallas", [False, True])
@pytest.mark.parametrize("hier", [False, True])
def test_peeled_accumulation_bitwise(hier, pallas):
    """The overlapped step (last microbatch peeled out of the scan, each
    exchange unit issued under its own cond) must be BITWISE the
    sequential all-scanned step, across flat/hierarchical topologies and
    Pallas kernels on/off — the exchange schedule restructure may not
    move a single bit of the trajectory."""
    ocfg = dataclasses.replace(
        DENSE_OPT, use_pallas=pallas,
        hierarchy=Hierarchy(inner=2) if hier else None)
    p_peel, l_peel = _sim_run(ocfg, peel=True)
    p_seq, l_seq = _sim_run(ocfg, peel=False)
    # the scalar loss *metric* sums every token's cross-entropy in one big
    # reduction whose split XLA picks differently for the unrolled last
    # microbatch — 1 f32 ulp of wobble. The trajectory itself (params,
    # hence gradients and the whole exchange) must stay bitwise.
    np.testing.assert_allclose(l_peel, l_seq, rtol=1e-6)
    for a, b in zip(jax.tree.leaves(p_peel), jax.tree.leaves(p_seq)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_micro_batches_validated_at_config():
    with pytest.raises(ValueError, match="micro_batches must be >= 1"):
        TrainerConfig(micro_batches=0)
    with pytest.raises(ValueError, match="micro_batches must be >= 1"):
        TrainerConfig(micro_batches=-3)


def test_non_divisible_microbatch_split_names_both_numbers():
    """A per-worker batch that does not split evenly must fail at step
    construction with an error naming the offending leaf, its row count,
    and the microbatch count — not an opaque reshape error."""
    def loss(p, b):
        return jnp.sum(p["w"]) * jnp.sum(b["tokens"]), ()

    params = {"w": jnp.ones((3,))}
    batch = {"tokens": jnp.zeros((5, 4))}
    with pytest.raises(ValueError) as ei:
        accumulate_grads(loss, params, batch, 3)
    msg = str(ei.value)
    assert "tokens" in msg and "5 rows" in msg and "micro_batches=3" in msg


def _moe_losses(cfg):
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=16,
                                  global_batch=8, seed=3))
    batch = data.batch(0)
    tr1 = Trainer(cfg, OPT, n_workers=1)
    tr4 = Trainer(cfg, OPT, n_workers=4)
    p1, s1 = tr1.single_init(jax.random.PRNGKey(0))
    p4, s4 = tr4.sim_init(jax.random.PRNGKey(0))
    _, _, m1 = tr1.single_step_fn()(p1, s1, batch)
    _, _, m4 = tr4.sim_step_fn()(p4, s4, batch)
    return (float(np.asarray(m1["loss"]).reshape(-1)[0]),
            float(np.asarray(m4["loss"]).reshape(-1)[0]))


def test_moe_ep_sim_matches_single_worker_routing():
    """Sim-mode EP (experts split over 4 workers, a2a dispatch) must agree
    with single-worker MoE on the same global batch at init (fwd loss).

    Tolerance rationale: the capacity router allots each expert
    ``cf*T_local*k/E`` slots *per worker*. The EP regime therefore drops a
    token whenever one worker's local batch overfills an expert, even if
    the expert has global headroom — single-worker evaluation only drops on
    global overflow. At init routing is near-uniform, so the differing drop
    patterns move the loss by well under 0.05; anything larger indicates a
    dispatch bug, not capacity noise.
    """
    l1, l4 = _moe_losses(get("llama4-scout-17b-a16e").smoke)
    assert abs(l1 - l4) < 0.05, (l1, l4)


def test_moe_ep_sim_exact_when_no_drops():
    """With capacity large enough that no tokens drop in either regime the
    a2a dispatch must route every token to the same expert output — this
    pins the routing itself, with the capacity-drop divergence excluded.

    The residual gap is the Switch aux loss: it is quadratic in the routing
    histogram, and the EP regime averages per-worker-local histograms while
    the single worker uses the global one (E[f·p] != E[f]·E[p]) — a few
    1e-4 at init-uniform routing. The LM cross-entropy itself matches to
    f32 accumulation noise, so 2e-3 cleanly separates "statistics of the
    aux term" from "tokens routed to the wrong expert" (which moves the
    loss by >1e-2 even for a single misrouted token at this scale)."""
    import dataclasses
    cfg = dataclasses.replace(get("llama4-scout-17b-a16e").smoke,
                              capacity_factor=8.0)
    l1, l4 = _moe_losses(cfg)
    assert abs(l1 - l4) < 2e-3, (l1, l4)


def test_checkpoint_roundtrip(tmp_path):
    cfg = get("chatglm3-6b").smoke
    tr = Trainer(cfg, OPT, n_workers=1)
    params, state = tr.single_init(jax.random.PRNGKey(0))
    path = os.path.join(tmp_path, "ck.npz")
    ckpt_io.save(path, {"params": params}, step=7, meta={"arch": cfg.name})
    like = {"params": params}
    restored, step, meta = ckpt_io.restore(path, like)
    assert step == 7 and meta["arch"] == cfg.name
    for a, b in zip(jax.tree.leaves(restored["params"]),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_determinism_and_sharding():
    d = SyntheticLM(DataConfig(vocab=128, seq_len=16, global_batch=8,
                               seed=9))
    b1, b2 = d.batch(5), d.batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = d.batch(6)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    s0 = worker_shard(b1, 0, 4)
    s3 = worker_shard(b1, 3, 4)
    assert s0["tokens"].shape == (2, 16)
    assert not np.array_equal(np.asarray(s0["tokens"]),
                              np.asarray(s3["tokens"]))
    # learnable structure: labels follow the bigram table mostly
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["labels"][:, :-1]))
