"""Optimizer algorithm semantics under n simulated workers (vmap axis).

These are the paper's core claims at unit scale:
  * 0/1 Adam degenerates EXACTLY to distributed Adam when T_u = T_v =
    every-step and the compressor is the identity;
  * workers reach bitwise consensus at every sync (anchor mode);
  * error-feedback norms stay bounded (Lemma 1 behaviour);
  * 0/1 Adam with compression + local steps converges comparably to Adam
    on a quadratic and on a tiny LM (Fig. 2 claim, unit scale).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (OptimizerConfig, make_optimizer, sim_comm,
                        schedules as S)

N = 4
COMM = sim_comm("w")


def make_params(key):
    return {"w": jax.random.normal(key, (6, 16)),
            "b": jnp.zeros((5,)),
            "deep": {"k": jax.random.normal(jax.random.fold_in(key, 1),
                                            (3, 8, 8))}}


def rep(tree):
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (N,) + x.shape) + 0,
                        tree)


def run_steps(opt, params, grad_fn, steps, key):
    state = jax.vmap(lambda _: opt.init(params))(jnp.arange(N))
    xs = rep(params)

    @jax.jit
    def one(xs, state, k):
        grads = grad_fn(xs, k)
        return jax.vmap(lambda x, g, s: opt.step(COMM, x, g, s),
                        axis_name="w")(xs, grads, state)

    for _ in range(steps):
        key, sk = jax.random.split(key)
        xs, state, met = one(xs, state, sk)
    return xs, state, met


def noise_grads(xs, k):
    ks = jax.random.split(k, N)
    return jax.vmap(lambda kk, x: jax.tree.map(
        lambda l: jax.random.normal(jax.random.fold_in(kk, 7), l.shape),
        x))(ks, xs)


def test_degenerate_equivalence_with_adam():
    params = make_params(jax.random.PRNGKey(0))
    cfg01 = OptimizerConfig(
        name="zero_one_adam", lr=S.ConstantLr(1e-2),
        var_policy=S.EveryStepVariancePolicy(),
        sync_policy=S.EveryStepSyncPolicy(),
        quantize=False, comm_dtype=jnp.float32)
    cfgA = OptimizerConfig(name="adam", lr=S.ConstantLr(1e-2),
                           comm_dtype=jnp.float32)
    o1 = make_optimizer(cfg01, params, n_workers=N)
    oA = make_optimizer(cfgA, params, n_workers=N)
    x1, _, _ = run_steps(o1, params, noise_grads, 15, jax.random.PRNGKey(3))
    xA, _, _ = run_steps(oA, params, noise_grads, 15, jax.random.PRNGKey(3))
    for l1, lA in zip(jax.tree.leaves(x1), jax.tree.leaves(xA)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(lA),
                                   rtol=2e-5, atol=2e-5)


def test_bitwise_consensus_at_syncs():
    params = make_params(jax.random.PRNGKey(0))
    cfg = OptimizerConfig(
        name="zero_one_adam", lr=S.ConstantLr(1e-2),
        var_policy=S.AdaptiveFreezePolicy(kappa=2),
        sync_policy=S.LrProportionalSyncPolicy(warmup_steps=3,
                                               double_every=3,
                                               max_interval=2))
    opt = make_optimizer(cfg, params, n_workers=N)
    state = jax.vmap(lambda _: opt.init(params))(jnp.arange(N))
    xs = rep(params)
    key = jax.random.PRNGKey(5)

    @jax.jit
    def one(xs, state, k):
        grads = noise_grads(xs, k)
        return jax.vmap(lambda x, g, s: opt.step(COMM, x, g, s),
                        axis_name="w")(xs, grads, state)

    saw_sync_consensus = 0
    for _ in range(12):
        key, sk = jax.random.split(key)
        xs, state, met = one(xs, state, sk)
        if bool(np.asarray(met["synced"])[0]):
            for leaf in jax.tree.leaves(xs):
                arr = np.asarray(leaf)
                assert (arr == arr[:1]).all(), "workers diverged at sync"
            saw_sync_consensus += 1
    assert saw_sync_consensus >= 3


def test_error_feedback_bounded():
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 32))}
    cfg = OptimizerConfig(
        name="zero_one_adam", lr=S.ConstantLr(1e-2),
        var_policy=S.AdaptiveFreezePolicy(kappa=4),
        sync_policy=S.EveryStepSyncPolicy())
    opt = make_optimizer(cfg, params, n_workers=N)
    _, state, _ = run_steps(opt, params, noise_grads, 30,
                            jax.random.PRNGKey(2))
    for e in state.err_w + state.err_s:
        if e is None:
            continue
        assert float(jnp.abs(e).max()) < 10.0  # Lemma 1: no blow-up


def _quadratic_grads(target):
    def g(xs, k):
        ks = jax.random.split(k, N)
        def per(kk, x):
            return jax.tree.map(
                lambda l, t: (l - t) + 0.3 * jax.random.normal(
                    jax.random.fold_in(kk, 3), l.shape),
                x, target)
        return jax.vmap(per)(ks, xs)
    return g


# The paper always pairs Adam's zero-initialized v with a linear lr warmup
# (no bias correction in Eq. 3); tests follow that convention. lr is kept
# small relative to the compression error — the EF stability condition of
# Theorem 1 (gamma bounded by constants involving (1-omega)).
_TEST_LR = S.LinearWarmupExpDecay(peak_lr=1e-2, warmup_steps=30,
                                  decay=0.9, decay_period=50)


@pytest.mark.parametrize("opt_name", ["adam", "one_bit_adam",
                                      "zero_one_adam"])
def test_quadratic_convergence(opt_name):
    key = jax.random.PRNGKey(1)
    params = {"w": jax.random.normal(key, (8, 8)) * 3}
    target = {"w": jnp.ones((8, 8))}
    cfg = OptimizerConfig(
        name=opt_name, lr=_TEST_LR,
        var_policy=S.AdaptiveFreezePolicy(kappa=4),
        sync_policy=S.LrProportionalSyncPolicy(warmup_steps=20,
                                               double_every=40,
                                               max_interval=4),
        onebit_warmup=20)
    opt = make_optimizer(cfg, params, n_workers=N)
    xs, _, _ = run_steps(opt, params, _quadratic_grads(target), 300,
                         jax.random.PRNGKey(7))
    err = float(jnp.abs(xs["w"][0] - 1.0).mean())
    # initial distance ~2.5; all three must contract substantially
    assert err < 0.8, f"{opt_name} failed to approach optimum: {err}"


def test_ef_quantized_tracks_adam():
    """Error feedback matters: with quantization the EF state absorbs the
    compression error so the mean iterate tracks Adam's trajectory."""
    key = jax.random.PRNGKey(1)
    params = {"w": jax.random.normal(key, (8, 8)) * 3}
    target = {"w": jnp.ones((8, 8))}
    base = dict(lr=_TEST_LR,
                var_policy=S.AdaptiveFreezePolicy(kappa=4),
                sync_policy=S.EveryStepSyncPolicy())
    cfg_q = OptimizerConfig(name="zero_one_adam", quantize=True, **base)
    opt = make_optimizer(cfg_q, params, n_workers=N)
    xs, _, _ = run_steps(opt, params, _quadratic_grads(target), 300,
                         jax.random.PRNGKey(7))
    err = float(jnp.abs(xs["w"][0] - 1.0).mean())
    assert err < 0.8


def test_ep_leaves_local_adam():
    """dp_mask=False leaves must not communicate (pure local Adam)."""
    params = {"dense": jnp.ones((8, 8)), "expert": jnp.ones((4, 8))}
    cfg = OptimizerConfig(name="zero_one_adam", lr=S.ConstantLr(1e-2),
                          var_policy=S.EveryStepVariancePolicy(),
                          sync_policy=S.EveryStepSyncPolicy())
    opt = make_optimizer(cfg, params,
                         dp_mask={"dense": True, "expert": False},
                         n_workers=N)

    def g(xs, k):
        ks = jax.random.split(k, N)
        return jax.vmap(lambda kk, x: jax.tree.map(
            lambda l: jax.random.normal(jax.random.fold_in(kk, 3), l.shape),
            x))(ks, xs)

    xs, state, _ = run_steps(opt, params, g, 5, jax.random.PRNGKey(0))
    dense = np.asarray(xs["dense"])
    expert = np.asarray(xs["expert"])
    assert (dense == dense[:1]).all()          # synced every step
    assert not (expert == expert[:1]).all()    # local, never exchanged
