"""Quickstart: train a tiny LM with 0/1 Adam on 4 simulated workers.

The full paper machinery runs here — adaptive variance freezing (T_v),
learning-rate-proportional local steps (T_u), error-feedback 1-bit
compressed sync — just at CPU scale. Built with the composable API: a base
step (``adam_base``) wrapped by the ``compressed_dp`` combinator; swap the
base for ``lamb_base()`` / ``momentum_sgd_base()`` to get 0/1-LAMB or
0/1-SGD with the identical sync machinery.

    PYTHONPATH=src python examples/quickstart.py
"""
import os

import jax
import numpy as np

from repro.configs import get
from repro.core import adam_base, comm_accounting, compressed_dp, \
    schedules as S
from repro.data import DataConfig, SyntheticLM
from repro.train import Trainer

STEPS = int(os.environ.get("REPRO_EXAMPLE_STEPS", "40"))

cfg = get("gpt2").smoke
opt = compressed_dp(
    adam_base(beta1=0.9, beta2=0.999),
    lr=S.LinearWarmupExpDecay(peak_lr=2e-3, warmup_steps=10,
                              decay=0.97, decay_period=20),
    var_policy=S.AdaptiveFreezePolicy(kappa=4),
    sync_policy=S.LrProportionalSyncPolicy(warmup_steps=10, double_every=20,
                                           max_interval=4),
)
trainer = Trainer(cfg, opt, n_workers=4)
acct = comm_accounting(trainer.opt)
print(f"model={cfg.name}  DP params={acct['dp_params']/1e6:.2f}M  "
      f"compressed sync: {acct['bits_per_param_sync']/2:.2f} bits/param "
      f"one-way (vs 16 for bf16 AllReduce)")

params, state = trainer.sim_init(jax.random.PRNGKey(0))
step = trainer.sim_step_fn()
data = SyntheticLM(DataConfig(vocab=64, seq_len=32, global_batch=8))
for t in range(STEPS):
    params, state, met = step(params, state, data.batch(t))
    if t % 5 == 0:
        print(f"step {t:3d}  loss {float(np.asarray(met['loss'])[0]):.4f}  "
              f"synced={bool(np.asarray(met['synced'])[0])}  "
              f"var_refresh={bool(np.asarray(met['var_round'])[0])}")
print("done — loss decreasing under 1-bit compressed local-step training")
