"""Continuous-batching serving: admit a handful of requests into the slot
scheduler, decode them to completion, and absorb a live codec-compressed
weight refresh mid-stream (the training->serving loop of serve/publish.py
+ serve/scheduler.py).

    PYTHONPATH=src python examples/serve_decode.py

``REPRO_EXAMPLE_STEPS`` caps the per-request new-token budget so CI can
smoke this in seconds (the default exercises slot reuse: more requests
than slots, staggered lengths).
"""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.models import transformer as T
from repro.models.layers import init_params
from repro.serve import (Publisher, PublishConfig, Request, Scheduler,
                         Server, Subscriber)

GEN = int(os.environ.get("REPRO_EXAMPLE_STEPS", "12"))

cfg = get("chatglm3-6b").smoke
SLOTS, REQUESTS, PROMPT, MAXSEQ = 3, 5, 10, 64

params = init_params(T.model_template(cfg), jax.random.PRNGKey(0))
srv = Server(cfg, batch=SLOTS, max_seq=MAXSEQ, cache_dtype=jnp.float32)

# trainer-side publisher + replica-side subscriber: the scheduler swaps
# weights at a tick boundary whenever a fresh payload is pending
pc = PublishConfig(codec="qint8", bucket_mb=4.0)
pub, sub = Publisher(params, pc), Subscriber(params, pc)
sub.push(pub.publish(params, step=0))          # initial full snapshot
sch = Scheduler(srv, params, subscriber=sub)

key = jax.random.PRNGKey(1)
reqs = [Request(rid=i,
                prompt=np.asarray(jax.random.randint(
                    jax.random.fold_in(key, i), (PROMPT + i,), 0,
                    cfg.vocab)).tolist(),
                max_new_tokens=GEN)
        for i in range(REQUESTS)]
for r in reqs:
    sch.submit(r)

t0 = time.time()
ticks = 0
while not sch.idle:
    if ticks == 2:   # a fine-tuning step lands mid-serve: delta publish
        tuned = jax.tree.map(lambda x: x * (1.0 + 1e-3), params)
        sub.push(pub.publish(tuned, step=1))
    sch.tick()
    ticks += 1
dt = time.time() - t0

for r in reqs:
    print(f"req {r.rid} (prompt {len(r.prompt)}): {r.output}")
s = sch.stats
print(f"{s['generated']} tokens over {SLOTS} slots in {dt:.2f}s "
      f"({s['generated'] / dt:.1f} tok/s, CPU, interpret-grade); "
      f"{s['prefills']} prefills, {s['decode_ticks']} decode ticks, "
      f"{s['weight_swaps']} live weight swap(s)")
assert all(r.done and len(r.output) == GEN for r in reqs)
assert s["weight_swaps"] >= 1
