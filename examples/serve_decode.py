"""Batched serving: prefill a prompt batch, then decode tokens step by step
with the KV cache (the decode_32k path at CPU scale).

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.models import transformer as T
from repro.models.layers import init_params
from repro.serve import Server

cfg = get("chatglm3-6b").smoke
B, PROMPT, GEN, MAXSEQ = 4, 12, 20, 64

params = init_params(T.model_template(cfg), jax.random.PRNGKey(0))
srv = Server(cfg, batch=B, max_seq=MAXSEQ, cache_dtype=jnp.float32)
prefill = srv.prefill_fn()
decode = srv.decode_fn()

prompt = jax.random.randint(jax.random.PRNGKey(1), (B, PROMPT), 0, cfg.vocab)
cache = T.init_cache(cfg, B, MAXSEQ, dtype=jnp.float32)
logits, cache = prefill(params, {"tokens": prompt}, cache)
tok = jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1)[:, None]

out = [tok]
t0 = time.time()
for i in range(GEN):
    logits, cache = decode(params, cache, tok, jnp.int32(PROMPT + i))
    tok = jnp.argmax(logits[:, 0, :cfg.vocab], axis=-1)[:, None]
    out.append(tok)
dt = time.time() - t0
toks = np.concatenate([np.asarray(t) for t in out], axis=1)
print(f"prompt shape {prompt.shape} -> generated {GEN} tokens/seq")
print(f"decode throughput: {B*GEN/dt:.1f} tok/s (CPU, interpret-grade)")
print("generated ids (batch 0):", toks[0].tolist())
