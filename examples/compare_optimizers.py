"""Paper Fig. 2 + Fig. 4 in miniature: Adam vs 1-bit Adam vs 0/1 Adam on
identical data — sample-wise convergence parity + communication volume.

    PYTHONPATH=src python examples/compare_optimizers.py
"""
import jax
import numpy as np

from repro.configs import get
from repro.core import OptimizerConfig, comm_accounting, schedules as S
from repro.data import DataConfig, SyntheticLM
from repro.train import Trainer

cfg = get("gpt2").smoke
STEPS = 60

def run(name):
    opt_cfg = OptimizerConfig(
        name=name,
        lr=S.LinearWarmupExpDecay(peak_lr=2e-3, warmup_steps=10,
                                  decay=0.97, decay_period=20),
        var_policy=S.AdaptiveFreezePolicy(kappa=4),
        sync_policy=S.LrProportionalSyncPolicy(
            warmup_steps=15, double_every=20, max_interval=4),
        onebit_warmup=15)
    tr = Trainer(cfg, opt_cfg, n_workers=4)
    params, state = tr.sim_init(jax.random.PRNGKey(0))
    fn = tr.sim_step_fn()
    data = SyntheticLM(DataConfig(vocab=64, seq_len=32, global_batch=8))
    acct = comm_accounting(tr.opt)
    losses, bytes_sent = [], 0.0
    for t in range(STEPS):
        params, state, met = fn(params, state, data.batch(t))
        losses.append(float(np.asarray(met["loss"])[0]))
        if name == "adam":
            bytes_sent += acct["fullprec_bytes_per_round"] / 2
        elif name == "one_bit_adam":
            w = bool(np.asarray(met["var_round"])[0])
            bytes_sent += (acct["fullprec_bytes_per_round"] if w
                           else acct["compressed_bytes_per_sync"]) / 2
        else:
            if bool(np.asarray(met["synced"])[0]):
                bytes_sent += acct["compressed_bytes_per_sync"] / 2
            if bool(np.asarray(met["var_round"])[0]):
                bytes_sent += acct["fullprec_bytes_per_round"] / 2
    return losses, bytes_sent, acct["dp_params"]

print(f"{'optimizer':16s} {'loss@0':>8s} {'loss@end':>9s} "
      f"{'MB sent/worker':>15s} {'bits/param/step':>16s}")
for name in ("adam", "one_bit_adam", "zero_one_adam"):
    losses, b, d = run(name)
    print(f"{name:16s} {losses[0]:8.4f} {np.mean(losses[-5:]):9.4f} "
          f"{b/2**20:15.2f} {8*b/d/STEPS:16.3f}")
print("\nsame convergence, a fraction of the bits — the paper's claim.")
