"""Paper Fig. 2 + Fig. 4 in miniature: the uncompressed Adam baseline vs
the compressed pipelines (1-bit Adam, 0/1 Adam, 0/1 LAMB) on identical
data — sample-wise convergence parity + communication volume.

Each series is one composition of the same combinator: a *base step*
(``adam_base`` / ``lamb_base``) wrapped by ``compressed_dp`` with a sync
style — ``"mean"`` (full-precision every step), ``"gradient"`` (1-bit
two-stage), or ``"accumulate"`` (0/1 local steps). That is the entire
public optimizer API.

    PYTHONPATH=src python examples/compare_optimizers.py
"""
import os

import jax
import numpy as np

from repro.configs import get
from repro.core import adam_base, comm_accounting, compressed_dp, \
    lamb_base, schedules as S
from repro.data import DataConfig, SyntheticLM
from repro.train import Trainer

cfg = get("gpt2").smoke
STEPS = int(os.environ.get("REPRO_EXAMPLE_STEPS", "60"))

LR = S.LinearWarmupExpDecay(peak_lr=2e-3, warmup_steps=10,
                            decay=0.97, decay_period=20)
VAR = S.AdaptiveFreezePolicy(kappa=4)
SYNC = S.LrProportionalSyncPolicy(warmup_steps=15, double_every=20,
                                  max_interval=4)

SERIES = {
    "adam": compressed_dp(adam_base(), style="mean", lr=LR),
    "one_bit_adam": compressed_dp(adam_base(), style="gradient", lr=LR,
                                  var_policy=S.FixedWarmupPolicy(15)),
    "zero_one_adam": compressed_dp(adam_base(), lr=LR, var_policy=VAR,
                                   sync_policy=SYNC),
    "zero_one_lamb": compressed_dp(lamb_base(), lr=LR, var_policy=VAR,
                                   sync_policy=SYNC),
}


def run(opt):
    tr = Trainer(cfg, opt, n_workers=4)
    params, state = tr.sim_init(jax.random.PRNGKey(0))
    fn = tr.sim_step_fn()
    data = SyntheticLM(DataConfig(vocab=64, seq_len=32, global_batch=8))
    acct = comm_accounting(tr.opt)
    losses, bytes_sent = [], 0.0
    for t in range(STEPS):
        params, state, met = fn(params, state, data.batch(t))
        losses.append(float(np.asarray(met["loss"])[0]))
        # traffic model keyed on the transform's sync style, so any series
        # added to SERIES is accounted correctly
        if opt.style == "mean":
            bytes_sent += acct["fullprec_bytes_per_round"] / 2
        elif opt.style == "gradient":
            w = bool(np.asarray(met["var_round"])[0])
            bytes_sent += (acct["fullprec_bytes_per_round"] if w
                           else acct["compressed_bytes_per_sync"]) / 2
        else:  # accumulate: compressed syncs + T_v full-precision rounds
            if bool(np.asarray(met["synced"])[0]):
                bytes_sent += acct["compressed_bytes_per_sync"] / 2
            if bool(np.asarray(met["var_round"])[0]):
                bytes_sent += acct["fullprec_bytes_per_round"] / 2
    return losses, bytes_sent, acct["dp_params"]


print(f"{'optimizer':16s} {'loss@0':>8s} {'loss@end':>9s} "
      f"{'MB sent/worker':>15s} {'bits/param/step':>16s}")
for name, opt in SERIES.items():
    losses, b, d = run(opt)
    print(f"{name:16s} {losses[0]:8.4f} {np.mean(losses[-5:]):9.4f} "
          f"{b/2**20:15.2f} {8*b/d/STEPS:16.3f}")
print("\nsame convergence, a fraction of the bits — the paper's claim, "
      "for every base the combinator wraps.")
