"""Paper Fig. 4 (+ Fig. 5 ablation): bits/parameter and communication
rounds over a full training run, per optimizer, from the actual schedule
machinery + per-leaf comm layouts (no hand-waved formulas).

Reproduces the headline claims: 0/1 Adam cuts data volume by ~87% and
communication rounds by ~54% vs 1-bit Adam on the BERT-Large recipe.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.core import OptimizerConfig, comm_accounting, make_optimizer
from repro.core import schedules as S
from repro.models.layers import abstract_params, param_specs
from repro.models import transformer as T


def schedule_trace(opt_cfg, total_steps):
    """(sync_steps, var_steps) boolean masks over a training run — pure
    numpy re-simulation of the jnp policy state machines."""
    sync, var = [], []
    sp = opt_cfg.sync_policy
    vp = opt_cfg.var_policy
    s_state = tuple(int(np.asarray(x)) for x in sp.init())
    v_state = vp.init()
    v_next, v_j, v_stop = 0, 0, False
    nxt = 0
    for t in range(total_steps):
        # sync policy interval (EveryStep == 1)
        iv = (int(np.asarray(sp.interval(jnp.int32(t))))
              if hasattr(sp, "interval") else 1)
        fire_s = t >= nxt
        if fire_s:
            nxt = t + iv
        sync.append(fire_s)
        # var policy (AdaptiveFreeze with stop rule)
        v_stop = v_stop or iv > 1
        fire_v = (t == v_next) and not v_stop
        if fire_v:
            gap = 2 ** min(v_j // vp.kappa, 30)
            v_next = t + gap
            v_j += 1
        var.append(fire_v)
    return np.asarray(sync), np.asarray(var)


def run(arch="bert-large", total_steps=100_000, warmup_frac=0.125,
        double_frac=0.32):
    cfg = get(arch).config
    tmpl = T.model_template(cfg)
    shapes = abstract_params(tmpl)
    specs = param_specs(tmpl)
    rows = []
    d = None
    for name in ("adam", "one_bit_adam", "zero_one_adam",
                 "zero_one_adam_no_skip"):
        oname = name.replace("_no_skip", "")
        sync_pol = (S.EveryStepSyncPolicy() if "no_skip" in name or
                    oname != "zero_one_adam"
                    else S.LrProportionalSyncPolicy(
                        warmup_steps=int(warmup_frac * total_steps),
                        double_every=int(double_frac * total_steps),
                        max_interval=16))
        ocfg = OptimizerConfig(
            name=oname,
            var_policy=S.AdaptiveFreezePolicy(kappa=16),
            sync_policy=sync_pol,
            onebit_warmup=int(0.16 * total_steps))
        opt = make_optimizer(ocfg, shapes, specs=specs, n_workers=16)
        acct = comm_accounting(opt)
        d = acct["dp_params"]
        comp_one_way = acct["compressed_bytes_per_sync"] / 2  # send side
        full_one_way = acct["fullprec_bytes_per_round"] / 2

        if oname == "adam":
            bits = 8 * full_one_way * total_steps / (d * total_steps)
            rounds = total_steps
        elif oname == "one_bit_adam":
            warm = int(0.16 * total_steps)
            vol = full_one_way * warm + comp_one_way * (total_steps - warm)
            bits = 8 * vol / (d * total_steps)
            rounds = total_steps
        else:
            if "no_skip" in name:
                sync = np.ones(total_steps, bool)
                _, var = schedule_trace(ocfg, total_steps)
            else:
                sync, var = schedule_trace(ocfg, total_steps)
            vol = comp_one_way * sync.sum() + full_one_way * var.sum()
            bits = 8 * vol / (d * total_steps)
            rounds = int(sync.sum() + var.sum())
        rows.append((name, bits, rounds))
    return rows, d


def main():
    t0 = time.time()
    results = []
    best_vol = best_rnd = 0.0
    recipes = [
        # (label, arch, steps, lr-warmup frac, lr half-life frac)
        ("bert-large-100k", "bert-large", 100_000, 0.125, 0.32),
        ("gpt2-300k", "gpt2", 300_000, 0.01, 0.12),
    ]
    for label, arch, steps, wf, df in recipes:
        rows, d = run(arch, total_steps=steps, warmup_frac=wf,
                      double_frac=df)
        base = dict((n, (b, r)) for n, b, r in rows)
        b1 = base["one_bit_adam"]
        print(f"# Fig.4 analogue — {label}, {d/1e6:.0f}M params, "
              f"16 workers")
        print("optimizer,bits_per_param_per_step,comm_rounds,"
              "volume_vs_1bitAdam,rounds_vs_1bitAdam")
        for n, b, r in rows:
            print(f"{n},{b:.4f},{r},{b/b1[0]:.3f},{r/b1[1]:.3f}")
        zo = base["zero_one_adam"]
        vol_red = 1 - zo[0] / b1[0]
        rnd_red = 1 - zo[1] / b1[1]
        best_vol, best_rnd = max(best_vol, vol_red), max(best_rnd, rnd_red)
        print(f"# {label}: 0/1 vs 1-bit Adam: volume -{vol_red:.1%}, "
              f"rounds -{rnd_red:.1%}")
        results.append((f"data_volume_{label}", 0.0,
                        f"vol_red={vol_red:.3f};rounds_red={rnd_red:.3f}"))
    print(f"# ACROSS RECIPES: up to {best_vol:.0%} volume reduction "
          f"(paper: up to 87%), up to {best_rnd:.0%} fewer rounds "
          f"(paper: up to 54%)")
    print(f"# elapsed {time.time()-t0:.1f}s")
    return results


if __name__ == "__main__":
    main()
