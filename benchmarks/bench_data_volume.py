"""Paper Fig. 4 (+ Fig. 5 ablation): bits/parameter and communication
rounds over a full training run, per optimizer, from the actual schedule
machinery + per-leaf comm layouts (no hand-waved formulas).

Reproduces the headline claims: 0/1 Adam cuts data volume by ~87% and
communication rounds by ~54% vs 1-bit Adam on the BERT-Large recipe; the
hierarchical section shows the two-level AllReduce cutting the *inter-pod*
sync traffic to ~1/32 of the f32 inter-pod baseline while the intra-pod
level stays uncompressed. ``--json`` appends one record per result with
the per-level byte counts.
"""
from __future__ import annotations

import argparse
import json
import time

import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.core import (Hierarchy, OptimizerConfig, comm_accounting,
                        build_optimizer)
from repro.core import schedules as S
from repro.models.layers import abstract_params, param_specs
from repro.models import transformer as T


def schedule_trace(opt_cfg, total_steps):
    """(sync_steps, var_steps) boolean masks over a training run — pure
    numpy re-simulation of the jnp policy state machines."""
    sync, var = [], []
    sp = opt_cfg.sync_policy
    vp = opt_cfg.var_policy
    v_next, v_j, v_stop = 0, 0, False
    nxt = 0
    for t in range(total_steps):
        # sync policy interval (EveryStep == 1)
        iv = (int(np.asarray(sp.interval(jnp.int32(t))))
              if hasattr(sp, "interval") else 1)
        fire_s = t >= nxt
        if fire_s:
            nxt = t + iv
        sync.append(fire_s)
        # var policy (AdaptiveFreeze with stop rule)
        v_stop = v_stop or iv > 1
        fire_v = (t == v_next) and not v_stop
        if fire_v:
            gap = 2 ** min(v_j // vp.kappa, 30)
            v_next = t + gap
            v_j += 1
        var.append(fire_v)
    return np.asarray(sync), np.asarray(var)


def run(arch="bert-large", total_steps=100_000, warmup_frac=0.125,
        double_frac=0.32):
    cfg = get(arch).config
    tmpl = T.model_template(cfg)
    shapes = abstract_params(tmpl)
    specs = param_specs(tmpl)
    rows = []
    d = None
    for name in ("adam", "one_bit_adam", "zero_one_adam",
                 "zero_one_adam_no_skip"):
        oname = name.replace("_no_skip", "")
        sync_pol = (S.EveryStepSyncPolicy() if "no_skip" in name or
                    oname != "zero_one_adam"
                    else S.LrProportionalSyncPolicy(
                        warmup_steps=int(warmup_frac * total_steps),
                        double_every=int(double_frac * total_steps),
                        max_interval=16))
        ocfg = OptimizerConfig(
            name=oname,
            var_policy=S.AdaptiveFreezePolicy(kappa=16),
            sync_policy=sync_pol,
            onebit_warmup=int(0.16 * total_steps))
        opt = build_optimizer(ocfg, shapes, specs=specs, n_workers=16)
        acct = comm_accounting(opt)
        d = acct["dp_params"]
        comp_one_way = acct["compressed_bytes_per_sync"] / 2  # send side
        full_one_way = acct["fullprec_bytes_per_round"] / 2

        if oname == "adam":
            bits = 8 * full_one_way * total_steps / (d * total_steps)
            rounds = total_steps
        elif oname == "one_bit_adam":
            warm = int(0.16 * total_steps)
            vol = full_one_way * warm + comp_one_way * (total_steps - warm)
            bits = 8 * vol / (d * total_steps)
            rounds = total_steps
        else:
            if "no_skip" in name:
                sync = np.ones(total_steps, bool)
                _, var = schedule_trace(ocfg, total_steps)
            else:
                sync, var = schedule_trace(ocfg, total_steps)
            vol = comp_one_way * sync.sum() + full_one_way * var.sum()
            bits = 8 * vol / (d * total_steps)
            rounds = int(sync.sum() + var.sum())
        rows.append((name, bits, rounds))
    return rows, d


def hier_levels(arch="bert-large", workers=32, inner=16):
    """Per-level per-worker bytes of one hierarchical 0/1 Adam sync vs the
    full-precision (f32 wire) baselines, from the real per-leaf layouts.

    Returns a JSON-ready record. The headline ratio is
    ``outer_sync / outer_fullprec_f32`` — the inter-pod reduction the
    two-level schedule buys (≈ 1/32: sign bits vs f32 on the slow links) —
    while ``inner_sync == inner_fullprec`` shows the intra-pod level stays
    uncompressed.
    """
    cfg = get(arch).config
    tmpl = T.model_template(cfg)
    shapes = abstract_params(tmpl)
    specs = param_specs(tmpl)

    def acct_for(h, comm_dtype):
        ocfg = OptimizerConfig(name="zero_one_adam", hierarchy=h,
                               comm_dtype=comm_dtype)
        opt = build_optimizer(ocfg, shapes, specs=specs, n_workers=workers)
        return comm_accounting(opt)

    h = Hierarchy(inner=inner)
    a = acct_for(h, jnp.float32)          # f32 wire = the paper's baseline
    flat = acct_for(None, jnp.float32)
    outer_ratio = (a["compressed_bytes_per_sync_outer"]
                   / max(a["fullprec_bytes_per_round_outer"], 1.0))
    return {
        "bench": "hier_levels", "arch": arch,
        "workers": workers, "inner": inner,
        "outer": workers // inner,
        "sync_bytes_inner": a["compressed_bytes_per_sync_inner"],
        "sync_bytes_outer": a["compressed_bytes_per_sync_outer"],
        "fullprec_bytes_inner": a["fullprec_bytes_per_round_inner"],
        "fullprec_bytes_outer": a["fullprec_bytes_per_round_outer"],
        "flat_sync_bytes": flat["compressed_bytes_per_sync"],
        "flat_fullprec_bytes": flat["fullprec_bytes_per_round"],
        "outer_sync_vs_fullprec": outer_ratio,
        "inner_uncompressed": (a["compressed_bytes_per_sync_inner"]
                               == a["fullprec_bytes_per_round_inner"]),
    }


def codec_sweep(arch="bert-large", workers=16):
    """Per-codec bytes of one zero_one_adam sync over the real per-leaf
    layouts — the volume/fidelity menu the pluggable-codec API opens.

    Returns JSON-ready records (one per codec) with per-level byte counts
    and bits/param, so the BENCH output tracks the bytes trajectory of
    every wire format, not just sign1bit.
    """
    cfg = get(arch).config
    tmpl = T.model_template(cfg)
    shapes = abstract_params(tmpl)
    specs = param_specs(tmpl)
    out = []
    for codec, arg in (("sign1bit", None), ("topk", 0.01), ("topk", 0.1),
                       ("qint8", None), ("qint4", None), ("identity", None)):
        ocfg = OptimizerConfig(name="zero_one_adam", codec=codec,
                               codec_arg=arg)
        opt = build_optimizer(ocfg, shapes, specs=specs, n_workers=workers)
        acct = comm_accounting(opt)
        out.append({
            "bench": "codec_volume", "arch": arch, "workers": workers,
            "codec": codec, "codec_arg": arg,
            "bits_per_param_sync": acct["bits_per_param_sync"],
            "sync_bytes_per_worker": acct["compressed_bytes_per_sync"],
            "fullprec_bytes_per_round": acct["fullprec_bytes_per_round"],
        })
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="append JSONL records (per-optimizer rows, the "
                         "hierarchical per-level record, and the per-codec "
                         "sweep) here")
    args = ap.parse_args(argv)
    t0 = time.time()
    results = []
    records = []
    best_vol = best_rnd = 0.0
    recipes = [
        # (label, arch, steps, lr-warmup frac, lr half-life frac)
        ("bert-large-100k", "bert-large", 100_000, 0.125, 0.32),
        ("gpt2-300k", "gpt2", 300_000, 0.01, 0.12),
    ]
    for label, arch, steps, wf, df in recipes:
        rows, d = run(arch, total_steps=steps, warmup_frac=wf,
                      double_frac=df)
        base = dict((n, (b, r)) for n, b, r in rows)
        b1 = base["one_bit_adam"]
        print(f"# Fig.4 analogue — {label}, {d/1e6:.0f}M params, "
              f"16 workers")
        print("optimizer,bits_per_param_per_step,comm_rounds,"
              "volume_vs_1bitAdam,rounds_vs_1bitAdam")
        for n, b, r in rows:
            print(f"{n},{b:.4f},{r},{b/b1[0]:.3f},{r/b1[1]:.3f}")
            records.append({"bench": "data_volume", "recipe": label,
                            "optimizer": n, "bits_per_param_per_step": b,
                            "comm_rounds": r})
        zo = base["zero_one_adam"]
        vol_red = 1 - zo[0] / b1[0]
        rnd_red = 1 - zo[1] / b1[1]
        best_vol, best_rnd = max(best_vol, vol_red), max(best_rnd, rnd_red)
        print(f"# {label}: 0/1 vs 1-bit Adam: volume -{vol_red:.1%}, "
              f"rounds -{rnd_red:.1%}")
        results.append((f"data_volume_{label}", 0.0,
                        f"vol_red={vol_red:.3f};rounds_red={rnd_red:.3f}"))
    print(f"# ACROSS RECIPES: up to {best_vol:.0%} volume reduction "
          f"(paper: up to 87%), up to {best_rnd:.0%} fewer rounds "
          f"(paper: up to 54%)")

    # hierarchical (intra-pod / inter-pod) per-level accounting
    hl = hier_levels("bert-large", workers=32, inner=16)
    records.append(hl)
    print(f"# Hierarchical 1-bit AllReduce — {hl['arch']}, "
          f"{hl['outer']} pods x {hl['inner']} workers:")
    print(f"#   inter-pod sync {hl['sync_bytes_outer']/2**20:.2f}MiB/worker "
          f"= {hl['outer_sync_vs_fullprec']:.4f}x of the f32 inter-pod "
          f"baseline ({1/max(hl['outer_sync_vs_fullprec'],1e-9):.1f}x "
          f"reduction; paper: 32x)")
    print(f"#   intra-pod sync {hl['sync_bytes_inner']/2**20:.2f}MiB/worker "
          f"uncompressed={hl['inner_uncompressed']}")
    results.append(("hier_outer_sync_vs_fullprec",
                    hl["outer_sync_vs_fullprec"], ""))

    # per-codec sync-volume sweep (the pluggable wire formats)
    cs = codec_sweep("bert-large", workers=16)
    records.extend(cs)
    print("# Codec sweep — bert-large, 16 workers, one zero_one_adam sync:")
    print("codec,codec_arg,bits_per_param_sync,sync_MiB_per_worker")
    for r in cs:
        print(f"{r['codec']},{r['codec_arg']},"
              f"{r['bits_per_param_sync']:.3f},"
              f"{r['sync_bytes_per_worker']/2**20:.2f}")
    s1 = next(r for r in cs if r["codec"] == "sign1bit")
    results.append(("codec_sweep_sign1bit_bits_per_param",
                    s1["bits_per_param_sync"], ""))
    if args.json:
        with open(args.json, "a") as f:
            for rec in records:
                f.write(json.dumps(rec) + "\n")
    print(f"# elapsed {time.time()-t0:.1f}s")
    return results


if __name__ == "__main__":
    main()
