"""CI guard for the committed benchmark snapshots.

Re-derives the *cheap, deterministic* half of the committed
``BENCH_fixed_cost.json`` / ``BENCH_throughput.json`` records — the
structural comm accounting (DP leaves, exchange units, collectives per
sync, bits per param) and the modeled latency/step-time/exposed-comm
breakdown — and diffs them against the snapshots. Structural integer
fields must match exactly; modeled floats within ``--rtol``. Measured
wall-clock fields (``syncs_per_s``, ``step_ms``, and the
measured-derived ``exposed_comm_ms_overlapped`` of the fixed-cost sweep)
and the slow Fig.3 grid (``throughput_model`` records, which need full
convergence sims) are not re-run and not compared.

    PYTHONPATH=src python -m benchmarks.check_bench

Exit 1 on any drift, naming the record and field. If a change is
intentional, regenerate the snapshots:

    python -m benchmarks.bench_fixed_cost --json BENCH_fixed_cost.json
    python -m benchmarks.bench_throughput --json BENCH_throughput.json
"""
import argparse
import json
import sys
from pathlib import Path

STRUCTURAL = ("dp_leaves", "exchange_units", "collectives_per_sync")
MODELED = {"fixed_cost_buckets": ("bits_per_param_sync", "sync_comm_ms"),
           "throughput_buckets": ("sync_latency_floor_ms",
                                  "sync_comm_ms", "step_ms_sequential",
                                  "step_ms_overlapped",
                                  "exposed_comm_ms_overlapped")}


def _load(path):
    recs = []
    for line in Path(path).read_text().splitlines():
        if line.strip():
            recs.append(json.loads(line))
    return recs


def _fresh_fixed_cost(snapshot):
    """Structural accounting for each snapshot point, without the timed
    training loop of bench_fixed_cost.bucket_sweep."""
    from benchmarks import hw
    from repro.configs import get
    from repro.core import OptimizerConfig, build_optimizer, comm_accounting
    from repro.core import schedules as S
    from repro.models.layers import (abstract_params, param_specs)
    from repro.models import transformer as T

    cfg = get("gpt2").smoke
    tmpl = T.model_template(cfg)
    shapes = abstract_params(tmpl)
    specs = param_specs(tmpl)
    out = {}
    for rec in snapshot:
        mb = rec["bucket_mb"]
        ocfg = OptimizerConfig(
            name="zero_one_adam", lr=S.ConstantLr(1e-3),
            var_policy=S.EveryStepVariancePolicy(),
            sync_policy=S.EveryStepSyncPolicy(), bucket_mb=mb)
        opt = build_optimizer(ocfg, shapes, specs=specs,
                              n_workers=rec["workers"])
        acct = comm_accounting(opt)
        out[json.dumps(mb)] = {
            "dp_leaves": int(acct["dp_leaves"]),
            "exchange_units": int(acct["exchange_units"]),
            "collectives_per_sync": int(acct["collectives_per_sync"]),
            "bits_per_param_sync": acct["bits_per_param_sync"],
            "sync_comm_ms": (acct["compressed_bytes_per_sync"]
                             / hw.ETHERNET_BW
                             + acct["collectives_per_sync"]
                             * hw.ETHERNET_LATENCY) * 1e3,
        }
    return out


def _fresh_throughput(snapshot):
    from benchmarks.bench_throughput import bucket_latency_sweep
    mbs = [rec["bucket_mb"] for rec in snapshot]
    arch = snapshot[0]["arch"]
    workers = snapshot[0]["workers"]
    fresh = bucket_latency_sweep(arch=arch, workers=workers,
                                 bucket_mbs=tuple(mbs))
    return {json.dumps(r["bucket_mb"]): r for r in fresh}


def _diff(kind, snapshot, fresh, rtol, problems):
    for rec in snapshot:
        key = json.dumps(rec["bucket_mb"])
        label = f"{kind}[bucket_mb={rec['bucket_mb']}]"
        f = fresh.get(key)
        if f is None:
            problems.append(f"{label}: no fresh record")
            continue
        for field in STRUCTURAL:
            if int(rec[field]) != int(f[field]):
                problems.append(f"{label}.{field}: snapshot {rec[field]} "
                                f"!= fresh {f[field]}")
        for field in MODELED[kind]:
            a, b = float(rec[field]), float(f[field])
            if abs(a - b) > rtol * max(abs(a), abs(b), 1e-12):
                problems.append(f"{label}.{field}: snapshot {a} != fresh "
                                f"{b} (rtol {rtol})")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    root = Path(__file__).resolve().parents[1]
    ap.add_argument("--fixed", default=str(root / "BENCH_fixed_cost.json"))
    ap.add_argument("--throughput",
                    default=str(root / "BENCH_throughput.json"))
    ap.add_argument("--rtol", type=float, default=0.05,
                    help="relative tolerance for modeled float fields")
    args = ap.parse_args(argv)

    problems = []
    fixed = [r for r in _load(args.fixed)
             if r["bench"] == "fixed_cost_buckets"]
    if not fixed:
        problems.append(f"{args.fixed}: no fixed_cost_buckets records")
    else:
        _diff("fixed_cost_buckets", fixed,
              _fresh_fixed_cost(fixed), args.rtol, problems)

    tput = [r for r in _load(args.throughput)
            if r["bench"] == "throughput_buckets"]
    if not tput:
        problems.append(f"{args.throughput}: no throughput_buckets records")
    else:
        _diff("throughput_buckets", tput,
              _fresh_throughput(tput), args.rtol, problems)

    for p in problems:
        print(f"BENCH DRIFT: {p}")
    n = len(fixed) + len(tput)
    print(f"check_bench: {n} snapshot records checked, "
          f"{len(problems)} problem(s)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
