"""CI guard for the committed benchmark snapshots.

Re-derives the *cheap, deterministic* half of the committed
``BENCH_fixed_cost.json`` / ``BENCH_throughput.json`` /
``BENCH_serve.json`` / ``BENCH_elastic.json`` records — the structural comm accounting (DP
leaves, exchange units, collectives per sync, bits per param), the
publish wire accounting (full-f32 vs delta/snapshot bytes per refresh,
bucket counts, scheduler slot accounting), and the modeled
latency/step-time/exposed-comm breakdown — and diffs them against the
snapshots. Structural integer fields must match exactly; modeled floats
within ``--rtol``. Measured wall-clock fields (``syncs_per_s``,
``step_ms``, the measured-derived ``exposed_comm_ms_overlapped`` of the
fixed-cost sweep, and the serve bench's ``tok_s`` / ``refresh_ms_*`` /
``weight_swap_tick_ms``) and the slow Fig.3 grid (``throughput_model``
records, which need full convergence sims) are not re-run and not
compared.

    PYTHONPATH=src python -m benchmarks.check_bench

Exit 1 on any drift, naming the record and field. If a change is
intentional, regenerate the snapshots:

    python -m benchmarks.bench_fixed_cost --json BENCH_fixed_cost.json
    python -m benchmarks.bench_throughput --json BENCH_throughput.json
    python -m benchmarks.bench_serve --json BENCH_serve.json
    python -m benchmarks.bench_elastic --json BENCH_elastic.json

The elastic snapshot gets two extra treatments: the ``elastic_reshard``
geometry is re-derived exactly (``reshard_report`` is a pure function of
the two layout plans), and the ``elastic_parity`` record is hard-gated —
the recorded kill/rejoin tail-loss gap must sit inside its recorded
tolerance (``bench_convergence.PARITY_TOL``), the same budget-assertion
pattern as the qint8 publish record.
"""
import argparse
import json
import sys
from pathlib import Path

STRUCTURAL = {
    "fixed_cost_buckets": ("dp_leaves", "exchange_units",
                           "collectives_per_sync"),
    "throughput_buckets": ("dp_leaves", "exchange_units",
                           "collectives_per_sync"),
    "serve_publish": ("n_buckets", "full_f32_bytes", "snapshot_bytes",
                      "delta_bytes"),
    "serve_throughput": ("generated", "prefills", "decode_ticks"),
    "elastic_reshard": ("n_from", "n_to", "inner_from", "inner_to",
                        "entities_from", "entities_to", "carried_entities",
                        "dead_entities", "joiner_workers", "ef_fold",
                        "dp_leaves", "exchange_units", "true_elems",
                        "padded_elems_from", "padded_elems_to"),
}
MODELED = {"fixed_cost_buckets": ("bits_per_param_sync", "sync_comm_ms"),
           "throughput_buckets": ("sync_latency_floor_ms",
                                  "sync_comm_ms", "step_ms_sequential",
                                  "step_ms_overlapped",
                                  "exposed_comm_ms_overlapped"),
           "serve_publish": ("reduction_x",),
           "serve_throughput": (),
           "elastic_reshard": ()}
#: field(s) identifying one record within its kind
KEY = {"fixed_cost_buckets": ("bucket_mb",),
       "throughput_buckets": ("bucket_mb", "tp"),
       "serve_publish": ("codec",),
       "serve_throughput": ("slots", "n_requests", "max_new_tokens"),
       "elastic_reshard": ("scenario",)}


def _key(kind, rec):
    return json.dumps([rec[f] for f in KEY[kind]])


def _load(path):
    recs = []
    for line in Path(path).read_text().splitlines():
        if line.strip():
            recs.append(json.loads(line))
    return recs


def _fresh_fixed_cost(snapshot):
    """Structural accounting for each snapshot point, without the timed
    training loop of bench_fixed_cost.bucket_sweep."""
    from benchmarks import hw
    from repro.configs import get
    from repro.core import OptimizerConfig, build_optimizer, comm_accounting
    from repro.core import schedules as S
    from repro.models.layers import (abstract_params, param_specs)
    from repro.models import transformer as T

    cfg = get("gpt2").smoke
    tmpl = T.model_template(cfg)
    shapes = abstract_params(tmpl)
    specs = param_specs(tmpl)
    out = {}
    for rec in snapshot:
        mb = rec["bucket_mb"]
        ocfg = OptimizerConfig(
            name="zero_one_adam", lr=S.ConstantLr(1e-3),
            var_policy=S.EveryStepVariancePolicy(),
            sync_policy=S.EveryStepSyncPolicy(), bucket_mb=mb)
        opt = build_optimizer(ocfg, shapes, specs=specs,
                              n_workers=rec["workers"])
        acct = comm_accounting(opt)
        out[_key("fixed_cost_buckets", rec)] = {
            "dp_leaves": int(acct["dp_leaves"]),
            "exchange_units": int(acct["exchange_units"]),
            "collectives_per_sync": int(acct["collectives_per_sync"]),
            "bits_per_param_sync": acct["bits_per_param_sync"],
            "sync_comm_ms": (acct["compressed_bytes_per_sync"]
                             / hw.ETHERNET_BW
                             + acct["collectives_per_sync"]
                             * hw.ETHERNET_LATENCY) * 1e3,
        }
    return out


def _fresh_throughput(snapshot):
    from benchmarks.bench_throughput import bucket_latency_sweep
    groups = {}
    for rec in snapshot:
        groups.setdefault((rec["arch"], rec["workers"], rec["tp"]),
                          []).append(rec["bucket_mb"])
    out = {}
    for (arch, workers, tp), mbs in groups.items():
        fresh = bucket_latency_sweep(arch=arch, workers=workers,
                                     bucket_mbs=tuple(mbs), tp=tp)
        out.update({_key("throughput_buckets", r): r for r in fresh})
    return out


def _fresh_serve_publish(snapshot):
    """Re-derive the publish wire accounting from the abstract parameter
    tree alone — byte counts are a pure function of (arch, codec, layout
    geometry), no parameters materialized."""
    import jax.numpy as jnp
    from repro.configs import get
    from repro.models import transformer as T
    from repro.models.layers import abstract_params
    from repro.serve import Publisher, PublishConfig

    out = {}
    for rec in snapshot:
        arch = rec["arch"].removesuffix("-smoke")
        abstract = abstract_params(T.model_template(get(arch).smoke),
                                   jnp.float32)
        pc = PublishConfig(codec=rec["codec"], bucket_mb=rec["bucket_mb"],
                           n_chunks=rec["n_chunks"])
        wire = Publisher(abstract, pc).wire
        full = wire.full_f32_bytes()
        delta = wire.wire_bytes("delta")
        out[_key("serve_publish", rec)] = {
            "n_buckets": len(wire.bp.buckets),
            "full_f32_bytes": full,
            "snapshot_bytes": wire.wire_bytes("snapshot"),
            "delta_bytes": delta,
            "reduction_x": full / delta,
        }
    return out


def _fresh_serve_throughput(snapshot):
    """Replay the scheduler's slot accounting (admit -> batched decode ->
    evict, uniform budgets, no EOS) in pure python — tick/prefill/token
    counts are deterministic in (slots, n_requests, max_new_tokens)."""
    out = {}
    for rec in snapshot:
        slots, queue = rec["slots"], rec["n_requests"]
        gen = rec["max_new_tokens"]
        rem = [0] * slots
        prefills = decode_ticks = generated = 0
        while queue or any(rem):
            for s in range(slots):
                if rem[s] == 0 and queue:
                    queue -= 1
                    prefills += 1
                    generated += 1          # first token from prefill
                    rem[s] = gen - 1
            active = [s for s in range(slots) if rem[s] > 0]
            if active:
                decode_ticks += 1
                for s in active:
                    generated += 1
                    rem[s] -= 1
        out[_key("serve_throughput", rec)] = {
            "generated": generated, "prefills": prefills,
            "decode_ticks": decode_ticks}
    return out


def _fresh_elastic(snapshot):
    """Re-derive each resize's geometry from the two layout plans alone —
    ``reshard_report`` never touches arrays, so this is exact and cheap."""
    from repro.configs import get
    from repro.core import (Hierarchy, OptimizerConfig, build_optimizer,
                            schedules as S)
    from repro.elastic import reshard_report
    from repro.models.layers import abstract_params, param_specs
    from repro.models import transformer as T

    cfg = get("gpt2").smoke
    tmpl = T.model_template(cfg)
    shapes = abstract_params(tmpl)
    specs = param_specs(tmpl)
    out = {}
    for rec in snapshot:
        ocfg = OptimizerConfig(
            name="zero_one_adam", lr=S.ConstantLr(1e-3),
            var_policy=S.EveryStepVariancePolicy(),
            sync_policy=S.EveryStepSyncPolicy(),
            hierarchy=(Hierarchy(inner=rec["inner"]) if rec["inner"]
                       else None),
            bucket_mb=rec["bucket_mb"])
        src = build_optimizer(ocfg, shapes, specs=specs,
                              n_workers=rec["n_from"])
        dst = build_optimizer(ocfg, shapes, specs=specs,
                              n_workers=rec["n_to"])
        sv = tuple(rec["survivors"]) if rec["survivors"] else None
        rep = reshard_report(src, dst, survivors=sv)
        out[_key("elastic_reshard", rec)] = {
            k: int(v) if isinstance(v, bool) else v for k, v in rep.items()}
    return out


def _diff(kind, snapshot, fresh, rtol, problems):
    for rec in snapshot:
        key = _key(kind, rec)
        label = f"{kind}[{key}]"
        f = fresh.get(key)
        if f is None:
            problems.append(f"{label}: no fresh record")
            continue
        for field in STRUCTURAL[kind]:
            if int(rec[field]) != int(f[field]):
                problems.append(f"{label}.{field}: snapshot {rec[field]} "
                                f"!= fresh {f[field]}")
        for field in MODELED[kind]:
            a, b = float(rec[field]), float(f[field])
            if abs(a - b) > rtol * max(abs(a), abs(b), 1e-12):
                problems.append(f"{label}.{field}: snapshot {a} != fresh "
                                f"{b} (rtol {rtol})")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    root = Path(__file__).resolve().parents[1]
    ap.add_argument("--fixed", default=str(root / "BENCH_fixed_cost.json"))
    ap.add_argument("--throughput",
                    default=str(root / "BENCH_throughput.json"))
    ap.add_argument("--serve", default=str(root / "BENCH_serve.json"))
    ap.add_argument("--elastic", default=str(root / "BENCH_elastic.json"))
    ap.add_argument("--rtol", type=float, default=0.05,
                    help="relative tolerance for modeled float fields")
    args = ap.parse_args(argv)

    problems = []
    fixed = [r for r in _load(args.fixed)
             if r["bench"] == "fixed_cost_buckets"]
    if not fixed:
        problems.append(f"{args.fixed}: no fixed_cost_buckets records")
    else:
        _diff("fixed_cost_buckets", fixed,
              _fresh_fixed_cost(fixed), args.rtol, problems)

    tput = [r for r in _load(args.throughput)
            if r["bench"] == "throughput_buckets"]
    if not tput:
        problems.append(f"{args.throughput}: no throughput_buckets records")
    else:
        _diff("throughput_buckets", tput,
              _fresh_throughput(tput), args.rtol, problems)

    serve = _load(args.serve)
    pub = [r for r in serve if r["bench"] == "serve_publish"]
    if not pub:
        problems.append(f"{args.serve}: no serve_publish records")
    else:
        _diff("serve_publish", pub, _fresh_serve_publish(pub),
              args.rtol, problems)
        q8 = next((r for r in pub if r["codec"] == "qint8"), None)
        if q8 is None:
            problems.append(f"{args.serve}: no qint8 serve_publish record")
        elif q8["delta_bytes"] * 3 > q8["full_f32_bytes"]:
            problems.append(
                f"serve_publish[qint8]: delta refresh {q8['delta_bytes']} "
                f"bytes exceeds 1/3 of the full-f32 push "
                f"({q8['full_f32_bytes']})")
    sthr = [r for r in serve if r["bench"] == "serve_throughput"]
    if not sthr:
        problems.append(f"{args.serve}: no serve_throughput records")
    else:
        _diff("serve_throughput", sthr, _fresh_serve_throughput(sthr),
              args.rtol, problems)

    elastic = _load(args.elastic)
    resh = [r for r in elastic if r["bench"] == "elastic_reshard"]
    if not resh:
        problems.append(f"{args.elastic}: no elastic_reshard records")
    else:
        _diff("elastic_reshard", resh, _fresh_elastic(resh), args.rtol,
              problems)
    par = [r for r in elastic if r["bench"] == "elastic_parity"]
    if not par:
        problems.append(f"{args.elastic}: no elastic_parity record")
    for rec in par:
        if rec["parity_gap"] > rec["parity_tol"]:
            problems.append(
                f"elastic_parity[{rec['scenario']}]: kill/rejoin tail-loss "
                f"gap {rec['parity_gap']:.3f} nats exceeds the recorded "
                f"tolerance {rec['parity_tol']}")

    for p in problems:
        print(f"BENCH DRIFT: {p}")
    n = len(fixed) + len(tput) + len(pub) + len(sthr) + len(resh) + len(par)
    print(f"check_bench: {n} snapshot records checked, "
          f"{len(problems)} problem(s)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
