"""Paper appendix Table 3 analogue: per-round cost decomposition.

Measures (on this host) the CPU-side cost of the compression pipeline per
round and scales the paper's measured fixed costs; reports the
compute/communication/fixed breakdown per optimizer round.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import hw
from repro.core import compressor as C


def main():
    rows = []
    # compression cost for a BERT-Large-sized flat leaf per worker
    d = 340_000_000 // 16  # per-worker shard of the full model, one chunk
    lo = C.make_layout((d,), None, 16)
    z = jnp.zeros(lo.view_shape, jnp.float32)
    mask = C.pad_mask(lo)

    @jax.jit
    def compress(z):
        return C.ef_compress(z, lo, "tensor", mask)

    out = compress(z)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    out = compress(z)
    jax.block_until_ready(out)
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("ef_compress_bertlarge_shard", us, f"elems={d}"))
    print(f"ef_compress_bertlarge_shard,{us:.0f},elems={d}")

    print("# Table 3 analogue — modeled per-round breakdown, BERT-Large")
    print("gpus,compute_ms,comm_ms_ethernet_1bit,fixed_ms(paper)")
    for n in (16, 32, 64, 128):
        comp = hw.PAPER_COMPUTE_MS["bert-large"][n]
        fixed = hw.PAPER_FIXED_MS["bert-large"][n]
        vol = 340e6 / 8  # 1 bit/param one-way
        comm = vol / hw.ETHERNET_BW * 1e3
        print(f"{n},{comp},{comm:.0f},{fixed}")
        rows.append((f"fixed_cost_{n}gpu", 0.0,
                     f"compute={comp}ms;fixed={fixed}ms"))
    return rows


if __name__ == "__main__":
    main()
