"""Paper appendix Table 3 analogue: per-round cost decomposition, plus the
fixed-cost (dispatch-count) regime the bucketed exchange targets.

Two sections:

1. the original Table-3 analogue — CPU-side compression cost for a
   BERT-Large-sized shard and the paper's measured compute/fixed costs;
2. a ``--bucket-mb`` sweep over a real gpt2-smoke sim run: per setting it
   records the number of exchange units (DP leaves vs buckets), the
   collective phases per sync — the dispatch count that dominates the
   many-small-leaves regime — and the *measured* syncs/sec of a
   sync-every-step trainer loop on this host. ``--json`` appends one
   record per sweep point, so the dispatch-count reduction is a recorded
   number rather than a claim.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from benchmarks import hw
from repro.core import compressor as C


def table3_section(rows):
    # compression cost for a BERT-Large-sized flat leaf per worker
    d = 340_000_000 // 16  # per-worker shard of the full model, one chunk
    lo = C.make_layout((d,), None, 16)
    z = jnp.zeros(lo.view_shape, jnp.float32)
    mask = C.pad_mask(lo)

    @jax.jit
    def compress(z):
        return C.ef_compress(z, lo, "tensor", mask)

    out = compress(z)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    out = compress(z)
    jax.block_until_ready(out)
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("ef_compress_bertlarge_shard", us, f"elems={d}"))
    print(f"ef_compress_bertlarge_shard,{us:.0f},elems={d}")

    print("# Table 3 analogue — modeled per-round breakdown, BERT-Large")
    print("gpus,compute_ms,comm_ms_ethernet_1bit,fixed_ms(paper)")
    for n in (16, 32, 64, 128):
        comp = hw.PAPER_COMPUTE_MS["bert-large"][n]
        fixed = hw.PAPER_FIXED_MS["bert-large"][n]
        vol = 340e6 / 8  # 1 bit/param one-way
        comm = vol / hw.ETHERNET_BW * 1e3
        print(f"{n},{comp},{comm:.0f},{fixed}")
        rows.append((f"fixed_cost_{n}gpu", 0.0,
                     f"compute={comp}ms;fixed={fixed}ms"))


def bucket_sweep(bucket_mbs, steps=6, workers=4, seed=0, micro_batches=2):
    """Measured sync-every-step gpt2-smoke sim step time per bucket_mb
    (None = the per-leaf exchange), through the gradient-accumulation
    (peeled, overlapped-issue) step. Returns one record per point.

    ``step_ms`` is the measured wall time per step on this host (the sim
    runs every worker on one device, so it is a compute-side number, not
    re-checked by check_bench). The exposed-comm breakdown is modeled on
    Ethernet constants: ``sync_comm_ms`` (volume/bandwidth + collectives
    x alpha; deterministic, checked) and ``exposed_comm_ms_overlapped``
    — the part of the exchange the readiness-ordered issue could NOT
    hide behind this host's backward window (measured-derived, not
    checked)."""
    from repro.configs import get
    from repro.core import OptimizerConfig, comm_accounting
    from repro.core import schedules as S
    from repro.data import DataConfig, SyntheticLM
    from repro.train import Trainer, TrainerConfig

    cfg = get("gpt2").smoke
    records = []
    for mb in bucket_mbs:
        opt_cfg = OptimizerConfig(
            name="zero_one_adam", lr=S.ConstantLr(1e-3),
            var_policy=S.EveryStepVariancePolicy(),
            sync_policy=S.EveryStepSyncPolicy(),
            bucket_mb=mb)
        tr = Trainer(cfg, opt_cfg, n_workers=workers,
                     trainer_cfg=TrainerConfig(
                         micro_batches=micro_batches))
        acct = comm_accounting(tr.opt)
        params, state = tr.sim_init(jax.random.PRNGKey(seed))
        fn = tr.sim_step_fn()
        data = SyntheticLM(DataConfig(
            vocab=cfg.vocab, seq_len=32,
            global_batch=workers * micro_batches, seed=seed))
        params, state, _ = fn(params, state, data.batch(0))  # compile
        jax.block_until_ready(params)
        t0 = time.perf_counter()
        for t in range(1, steps + 1):
            params, state, met = fn(params, state, data.batch(t))
        jax.block_until_ready(params)
        dt = time.perf_counter() - t0
        step_ms = dt / steps * 1e3
        sync_comm_ms = (acct["compressed_bytes_per_sync"] / hw.ETHERNET_BW
                        + acct["collectives_per_sync"]
                        * hw.ETHERNET_LATENCY) * 1e3
        exposed_ms = max(0.0, sync_comm_ms
                         - hw.BACKWARD_FRACTION * step_ms)
        records.append({
            "bench": "fixed_cost_buckets", "arch": "gpt2-smoke",
            "workers": workers, "bucket_mb": mb,
            "micro_batches": micro_batches,
            "dp_leaves": int(acct["dp_leaves"]),
            "exchange_units": int(acct["exchange_units"]),
            "collectives_per_sync": int(acct["collectives_per_sync"]),
            "bits_per_param_sync": acct["bits_per_param_sync"],
            "syncs_per_s": steps / dt,
            "step_ms": step_ms,
            "sync_comm_ms": sync_comm_ms,
            "exposed_comm_ms_overlapped": exposed_ms,
        })
    return records


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="append one JSONL record per --bucket-mb sweep "
                         "point (exchange_units, collectives_per_sync, "
                         "measured syncs_per_s)")
    ap.add_argument("--bucket-mb", type=float, nargs="*",
                    default=[0.25, 1.0, 4.0],
                    help="bucket budgets (MiB) to sweep, besides the "
                         "per-leaf baseline")
    ap.add_argument("--steps", type=int, default=6,
                    help="measured sync-every-step iterations per point")
    ap.add_argument("--micro-batches", type=int, default=2,
                    help="gradient-accumulation microbatches of the "
                         "measured step (>1 exercises the peeled, "
                         "overlapped-issue accumulation path)")
    args = ap.parse_args(argv)
    rows = []
    table3_section(rows)

    print("# Bucketed-exchange sweep — gpt2-smoke sim, sync every step, "
          f"micro_batches={args.micro_batches}")
    print("bucket_mb,dp_leaves,exchange_units,collectives_per_sync,"
          "step_ms,sync_comm_ms,exposed_comm_ms_overlapped,syncs_per_s")
    records = bucket_sweep([None] + list(args.bucket_mb), steps=args.steps,
                           micro_batches=args.micro_batches)
    for r in records:
        mb = "per-leaf" if r["bucket_mb"] is None else r["bucket_mb"]
        print(f"{mb},{r['dp_leaves']},{r['exchange_units']},"
              f"{r['collectives_per_sync']},{r['step_ms']:.1f},"
              f"{r['sync_comm_ms']:.2f},"
              f"{r['exposed_comm_ms_overlapped']:.2f},"
              f"{r['syncs_per_s']:.2f}")
        rows.append((f"bucket_sweep_{mb}", 1e6 / r["syncs_per_s"],
                     f"units={r['exchange_units']};"
                     f"collectives={r['collectives_per_sync']}"))
    base = records[0]
    best = min(records[1:], key=lambda r: r["collectives_per_sync"],
               default=base)
    print(f"# collectives/sync: {base['collectives_per_sync']} per-leaf "
          f"-> {best['collectives_per_sync']} bucketed "
          f"({base['dp_leaves']} DP leaves -> {best['exchange_units']} "
          f"buckets)")
    if args.json:
        with open(args.json, "a") as f:
            for rec in records:
                f.write(json.dumps(rec) + "\n")
    return rows


if __name__ == "__main__":
    main()
