"""Paper Fig. 3: end-to-end throughput vs cluster size / interconnect.

Alpha-beta communication model parameterized by (a) the paper's measured
per-step compute + fixed costs (appendix Table 3) and (b) OUR optimizers'
actual per-round communication volumes (from the comm layouts) and round
schedules. Reproduces the headline: 0/1 Adam reaches ~2x 1-bit Adam
throughput on the bandwidth-starved Ethernet cluster, and 0/1 Adam on
Ethernet ~= 1-bit Adam on InfiniBand.

The ``--bucket-mb`` sweep adds the dispatch-latency term the fused
exchange attacks: per sweep point it reports the exchange-unit count, the
collective phases per sync, and the modeled per-sync latency floor
``collectives_per_sync x alpha`` on Ethernet — appended as JSONL records
with ``--json``.
"""
from __future__ import annotations

import argparse
import json
import time

from benchmarks import hw
from repro.configs import get
from repro.core import OptimizerConfig, comm_accounting, build_optimizer
from repro.models import transformer as T
from repro.models.layers import abstract_params, param_specs

BATCHES = {"bert-base": 4096, "bert-large": 4096}

_AVG_CACHE = {}


def _run_averages(arch):
    """Whole-run average (one-way bytes/step, rounds/step) per optimizer,
    from the actual schedule traces (bench_data_volume)."""
    if arch in _AVG_CACHE:
        return _AVG_CACHE[arch]
    from benchmarks.bench_data_volume import run as dv_run
    steps = 100_000
    rows, d = dv_run(arch, total_steps=steps, warmup_frac=0.125,
                     double_frac=0.32)
    out = {}
    for name, bits, rounds in rows:
        if name.endswith("no_skip"):
            continue
        out[name] = (bits * d / 8.0, rounds / steps)
    _AVG_CACHE[arch] = out
    return out


def avg_step_time(arch, optimizer, n_gpus, bw, alpha, compute_ms,
                  fixed_ms):
    """Modeled per-step wall time (s), whole-run average (what Fig. 3
    measures): compute + volume/bandwidth + rounds x (latency + fixed)."""
    vol, rps = _run_averages(arch)[optimizer]
    fixed = fixed_ms if optimizer != "adam" else 0.3 * fixed_ms
    comm_s = vol / bw + rps * (alpha + fixed / 1e3)
    return compute_ms / 1e3 + comm_s


def _tp_local_shapes(shapes, specs, model_axis_sizes):
    """TP-LOCAL abstract params: dims a spec shards over a model axis are
    divided by that axis's size — the fully-manual-regime convention
    ``build_optimizer`` expects alongside ``model_axis_sizes`` (mirrors
    ``train.step.Trainer._shrink_model``)."""
    import jax
    leaves, tdef = jax.tree.flatten(shapes)
    specs_f = tdef.flatten_up_to(specs)
    out = []
    for leaf, spec in zip(leaves, specs_f):
        shape = list(leaf.shape)
        for ax, e in enumerate(tuple(spec) if spec is not None else ()):
            if e is None:
                continue
            f = 1
            for name in (e if isinstance(e, tuple) else (e,)):
                f *= model_axis_sizes.get(name, 1)
            assert shape[ax] % f == 0, (leaf.shape, spec, f)
            shape[ax] //= f
        out.append(jax.ShapeDtypeStruct(tuple(shape), leaf.dtype))
    return jax.tree.unflatten(tdef, out)


def bucket_latency_sweep(arch="bert-large", workers=16,
                         bucket_mbs=(None, 4.0, 32.0), tp=0):
    """Exchange-unit counts, the modeled per-sync dispatch-latency floor,
    and the modeled Ethernet step-time breakdown per bucket budget, from
    the real comm layouts.

    Step-time fields (all deterministic, guarded by check_bench):
    ``sync_comm_ms`` is the full exchange wire time (volume/bandwidth +
    collectives x alpha); ``step_ms_sequential`` runs it after the
    backward; ``step_ms_overlapped`` hides it inside the backward window
    (``hw.BACKWARD_FRACTION`` of the paper's measured compute), leaving
    only ``exposed_comm_ms_overlapped`` on the critical path — the number
    the readiness-ordered per-unit issue targets.

    ``tp > 0`` plans against TP-local shards (``model_axis_sizes=
    {"model": tp}``): same-spec shards then pack into *sharded* fused
    buckets (core/bucketing.py), so the sweep shows the exchange-unit
    collapse surviving tensor parallelism instead of shattering into
    per-leaf singletons."""
    cfg = get(arch).config
    tmpl = T.model_template(cfg)
    shapes = abstract_params(tmpl)
    specs = param_specs(tmpl)
    ms = {"model": tp} if tp else None
    if ms:
        shapes = _tp_local_shapes(shapes, specs, ms)
    compute_ms = hw.PAPER_COMPUTE_MS.get(arch, {}).get(workers, 0.0)
    overlap_ms = hw.BACKWARD_FRACTION * compute_ms
    records = []
    for mb in bucket_mbs:
        ocfg = OptimizerConfig(name="zero_one_adam", bucket_mb=mb)
        opt = build_optimizer(ocfg, shapes, specs=specs, n_workers=workers,
                              model_axis_sizes=ms)
        acct = comm_accounting(opt)
        colls = acct["collectives_per_sync"]
        latency_floor_ms = colls * hw.ETHERNET_LATENCY * 1e3
        sync_comm_ms = (acct["compressed_bytes_per_sync"] / hw.ETHERNET_BW
                        * 1e3 + latency_floor_ms)
        exposed_ms = max(0.0, sync_comm_ms - overlap_ms)
        records.append({
            "bench": "throughput_buckets", "arch": arch,
            "workers": workers, "bucket_mb": mb, "tp": tp,
            "dp_leaves": int(acct["dp_leaves"]),
            "exchange_units": int(acct["exchange_units"]),
            "collectives_per_sync": int(colls),
            "sync_latency_floor_ms": latency_floor_ms,
            "syncs_per_s_latency_bound": 1e3 / max(latency_floor_ms,
                                                   1e-9),
            "sync_comm_ms": sync_comm_ms,
            "step_ms_sequential": compute_ms + sync_comm_ms,
            "step_ms_overlapped": compute_ms + exposed_ms,
            "exposed_comm_ms_overlapped": exposed_ms,
        })
    return records


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="append JSONL records (the Fig.3 grid and the "
                         "bucket-latency sweep) here")
    ap.add_argument("--bucket-mb", type=float, nargs="*",
                    default=[4.0, 32.0],
                    help="bucket budgets (MiB) for the dispatch-latency "
                         "sweep, besides the per-leaf baseline")
    args = ap.parse_args(argv)
    t0 = time.time()
    rows = []
    records = []
    print("# Fig.3 analogue — modeled whole-run throughput (samples/s)")
    print("arch,cluster,gpus,adam,one_bit_adam,zero_one_adam,"
          "speedup_01_vs_1bit")
    headline = {}
    for arch in ("bert-base", "bert-large"):
        for cluster, bw, alpha in (
                ("ethernet", hw.ETHERNET_BW, hw.ETHERNET_LATENCY),
                ("infiniband", hw.INFINIBAND_BW, hw.INFINIBAND_LATENCY)):
            for n in (16, 32, 64, 128):
                comp = hw.PAPER_COMPUTE_MS[arch][n]
                fix = hw.PAPER_FIXED_MS[arch][n]
                tput = {}
                for o in ("adam", "one_bit_adam", "zero_one_adam"):
                    st = avg_step_time(arch, o, n, bw, alpha, comp, fix)
                    tput[o] = BATCHES[arch] / st
                sp = tput["zero_one_adam"] / tput["one_bit_adam"]
                headline[(arch, cluster, n)] = tput
                print(f"{arch},{cluster},{n},{tput['adam']:.0f},"
                      f"{tput['one_bit_adam']:.0f},"
                      f"{tput['zero_one_adam']:.0f},{sp:.2f}")
                records.append({"bench": "throughput_model", "arch": arch,
                                "cluster": cluster, "gpus": n,
                                **{f"samples_per_s_{k}": v
                                   for k, v in tput.items()}})
    # headline checks
    eth = headline[("bert-large", "ethernet", 128)]
    ib = headline[("bert-large", "infiniband", 128)]
    sp = eth["zero_one_adam"] / eth["one_bit_adam"]
    cross = eth["zero_one_adam"] / ib["one_bit_adam"]
    print(f"# BERT-Large@128 Ethernet: 0/1 vs 1-bit Adam speedup "
          f"{sp:.2f}x (paper: up to 2x)")
    print(f"# 0/1 Adam on Ethernet vs 1-bit Adam on InfiniBand: "
          f"{cross:.2f}x (paper: comparable, ~1x)")

    # dispatch-latency (fixed-cost) floor per bucket budget
    sweep = bucket_latency_sweep(bucket_mbs=[None] + list(args.bucket_mb))
    records.extend(sweep)
    print("# Bucketed-exchange dispatch floor + modeled step-time "
          "breakdown — bert-large, 16 workers, Ethernet")
    print("bucket_mb,dp_leaves,exchange_units,collectives_per_sync,"
          "sync_latency_floor_ms,sync_comm_ms,step_ms_sequential,"
          "step_ms_overlapped,exposed_comm_ms_overlapped")
    for r in sweep:
        mb = "per-leaf" if r["bucket_mb"] is None else r["bucket_mb"]
        print(f"{mb},{r['dp_leaves']},{r['exchange_units']},"
              f"{r['collectives_per_sync']},"
              f"{r['sync_latency_floor_ms']:.2f},"
              f"{r['sync_comm_ms']:.1f},{r['step_ms_sequential']:.1f},"
              f"{r['step_ms_overlapped']:.1f},"
              f"{r['exposed_comm_ms_overlapped']:.1f}")
    rows.append(("bucket_dispatch_floor", 0.0,
                 f"per_leaf={sweep[0]['collectives_per_sync']};"
                 f"best={min(r['collectives_per_sync'] for r in sweep)}"))

    # same sweep against tensor-parallel-local shards: sharded fused
    # buckets must keep the unit collapse under TP
    sweep_tp = bucket_latency_sweep(bucket_mbs=[None] + list(args.bucket_mb),
                                    tp=2)
    records.extend(sweep_tp)
    print("# Sharded-bucket sweep — same model planned over tp=2 "
          "TP-local shards")
    print("bucket_mb,tp,dp_leaves,exchange_units,collectives_per_sync,"
          "sync_latency_floor_ms")
    for r in sweep_tp:
        mb = "per-leaf" if r["bucket_mb"] is None else r["bucket_mb"]
        print(f"{mb},{r['tp']},{r['dp_leaves']},{r['exchange_units']},"
              f"{r['collectives_per_sync']},"
              f"{r['sync_latency_floor_ms']:.2f}")
    rows.append(("bucket_dispatch_floor_tp2", 0.0,
                 f"per_leaf={sweep_tp[0]['collectives_per_sync']};"
                 f"best={min(r['collectives_per_sync'] for r in sweep_tp)}"))
    if args.json:
        with open(args.json, "a") as f:
            for rec in records:
                f.write(json.dumps(rec) + "\n")
    print(f"# elapsed {time.time()-t0:.1f}s")
    rows.append(("throughput_model", 0.0,
                 f"eth_speedup={sp:.2f};cross_fabric={cross:.2f}"))
    return rows


if __name__ == "__main__":
    main()
