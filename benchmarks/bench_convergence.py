"""Paper Fig. 2: sample-wise convergence of the compressed pipelines vs
their uncompressed base optimizers — same data order, n=4 simulated
workers, tiny-GPT2 LM on the structured synthetic stream. The claim under
test: the 0/1 recipe matches the sample-wise convergence of the
uncompressed base while communicating a fraction of the bits — for *any*
base the ``compressed_dp`` combinator wraps, not just Adam.

    python -m benchmarks.bench_convergence                       # classic trio
    python -m benchmarks.bench_convergence --optimizer zero_one_lamb
    python -m benchmarks.bench_convergence --optimizer zero_one_sgd --steps 80

With ``--optimizer`` the bench runs the named pipeline *and* its
uncompressed base (``zero_one_lamb`` -> ``lamb``, ``zero_one_sgd`` ->
``momentum_sgd``, ...) and reports the final-loss parity gap — the
Fig.-2-style evidence for the new variants.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get
from repro.core import (CODEC_NAMES, OptimizerConfig, REGISTRY_NAMES,
                        schedules as S)
from repro.data import DataConfig, SyntheticLM
from repro.train import Trainer

STEPS = 120
WORKERS = 4
BATCH = 8
SEQ = 32

# compressed pipeline -> its uncompressed base (the parity reference)
BASE_OF = {
    "zero_one_adam": "adam",
    "zero_one_lamb": "lamb",
    "zero_one_sgd": "momentum_sgd",
    "one_bit_adam": "adam",
    "one_bit_lamb": "lamb",
}

# parity is one-sided: the compressed pipeline may trail its uncompressed
# base by at most this (nats, avg of the last 10 steps) — beating the base
# (which 0/1 Adam does at this toy scale, where local steps act like extra
# momentum) is fine. CI-stable with margin (observed trailing gaps ~<0.16)
PARITY_TOL = 0.25


def run_one(optimizer: str, steps: int = STEPS, codec: str = "sign1bit",
            codec_arg=None):
    cfg = get("gpt2").smoke
    lr = S.LinearWarmupExpDecay(peak_lr=2e-3, warmup_steps=20,
                                decay=0.97, decay_period=20)
    ocfg = OptimizerConfig(
        name=optimizer, lr=lr,
        var_policy=S.AdaptiveFreezePolicy(kappa=4),
        sync_policy=S.LrProportionalSyncPolicy(
            warmup_steps=30, double_every=40, max_interval=4),
        onebit_warmup=30, codec=codec, codec_arg=codec_arg)
    tr = Trainer(cfg, ocfg, n_workers=WORKERS)
    params, state = tr.sim_init(jax.random.PRNGKey(0))
    fn = tr.sim_step_fn()
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=SEQ,
                                  global_batch=BATCH, seed=17))
    losses = []
    for step in range(steps):
        batch = data.batch(step)
        params, state, met = fn(params, state, batch)
        losses.append(float(np.asarray(met["loss"]).reshape(-1)[0]))
    return losses


def _tail(curve):
    return float(np.mean(curve[-10:]))


def run_parity(optimizers, steps: int, codec: str = "sign1bit",
               codec_arg=None):
    """Each compressed pipeline against its uncompressed base; returns
    bench rows and prints the loss-vs-samples table. ``codec`` selects the
    wire format of the compressed pipelines (the uncompressed bases ignore
    it), so the same parity gate covers every codec."""
    t0 = time.time()
    names = []
    for o in optimizers:
        base = BASE_OF.get(o)
        if base and base not in names:
            names.append(base)
        if o not in names:
            names.append(o)
    curves = {}
    for o in names:
        curves[o] = run_one(o, steps,
                            codec=codec if o in BASE_OF else "sign1bit",
                            codec_arg=codec_arg if o in BASE_OF else None)
        print(f"# {o}: start {curves[o][0]:.3f} -> "
              f"final(avg last 10) {_tail(curves[o]):.3f}")
    print("step," + ",".join(names))
    for i in range(0, steps, 10):
        print(f"{i}," + ",".join(f"{curves[o][i]:.4f}" for o in names))
    rows = []
    ok = True
    for o in optimizers:
        base = BASE_OF.get(o)
        if base is None:
            continue
        gap = _tail(curves[o]) - _tail(curves[base])
        within = gap <= PARITY_TOL
        ok = ok and within
        print(f"# {o} (codec={codec}) final-loss gap vs {base}: "
              f"{gap:+.4f} nats "
              f"(gap <= {PARITY_TOL} -> parity "
              f"{'OK' if within else 'FAILED'})")
        rows.append((f"convergence_{o}_vs_{base}", 0.0,
                     f"codec={codec};gap={gap:.4f}"))
    print(f"# elapsed {time.time()-t0:.1f}s")
    if not ok:
        raise AssertionError("sample-wise parity exceeded tolerance; see "
                             "gaps above")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--optimizer", action="append", default=None,
                    choices=list(REGISTRY_NAMES),
                    help="pipeline(s) to check against their uncompressed "
                         "base (repeatable); default: the classic trio")
    ap.add_argument("--steps", type=int, default=STEPS)
    ap.add_argument("--codec", default="sign1bit",
                    choices=list(CODEC_NAMES),
                    help="wire format of the compressed pipelines "
                         "(the uncompressed bases are unaffected)")
    ap.add_argument("--codec-arg", type=float, default=None,
                    help="parameter for parameterized codecs (topk density)")
    args = ap.parse_args(argv)
    optimizers = args.optimizer or ["one_bit_adam", "zero_one_adam"]
    return run_parity(optimizers, args.steps, codec=args.codec,
                      codec_arg=args.codec_arg)


if __name__ == "__main__":
    main()
