"""Paper Fig. 2: sample-wise convergence — Adam vs 1-bit Adam vs 0/1 Adam,
same data order, n=4 simulated workers, tiny-GPT2 LM on the structured
synthetic stream. The claim under test: 0/1 Adam matches the sample-wise
convergence of the baselines while communicating a fraction of the bits.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.core import OptimizerConfig, schedules as S
from repro.data import DataConfig, SyntheticLM
from repro.train import Trainer, TrainerConfig

STEPS = 120
WORKERS = 4
BATCH = 8
SEQ = 32


def run_one(optimizer: str):
    cfg = get("gpt2").smoke
    lr = S.LinearWarmupExpDecay(peak_lr=2e-3, warmup_steps=20,
                                decay=0.97, decay_period=20)
    ocfg = OptimizerConfig(
        name=optimizer, lr=lr,
        var_policy=S.AdaptiveFreezePolicy(kappa=4),
        sync_policy=S.LrProportionalSyncPolicy(
            warmup_steps=30, double_every=40, max_interval=4),
        onebit_warmup=30)
    tr = Trainer(cfg, ocfg, n_workers=WORKERS)
    params, state = tr.sim_init(jax.random.PRNGKey(0))
    fn = tr.sim_step_fn()
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=SEQ,
                                  global_batch=BATCH, seed=17))
    losses = []
    for step in range(STEPS):
        batch = data.batch(step)
        params, state, met = fn(params, state, batch)
        losses.append(float(np.asarray(met["loss"]).reshape(-1)[0]))
    return losses


def main():
    t0 = time.time()
    curves = {}
    for o in ("adam", "one_bit_adam", "zero_one_adam"):
        curves[o] = run_one(o)
        tail = np.mean(curves[o][-10:])
        print(f"# {o}: start {curves[o][0]:.3f} -> "
              f"final(avg last 10) {tail:.3f}")
    print("step,adam,one_bit_adam,zero_one_adam")
    for i in range(0, STEPS, 10):
        print(f"{i},{curves['adam'][i]:.4f},"
              f"{curves['one_bit_adam'][i]:.4f},"
              f"{curves['zero_one_adam'][i]:.4f}")
    a = np.mean(curves["adam"][-10:])
    z = np.mean(curves["zero_one_adam"][-10:])
    gap = z - a
    print(f"# 0/1 Adam final-loss gap vs Adam: {gap:+.4f} nats "
          f"(paper claim: same sample-wise convergence)")
    print(f"# elapsed {time.time()-t0:.1f}s")
    return [("convergence_fig2", 0.0, f"final_gap={gap:.4f}")]


if __name__ == "__main__":
    main()
