"""Elastic-DP benchmark: resharding cost + post-resize convergence parity.

Two sections:

1. ``elastic_reshard`` — one record per resize scenario (identity,
   kill/shrink, grow, pod kill under hierarchy, bucketed shrink) over a
   trained gpt2-smoke sim state: the full :func:`repro.elastic.
   reshard_report` geometry (entities carried/dead, joiners, fold,
   true/padded elements — all static, re-derived by ``check_bench.py``)
   plus the measured wall-clock of the state remap itself
   (``reshard_ms``, host-dependent, not re-checked).
2. ``elastic_parity`` — a kill -> shrink -> rejoin -> grow FleetSim run
   vs its uninterrupted baseline: the recorded tail-loss gap must sit
   inside ``bench_convergence.PARITY_TOL`` (hard-gated by
   ``check_bench.py``, same pattern as the qint8 publish budget).

    PYTHONPATH=src python -m benchmarks.bench_elastic --json BENCH_elastic.json
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from benchmarks.bench_convergence import PARITY_TOL
from repro.configs import get
from repro.core import Hierarchy, OptimizerConfig, schedules as S
from repro.data import DataConfig, SyntheticLM
from repro.elastic import (FleetSim, ResizeEvent, parity_gap,
                           reshard_report, reshard_trainer)
from repro.train import Trainer

ARCH = "gpt2-smoke"
SEQ, BATCH = 16, 8


def _opt_cfg(inner=0, bucket_mb=None):
    return OptimizerConfig(
        name="zero_one_adam", lr=S.ConstantLr(1e-3),
        var_policy=S.AdaptiveFreezePolicy(kappa=2),
        sync_policy=S.LrProportionalSyncPolicy(warmup_steps=2,
                                               double_every=3,
                                               max_interval=2),
        hierarchy=Hierarchy(inner=inner) if inner else None,
        bucket_mb=bucket_mb)


#: scenario -> (n_from, n_to, survivors, inner, bucket_mb)
SCENARIOS = {
    "flat_4to4_identity": (4, 4, None, 0, None),
    "flat_4to2_kill1": (4, 2, (0, 2), 0, None),
    "flat_2to4_grow": (2, 4, None, 0, None),
    "hier_4to2_podkill": (4, 2, (0, 1), 2, None),
    "bucketed_4to2_kill1": (4, 2, (0, 2), 0, 0.25),
}


def _trained(cfg, opt_cfg, n, steps, seed=5):
    tr = Trainer(cfg, opt_cfg, n_workers=n)
    params, state = tr.sim_init(jax.random.PRNGKey(seed))
    fn = tr.sim_step_fn()
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=SEQ,
                                  global_batch=BATCH, seed=seed))
    for t in range(steps):
        params, state, _ = fn(params, state, data.batch(t))
    return tr, params, state


def reshard_section(steps=4, repeats=3):
    """Measured remap latency + static geometry per resize scenario."""
    cfg = get("gpt2").smoke
    records = []
    print("# Resharding — gpt2-smoke sim, trained state")
    print("scenario,n_from,n_to,carried,dead,joiners,fold,true_elems,"
          "reshard_ms")
    for name, (n, m, survivors, inner, mb) in SCENARIOS.items():
        opt_cfg = _opt_cfg(inner, mb)
        tr, params, state = _trained(cfg, opt_cfg, n, steps)
        dst = Trainer(cfg, opt_cfg, n_workers=m)
        rep = reshard_report(tr.opt, dst.opt, survivors=survivors)
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            p2, s2 = reshard_trainer(tr, dst, params, state,
                                     survivors=survivors)
            jax.block_until_ready((p2, s2.step))
            best = min(best, (time.perf_counter() - t0) * 1e3)
        rec = {"bench": "elastic_reshard", "scenario": name, "arch": ARCH,
               "inner": inner, "bucket_mb": mb,
               "survivors": list(survivors) if survivors else None,
               "reshard_ms": best}
        rec.update({k: (int(v) if isinstance(v, bool) else v)
                    for k, v in rep.items()})
        records.append(rec)
        print(f"{name},{rep['n_from']},{rep['n_to']},"
              f"{rep['carried_entities']},{rep['dead_entities']},"
              f"{rep['joiner_workers']},{int(rep['ef_fold'])},"
              f"{rep['true_elems']},{best:.1f}")
    return records


def parity_section(steps=30):
    """Kill worker 1 at steps//3 (4 -> 2), rejoin at 2*steps//3 (2 -> 4);
    tail-loss gap vs the uninterrupted 4-worker baseline."""
    cfg = get("gpt2").smoke
    opt_cfg = _opt_cfg()
    events = [ResizeEvent(step=steps // 3, workers=2, survivors=(0, 2)),
              ResizeEvent(step=2 * steps // 3, workers=4)]
    base = FleetSim(cfg, opt_cfg, 4, seed=3).run(
        steps, global_batch=BATCH, seq=SEQ)
    el = FleetSim(cfg, opt_cfg, 4, seed=3).run(
        steps, global_batch=BATCH, seq=SEQ, events=events)
    gap = parity_gap(el["losses"], base["losses"])
    tail = min(10, steps)
    rec = {
        "bench": "elastic_parity", "scenario": "kill_shrink_rejoin",
        "arch": ARCH, "steps": steps, "n_resizes": len(el["resizes"]),
        "parity_gap": gap, "parity_tol": PARITY_TOL,
        "baseline_tail": float(np.mean(base["losses"][-tail:])),
        "elastic_tail": float(np.mean(el["losses"][-tail:])),
        "reshard_ms": [r["reshard_ms"] for r in el["resizes"]],
    }
    verdict = "OK" if gap <= PARITY_TOL else "DIVERGED"
    print(f"# Parity — {steps} steps, kill@{events[0].step} "
          f"rejoin@{events[1].step}: gap {gap:+.3f} nats "
          f"(tol {PARITY_TOL}) -> {verdict}")
    return [rec]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="append one JSONL record per scenario")
    ap.add_argument("--steps", type=int, default=30,
                    help="parity-run length (baseline and elastic)")
    ap.add_argument("--smoke", action="store_true",
                    help="reshard geometry only — skip the parity sims")
    args = ap.parse_args(argv)

    records = reshard_section()
    if not args.smoke:
        records += parity_section(steps=args.steps)
    if args.json:
        with open(args.json, "a") as f:
            for r in records:
                f.write(json.dumps(r) + "\n")
    gaps = [r for r in records
            if r["bench"] == "elastic_parity"
            and r["parity_gap"] > r["parity_tol"]]
    return 1 if gaps else 0


if __name__ == "__main__":
    raise SystemExit(main())
