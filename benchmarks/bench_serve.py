"""Serving-loop benchmark: publish bytes, refresh latency, batcher tok/s.

Two record kinds, appended as JSONL with ``--json``:

* ``serve_publish`` — per codec (qint8/qint4/identity): the declared wire
  bytes of a delta refresh and a full snapshot over the bucketed publish
  layout, against the full-f32 baseline push (structural — re-derived by
  ``check_bench.py``), the modeled ``reduction_x`` ratio, and the measured
  subscriber decode+apply latency (``refresh_ms_*``, wall-clock, not
  gated). Reconstruction error across the delta cycle is printed so the
  "bounded, non-accumulating" claim is a number, not a comment.
* ``serve_throughput`` — a continuous-batching run over the scheduler with
  a live Publisher→Subscriber refresh every ``--publish-every`` ticks:
  structural counts (requests, slots, generated tokens, prefills) plus
  measured tok/s and mean weight-swap latency.

    PYTHONPATH=src python -m benchmarks.bench_serve --json BENCH_serve.json
    PYTHONPATH=src python -m benchmarks.bench_serve --smoke
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.models import transformer as T
from repro.models.layers import init_params
from repro.serve import (Publisher, PublishConfig, Request, Scheduler,
                         Server, Subscriber)


def _perturb(params, key, scale=1e-3):
    """A deterministic fine-tuning-like drift of every leaf."""
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [
        x + scale * jax.random.normal(k, x.shape, x.dtype)
        for x, k in zip(leaves, keys)])


def publish_records(arch, params, *, codecs, bucket_mb, n_chunks, cycles):
    records = []
    for name in codecs:
        pc = PublishConfig(codec=name, bucket_mb=bucket_mb,
                           n_chunks=n_chunks,
                           snapshot_every=cycles + 1)
        pub = Publisher(params, pc)
        sub = Subscriber(params, pc)
        u0 = pub.publish(params, step=0)
        t0 = time.perf_counter()
        jax.block_until_ready(jax.tree.leaves(sub.apply(u0)))
        snap_ms = (time.perf_counter() - t0) * 1e3
        p, key = params, jax.random.PRNGKey(0)
        delta_ms, errs = [], []
        for t in range(1, cycles + 1):
            key, k = jax.random.split(key)
            p = _perturb(p, k)
            u = pub.publish(p, step=t)
            t0 = time.perf_counter()
            got = sub.apply(u)
            jax.block_until_ready(jax.tree.leaves(got))
            delta_ms.append((time.perf_counter() - t0) * 1e3)
            errs.append(max(float(jnp.max(jnp.abs(a - b))) for a, b in
                            zip(jax.tree.leaves(got), jax.tree.leaves(p))))
        full = pub.wire.full_f32_bytes()
        delta_bytes = pub.wire.wire_bytes("delta")
        records.append({
            "bench": "serve_publish", "arch": f"{arch}-smoke",
            "codec": pub.wire.codec.name, "bucket_mb": bucket_mb,
            "n_chunks": n_chunks, "cycles": cycles,
            "n_buckets": u0.manifest["n_buckets"],
            "full_f32_bytes": full,
            "snapshot_bytes": pub.wire.wire_bytes("snapshot"),
            "delta_bytes": delta_bytes,
            "reduction_x": full / delta_bytes,
            "refresh_ms_snapshot": snap_ms,
            "refresh_ms_delta": (float(np.mean(delta_ms))
                                 if delta_ms else snap_ms),
            "max_abs_err": float(max(errs)) if errs else 0.0,
        })
    return records


def serve_run(arch, params, *, slots, n_requests, prompt_len, gen,
              max_seq, publish_every, codec, kv_quant):
    cfg = get(arch).smoke
    srv = Server(cfg, batch=slots, max_seq=max_seq,
                 cache_dtype=jnp.float32)
    pc = PublishConfig(codec=codec, bucket_mb=4.0)
    pub, sub = Publisher(params, pc), Subscriber(params, pc)
    sub.push(pub.publish(params, step=0))
    sch = Scheduler(srv, params, subscriber=sub,
                    kv_quant=kv_quant, kv_page=max_seq // 4)

    def make_requests(tag):
        key = jax.random.PRNGKey(42)
        return [Request(rid=f"{tag}{i}",
                        prompt=np.asarray(jax.random.randint(
                            jax.random.fold_in(key, i), (prompt_len,), 0,
                            cfg.vocab)).tolist(),
                        max_new_tokens=gen)
                for i in range(n_requests)]

    sch.run(make_requests("warm"))          # compile warmup
    for r in make_requests("run"):
        sch.submit(r)
    base = dict(sch.stats)
    p, key, swap_ms = params, jax.random.PRNGKey(9), []
    t0 = time.perf_counter()
    ticks = 0
    while not sch.idle:
        if publish_every and ticks and ticks % publish_every == 0:
            key, k = jax.random.split(key)
            p = _perturb(p, k)
            sub.push(pub.publish(p, step=ticks))
            ts = time.perf_counter()
            sch.tick()                      # swap happens at tick boundary
            swap_ms.append((time.perf_counter() - ts) * 1e3)
        else:
            sch.tick()
        ticks += 1
    dt = time.perf_counter() - t0
    generated = sch.stats["generated"] - base["generated"]
    return {
        "bench": "serve_throughput", "arch": f"{arch}-smoke",
        "codec": codec, "kv_quant": kv_quant or "none",
        "slots": slots, "n_requests": n_requests,
        "prompt_len": prompt_len, "max_new_tokens": gen,
        "generated": generated,
        "prefills": sch.stats["prefills"] - base["prefills"],
        "decode_ticks": sch.stats["decode_ticks"] - base["decode_ticks"],
        "weight_swaps": sch.stats["weight_swaps"] - base["weight_swaps"],
        "pages_quantized": sch.stats["pages_quantized"]
        - base["pages_quantized"],
        "tok_s": generated / dt,
        "weight_swap_tick_ms": (float(np.mean(swap_ms))
                                if swap_ms else 0.0),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2")
    ap.add_argument("--codecs", nargs="*",
                    default=["qint8", "qint4", "identity"])
    ap.add_argument("--bucket-mb", type=float, default=4.0)
    ap.add_argument("--n-chunks", type=int, default=16)
    ap.add_argument("--cycles", type=int, default=10,
                    help="delta publish/apply cycles per codec")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--publish-every", type=int, default=8,
                    help="push a delta publish every N ticks (0 = never)")
    ap.add_argument("--kv-quant", choices=["none", "qint8"],
                    default="none")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes for CI")
    ap.add_argument("--json", default=None,
                    help="append one JSONL record per point")
    args = ap.parse_args(argv)
    if args.smoke:
        args.cycles, args.requests, args.gen = 3, 3, 6
        args.slots = min(args.slots, 2)

    params = init_params(T.model_template(get(args.arch).smoke),
                         jax.random.PRNGKey(0))
    records = publish_records(
        args.arch, params, codecs=args.codecs, bucket_mb=args.bucket_mb,
        n_chunks=args.n_chunks, cycles=args.cycles)
    print("# publish wire accounting — delta refresh vs full-f32 push")
    print("codec,full_f32_bytes,delta_bytes,reduction_x,"
          "refresh_ms_delta,max_abs_err")
    for r in records:
        print(f"{r['codec']},{r['full_f32_bytes']},{r['delta_bytes']},"
              f"{r['reduction_x']:.2f},{r['refresh_ms_delta']:.1f},"
              f"{r['max_abs_err']:.2e}")
    q8 = next((r for r in records if r["codec"] == "qint8"), None)
    if q8 is not None and q8["delta_bytes"] * 3 > q8["full_f32_bytes"]:
        raise SystemExit(
            f"qint8 delta refresh moves {q8['delta_bytes']} bytes — more "
            f"than 1/3 of the full-f32 push ({q8['full_f32_bytes']})")

    sr = serve_run(args.arch, params, slots=args.slots,
                   n_requests=args.requests, prompt_len=args.prompt_len,
                   gen=args.gen, max_seq=args.max_seq,
                   publish_every=args.publish_every,
                   codec="qint8",
                   kv_quant=None if args.kv_quant == "none"
                   else args.kv_quant)
    records.append(sr)
    print(f"# continuous batching: {sr['n_requests']} requests over "
          f"{sr['slots']} slots -> {sr['generated']} tokens, "
          f"{sr['tok_s']:.1f} tok/s, {sr['weight_swaps']} live weight "
          f"swap(s), swap-tick {sr['weight_swap_tick_ms']:.1f} ms")

    if args.json:
        with open(args.json, "a") as f:
            for rec in records:
                f.write(json.dumps(rec) + "\n")
    return records


if __name__ == "__main__":
    main()
