"""Paper Tables 1-2 analogue: end-task quality parity.

GLUE/ImageNet are proxied by a synthetic classification task (deterministic,
linearly-separable-with-noise). The claim under test is PARITY: the three
optimizers reach the same final accuracy, not that any wins.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (OptimizerConfig, build_optimizer, sim_comm,
                        schedules as S)
from repro.data import SyntheticClassify

DIM, CLASSES, N = 32, 8, 4
STEPS, BATCH = 800, 64
COMM = sim_comm("w")


def init_mlp(key):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (DIM, 64)) * 0.1,
            "b1": jnp.zeros((64,)),
            "w2": jax.random.normal(k2, (64, CLASSES)) * 0.1,
            "b2": jnp.zeros((CLASSES,))}


def fwd(p, x):
    h = jax.nn.relu(x @ p["w1"] + p["b1"])
    return h @ p["w2"] + p["b2"]


def loss_fn(p, x, y):
    lg = fwd(p, x)
    return -jnp.mean(jax.nn.log_softmax(lg)[jnp.arange(y.shape[0]), y])


def run_one(optimizer, task):
    params = init_mlp(jax.random.PRNGKey(0))
    lr = S.LinearWarmupExpDecay(peak_lr=5e-3, warmup_steps=60,
                                decay=0.97, decay_period=60)
    cfg = OptimizerConfig(
        name=optimizer, lr=lr,
        var_policy=S.AdaptiveFreezePolicy(kappa=8),
        sync_policy=S.LrProportionalSyncPolicy(warmup_steps=150,
                                               double_every=200,
                                               max_interval=4),
        onebit_warmup=150)
    opt = build_optimizer(cfg, params, n_workers=N)
    state = jax.vmap(lambda _: opt.init(params))(jnp.arange(N))
    xs = jax.tree.map(lambda x: jnp.broadcast_to(x, (N,) + x.shape) + 0,
                      params)

    @jax.jit
    def one(xs, state, x, y):
        xw = x.reshape(N, -1, DIM)
        yw = y.reshape(N, -1)

        def per(p, s, xi, yi):
            g = jax.grad(loss_fn)(p, xi, yi)
            return opt.step(COMM, p, g, s)

        return jax.vmap(per, axis_name="w")(xs, state, xw, yw)

    for step in range(STEPS):
        x, y = task.batch(step, BATCH)
        xs, state, _ = one(xs, state, x, y)

    # eval on held-out batches
    p0 = jax.tree.map(lambda l: l[0], xs)
    accs = []
    for step in range(1000, 1010):
        x, y = task.batch(step, 256)
        accs.append(float((jnp.argmax(fwd(p0, x), -1) == y).mean()))
    return float(np.mean(accs))


def main():
    t0 = time.time()
    task = SyntheticClassify(DIM, CLASSES, seed=7)
    print("# Tables 1-2 analogue — end-task accuracy parity "
          "(synthetic classification)")
    print("optimizer,accuracy")
    accs = {}
    for o in ("adam", "one_bit_adam", "zero_one_adam"):
        accs[o] = run_one(o, task)
        print(f"{o},{accs[o]:.4f}")
    spread = max(accs.values()) - min(accs.values())
    print(f"# accuracy spread across optimizers: {spread:.4f} "
          f"(paper claim: parity, within noise)")
    print(f"# elapsed {time.time()-t0:.1f}s")
    return [("quality_parity", 0.0, f"spread={spread:.4f}")]


if __name__ == "__main__":
    main()
