"""Kernel microbenchmarks (interpret-mode timings are NOT TPU performance —
they validate plumbing; derived column reports bytes touched per call)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6, out


def main():
    rows = []
    R, C = 64, 4096
    key = jax.random.PRNGKey(0)
    z = jax.random.normal(key, (R, C))
    e = jnp.zeros((R, C))
    us, out = _time(ops.ef_compress, z, e)
    rows.append(("kernel_ef_compress_64x4096", us,
                 f"bytes={R*C*4*3 + R*C//8}"))
    # correctness vs oracle (also asserted in tests)
    p2, s2, e2 = ref.ef_compress_ref(z, e)
    assert bool((out[0] == p2).all())
    us, _ = _time(ops.decompress, out[0], out[1])
    rows.append(("kernel_decompress_64x4096", us, f"bytes={R*C*4 + R*C//8}"))
    g = jax.random.normal(key, (R, C))
    m = jnp.zeros_like(g)
    u = jnp.zeros_like(g)
    v = jnp.ones_like(g)
    us, _ = _time(lambda *a: ops.fused_local_step(*a, 0.01), g, m, u, v)
    rows.append(("kernel_fused_local_step_64x4096", us,
                 f"bytes={R*C*4*7}"))
    # jnp reference pipeline for comparison
    us, _ = _time(jax.jit(lambda z, e: ref.ef_compress_ref(z, e)), z, e)
    rows.append(("jnp_ef_compress_ref_64x4096", us, "oracle"))
    for r in rows:
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
    return rows


if __name__ == "__main__":
    main()
