"""Kernel microbenchmarks: fused Pallas path vs unfused XLA path.

Interpret-mode timings are NOT TPU performance — they validate plumbing.
The load-bearing column is ``bytes/param``: HBM bytes touched per parameter
per call, derived from the op structure. The fused kernels win by touching
each parameter byte once per pass instead of once per XLA op:

  EF-compress  unfused: add err (8r+4w) + |.| reduce (4r) + sign/where
               (4r+4w) + packbits (4r + 0.125w) + err write (8r+4w)
               = ~40 bytes/param
               fused (1-pass): 8r + 4w + 0.125w + scales  = ~12.1 bytes/param
               fused (2-pass): + one extra 8r sweep       = ~20.1 bytes/param
  local step   unfused: ~10 sweeps of m/v/u/g/delta      = ~40 bytes/param
               fused: 4r + 3w f32                         =  28 bytes/param
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import compressor as C
from repro.kernels import dispatch as K
from repro.kernels import ops, ref


def _time(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6, out


def main():
    rows = []
    R, C2 = 64, 4096
    d = R * C2
    key = jax.random.PRNGKey(0)
    z = jax.random.normal(key, (R, C2))
    e = jnp.zeros((R, C2))

    # --- EF-compress: fused single-pass vs unfused jnp pipeline ------------
    us, out = _time(ops.ef_compress, z, e)
    rows.append(("kernel_ef_compress_64x4096", us,
                 f"bytes/param={12.0 + 1/8:.2f}"))
    p2, s2, e2 = ref.ef_compress_ref(z, e)
    assert bool((out[0] == p2).all())
    us, _ = _time(jax.jit(lambda z, e: ref.ef_compress_ref(z, e)), z, e)
    rows.append(("jnp_ef_compress_ref_64x4096", us,
                 f"bytes/param={40.0 + 1/8:.2f}"))

    # fused two-pass (tensor granularity) vs compressor on a real comm view
    lo = C.make_layout((d,), None, 8)
    zv = C.to_view(z.reshape(-1), lo)
    ev = jnp.zeros_like(zv)
    mask = C.pad_mask(lo)
    us, kout = _time(jax.jit(
        lambda a, b: K.ef_compress_view(a, b, lo, "tensor")), zv, ev)
    rows.append(("fused_ef_compress_view_tensor", us,
                 f"bytes/param={20.0 + 1/8:.2f}"))
    us, jout = _time(jax.jit(
        lambda a, b: C.ef_compress(a + b, lo, "tensor", mask)), zv, ev)
    rows.append(("unfused_ef_compress_view_tensor", us,
                 f"bytes/param={40.0 + 1/8:.2f}"))
    assert bool((kout[0] == jout[0]).all())  # identical wire bytes

    # --- decompress --------------------------------------------------------
    us, _ = _time(ops.decompress, out[0], out[1])
    rows.append(("kernel_decompress_64x4096", us,
                 f"bytes/param={4.0 + 1/8:.2f}"))

    # --- local half-step: fused kernel vs unfused three-sweep chain --------
    g = jax.random.normal(key, (R, C2))
    m = jnp.zeros_like(g)
    u = jnp.zeros_like(g)
    v = jnp.ones_like(g)
    us, _ = _time(lambda *a: ops.fused_local_step(*a, 0.01), g, m, u, v)
    rows.append(("kernel_fused_local_step_64x4096", us,
                 "bytes/param=28.00"))

    def unfused_step(g, m, u, v):
        mh = 0.9 * m + 0.1 * g
        delta = 0.01 * mh / jnp.sqrt(v + 1e-8)
        return mh, u + 0.01 * mh, delta

    us, _ = _time(jax.jit(unfused_step), g, m, u, v)
    rows.append(("jnp_local_step_64x4096", us, "bytes/param=40.00"))

    # wire bytes per synced param (comm accounting, Fig. 3/4 feed)
    rows.append(("compressed_wire_bits_per_param", 0.0,
                 f"bits={8.0 * C.compressed_bytes(lo, 'tensor') / d:.3f}"))
    for r in rows:
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
    return rows


if __name__ == "__main__":
    main()
