"""Hardware constants for the roofline + throughput models."""

# TPU v5e target (roofline terms)
TPU_PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
TPU_HBM_BW = 819e9               # bytes/s per chip
TPU_ICI_BW = 50e9                # bytes/s per link (intra-pod)
TPU_DCI_BW = 6.25e9              # bytes/s per chip across pods (slow
                                 # data-center links; ~order below ICI —
                                 # why the hierarchical AllReduce exists)

# The paper's clusters (Fig. 3 reproduction)
V100_FP16_FLOPS = 112e12
ETHERNET_BW = 2.7e9 / 8          # 2.7 Gb/s effective -> bytes/s
INFINIBAND_BW = 100e9 / 8 * 0.9  # ~100 Gb/s EDR, 90% efficiency
ETHERNET_LATENCY = 50e-6         # per collective round (alpha)
INFINIBAND_LATENCY = 5e-6

# Fraction of a step's compute that is backward pass — the window the
# readiness-ordered (reverse_backward) bucket issue can hide exchange
# traffic behind: a unit's collectives launch as soon as its member
# leaves' accumulated gradients are final, while the rest of the last
# microbatch's backward is still running. ~2 matmuls backward per 1
# forward for transformer blocks.
BACKWARD_FRACTION = 2.0 / 3.0

# paper Table 3: measured per-step compute (ms) on V100s, by cluster size
PAPER_COMPUTE_MS = {
    # task: {gpus: ms}
    "bert-base": {16: 941, 32: 490, 64: 263, 128: 162},
    "bert-large": {16: 1840, 32: 970, 64: 640, 128: 332},
    "imagenet": {16: 73, 32: 68, 64: 44, 128: 51},
}
PAPER_FIXED_MS = {  # "Others" row: init + compression fixed cost
    "bert-base": {16: 153, 32: 250, 64: 397, 128: 658},
    "bert-large": {16: 340, 32: 510, 64: 590, 128: 931},
    "imagenet": {16: 8, 32: 6, 64: 21, 128: 19},
}
