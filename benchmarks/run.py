"""Benchmark aggregator: one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines per benchmark, then the
suite summary. Individual benches are importable and runnable standalone:

    python -m benchmarks.bench_data_volume     # Fig. 4 + Fig. 5
    python -m benchmarks.bench_throughput      # Fig. 3
    python -m benchmarks.bench_convergence     # Fig. 2
    python -m benchmarks.bench_quality         # Tables 1-2
    python -m benchmarks.bench_fixed_cost      # appendix Table 3
    python -m benchmarks.bench_kernels         # Pallas kernel microbench
    python -m benchmarks.roofline              # deliverable (g)
"""
from __future__ import annotations

import os
import sys
import time
import traceback


def main() -> None:
    from benchmarks import (bench_convergence, bench_data_volume,
                            bench_fixed_cost, bench_kernels, bench_quality,
                            bench_throughput)
    suites = [
        ("fig4_data_volume", bench_data_volume.main),
        ("fig3_throughput", bench_throughput.main),
        ("fig2_convergence", bench_convergence.main),
        ("tables12_quality", bench_quality.main),
        ("table3_fixed_cost", bench_fixed_cost.main),
        ("kernels", bench_kernels.main),
    ]
    if os.path.exists("results/dryrun.jsonl"):
        from benchmarks import roofline
        suites.append(("roofline", roofline.main))

    all_rows = []
    failures = 0
    for name, fn in suites:
        print(f"\n===== {name} =====")
        t0 = time.time()
        try:
            rows = fn() or []
            all_rows.extend(rows)
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
        print(f"===== {name} done in {time.time()-t0:.1f}s =====")

    print("\nname,us_per_call,derived")
    for n, us, d in all_rows:
        print(f"{n},{us:.1f},{d}")
    if failures:
        print(f"\n{failures} benchmark suites FAILED", file=sys.stderr)
        sys.exit(1)
    print(f"\nAll {len(suites)} benchmark suites completed.")


if __name__ == "__main__":
    main()
