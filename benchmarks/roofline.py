"""Roofline analysis from the compiled dry-run artifacts (deliverable g).

Reads results/dryrun.jsonl (produced by launch/dryrun.py) and derives, per
(arch x shape x mesh):

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

plus MODEL_FLOPS = 6*N*D (train, active params for MoE) or 2*N*D
(prefill/decode) and the useful-compute ratio MODEL_FLOPS / HLO_FLOPs.

Caveat recorded in EXPERIMENTS.md: the CPU XLA backend upcasts bf16
collective payloads to f32 in the lowered HLO, so the collective term is an
upper bound (~2x) for the bf16-wire fraction of traffic; uint8 (compressed)
traffic is measured exactly.
"""
from __future__ import annotations

import json
import sys
from collections import defaultdict

from benchmarks import hw
from repro.configs import get
from repro.launch.shapes import SHAPES


def analytic_terms(arch, shape, chips):
    """Napkin-math compute and HBM-traffic terms per device per step.

    XLA's HLO cost analysis counts while-loop (lax.scan) bodies once, so
    the layer/microbatch-scanned model under-reports ~L x mb fold; these
    analytic terms are the trustworthy roofline inputs (the measured HLO
    numbers are reported alongside as a lower bound; collectives ARE
    trip-count-corrected in the parser).
    """
    spec = get(arch)
    cfg = spec.config
    sh = SHAPES[shape]
    n_active = cfg.active_param_count()
    n_total = cfg.param_count()
    tp = 16
    p_shard = 2.0 * n_total / tp            # bf16 weight bytes per chip
    d = cfg.d_model
    L = cfg.n_layers + cfg.enc_layers
    if sh.kind == "train":
        tokens = sh.global_batch * sh.seq
        flops = 6.0 * n_active * tokens / chips
        workers = chips // tp
        tok_dev = tokens / chips
        mb = max(1, (sh.global_batch // workers) // 2)
        # weights swept fwd+bwd per microbatch + grads + optimizer states
        wbytes = p_shard * (2.0 * mb + 2.0)
        obytes = p_shard * 7.0              # m,u,err,anchor r/w + v
        act = tok_dev * d * 2.0 * L * 4.0   # remat'd layer boundaries
        logits = tok_dev * (cfg.padded_vocab / tp) * 2.0 * 3.0
        mem = wbytes + obytes + act + logits
    elif sh.kind == "prefill":
        tokens = sh.global_batch * sh.seq
        flops = 2.0 * n_active * tokens / chips
        tok_dev = tokens / chips
        act = tok_dev * d * 2.0 * L * 2.0
        kv_write = tok_dev * d * 2.0 * 2.0 * L / 8
        # blockwise attention re-reads KV per query block
        attn = (sh.seq / 512.0) * tok_dev * d * 2.0 / 4.0
        mem = p_shard + act + kv_write + attn
    else:  # decode one token
        flops = 2.0 * n_active * sh.global_batch / chips
        # weights read once + full KV/state cache read
        if cfg.family in ("ssm", "hybrid"):
            cache = (cfg.n_layers * sh.global_batch * cfg.ssm_heads
                     * cfg.ssm_head_dim * cfg.ssm_state * 4.0) / chips
        elif cfg.attn_type == "mla":
            cache = (cfg.n_layers * sh.global_batch * sh.seq
                     * (cfg.kv_lora_rank + cfg.mla_qk_rope) * 2.0) / chips
        else:
            cache = (2.0 * cfg.n_layers * sh.global_batch * sh.seq
                     * cfg.n_kv * cfg.hd * 2.0) / chips
        mem = p_shard + cache
    return flops, mem


def analyze(path="results/dryrun.jsonl", mesh_filter="16x16"):
    recs = {}
    for line in open(path):
        r = json.loads(line)
        if r.get("status") != "ok" or r.get("mesh") != mesh_filter:
            continue
        recs[(r["arch"], r["shape"])] = r  # keep the latest per pair

    rows = []
    for (arch, shape), r in sorted(recs.items()):
        chips = 256 if mesh_filter == "16x16" else 512
        flops_a, mem_a = analytic_terms(arch, shape, chips)
        t_c = flops_a / hw.TPU_PEAK_FLOPS
        t_m = mem_a / hw.TPU_HBM_BW
        coll = sum(r["collective_bytes"].values())
        # per-level split when the dry-run classified replica groups by pod
        # crossing (multi-pod meshes): intra-pod traffic rides the fast ICI,
        # inter-pod the slow DCI — the hierarchical AllReduce moves bytes
        # from the second bucket into the first
        intra, inter = r.get("intrapod_bytes"), r.get("interpod_bytes")
        if intra is not None and inter is not None:
            # unattributed traffic (e.g. collective-permutes without
            # replica groups) is charged at ICI speed so the split never
            # under-counts the flat fallback's total
            intra += r.get("unattributed_collective_bytes") or 0.0
            t_x_intra = intra / hw.TPU_ICI_BW
            t_x_inter = inter / hw.TPU_DCI_BW
            t_x = t_x_intra + t_x_inter
        else:
            t_x_intra, t_x_inter = coll / hw.TPU_ICI_BW, 0.0
            t_x = t_x_intra
        dom = max(("compute", t_c), ("memory", t_m),
                  ("collective", t_x), key=lambda kv: kv[1])[0]
        ratio = flops_a / max(r["flops_per_device"], 1.0)
        bound = max(t_c, t_m, t_x)
        mfu_bound = t_c / bound if bound else 0.0
        rows.append(dict(arch=arch, shape=shape, t_c=t_c, t_m=t_m, t_x=t_x,
                         t_x_intra=t_x_intra, t_x_inter=t_x_inter,
                         t_c_hlo=r["flops_per_device"] / hw.TPU_PEAK_FLOPS,
                         t_m_hlo=r["bytes_per_device"] / hw.TPU_HBM_BW,
                         dominant=dom, model_flops=flops_a, ratio=ratio,
                         mfu_bound=mfu_bound, rec=r))
    return rows


_SUGGEST = {
    "compute": "compute-bound: raise MXU utilization (larger micro-batch, "
               "fuse small ops); already near the best regime",
    "memory": "HBM-bound: increase arithmetic intensity — bigger "
              "micro-batches, fewer remat sweeps, fuse optimizer "
              "elementwise chain (kernels/fused_adam)",
    "collective": "collective-bound: cut wire bytes (0/1 Adam compressed "
                  "sync already does; next: overlap collectives with "
                  "compute, hierarchical pod-local reduction)",
}


def main():
    mesh = sys.argv[1] if len(sys.argv) > 1 else "16x16"
    rows = analyze(mesh_filter=mesh)
    print(f"# Roofline terms per (arch x shape), mesh {mesh} "
          f"(seconds/step/device; compute/memory analytic, collective "
          f"trip-count-corrected from HLO)")
    print("arch,shape,compute_s,memory_s,collective_s,collective_intra_s,"
          "collective_inter_s,dominant,model_vs_hlo_flops,mfu_upper_bound")
    for r in rows:
        print(f"{r['arch']},{r['shape']},{r['t_c']:.3e},{r['t_m']:.3e},"
              f"{r['t_x']:.3e},{r['t_x_intra']:.3e},{r['t_x_inter']:.3e},"
              f"{r['dominant']},{r['ratio']:.3f},"
              f"{r['mfu_bound']:.3f}")
    by_dom = defaultdict(list)
    for r in rows:
        by_dom[r["dominant"]].append(f"{r['arch']}x{r['shape']}")
    print()
    for dom, items in by_dom.items():
        print(f"# {dom}-bound ({len(items)}): {_SUGGEST[dom]}")
    return [("roofline_pairs_analyzed", 0.0, f"n={len(rows)};mesh={mesh}")]


if __name__ == "__main__":
    main()
