"""Attention substrate: GQA, MLA (DeepSeek-V2), sliding-window, cross-attn,
blockwise (flash-style) execution for long sequences, and KV-cache decode.

Layout conventions: activations (B, S, D); per-head tensors (B, S, H, hd);
KV caches (B, S_max, K, hd). Head axes are tensor-parallel sharded when they
divide the TP degree (see layers.model_dim_spec).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import rope as R
from repro.models.layers import PD, maybe_shard, model_dim_spec

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Templates
# ---------------------------------------------------------------------------

def gqa_template(d, n_heads, n_kv, head_dim, bias=False, stack=None):
    hs = model_dim_spec(n_heads * head_dim)
    ks = model_dim_spec(n_kv * head_dim)

    def st(shape, spec):
        if stack is None:
            return PD(shape, spec=spec)
        return PD((stack, *shape), spec=(None, *spec))

    t = {
        "wq": st((d, n_heads * head_dim), (None, hs)),
        "wk": st((d, n_kv * head_dim), (None, ks)),
        "wv": st((d, n_kv * head_dim), (None, ks)),
        "wo": st((n_heads * head_dim, d), (hs, None)),
    }
    if bias:
        t["bq"] = st((n_heads * head_dim,), (hs,))
        t["bk"] = st((n_kv * head_dim,), (ks,))
        t["bv"] = st((n_kv * head_dim,), (ks,))
        for k in ("bq", "bk", "bv"):
            t[k] = dataclasses.replace(t[k], init="zeros")
    return t


def mla_template(d, n_heads, kv_lora, qk_nope, qk_rope, v_dim, stack=None):
    hq = model_dim_spec(n_heads * (qk_nope + qk_rope))
    hu = model_dim_spec(n_heads * qk_nope)
    hv = model_dim_spec(n_heads * v_dim)

    def st(shape, spec):
        if stack is None:
            return PD(shape, spec=spec)
        return PD((stack, *shape), spec=(None, *spec))

    return {
        "wq": st((d, n_heads * (qk_nope + qk_rope)), (None, hq)),
        "w_dkv": st((d, kv_lora + qk_rope), (None, None)),
        "kv_norm": st((kv_lora,), (None,)),
        "w_uk": st((kv_lora, n_heads * qk_nope), (None, hu)),
        "w_uv": st((kv_lora, n_heads * v_dim), (None, hv)),
        "wo": st((n_heads * v_dim, d), (hv, None)),
    }


# ---------------------------------------------------------------------------
# Masks & core attention
# ---------------------------------------------------------------------------

_PAD_SENTINEL = 2 ** 29  # k positions >= this are padding (blockwise tails)


def _mask_bias(q_pos, k_pos, kind: str, window: int = 0, kv_len=None):
    """Additive mask (…, Sq, Sk). kind: causal|sliding|bidir|decode."""
    valid = (k_pos < _PAD_SENTINEL)[..., None, :]
    if kind == "bidir":
        ok = jnp.broadcast_to(valid,
                              (q_pos.shape[-1], k_pos.shape[-1]))
        return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
    rel = q_pos[..., :, None] - k_pos[..., None, :]
    ok = jnp.logical_and(rel >= 0, valid)
    if kind == "sliding" and window:
        ok = jnp.logical_and(ok, rel < window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def dot_attn(q, k, v, bias):
    """q (B,Sq,H,hd), k (B,Sk,K,hd), v (B,Sk,K,dv), bias (B?,Sq,Sk)."""
    B, Sq, H, hd = q.shape
    K, dv = k.shape[2], v.shape[3]
    g = H // K
    qg = q.reshape(B, Sq, K, g, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    s = s / jnp.sqrt(hd).astype(jnp.float32)
    s = s + bias[..., None, None, :, :] if bias.ndim == 3 else s + bias
    w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return o.reshape(B, Sq, H, dv)


def blockwise_attn(q, k, v, q_pos, k_pos, kind, window=0, bq=512, bk=1024):
    """Flash-style attention in pure JAX: outer map over query blocks, inner
    scan over KV blocks with an online softmax. Memory is O(bq·bk) per step
    regardless of sequence length — this is the memory-bounded execution path
    for prefill_32k / long_500k. (A Pallas port would fuse this on TPU; the
    paper's contribution is optimizer-side so we keep attention pure JAX.)
    """
    B, Sq, H, hd = q.shape
    Sk, K, dv = k.shape[1], k.shape[2], v.shape[3]
    g = H // K
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    nq, nk = -(-Sq // bq), -(-Sk // bk)
    # pad to block multiples
    qp = jnp.pad(q, ((0, 0), (0, nq * bq - Sq), (0, 0), (0, 0)))
    qposp = jnp.pad(q_pos, (0, nq * bq - Sq), constant_values=-1)
    kp = jnp.pad(k, ((0, 0), (0, nk * bk - Sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * bk - Sk), (0, 0), (0, 0)))
    kposp = jnp.pad(k_pos, (0, nk * bk - Sk), constant_values=2**29)
    scale = 1.0 / jnp.sqrt(hd)

    kb = kp.reshape(B, nk, bk, K, hd)
    vb = vp.reshape(B, nk, bk, K, dv)
    kposb = kposp.reshape(nk, bk)

    def one_qblock(args):
        qi, qpos_i = args                      # (B,bq,H,hd), (bq,)
        qg = qi.reshape(B, bq, K, g, hd)

        def inner(carry, blk):
            acc, mx, den = carry
            kj, vj, kpos_j = blk
            s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kj).astype(jnp.float32)
            s = s * scale
            bias = _mask_bias(qpos_i, kpos_j, kind, window)
            s = s + bias[None, None, None]
            new_mx = jnp.maximum(mx, s.max(axis=-1))
            p = jnp.exp(s - new_mx[..., None])
            corr = jnp.exp(mx - new_mx)
            den = den * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vj.dtype), vj)
            acc = acc * corr[..., None].astype(acc.dtype) + pv.astype(jnp.float32)
            return (acc, new_mx, den), None

        acc0 = jnp.zeros((B, K, g, bq, dv), jnp.float32)
        mx0 = jnp.full((B, K, g, bq), NEG_INF, jnp.float32)
        den0 = jnp.zeros((B, K, g, bq), jnp.float32)
        (acc, mx, den), _ = jax.lax.scan(
            inner, (acc0, mx0, den0),
            (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), kposb))
        o = acc / jnp.maximum(den[..., None], 1e-30)
        o = jnp.moveaxis(o, 3, 1).reshape(B, bq, K * g, dv)  # (B,bq,H,dv)
        return o.astype(q.dtype)

    qblocks = jnp.moveaxis(qp.reshape(B, nq, bq, H, hd), 1, 0)
    qposblocks = qposp.reshape(nq, bq)
    out = jax.lax.map(one_qblock, (qblocks, qposblocks))
    out = jnp.moveaxis(out, 0, 1).reshape(B, nq * bq, H, dv)
    return out[:, :Sq]


def decode_attn(q, k_cache, v_cache, pos, kind="causal", window=0,
                ring=False):
    """Single-token decode against a (B, S, K, hd) cache. O(S) per token.

    ``ring=True``: the cache is a ring buffer of size S == window holding
    the last S positions (keys already rotary-encoded at their absolute
    positions, so slot order is irrelevant); a slot is valid once written.
    """
    B, _, H, hd = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    kpos = jnp.arange(S, dtype=jnp.int32)
    if ring:
        ok = jnp.logical_or(kpos <= pos, pos >= S)
    else:
        ok = kpos <= pos
        if kind == "sliding" and window:
            ok = jnp.logical_and(ok, kpos > pos - window)
    bias = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)  # (S,)
    g = H // K
    qg = q.reshape(B, 1, K, g, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache).astype(jnp.float32)
    s = s / jnp.sqrt(hd) + bias[None, None, None, None, :]
    w = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v_cache)
    return o.reshape(B, 1, H, hd)


# ---------------------------------------------------------------------------
# GQA block forward
# ---------------------------------------------------------------------------

def gqa_forward(p, cfg, x, positions, *, kind="causal", window=0,
                cache=None, cache_pos=None, kv_override=None,
                use_blockwise=False):
    """Full GQA attention. Returns (out, new_cache_kv or None).

    cache: optional dict {"k","v"} (B, Smax, K, hd); cache_pos: scalar write
    position (decode) or 0 (prefill fills [0, S)).
    kv_override: (k, v) computed elsewhere (cross-attention).
    """
    B, S, _ = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(B, S, H, hd)
    if kv_override is None:
        k = x @ p["wk"]
        v = x @ p["wv"]
        if "bk" in p:
            k, v = k + p["bk"], v + p["bv"]
        k = k.reshape(B, S, K, hd)
        v = v.reshape(B, S, K, hd)
        if cfg.rope != "none":
            sections = cfg.mrope_sections if cfg.rope == "mrope" else None
            frac = cfg.rope_fraction
            q = R.apply_rope(q, positions, cfg.rope_theta, frac, sections)
            k = R.apply_rope(k, positions, cfg.rope_theta, frac, sections)
    else:
        k, v = kv_override
        if cfg.rope != "none" and kv_override is None:
            pass

    q = maybe_shard(q, None, None, "model", None)
    new_kv = None
    if cache is not None:
        if S == 1 and cache_pos is not None:
            ring = cache["k"].shape[1] == window and window > 0 \
                and kind == "sliding"
            wpos = cache_pos % window if ring else cache_pos
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, wpos, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, wpos, 0, 0))
            o = decode_attn(q, ck, cv, cache_pos, kind, window, ring=ring)
            new_kv = {"k": ck, "v": cv}
            return o.reshape(B, S, H * hd) @ p["wo"], new_kv
        # prefill: write [0, S)
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
        new_kv = {"k": ck, "v": cv}

    qpos = positions[0] if positions.ndim == 3 else positions
    qpos0 = qpos[0] if qpos.ndim == 2 else qpos
    kpos0 = qpos0  # self-attention
    if kv_override is not None:
        kpos0 = jnp.arange(k.shape[1], dtype=jnp.int32)
        kind = "bidir"
    if use_blockwise:
        o = blockwise_attn(q, k, v, qpos0, kpos0, kind, window)
    else:
        bias = _mask_bias(qpos0, kpos0, kind, window)
        o = dot_attn(q, k, v, bias)
    o = maybe_shard(o, None, None, "model", None)
    out = o.reshape(B, S, H * hd) @ p["wo"]
    return out, new_kv


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2) forward
# ---------------------------------------------------------------------------

def mla_forward(p, cfg, x, positions, *, cache=None, cache_pos=None,
                use_blockwise=False):
    """Multi-head Latent Attention. Cache holds the *compressed* KV:
    {"ckv": (B, Smax, r), "kr": (B, Smax, dr)} — the MLA memory win.
    Decode uses the absorbed-matmul form (scores in latent space).
    """
    from repro.models.layers import rms_norm
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv, r = (cfg.mla_qk_nope, cfg.mla_qk_rope, cfg.mla_v_dim,
                     cfg.kv_lora_rank)
    q = (x @ p["wq"]).reshape(B, S, H, dn + dr)
    qn, qr = q[..., :dn], q[..., dn:]
    dkv = x @ p["w_dkv"]
    ckv, kr = dkv[..., :r], dkv[..., r:]
    ckv = rms_norm(ckv, p["kv_norm"])
    qr = R.apply_rope(qr, positions, cfg.rope_theta)
    kr = R.apply_rope(kr[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]

    scale = 1.0 / jnp.sqrt(dn + dr)

    if cache is not None and S == 1 and cache_pos is not None:
        cc = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, cache_pos, 0))
        ckr = jax.lax.dynamic_update_slice(
            cache["kr"], kr.astype(cache["kr"].dtype), (0, cache_pos, 0))
        # absorbed decode: q_lat = qn @ w_uk  (per head)
        wuk = p["w_uk"].reshape(r, H, dn)
        qlat = jnp.einsum("bqhd,rhd->bqhr", qn, wuk)       # (B,1,H,r)
        s = (jnp.einsum("bqhr,bsr->bhqs", qlat, cc)
             + jnp.einsum("bqhd,bsd->bhqs", qr, ckr)).astype(jnp.float32)
        s = s * scale
        kpos = jnp.arange(cc.shape[1], dtype=jnp.int32)
        bias = jnp.where(kpos <= cache_pos, 0.0, NEG_INF)
        s = s + bias[None, None, None, :]
        w = jax.nn.softmax(s, axis=-1).astype(cc.dtype)
        ctx = jnp.einsum("bhqs,bsr->bqhr", w, cc)          # latent context
        wuv = p["w_uv"].reshape(r, H, dv)
        o = jnp.einsum("bqhr,rhd->bqhd", ctx, wuv)
        out = o.reshape(B, 1, H * dv) @ p["wo"]
        return out, {"ckv": cc, "kr": ckr}

    new_cache = None
    if cache is not None:  # prefill
        cc = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, 0, 0))
        ckr = jax.lax.dynamic_update_slice(
            cache["kr"], kr.astype(cache["kr"].dtype), (0, 0, 0))
        new_cache = {"ckv": cc, "kr": ckr}

    # train / prefill: expand the latent to per-head K and V
    kn = jnp.einsum("bsr,rhd->bshd", ckv, p["w_uk"].reshape(r, H, dn))
    v = jnp.einsum("bsr,rhd->bshd", ckv, p["w_uv"].reshape(r, H, dv))
    k = jnp.concatenate([kn, jnp.broadcast_to(kr[:, :, None, :],
                                              (B, S, H, dr))], axis=-1)
    qfull = jnp.concatenate([qn, qr], axis=-1)
    qpos = positions[0] if positions.ndim == 3 else positions
    qpos0 = qpos[0] if qpos.ndim == 2 else qpos
    if use_blockwise:
        o = blockwise_attn(qfull, k, v, qpos0, qpos0, "causal")
    else:
        bias = _mask_bias(qpos0, qpos0, "causal")
        o = dot_attn(qfull, k, v, bias)
    out = o.reshape(B, S, H * dv) @ p["wo"]
    return out, new_cache
