"""Rotary position embeddings: standard, partial (ChatGLM), M-RoPE (Qwen2-VL).

All variants operate on ``x: (B, S, H, D)`` with ``positions`` describing the
token positions:

* standard / partial: positions (B, S) int32
* mrope: positions (3, B, S) int32 — temporal / height / width streams, with
  head-dim frequency bands split by ``sections`` (Qwen2-VL §3.1).
"""
from __future__ import annotations

import jax.numpy as jnp


def _freqs(dim: int, theta: float, dtype=jnp.float32):
    # dim = number of rotated pairs
    return 1.0 / (theta ** (jnp.arange(0, dim, dtype=dtype) / dim))


def _rotate(x, cos, sin):
    # x: (..., 2k) pairs interleaved as [x1, x2] halves
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1)


def apply_rope(x, positions, theta=10000.0, fraction=1.0, sections=None):
    """Apply rotary embedding.

    Args:
      x: (B, S, H, D)
      positions: (B, S) or (3, B, S) for mrope
      fraction: fraction of head dim rotated (ChatGLM uses 0.5)
      sections: m-rope head-dim band split (pairs per stream), e.g. (16,24,24)
    """
    d = x.shape[-1]
    rot = int(d * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    inv = _freqs(half, theta)

    if sections is not None:
        # M-RoPE: frequency bands alternate between t/h/w position streams.
        assert positions.ndim == 3, "mrope needs (3, B, S) positions"
        assert sum(sections) == half, (sections, half)
        band = jnp.concatenate([
            jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)])
        # pos_per_band: (B, S, half)
        pos = jnp.take(positions, band, axis=0)          # (half, B, S)
        pos = jnp.moveaxis(pos, 0, -1).astype(jnp.float32)
        ang = pos * inv[None, None, :]
    else:
        if positions.ndim == 3:
            positions = positions[0]
        ang = positions.astype(jnp.float32)[..., None] * inv[None, None, :]

    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)    # (B, S, 1, half)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([_rotate(xr, cos, sin), xp], axis=-1)


def text_positions(batch: int, seq: int, offset=0):
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    return jnp.broadcast_to(pos, (batch, seq))


def mrope_positions(batch: int, seq: int, n_vision: int, grid_h: int,
                    offset=0):
    """Qwen2-VL style positions: a (t,h,w) grid for the vision prefix, then
    sequential text positions for the remainder. ``offset`` supports decode.

    Returns (3, B, S) int32.
    """
    idx = jnp.arange(seq, dtype=jnp.int32) + offset
    is_vis = idx < n_vision
    vis_idx = jnp.minimum(idx, max(n_vision - 1, 0))
    h = vis_idx // max(grid_h, 1)
    w = vis_idx % max(grid_h, 1)
    # text positions continue after the max vision position
    base = (n_vision + grid_h - 1) // max(grid_h, 1) if n_vision else 0
    text = base + (idx - n_vision)
    t = jnp.where(is_vis, 0, text)
    hh = jnp.where(is_vis, h, text)
    ww = jnp.where(is_vis, w, text)
    pos = jnp.stack([t, hh, ww])                       # (3, S)
    return jnp.broadcast_to(pos[:, None, :], (3, batch, seq))
