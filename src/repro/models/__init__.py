"""Model substrate: composable transformer/SSM/MoE definitions in pure JAX.

The modality frontends for the audio/VLM architectures are stubs per the
assignment: ``input_specs`` provides precomputed frame/patch embeddings of
the right shape (see launch/dryrun.py); the language/decoder backbone that
consumes them is fully implemented here.
"""
from repro.models.config import ModelConfig
from repro.models import transformer
from repro.models import layers

__all__ = ["ModelConfig", "transformer", "layers"]
