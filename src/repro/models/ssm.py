"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) layer.

Train/prefill use the chunked SSD algorithm (quadratic within a chunk,
linear across chunks); decode uses the O(1) recurrence with a constant-size
state — this is the sub-quadratic path that makes long_500k decode feasible
for the SSM/hybrid architectures.

State layout: h (B, H, P, N) with H = heads, P = head dim, N = ssm state.
Conv state: last K-1 raw channel inputs for each of the x/B/C streams.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.layers import PD, maybe_shard, model_dim_spec, rms_norm


def ssm_template(d, d_inner, n_heads, head_dim, n_state, n_groups, conv_k,
                 stack=None):
    ins = model_dim_spec(d_inner)
    gn = n_groups * n_state

    def st(shape, spec):
        if stack is None:
            return PD(shape, spec=spec)
        return PD((stack, *shape), spec=(None, *spec))

    def stz(shape, spec, init="zeros"):
        pd = st(shape, spec)
        import dataclasses
        return dataclasses.replace(pd, init=init)

    return {
        "w_z": st((d, d_inner), (None, ins)),
        "w_x": st((d, d_inner), (None, ins)),
        "w_B": st((d, gn), (None, None)),
        "w_C": st((d, gn), (None, None)),
        "w_dt": st((d, n_heads), (None, None)),
        "conv_x": st((conv_k, d_inner), (None, ins)),
        "conv_B": st((conv_k, gn), (None, None)),
        "conv_C": st((conv_k, gn), (None, None)),
        "A_log": stz((n_heads,), (None,), "zeros"),
        "D": stz((n_heads,), (None,), "ones"),
        "dt_bias": stz((n_heads,), (None,), "zeros"),
        "norm": stz((d_inner,), (ins,), "zeros"),
        "w_out": st((d_inner, d), (ins, None)),
    }


def _causal_conv(x, w):
    """Depthwise causal conv: x (B, L, C), w (K, C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(K))
    return out


def _conv_step(x_t, conv_state, w):
    """x_t (B, C); conv_state (B, K-1, C). Returns (y, new_state)."""
    cat = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", cat, w)
    return y, cat[:, 1:]


def ssd_chunked(xh, dt, A, Bh, Ch, chunk, h0=None):
    """Chunked SSD scan.

    xh (B,L,H,P), dt (B,L,H), A (H,), Bh/Ch (B,L,H,N).
    Returns (y (B,L,H,P), final state (B,H,P,N)).
    """
    B, L, H, P = xh.shape
    N = Bh.shape[-1]
    assert L % chunk == 0, (L, chunk)
    nc, Q = L // chunk, chunk

    dA = dt * A[None, None, :]                        # (B,L,H) negatives
    dtx = xh * dt[..., None]                          # input scaled by dt
    resh = lambda t: t.reshape(B, nc, Q, *t.shape[2:])
    dA_c, dtx_c = resh(dA), resh(dtx)
    B_c, C_c = resh(Bh), resh(Ch)

    cs = jnp.cumsum(dA_c, axis=2)                     # (B,nc,Q,H)

    # --- intra-chunk (diagonal blocks) ---------------------------------
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]    # (B,nc,Q,Q,H) i-j
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    Lmat = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    G = jnp.einsum("bcqhn,bcshn->bcqsh", C_c, B_c)
    M = G * Lmat
    y_diag = jnp.einsum("bcqsh,bcshp->bcqhp", M, dtx_c)

    # --- per-chunk input states ----------------------------------------
    decay_states = jnp.exp(cs[:, :, -1:, :] - cs)        # (B,nc,Q,H)
    states = jnp.einsum("bcshn,bcsh,bcshp->bchpn", B_c, decay_states, dtx_c)

    # --- inter-chunk recurrence ----------------------------------------
    chunk_decay = jnp.exp(cs[:, :, -1, :])               # (B,nc,H)

    def scan_fn(h, inp):
        st, cd = inp                                     # (B,H,P,N),(B,H)
        h_out = h
        h = h * cd[:, :, None, None] + st
        return h, h_out

    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)
    hT, h_prev = jax.lax.scan(
        scan_fn, h0.astype(jnp.float32),
        (jnp.moveaxis(states.astype(jnp.float32), 1, 0),
         jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                  # (B,nc,H,P,N)

    # --- off-diagonal contribution --------------------------------------
    state_decay = jnp.exp(cs)                            # (B,nc,Q,H)
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", C_c,
                       h_prev.astype(xh.dtype), state_decay)

    y = (y_diag + y_off).reshape(B, L, H, P)
    return y, hT


def ssm_forward(p, cfg, x, *, state=None, decode=False):
    """Mamba2 block. x (B, L, d). If decode, L == 1 and ``state`` is the
    dict {"h", "conv_x", "conv_B", "conv_C"}; returns (out, new_state).
    For train (state=None, decode=False) returns (out, None); for prefill
    pass a zero state to receive the final state for the cache.
    """
    Bsz, L, d = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    G = cfg.ssm_groups
    d_in = H * P

    z = x @ p["w_z"]
    xs = x @ p["w_x"]
    Bp = x @ p["w_B"]
    Cp = x @ p["w_C"]
    dt = (x @ p["w_dt"]).astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    new_state = None
    if decode:
        assert L == 1 and state is not None
        cx, scx = _conv_step(xs[:, 0], state["conv_x"], p["conv_x"])
        cB, scB = _conv_step(Bp[:, 0], state["conv_B"], p["conv_B"])
        cC, scC = _conv_step(Cp[:, 0], state["conv_C"], p["conv_C"])
        xs, Bp, Cp = (jax.nn.silu(cx)[:, None], jax.nn.silu(cB)[:, None],
                      jax.nn.silu(cC)[:, None])
        dts = jax.nn.softplus(dt[:, 0] + p["dt_bias"][None, :])   # (B,H)
        xh = xs.reshape(Bsz, H, P)
        Bh = _expand_groups(Bp.reshape(Bsz, 1, G, N), H)[:, 0]    # (B,H,N)
        Ch = _expand_groups(Cp.reshape(Bsz, 1, G, N), H)[:, 0]
        dAe = jnp.exp(dts * A[None, :])                           # (B,H)
        h = state["h"].astype(jnp.float32)
        h = (h * dAe[:, :, None, None]
             + jnp.einsum("bhp,bhn,bh->bhpn", xh.astype(jnp.float32),
                          Bh.astype(jnp.float32), dts))
        y = jnp.einsum("bhpn,bhn->bhp", h, Ch.astype(jnp.float32))
        y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
        y = y.reshape(Bsz, 1, d_in).astype(x.dtype)
        new_state = {"h": h.astype(state["h"].dtype), "conv_x": scx,
                     "conv_B": scB, "conv_C": scC}
    else:
        xs = jax.nn.silu(_causal_conv(xs, p["conv_x"]))
        Bp = jax.nn.silu(_causal_conv(Bp, p["conv_B"]))
        Cp = jax.nn.silu(_causal_conv(Cp, p["conv_C"]))
        dts = jax.nn.softplus(dt + p["dt_bias"][None, None, :])
        xh = xs.reshape(Bsz, L, H, P)
        Bh = _expand_groups(Bp.reshape(Bsz, L, G, N), H)
        Ch = _expand_groups(Cp.reshape(Bsz, L, G, N), H)
        h0 = state["h"].astype(jnp.float32) if state is not None else None
        y, hT = ssd_chunked(xh.astype(jnp.float32), dts, A,
                            Bh.astype(jnp.float32), Ch.astype(jnp.float32),
                            cfg.ssm_chunk, h0)
        y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh
        y = y.reshape(Bsz, L, d_in).astype(x.dtype)
        if state is not None:
            K = p["conv_x"].shape[0]
            new_state = {
                "h": hT.astype(state["h"].dtype),
                "conv_x": (x @ p["w_x"])[:, -(K - 1):, :],
                "conv_B": (x @ p["w_B"])[:, -(K - 1):, :],
                "conv_C": (x @ p["w_C"])[:, -(K - 1):, :],
            }

    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["norm"])
    y = maybe_shard(y, None, None, "model")
    return y @ p["w_out"], new_state


def _expand_groups(b, n_heads):
    """(B, L, G, N) -> (B, L, H, N) by repeating groups."""
    B, L, G, N = b.shape
    rep = n_heads // G
    return jnp.repeat(b, rep, axis=2)


def init_ssm_state(cfg, batch, dtype=jnp.float32):
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    G = cfg.ssm_groups
    K = cfg.conv_kernel
    d_in = H * P
    return {
        "h": jnp.zeros((batch, H, P, N), dtype),
        "conv_x": jnp.zeros((batch, K - 1, d_in), dtype),
        "conv_B": jnp.zeros((batch, K - 1, G * N), dtype),
        "conv_C": jnp.zeros((batch, K - 1, G * N), dtype),
    }
