"""Unified model configuration driving every assigned architecture."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None

    # attention
    attn_type: str = "gqa"       # gqa | mla
    attn_bias: bool = False
    rope: str = "standard"       # none | standard | partial | mrope | learned
    rope_fraction: float = 1.0
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = ()
    sliding_window: int = 0      # >0 enables local attention
    global_every: int = 0        # gemma3: every k-th layer is global
    causal: bool = True          # False = bidirectional (BERT / encoders)

    # MLA (DeepSeek-V2)
    kv_lora_rank: int = 0
    mla_qk_nope: int = 128
    mla_qk_rope: int = 64
    mla_v_dim: int = 128

    # MoE
    n_experts: int = 0
    top_k: int = 1
    n_shared_experts: int = 0
    first_k_dense: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01

    # SSM (Mamba2) / hybrid (Zamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_chunk: int = 256
    conv_kernel: int = 4
    attn_every: int = 0          # zamba2: shared attn block every k layers

    # encoder-decoder (Whisper)
    enc_layers: int = 0
    enc_frames: int = 1500

    # VLM stub (Qwen2-VL)
    vision_tokens: int = 0
    vision_grid_h: int = 32

    # serving
    window_cache: bool = False   # sliding-window layers keep only `window`
                                 # KV slots (ring buffer); global layers a
                                 # compact stack — beyond-paper §Perf item

    # misc
    mlp_type: str = "swiglu"     # swiglu | gelu
    norm_type: str = "rmsnorm"   # rmsnorm | layernorm
    tie_embeddings: bool = False
    max_seq: int = 8192
    vocab_pad_multiple: int = 256
    param_dtype: object = jnp.float32
    compute_dtype: object = jnp.float32
    remat: bool = False
    blockwise_threshold: int = 8192   # use flash-style attn at/above this S
    citation: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab + m - 1) // m) * m

    @property
    def ssm_heads(self) -> int:
        return (self.ssm_expand * self.d_model) // self.ssm_head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_global_layers(self) -> int:
        if not self.global_every:
            return 0
        return self.n_layers // self.global_every

    @property
    def n_attn_apps(self) -> int:
        """Hybrid: how many times the shared attention block fires."""
        if not self.attn_every:
            return 0
        return self.n_layers // self.attn_every

    def param_count(self) -> float:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d, L = self.d_model, self.n_layers
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        total = emb
        if self.family in ("ssm", "hybrid"):
            din = self.d_inner
            gn = self.ssm_groups * self.ssm_state
            per = (2 * d * din + 2 * d * gn + d * self.ssm_heads
                   + din * d + self.conv_kernel * (din + 2 * gn))
            total += L * per
            if self.attn_every:
                hd = self.hd
                total += (2 * d * self.n_heads * hd
                          + 2 * d * self.n_kv * hd
                          + 3 * d * self.d_ff)
            return float(total)
        hd = self.hd
        if self.attn_type == "mla":
            attn = (d * self.n_heads * (self.mla_qk_nope + self.mla_qk_rope)
                    + d * (self.kv_lora_rank + self.mla_qk_rope)
                    + self.kv_lora_rank * self.n_heads
                    * (self.mla_qk_nope + self.mla_v_dim)
                    + self.n_heads * self.mla_v_dim * d)
        else:
            attn = (d * self.n_heads * hd + 2 * d * self.n_kv * hd
                    + self.n_heads * hd * d)
        n_mlp = 3 if self.mlp_type == "swiglu" else 2
        if self.n_experts:
            ff = self.moe_d_ff or self.d_ff
            dense_ff = n_mlp * d * self.d_ff
            moe_ff = (self.n_experts * n_mlp * d * ff
                      + self.n_shared_experts * n_mlp * d * ff
                      + d * self.n_experts)
            total += (self.first_k_dense * (attn + dense_ff)
                      + (L - self.first_k_dense) * (attn + moe_ff))
        else:
            total += L * (attn + n_mlp * d * self.d_ff)
        if self.enc_layers:
            total += self.enc_layers * (attn + n_mlp * d * self.d_ff)
            total += L * (attn + n_mlp * d * self.d_ff)  # cross attention ~attn
        return float(total)

    def active_param_count(self) -> float:
        """Active params per token (MoE: only routed top-k experts count)."""
        if not self.n_experts:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        n_mlp = 3 if self.mlp_type == "swiglu" else 2
        ff = self.moe_d_ff or self.d_ff
        full = self.param_count()
        moe_all = (L - self.first_k_dense) * self.n_experts * n_mlp * d * ff
        moe_active = (L - self.first_k_dense) * self.top_k * n_mlp * d * ff
        return float(full - moe_all + moe_active)
