"""Mixture-of-Experts with expert parallelism over the worker axes.

Experts are sharded across the data-parallel worker axes (``E`` divides the
worker count; each worker group owns ``E/n`` experts, each expert's FFN
additionally tensor-parallel over 'model'). Token dispatch uses a
sort/scatter capacity router; the cross-worker exchange is a manual
``all_to_all`` over the worker axes (the classic EP dispatch), which makes
the MoE collective volume visible verbatim in the dry-run HLO.

Because EP experts exist exactly once across the worker axis they have **no
data-parallel gradient exchange**, so the paper's 0/1 Adam compression scopes
to the dense/attention/embedding parameters (``dp=False`` on expert leaves;
see DESIGN §Arch-applicability). The a2a transpose in backward automatically
accumulates each expert's gradient contributions from every worker.

With ``comm=None`` (single worker: CPU smoke tests, serving without EP) the
same code runs with the a2a skipped — one code path everywhere.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.layers import PD, maybe_shard, model_dim_spec


def moe_template(d, d_ff, n_experts, n_shared, ep_workers, stack=None):
    """Expert + router params. ``ep_workers`` = worker-axis size the expert
    dim is sharded over (1 = no EP; experts then DP-replicated and dp=True).
    """
    ffs = model_dim_spec(d_ff)
    ep = ep_workers > 1
    dp = not ep

    def st(shape, spec):
        if stack is None:
            return shape, spec
        return (stack, *shape), (None, *spec)

    sg, pg = st((n_experts, d, d_ff), (None, None, ffs))
    sd_, pd_ = st((n_experts, d_ff, d), (None, ffs, None))
    e_ax = None if not ep else (0 if stack is None else 1)
    t = {
        "router": PD(st((d, n_experts), (None, None))[0],
                     spec=st((d, n_experts), (None, None))[1]),
        "w_gate": PD(sg, spec=pg, dp=dp, ep_axis=e_ax),
        "w_up": PD(sg, spec=pg, dp=dp, ep_axis=e_ax),
        "w_down": PD(sd_, spec=pd_, dp=dp, ep_axis=e_ax),
    }
    if n_shared:
        ssg, spg = st((d, n_shared * d_ff), (None, ffs))
        ssd, spd = st((n_shared * d_ff, d), (ffs, None))
        t["shared_gate"] = PD(ssg, spec=spg)
        t["shared_up"] = PD(ssg, spec=spg)
        t["shared_down"] = PD(ssd, spec=spd)
    return t


def _dispatch_indices(eids, n_experts, capacity):
    """Sort/scatter positions: for flat expert ids (T,), the slot each token
    occupies within its expert's capacity buffer (slots >= capacity drop)."""
    T = eids.shape[0]
    order = jnp.argsort(eids, stable=True)
    sorted_eids = eids[order]
    # position within the run of equal expert ids
    first = jnp.searchsorted(sorted_eids, sorted_eids, side="left")
    pos_sorted = jnp.arange(T, dtype=jnp.int32) - first.astype(jnp.int32)
    pos = jnp.zeros((T,), jnp.int32).at[order].set(pos_sorted)
    return pos


def moe_forward(p, x, *, top_k, n_experts, capacity_factor, comm=None,
                router_noise=0.0, rng=None):
    """x: (B, S, d) -> (out (B, S, d), aux_metrics dict).

    comm: worker-axis Comm for EP dispatch (None = single worker).
    """
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)
    n_workers = 1
    if comm is not None:
        n_workers = comm.size()

    logits = (xf @ p["router"]).astype(jnp.float32)          # (T, E)
    if router_noise and rng is not None:
        logits = logits + router_noise * jax.random.normal(rng, logits.shape)
    gates_full = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates_full, top_k)            # (T, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = gates_full.mean(axis=0)
    ce = jnp.zeros((n_experts,), jnp.float32).at[topi.reshape(-1)].add(
        1.0 / (T * top_k))
    aux_loss = n_experts * jnp.sum(me * ce)

    capacity = int(max(1, -(-int(capacity_factor * T * top_k) // n_experts)))

    eids = topi.reshape(-1)                                   # (T*k,)
    gvals = topv.reshape(-1)
    slot = _dispatch_indices(eids, n_experts, capacity)
    keep = slot < capacity
    # scatter tokens into (E, C, d); dropped tokens routed out-of-bounds
    drop_slot = jnp.where(keep, slot, capacity)
    tok_idx = jnp.repeat(jnp.arange(T, dtype=jnp.int32), top_k)
    buf = jnp.zeros((n_experts, capacity, d), x.dtype)
    buf = buf.at[eids, drop_slot].set(xf[tok_idx], mode="drop")

    if comm is not None and n_workers > 1:
        # EP exchange: (E, C, d) -> (n, E_local, C, d) -> a2a -> local experts
        e_local = n_experts // n_workers
        sendbuf = buf.reshape(n_workers, e_local, capacity, d)
        recvbuf = comm.all_to_all(sendbuf, split_axis=0, concat_axis=0)
        # (n_senders, E_local, C, d) -> (E_local, n*C, d)
        ein = jnp.moveaxis(recvbuf, 0, 1).reshape(
            e_local, n_workers * capacity, d)
    else:
        ein = buf                                             # (E, C, d)

    # expert FFN (w_*: (E_local, d, ff) leaves arrive worker-sharded)
    h = jnp.einsum("ecd,edf->ecf", ein, p["w_gate"])
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", ein, p["w_up"])
    h = maybe_shard(h, None, None, "model")
    eout = jnp.einsum("ecf,efd->ecd", h, p["w_down"])

    if comm is not None and n_workers > 1:
        e_local = n_experts // n_workers
        back = eout.reshape(e_local, n_workers, capacity, d)
        back = jnp.moveaxis(back, 1, 0)                       # (n, E_l, C, d)
        ret = comm.all_to_all(back, split_axis=0, concat_axis=0)
        outbuf = ret.reshape(n_experts, capacity, d)
    else:
        outbuf = eout

    # combine: gather each assignment's expert output, weight, sum over k
    safe_slot = jnp.minimum(drop_slot, capacity - 1)
    y = outbuf[eids, safe_slot]                               # (T*k, d)
    y = y * (gvals * keep.astype(gvals.dtype))[:, None].astype(y.dtype)
    out = jnp.zeros((T, d), y.dtype).at[tok_idx].add(y)

    if "shared_gate" in p:
        sh = jax.nn.silu(xf @ p["shared_gate"]) * (xf @ p["shared_up"])
        sh = maybe_shard(sh, None, "model")
        out = out + sh @ p["shared_down"]

    metrics = {"aux_loss": aux_loss,
               "dropped_frac": 1.0 - keep.mean()}
    return out.reshape(B, S, d), metrics
