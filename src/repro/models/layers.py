"""Parameter templates + elementary layers.

Single-source-of-truth parameter definition: every module builds a *template*
tree whose leaves are :class:`PD` (param descriptors). From one template we
derive, with guaranteed structural agreement:

  * ``init_params``   — materialized arrays (deterministic per-path keys),
  * ``param_specs``   — tensor-parallel PartitionSpecs ('model' axis only;
                        the worker axis is added by the trainer),
  * ``dp_mask``       — which leaves are DP-replicated (False = expert-
                        parallel leaves updated with local Adam),
  * ``abstract``      — ShapeDtypeStructs for the dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class PD:
    """Param descriptor: shape + init + sharding + DP membership."""

    shape: Tuple[int, ...]
    init: str = "normal"          # normal | zeros | ones | small
    scale: float = 0.02
    spec: Optional[tuple] = None  # entries over the 'model' axis or None
    dp: bool = True
    dtype: object = jnp.float32
    ep_axis: Optional[int] = None  # expert-parallel axis (dp=False leaves):
                                   # sharded over the worker axes by trainer


def _materialize(path, pd: PD, key):
    import zlib
    k = jax.random.fold_in(key, zlib.crc32(path.encode()) & 0x7FFFFFFF)
    if pd.init == "zeros":
        return jnp.zeros(pd.shape, pd.dtype)
    if pd.init == "ones":
        return jnp.ones(pd.shape, pd.dtype)
    scale = pd.scale
    if pd.init == "small":
        scale = pd.scale * 0.1
    return (jax.random.normal(k, pd.shape) * scale).astype(pd.dtype)


def _path_str(path):
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def is_pd(x):
    return isinstance(x, PD)


def init_params(template, key, dtype=None):
    def f(path, pd):
        arr = _materialize(_path_str(path), pd, key)
        return arr.astype(dtype) if dtype is not None else arr
    return jax.tree_util.tree_map_with_path(f, template, is_leaf=is_pd)


def param_specs(template):
    return jax.tree.map(
        lambda pd: P(*pd.spec) if pd.spec is not None else P(),
        template, is_leaf=is_pd)


def dp_mask(template):
    return jax.tree.map(lambda pd: pd.dp, template, is_leaf=is_pd)


def abstract_params(template, dtype=None):
    return jax.tree.map(
        lambda pd: jax.ShapeDtypeStruct(pd.shape, dtype or pd.dtype),
        template, is_leaf=is_pd)


def stack_template(tmpl, n: int):
    """Prepend a layer-stacking axis to every PD in a template."""
    def f(pd: PD) -> PD:
        spec = pd.spec if pd.spec is not None else (None,) * len(pd.shape)
        ep = None if pd.ep_axis is None else pd.ep_axis + 1
        return dataclasses.replace(
            pd, shape=(n, *pd.shape), spec=(None, *spec), ep_axis=ep)
    return jax.tree.map(f, tmpl, is_leaf=is_pd)


def maybe_shard(x, *spec):
    """with_sharding_constraint that degrades gracefully off-mesh.

    Only constrains over GSPMD-auto axes of the ambient mesh when the dims
    divide; otherwise a no-op (CPU tests, simulation mode, manual axes).
    """
    from repro.core.compressor import constrain
    return constrain(x, spec)


def model_dim_spec(dim: int, mesh_axis: str = "model"):
    """Helper used by templates: shard `dim` over 'model' iff divisible.

    Divisibility is checked against the production TP degree (16); configs
    that cannot divide simply replicate that axis.
    """
    return mesh_axis if dim % 16 == 0 else None


# ---------------------------------------------------------------------------
# Elementary ops
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def norm_template(cfg_norm: str, d: int):
    if cfg_norm == "rmsnorm":
        return {"scale": PD((d,), "zeros")}
    return {"scale": PD((d,), "ones"), "bias": PD((d,), "zeros")}


def apply_norm(p, x, cfg_norm: str):
    if cfg_norm == "rmsnorm":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def mlp_template(d: int, ff: int, kind: str, layers_axis: Optional[int] = None):
    """SwiGLU or GELU MLP params (optionally stacked over a layers axis)."""
    def st(shape, spec):
        if layers_axis is None:
            return shape, spec
        return (layers_axis, *shape), (None, *spec)
    ffs = model_dim_spec(ff)
    if kind == "swiglu":
        s1, p1 = st((d, ff), (None, ffs))
        s3, p3 = st((d, ff), (None, ffs))
        s2, p2 = st((ff, d), (ffs, None))
        return {"w_gate": PD(s1, spec=p1), "w_up": PD(s3, spec=p3),
                "w_down": PD(s2, spec=p2)}
    s1, p1 = st((d, ff), (None, ffs))
    s2, p2 = st((ff, d), (ffs, None))
    sb1, pb1 = st((ff,), (ffs,))
    sb2, pb2 = st((d,), (None,))
    return {"w_in": PD(s1, spec=p1), "b_in": PD(sb1, "zeros", spec=pb1),
            "w_out": PD(s2, spec=p2), "b_out": PD(sb2, "zeros", spec=pb2)}


def apply_mlp(p, x, kind: str):
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
        h = maybe_shard(h, None, None, "model")
        return h @ p["w_down"]
    h = jax.nn.gelu(x @ p["w_in"] + p["b_in"])
    h = maybe_shard(h, None, None, "model")
    return h @ p["w_out"] + p["b_out"]
