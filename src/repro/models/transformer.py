"""Model assembly: every assigned architecture composes from this module.

Entry points:
  model_template(cfg)                       -> PD tree (params single source)
  forward(params, cfg, batch, comm, rng)    -> (logits, aux) for training
  prefill(params, cfg, batch, cache, comm)  -> (last logits, cache)
  decode(params, cfg, tokens, cache, pos, comm[, enc_out]) -> (logits, cache)
  init_cache(cfg, batch, max_seq)           -> cache pytree (zeros)

Layers run under lax.scan over stacked parameters (homogeneous per family),
with per-layer flag arrays expressing heterogeneity (gemma3's 5:1
local:global pattern, zamba2's shared-attention interleave, DeepSeek's
dense-prefix layers are a separate unstacked prefix).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import moe as MOE
from repro.models import rope as R
from repro.models import ssm as SSM
from repro.models.config import ModelConfig
from repro.models.layers import (PD, apply_mlp, apply_norm, maybe_shard,
                                 mlp_template, model_dim_spec,
                                 norm_template, stack_template)


# ---------------------------------------------------------------------------
# Templates
# ---------------------------------------------------------------------------

def _block_template(cfg: ModelConfig, n_layers: int, moe: bool,
                    ep_workers: int):
    """One stacked run of decoder blocks."""
    d = cfg.d_model
    t = {"attn_norm": stack_template(norm_template(cfg.norm_type, d),
                                     n_layers),
         "mlp_norm": stack_template(norm_template(cfg.norm_type, d),
                                    n_layers)}
    if cfg.attn_type == "mla":
        t["attn"] = A.mla_template(d, cfg.n_heads, cfg.kv_lora_rank,
                                   cfg.mla_qk_nope, cfg.mla_qk_rope,
                                   cfg.mla_v_dim, stack=n_layers)
    else:
        t["attn"] = A.gqa_template(d, cfg.n_heads, cfg.n_kv, cfg.hd,
                                   bias=cfg.attn_bias, stack=n_layers)
    if moe:
        ff = cfg.moe_d_ff or cfg.d_ff
        t["moe"] = MOE.moe_template(d, ff, cfg.n_experts,
                                    cfg.n_shared_experts, ep_workers,
                                    stack=n_layers)
    else:
        t["mlp"] = mlp_template(d, cfg.d_ff, cfg.mlp_type,
                                layers_axis=n_layers)
    return t


def _ssm_block_template(cfg: ModelConfig, n_layers: int):
    t = {"norm": stack_template(norm_template(cfg.norm_type, cfg.d_model),
                                n_layers),
         "ssm": SSM.ssm_template(cfg.d_model, cfg.d_inner, cfg.ssm_heads,
                                 cfg.ssm_head_dim, cfg.ssm_state,
                                 cfg.ssm_groups, cfg.conv_kernel,
                                 stack=n_layers)}
    return t


def model_template(cfg: ModelConfig, ep_workers: int = 1):
    d, V = cfg.d_model, cfg.padded_vocab
    vs = model_dim_spec(V)
    t = {"embed": PD((V, d), spec=(vs, None), scale=0.02),
         "final_norm": norm_template(cfg.norm_type, d)}
    if not cfg.tie_embeddings:
        t["lm_head"] = PD((d, V), spec=(None, vs))
    if cfg.rope == "learned":
        t["pos_embed"] = PD((cfg.max_seq, d), scale=0.02)

    if cfg.family in ("ssm", "hybrid"):
        t["blocks"] = _ssm_block_template(cfg, cfg.n_layers)
        if cfg.attn_every:
            t["shared_attn"] = {
                "norm": norm_template(cfg.norm_type, d),
                "attn": A.gqa_template(d, cfg.n_heads, cfg.n_kv, cfg.hd),
                "mlp_norm": norm_template(cfg.norm_type, d),
                "mlp": mlp_template(d, cfg.d_ff, cfg.mlp_type),
            }
        return t

    moe = cfg.n_experts > 0
    n_main = cfg.n_layers - cfg.first_k_dense
    if cfg.first_k_dense:
        t["dense_blocks"] = _block_template(cfg, cfg.first_k_dense,
                                            moe=False, ep_workers=1)
    t["blocks"] = _block_template(cfg, n_main, moe=moe,
                                  ep_workers=ep_workers)

    if cfg.enc_layers:  # whisper: encoder + per-decoder-layer cross attn
        t["encoder"] = {
            "blocks": _block_template(
                dataclasses.replace(cfg, n_experts=0), cfg.enc_layers,
                moe=False, ep_workers=1),
            "pos_embed": PD((cfg.enc_frames, d), scale=0.02),
            "final_norm": norm_template(cfg.norm_type, d),
        }
        t["cross"] = {
            "norm": stack_template(norm_template(cfg.norm_type, d),
                                   cfg.n_layers),
            "attn": A.gqa_template(d, cfg.n_heads, cfg.n_kv, cfg.hd,
                                   bias=cfg.attn_bias, stack=cfg.n_layers),
        }
    return t


# ---------------------------------------------------------------------------
# Positions / embeddings
# ---------------------------------------------------------------------------

def _positions(cfg: ModelConfig, B: int, S: int, offset=0):
    if cfg.rope == "mrope":
        return R.mrope_positions(B, S, cfg.vision_tokens, cfg.vision_grid_h,
                                 offset)
    return R.text_positions(B, S, offset)


def _embed(params, cfg: ModelConfig, tokens, offset=0, vision_embeds=None):
    h = jnp.take(params["embed"], tokens, axis=0)
    h = h.astype(cfg.compute_dtype)
    if cfg.rope == "learned":
        S = tokens.shape[1]
        pe = jax.lax.dynamic_slice_in_dim(params["pos_embed"],
                                          offset, S, axis=0)
        h = h + pe[None].astype(h.dtype)
    if vision_embeds is not None and cfg.vision_tokens:
        # VLM stub: precomputed patch embeddings replace the prefix.
        h = jax.lax.dynamic_update_slice(
            h, vision_embeds.astype(h.dtype), (0, 0, 0))
    return maybe_shard(h, ("pod", "data"), None, None)


def _logits(params, cfg: ModelConfig, h):
    h = apply_norm(params["final_norm"], h, cfg.norm_type)
    if cfg.tie_embeddings:
        return h @ params["embed"].T.astype(h.dtype)
    return h @ params["lm_head"].astype(h.dtype)


# ---------------------------------------------------------------------------
# Layer scans
# ---------------------------------------------------------------------------

def _layer_flags(cfg: ModelConfig):
    """Per-layer int flags: attention kind (0 causal / 1 sliding)."""
    L = cfg.n_layers - cfg.first_k_dense
    if cfg.sliding_window and cfg.global_every:
        # gemma3: every `global_every`-th layer is global, rest sliding.
        f = [0 if (i + 1) % cfg.global_every == 0 else 1 for i in range(L)]
    elif cfg.sliding_window:
        f = [1] * L
    else:
        f = [0] * L
    return jnp.asarray(f, jnp.int32)


def _attn_call(p, cfg, h, positions, sliding, *, kind_flag=None, cache=None,
               cache_pos=None, kv_override=None, use_blockwise=False):
    """Attention with a traced sliding/global selector."""
    base_kind = "causal" if cfg.causal else "bidir"
    if cfg.attn_type == "mla":
        return A.mla_forward(p, cfg, h, positions, cache=cache,
                             cache_pos=cache_pos,
                             use_blockwise=use_blockwise)
    if kind_flag is None or not cfg.sliding_window:
        return A.gqa_forward(p, cfg, h, positions, kind=base_kind,
                             window=0, cache=cache, cache_pos=cache_pos,
                             kv_override=kv_override,
                             use_blockwise=use_blockwise)

    def sl(args):
        return A.gqa_forward(p, cfg, h, positions, kind="sliding",
                             window=cfg.sliding_window, cache=cache,
                             cache_pos=cache_pos,
                             use_blockwise=use_blockwise)

    def gl(args):
        return A.gqa_forward(p, cfg, h, positions, kind=base_kind, window=0,
                             cache=cache, cache_pos=cache_pos,
                             use_blockwise=use_blockwise)

    return jax.lax.cond(kind_flag == 1, sl, gl, ())


def _decoder_scan(params, cfg: ModelConfig, h, positions, *, comm=None,
                  cache=None, cache_pos=None, enc_out=None,
                  use_blockwise=False, prefix=False):
    """Scan the (stacked) decoder blocks. Returns (h, new_cache, aux)."""
    block = params["dense_blocks"] if prefix else params["blocks"]
    n = (cfg.first_k_dense if prefix
         else cfg.n_layers - cfg.first_k_dense)
    moe = (cfg.n_experts > 0) and not prefix
    flags = (_layer_flags(cfg)[:n] if not prefix
             else jnp.zeros((n,), jnp.int32))
    cross = params.get("cross") if not prefix else None

    enc_kv = None
    if enc_out is not None and cross is not None:
        # cross K/V from encoder output, per layer (stacked weights)
        K, hd = cfg.n_kv, cfg.hd
        ck = jnp.einsum("bsd,lde->lbse", enc_out, cross["attn"]["wk"])
        cv = jnp.einsum("bsd,lde->lbse", enc_out, cross["attn"]["wv"])
        Benc, Senc = enc_out.shape[0], enc_out.shape[1]
        enc_kv = (ck.reshape(n, Benc, Senc, K, hd),
                  cv.reshape(n, Benc, Senc, K, hd))

    def body(carry, xs):
        hh = carry
        lp, flag, layer_cache, ckv = xs
        x0 = hh
        hn = apply_norm(lp["attn_norm"], hh, cfg.norm_type)
        ao, new_kv = _attn_call(lp["attn"], cfg, hn, positions, None,
                                kind_flag=flag, cache=layer_cache,
                                cache_pos=cache_pos,
                                use_blockwise=use_blockwise)
        hh = x0 + ao
        if ckv is not None:
            cn = apply_norm(lp["cross_norm"], hh, cfg.norm_type)
            co, _ = A.gqa_forward(lp["cross_attn"], cfg, cn, positions,
                                  kind="bidir",
                                  kv_override=(ckv["k"], ckv["v"]))
            hh = hh + co
        hm = apply_norm(lp["mlp_norm"], hh, cfg.norm_type)
        aux = jnp.zeros((), jnp.float32)
        if moe:
            mo, mmet = MOE.moe_forward(
                lp["moe"], hm, top_k=cfg.top_k, n_experts=cfg.n_experts,
                capacity_factor=cfg.capacity_factor, comm=comm)
            aux = mmet["aux_loss"]
        else:
            mo = apply_mlp(lp["mlp"], hm, cfg.mlp_type)
        hh = hh + mo
        hh = maybe_shard(hh, ("pod", "data"), None, None)
        return hh, (new_kv, aux)

    if cfg.remat:
        body = jax.checkpoint(body)

    # assemble scan xs
    lp = dict(block)
    if cross is not None:
        lp["cross_norm"] = cross["norm"]
        lp["cross_attn"] = cross["attn"]
    xs = (lp, flags,
          cache if cache is not None else _none_like(n),
          _stack_tuple(enc_kv) if enc_kv is not None else _none_like(n))
    h, (new_cache, aux) = jax.lax.scan(body, h, xs)
    return h, new_cache, aux.sum()


def _decoder_scan_window_decode(params, cfg: ModelConfig, h, positions,
                                cache, cache_pos):
    """Single-token decode with the split window cache (window_cache=True):
    sliding layers ring-write their (L, B, W) stack slice; the few global
    layers dynamic-index a compact (G, B, S) stack carried through the scan
    (same pattern as the zamba2 shared-attention cache)."""
    flags = _layer_flags(cfg)               # 1 = sliding, 0 = global

    def body(carry, xs):
        hh, gc, gidx = carry
        lp, flag, lc = xs
        x0 = hh
        hn = apply_norm(lp["attn_norm"], hh, cfg.norm_type)

        def local_branch(op):
            hh_, lc_, gc_ = op
            ao, new_kv = A.gqa_forward(lp["attn"], cfg, hh_, positions,
                                       kind="sliding",
                                       window=cfg.sliding_window,
                                       cache=lc_, cache_pos=cache_pos)
            return ao, new_kv, gc_

        def global_branch(op):
            hh_, lc_, gc_ = op
            slot = {"k": jax.lax.dynamic_index_in_dim(gc_["k"], gidx, 0,
                                                      keepdims=False),
                    "v": jax.lax.dynamic_index_in_dim(gc_["v"], gidx, 0,
                                                      keepdims=False)}
            ao, new_kv = A.gqa_forward(lp["attn"], cfg, hh_, positions,
                                       kind="causal", window=0,
                                       cache=slot, cache_pos=cache_pos)
            gc_ = {"k": jax.lax.dynamic_update_index_in_dim(
                        gc_["k"], new_kv["k"], gidx, 0),
                   "v": jax.lax.dynamic_update_index_in_dim(
                        gc_["v"], new_kv["v"], gidx, 0)}
            return ao, lc_, gc_

        ao, new_lc, gc = jax.lax.cond(flag == 1, local_branch,
                                      global_branch, (hn, lc, gc))
        hh = x0 + ao
        hm = apply_norm(lp["mlp_norm"], hh, cfg.norm_type)
        hh = hh + apply_mlp(lp["mlp"], hm, cfg.mlp_type)
        gidx = gidx + (flag == 0).astype(jnp.int32)
        return (hh, gc, gidx), new_lc

    (h, gcache, _), new_local = jax.lax.scan(
        body, (h, cache["global"], jnp.zeros((), jnp.int32)),
        (params["blocks"], flags, cache["local"]))
    return h, {"local": new_local, "global": gcache}


def _none_like(n):
    return None


def _stack_tuple(kv):
    return {"k": kv[0], "v": kv[1]}


# ---------------------------------------------------------------------------
# SSM / hybrid scan
# ---------------------------------------------------------------------------

def _ssm_scan(params, cfg: ModelConfig, h, positions, *, cache=None,
              cache_pos=None, decode_mode=False, use_blockwise=False):
    n = cfg.n_layers
    do_attn = jnp.asarray(
        [1 if cfg.attn_every and (i + 1) % cfg.attn_every == 0 else 0
         for i in range(n)], jnp.int32)
    shared = params.get("shared_attn")
    shared_cache = None if cache is None else cache.get("shared")

    def body(carry, xs):
        hh, sc, app_idx = carry
        lp, flag, layer_state = xs
        x0 = hh
        hn = apply_norm(lp["norm"], hh, cfg.norm_type)
        so, new_state = SSM.ssm_forward(lp["ssm"], cfg, hn,
                                        state=layer_state,
                                        decode=decode_mode)
        hh = x0 + so

        if shared is not None:
            def with_attn(op):
                hh_, sc_ = op
                an = apply_norm(shared["norm"], hh_, cfg.norm_type)
                if sc_ is not None:
                    slot = {"k": jax.lax.dynamic_index_in_dim(
                                sc_["k"], app_idx, 0, keepdims=False),
                            "v": jax.lax.dynamic_index_in_dim(
                                sc_["v"], app_idx, 0, keepdims=False)}
                else:
                    slot = None
                ao, new_kv = A.gqa_forward(
                    shared["attn"], cfg, an, positions, kind="causal",
                    cache=slot, cache_pos=cache_pos,
                    use_blockwise=use_blockwise)
                hh_ = hh_ + ao
                mn = apply_norm(shared["mlp_norm"], hh_, cfg.norm_type)
                hh_ = hh_ + apply_mlp(shared["mlp"], mn, cfg.mlp_type)
                if sc_ is not None and new_kv is not None:
                    sc_ = {"k": jax.lax.dynamic_update_index_in_dim(
                                sc_["k"], new_kv["k"], app_idx, 0),
                           "v": jax.lax.dynamic_update_index_in_dim(
                                sc_["v"], new_kv["v"], app_idx, 0)}
                return hh_, sc_

            def no_attn(op):
                return op

            hh, sc = jax.lax.cond(flag == 1, with_attn, no_attn, (hh, sc))
            app_idx = app_idx + flag
        hh = maybe_shard(hh, ("pod", "data"), None, None)
        return (hh, sc, app_idx), new_state

    if cfg.remat:
        body = jax.checkpoint(body)

    layer_states = None if cache is None else cache["ssm"]
    xs = (params["blocks"], do_attn, layer_states)
    (h, shared_cache, _), new_states = jax.lax.scan(
        body, (h, shared_cache, jnp.zeros((), jnp.int32)), xs)
    new_cache = None
    if cache is not None:
        new_cache = {"ssm": new_states}
        if shared_cache is not None:
            new_cache["shared"] = shared_cache
    return h, new_cache


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def encode(params, cfg: ModelConfig, frames):
    """Whisper encoder over stub frame embeddings (B, T_enc, d)."""
    enc = params["encoder"]
    h = frames.astype(cfg.compute_dtype) + enc["pos_embed"][None].astype(
        cfg.compute_dtype)
    ecfg = dataclasses.replace(cfg, causal=False, rope="none",
                               n_experts=0, first_k_dense=0)
    B, S, _ = h.shape
    pos = R.text_positions(B, S)

    def body(carry, lp):
        hh = carry
        x0 = hh
        hn = apply_norm(lp["attn_norm"], hh, cfg.norm_type)
        ao, _ = A.gqa_forward(lp["attn"], ecfg, hn, pos, kind="bidir")
        hh = x0 + ao
        hm = apply_norm(lp["mlp_norm"], hh, cfg.norm_type)
        hh = hh + apply_mlp(lp["mlp"], hm, cfg.mlp_type)
        return hh, None

    if cfg.remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, enc["blocks"])
    return apply_norm(enc["final_norm"], h, cfg.norm_type)


def forward(params, cfg: ModelConfig, batch, *, comm=None):
    """Training forward: returns (logits, aux_loss)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    vision = batch.get("vision_embeds")
    h = _embed(params, cfg, tokens, 0, vision)
    positions = _positions(cfg, B, S)
    use_bw = S >= cfg.blockwise_threshold
    enc_out = None
    if cfg.enc_layers:
        enc_out = encode(params, cfg, batch["frames"])

    aux = jnp.zeros((), jnp.float32)
    if cfg.family in ("ssm", "hybrid"):
        h, _ = _ssm_scan(params, cfg, h, positions, use_blockwise=use_bw)
    else:
        if cfg.first_k_dense:
            h, _, _ = _decoder_scan(params, cfg, h, positions, comm=comm,
                                    use_blockwise=use_bw, prefix=True)
        h, _, aux = _decoder_scan(params, cfg, h, positions, comm=comm,
                                  enc_out=enc_out, use_blockwise=use_bw)
    return _logits(params, cfg, h), aux


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16):
    """Zeroed decode cache for the architecture."""
    if cfg.family in ("ssm", "hybrid"):
        one = SSM.init_ssm_state(cfg, batch, jnp.float32)
        states = jax.tree.map(
            lambda x: jnp.zeros((cfg.n_layers,) + x.shape, x.dtype), one)
        cache = {"ssm": states}
        if cfg.attn_every:
            napps = cfg.n_attn_apps
            cache["shared"] = {
                "k": jnp.zeros((napps, batch, max_seq, cfg.n_kv, cfg.hd),
                               dtype),
                "v": jnp.zeros((napps, batch, max_seq, cfg.n_kv, cfg.hd),
                               dtype)}
        return cache
    if cfg.attn_type == "mla":
        return {"ckv": jnp.zeros((cfg.n_layers, batch, max_seq,
                                  cfg.kv_lora_rank), dtype),
                "kr": jnp.zeros((cfg.n_layers, batch, max_seq,
                                 cfg.mla_qk_rope), dtype)}
    if cfg.window_cache and cfg.sliding_window and cfg.global_every:
        # beyond-paper decode optimization: sliding-window layers keep a
        # ring buffer of `window` slots; the few global layers keep the
        # full sequence in a compact stack (gemma3: 48*S -> 40*1024 + 8*S)
        L, G, W = cfg.n_layers, cfg.n_global_layers, cfg.sliding_window
        return {
            "local": {"k": jnp.zeros((L, batch, W, cfg.n_kv, cfg.hd),
                                     dtype),
                      "v": jnp.zeros((L, batch, W, cfg.n_kv, cfg.hd),
                                     dtype)},
            "global": {"k": jnp.zeros((G, batch, max_seq, cfg.n_kv,
                                       cfg.hd), dtype),
                       "v": jnp.zeros((G, batch, max_seq, cfg.n_kv,
                                       cfg.hd), dtype)},
        }
    L = cfg.n_layers
    c = {"k": jnp.zeros((L, batch, max_seq, cfg.n_kv, cfg.hd), dtype),
         "v": jnp.zeros((L, batch, max_seq, cfg.n_kv, cfg.hd), dtype)}
    if cfg.first_k_dense:
        c = {"k": c["k"], "v": c["v"]}  # prefix layers share the stack
    return c


def _split_cache(cfg, cache):
    """MLA caches keep their dict form; GQA caches are {'k','v'} stacked."""
    return cache


def prefill(params, cfg: ModelConfig, batch, cache, *, comm=None):
    """Process the prompt, fill the cache, return logits of the last token."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    vision = batch.get("vision_embeds")
    h = _embed(params, cfg, tokens, 0, vision)
    positions = _positions(cfg, B, S)
    use_bw = S >= cfg.blockwise_threshold
    enc_out = batch.get("enc_out")
    if cfg.enc_layers and enc_out is None:
        enc_out = encode(params, cfg, batch["frames"])

    if cfg.family in ("ssm", "hybrid"):
        h, new_cache = _ssm_scan(params, cfg, h, positions, cache=cache,
                                 cache_pos=0, use_blockwise=use_bw)
    else:
        assert not cfg.first_k_dense or True
        if cfg.first_k_dense:
            # prefix layers use the first slots of the stacked cache
            pre_cache = jax.tree.map(lambda x: x[:cfg.first_k_dense], cache)
            h, pre_new, _ = _decoder_scan(params, cfg, h, positions,
                                          comm=comm, cache=pre_cache,
                                          cache_pos=0, use_blockwise=use_bw,
                                          prefix=True)
            main_cache = jax.tree.map(lambda x: x[cfg.first_k_dense:], cache)
            h, main_new, _ = _decoder_scan(params, cfg, h, positions,
                                           comm=comm, cache=main_cache,
                                           cache_pos=0, enc_out=enc_out,
                                           use_blockwise=use_bw)
            new_cache = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], 0), pre_new, main_new)
        else:
            h, new_cache, _ = _decoder_scan(params, cfg, h, positions,
                                            comm=comm, cache=cache,
                                            cache_pos=0, enc_out=enc_out,
                                            use_blockwise=use_bw)
    logits = _logits(params, cfg, h[:, -1:])
    return logits, new_cache


def decode(params, cfg: ModelConfig, tokens, cache, pos, *, comm=None,
           enc_out=None):
    """One decode step: tokens (B,1), pos scalar index into the cache."""
    B = tokens.shape[0]
    h = _embed(params, cfg, tokens, pos, None)
    positions = _positions(cfg, B, 1, offset=pos)

    if cfg.family in ("ssm", "hybrid"):
        h, new_cache = _ssm_scan(params, cfg, h, positions, cache=cache,
                                 cache_pos=pos, decode_mode=True)
    elif (cfg.window_cache and cfg.sliding_window and cfg.global_every
          and isinstance(cache, dict) and "local" in cache):
        h, new_cache = _decoder_scan_window_decode(params, cfg, h,
                                                   positions, cache, pos)
    else:
        if cfg.first_k_dense:
            pre_cache = jax.tree.map(lambda x: x[:cfg.first_k_dense], cache)
            h, pre_new, _ = _decoder_scan(params, cfg, h, positions,
                                          comm=comm, cache=pre_cache,
                                          cache_pos=pos, prefix=True)
            main_cache = jax.tree.map(lambda x: x[cfg.first_k_dense:], cache)
            h, main_new, _ = _decoder_scan(params, cfg, h, positions,
                                           comm=comm, cache=main_cache,
                                           cache_pos=pos, enc_out=enc_out)
            new_cache = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], 0), pre_new, main_new)
        else:
            h, new_cache, _ = _decoder_scan(params, cfg, h, positions,
                                            comm=comm, cache=cache,
                                            cache_pos=pos, enc_out=enc_out)
    return _logits(params, cfg, h), new_cache


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def lm_loss(params, cfg: ModelConfig, batch, *, comm=None):
    """Next-token (or MLM via ``loss_mask``) cross-entropy + MoE aux."""
    logits, aux = forward(params, cfg, batch, comm=comm)
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    # gold logit via a shard-local masked reduction: with the vocab axis
    # tensor-parallel sharded this lowers to a local reduce + tiny psum
    # instead of all-gathering the logits (take_along_axis would).
    V = logits.shape[-1]
    hit = jax.lax.broadcasted_iota(jnp.int32, (1, 1, V), 2) == \
        labels[..., None]
    gold = jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(mask.sum(), 1.0)
    else:
        denom = nll.size
    loss = nll.sum() / denom
    return loss + cfg.aux_loss_weight * aux, {"nll": loss, "aux": aux}
