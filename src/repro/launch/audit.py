"""IR-audit CLI: statically verify the lowered train step's communication.

Builds a (sim-mode, device-free) trainer for the requested config, traces
its per-worker step through ``shard_map`` over an abstract mesh, and runs
:func:`repro.analysis.audit_trainer` — collective schedule vs the declared
manifest, payload bytes vs ``codec.wire_bytes``, inter-pod precision, and
f64/weak-type discipline — plus the static Pallas frame pre-check
(:func:`repro.kernels.dispatch.frame_precheck`) on every exchange unit.

    python -m repro.launch.audit --config gpt2 --codec sign1bit \
        --bucket-mb 4 --hierarchy 4 --json report.jsonl
    python -m repro.launch.audit --matrix --lints   # CI smoke matrix

Exits non-zero and prints the first violation on any failure. Unlike
``launch.dryrun`` this never compiles (and never forces a host device
count), so the full matrix runs in seconds on one CPU.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis import audit_trainer
from repro.analysis.lints import run_lints
from repro.configs import get, list_archs
from repro.core import schedules as S
from repro.core.api import REGISTRY_NAMES, OptimizerConfig
from repro.core.codecs import CODEC_NAMES
from repro.core.comm import Hierarchy
from repro.kernels import dispatch as KD
from repro.train.step import Trainer, TrainerConfig


def build_opt_cfg(optimizer: str = "zero_one_adam", scale_mode="tensor",
                  hierarchy_inner: int = 0, codec: str = "sign1bit",
                  codec_arg=None, bucket_mb=None,
                  pack_order: str = "flat") -> OptimizerConfig:
    """The production-shaped optimizer config the audits run against
    (mirrors ``launch.dryrun.default_opt_cfg``, which we can't import —
    dryrun forces a 512-device host platform at import time)."""
    return OptimizerConfig(
        name=optimizer,
        codec=codec, codec_arg=codec_arg, bucket_mb=bucket_mb,
        pack_order=pack_order,
        lr=S.LinearWarmupExpDecay(peak_lr=4e-4, warmup_steps=12500),
        var_policy=S.AdaptiveFreezePolicy(kappa=16),
        sync_policy=S.LrProportionalSyncPolicy(
            warmup_steps=12500, double_every=32768, max_interval=16),
        onebit_warmup=16000,
        scale_mode=scale_mode,
        hierarchy=(Hierarchy(inner=hierarchy_inner) if hierarchy_inner
                   else None),
    )


def first_violation(report_dict) -> str:
    """One-line description of the first violation in an audit report dict
    (shared with ``launch.dryrun --audit``)."""
    vs = report_dict.get("violations") or []
    if not vs:
        return ""
    v = vs[0]
    more = f" (+{len(vs) - 1} more)" if len(vs) > 1 else ""
    return f"[{v['code']}] {v['message']}{more}"


def audit_one(arch: str, *, optimizer="zero_one_adam", codec="sign1bit",
              codec_arg=None, scale_mode="tensor", bucket_mb=None,
              hierarchy_inner: int = 0, workers: int = 4,
              micro_batches: int = 1, pack_order: str = "flat",
              tp: int = 0, smoke: bool = True):
    """Run the IR audit + frame pre-check on one config; returns a JSON-able
    record. ``tp > 1`` audits the meshless tensor-parallel regime
    (``TrainerConfig.model_shards``): TP-local layouts, sharded fused
    buckets, and the model-axis psums of the exchange — all traced under
    the abstract mesh, no devices needed."""
    spec = get(arch)
    cfg = spec.smoke if smoke else spec.config
    ocfg = build_opt_cfg(optimizer, scale_mode,
                         hierarchy_inner=hierarchy_inner, codec=codec,
                         codec_arg=codec_arg, bucket_mb=bucket_mb,
                         pack_order=pack_order)
    tr = Trainer(cfg, ocfg, n_workers=workers,
                 trainer_cfg=TrainerConfig(micro_batches=micro_batches,
                                           model_shards=tp))
    rep = audit_trainer(tr)
    rec = rep.to_dict()
    rec["config"] = {
        "arch": cfg.name, "optimizer": optimizer, "codec": codec,
        "codec_arg": codec_arg, "scale_mode": scale_mode,
        "bucket_mb": bucket_mb, "hierarchy_inner": hierarchy_inner,
        "workers": workers, "micro_batches": micro_batches,
        "pack_order": pack_order, "tp": tp,
    }
    frames = []
    for lo, _, label in tr.opt.exchange_units():
        for issue in KD.frame_precheck(lo):
            frames.append(f"{label}: {issue}")
    rec["frame_issues"] = frames
    rec["ok"] = rec["ok"] and not frames
    return rec


def _matrix(workers: int):
    """The CI smoke matrix: flat + hierarchical, per-leaf + bucketed, every
    shipped codec, and the overlapped gradient-accumulation step
    (micro_batches=2, readiness-ordered packing), on gpt2-smoke."""
    for hierarchy_inner in (0, 2):
        for bucket_mb in (None, 4.0):
            yield dict(codec="sign1bit", hierarchy_inner=hierarchy_inner,
                       bucket_mb=bucket_mb, workers=workers)
    for codec in sorted(set(CODEC_NAMES) - {"sign1bit"}):
        yield dict(codec=codec, workers=workers)
    yield dict(optimizer="one_bit_adam", workers=workers)
    yield dict(optimizer="adam", workers=workers)
    # the scanned/peeled accumulation step with the per-unit overlapped
    # exchange, flat and hierarchical, plus readiness-ordered packing
    yield dict(codec="sign1bit", bucket_mb=4.0, micro_batches=2,
               workers=workers)
    yield dict(codec="sign1bit", hierarchy_inner=2, bucket_mb=4.0,
               micro_batches=2, pack_order="reverse_backward",
               workers=workers)
    # sharded fused buckets: the meshless-TP regime packs same-vspec
    # TP-local shards into multi-member buckets whose scales psum over
    # 'model' — flat and hierarchical
    yield dict(codec="sign1bit", bucket_mb=4.0, tp=2, workers=workers)
    yield dict(codec="sign1bit", hierarchy_inner=2, bucket_mb=4.0, tp=2,
               workers=workers)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Static IR audit of the train step's communication")
    ap.add_argument("--config", "--arch", dest="arch", default="gpt2",
                    choices=list_archs())
    ap.add_argument("--optimizer", default="zero_one_adam",
                    choices=list(REGISTRY_NAMES))
    ap.add_argument("--codec", default="sign1bit",
                    choices=list(CODEC_NAMES))
    ap.add_argument("--codec-arg", type=float, default=None)
    ap.add_argument("--scale-mode", default="tensor",
                    choices=["tensor", "chunk", "row"])
    ap.add_argument("--bucket-mb", type=float, default=None)
    ap.add_argument("--hierarchy", type=int, default=0, metavar="INNER",
                    help="two-level exchange with INNER intra-pod workers "
                         "(0 = flat)")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--micro-batches", type=int, default=1,
                    help="gradient-accumulation microbatches of the traced "
                         "step (>1 audits the scanned/peeled accumulation "
                         "path)")
    ap.add_argument("--pack-order", default="flat",
                    choices=["flat", "reverse_backward"],
                    help="exchange-unit packing/issue order "
                         "(reverse_backward ≈ backward readiness order)")
    ap.add_argument("--tp", type=int, default=0, metavar="SHARDS",
                    help="audit the meshless tensor-parallel regime with "
                         "SHARDS model shards (TrainerConfig.model_shards; "
                         "0 = off)")
    ap.add_argument("--full", action="store_true",
                    help="audit the full-size config (default: smoke)")
    ap.add_argument("--matrix", action="store_true",
                    help="run the CI smoke matrix on --config instead of "
                         "one configuration")
    ap.add_argument("--lints", action="store_true",
                    help="also run the AST repo-invariant lints")
    ap.add_argument("--json", nargs="?", const="-", default=None,
                    metavar="PATH",
                    help="emit JSONL records; bare --json prints to stdout")
    args = ap.parse_args(argv)

    combos = (list(_matrix(args.workers)) if args.matrix
              else [dict(optimizer=args.optimizer, codec=args.codec,
                         codec_arg=args.codec_arg,
                         scale_mode=args.scale_mode,
                         bucket_mb=args.bucket_mb,
                         hierarchy_inner=args.hierarchy,
                         micro_batches=args.micro_batches,
                         pack_order=args.pack_order,
                         tp=args.tp, workers=args.workers)])
    failed = 0
    for kw in combos:
        rec = audit_one(args.arch, smoke=not args.full, **kw)
        c = rec["config"]
        label = (f"{c['arch']} opt={c['optimizer']} codec={c['codec']} "
                 f"hier={c['hierarchy_inner']} bucket={c['bucket_mb']} "
                 f"mb={c['micro_batches']}"
                 + (f" pack={c['pack_order']}"
                    if c['pack_order'] != "flat" else "")
                 + (f" tp={c['tp']}" if c.get("tp") else ""))
        if rec["ok"]:
            print(f"audit OK   {label} "
                  f"({rec['summary']['collectives_traced']} collectives, "
                  f"{rec['summary']['sync_collectives_declared']} declared "
                  f"sync)")
        else:
            failed += 1
            msg = first_violation(rec) or "; ".join(rec["frame_issues"][:1])
            print(f"audit FAIL {label}\n  first violation: {msg}")
        if args.json == "-":
            print(json.dumps(rec))
        elif args.json:
            with open(args.json, "a") as f:
                f.write(json.dumps(rec) + "\n")

    if args.lints:
        findings = run_lints()
        for f in findings:
            print(f)
        if findings:
            print(f"lints: {len(findings)} finding(s)")
            failed += 1
        else:
            print("lints: clean")

    print(f"\nAUDIT SUMMARY: {len(combos) - failed}/{len(combos)} configs "
          f"clean" + (" + lints" if args.lints else ""))
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
