import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST precede every other import (jax locks the device
# count at first initialization).

"""Multi-pod dry-run: prove every (architecture x input shape x mesh)
combination lowers and compiles, and extract the roofline inputs.

For each combination this lowers + compiles the real jitted program
(train_step under partial-manual shard_map, or the serving prefill/decode
step), prints ``memory_analysis()`` / ``cost_analysis()``, parses the
optimized HLO for collective traffic, and (optionally) appends a JSON
record consumed by benchmarks/roofline.py.

Usage:
  python -m repro.launch.dryrun --arch chatglm3-6b --shape train_4k
  python -m repro.launch.dryrun --all --json results/dryrun.jsonl
  python -m repro.launch.dryrun --arch gemma3-12b --shape long_500k --multi-pod
"""
import argparse
import dataclasses
import gc
import json
import re
import sys
import time
from typing import Optional

import jax.numpy as jnp

from repro.configs import ALL_SHAPES, ASSIGNED, get
from repro.core import (CODEC_NAMES, OptimizerConfig, REGISTRY_NAMES,
                        schedules as S)
from repro.launch import shapes as SH
from repro.launch.mesh import make_production_mesh, worker_axes
from repro.serve import Server
from repro.train import Trainer, TrainerConfig

BYTES = {"f32": 4, "bf16": 2, "f16": 2, "u8": 1, "s8": 1, "u32": 4,
         "s32": 4, "f64": 8, "s64": 8, "u64": 8, "pred": 1, "s16": 2,
         "u16": 2}

_COLL = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")


def _computation_blocks(hlo_text: str):
    """Split an HLO module into named computation blocks."""
    blocks = {}
    cur, buf = None, []
    hdr = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(")
    for line in hlo_text.splitlines():
        m = hdr.match(line)
        if m and line.rstrip().endswith("{") and "->" in line:
            if cur is not None:
                blocks[cur] = buf
            cur, buf = m.group(1), []
            continue
        if line.startswith("}"):
            if cur is not None:
                blocks[cur] = buf
            cur, buf = None, []
            continue
        if cur is not None:
            buf.append(line)
    return blocks


def _loop_multipliers(hlo_text: str, blocks):
    """body-computation -> trip count (XLA cost analysis counts while-loop
    bodies once; scans over layers/microbatches must be scaled).

    The trip count is read from the condition computation's *actual* loop
    bound: the integer constant feeding a ``compare`` with
    ``direction=LT`` (trip = bound, the standard counting-up ``lax.scan``
    lowering) or ``LE`` (trip = bound + 1). An unrelated large integer
    constant in the condition block — a threshold, a packed literal —
    must NOT be mistaken for the bound; the old max-over-all-constants
    heuristic did exactly that (e.g. a ``constant(32768)`` sync-schedule
    literal scaling a 4-iteration microbatch scan 32768x). When no
    compare/constant pair parses, fall back to that heuristic rather
    than silently under-counting."""
    mult = {}
    cond_body = []
    for line in hlo_text.splitlines():
        m = re.search(r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*"
                      r"body=%?([\w\.\-]+)", line)
        if m:
            cond_body.append((m.group(1), m.group(2)))
    const_re = re.compile(r"%?([\w\.\-]+)\s*=\s*[su]\d+\[\]\s*"
                          r"constant\((\d+)\)")
    cmp_re = re.compile(r"compare\(([^)]*)\).*?direction=(LT|LE)")
    for cond, body in cond_body:
        lines = blocks.get(cond, [])
        consts = {}
        for line in lines:
            for name, val in const_re.findall(line):
                consts[name] = int(val)
        trip = None
        for line in lines:
            m = cmp_re.search(line)
            if not m:
                continue
            operands = re.findall(r"%?([\w\.\-]+)", m.group(1))
            bound = next((consts[n] for n in operands if n in consts),
                         None)
            if bound is None:
                continue
            trip = bound + 1 if m.group(2) == "LE" else bound
            break
        if trip is None:   # unrecognized condition shape: legacy heuristic
            trip = 1
            for line in lines:
                for c in re.findall(r"constant\((\d+)\)", line):
                    trip = max(trip, int(c))
        mult[body] = max(1, trip)
    return mult


def _block_parents(hlo_text: str, blocks):
    """computation -> list of computations that call it (while/call/cond)."""
    parents = {}
    ref_re = re.compile(
        r"(?:body=|condition=|to_apply=|calls=|branch_computations=\{|"
        r"true_computation=|false_computation=)%?([\w\.\-]+)")
    extra_re = re.compile(r"branch_computations=\{([^}]*)\}")
    for name, lines in blocks.items():
        for line in lines:
            for ref in ref_re.findall(line):
                parents.setdefault(ref, []).append(name)
            for grp in extra_re.findall(line):
                for ref in re.findall(r"%?([\w\.\-]+)", grp):
                    parents.setdefault(ref, []).append(name)
    return parents


def collective_bytes(hlo_text: str):
    """Per-device collective traffic from optimized (SPMD-partitioned) HLO.

    Shapes in the partitioned module are per-device. Ring all-reduce moves
    ~2x the payload; the other collectives ~1x of the result shape.
    Ops inside while-loop bodies (lax.scan over layers / microbatches) are
    scaled by the loop trip count — XLA's own cost analysis counts loop
    bodies once, which would understate scanned-model traffic ~L-fold.
    """
    blocks = _computation_blocks(hlo_text)
    loop_mult = _loop_multipliers(hlo_text, blocks)
    parents = _block_parents(hlo_text, blocks)

    def total_mult(comp, depth=0):
        if depth > 8:
            return 1
        m = loop_mult.get(comp, 1)
        ps = parents.get(comp, [])
        if not ps:
            return m
        return m * max(total_mult(p, depth + 1) for p in ps)

    out = {k: 0.0 for k in _COLL}
    counts = {k: 0 for k in _COLL}
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    op_re = re.compile(
        r"=\s+(\(?[\w\[\],\s{}/#]*?\)?)\s+"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
        r"collective-permute)(-start|-done)?\(")
    for comp, lines in blocks.items():
        scale = total_mult(comp)
        for line in lines:
            m = op_re.search(line)
            if not m:
                continue
            op = m.group(2)
            if m.group(3) == "-done":
                continue  # counted at -start
            nbytes = 0.0
            for dt, dims in shape_re.findall(m.group(1)):
                if dt not in BYTES:
                    continue
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                nbytes += n * BYTES[dt]
            mult = 2.0 if op == "all-reduce" else 1.0
            out[op] += nbytes * mult * scale
            counts[op] += scale
    return out, counts


def _parse_replica_groups(line: str):
    """Replica groups of one collective line: list of id-lists, or None.

    Handles both the explicit ``{{0,1},{2,3}}`` form and the iota form
    ``[g,s]<=[t0,..]T(perm)`` (decoded numerically).
    """
    m = re.search(r"replica_groups=\{\{([\d,{}\s]*)\}\}", line)
    if m:
        return [[int(x) for x in grp.split(",") if x.strip()]
                for grp in m.group(1).split("},{")]
    m = re.search(r"replica_groups=\[([\d,]+)\]<=\[([\d,]+)\]"
                  r"(?:T\(([\d,]+)\))?", line)
    if m:
        import numpy as np
        try:
            dims = [int(x) for x in m.group(1).split(",")]
            tdims = [int(x) for x in m.group(2).split(",")]
            ids = np.arange(int(np.prod(tdims))).reshape(tdims)
            if m.group(3):
                ids = ids.transpose([int(x) for x in m.group(3).split(",")])
            return ids.reshape(dims).tolist()
        except ValueError:   # unexpected form -> caller's unattributed bucket
            return None
    # collective-permute carries source_target_pairs instead; each (src,
    # tgt) pair is its own two-device "group" for pod-crossing purposes
    m = re.search(r"source_target_pairs=\{\{([\d,{}\s]*)\}\}", line)
    if m:
        return [[int(x) for x in pair.split(",") if x.strip()]
                for pair in m.group(1).split("},{")]
    return None


def collective_group_bytes(hlo_text: str, pod_span: Optional[int] = None):
    """Collective traffic bucketed by replica-group size, plus the
    intra/inter-pod split when ``pod_span`` (devices per pod) is given.

    This is what makes the hierarchical AllReduce's promise checkable in
    the lowered HLO: the inner (intra-pod) collectives appear as groups
    whose device ids stay inside one ``pod_span`` block, the outer 1-bit
    exchange as (small) groups that cross blocks.
    """
    blocks = _computation_blocks(hlo_text)
    loop_mult = _loop_multipliers(hlo_text, blocks)
    parents = _block_parents(hlo_text, blocks)

    def total_mult(comp, depth=0):
        if depth > 8:
            return 1
        m = loop_mult.get(comp, 1)
        ps = parents.get(comp, [])
        if not ps:
            return m
        return m * max(total_mult(p, depth + 1) for p in ps)

    by_group = {}
    intra = inter = unattributed = 0.0
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    op_re = re.compile(
        r"=\s+(\(?[\w\[\],\s{}/#]*?\)?)\s+"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
        r"collective-permute)(-start|-done)?\(")
    for comp, lines in blocks.items():
        scale = total_mult(comp)
        for line in lines:
            m = op_re.search(line)
            if not m or m.group(3) == "-done":
                continue
            op = m.group(2)
            nbytes = 0.0
            for dt, dims in shape_re.findall(m.group(1)):
                if dt not in BYTES:
                    continue
                nelt = 1
                for d in dims.split(","):
                    if d:
                        nelt *= int(d)
                nbytes += nelt * BYTES[dt]
            nbytes *= (2.0 if op == "all-reduce" else 1.0) * scale
            groups = _parse_replica_groups(line)
            gsize = len(groups[0]) if groups else 0
            key = f"{op}|g{gsize}"
            by_group[key] = by_group.get(key, 0.0) + nbytes
            if pod_span:
                if groups:
                    crosses = any(len({i // pod_span for i in g}) > 1
                                  for g in groups)
                    if crosses:
                        inter += nbytes
                    else:
                        intra += nbytes
                else:
                    # global groups ("{}") or an unparsed form: keep it out
                    # of both pod buckets but visible, so the split never
                    # silently under-counts the collective term
                    unattributed += nbytes
    out = {"by_group_size": by_group}
    if pod_span:
        out["intrapod_bytes"] = intra
        out["interpod_bytes"] = inter
        out["unattributed_bytes"] = unattributed
    return out


def default_opt_cfg(optimizer: str = "zero_one_adam", scale_mode="tensor",
                    hierarchy_inner: int = 0, codec: str = "sign1bit",
                    codec_arg=None, bucket_mb=None):
    from repro.core import Hierarchy
    return OptimizerConfig(
        name=optimizer,
        codec=codec, codec_arg=codec_arg, bucket_mb=bucket_mb,
        lr=S.LinearWarmupExpDecay(peak_lr=4e-4, warmup_steps=12500),
        var_policy=S.AdaptiveFreezePolicy(kappa=16),
        sync_policy=S.LrProportionalSyncPolicy(
            warmup_steps=12500, double_every=32768, max_interval=16),
        onebit_warmup=16000,
        scale_mode=scale_mode,
        state_dtype=jnp.bfloat16,   # production state dtype (fp16 in paper)
        comm_dtype=jnp.bfloat16,
        hierarchy=(Hierarchy(inner=hierarchy_inner) if hierarchy_inner
                   else None),
    )


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            optimizer: str = "zero_one_adam", scale_mode: str = "tensor",
            micro_override=None, window_cache: bool = False,
            mesh_shape=None, verbose: bool = True,
            hierarchy: bool = False, codec: str = "sign1bit",
            codec_arg=None, bucket_mb=None, audit: bool = False,
            resize_to=None):
    spec = get(arch)
    shape = SH.SHAPES[shape_name]
    if shape_name not in spec.shapes:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "note": spec.skip_notes}
    if mesh_shape is not None:  # perf-iteration override (same chip count)
        dp, tp = mesh_shape
        from repro.core.compat import make_mesh
        mesh = make_mesh((dp, tp), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    W = worker_axes(mesh)
    cfg = dataclasses.replace(spec.config, param_dtype=jnp.bfloat16,
                              compute_dtype=jnp.bfloat16,
                              window_cache=window_cache)
    t0 = time.time()
    n_buckets = n_dp_leaves = audit_rec = elastic_rec = None

    if shape.kind == "train":
        n_workers = 1
        for a in W:
            n_workers *= mesh.shape[a]
        b_local = shape.global_batch // n_workers
        micro = micro_override or max(1, b_local // 2)
        inner = 0
        if hierarchy:
            if "pod" not in mesh.axis_names:
                raise ValueError("--hierarchy needs the multi-pod mesh")
            inner = mesh.shape["data"]
        tr = Trainer(cfg, default_opt_cfg(optimizer, scale_mode,
                                          hierarchy_inner=inner,
                                          codec=codec,
                                          codec_arg=codec_arg,
                                          bucket_mb=bucket_mb), mesh=mesh,
                     trainer_cfg=TrainerConfig(micro_batches=micro,
                                               worker_axes=W))
        n_buckets = (len(tr.opt.bucket_plan.buckets)
                     if getattr(tr.opt, "bucket_plan", None) is not None
                     else None)
        n_dp_leaves = sum(1 for dp in tr.opt.dp_mask if dp)
        if resize_to:
            # static pre/post-resize layout geometry: rebind the optimizer
            # at the target width and record the remap plan — no arrays,
            # no compile, just the two LeafLayout/bucket geometries
            from repro.elastic import reshard_report, resize_opt
            dst_opt = resize_opt(tr.opt, resize_to,
                                 model_axis_sizes=tr.model_sizes)
            elastic_rec = reshard_report(tr.opt, dst_opt)
        if audit:
            from repro.analysis import audit_trainer
            audit_rec = audit_trainer(tr, seq=shape.seq).to_dict()
        fn, _ = tr.mesh_step_fn()
        params, state, batch = tr.abstract_inputs(
            shape.global_batch, shape.seq,
            extra_fn=lambda B, s, c: SH.batch_extras(c, B, s))
        lowered = fn.lower(params, state, batch)
    else:
        srv = Server(cfg, mesh=mesh, worker_axes=W,
                     batch=shape.global_batch, max_seq=shape.seq)
        params = srv.abstract_params()
        cache = srv.abstract_cache()
        if shape.kind == "prefill":
            batch = SH.prefill_input_specs(cfg, shape)
            lowered = srv.prefill_fn().lower(params, batch, cache)
        else:
            d = SH.decode_input_specs(cfg, shape)
            if cfg.enc_layers:
                lowered = srv.decode_fn().lower(
                    params, cache, d["tokens"], d["pos"], d["enc_out"])
            else:
                lowered = srv.decode_fn().lower(
                    params, cache, d["tokens"], d["pos"])

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):   # older jax: one properties-dict per device
        cost = cost[0] if cost else {}
    hlo_text = compiled.as_text()
    coll, coll_counts = collective_bytes(hlo_text)
    pod_span = (mesh.devices.size // mesh.shape["pod"]
                if "pod" in mesh.axis_names else None)
    grp = collective_group_bytes(hlo_text, pod_span)

    rec = {
        "arch": arch, "shape": shape_name, "status": "ok",
        "mesh": (f"{mesh_shape[0]}x{mesh_shape[1]}" if mesh_shape
                 else ("2x16x16" if multi_pod else "16x16")),
        "optimizer": optimizer if shape.kind == "train" else None,
        "scale_mode": scale_mode if shape.kind == "train" else None,
        "codec": codec if shape.kind == "train" else None,
        "hierarchy": bool(hierarchy) if shape.kind == "train" else None,
        "bucket_mb": bucket_mb if shape.kind == "train" else None,
        "n_buckets": n_buckets,
        "n_dp_leaves": n_dp_leaves,
        "audit": audit_rec,
        "elastic": elastic_rec,
        "micro": micro_override, "window_cache": window_cache,
        "kind": shape.kind,
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll,
        "collective_counts": coll_counts,
        "collective_by_group": grp["by_group_size"],
        "intrapod_bytes": grp.get("intrapod_bytes"),
        "interpod_bytes": grp.get("interpod_bytes"),
        "unattributed_collective_bytes": grp.get("unattributed_bytes"),
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "params": get(arch).config.param_count(),
        "active_params": get(arch).config.active_param_count(),
    }
    if verbose:
        print(f"== {arch} x {shape_name} [{rec['mesh']}] "
              f"{'opt=' + optimizer if shape.kind == 'train' else shape.kind}")
        print(f"   memory_analysis: args={mem.argument_size_in_bytes/2**30:.2f}GiB "
              f"out={mem.output_size_in_bytes/2**30:.2f}GiB "
              f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB (per device)")
        print(f"   cost_analysis: flops={rec['flops_per_device']:.3e} "
              f"bytes={rec['bytes_per_device']:.3e} (per device)")
        tot_coll = sum(coll.values())
        print(f"   collectives: {tot_coll/2**20:.1f}MiB/device "
              f"{ {k: round(v/2**20, 2) for k, v in coll.items() if v} }")
        if pod_span:
            print(f"   pod split: intra={grp['intrapod_bytes']/2**20:.1f}MiB "
                  f"inter={grp['interpod_bytes']/2**20:.1f}MiB/device")
        print(f"   lower {t_lower:.1f}s compile {t_compile:.1f}s")
    del lowered, compiled
    gc.collect()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--optimizer", default="zero_one_adam",
                    choices=list(REGISTRY_NAMES))
    ap.add_argument("--scale-mode", default="tensor",
                    choices=["tensor", "chunk", "row"])
    ap.add_argument("--codec", default="sign1bit",
                    choices=list(CODEC_NAMES),
                    help="wire format of the compressed EF exchange; "
                         "non-sign1bit codecs lower through the jnp path")
    ap.add_argument("--codec-arg", type=float, default=None,
                    help="parameter for parameterized codecs (topk density)")
    ap.add_argument("--bucket-mb", type=float, default=None,
                    help="fuse the per-leaf exchange into flat buckets of "
                         "this many MiB each; the bucket count lands in "
                         "the JSON record (n_buckets)")
    ap.add_argument("--micro", type=int, default=None)
    ap.add_argument("--hierarchy", action="store_true",
                    help="two-level AllReduce: uncompressed intra-pod "
                         "('data'), 1-bit inter-pod ('pod'); needs "
                         "--multi-pod")
    ap.add_argument("--window-cache", action="store_true")
    ap.add_argument("--mesh-shape", default=None,
                    help="DPxTP override, e.g. 32x8 (perf iterations)")
    ap.add_argument("--json", default=None,
                    help="append JSONL records here")
    ap.add_argument("--audit", action="store_true",
                    help="run the IR communication audit on train shapes; "
                         "any violation fails the run (non-zero exit) and "
                         "prints the first offending collective")
    ap.add_argument("--resize-to", type=int, default=None, metavar="M",
                    help="record the elastic pre/post-resize layout "
                         "geometry for a DP resize to M workers "
                         "(repro.elastic.reshard_report) in the JSON "
                         "record — static, no second compile")
    args = ap.parse_args()

    combos = []
    archs = ASSIGNED if (args.all or args.arch is None) else [args.arch]
    shapes = list(ALL_SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))

    ok = skipped = failed = 0
    for a, s, mp in combos:
        try:
            ms = (tuple(int(x) for x in args.mesh_shape.split("x"))
                  if args.mesh_shape else None)
            rec = run_one(a, s, multi_pod=mp, optimizer=args.optimizer,
                          scale_mode=args.scale_mode,
                          micro_override=args.micro,
                          window_cache=args.window_cache,
                          mesh_shape=ms, hierarchy=args.hierarchy,
                          codec=args.codec, codec_arg=args.codec_arg,
                          bucket_mb=args.bucket_mb, audit=args.audit,
                          resize_to=args.resize_to)
        except Exception as e:  # noqa: BLE001 — report, keep going
            rec = {"arch": a, "shape": s,
                   "mesh": "2x16x16" if mp else "16x16",
                   "status": "failed", "error": f"{type(e).__name__}: {e}"}
            print(f"== {a} x {s} FAILED: {rec['error'][:500]}")
        if rec["status"] == "ok" and rec.get("audit") \
                and not rec["audit"]["ok"]:
            # audit violations fail the run loudly, not just in the JSON
            from repro.launch.audit import first_violation
            rec["status"] = "audit-failed"
            print(f"== {a} x {s} AUDIT FAILED: "
                  f"{first_violation(rec['audit'])}")
        if rec["status"] == "ok":
            ok += 1
        elif rec["status"] == "skipped":
            skipped += 1
            print(f"== {a} x {s} skipped ({rec['note'][:60]}...)")
        else:
            failed += 1
        if args.json:
            with open(args.json, "a") as f:
                f.write(json.dumps(rec) + "\n")
        gc.collect()
    print(f"\nDRY-RUN SUMMARY: ok={ok} skipped={skipped} failed={failed}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
