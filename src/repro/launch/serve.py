"""Serving driver CLI: continuous batching + live weight refresh.

Builds a :class:`~repro.serve.Server` + :class:`~repro.serve.Scheduler`
over a smoke-scale config, admits a batch of synthetic requests, and
decodes them to completion. With ``--publish-every N`` a trainer-side
:class:`~repro.serve.Publisher` pushes a codec-compressed delta refresh
every N ticks and the scheduler swaps weights at the tick boundary — the
full train-compressed -> ship-compressed -> serve loop in one process.

Examples:
  PYTHONPATH=src python -m repro.launch.serve --arch gpt2 --smoke \\
      --slots 4 --requests 8 --gen 16 --codec qint8 --publish-every 8
  PYTHONPATH=src python -m repro.launch.serve --arch chatglm3-6b --smoke \\
      --kv-quant qint8 --kv-page 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.core import CODEC_NAMES
from repro.models import transformer as T
from repro.models.layers import init_params
from repro.serve import (Publisher, PublishConfig, Request, Scheduler,
                         Server, Subscriber)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--slots", type=int, default=4,
                    help="concurrent batch slots of the scheduler")
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=16,
                    help="new tokens per request")
    ap.add_argument("--codec", default="qint8", choices=list(CODEC_NAMES),
                    help="publish wire codec")
    ap.add_argument("--bucket-mb", type=float, default=4.0)
    ap.add_argument("--publish-every", type=int, default=0,
                    help="push a delta weight refresh every N ticks "
                         "(0 = serve fixed weights)")
    ap.add_argument("--kv-quant", choices=["none", "qint8"],
                    default="none",
                    help="paged qint8 KV-cache storage quantization")
    ap.add_argument("--kv-page", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    spec = get(args.arch)
    cfg = spec.smoke if args.smoke else spec.config
    params = init_params(T.model_template(cfg),
                         jax.random.PRNGKey(args.seed))
    srv = Server(cfg, batch=args.slots, max_seq=args.max_seq,
                 cache_dtype=jnp.float32)

    sub = None
    pub = None
    if args.publish_every:
        pc = PublishConfig(codec=args.codec, bucket_mb=args.bucket_mb)
        pub, sub = Publisher(params, pc), Subscriber(params, pc)
        sub.push(pub.publish(params, step=0))
    sch = Scheduler(srv, params, subscriber=sub,
                    kv_quant=None if args.kv_quant == "none"
                    else args.kv_quant,
                    kv_page=args.kv_page)

    key = jax.random.PRNGKey(args.seed + 1)
    reqs = [Request(rid=i,
                    prompt=np.asarray(jax.random.randint(
                        jax.random.fold_in(key, i), (args.prompt_len,),
                        0, cfg.vocab)).tolist(),
                    max_new_tokens=args.gen)
            for i in range(args.requests)]
    for r in reqs:
        sch.submit(r)

    p, pkey = params, jax.random.PRNGKey(args.seed + 2)
    t0 = time.perf_counter()
    ticks = 0
    while not sch.idle:
        if (pub is not None and ticks
                and ticks % args.publish_every == 0):
            pkey, k = jax.random.split(pkey)
            p = jax.tree.map(
                lambda x, kk=k: x + 1e-3 * jax.random.normal(
                    jax.random.fold_in(kk, x.size), x.shape, x.dtype), p)
            sub.push(pub.publish(p, step=ticks))
        sch.tick()
        ticks += 1
    dt = time.perf_counter() - t0

    for r in reqs:
        print(f"req {r.rid}: {len(r.output)} tokens  {r.output}")
    s = sch.stats
    print(f"# {args.requests} requests over {args.slots} slots: "
          f"{s['generated']} tokens in {dt:.2f}s "
          f"({s['generated'] / dt:.1f} tok/s), "
          f"{s['prefills']} prefills, {s['decode_ticks']} decode ticks, "
          f"{s['weight_swaps']} weight swap(s), "
          f"{s['pages_quantized']} KV page(s) quantized")


if __name__ == "__main__":
    main()
