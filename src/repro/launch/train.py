"""Training driver CLI.

Single-host modes (this container): ``--mode single`` (one worker) or
``--mode sim --workers N`` (N simulated paper-workers via vmap — the real
0/1 Adam communication semantics at algorithm level). On a TPU fleet the
same Trainer builds the mesh-mode step (``--mode mesh``) where workers are
data-parallel groups of the production mesh.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch gpt2 --smoke \\
      --optimizer zero_one_adam --steps 50 --batch 8 --seq 64 --mode sim \\
      --workers 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpointing import io as ckpt_io
from repro.configs import get
from repro.core import (CODEC_NAMES, Hierarchy, OptimizerConfig,
                        REGISTRY_NAMES, comm_accounting, schedules as S)
from repro.data import DataConfig, SyntheticLM
from repro.train import Trainer, TrainerConfig


def build_opt_cfg(args) -> OptimizerConfig:
    lr = S.LinearWarmupExpDecay(peak_lr=args.lr,
                                warmup_steps=args.lr_warmup,
                                decay=0.99, decay_period=max(args.steps // 20,
                                                             1))
    return OptimizerConfig(
        name=args.optimizer, lr=lr,
        var_policy=S.AdaptiveFreezePolicy(kappa=args.kappa),
        sync_policy=S.LrProportionalSyncPolicy(
            warmup_steps=args.sync_warmup, double_every=args.double_every,
            max_interval=args.max_interval),
        onebit_warmup=args.onebit_warmup,
        scale_mode=args.scale_mode,
        codec=args.codec, codec_arg=args.codec_arg,
        use_pallas=args.use_pallas,
        hierarchy=(Hierarchy(inner=args.hierarchy)
                   if args.hierarchy else None),
        bucket_mb=args.bucket_mb)


def _parse_resizes(specs):
    events = []
    for s in specs:
        try:
            step, m = s.split(":")
            step, m = int(step), int(m)
        except ValueError:
            raise SystemExit(f"--resize expects STEP:M, got {s!r}")
        events.append((step, m))
    return sorted(events)


def _run_elastic(args, cfg, opt_cfg, acct):
    """Sim-mode run with in-run DP resizes via repro.elastic.FleetSim."""
    from repro.elastic import FleetSim, ResizeEvent
    from repro.train import TrainerConfig as TC
    events = [ResizeEvent(step=s, workers=m)
              for s, m in _parse_resizes(args.resize)]
    fleet = FleetSim(cfg, opt_cfg, args.workers,
                     trainer_cfg=TC(micro_batches=args.micro_batches),
                     seed=args.seed)
    t0 = time.time()
    res = fleet.run(args.steps, global_batch=args.batch, seq=args.seq,
                    events=events)
    for t, loss in enumerate(res["losses"]):
        if t % args.log_every == 0 or t == args.steps - 1:
            print(f"step {t:5d} loss {loss:.4f} [{time.time()-t0:.1f}s]")
    print(f"DONE: {args.steps} steps with {len(res['resizes'])} "
          f"resize(s) ({time.time()-t0:.1f}s)")
    for r in res["resizes"]:
        print(f"  resize @ step {r['step']}: {r['n_from']} -> {r['n_to']} "
              f"workers ({r['carried_entities']} EF entities carried, "
              f"{r['dead_entities']} folded, fold={r['ef_fold']}) in "
              f"{r['reshard_ms']:.1f}ms")
    if args.save:
        n_final = res["trainer"].n_workers
        ckpt_io.save(args.save,
                     {"params": res["params"], "state": res["state"]},
                     step=args.steps,
                     meta={"arch": cfg.name, "n_workers": n_final,
                           "resizes": [
                               {k: r[k] for k in ("step", "n_from", "n_to")}
                               for r in res["resizes"]]})
        print(f"saved checkpoint to {args.save} (width {n_final})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--optimizer", default="zero_one_adam",
                    choices=list(REGISTRY_NAMES))
    ap.add_argument("--mode", default="single",
                    choices=["single", "sim", "mesh"])
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--lr-warmup", type=int, default=20)
    ap.add_argument("--kappa", type=int, default=4)
    ap.add_argument("--sync-warmup", type=int, default=20)
    ap.add_argument("--double-every", type=int, default=50)
    ap.add_argument("--max-interval", type=int, default=16)
    ap.add_argument("--onebit-warmup", type=int, default=20)
    ap.add_argument("--scale-mode", default="tensor",
                    choices=["tensor", "chunk", "row"])
    ap.add_argument("--codec", default="sign1bit",
                    choices=list(CODEC_NAMES),
                    help="wire format of the compressed EF exchange "
                         "(repro.core.codecs); sign1bit is the paper's")
    ap.add_argument("--codec-arg", type=float, default=None,
                    help="parameter for parameterized codecs "
                         "(topk: density, default 0.01)")
    ap.add_argument("--use-pallas", action="store_true",
                    help="route the optimizer hot path through the fused "
                         "Pallas kernels (interpreted off-TPU)")
    ap.add_argument("--hierarchy", type=int, default=0, metavar="INNER",
                    help="workers per pod for the two-level AllReduce: "
                         "reduce uncompressed inside pods ('data' axis), "
                         "1-bit-compress only across pods ('pod' axis). "
                         "0 = flat single-level exchange")
    ap.add_argument("--bucket-mb", type=float, default=None, metavar="MB",
                    help="fuse the per-leaf compressed exchange into flat "
                         "buckets of MB MiB of f32 elements each "
                         "(repro.core.bucketing): one codec encode + one "
                         "collective pair per bucket instead of per leaf. "
                         "Default: per-leaf exchange")
    ap.add_argument("--micro-batches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--save", default=None, help="checkpoint path (.npz)")
    ap.add_argument("--resize", action="append", default=None,
                    metavar="STEP:M",
                    help="sim mode only: resize the fleet to M workers "
                         "before running STEP (repeatable). Routes the run "
                         "through repro.elastic.FleetSim — EF state and "
                         "anchors are resharded, not reset; the resize is "
                         "recorded in the run summary")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    spec = get(args.arch)
    cfg = spec.smoke if args.smoke else spec.config
    opt_cfg = build_opt_cfg(args)

    if args.mode == "mesh":
        from repro.launch.mesh import make_production_mesh, worker_axes
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        tr = Trainer(cfg, opt_cfg, mesh=mesh, trainer_cfg=TrainerConfig(
            micro_batches=args.micro_batches, worker_axes=worker_axes(mesh)))
        raise SystemExit("mesh mode requires a real TPU fleet; use "
                         "launch/dryrun.py for the compile-only proof")

    n = args.workers if args.mode == "sim" else 1
    tr = Trainer(cfg, opt_cfg, n_workers=n, trainer_cfg=TrainerConfig(
        micro_batches=args.micro_batches))
    acct = comm_accounting(tr.opt)
    print(f"arch={cfg.name} params(dp)={acct['dp_params']/1e6:.2f}M "
          f"codec={acct['codec']} "
          f"bits/param/sync={acct['bits_per_param_sync']:.3f} "
          f"workers={n} optimizer={args.optimizer}")
    if args.bucket_mb:
        print(f"bucketed exchange: {int(acct['exchange_units'])} buckets "
              f"({args.bucket_mb}MiB budget) over "
              f"{int(acct['dp_leaves'])} DP leaves -> "
              f"{int(acct['collectives_per_sync'])} collective phases/sync")
    if acct["n_inner"] > 1:
        print(f"hierarchy: {int(acct['n_outer'])} pods x "
              f"{int(acct['n_inner'])} workers/pod; sync bytes/worker "
              f"intra={acct['compressed_bytes_per_sync_inner']/2**20:.2f}MiB "
              f"inter={acct['compressed_bytes_per_sync_outer']/2**20:.2f}MiB")

    if args.resize:
        if args.mode != "sim":
            raise SystemExit("--resize needs --mode sim (the elastic "
                             "resharding path runs over the sim trainer)")
        return _run_elastic(args, cfg, opt_cfg, acct)

    if args.mode == "sim":
        params, state = tr.sim_init(jax.random.PRNGKey(args.seed))
        step_fn = tr.sim_step_fn()
    else:
        params, state = tr.single_init(jax.random.PRNGKey(args.seed))
        step_fn = tr.single_step_fn()

    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch, seed=args.seed))

    t0 = time.time()
    comp_bytes = 0.0
    rounds = 0
    for step in range(args.steps):
        batch = data.batch(step)
        if cfg.enc_layers:
            batch["frames"] = jnp.zeros((args.batch, cfg.enc_frames,
                                         cfg.d_model))
        if cfg.vision_tokens:
            batch["vision_embeds"] = jnp.zeros(
                (args.batch, cfg.vision_tokens, cfg.d_model))
        if not cfg.causal:
            batch["loss_mask"] = jnp.ones((args.batch, args.seq))
        params, state, met = step_fn(params, state, batch)
        synced = bool(np.asarray(met["synced"]).reshape(-1)[0])
        var_r = bool(np.asarray(met["var_round"]).reshape(-1)[0])
        if synced:
            comp_bytes += acct["compressed_bytes_per_sync"]
            rounds += 1
        if var_r:
            comp_bytes += acct["fullprec_bytes_per_round"]
            rounds += 1
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(np.asarray(met["loss"]).reshape(-1)[0])
            print(f"step {step:5d} loss {loss:.4f} "
                  f"lr {float(np.asarray(met['lr']).reshape(-1)[0]):.2e} "
                  f"sync={synced} var={var_r} "
                  f"[{time.time()-t0:.1f}s]")

    bits_pp = 8 * comp_bytes / max(acct["dp_params"], 1) / max(args.steps, 1)
    print(f"DONE: {args.steps} steps, {rounds} comm rounds, "
          f"avg {bits_pp:.3f} bits/param/step "
          f"({time.time()-t0:.1f}s)")
    if args.save:
        ckpt_io.save(args.save, {"params": params, "state": state},
                     step=args.steps,
                     meta={"arch": cfg.name, "n_workers": n})
        print(f"saved checkpoint to {args.save}")


if __name__ == "__main__":
    main()
