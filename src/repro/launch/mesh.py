"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run sets
``xla_force_host_platform_device_count`` before any jax initialization.
"""
from __future__ import annotations

from repro.core.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; (2,16,16) = 512 chips across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def worker_axes(mesh) -> tuple:
    """The manual (paper-worker) axes of a mesh: everything except model."""
    return tuple(a for a in mesh.axis_names if a != "model")


def make_debug_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CI subprocess tests (needs device_count >= product)."""
    if pod:
        return make_mesh((pod, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))
