"""Assigned input shapes + abstract input specs (ShapeDtypeStruct only).

``input_specs`` is the single source of the dry-run inputs: weak-type
correct, shardable, and never allocated. Modality frontends are stubs —
the audio/VLM entries provide precomputed frame/patch embeddings of the
right shape (the one sanctioned carve-out).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def batch_extras(cfg: ModelConfig, B: int, S: int, dtype=jnp.bfloat16):
    """Stub-frontend inputs (audio frames / vision patch embeddings)."""
    extra = {}
    if cfg.enc_layers:
        extra["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.enc_frames, cfg.d_model), dtype)
    if cfg.vision_tokens:
        extra["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.vision_tokens, cfg.d_model), dtype)
    return extra


def train_input_specs(cfg: ModelConfig, shape: ShapeSpec,
                      dtype=jnp.bfloat16):
    B, S = shape.global_batch, shape.seq
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    batch.update(batch_extras(cfg, B, S, dtype))
    return batch


def prefill_input_specs(cfg: ModelConfig, shape: ShapeSpec,
                        dtype=jnp.bfloat16):
    B, S = shape.global_batch, shape.seq
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    batch.update(batch_extras(cfg, B, S, dtype))
    if cfg.enc_layers:
        # decoder-serving consumes precomputed encoder states
        batch["enc_out"] = jax.ShapeDtypeStruct(
            (B, cfg.enc_frames, cfg.d_model), dtype)
        del batch["frames"]
    return batch


def decode_input_specs(cfg: ModelConfig, shape: ShapeSpec,
                       dtype=jnp.bfloat16):
    B = shape.global_batch
    d = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
         "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    if cfg.enc_layers:
        d["enc_out"] = jax.ShapeDtypeStruct(
            (B, cfg.enc_frames, cfg.d_model), dtype)
    return d
