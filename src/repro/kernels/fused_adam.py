"""Pallas TPU kernels: fused local half-steps, one per base kind.

``fused_local_step`` fuses the 0/1 **Adam** per-step elementwise chain
(Algorithm 1 lines 3-5):

    m' = β₁·m + (1−β₁)·g
    Δ  = γ·m' / sqrt(v + ε)        (applied to x outside, natural shape)
    u' = u + γ·m'

into one VMEM pass: 4 reads + 3 writes instead of ~10 memory sweeps as
separate XLA ops — the optimizer becomes strictly HBM-bandwidth-bound at
~7 bytes/param/step.

``fused_local_step_sgd`` is the momentum-SGD (0/1-SGD) variant — no second
moment, Δ = γ·m'. The LAMB base reuses the Adam kernel and applies its
per-leaf trust scalar outside the kernel (one cheap broadcast multiply),
keeping the fused/unfused bit-parity contract: both paths compute
``trust * ((γ·m')/sqrt(v+ε))``.

Operands are 2-D tiles of the comm view; scalars (γ, β₁) arrive as (1, 1)
operands so one compiled kernel serves every step. The chain is purely
elementwise, so model-sharded views need no cross-shard traffic at all:
``dispatch.fused_local_step_view`` runs this kernel per shard under its
``shard_map`` partitioning rule with the shard-local frame, and the
results compose to the global update by construction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_kernel(g_ref, m_ref, u_ref, v_ref, lr_ref, b1_ref, omb1_ref,
                  m_out, u_out, delta_out, *, eps):
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    lr = lr_ref[0, 0].astype(jnp.float32)
    b1 = b1_ref[0, 0].astype(jnp.float32)
    # 1-β₁ is folded at trace time (f64) and shipped as its own operand:
    # recomputing it in f32 here is 1 ulp off the unfused XLA path and
    # breaks the use_pallas on/off bit-parity contract
    omb1 = omb1_ref[0, 0].astype(jnp.float32)
    mh = b1 * m + omb1 * g
    # divide (not rsqrt) so use_pallas=True reproduces the unfused XLA path
    # bit-for-bit in f32; rsqrt is ~1 ulp off and breaks step-parity tests
    delta = lr * mh / jnp.sqrt(v + eps)
    m_out[...] = mh.astype(m_out.dtype)
    u_out[...] = (u + lr * mh).astype(u_out.dtype)
    delta_out[...] = delta.astype(delta_out.dtype)


def fused_local_step(g, m, u, v, lr, beta1, *, eps=1e-8,
                     block=(8, 1024), interpret: bool = True):
    """One fused 0/1 Adam local step over (R, C) views.

    Returns (m', u', delta). ``lr`` traced scalar; β₁ static-ish scalar.
    """
    R, C = g.shape
    br, bc = min(block[0], R), min(block[1], C)
    assert R % br == 0 and C % bc == 0, (g.shape, block)
    grid = (R // br, C // bc)
    lr_arr = jnp.asarray(lr, jnp.float32).reshape(1, 1)
    b1_arr = jnp.asarray(beta1, jnp.float32).reshape(1, 1)
    omb1_arr = jnp.asarray(1.0 - beta1, jnp.float32).reshape(1, 1)
    tile = lambda: pl.BlockSpec((br, bc), lambda i, j: (i, j))
    scal = lambda: pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    import functools
    return pl.pallas_call(
        functools.partial(_fused_kernel, eps=eps),
        grid=grid,
        in_specs=[tile(), tile(), tile(), tile(), scal(), scal(), scal()],
        out_specs=[tile(), tile(), tile()],
        out_shape=[
            jax.ShapeDtypeStruct((R, C), m.dtype),
            jax.ShapeDtypeStruct((R, C), u.dtype),
            jax.ShapeDtypeStruct((R, C), jnp.float32),
        ],
        interpret=interpret,
    )(g, m, u, v, lr_arr, b1_arr, omb1_arr)


def _fused_kernel_sgd(g_ref, m_ref, u_ref, lr_ref, b1_ref, omb1_ref,
                      m_out, u_out, delta_out):
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    lr = lr_ref[0, 0].astype(jnp.float32)
    b1 = b1_ref[0, 0].astype(jnp.float32)
    omb1 = omb1_ref[0, 0].astype(jnp.float32)
    mh = b1 * m + omb1 * g
    delta = lr * mh
    m_out[...] = mh.astype(m_out.dtype)
    u_out[...] = (u + delta).astype(u_out.dtype)
    delta_out[...] = delta.astype(delta_out.dtype)


def fused_local_step_sgd(g, m, u, lr, beta1, *, block=(8, 1024),
                         interpret: bool = True):
    """One fused momentum-SGD local step over (R, C) views.

    Returns (m', u', delta) with delta = lr·m' — the no-variance analogue of
    :func:`fused_local_step`, bit-identical to the unfused jnp chain.
    """
    R, C = g.shape
    br, bc = min(block[0], R), min(block[1], C)
    assert R % br == 0 and C % bc == 0, (g.shape, block)
    grid = (R // br, C // bc)
    lr_arr = jnp.asarray(lr, jnp.float32).reshape(1, 1)
    b1_arr = jnp.asarray(beta1, jnp.float32).reshape(1, 1)
    omb1_arr = jnp.asarray(1.0 - beta1, jnp.float32).reshape(1, 1)
    tile = lambda: pl.BlockSpec((br, bc), lambda i, j: (i, j))
    scal = lambda: pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    return pl.pallas_call(
        _fused_kernel_sgd,
        grid=grid,
        in_specs=[tile(), tile(), tile(), scal(), scal(), scal()],
        out_specs=[tile(), tile(), tile()],
        out_shape=[
            jax.ShapeDtypeStruct((R, C), m.dtype),
            jax.ShapeDtypeStruct((R, C), u.dtype),
            jax.ShapeDtypeStruct((R, C), jnp.float32),
        ],
        interpret=interpret,
    )(g, m, u, lr_arr, b1_arr, omb1_arr)
