"""View-level dispatch: comm views -> 2-D tiles -> Pallas kernels.

This is the seam ``OptimizerConfig.use_pallas=True`` routes through. Each
function mirrors one jnp hot-path in ``repro.core`` — same argument
semantics, same output shapes, f32-identical numerics:

    ef_compress_view      <->  compressor.ef_compress (+ the caller's
                               ``z + err`` pre-add, fused into the kernel)
    server_compress_view  <->  onebit_allreduce._server_compress
    decompress_view       <->  unpack_signs(...) * scales
    fused_local_step_view <->  zero_one_adam's unfused local half-step

Views map to the kernels' (rows, cols) frame by pure reshape (see
compressor.view_to_2d); padding is carried as per-row true counts so the
kernels' scales/error-feedback are pad-exact. Scale granularities that
group multiple 2-D rows ("tensor", "chunk", and "row" with trailing view
dims) use the two-pass reduction (abs_rowsum -> O(rows) combine ->
ef_quantize); per-2-D-row granularity uses the single-pass fused kernel.
The combine step also psums over manual tensor-parallel axes and applies
``rest_factor`` global denominators, exactly like ``compressor._scales``.

Partitioning rules: views that are model-sharded over a GSPMD-*auto* mesh
axis no longer fall back to jnp — :func:`shard_context` derives the
per-shard local layout of a structured view and each view function wraps
its kernels in a manual ``shard_map`` over the view's model axes (fully
manual over every mesh axis on jax 0.4.x, whose partitioner rejects
Pallas calls inside partial-manual regions), recursing into itself with
the local layout and the model axes extended — so scales still psum to
their global values and the outputs come back sharded exactly as the
inputs were. ``kernel_safe`` is the dispatch gate: manual-TP vspecs are
handled by the psum machinery, auto-mesh vspecs require a valid
``shard_context``, and a named vspec on a *meshless* trace is only safe
when the view is the global buffer (``rest_factor == 1``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compressor as C
from repro.kernels import ops


def _largest_divisor(x: int, cap: int) -> int:
    d = min(x, cap)
    while x % d:
        d -= 1
    return d


@functools.lru_cache(maxsize=None)
def _row_counts_np(layout: C.LeafLayout) -> np.ndarray:
    return C.view_row_counts(layout)


def _counts(layout: C.LeafLayout) -> jnp.ndarray:
    return jnp.asarray(_row_counts_np(layout))


@functools.lru_cache(maxsize=None)
def _slice_counts_np(layout: C.LeafLayout) -> np.ndarray:
    return C.slice_row_counts(layout)


@functools.lru_cache(maxsize=None)
def _chunk_counts_np(layout: C.LeafLayout) -> np.ndarray:
    # cached like _row_counts_np: bucketed exchanges re-trace the server
    # compress once per bucket per pipeline stage (see
    # onebit_allreduce_buckets), and LeafLayout is hashable either way
    return C.chunk_row_counts(layout)


def _scales_to_rows(scales, lead_shape, rows, layout=None):
    """Broadcast granular scales (tensor/chunk/row shapes) over the buffer's
    leading view dims, then repeat onto frame sub-rows when the 2-D frame
    folds wider views (see compressor.view_rows_cols)."""
    s = jnp.broadcast_to(scales.astype(jnp.float32),
                         lead_shape + (1,)).reshape(-1)
    if s.shape[0] != rows:
        if s.shape[0] == 0 or rows % s.shape[0]:
            raise ValueError(
                f"cannot spread {s.shape[0]} scale rows over a {rows}-row "
                f"kernel frame (not an integer multiple); scales "
                f"{tuple(scales.shape)} broadcast over lead dims "
                f"{tuple(lead_shape)}"
                + (f", layout {layout}" if layout is not None else ""))
        s = jnp.repeat(s, rows // s.shape[0])
    return s


def kernel_codec(codec) -> bool:
    """Whether the fused Pallas path exists for this wire format.

    Only the sign-1-bit codec has kernels (this module mirrors its packed
    signs + L1 scales bit-for-bit); every other codec declares
    ``has_pallas=False`` and the exchange stays on the jnp path even when
    ``use_pallas=True`` is configured.
    """
    return bool(getattr(codec, "has_pallas", False))


def _vspec_axis_names(vspec) -> Tuple[str, ...]:
    """Flat tuple of mesh-axis names a vspec's entries reference."""
    if vspec is None:
        return ()
    names = []
    for e in tuple(vspec):
        if e is None:
            continue
        names.extend(e if isinstance(e, tuple) else (e,))
    return tuple(names)


def kernel_safe(vspec, layout: C.LeafLayout = None, model_axes=()) -> bool:
    """Whether kernel dispatch may handle a view with this tensor-parallel
    spec, given where the trace is running. Three cases:

    * the vspec's axes are all *manual* model axes (fully-manual optimizer
      region, or a sharded fused bucket): safe — the scale psum machinery
      handles them, no partitioning rule needed;
    * the vspec's axes are bound by an ambient GSPMD-*auto* mesh: safe iff
      :func:`shard_context` can derive a static per-shard layout (the view
      functions then wrap their kernels in a manual ``shard_map``, the
      partitioning rule); flatten views and non-divisible shards stay on
      the constrained jnp path;
    * the vspec names axes that no ambient mesh binds (a meshless trace
      handed a sharded vspec): safe only when the view is the GLOBAL
      buffer (``rest_factor == 1``) — a shard-LOCAL layout would silently
      skip its model psums and produce wrong scales, so that combination
      is routed to jnp where ``compressor._psum_model`` fails loudly on
      the unbound axis instead of corrupting scales.
    """
    names = _vspec_axis_names(vspec)
    if not names:
        return True
    if set(names) <= set(model_axes):
        return True
    auto = C.ambient_auto_mesh()
    if auto and all(n in auto for n in names):
        return layout is not None and shard_context(layout, vspec) is not None
    return layout is None or layout.rest_factor == 1


@dataclasses.dataclass(frozen=True)
class ShardContext:
    """Static per-shard dispatch plan for a model-sharded view (the
    partitioning rule of the Pallas path): which mesh axes the view is
    sharded over, the total shard count, and the shard-local layout
    (sharded view dims divided by their axis sizes, ``rest_factor``
    multiplied by the same factor so scale denominators stay global)."""

    names: Tuple[str, ...]     # mesh axes the vspec shards over
    factor: int                # product of those axes' sizes
    local: C.LeafLayout        # per-shard layout
    entries: Tuple             # vspec entries padded to the view rank


def shard_context(layout: C.LeafLayout, vspec):
    """Derive the per-shard dispatch plan, or None if the sharded view has
    no uniform static local layout and must stay on the jnp path.

    Only *structured* views qualify: a GSPMD-sharded flatten view's pad
    tail lands asymmetrically in the last shard, so there is no local
    layout with pad-exact static row counts. Structured views pad whole
    chunk rows along the (never sharded) split axis, so dividing the
    sharded rest dims — when the axis sizes divide them and the local
    bit-packing dim stays a multiple of 8 — yields an ordinary local
    layout every existing count/scale helper accepts.
    """
    names = _vspec_axis_names(vspec)
    if not names or layout.flatten:
        return None
    auto = C.ambient_auto_mesh()
    if not auto or any(n not in auto for n in names):
        return None
    vs = layout.view_shape
    entries = tuple(vspec)[:len(vs)]
    entries = entries + (None,) * (len(vs) - len(entries))
    local_vs, factor = [], 1
    for dim, e in zip(vs, entries):
        if e is None:
            local_vs.append(dim)
            continue
        f = 1
        for n in (e if isinstance(e, tuple) else (e,)):
            f *= auto[n]
        if f <= 0 or dim % f:
            return None
        local_vs.append(dim // f)
        factor *= f
    if factor == 1:
        return None
    if local_vs[-1] % 8:
        return None
    local = dataclasses.replace(layout, view_shape=tuple(local_vs),
                                rest_factor=layout.rest_factor * factor)
    return ShardContext(names=names, factor=factor, local=local,
                        entries=entries)


def _ambient_concrete_mesh():
    try:
        from jax.interpreters.pxla import thread_resources
        m = thread_resources.env.physical_mesh
        if not m.empty:
            return m
    except Exception:
        pass
    return None


def _shard_wrap(fn, in_specs, out_specs, ctx: ShardContext):
    """Manual ``shard_map`` around one kernel dispatch — the partitioning
    rule. On current jax the view's model axes alone go manual (the mesh is
    picked up ambiently); the jax 0.4.x partitioner rejects Pallas calls
    inside partial-manual regions (``IsManualSubgroup`` check), so there
    every mesh axis goes manual — unmentioned axes are a replicated claim,
    which holds for the optimizer's comm buffers under a pure-GSPMD trace.
    """
    from repro.core import compat
    if hasattr(jax, "shard_map"):
        return compat.shard_map(fn, in_specs=in_specs, out_specs=out_specs,
                                axis_names=ctx.names)
    mesh = _ambient_concrete_mesh()
    if mesh is None:
        raise RuntimeError(
            f"shard_context engaged for axes {ctx.names} but no concrete "
            f"mesh is ambient; on jax<0.5 the sharded kernel dispatch "
            f"needs the `with mesh:` context it was traced under")
    return compat.shard_map(fn, in_specs=in_specs, out_specs=out_specs,
                            axis_names=tuple(mesh.axis_names), mesh=mesh)


# Static VMEM budget per core for the pre-check: the hardware holds ~16
# MiB; leave headroom for compiler temporaries and double-buffering.
VMEM_BUDGET_BYTES = 12 * 1024 * 1024
# Worst-case simultaneous f32 operand blocks of one kernel invocation
# (fused_local_step: params, u, grad, err in, params/u/err out -> ~6
# distinct block-shaped refs after input/output aliasing).
_KERNEL_OPERANDS = 6


def frame_precheck(layout: C.LeafLayout, *, block_rows: int = 8,
                   vmem_budget: int = VMEM_BUDGET_BYTES) -> list:
    """Static tile-alignment / VMEM audit of one comm layout against the
    kernels' ``n*128`` frame contract. Returns a list of human-readable
    issues — empty means every kernel in this module can legally tile the
    layout's 2-D frame. Pure metadata: nothing is traced or compiled, so
    the IR-audit CLI can run it over a whole config matrix.
    """
    issues = []
    rows, cols = C.view_rows_cols(layout)
    if cols % 128:
        issues.append(
            f"frame cols={cols} not a multiple of the 128-lane tile "
            f"(layout shape {layout.shape}, view {layout.view_shape}) — "
            f"violates the n*128 flatten quantum")
    if cols % 8:
        issues.append(
            f"frame cols={cols} not a multiple of 8: sign-bit packing "
            f"needs byte-aligned rows")
    if cols > C.FRAME_MAX_COLS:
        issues.append(
            f"frame cols={cols} exceeds FRAME_MAX_COLS={C.FRAME_MAX_COLS} "
            f"— view_rows_cols should have folded this view")
    br = _largest_divisor(rows, block_rows) if rows else 0
    est = _KERNEL_OPERANDS * br * cols * 4
    if est > vmem_budget:
        issues.append(
            f"block ({br}, {cols}) f32 working set ~{est} B exceeds the "
            f"~{vmem_budget} B VMEM budget ({_KERNEL_OPERANDS} operand "
            f"blocks)")
    return issues


def _row_group_scales(rowsum, shape, rest_factor, model_axes):
    """Row-granularity scales for a buffer of the given (lead, chunk, *rest)
    shape: one scale per (lead, chunk-row) pair, i.e. per group of
    prod(rest[:-1]) 2-D rows, divided by the full (global) rest extent —
    padding is whole rows, already zeroed in the masked rowsums. Shared by
    the worker view (lead = n) and the server chunk (lead = 1)."""
    ndim = len(shape)
    group = int(np.prod(shape[2:-1])) if ndim > 3 else 1
    rest = max(int(np.prod(shape[2:])) * rest_factor, 1)
    rs = rowsum.reshape(shape[0], shape[1], group).sum(axis=-1)
    s = C._psum_model(rs, model_axes) / rest
    return s.reshape(shape[:2] + (1,) * (ndim - 2))


def _combine_scales(rowsum, layout: C.LeafLayout, mode: C.ScaleMode,
                    model_axes, inner_index=None):
    """Masked per-row L1 sums (R,) -> scales shaped like compressor._scales.

    With ``inner_index`` the buffer is one inner reduce-scatter slice
    (n_outer leading chunks) and the denominators are the statically
    precomputed per-slice counts selected by the traced index — mirroring
    ``compressor._slice_scales``.
    """
    vs = layout.view_shape
    ndim = len(vs)
    rf = layout.rest_factor
    if inner_index is None:
        lead, shape = vs[0], vs
        total, per_chunk = C.true_counts(layout)
        denom = total * rf
        cnt = jnp.asarray(np.maximum(per_chunk * rf, 1.0), jnp.float32)
    else:
        lead, shape = layout.n_outer, layout.slice_shape
        totals, per_chunk = C.slice_true_counts(layout)
        denom = jnp.take(jnp.asarray(np.maximum(totals * rf, 1.0),
                                     jnp.float32), inner_index)
        cnt = jnp.take(jnp.asarray(np.maximum(per_chunk * rf, 1.0),
                                   jnp.float32), inner_index, axis=0)
    if mode == "tensor":
        s = C._psum_model(rowsum.sum(), model_axes) / denom
        return s.reshape((1,) * ndim)
    if mode == "chunk":
        cs = rowsum.reshape(lead, -1).sum(axis=1)
        s = C._psum_model(cs, model_axes) / cnt
        return s.reshape((lead,) + (1,) * (ndim - 1))
    return _row_group_scales(rowsum, shape, rf, model_axes)


def ef_compress_view(z, err, layout: C.LeafLayout, mode: C.ScaleMode,
                     model_axes=(), inner_index=None, vspec=None):
    """Worker-side fused EF-compress of a comm view.

    Fuses the caller's ``z + err`` accumulation; returns
    (packed view, scales shaped like compressor._scales, err view).

    With ``inner_index`` the buffer is the inner reduce-scatter slice of the
    hierarchical path (``layout.slice_shape``): the frame shrinks to the
    slice's contiguous block of rows and the pad-exact row counts/denominators
    are selected by the traced intra-pod index.

    With ``vspec`` naming ambient GSPMD-auto mesh axes the kernels run
    per shard under a manual ``shard_map`` (see :func:`shard_context`):
    this function recurses on the shard-local layout with the model axes
    extended by the view's axes, so the scales psum to their global values
    and packed/err come back sharded exactly like the inputs.
    """
    ctx = shard_context(layout, vspec)
    if ctx is not None:
        from jax.sharding import PartitionSpec as P
        pv = P(*ctx.entries)
        ma = tuple(model_axes) + ctx.names

        def body(z_l, e_l, j):
            return ef_compress_view(
                z_l, e_l, ctx.local, mode, ma,
                inner_index=(j if inner_index is not None else None))

        j_in = (inner_index if inner_index is not None
                else jnp.zeros((), jnp.int32))
        return _shard_wrap(body, in_specs=(pv, pv, P()),
                           out_specs=(pv, P(), pv), ctx=ctx)(z, err, j_in)
    rows, cols = C.view_rows_cols(layout)
    vs = layout.view_shape
    ndim = len(vs)
    eff = "chunk" if (mode == "row" and ndim == 2) else mode
    if inner_index is None:
        bshape, cnts = vs, _counts(layout)
    else:
        bshape = layout.slice_shape
        rows = rows // layout.n_inner
        cnts = jnp.take(jnp.asarray(_slice_counts_np(layout)), inner_index,
                        axis=0)
    z2, e2 = z.reshape(rows, cols), err.reshape(rows, cols)
    br = _largest_divisor(rows, 8)
    if eff == "row" and ndim == 3 and not model_axes and \
            layout.rest_factor == 1:
        # per-2-D-row scales: the single-pass fully fused kernel applies
        packed2, srow, err2 = ops.ef_compress(z2, e2, cnts, block_rows=br)
        scales = srow.reshape(bshape[:2] + (1,) * (ndim - 2))
    else:
        rowsum = ops.abs_rowsum(z2, e2, cnts, block_rows=br)
        scales = _combine_scales(rowsum, layout, eff, model_axes,
                                 inner_index)
        srow = _scales_to_rows(scales, bshape[:-1], rows, layout)
        packed2, err2 = ops.ef_quantize(z2, e2, srow, cnts, block_rows=br)
    return (packed2.reshape(bshape[:-1] + (-1,)), scales,
            err2.reshape(bshape).astype(err.dtype))


def server_compress_view(avg, err, layout: C.LeafLayout, mode: C.ScaleMode,
                         worker_index, model_axes=(), vspec=None):
    """Server-side fused EF-compress of one chunk (leading dim 1).

    Mirrors onebit_allreduce._server_compress with the ``avg + err`` add
    fused in. Not applicable to row granularity on 2-D (flatten) views —
    that degenerates to per-element scales; callers keep the jnp path there.
    ``vspec`` (the VIEW's entries — the chunk shares the view rank) engages
    the per-shard dispatch exactly like :func:`ef_compress_view`.
    """
    ctx = shard_context(layout, vspec)
    if ctx is not None:
        from jax.sharding import PartitionSpec as P
        pv = P(*ctx.entries)
        ma = tuple(model_axes) + ctx.names

        def body(a_l, e_l, w):
            return server_compress_view(a_l, e_l, ctx.local, mode, w, ma)

        return _shard_wrap(body, in_specs=(pv, pv, P()),
                           out_specs=(pv, P(), pv),
                           ctx=ctx)(avg, err, worker_index)
    ys = avg.shape
    ndim = len(ys)
    assert not (mode == "row" and ndim == 2)
    rows_all, cols = C.view_rows_cols(layout)
    rows = rows_all // layout.n   # the frame splits chunks into equal blocks
    cnts = jnp.take(jnp.asarray(_chunk_counts_np(layout)), worker_index,
                    axis=0)
    z2, e2 = avg.reshape(rows, cols), err.reshape(rows, cols)
    br = _largest_divisor(rows, 8)
    rowsum = ops.abs_rowsum(z2, e2, cnts, block_rows=br)
    rf = layout.rest_factor
    if mode == "row":
        scales = _row_group_scales(rowsum, ys, rf, model_axes)
    else:  # tensor / chunk -> one scale for this chunk
        denom = jnp.maximum(cnts.sum().astype(jnp.float32) * rf, 1.0)
        s = C._psum_model(rowsum.sum(), model_axes) / denom
        scales = s.reshape((1,) * ndim)
    srow = _scales_to_rows(scales, ys[:-1], rows, layout)
    packed2, err2 = ops.ef_quantize(z2, e2, srow, cnts, block_rows=br)
    return (packed2.reshape(ys[:-1] + (ys[-1] // 8,)), scales,
            err2.reshape(ys).astype(err.dtype))


def decompress_view(packed, scales, layout: C.LeafLayout,
                    dtype=jnp.float32, vspec=None):
    """Fused unpack·scale of a view-shaped packed buffer (the a2a receive
    or the gathered chunk results — both carry the full view shape).

    ``scales`` must broadcast against the packed array's leading dims (the
    shapes _scales / server compression produce for tensor/chunk/row modes).
    Slice-shaped buffers of the hierarchical path (leading dim n_outer
    instead of n) shrink the frame proportionally. ``vspec`` engages the
    per-shard dispatch (scales are already replicated — post-psum — so
    only the packed bits and the output are sharded).
    """
    ctx = shard_context(layout, vspec)
    if ctx is not None:
        from jax.sharding import PartitionSpec as P
        pv = P(*ctx.entries)

        def body(p_l, s_l):
            return decompress_view(p_l, s_l, ctx.local, dtype)

        return _shard_wrap(body, in_specs=(pv, P()), out_specs=pv,
                           ctx=ctx)(packed, scales)
    rows, cols = C.view_rows_cols(layout)
    rows = (rows * int(np.prod(packed.shape[:-1]))
            // int(np.prod(layout.view_shape[:-1])))
    p2 = packed.reshape(rows, cols // 8)
    srow = _scales_to_rows(scales, packed.shape[:-1], rows, layout)
    out2 = ops.decompress(p2, srow, block_rows=_largest_divisor(rows, 8),
                          dtype=dtype)
    return out2.reshape(packed.shape[:-1] + (layout.pack_count,))


def fused_local_step_view(g, m, u, v, lr, beta1, eps,
                          layout: C.LeafLayout, kind: str = "adam",
                          vspec=None):
    """Fused local half-step over one leaf's comm view, keyed on the base
    kind ("adam" | "lamb" | "sgd" — see repro.core.base_steps).

    Returns (m', u', delta) in view shape — identical math to the unfused
    three-sweep XLA chain, in one VMEM pass. "adam" and "lamb" share the
    variance-preconditioned kernel (``v`` required; the caller applies the
    LAMB trust scalar to ``delta`` afterwards); "sgd" uses the no-variance
    kernel (``v`` ignored, may be None). ``vspec`` engages the per-shard
    dispatch — the step is elementwise, so the local call needs no psums.
    """
    ctx = shard_context(layout, vspec)
    if ctx is not None:
        from jax.sharding import PartitionSpec as P
        pv = P(*ctx.entries)
        if kind == "sgd":
            def body(g_l, m_l, u_l, lr_l):
                return fused_local_step_view(g_l, m_l, u_l, None, lr_l,
                                             beta1, eps, ctx.local, kind)
            return _shard_wrap(body, in_specs=(pv, pv, pv, P()),
                               out_specs=(pv, pv, pv),
                               ctx=ctx)(g, m, u, jnp.asarray(lr))

        def body(g_l, m_l, u_l, v_l, lr_l):
            return fused_local_step_view(g_l, m_l, u_l, v_l, lr_l,
                                         beta1, eps, ctx.local, kind)
        return _shard_wrap(body, in_specs=(pv, pv, pv, pv, P()),
                           out_specs=(pv, pv, pv),
                           ctx=ctx)(g, m, u, v, jnp.asarray(lr))
    rows, cols = C.view_rows_cols(layout)
    vs = layout.view_shape
    r2 = lambda a: a.reshape(rows, cols)
    block = (_largest_divisor(rows, 8), _largest_divisor(cols, 1024))
    if kind == "sgd":
        mh2, uh2, d2 = ops.fused_local_step_sgd(r2(g), r2(m), r2(u), lr,
                                                beta1, block=block)
    elif kind in ("adam", "lamb"):
        mh2, uh2, d2 = ops.fused_local_step(r2(g), r2(m), r2(u), r2(v), lr,
                                            beta1, eps, block=block)
    else:
        raise ValueError(f"unknown base kind {kind!r} for the fused "
                         f"local step")
    return mh2.reshape(vs), uh2.reshape(vs), d2.reshape(vs)
