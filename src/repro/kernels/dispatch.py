"""View-level dispatch: comm views -> 2-D tiles -> Pallas kernels.

This is the seam ``OptimizerConfig.use_pallas=True`` routes through. Each
function mirrors one jnp hot-path in ``repro.core`` — same argument
semantics, same output shapes, f32-identical numerics:

    ef_compress_view      <->  compressor.ef_compress (+ the caller's
                               ``z + err`` pre-add, fused into the kernel)
    server_compress_view  <->  onebit_allreduce._server_compress
    decompress_view       <->  unpack_signs(...) * scales
    fused_local_step_view <->  zero_one_adam's unfused local half-step

Views map to the kernels' (rows, cols) frame by pure reshape (see
compressor.view_to_2d); padding is carried as per-row true counts so the
kernels' scales/error-feedback are pad-exact. Scale granularities that
group multiple 2-D rows ("tensor", "chunk", and "row" with trailing view
dims) use the two-pass reduction (abs_rowsum -> O(rows) combine ->
ef_quantize); per-2-D-row granularity uses the single-pass fused kernel.
The combine step also psums over manual tensor-parallel axes and applies
``rest_factor`` global denominators, exactly like ``compressor._scales``.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from repro.core import compressor as C
from repro.kernels import ops


def _largest_divisor(x: int, cap: int) -> int:
    d = min(x, cap)
    while x % d:
        d -= 1
    return d


@functools.lru_cache(maxsize=None)
def _row_counts_np(layout: C.LeafLayout) -> np.ndarray:
    return C.view_row_counts(layout)


def _counts(layout: C.LeafLayout) -> jnp.ndarray:
    return jnp.asarray(_row_counts_np(layout))


@functools.lru_cache(maxsize=None)
def _slice_counts_np(layout: C.LeafLayout) -> np.ndarray:
    return C.slice_row_counts(layout)


@functools.lru_cache(maxsize=None)
def _chunk_counts_np(layout: C.LeafLayout) -> np.ndarray:
    # cached like _row_counts_np: bucketed exchanges re-trace the server
    # compress once per bucket per pipeline stage (see
    # onebit_allreduce_buckets), and LeafLayout is hashable either way
    return C.chunk_row_counts(layout)


def _scales_to_rows(scales, lead_shape, rows):
    """Broadcast granular scales (tensor/chunk/row shapes) over the buffer's
    leading view dims, then repeat onto frame sub-rows when the 2-D frame
    folds wider views (see compressor.view_rows_cols)."""
    s = jnp.broadcast_to(scales.astype(jnp.float32),
                         lead_shape + (1,)).reshape(-1)
    if s.shape[0] != rows:
        s = jnp.repeat(s, rows // s.shape[0])
    return s


def kernel_codec(codec) -> bool:
    """Whether the fused Pallas path exists for this wire format.

    Only the sign-1-bit codec has kernels (this module mirrors its packed
    signs + L1 scales bit-for-bit); every other codec declares
    ``has_pallas=False`` and the exchange stays on the jnp path even when
    ``use_pallas=True`` is configured.
    """
    return bool(getattr(codec, "has_pallas", False))


def kernel_safe(vspec) -> bool:
    """Whether kernel dispatch may handle a view with this tensor-parallel
    spec. Pallas calls carry no GSPMD partitioning rules yet, so a view
    that is model-sharded over an ambient *auto* mesh axis must stay on
    the jnp path — otherwise XLA all-gathers the view onto every chip at
    the kernel boundary (the exact regression ``compressor.constrain``
    exists to prevent). Fully-manual meshes (model axes Manual) and
    meshless runs are safe.
    """
    if vspec is None or all(e is None for e in tuple(vspec)):
        return True
    return not C.ambient_auto_mesh()


# Static VMEM budget per core for the pre-check: the hardware holds ~16
# MiB; leave headroom for compiler temporaries and double-buffering.
VMEM_BUDGET_BYTES = 12 * 1024 * 1024
# Worst-case simultaneous f32 operand blocks of one kernel invocation
# (fused_local_step: params, u, grad, err in, params/u/err out -> ~6
# distinct block-shaped refs after input/output aliasing).
_KERNEL_OPERANDS = 6


def frame_precheck(layout: C.LeafLayout, *, block_rows: int = 8,
                   vmem_budget: int = VMEM_BUDGET_BYTES) -> list:
    """Static tile-alignment / VMEM audit of one comm layout against the
    kernels' ``n*128`` frame contract. Returns a list of human-readable
    issues — empty means every kernel in this module can legally tile the
    layout's 2-D frame. Pure metadata: nothing is traced or compiled, so
    the IR-audit CLI can run it over a whole config matrix.
    """
    issues = []
    rows, cols = C.view_rows_cols(layout)
    if cols % 128:
        issues.append(
            f"frame cols={cols} not a multiple of the 128-lane tile "
            f"(layout shape {layout.shape}, view {layout.view_shape}) — "
            f"violates the n*128 flatten quantum")
    if cols % 8:
        issues.append(
            f"frame cols={cols} not a multiple of 8: sign-bit packing "
            f"needs byte-aligned rows")
    if cols > C.FRAME_MAX_COLS:
        issues.append(
            f"frame cols={cols} exceeds FRAME_MAX_COLS={C.FRAME_MAX_COLS} "
            f"— view_rows_cols should have folded this view")
    br = _largest_divisor(rows, block_rows) if rows else 0
    est = _KERNEL_OPERANDS * br * cols * 4
    if est > vmem_budget:
        issues.append(
            f"block ({br}, {cols}) f32 working set ~{est} B exceeds the "
            f"~{vmem_budget} B VMEM budget ({_KERNEL_OPERANDS} operand "
            f"blocks)")
    return issues


def _row_group_scales(rowsum, shape, rest_factor, model_axes):
    """Row-granularity scales for a buffer of the given (lead, chunk, *rest)
    shape: one scale per (lead, chunk-row) pair, i.e. per group of
    prod(rest[:-1]) 2-D rows, divided by the full (global) rest extent —
    padding is whole rows, already zeroed in the masked rowsums. Shared by
    the worker view (lead = n) and the server chunk (lead = 1)."""
    ndim = len(shape)
    group = int(np.prod(shape[2:-1])) if ndim > 3 else 1
    rest = max(int(np.prod(shape[2:])) * rest_factor, 1)
    rs = rowsum.reshape(shape[0], shape[1], group).sum(axis=-1)
    s = C._psum_model(rs, model_axes) / rest
    return s.reshape(shape[:2] + (1,) * (ndim - 2))


def _combine_scales(rowsum, layout: C.LeafLayout, mode: C.ScaleMode,
                    model_axes, inner_index=None):
    """Masked per-row L1 sums (R,) -> scales shaped like compressor._scales.

    With ``inner_index`` the buffer is one inner reduce-scatter slice
    (n_outer leading chunks) and the denominators are the statically
    precomputed per-slice counts selected by the traced index — mirroring
    ``compressor._slice_scales``.
    """
    vs = layout.view_shape
    ndim = len(vs)
    rf = layout.rest_factor
    if inner_index is None:
        lead, shape = vs[0], vs
        total, per_chunk = C.true_counts(layout)
        denom = total * rf
        cnt = jnp.asarray(np.maximum(per_chunk * rf, 1.0), jnp.float32)
    else:
        lead, shape = layout.n_outer, layout.slice_shape
        totals, per_chunk = C.slice_true_counts(layout)
        denom = jnp.take(jnp.asarray(np.maximum(totals * rf, 1.0),
                                     jnp.float32), inner_index)
        cnt = jnp.take(jnp.asarray(np.maximum(per_chunk * rf, 1.0),
                                   jnp.float32), inner_index, axis=0)
    if mode == "tensor":
        s = C._psum_model(rowsum.sum(), model_axes) / denom
        return s.reshape((1,) * ndim)
    if mode == "chunk":
        cs = rowsum.reshape(lead, -1).sum(axis=1)
        s = C._psum_model(cs, model_axes) / cnt
        return s.reshape((lead,) + (1,) * (ndim - 1))
    return _row_group_scales(rowsum, shape, rf, model_axes)


def ef_compress_view(z, err, layout: C.LeafLayout, mode: C.ScaleMode,
                     model_axes=(), inner_index=None):
    """Worker-side fused EF-compress of a comm view.

    Fuses the caller's ``z + err`` accumulation; returns
    (packed view, scales shaped like compressor._scales, err view).

    With ``inner_index`` the buffer is the inner reduce-scatter slice of the
    hierarchical path (``layout.slice_shape``): the frame shrinks to the
    slice's contiguous block of rows and the pad-exact row counts/denominators
    are selected by the traced intra-pod index.
    """
    rows, cols = C.view_rows_cols(layout)
    vs = layout.view_shape
    ndim = len(vs)
    eff = "chunk" if (mode == "row" and ndim == 2) else mode
    if inner_index is None:
        bshape, cnts = vs, _counts(layout)
    else:
        bshape = layout.slice_shape
        rows = rows // layout.n_inner
        cnts = jnp.take(jnp.asarray(_slice_counts_np(layout)), inner_index,
                        axis=0)
    z2, e2 = z.reshape(rows, cols), err.reshape(rows, cols)
    br = _largest_divisor(rows, 8)
    if eff == "row" and ndim == 3 and not model_axes and \
            layout.rest_factor == 1:
        # per-2-D-row scales: the single-pass fully fused kernel applies
        packed2, srow, err2 = ops.ef_compress(z2, e2, cnts, block_rows=br)
        scales = srow.reshape(bshape[:2] + (1,) * (ndim - 2))
    else:
        rowsum = ops.abs_rowsum(z2, e2, cnts, block_rows=br)
        scales = _combine_scales(rowsum, layout, eff, model_axes,
                                 inner_index)
        srow = _scales_to_rows(scales, bshape[:-1], rows)
        packed2, err2 = ops.ef_quantize(z2, e2, srow, cnts, block_rows=br)
    return (packed2.reshape(bshape[:-1] + (-1,)), scales,
            err2.reshape(bshape).astype(err.dtype))


def server_compress_view(avg, err, layout: C.LeafLayout, mode: C.ScaleMode,
                         worker_index, model_axes=()):
    """Server-side fused EF-compress of one chunk (leading dim 1).

    Mirrors onebit_allreduce._server_compress with the ``avg + err`` add
    fused in. Not applicable to row granularity on 2-D (flatten) views —
    that degenerates to per-element scales; callers keep the jnp path there.
    """
    ys = avg.shape
    ndim = len(ys)
    assert not (mode == "row" and ndim == 2)
    rows_all, cols = C.view_rows_cols(layout)
    rows = rows_all // layout.n   # the frame splits chunks into equal blocks
    cnts = jnp.take(jnp.asarray(_chunk_counts_np(layout)), worker_index,
                    axis=0)
    z2, e2 = avg.reshape(rows, cols), err.reshape(rows, cols)
    br = _largest_divisor(rows, 8)
    rowsum = ops.abs_rowsum(z2, e2, cnts, block_rows=br)
    rf = layout.rest_factor
    if mode == "row":
        scales = _row_group_scales(rowsum, ys, rf, model_axes)
    else:  # tensor / chunk -> one scale for this chunk
        denom = jnp.maximum(cnts.sum().astype(jnp.float32) * rf, 1.0)
        s = C._psum_model(rowsum.sum(), model_axes) / denom
        scales = s.reshape((1,) * ndim)
    srow = _scales_to_rows(scales, ys[:-1], rows)
    packed2, err2 = ops.ef_quantize(z2, e2, srow, cnts, block_rows=br)
    return (packed2.reshape(ys[:-1] + (ys[-1] // 8,)), scales,
            err2.reshape(ys).astype(err.dtype))


def decompress_view(packed, scales, layout: C.LeafLayout,
                    dtype=jnp.float32):
    """Fused unpack·scale of a view-shaped packed buffer (the a2a receive
    or the gathered chunk results — both carry the full view shape).

    ``scales`` must broadcast against the packed array's leading dims (the
    shapes _scales / server compression produce for tensor/chunk/row modes).
    Slice-shaped buffers of the hierarchical path (leading dim n_outer
    instead of n) shrink the frame proportionally.
    """
    rows, cols = C.view_rows_cols(layout)
    rows = (rows * int(np.prod(packed.shape[:-1]))
            // int(np.prod(layout.view_shape[:-1])))
    p2 = packed.reshape(rows, cols // 8)
    srow = _scales_to_rows(scales, packed.shape[:-1], rows)
    out2 = ops.decompress(p2, srow, block_rows=_largest_divisor(rows, 8),
                          dtype=dtype)
    return out2.reshape(packed.shape[:-1] + (layout.pack_count,))


def fused_local_step_view(g, m, u, v, lr, beta1, eps,
                          layout: C.LeafLayout, kind: str = "adam"):
    """Fused local half-step over one leaf's comm view, keyed on the base
    kind ("adam" | "lamb" | "sgd" — see repro.core.base_steps).

    Returns (m', u', delta) in view shape — identical math to the unfused
    three-sweep XLA chain, in one VMEM pass. "adam" and "lamb" share the
    variance-preconditioned kernel (``v`` required; the caller applies the
    LAMB trust scalar to ``delta`` afterwards); "sgd" uses the no-variance
    kernel (``v`` ignored, may be None).
    """
    rows, cols = C.view_rows_cols(layout)
    vs = layout.view_shape
    r2 = lambda a: a.reshape(rows, cols)
    block = (_largest_divisor(rows, 8), _largest_divisor(cols, 1024))
    if kind == "sgd":
        mh2, uh2, d2 = ops.fused_local_step_sgd(r2(g), r2(m), r2(u), lr,
                                                beta1, block=block)
    elif kind in ("adam", "lamb"):
        mh2, uh2, d2 = ops.fused_local_step(r2(g), r2(m), r2(u), r2(v), lr,
                                            beta1, eps, block=block)
    else:
        raise ValueError(f"unknown base kind {kind!r} for the fused "
                         f"local step")
    return mh2.reshape(vs), uh2.reshape(vs), d2.reshape(vs)
