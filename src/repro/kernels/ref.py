"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ef_compress_ref(z, err):
    """(R, C) -> (packed u8 (R, C//8), scales f32 (R,), err_out)."""
    zw = z.astype(jnp.float32) + err.astype(jnp.float32)
    s = jnp.abs(zw).mean(axis=1)
    bits = zw >= 0
    packed = jnp.packbits(bits.astype(jnp.uint8), axis=-1, bitorder="big")
    zhat = jnp.where(bits, s[:, None], -s[:, None])
    return packed, s, (zw - zhat).astype(err.dtype)


def decompress_ref(packed, scales, dtype=jnp.float32):
    bits = jnp.unpackbits(packed, axis=-1, bitorder="big")
    vals = bits.astype(jnp.float32) * 2.0 - 1.0
    return (vals * scales[:, None].astype(jnp.float32)).astype(dtype)


def fused_local_step_ref(g, m, u, v, lr, beta1, eps=1e-8):
    g32, m32 = g.astype(jnp.float32), m.astype(jnp.float32)
    u32, v32 = u.astype(jnp.float32), v.astype(jnp.float32)
    mh = beta1 * m32 + (1.0 - beta1) * g32
    delta = lr * mh / jnp.sqrt(v32 + eps)
    return mh.astype(m.dtype), (u32 + lr * mh).astype(u.dtype), delta
