"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Each oracle mirrors one kernel in onebit.py / fused_adam.py, including the
mask-aware semantics: ``counts`` is the per-row true-element count (None
means no padding), identical to what the kernels receive.
"""
from __future__ import annotations

import jax.numpy as jnp


def _mask(counts, R, C):
    if counts is None:
        return jnp.ones((R, C), bool)
    return jnp.arange(C, dtype=jnp.int32)[None, :] < counts[:, None]


def ef_compress_ref(z, err, counts=None):
    """(R, C) -> (packed u8 (R, C//8), per-row scales f32 (R,), err_out)."""
    zw = z.astype(jnp.float32) + err.astype(jnp.float32)
    R, C = zw.shape
    m = _mask(counts, R, C)
    denom = (jnp.full((R,), float(C)) if counts is None
             else jnp.maximum(counts.astype(jnp.float32), 1.0))
    s = jnp.where(m, jnp.abs(zw), 0.0).sum(axis=1) / denom
    bits = zw >= 0
    packed = jnp.packbits(bits.astype(jnp.uint8), axis=-1, bitorder="big")
    zhat = jnp.where(bits, s[:, None], -s[:, None])
    return packed, s, jnp.where(m, zw - zhat, 0.0).astype(err.dtype)


def abs_rowsum_ref(z, err, counts=None):
    zw = z.astype(jnp.float32) + err.astype(jnp.float32)
    R, C = zw.shape
    return jnp.where(_mask(counts, R, C), jnp.abs(zw), 0.0).sum(axis=1)


def ef_quantize_ref(z, err, scales, counts=None):
    zw = z.astype(jnp.float32) + err.astype(jnp.float32)
    R, C = zw.shape
    bits = zw >= 0
    packed = jnp.packbits(bits.astype(jnp.uint8), axis=-1, bitorder="big")
    s = scales.astype(jnp.float32)
    zhat = jnp.where(bits, s[:, None], -s[:, None])
    return packed, jnp.where(_mask(counts, R, C), zw - zhat,
                             0.0).astype(err.dtype)


def decompress_ref(packed, scales, dtype=jnp.float32):
    bits = jnp.unpackbits(packed, axis=-1, bitorder="big")
    vals = bits.astype(jnp.float32) * 2.0 - 1.0
    return (vals * scales[:, None].astype(jnp.float32)).astype(dtype)


def fused_local_step_ref(g, m, u, v, lr, beta1, eps=1e-8):
    g32, m32 = g.astype(jnp.float32), m.astype(jnp.float32)
    u32, v32 = u.astype(jnp.float32), v.astype(jnp.float32)
    mh = beta1 * m32 + (1.0 - beta1) * g32
    delta = lr * mh / jnp.sqrt(v32 + eps)
    return mh.astype(m.dtype), (u32 + lr * mh).astype(u.dtype), delta
