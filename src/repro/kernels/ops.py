"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (the kernels execute in Python via
the Pallas interpreter for correctness validation); on TPU the same calls
compile to fused Mosaic kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import fused_adam as _fa
from repro.kernels import onebit as _ob


def _interpret_default():
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def ef_compress(z, err, block_rows: int = 8, interpret: bool | None = None):
    if interpret is None:
        interpret = _interpret_default()
    return _ob.ef_compress(z, err, block_rows=block_rows,
                           interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret",
                                             "dtype"))
def decompress(packed, scales, block_rows: int = 8,
               interpret: bool | None = None, dtype=jnp.float32):
    if interpret is None:
        interpret = _interpret_default()
    return _ob.decompress(packed, scales, block_rows=block_rows,
                          interpret=interpret, dtype=dtype)


@functools.partial(jax.jit, static_argnames=("beta1", "eps", "block",
                                             "interpret"))
def fused_local_step(g, m, u, v, lr, beta1: float = 0.9, eps: float = 1e-8,
                     block=(8, 1024), interpret: bool | None = None):
    if interpret is None:
        interpret = _interpret_default()
    return _fa.fused_local_step(g, m, u, v, lr, beta1, eps=eps, block=block,
                                interpret=interpret)
