"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (the kernels execute via the Pallas
interpreter for correctness validation); on TPU the same calls compile to
fused Mosaic kernels.

All wrappers take 2-D (rows, cols) operands; ``counts`` is the optional
per-row true-element count for pad-exact scales/error-feedback (None means
no padding). View-shaped callers go through ``repro.kernels.dispatch``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import fused_adam as _fa
from repro.kernels import onebit as _ob


def _interpret_default():
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def ef_compress(z, err, counts=None, block_rows: int = 8,
                interpret: bool | None = None):
    """Single-pass fused EF-compress with per-row scales."""
    if interpret is None:
        interpret = _interpret_default()
    return _ob.ef_compress(z, err, counts, block_rows=block_rows,
                           interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def abs_rowsum(z, err, counts=None, block_rows: int = 8,
               interpret: bool | None = None):
    """Masked per-row L1 sums of z + err (two-pass compress, pass 1)."""
    if interpret is None:
        interpret = _interpret_default()
    return _ob.abs_rowsum(z, err, counts, block_rows=block_rows,
                          interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def ef_quantize(z, err, scales, counts=None, block_rows: int = 8,
                interpret: bool | None = None):
    """Quantize z + err against per-row scales (two-pass compress, pass 2)."""
    if interpret is None:
        interpret = _interpret_default()
    return _ob.ef_quantize(z, err, scales, counts, block_rows=block_rows,
                           interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret",
                                             "dtype"))
def decompress(packed, scales, block_rows: int = 8,
               interpret: bool | None = None, dtype=jnp.float32):
    if interpret is None:
        interpret = _interpret_default()
    return _ob.decompress(packed, scales, block_rows=block_rows,
                          interpret=interpret, dtype=dtype)


@functools.partial(jax.jit, static_argnames=("beta1", "eps", "block",
                                             "interpret"))
def fused_local_step(g, m, u, v, lr, beta1: float = 0.9, eps: float = 1e-8,
                     block=(8, 1024), interpret: bool | None = None):
    if interpret is None:
        interpret = _interpret_default()
    return _fa.fused_local_step(g, m, u, v, lr, beta1, eps=eps, block=block,
                                interpret=interpret)


@functools.partial(jax.jit, static_argnames=("beta1", "block", "interpret"))
def fused_local_step_sgd(g, m, u, lr, beta1: float = 0.9,
                         block=(8, 1024), interpret: bool | None = None):
    if interpret is None:
        interpret = _interpret_default()
    return _fa.fused_local_step_sgd(g, m, u, lr, beta1, block=block,
                                    interpret=interpret)
