"""Pallas TPU kernels for the paper's compute hot-spots (optimizer side):
fused error-feedback 1-bit compress/decompress + fused 0/1 Adam local step.
Validated with interpret=True against ref.py oracles on CPU.
"""
from repro.kernels import ops, ref
