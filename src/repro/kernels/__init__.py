"""Pallas TPU kernels for the paper's compute hot-spots (optimizer side):
fused error-feedback 1-bit compress/decompress + fused 0/1 Adam local step.

``ops`` exposes the jitted 2-D kernel wrappers, ``ref`` their pure-jnp
oracles, and ``dispatch`` the comm-view-level glue that
``OptimizerConfig.use_pallas=True`` routes through. Validated with
interpret=True against ref.py on CPU; on TPU the same calls compile to
fused Mosaic kernels.
"""
from repro.kernels import dispatch, ops, ref

__all__ = ["dispatch", "ops", "ref"]
