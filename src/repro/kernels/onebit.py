"""Pallas TPU kernel: fused error-feedback 1-bit compression.

The compression hot-path of 0/1 Adam touches every parameter byte three
times when expressed as separate XLA ops (add error, compute scale+sign,
write error). This kernel fuses the whole worker-side EF-compress into one
VMEM pass per tile:

    zw   = z + err_in
    s    = mean(|zw|) per row            (the "row" scale granularity)
    bits = zw >= 0  -> packed uint8 (8 lanes per byte)
    err  = zw - sign(zw)·s

Layout: operands are 2-D (rows, cols) — the optimizer's comm views flatten
to this. Tiles are (BLOCK_R, cols): a full row per tile so the scale
reduction stays in-register; cols must be a multiple of 128 for lane
alignment and of 8 for packing (the comm-view layouts guarantee both).

TPU is the TARGET; correctness is validated on CPU with interpret=True
against ref.py (tests/test_kernels.py sweeps shapes/dtypes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

def _ef_compress_kernel(z_ref, err_ref, packed_ref, scale_ref, errout_ref):
    zw = z_ref[...].astype(jnp.float32) + err_ref[...].astype(jnp.float32)
    r, c = zw.shape
    s = jnp.abs(zw).mean(axis=1)                       # (BLOCK_R,)
    bits = (zw >= 0)
    b8 = bits.reshape(r, c // 8, 8).astype(jnp.uint8)
    weights = (jnp.uint8(128) >> jax.lax.broadcasted_iota(
        jnp.uint8, (1, 1, 8), 2))
    packed_ref[...] = (b8 * weights).sum(axis=-1).astype(jnp.uint8)
    scale_ref[...] = s.astype(scale_ref.dtype)
    zhat = jnp.where(bits, s[:, None], -s[:, None])
    errout_ref[...] = (zw - zhat).astype(errout_ref.dtype)


def ef_compress(z: jnp.ndarray, err: jnp.ndarray, *, block_rows: int = 8,
                interpret: bool = True):
    """Fused EF 1-bit compress over (R, C). Returns (packed u8 (R, C//8),
    scales f32 (R,), err_out like err)."""
    R, C = z.shape
    assert C % 8 == 0, C
    assert R % block_rows == 0, (R, block_rows)
    grid = (R // block_rows,)
    return pl.pallas_call(
        _ef_compress_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, C // 8), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, C // 8), jnp.uint8),
            jax.ShapeDtypeStruct((R,), jnp.float32),
            jax.ShapeDtypeStruct((R, C), err.dtype),
        ],
        interpret=interpret,
    )(z, err)


def _decompress_kernel(packed_ref, scale_ref, out_ref):
    p = packed_ref[...]
    r, cb = p.shape
    shifts = jnp.uint8(7) - jax.lax.broadcasted_iota(jnp.uint8, (1, 1, 8), 2)
    bits = jnp.right_shift(p[:, :, None], shifts) & 1
    vals = bits.astype(jnp.float32) * 2.0 - 1.0
    s = scale_ref[...].astype(jnp.float32)
    out_ref[...] = (vals.reshape(r, cb * 8)
                    * s[:, None]).astype(out_ref.dtype)


def decompress(packed: jnp.ndarray, scales: jnp.ndarray, *,
               block_rows: int = 8, interpret: bool = True,
               dtype=jnp.float32):
    """Inverse quantizer over (R, C//8) packed + per-row scales."""
    R, CB = packed.shape
    assert R % block_rows == 0, (R, block_rows)
    grid = (R // block_rows,)
    return pl.pallas_call(
        _decompress_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, CB), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_rows, CB * 8), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, CB * 8), dtype),
        interpret=interpret,
    )(packed, scales)
