"""Pallas TPU kernels: fused error-feedback 1-bit compression.

The compression hot-path of 0/1 Adam touches every parameter byte three
times when expressed as separate XLA ops (add error, compute scale+sign,
write error). These kernels fuse the whole worker-side EF-compress into one
or two VMEM passes per tile:

    zw   = z + err_in
    s    = masked-mean(|zw|) at the requested granularity
    bits = zw >= 0  -> packed uint8 (8 lanes per byte)
    err  = (zw - sign(zw)·s) · mask

Layout: operands are 2-D (rows, cols) — the optimizer's comm views reshape
to this frame (see ``compressor.view_to_2d``). Tiles are (BLOCK_R, cols): a
full row per tile so row reductions stay in-register; cols must be a
multiple of 8 for packing. Flatten views are padded and folded so their
frame cols are 128-lane aligned and capped at ``FRAME_MAX_COLS`` (VMEM
bound); structured views keep their model-local last dim.

Pad-exactness: each row carries a true-element *count* (padding is always a
row tail or a whole row — see compressor.view_row_counts); the kernels
rebuild the elementwise mask as ``iota(cols) < count`` so scales and error
feedback never see padding. ``counts=None`` means "no padding".

Scale granularities (tensor / chunk / row of the comm view) that span
multiple 2-D rows use a two-pass reduction: ``abs_rowsum`` produces masked
per-row L1 sums, the (R,)-sized combine runs as plain XLA, and
``ef_quantize`` consumes the broadcast per-row scales. The single-pass
``ef_compress`` covers the per-row granularity. ``kernels/dispatch.py``
picks the pass structure per leaf.

Sharding: these kernels are deliberately shard-oblivious — they see one
device's (rows, cols) frame and nothing else. Model-sharded views reach
them through ``dispatch._shard_wrap`` (a manual ``shard_map`` over the
view's mesh axes, the partitioning rule): the frame they receive is then
the shard-LOCAL 2-D fold, and the cross-shard parts of a scale —
the model-axis psum and the global denominator (``layout.rest_factor``) —
happen in the plain-XLA combine between the two passes, never inside a
kernel. That keeps every kernel a pure local map, so one implementation
serves unsharded, manual-TP, and GSPMD-sharded views bit-identically.

TPU is the TARGET; correctness is validated on CPU with interpret=True
against ref.py (tests/test_kernels.py + tests/test_pallas_parity.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _row_mask(cnt_i32, r, c):
    """(r, c) bool mask from per-row true counts; 2-D iota (TPU-safe)."""
    col = jax.lax.broadcasted_iota(jnp.int32, (r, c), 1)
    return col < cnt_i32[:, None]


def _pack_bits(bits, r, c):
    """(r, c) bool -> (r, c//8) uint8, big-endian (matches jnp.packbits)."""
    b8 = bits.reshape(r, c // 8, 8).astype(jnp.uint8)
    weights = (jnp.uint8(128) >> jax.lax.broadcasted_iota(
        jnp.uint8, (1, 1, 8), 2))
    return (b8 * weights).sum(axis=-1).astype(jnp.uint8)


def _ef_compress_kernel(z_ref, err_ref, cnt_ref, packed_ref, scale_ref,
                        errout_ref):
    zw = z_ref[...].astype(jnp.float32) + err_ref[...].astype(jnp.float32)
    r, c = zw.shape
    cnt = cnt_ref[...]
    mask = _row_mask(cnt, r, c)
    s = (jnp.where(mask, jnp.abs(zw), 0.0).sum(axis=1)
         / jnp.maximum(cnt.astype(jnp.float32), 1.0))       # (BLOCK_R,)
    bits = (zw >= 0)
    packed_ref[...] = _pack_bits(bits, r, c)
    scale_ref[...] = s.astype(scale_ref.dtype)
    zhat = jnp.where(bits, s[:, None], -s[:, None])
    errout_ref[...] = jnp.where(mask, zw - zhat, 0.0).astype(errout_ref.dtype)


def ef_compress(z: jnp.ndarray, err: jnp.ndarray, counts=None, *,
                block_rows: int = 8, interpret: bool = True):
    """Fused single-pass EF 1-bit compress over (R, C) with per-row scales.
    Returns (packed u8 (R, C//8), scales f32 (R,), err_out like err)."""
    R, C = z.shape
    assert C % 8 == 0, C
    assert R % block_rows == 0, (R, block_rows)
    if counts is None:
        counts = jnp.full((R,), C, jnp.int32)
    grid = (R // block_rows,)
    return pl.pallas_call(
        _ef_compress_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, C // 8), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, C // 8), jnp.uint8),
            jax.ShapeDtypeStruct((R,), jnp.float32),
            jax.ShapeDtypeStruct((R, C), err.dtype),
        ],
        interpret=interpret,
    )(z, err, counts)


def _abs_rowsum_kernel(z_ref, err_ref, cnt_ref, out_ref):
    zw = z_ref[...].astype(jnp.float32) + err_ref[...].astype(jnp.float32)
    r, c = zw.shape
    mask = _row_mask(cnt_ref[...], r, c)
    out_ref[...] = jnp.where(mask, jnp.abs(zw), 0.0).sum(axis=1)


def abs_rowsum(z: jnp.ndarray, err: jnp.ndarray, counts=None, *,
               block_rows: int = 8, interpret: bool = True):
    """Pass 1 of the two-pass EF-compress: masked per-row L1 sums of
    ``z + err``. Returns f32 (R,)."""
    R, C = z.shape
    assert R % block_rows == 0, (R, block_rows)
    if counts is None:
        counts = jnp.full((R,), C, jnp.int32)
    grid = (R // block_rows,)
    return pl.pallas_call(
        _abs_rowsum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((R,), jnp.float32),
        interpret=interpret,
    )(z, err, counts)


def _ef_quantize_kernel(z_ref, err_ref, scale_ref, cnt_ref, packed_ref,
                        errout_ref):
    zw = z_ref[...].astype(jnp.float32) + err_ref[...].astype(jnp.float32)
    r, c = zw.shape
    mask = _row_mask(cnt_ref[...], r, c)
    s = scale_ref[...].astype(jnp.float32)
    bits = (zw >= 0)
    packed_ref[...] = _pack_bits(bits, r, c)
    zhat = jnp.where(bits, s[:, None], -s[:, None])
    errout_ref[...] = jnp.where(mask, zw - zhat, 0.0).astype(errout_ref.dtype)


def ef_quantize(z: jnp.ndarray, err: jnp.ndarray, scales: jnp.ndarray,
                counts=None, *, block_rows: int = 8, interpret: bool = True):
    """Pass 2 of the two-pass EF-compress: quantize ``z + err`` against
    precomputed per-row scales (R,). Returns (packed u8 (R, C//8), err_out)."""
    R, C = z.shape
    assert C % 8 == 0, C
    assert R % block_rows == 0, (R, block_rows)
    if counts is None:
        counts = jnp.full((R,), C, jnp.int32)
    grid = (R // block_rows,)
    return pl.pallas_call(
        _ef_quantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, C // 8), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, C // 8), jnp.uint8),
            jax.ShapeDtypeStruct((R, C), err.dtype),
        ],
        interpret=interpret,
    )(z, err, scales, counts)


def _decompress_kernel(packed_ref, scale_ref, out_ref):
    p = packed_ref[...]
    r, cb = p.shape
    shifts = jnp.uint8(7) - jax.lax.broadcasted_iota(jnp.uint8, (1, 1, 8), 2)
    bits = jnp.right_shift(p[:, :, None], shifts) & 1
    vals = bits.astype(jnp.float32) * 2.0 - 1.0
    s = scale_ref[...].astype(jnp.float32)
    out_ref[...] = (vals.reshape(r, cb * 8)
                    * s[:, None]).astype(out_ref.dtype)


def decompress(packed: jnp.ndarray, scales: jnp.ndarray, *,
               block_rows: int = 8, interpret: bool = True,
               dtype=jnp.float32):
    """Inverse quantizer over (R, C//8) packed + per-row scales."""
    R, CB = packed.shape
    assert R % block_rows == 0, (R, block_rows)
    grid = (R // block_rows,)
    return pl.pallas_call(
        _decompress_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, CB), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_rows, CB * 8), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, CB * 8), dtype),
        interpret=interpret,
    )(packed, scales)
