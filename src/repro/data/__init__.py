from repro.data.synthetic import (DataConfig, SyntheticClassify, SyntheticLM,
                                  worker_shard)

__all__ = ["DataConfig", "SyntheticClassify", "SyntheticLM", "worker_shard"]
