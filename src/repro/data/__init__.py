from repro.data.synthetic import DataConfig, SyntheticLM, SyntheticClassify, worker_shard
