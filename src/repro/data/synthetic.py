"""Deterministic synthetic data pipelines.

The LM stream has learnable structure (a latent bigram process over a
zipf-weighted vocabulary) so training losses genuinely decrease and the
optimizer-comparison benchmarks (paper Fig. 2) have signal to converge on.
Everything is a pure function of (seed, step) — reproducible across hosts
with zero coordination, which is exactly what a multi-pod data pipeline
needs (each worker slices its own batch shard by index).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    kind: str = "lm"             # lm | mlm | classify
    mlm_mask_frac: float = 0.15
    n_classes: int = 8


def _bigram_table(vocab: int, seed: int) -> np.ndarray:
    """Sparse-ish random bigram transition targets: tok -> 4 candidates."""
    rng = np.random.RandomState(seed)
    return rng.randint(0, vocab, size=(vocab, 4)).astype(np.int32)


class SyntheticLM:
    """Latent bigram LM stream; ~2 bits of predictable structure/token."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.table = jnp.asarray(_bigram_table(cfg.vocab, cfg.seed))

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        B, S = cfg.global_batch, cfg.seq_len
        k1, k2, k3 = jax.random.split(key, 3)
        first = jax.random.randint(k1, (B,), 0, cfg.vocab)
        choice = jax.random.randint(k2, (B, S), 0, 4)
        noise = jax.random.bernoulli(k3, 0.1, (B, S))
        nz = jax.random.randint(jax.random.fold_in(k3, 1), (B, S), 0,
                                cfg.vocab)

        def step_fn(tok, xs):
            ch, nv, nzv = xs
            nxt = jnp.where(nv, nzv, self.table[tok, ch])
            return nxt, nxt

        _, toks = jax.lax.scan(
            step_fn, first,
            (choice.T, noise.T, nz.T))
        tokens = jnp.concatenate([first[:, None], toks.T[:, :-1]], axis=1)
        labels = toks.T
        out = {"tokens": tokens.astype(jnp.int32),
               "labels": labels.astype(jnp.int32)}
        if cfg.kind == "mlm":
            km = jax.random.fold_in(key, 99)
            mask = jax.random.bernoulli(km, cfg.mlm_mask_frac, (B, S))
            out["labels"] = out["tokens"]
            out["tokens"] = jnp.where(mask, 0, out["tokens"])  # 0 = [MASK]
            out["loss_mask"] = mask.astype(jnp.float32)
        return out


class SyntheticClassify:
    """Linearly-separable-ish classification (GLUE/ImageNet quality proxy)."""

    def __init__(self, dim: int, n_classes: int, seed: int = 7):
        rng = np.random.RandomState(seed)
        self.w = jnp.asarray(rng.randn(dim, n_classes).astype(np.float32))
        self.dim, self.n_classes, self.seed = dim, n_classes, seed

    def batch(self, step: int, batch_size: int):
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        x = jax.random.normal(key, (batch_size, self.dim))
        logits = x @ self.w
        noise = 0.5 * jax.random.normal(jax.random.fold_in(key, 1),
                                        logits.shape)
        y = jnp.argmax(logits + noise, axis=-1)
        return x, y


def worker_shard(batch: Dict[str, jnp.ndarray], idx: int, n: int):
    """Deterministic per-worker slice of a global batch (host pipelines)."""
    def sl(x):
        per = x.shape[0] // n
        return x[idx * per:(idx + 1) * per]
    return jax.tree.map(sl, batch)
