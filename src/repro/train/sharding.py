"""Sharding-spec derivation for the trainer.

Terminology: *worker axes* (``W``) are the manual mesh axes carrying the
paper's data-parallel workers — ('data',) single-pod, ('pod','data')
multi-pod. 'model' is the GSPMD-auto tensor-parallel axis.

Storage layout:
  * DP-replicated param leaves gain a leading worker axis (each DP group
    owns its local-step replica): full spec P(W, *model_entries).
  * Expert-parallel leaves keep their natural rank; the expert axis is
    sharded over W: model entries with W inserted at ep_axis.
  * Optimizer state for DP leaves is per-worker (leading W) in comm-view
    shape; EP-leaf state mirrors the param spec. Scalars replicate.

``inner_*`` variants keep only the worker axes (what shard_map in_specs
are allowed to mention); model-axis sharding rides along on the argument
shardings (partial-manual shard_map).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import compressor as C
from repro.core.adam import Adam, AdamState
from repro.core.one_bit_adam import OneBitAdam, OneBitAdamState
from repro.core.zero_one_adam import ZeroOneAdam, ZeroOneAdamState


def _entries(spec) -> Tuple:
    if spec is None:
        return ()
    return tuple(spec)


def param_full_spec(spec, dp: bool, ep_axis: Optional[int], W: Tuple,
                    ep_axes: Tuple = ()) -> P:
    e = _entries(spec)
    if dp:
        return P(W, *e)
    if not ep_axes:
        return P(*e)
    ax = ep_axis or 0
    e = e + (None,) * max(0, ax + 1 - len(e))
    assert e[ax] is None, f"ep axis {ax} already sharded: {e}"
    return P(*(e[:ax] + (ep_axes,) + e[ax + 1:]))


def param_inner_spec(dp: bool, ep_axis: Optional[int], W: Tuple,
                     ep_axes: Tuple = ()) -> P:
    if dp:
        return P(W)
    if not ep_axes:
        return P()
    ax = ep_axis or 0
    return P(*((None,) * ax + (ep_axes,)))


def _drop_model(spec: P) -> P:
    """Keep only worker-axis entries (for shard_map in/out specs)."""
    return spec


class TreeSpecs:
    """Per-leaf spec derivation shared by trainer and dry-run."""

    def __init__(self, opt, pds: List, W: Tuple[str, ...],
                 ep_axes: Tuple[str, ...] = ()):
        # pds: flat list of layers.PD aligned with opt's flat leaves
        self.opt = opt
        self.pds = pds
        self.W = W
        self.ep_axes = tuple(ep_axes)

    # ---- params ----------------------------------------------------------
    def params_full(self) -> List[P]:
        return [param_full_spec(tuple(pd.spec) if pd.spec else None,
                                pd.dp, pd.ep_axis, self.W, self.ep_axes)
                for pd in self.pds]

    def params_inner(self) -> List[P]:
        return [param_inner_spec(pd.dp, pd.ep_axis, self.W, self.ep_axes)
                for pd in self.pds]

    def params_model(self) -> List[P]:
        """Model-axis-only specs (for the nested fully-manual optimizer
        shard_map: worker axes are already manual in the outer context)."""
        return [P(*pd.spec) if pd.spec else P() for pd in self.pds]

    def state_model_specs(self):
        """Model-axis-only specs matching the optimizer state structure."""
        opt = self.opt

        def view_e(i):
            return P(*C.view_spec_entries(opt.layouts[i],
                                          tuple(self.pds[i].spec)
                                          if self.pds[i].spec else None))

        def chunk_e(i):
            return P(*C.chunk_spec_entries(opt.layouts[i],
                                           tuple(self.pds[i].spec)
                                           if self.pds[i].spec else None))

        def nat_e(i):
            pd = self.pds[i]
            return P(*pd.spec) if pd.spec else P()

        n = len(self.pds)
        mv = [view_e(i) if self.pds[i].dp else nat_e(i) for i in range(n)]
        u = [view_e(i) if self.pds[i].dp else None for i in range(n)]
        es = [chunk_e(i) if self.pds[i].dp else None for i in range(n)]
        if isinstance(opt, Adam):
            nat = [nat_e(i) for i in range(n)]
            return AdamState(step=P(), m=nat, v=nat)
        if isinstance(opt, OneBitAdam):
            return OneBitAdamState(step=P(), m=mv, v=mv, err_w=u, err_s=es)
        if isinstance(opt, ZeroOneAdam):
            ps = opt.cfg.sync_policy.init()
            vs = opt.cfg.var_policy.init()
            anc = [nat_e(i) if (self.pds[i].dp and opt.cfg.store_anchor)
                   else None for i in range(n)]
            return ZeroOneAdamState(
                step=P(), gamma_acc=P(),
                sync_pstate=tuple(P() for _ in ps),
                var_pstate=tuple(P() for _ in vs),
                m=mv, v=mv, u=u, err_w=u, err_s=es, anchor=anc)
        raise TypeError(type(opt))

    # ---- optimizer state -------------------------------------------------
    def _leaf_state_specs(self, kind: str):
        """kind: view | chunk | natural — full and inner specs per leaf."""
        full, inner = [], []
        for pd, lo in zip(self.pds, self.opt.layouts):
            spec = tuple(pd.spec) if pd.spec else None
            if pd.dp:
                if kind == "view":
                    e = C.view_spec_entries(lo, spec)
                elif kind == "chunk":
                    e = C.chunk_spec_entries(lo, spec)
                else:
                    e = _entries(spec)
                full.append(P(self.W, *e))
                inner.append(P(self.W))
            else:
                full.append(param_full_spec(spec, False, pd.ep_axis, self.W,
                                            self.ep_axes))
                inner.append(param_inner_spec(False, pd.ep_axis, self.W,
                                              self.ep_axes))
        return full, inner

    def state_specs(self):
        """(full_specs, inner_specs) trees matching the optimizer state."""
        opt = self.opt
        mv_f, mv_i = self._leaf_state_specs("view")
        nat_f, nat_i = self._leaf_state_specs("natural")
        ch_f, ch_i = self._leaf_state_specs("chunk")

        def dp_only(lst):
            return [x if pd.dp else None
                    for x, pd in zip(lst, self.pds)]

        if isinstance(opt, Adam):
            full = AdamState(step=P(), m=nat_f, v=nat_f)
            inner = AdamState(step=P(), m=nat_i, v=nat_i)
        elif isinstance(opt, OneBitAdam):
            full = OneBitAdamState(step=P(), m=mv_f, v=mv_f,
                                   err_w=dp_only(mv_f), err_s=dp_only(ch_f))
            inner = OneBitAdamState(step=P(), m=mv_i, v=mv_i,
                                    err_w=dp_only(mv_i),
                                    err_s=dp_only(ch_i))
        elif isinstance(opt, ZeroOneAdam):
            ps = opt.cfg.sync_policy.init()
            vs = opt.cfg.var_policy.init()
            sync_spec = tuple(P() for _ in ps)
            var_spec = tuple(P() for _ in vs)
            anchor_f = [nat_f[i] if (pd.dp and opt.cfg.store_anchor)
                        else None for i, pd in enumerate(self.pds)]
            anchor_i = [nat_i[i] if (pd.dp and opt.cfg.store_anchor)
                        else None for i, pd in enumerate(self.pds)]
            full = ZeroOneAdamState(
                step=P(), gamma_acc=P(), sync_pstate=sync_spec,
                var_pstate=var_spec, m=mv_f, v=mv_f, u=dp_only(mv_f),
                err_w=dp_only(mv_f), err_s=dp_only(ch_f), anchor=anchor_f)
            inner = ZeroOneAdamState(
                step=P(), gamma_acc=P(), sync_pstate=sync_spec,
                var_pstate=var_spec, m=mv_i, v=mv_i, u=dp_only(mv_i),
                err_w=dp_only(mv_i), err_s=dp_only(ch_i), anchor=anchor_i)
        else:
            raise TypeError(type(opt))
        return full, inner

    # ---- convenience -----------------------------------------------------
    def shardings(self, mesh, tree_specs):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s), tree_specs,
            is_leaf=lambda x: isinstance(x, P))
