"""Sharding-spec derivation for the trainer.

Terminology: *worker axes* (``W``) are the manual mesh axes carrying the
paper's data-parallel workers — ('data',) single-pod, ('pod','data')
multi-pod. 'model' is the GSPMD-auto tensor-parallel axis.

Storage layout:
  * DP-replicated param leaves gain a leading worker axis (each DP group
    owns its local-step replica): full spec P(W, *model_entries).
  * Expert-parallel leaves keep their natural rank; the expert axis is
    sharded over W: model entries with W inserted at ep_axis.
  * Optimizer state for DP leaves is per-worker (leading W) in comm-view
    shape; EP-leaf state mirrors the param spec. Scalars replicate.

``inner_*`` variants keep only the worker axes (what shard_map in_specs
are allowed to mention); model-axis sharding rides along on the argument
shardings (partial-manual shard_map).

State specs are derived *generically* from the optimizer's
``state_kinds()`` tree (see repro.core.compressed.StateKind): every state
leaf carries a tag — scalar / view / chunk / natural / leaf_scalar — plus
the flat param-leaf index it belongs to, so one derivation serves every
composed optimizer (any base, any style) with no per-class branching.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import compressor as C


def _entries(spec) -> Tuple:
    if spec is None:
        return ()
    return tuple(spec)


def param_full_spec(spec, dp: bool, ep_axis: Optional[int], W: Tuple,
                    ep_axes: Tuple = ()) -> P:
    e = _entries(spec)
    if dp:
        return P(W, *e)
    if not ep_axes:
        return P(*e)
    ax = ep_axis or 0
    e = e + (None,) * max(0, ax + 1 - len(e))
    assert e[ax] is None, f"ep axis {ax} already sharded: {e}"
    return P(*(e[:ax] + (ep_axes,) + e[ax + 1:]))


def param_inner_spec(dp: bool, ep_axis: Optional[int], W: Tuple,
                     ep_axes: Tuple = ()) -> P:
    if dp:
        return P(W)
    if not ep_axes:
        return P()
    ax = ep_axis or 0
    return P(*((None,) * ax + (ep_axes,)))


class TreeSpecs:
    """Per-leaf spec derivation shared by trainer and dry-run."""

    def __init__(self, opt, pds: List, W: Tuple[str, ...],
                 ep_axes: Tuple[str, ...] = ()):
        # pds: flat list of layers.PD aligned with opt's flat leaves
        self.opt = opt
        self.pds = pds
        self.W = W
        self.ep_axes = tuple(ep_axes)

    # ---- params ----------------------------------------------------------
    def params_full(self) -> List[P]:
        return [param_full_spec(tuple(pd.spec) if pd.spec else None,
                                pd.dp, pd.ep_axis, self.W, self.ep_axes)
                for pd in self.pds]

    def params_inner(self) -> List[P]:
        return [param_inner_spec(pd.dp, pd.ep_axis, self.W, self.ep_axes)
                for pd in self.pds]

    def params_model(self) -> List[P]:
        """Model-axis-only specs (for the nested fully-manual optimizer
        shard_map: worker axes are already manual in the outer context)."""
        return [P(*pd.spec) if pd.spec else P() for pd in self.pds]

    # ---- optimizer state (generic over state_kinds) ----------------------
    def _leaf_model_entries(self, kind):
        if kind.bucketed:
            # bucket-shaped state: ``leaf`` indexes the bucket plan. A
            # bucket's spec is authoritative for its state sharding:
            # unsharded fused buckets carry None, sharded fused buckets
            # carry the canonical P(ax) of their TP-local members, and
            # singleton buckets keep their leaf's own spec — all three
            # derive view/chunk entries exactly like per-leaf state
            b = self.opt.bucket_plan.buckets[kind.leaf]
            spec = tuple(b.spec) if b.spec else None
            if kind.tag == "bucket_view":
                return C.view_spec_entries(b.layout, spec)
            return C.chunk_spec_entries(b.layout, spec)
        pd = self.pds[kind.leaf]
        spec = tuple(pd.spec) if pd.spec else None
        lo = self.opt.layouts[kind.leaf]
        if not pd.dp or kind.tag == "natural":
            return _entries(spec)
        if kind.tag == "view":
            return C.view_spec_entries(lo, spec)
        if kind.tag == "chunk":
            return C.chunk_spec_entries(lo, spec)
        return ()  # leaf_scalar

    def state_model_specs(self):
        """Model-axis-only specs matching the optimizer state structure."""
        def f(k):
            if k.tag in ("scalar", "leaf_scalar"):
                return P()
            return P(*self._leaf_model_entries(k))

        return jax.tree.map(f, self.opt.state_kinds())

    def _spec_pair(self, k):
        """(full, inner) specs for one tagged state leaf."""
        if k.tag == "scalar":
            return P(), P()
        if k.bucketed:
            # buckets only cover DP leaves -> always per-worker state
            return (P(self.W, *self._leaf_model_entries(k)), P(self.W))
        pd = self.pds[k.leaf]
        if pd.dp:
            # per-worker state: leading worker axis, model entries ride along
            return (P(self.W, *self._leaf_model_entries(k)), P(self.W))
        spec = tuple(pd.spec) if pd.spec else None
        return (param_full_spec(spec, False, pd.ep_axis, self.W,
                                self.ep_axes),
                param_inner_spec(False, pd.ep_axis, self.W, self.ep_axes))

    def state_specs(self):
        """(full_specs, inner_specs) trees matching the optimizer state."""
        kinds = self.opt.state_kinds()
        full = jax.tree.map(lambda k: self._spec_pair(k)[0], kinds)
        inner = jax.tree.map(lambda k: self._spec_pair(k)[1], kinds)
        return full, inner

    # ---- convenience -----------------------------------------------------
    def shardings(self, mesh, tree_specs):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s), tree_specs,
            is_leaf=lambda x: isinstance(x, P))
