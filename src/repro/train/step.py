"""Trainer: builds the distributed train step for any registered arch.

Three execution modes share one per-worker step function:

  * ``mesh``   — partial-manual ``jax.shard_map``: manual over the worker
    axes (the paper's communication pattern, hand-written collectives),
    GSPMD-auto over 'model' (tensor parallelism via sharding constraints).
    This is the production / dry-run path.
  * ``sim``    — ``jax.vmap(axis_name=...)`` materializes n workers on one
    device; identical collectives run through the vmap axis. Used by the
    convergence tests/benchmarks (paper Fig. 2) on CPU.
  * ``single`` — one worker, NullComm. CPU smoke tests.

Parameters/optimizer state carry a leading worker axis for DP-replicated
leaves (each DP group's local-step replica); expert-parallel leaves are
split across workers on their expert axis (see train/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import api as opt_api
from repro.core import compat
from repro.core.comm import (Comm, NullComm, mesh_comm, norm_hierarchy,
                             sim_comm)
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.layers import (dp_mask as tmpl_dp_mask, init_params,
                                 is_pd, param_specs)
from repro.train.sharding import TreeSpecs


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    micro_batches: int = 1
    worker_axes: Tuple[str, ...] = ("data",)
    donate: bool = True
    # Peel the final microbatch out of the accumulation scan so its
    # per-leaf gradient completions are visible dataflow: each exchange
    # unit's collectives (issued under their own per-unit cond in
    # repro.core.compressed) then depend only on that unit's member
    # leaves, and XLA's latency-hiding scheduler can overlap early units'
    # exchanges with the rest of the last backward. Bitwise-identical to
    # the full scan (same accumulation association order); False keeps
    # the sequential all-scanned path (used to regenerate goldens and by
    # the overlapped-vs-sequential parity tests).
    peel_last_microbatch: bool = True
    # Meshless tensor parallelism: model_shards > 1 binds a manual 'model'
    # axis of that size with no mesh attached, so the optimizer plans
    # TP-LOCAL force-flatten layouts (rest_factor = model_shards, sharded
    # fused buckets) exactly as the fully-manual mesh path would. Only the
    # abstract paths run in this regime — ``analysis.ir_audit`` traces the
    # per-worker step under an abstract mesh that binds 'model' — the
    # executable sim/single step functions refuse it (a vmap sim has no
    # 'model' axis for the exchange's psums to resolve against).
    model_shards: int = 0

    def __post_init__(self):
        if self.micro_batches < 1:
            raise ValueError(
                f"micro_batches must be >= 1, got "
                f"{self.micro_batches!r}")
        if self.model_shards < 0 or self.model_shards == 1:
            raise ValueError(
                f"model_shards must be 0 (off) or >= 2, got "
                f"{self.model_shards!r}")


def accumulate_grads(loss_fn, params, batch, micro_batches, *, peel=True):
    """Mean loss/gradients over ``micro_batches`` splits of the per-worker
    batch (leading axis). ``loss_fn(params, microbatch) -> (loss, aux)``.

    With ``peel=True`` the last microbatch runs unrolled after a scan over
    the first ``micro_batches - 1`` — the same sum in the same association
    order (bitwise-identical to the full scan), but the final backward's
    per-leaf gradients are individual equations instead of one opaque scan
    output, which is what lets the per-unit exchange issue early.
    """
    mb = micro_batches
    if mb <= 1:
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        return loss, jax.tree.map(lambda g: g.astype(jnp.float32), grads)

    for path, x in jax.tree_util.tree_flatten_with_path(batch)[0]:
        if x.shape[0] % mb:
            raise ValueError(
                f"per-worker batch leaf {jax.tree_util.keystr(path)} has "
                f"{x.shape[0]} rows, which is not divisible by "
                f"micro_batches={mb}; choose a global batch size divisible "
                f"by n_workers * micro_batches")

    def resh(x):
        return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])

    mbs = jax.tree.map(resh, batch)

    def acc(carry, b_):
        gsum, lsum = carry
        (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, b_)
        gsum = jax.tree.map(jnp.add, gsum, g)
        return (gsum, lsum + l), None

    g0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
    init = (g0, jnp.zeros(()))
    if peel:
        head = jax.tree.map(lambda x: x[:-1], mbs)
        last = jax.tree.map(lambda x: x[-1], mbs)
        carry, _ = jax.lax.scan(acc, init, head)
        (gsum, lsum), _ = acc(carry, last)
    else:
        (gsum, lsum), _ = jax.lax.scan(acc, init, mbs)
    grads = jax.tree.map(lambda g: g / mb, gsum)
    return lsum / mb, grads


class Trainer:
    """Holds the static plan: templates, specs, optimizer, jitted step."""

    def __init__(self, model_cfg: ModelConfig, opt_cfg, *, mesh=None,
                 n_workers: Optional[int] = None,
                 trainer_cfg: TrainerConfig = TrainerConfig()):
        self.model_cfg = model_cfg
        self.opt_cfg = opt_cfg
        self.mesh = mesh
        self.tc = trainer_cfg
        W = trainer_cfg.worker_axes
        if mesh is not None:
            n_workers = 1
            for a in W:
                n_workers = n_workers * mesh.shape[a]
        self.n_workers = n_workers or 1

        # Two-level (intra-pod x inter-pod) topology for the compressed
        # optimizer exchange. In mesh mode the hierarchy must name a split
        # of the worker axes; in sim mode both levels are materialized as
        # nested vmap axes carrying the same names. (opt_cfg is either an
        # OptimizerConfig or an unbound compressed_dp transform — both
        # always carry the hierarchy field.)
        self.hierarchy = norm_hierarchy(opt_cfg.hierarchy, self.n_workers)
        if self.hierarchy is not None:
            h = self.hierarchy
            if mesh is not None:
                if h.axes != tuple(W):
                    raise ValueError(
                        f"hierarchy axes {h.axes} must equal the worker "
                        f"axes {tuple(W)}")
                inner = 1
                for a in h.inner_axes:
                    inner *= mesh.shape[a]
                if inner != h.inner:
                    raise ValueError(
                        f"hierarchy.inner={h.inner} != mesh inner-axes "
                        f"product {inner}")
            elif len(h.outer_axes) != 1 or len(h.inner_axes) != 1:
                raise ValueError("sim mode materializes one vmap axis per "
                                 "hierarchy level (one outer + one inner "
                                 "axis name)")

        # Expert parallelism spans the largest suffix of the worker axes
        # whose size divides the expert count (llama4: 16 experts -> EP over
        # 'data' only on the 2x16x16 mesh, replicated over 'pod' with the
        # residual-axis gradient pmean in _ep_scale_grads).
        self.ep_axes, self.ep_degree = self._choose_ep(W)
        self.template = T.model_template(model_cfg,
                                         ep_workers=self.ep_degree)
        self.pd_leaves, self.treedef = jax.tree.flatten(
            self.template, is_leaf=is_pd)
        # The optimizer runs in the FULLY-manual domain: manual over the
        # worker axes (outer shard_map) AND over 'model' (nested shard_map in
        # _per_worker_step) — every op is chip-local except the worker-axis
        # collectives, so GSPMD never re-gathers the comm views. jax 0.4.x
        # cannot nest a manual region inside a partial-auto one (the XLA
        # partitioner of that vintage rejects manual-subgroup resharding),
        # so there the optimizer stays in the GSPMD-auto domain: structured
        # per-leaf layouts chunk along a replicated axis and the views keep
        # their model sharding via compressor.constrain.
        if (mesh is not None and "model" in mesh.axis_names
                and hasattr(jax, "shard_map")):
            self.model_axes = ("model",)
            self.model_sizes = {"model": mesh.shape["model"]}
        elif mesh is None and trainer_cfg.model_shards > 1:
            # meshless sim-TP (TrainerConfig.model_shards): same manual
            # 'model' planning domain as the fully-manual mesh path —
            # TP-local layouts, sharded fused buckets, model-axis psums —
            # resolved against the abstract mesh the auditor binds. Works
            # on any jax version because the abstract trace never reaches
            # the XLA partitioner.
            self.model_axes = ("model",)
            self.model_sizes = {"model": trainer_cfg.model_shards}
        else:
            self.model_axes, self.model_sizes = (), {}
        # per-worker local shapes: EP leaves divide their expert axis
        self.local_abstract = self._local_abstract()
        # worker+model local shapes (what the optimizer sees)
        self.inner_abstract = self._inner_abstract()
        specs_tree = param_specs(self.template)
        dpm_tree = tmpl_dp_mask(self.template)
        self.opt = opt_api.build_optimizer(
            opt_cfg, self.inner_abstract, specs=specs_tree,
            dp_mask=dpm_tree, n_workers=self.n_workers,
            model_axis_sizes=self.model_sizes)
        self.tree_specs = TreeSpecs(self.opt, self.pd_leaves, W,
                                    ep_axes=self.ep_axes)

    # ------------------------------------------------------------------ #
    def _choose_ep(self, W):
        """(ep_axes suffix, ep_degree): largest suffix of the worker axes
        whose total size divides the expert count."""
        if self.mesh is not None:
            names, sizes = list(W), [self.mesh.shape[a] for a in W]
        elif self.hierarchy is not None:  # sim: one vmap axis per level
            h = self.hierarchy
            names = list(h.axes)
            sizes = [self.n_workers // h.inner, h.inner]
        else:  # sim / single: one logical worker axis
            names, sizes = ["workers"], [self.n_workers]
        self._worker_axis_names = tuple(names)
        E = self.model_cfg.n_experts
        if not E:
            return (), 1
        for start in range(len(names) + 1):
            deg = 1
            for s in sizes[start:]:
                deg *= s
            if E % deg == 0:
                return tuple(names[start:]), deg
        return (), 1

    def _residual_axes(self):
        names = getattr(self, "_worker_axis_names", self.tc.worker_axes)
        return tuple(a for a in names if a not in self.ep_axes)

    def _abstract_tp_mesh(self):
        """Worker axes + model axes as an abstract mesh — the meshless-TP
        stand-in for ``self.mesh`` in the nested optimizer shard_map."""
        if self.hierarchy is not None:
            axes = list(self.hierarchy.axes)
            sizes = [self.n_workers // self.hierarchy.inner,
                     self.hierarchy.inner]
        else:
            axes, sizes = ["workers"], [self.n_workers]
        for a, s in self.model_sizes.items():
            axes.append(a)
            sizes.append(s)
        return compat.abstract_mesh(axes, sizes)

    def _local_abstract(self):
        n = self.ep_degree
        dt = self.model_cfg.param_dtype

        def f(pd):
            shape = list(pd.shape)
            if not pd.dp and pd.ep_axis is not None and n > 1:
                ax = pd.ep_axis
                assert shape[ax] % n == 0, (pd.shape, ax, n)
                shape[ax] = shape[ax] // n
            return jax.ShapeDtypeStruct(tuple(shape), dt)

        return jax.tree.map(f, self.template, is_leaf=is_pd)

    def _shrink_model(self, shape, spec):
        """Divide tensor-parallel-sharded dims by the model axis size."""
        if not self.model_sizes:
            return tuple(shape)
        entries = tuple(spec) if spec is not None else ()
        out = list(shape)
        for ax, e in enumerate(entries):
            if e is None or ax >= len(out):
                continue
            f = 1
            for name in (e if isinstance(e, tuple) else (e,)):
                f *= self.model_sizes.get(name, 1)
            assert out[ax] % f == 0, (shape, spec, f)
            out[ax] = out[ax] // f
        return tuple(out)

    def _grow_model(self, shape, entries):
        if not self.model_sizes or entries is None:
            return tuple(shape)
        out = list(shape)
        for ax, e in enumerate(tuple(entries)[:len(out)]):
            if e is None:
                continue
            f = 1
            for name in (e if isinstance(e, tuple) else (e,)):
                f *= self.model_sizes.get(name, 1)
            out[ax] = out[ax] * f
        return tuple(out)

    def _inner_abstract(self):
        ll, ldef = jax.tree.flatten(self.local_abstract)
        out = []
        for loc, pd in zip(ll, self.pd_leaves):
            shape = self._shrink_model(loc.shape, pd.spec)
            out.append(jax.ShapeDtypeStruct(shape, loc.dtype))
        return jax.tree.unflatten(ldef, out)

    def _ep_scale_grads(self, grads, comm):
        """EP-leaf grads arrive as sums over the EP axes (a2a transpose):
        pmean over the residual (replication) axes, then divide by the EP
        degree to match the mean-loss objective."""
        if self.n_workers == 1:
            return grads
        res = self._residual_axes()
        gl = self.treedef.flatten_up_to(grads)
        out = []
        for g, pd in zip(gl, self.pd_leaves):
            if pd.dp:
                out.append(g)
                continue
            if res and not isinstance(comm, NullComm) and comm.axes:
                g = jax.lax.pmean(g, res if len(res) > 1 else res[0])  # audit-ok: raw-collective
            out.append(g / self.ep_degree)
        return jax.tree.unflatten(self.treedef, out)

    # ------------------------------------------------------------------ #
    def _per_worker_step(self, comm: Comm, params_local, opt_state, batch,
                         ep_comm: Optional[Comm] = None):
        """params_local: DP leaves WITH leading worker dim of size 1."""
        p = self._squeeze(params_local)
        mb = self.tc.micro_batches
        if ep_comm is None:
            ep_comm = (Comm(self.ep_axes) if self.ep_axes
                       and not isinstance(comm, NullComm) else NullComm())

        def loss_fn(p_, b_):
            loss, met = T.lm_loss(p_, self.model_cfg, b_, comm=ep_comm)
            return loss, met

        loss, grads = accumulate_grads(
            loss_fn, p, batch, mb, peel=self.tc.peel_last_microbatch)

        grads = self._ep_scale_grads(grads, comm)
        widx = (comm.index() if not isinstance(comm, NullComm)
                else jnp.zeros((), jnp.int32))

        def opt_apply(p_, g_, s_, w_):
            return self.opt.step(comm, p_, g_, s_, worker_index=w_)

        if self.model_axes:
            pm = jax.tree.unflatten(self.treedef,
                                    self.tree_specs.params_model())
            sm = self.tree_specs.state_model_specs()
            # meshless sim-TP substitutes the abstract mesh ir_audit traces
            # under — shapes and collectives are identical to the physical
            # nesting, and the trace never reaches the compiler
            opt_apply = compat.shard_map(
                opt_apply, in_specs=(pm, pm, sm, P()),
                out_specs=(pm, sm, P()),
                axis_names=set(self.model_axes),
                mesh=(self.mesh if self.mesh is not None
                      else self._abstract_tp_mesh()))

        new_p, new_opt, met = opt_apply(p, grads, opt_state, widx)
        met["loss"] = comm.pmean(loss)
        return self._unsqueeze(new_p), new_opt, met

    def _squeeze(self, params):
        pl = self.treedef.flatten_up_to(params)
        out = [x[0] if pd.dp else x for x, pd in zip(pl, self.pd_leaves)]
        return jax.tree.unflatten(self.treedef, out)

    def _unsqueeze(self, params):
        pl = self.treedef.flatten_up_to(params)
        out = [x[None] if pd.dp else x for x, pd in zip(pl, self.pd_leaves)]
        return jax.tree.unflatten(self.treedef, out)

    def _is_per_worker_spec(self, s):
        ent = tuple(s)
        if not ent or ent[0] is None:
            return False
        first = ent[0] if isinstance(ent[0], tuple) else (ent[0],)
        return first == tuple(self.tc.worker_axes)

    def _squeeze_state(self, state, inner_specs):
        def f(x, s):
            return x[0] if self._is_per_worker_spec(s) else x
        return jax.tree.map(f, state, inner_specs)

    def _unsqueeze_state(self, state, inner_specs):
        def f(x, s):
            return x[None] if self._is_per_worker_spec(s) else x
        return jax.tree.map(f, state, inner_specs)

    # ------------------------------------------------------------------ #
    # mesh (production) mode
    # ------------------------------------------------------------------ #
    def mesh_step_fn(self):
        """jit(shard_map(step)) for the production mesh, plus shardings.

        jax 0.4.x cannot run worker-axis collectives inside a partial-auto
        shard_map region (the XLA partitioner of that vintage rejects
        manual-subgroup resharding of shape-changing collectives), so the
        same program is lowered through GSPMD + vmap-over-workers instead:
        identical per-worker semantics, the worker axes sharded over the
        real mesh, collectives emitted by the partitioner.
        """
        assert self.mesh is not None
        if not hasattr(jax, "shard_map"):
            return self._gspmd_mesh_step_fn()
        W = self.tc.worker_axes
        comm = mesh_comm(W)
        pf = self._params_full_specs_tree()
        pi = self._params_inner_specs_tree()
        sf, si = self.tree_specs.state_specs()
        batch_i = P(W)
        batch_f = P(W)

        def body(params, opt_state, batch):
            opt_local = self._squeeze_state(opt_state, si)
            new_p, new_s, met = self._per_worker_step(
                comm, params, opt_local, batch)
            return new_p, self._unsqueeze_state(new_s, si), met

        shmapped = compat.shard_map(
            body, mesh=self.mesh,
            in_specs=(pi, si, batch_i),
            out_specs=(pi, si, P()),
            axis_names=set(W))

        shardings = {
            "params": self.tree_specs.shardings(self.mesh, pf),
            "state": self.tree_specs.shardings(self.mesh, sf),
        }
        donate = (0, 1) if self.tc.donate else ()
        fn = jax.jit(
            shmapped,
            in_shardings=(shardings["params"], shardings["state"],
                          NamedSharding(self.mesh, batch_f)),
            out_shardings=(shardings["params"], shardings["state"], None),
            donate_argnums=donate)
        return fn, shardings

    # ------------------------------------------------------------------ #
    # GSPMD-vmap fallback (jax 0.4.x mesh mode)
    # ------------------------------------------------------------------ #
    def _gspmd_mesh_step_fn(self):
        """Mesh-mode step as jit(nested-vmap) with worker axes GSPMD-sharded.

        Each mesh worker axis becomes a vmap axis of the same name, so the
        per-worker step — collectives, hierarchy split and all — is the
        exact sim-mode trace; ``in_shardings`` lay the mapped axes over the
        real mesh and GSPMD partitions the lot. Worker-stacked leaves are
        reshaped (n, ...) -> mesh axis sizes around the vmap; EP leaves
        split their expert axis over the EP suffix and broadcast over the
        residual worker axes (the same replication the shard_map specs
        declare).
        """
        W = self.tc.worker_axes
        sizes = tuple(self.mesh.shape[a] for a in W)
        ep_deg, n = self.ep_degree, self.n_workers
        res_ndim = len(W) - len(self.ep_axes)
        res_sizes, ep_sizes = sizes[:res_ndim], sizes[res_ndim:]
        comm = mesh_comm(W)
        one = self._one_worker_fn(comm)
        mapped = one
        for name in reversed(W):
            mapped = jax.vmap(mapped, axis_name=name)

        def split_lead(x):
            return x.reshape(sizes + x.shape[1:])

        def merge_lead(x):
            return x.reshape((n,) + x.shape[len(sizes):])

        def split_ep(x, ax):
            shp = x.shape
            x = x.reshape(shp[:ax] + ep_sizes + (shp[ax] // ep_deg,)
                          + shp[ax + 1:])
            x = jnp.moveaxis(x, tuple(range(ax, ax + len(ep_sizes))),
                             tuple(range(len(ep_sizes))))
            return jnp.broadcast_to(x[(None,) * res_ndim],
                                    res_sizes + x.shape)

        def merge_ep(x, ax):
            x = x[(0,) * res_ndim]
            x = jnp.moveaxis(x, tuple(range(len(ep_sizes))),
                             tuple(range(ax, ax + len(ep_sizes))))
            shp = x.shape
            return x.reshape(shp[:ax] + (-1,)
                             + shp[ax + len(ep_sizes) + 1:])

        def _ep_axis_of(spec):
            for ax, e in enumerate(tuple(spec)):
                if e is None:
                    continue
                names = e if isinstance(e, tuple) else (e,)
                if set(names) & set(self.ep_axes):
                    return ax
            return None

        def split_state(x, s):
            if x is None:
                return None
            if self._is_per_worker_spec(s):
                return split_lead(x)
            ax = _ep_axis_of(s)
            if ax is not None:
                return split_ep(x, ax)
            return jnp.broadcast_to(x[(None,) * len(sizes)],
                                    sizes + x.shape)

        def merge_state(x, s):
            if x is None:
                return None
            if self._is_per_worker_spec(s):
                return merge_lead(x)
            ax = _ep_axis_of(s)
            if ax is not None:
                return merge_ep(x, ax)
            return x[(0,) * len(sizes)]

        sf, si = self.tree_specs.state_specs()
        pf = self._params_full_specs_tree()
        pd_flat = self.pd_leaves

        def body(params, opt_state, batch):
            pl = self.treedef.flatten_up_to(params)
            pl = [split_lead(x) if pd.dp else split_ep(x, pd.ep_axis or 0)
                  for x, pd in zip(pl, pd_flat)]
            p2 = jax.tree.unflatten(self.treedef, pl)
            s2 = jax.tree.map(split_state, opt_state, si)
            b2 = jax.tree.map(
                lambda x: x.reshape(sizes + (x.shape[0] // n,)
                                    + x.shape[1:]), batch)
            new_p, new_s, met = mapped(p2, s2, b2)
            npl = self.treedef.flatten_up_to(new_p)
            npl = [merge_lead(x) if pd.dp else merge_ep(x, pd.ep_axis or 0)
                   for x, pd in zip(npl, pd_flat)]
            return (jax.tree.unflatten(self.treedef, npl),
                    jax.tree.map(merge_state, new_s, si),
                    jax.tree.map(lambda x: x[(0,) * len(sizes)], met))

        shardings = {
            "params": self.tree_specs.shardings(self.mesh, pf),
            "state": self.tree_specs.shardings(self.mesh, sf),
        }
        donate = (0, 1) if self.tc.donate else ()
        fn = jax.jit(
            body,
            in_shardings=(shardings["params"], shardings["state"],
                          NamedSharding(self.mesh, P(W))),
            out_shardings=(shardings["params"], shardings["state"], None),
            donate_argnums=donate)
        return fn, shardings

    def _params_full_specs_tree(self):
        return jax.tree.unflatten(self.treedef,
                                  self.tree_specs.params_full())

    def _params_inner_specs_tree(self):
        return jax.tree.unflatten(self.treedef,
                                  self.tree_specs.params_inner())

    def abstract_inputs(self, global_batch: int, seq: int,
                        extra_fn=None):
        """ShapeDtypeStructs for (params, opt_state, batch) — the dry-run
        inputs. Nothing is allocated."""
        pl = []
        for pd, loc in zip(self.pd_leaves,
                           jax.tree.leaves(self.local_abstract)):
            if pd.dp:
                pl.append(jax.ShapeDtypeStruct(
                    (self.n_workers,) + loc.shape, loc.dtype))
            else:
                ax = pd.ep_axis or 0
                shape = list(loc.shape)
                shape[ax] = shape[ax] * self.ep_degree
                pl.append(jax.ShapeDtypeStruct(tuple(shape), loc.dtype))
        params = jax.tree.unflatten(self.treedef, pl)

        inner_params = jax.tree.unflatten(
            self.treedef, list(jax.tree.leaves(self.inner_abstract)))
        state_local = jax.eval_shape(self.opt.init, inner_params)
        state = self._stack_state_abstract(state_local)

        batch = {"tokens": jax.ShapeDtypeStruct((global_batch, seq),
                                                jnp.int32),
                 "labels": jax.ShapeDtypeStruct((global_batch, seq),
                                                jnp.int32)}
        if extra_fn is not None:
            batch.update(extra_fn(global_batch, seq, self.model_cfg))
        return params, state, batch

    def _stack_state_abstract(self, state_local):
        """Globalize abstract state: grow model-sharded dims back to global,
        add the worker axis to per-worker (DP) leaves, re-globalize the
        expert axis of EP leaves. Fully generic: driven by the optimizer's
        ``state_kinds()`` tags, so any composed optimizer (any base, any
        style) globalizes without per-class branching."""
        n = self.n_workers
        kinds = self.opt.state_kinds()
        model_specs = self.tree_specs.state_model_specs()

        def glob(x, k, ms):
            if k.tag == "scalar":
                return x
            shape = self._grow_model(x.shape, tuple(ms) if ms else None)
            if k.bucketed:
                # bucket-shaped state (EF / anchors): buckets only cover DP
                # leaves, so the state is always per-worker stacked
                return jax.ShapeDtypeStruct((n,) + shape, x.dtype)
            pd = self.pd_leaves[k.leaf]
            if pd.dp:
                return jax.ShapeDtypeStruct((n,) + shape, x.dtype)
            ax = pd.ep_axis or 0
            shape = list(shape)
            shape[ax] = shape[ax] * self.ep_degree
            return jax.ShapeDtypeStruct(tuple(shape), x.dtype)

        return jax.tree.map(glob, state_local, kinds, model_specs)

    def _no_meshless_tp(self, mode: str) -> None:
        """The executable sim/single paths cannot honor meshless TP: their
        vmap/NullComm traces bind no 'model' axis, so the exchange's
        model-axis psums (and the TP-local state shapes) have nothing to
        resolve against. Only the abstract paths (ir_audit) run there."""
        if self.model_sizes and self.mesh is None:
            raise ValueError(
                f"TrainerConfig.model_shards="
                f"{self.model_sizes.get('model')} is abstract-trace-only "
                f"(analysis.ir_audit); the executable {mode} path has no "
                f"'model' axis to bind — use a mesh with a 'model' axis "
                f"instead")

    # ------------------------------------------------------------------ #
    # single-worker mode (CPU smoke)
    # ------------------------------------------------------------------ #
    def single_init(self, key):
        self._no_meshless_tp("single")
        params = init_params(self.template, key,
                             dtype=self.model_cfg.param_dtype)
        pl = self.treedef.flatten_up_to(params)
        pl = [x[None] if pd.dp else x for x, pd in zip(pl, self.pd_leaves)]
        params = jax.tree.unflatten(self.treedef, pl)
        state = self.opt.init(self._squeeze(params))
        return params, state

    def single_step_fn(self):
        self._no_meshless_tp("single")
        comm = NullComm()

        @jax.jit
        def fn(params, opt_state, batch):
            return self._per_worker_step(comm, params, opt_state, batch)

        return fn

    # ------------------------------------------------------------------ #
    # sim mode (n workers on one device via vmap)
    # ------------------------------------------------------------------ #
    def sim_init(self, key):
        self._no_meshless_tp("sim")
        n = self.n_workers
        params = init_params(self.template, key,
                             dtype=self.model_cfg.param_dtype)
        pl = self.treedef.flatten_up_to(params)
        out = []
        for x, pd in zip(pl, self.pd_leaves):
            if pd.dp:
                out.append(jnp.broadcast_to(x[None], (n,) + x.shape) + 0)
            else:  # split expert axis across simulated workers
                ax = pd.ep_axis or 0
                xs = jnp.moveaxis(
                    x.reshape(x.shape[:ax] + (n, x.shape[ax] // n)
                              + x.shape[ax + 1:]), ax, 0)
                out.append(xs)
        params = jax.tree.unflatten(self.treedef, out)
        # per-worker init (worker-dependent for EP slices / anchors)
        state = jax.vmap(lambda i: self.opt.init(
            jax.tree.map(lambda x: x[i], params)))(jnp.arange(n))
        return params, state

    def _sim_local(self, params, i):
        return jax.tree.map(lambda x: x[i], params)

    def _one_worker_fn(self, comm):
        """Per-worker step on worker-local trees (shared by sim's vmap, the
        hierarchical nested vmap, and the GSPMD-vmap mesh fallback)."""

        def one(params_i, state_i, batch_i):
            # params_i: DP leaves (shape local), EP leaves local slice
            pl = self.treedef.flatten_up_to(params_i)
            pl = [x[None] if pd.dp else x
                  for x, pd in zip(pl, self.pd_leaves)]
            p = jax.tree.unflatten(self.treedef, pl)
            new_p, new_s, met = self._per_worker_step(comm, p, state_i,
                                                      batch_i)
            npl = self.treedef.flatten_up_to(new_p)
            npl = [x[0] if pd.dp else x
                   for x, pd in zip(npl, self.pd_leaves)]
            return jax.tree.unflatten(self.treedef, npl), new_s, met

        return one

    def sim_step_fn(self):
        self._no_meshless_tp("sim")
        n = self.n_workers
        h = self.hierarchy
        if h is None:
            axes, sizes = ("workers",), (n,)
        else:
            # materialize both topology levels so Comm.split sees real axes
            axes = h.axes
            sizes = (n // h.inner, h.inner)
        comm = Comm(axes) if len(axes) > 1 else sim_comm(axes[0])
        one = self._one_worker_fn(comm)
        mapped = one
        for name in reversed(axes):
            mapped = jax.vmap(mapped, axis_name=name)

        @jax.jit
        def fn(params, state, batch):
            # batch: (GB, S) -> per-worker (*sizes, GB/n, S); the stacked
            # params/state keep their flat leading worker axis externally
            # (outer-major = the flattened collective order) and are only
            # reshaped around the nested vmap
            def resh_b(x):
                return x.reshape(sizes + (x.shape[0] // n,) + x.shape[1:])

            def lead(x):
                return x.reshape(sizes + x.shape[1:])

            def unlead(x):
                return x.reshape((n,) + x.shape[len(sizes):])

            b = jax.tree.map(resh_b, batch)
            if len(sizes) == 1:
                return mapped(params, state, b)
            p2 = jax.tree.map(lead, params)
            s2 = jax.tree.map(lead, state)
            new_p, new_s, met = mapped(p2, s2, b)
            return (jax.tree.map(unlead, new_p),
                    jax.tree.map(unlead, new_s),
                    jax.tree.map(unlead, met))

        return fn
