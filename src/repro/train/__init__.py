from repro.train.step import Trainer, TrainerConfig
