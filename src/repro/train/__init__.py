from repro.train.step import Trainer, TrainerConfig

__all__ = ["Trainer", "TrainerConfig"]
