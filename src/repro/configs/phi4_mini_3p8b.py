"""phi4-mini-3.8b [dense] — arXiv:2412.08905 (Microsoft).

32 layers, d_model=3072, 24 heads GQA kv=8, d_ff=8192, vocab=200064,
RoPE + SwiGLU + RMSNorm. long_500k skipped (full attention).
"""
from repro.configs import base
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="phi4-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv=8, d_ff=8192,
    vocab=200064, head_dim=128,
    mlp_type="swiglu", norm_type="rmsnorm", max_seq=32768, remat=True,
    citation="arXiv:2412.08905",
)

SMOKE = ModelConfig(
    name="phi4-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv=2, d_ff=256, vocab=512,
    head_dim=32, max_seq=128, citation="arXiv:2412.08905",
)

base.register("phi4-mini-3.8b", base.ArchSpec(
    config=FULL, smoke=SMOKE,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes="long_500k skipped: full attention only.",
))
