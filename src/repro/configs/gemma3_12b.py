"""gemma3-12b [dense] — hf:google/gemma-3-1b-pt family card (12B variant).

48 layers, d_model=3840, 16 heads GQA kv=8 with head_dim=256, d_ff=15360,
vocab=262144, tied embeddings, 5:1 local:global attention (sliding window
1024; every 6th layer global), 128k context. Single rope_theta used for
both bands (model card uses 10k local / 1M global; recorded simplification).
long_500k RUNS: the sliding-window layers are sub-quadratic and the 8
global layers decode one token in O(S) against a sequence-sharded cache.
"""
from repro.configs import base
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv=8, d_ff=15360,
    vocab=262144, head_dim=256,
    sliding_window=1024, global_every=6, rope_theta=1_000_000.0,
    tie_embeddings=True,
    mlp_type="swiglu", norm_type="rmsnorm", max_seq=131072, remat=True,
    citation="hf:google/gemma-3-1b-pt",
)

SMOKE = ModelConfig(
    name="gemma3-smoke", family="dense",
    n_layers=6, d_model=128, n_heads=4, n_kv=2, d_ff=256, vocab=512,
    head_dim=32, sliding_window=8, global_every=6, tie_embeddings=True,
    max_seq=128, citation="hf:google/gemma-3-1b-pt",
)

base.register("gemma3-12b", base.ArchSpec(
    config=FULL, smoke=SMOKE,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
))
