"""BERT-Base — the paper's own pre-training benchmark [Devlin et al. 2018].

12 layers, d_model=768, 12 heads, d_ff=3072, vocab=30522 — bidirectional
encoder trained with masked-LM loss (loss_mask in the batch).
"""
from repro.configs import base
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="bert-base", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv=12, d_ff=3072,
    vocab=30522, head_dim=64, causal=False,
    rope="learned", mlp_type="gelu", norm_type="layernorm",
    attn_bias=True, max_seq=4096,  # train_4k shape
    citation="arXiv:1810.04805",
)

SMOKE = ModelConfig(
    name="bert-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv=4, d_ff=256, vocab=512,
    head_dim=32, causal=False, rope="learned", mlp_type="gelu",
    norm_type="layernorm", attn_bias=True, max_seq=128,
    citation="arXiv:1810.04805",
)

base.register("bert-base", base.ArchSpec(
    config=FULL, smoke=SMOKE, shapes=("train_4k",),
    skip_notes="paper's own workload; encoder-only -> no decode shapes; "
               "trained at its native 128/512 seq in benchmarks.",
))
