"""mamba2-2.7b [ssm] — arXiv:2405.21060 (Dao & Gu, SSD).

64 layers, d_model=2560, attention-free, vocab=50280, ssm_state=128,
expand=2 (d_inner=5120, 80 heads of dim 64), conv kernel 4. Chunked SSD
for train/prefill, O(1) recurrence for decode — long_500k runs natively.
0/1 Adam applies unchanged (optimizer-level technique; attention-free is
irrelevant — DESIGN §Arch-applicability).
"""
from repro.configs import base
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=80, n_kv=80, d_ff=0,
    vocab=50280, ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    ssm_groups=1, ssm_chunk=256, conv_kernel=4,
    norm_type="rmsnorm", max_seq=524288, remat=True,
    citation="arXiv:2405.21060",
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    n_layers=2, d_model=128, n_heads=8, n_kv=8, d_ff=0, vocab=512,
    ssm_state=16, ssm_head_dim=32, ssm_expand=2, ssm_chunk=8,
    conv_kernel=4, max_seq=128, citation="arXiv:2405.21060",
)

base.register("mamba2-2.7b", base.ArchSpec(
    config=FULL, smoke=SMOKE,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
))
