"""BERT-Large — the paper's own pre-training benchmark [Devlin et al. 2018].

24 layers, d_model=1024, 16 heads, d_ff=4096, vocab=30522.
"""
from repro.configs import base
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="bert-large", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv=16, d_ff=4096,
    vocab=30522, head_dim=64, causal=False,
    rope="learned", mlp_type="gelu", norm_type="layernorm",
    attn_bias=True, max_seq=4096,  # train_4k shape
    citation="arXiv:1810.04805",
)

SMOKE = ModelConfig(
    name="bert-large-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv=4, d_ff=256, vocab=512,
    head_dim=32, causal=False, rope="learned", mlp_type="gelu",
    norm_type="layernorm", attn_bias=True, max_seq=128,
    citation="arXiv:1810.04805",
)

base.register("bert-large", base.ArchSpec(
    config=FULL, smoke=SMOKE, shapes=("train_4k",),
    skip_notes="paper's own workload; encoder-only.",
))
