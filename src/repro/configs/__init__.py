from repro.configs.base import ALL_SHAPES, ASSIGNED, ArchSpec, get, list_archs
