from repro.configs.base import ALL_SHAPES, ASSIGNED, ArchSpec, get, list_archs

__all__ = ["ALL_SHAPES", "ASSIGNED", "ArchSpec", "get", "list_archs"]
