"""llama4-scout-17b-a16e [moe] — hf:meta-llama/Llama-4-Scout-17B-16E.

48 layers, d_model=5120, 40 heads GQA kv=8, d_ff=8192 per expert,
vocab=202048, 16 routed experts top-1 + 1 shared expert. Early fusion is
multimodal input handling — modeled text-only here per the backbone-only
carve-out. Experts are expert-parallel over the worker axes (16 experts /
16 data-parallel groups single-pod); expert leaves are dp=False for the
optimizer (no DP gradient exchange to compress — DESIGN
§Arch-applicability). long_500k skipped (full/chunked attention;
no sub-quadratic variant implemented).
"""
from repro.configs import base
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv=8, d_ff=8192,
    vocab=202048, head_dim=128,
    n_experts=16, top_k=1, n_shared_experts=1, moe_d_ff=8192,
    capacity_factor=1.25,
    mlp_type="swiglu", norm_type="rmsnorm", max_seq=32768, remat=True,
    citation="hf:meta-llama/Llama-4-Scout-17B-16E",
)

SMOKE = ModelConfig(
    name="llama4-smoke", family="moe",
    n_layers=2, d_model=128, n_heads=4, n_kv=2, d_ff=256, vocab=512,
    head_dim=32, n_experts=4, top_k=1, n_shared_experts=1, moe_d_ff=192,
    capacity_factor=2.0, max_seq=128,
    citation="hf:meta-llama/Llama-4-Scout-17B-16E",
)

base.register("llama4-scout-17b-a16e", base.ArchSpec(
    config=FULL, smoke=SMOKE,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes="long_500k skipped: full attention only.",
))
