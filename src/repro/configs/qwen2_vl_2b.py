"""qwen2-vl-2b [vlm] — arXiv:2409.12191 (Qwen team).

28 layers, d_model=1536, 12 heads GQA kv=2, d_ff=8960, vocab=151936,
M-RoPE (temporal/height/width bands 16+24+24 over head_dim/2=64), QKV bias.
The ViT vision tower + projector is a STUB: ``input_specs`` provides
patch embeddings (B, vision_tokens, d) merged at the sequence prefix;
M-RoPE assigns the prefix a (t,h,w) grid. Dynamic resolution is modeled by
the configurable vision_tokens/grid.
"""
from repro.configs import base
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv=2, d_ff=8960,
    vocab=151936, head_dim=128,
    rope="mrope", mrope_sections=(16, 24, 24), attn_bias=True,
    vision_tokens=1024, vision_grid_h=32,
    mlp_type="swiglu", norm_type="rmsnorm", max_seq=32768, remat=True,
    citation="arXiv:2409.12191",
)

SMOKE = ModelConfig(
    name="qwen2vl-smoke", family="vlm",
    n_layers=2, d_model=128, n_heads=4, n_kv=2, d_ff=256, vocab=512,
    head_dim=32, rope="mrope", mrope_sections=(4, 6, 6), attn_bias=True,
    vision_tokens=8, vision_grid_h=4, max_seq=128,
    citation="arXiv:2409.12191",
)

base.register("qwen2-vl-2b", base.ArchSpec(
    config=FULL, smoke=SMOKE,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes="long_500k skipped: full attention only.",
))
