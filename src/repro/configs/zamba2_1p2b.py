"""zamba2-1.2b [hybrid] — arXiv:2411.15242 (Zyphra).

38 Mamba2 layers (d_model=2048, ssm_state=64, d_inner=4096, 64 heads of
dim 64) with a SHARED attention+MLP block (32 heads MHA kv=32, d_ff=8192)
applied every 6 layers — the same weights fire at each application, each
with its own KV cache slot. (The model card's per-application LoRA deltas
and embedding-concat input are recorded simplifications.) long_500k RUNS:
SSM decode is O(1) and the 6 shared-attention applications decode one
token in O(S) against sequence-sharded caches.
"""
from repro.configs import base
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv=32, d_ff=8192,
    vocab=32000, head_dim=64,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
    ssm_chunk=256, conv_kernel=4, attn_every=6,
    norm_type="rmsnorm", max_seq=524288, remat=True,
    citation="arXiv:2411.15242",
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    n_layers=4, d_model=128, n_heads=4, n_kv=4, d_ff=256, vocab=512,
    head_dim=32, ssm_state=16, ssm_head_dim=32, ssm_expand=2,
    ssm_chunk=8, conv_kernel=4, attn_every=2, max_seq=128,
    citation="arXiv:2411.15242",
)

base.register("zamba2-1.2b", base.ArchSpec(
    config=FULL, smoke=SMOKE,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
))
