"""deepseek-v2-236b [moe+MLA] — arXiv:2405.04434 (DeepSeek-AI).

60 layers, d_model=5120, 128 heads MLA with kv_lora_rank=512
(qk_nope=128, qk_rope=64, v=128), vocab=102400, 160 routed experts top-6
+ 2 shared experts (moe d_ff=1536), first layer dense (d_ff=12288).
Experts expert-parallel over the worker axes (160/16 = 10 per DP group
single-pod, 5 per group multi-pod), dp=False for the optimizer.
The MLA cache stores the 512-dim latent + 64-dim rope key — the paper's
93% KV-cache reduction — and decode uses the absorbed-matmul form.
long_500k skipped (full attention).
"""
from repro.configs import base
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv=128, d_ff=12288,
    vocab=102400,
    attn_type="mla", kv_lora_rank=512, mla_qk_nope=128, mla_qk_rope=64,
    mla_v_dim=128,
    n_experts=160, top_k=6, n_shared_experts=2, moe_d_ff=1536,
    first_k_dense=1, capacity_factor=1.25,
    mlp_type="swiglu", norm_type="rmsnorm", max_seq=32768, remat=True,
    citation="arXiv:2405.04434",
)

SMOKE = ModelConfig(
    name="deepseek-smoke", family="moe",
    n_layers=3, d_model=128, n_heads=4, n_kv=4, d_ff=256, vocab=512,
    attn_type="mla", kv_lora_rank=32, mla_qk_nope=16, mla_qk_rope=8,
    mla_v_dim=16,
    n_experts=4, top_k=2, n_shared_experts=1, moe_d_ff=96,
    first_k_dense=1, capacity_factor=2.0, max_seq=128,
    citation="arXiv:2405.04434",
)

base.register("deepseek-v2-236b", base.ArchSpec(
    config=FULL, smoke=SMOKE,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes="long_500k skipped: full attention only.",
))
