"""granite-3-8b [dense] — hf:ibm-granite/granite-3.0-2b-base family (8B).

40 layers, d_model=4096, 32 heads GQA kv=8, d_ff=12800, vocab=49155,
RoPE + SwiGLU + RMSNorm. long_500k skipped (full attention).
"""
from repro.configs import base
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="granite-3-8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv=8, d_ff=12800,
    vocab=49155, head_dim=128,
    mlp_type="swiglu", norm_type="rmsnorm", max_seq=32768, remat=True,
    citation="hf:ibm-granite/granite-3.0-2b-base",
)

SMOKE = ModelConfig(
    name="granite-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv=2, d_ff=256, vocab=512,
    head_dim=32, max_seq=128, citation="hf:ibm-granite/granite-3.0-2b-base",
)

base.register("granite-3-8b", base.ArchSpec(
    config=FULL, smoke=SMOKE,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes="long_500k skipped: full attention only.",
))
