"""whisper-large-v3 [audio enc-dec] — arXiv:2212.04356 (Radford et al.).

32 encoder + 32 decoder layers, d_model=1280, 20 heads (MHA: kv=20),
d_ff=5120, vocab=51866, GELU MLP, LayerNorm, learned positions. The
mel-spectrogram + conv feature extractor frontend is a STUB per the
assignment: ``input_specs`` supplies precomputed frame embeddings
(B, 1500, 1280). Decode shapes apply (enc-dec, not encoder-only);
long_500k skipped: pure full attention, no sub-quadratic variant.
"""
from repro.configs import base
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, enc_layers=32, d_model=1280, n_heads=20, n_kv=20,
    d_ff=5120, vocab=51866, head_dim=64,
    rope="learned", mlp_type="gelu", norm_type="layernorm",
    attn_bias=True, enc_frames=1500, max_seq=32768, remat=True,
    citation="arXiv:2212.04356",
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="encdec",
    n_layers=2, enc_layers=2, d_model=128, n_heads=4, n_kv=4,
    d_ff=256, vocab=512, head_dim=32,
    rope="learned", mlp_type="gelu", norm_type="layernorm",
    attn_bias=True, enc_frames=16, max_seq=128,
    citation="arXiv:2212.04356",
)

base.register("whisper-large-v3", base.ArchSpec(
    config=FULL, smoke=SMOKE,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes="long_500k skipped: full-attention enc-dec, no sub-quadratic "
               "variant in the model card.",
))
