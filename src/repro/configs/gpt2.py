"""GPT-2 — the paper's own generative pre-training benchmark [Radford 2019].

The paper text says "117M parameters (48 layers, 1600 hidden size, 25
attention heads)" — those hyperparameters describe GPT-2-XL (1.5B), not
117M. We register the 117M GPT-2 (12L, d=768, 12H) that matches the stated
parameter count and the GPT-2 evaluation protocol, and note the
inconsistency here.
"""
from repro.configs import base
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="gpt2", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv=12, d_ff=3072,
    vocab=50257, head_dim=64,
    rope="learned", mlp_type="gelu", norm_type="layernorm",
    attn_bias=True, max_seq=32768, tie_embeddings=True,  # assigned shapes need 32k positions
    citation="Radford et al. 2019",
)

SMOKE = ModelConfig(
    name="gpt2-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv=4, d_ff=256, vocab=512,
    head_dim=32, rope="learned", mlp_type="gelu", norm_type="layernorm",
    attn_bias=True, max_seq=128, tie_embeddings=True,
    citation="Radford et al. 2019",
)

base.register("gpt2", base.ArchSpec(
    config=FULL, smoke=SMOKE, shapes=("train_4k", "prefill_32k",
                                      "decode_32k"),
    skip_notes="paper's own workload (native 1024 ctx; assigned shapes "
               "exercise the backbone). long_500k skipped: full attention.",
))
