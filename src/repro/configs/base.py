"""Architecture registry: one module per assigned architecture.

Every entry carries the FULL config (dry-run only — never materialized on
CPU), a reduced SMOKE config of the same family (2 layers, d_model ≤ 512,
≤ 4 experts) exercised by tests/test_arch_smoke.py, and the input-shape
eligibility with skip justifications (see DESIGN §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Tuple

from repro.models.config import ModelConfig

ALL_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    config: ModelConfig
    smoke: ModelConfig
    shapes: Tuple[str, ...]
    skip_notes: str = ""


_REGISTRY: Dict[str, ArchSpec] = {}

_ARCH_MODULES = [
    "whisper_large_v3", "chatglm3_6b", "qwen2_vl_2b",
    "llama4_scout_17b_a16e", "gemma3_12b", "mamba2_2p7b", "granite_3_8b",
    "deepseek_v2_236b", "zamba2_1p2b", "phi4_mini_3p8b",
    "bert_base", "bert_large", "gpt2",
]

ASSIGNED = [
    "whisper-large-v3", "chatglm3-6b", "qwen2-vl-2b",
    "llama4-scout-17b-a16e", "gemma3-12b", "mamba2-2.7b", "granite-3-8b",
    "deepseek-v2-236b", "zamba2-1.2b", "phi4-mini-3.8b",
]


def register(name: str, spec: ArchSpec):
    _REGISTRY[name] = spec


def _load():
    if _REGISTRY:
        return
    for m in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")


def get(name: str) -> ArchSpec:
    _load()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs():
    _load()
    return sorted(_REGISTRY)
