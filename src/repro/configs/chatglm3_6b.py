"""chatglm3-6b [dense] — arXiv:2406.12793 (GLM team).

28 layers, d_model=4096, 32 heads with GQA kv=2, d_ff=13696, vocab=65024,
partial rotary ("RoPE 2d" lineage: rotary on half the head dim), SwiGLU,
RMSNorm, QKV bias. All shapes except long_500k (full attention).
"""
from repro.configs import base
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv=2, d_ff=13696,
    vocab=65024, head_dim=128,
    rope="partial", rope_fraction=0.5, attn_bias=True,
    mlp_type="swiglu", norm_type="rmsnorm", max_seq=32768, remat=True,
    citation="arXiv:2406.12793",
)

SMOKE = ModelConfig(
    name="chatglm3-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv=2, d_ff=256, vocab=512,
    head_dim=32, rope="partial", rope_fraction=0.5, attn_bias=True,
    max_seq=128, citation="arXiv:2406.12793",
)

base.register("chatglm3-6b", base.ArchSpec(
    config=FULL, smoke=SMOKE,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes="long_500k skipped: full attention only.",
))
