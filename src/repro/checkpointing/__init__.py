from repro.checkpointing import io
