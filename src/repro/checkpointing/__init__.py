from repro.checkpointing import io

__all__ = ["io"]
