"""Checkpointing: atomic save/restore of (params, opt_state, step) pytrees.

Single-host NPZ-based storage with an atomic rename — adequate for the
CPU-scale examples/tests here; a production multi-pod deployment would swap
in orbax/tensorstore behind the same interface (noted in DESIGN.md).
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Tuple

import jax
import numpy as np


def save(path: str, tree: Any, step: int = 0, meta: Dict | None = None):
    leaves, treedef = jax.tree.flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    payload = {
        "step": step,
        "meta": meta or {},
        "treedef": str(treedef),
        "n_leaves": len(leaves),
    }
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, __manifest__=json.dumps(payload), **arrays)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def restore(path: str, like: Any) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``like`` (shape/dtype validated)."""
    with np.load(path, allow_pickle=False) as z:
        manifest = json.loads(str(z["__manifest__"]))
        leaves_like, treedef = jax.tree.flatten(like)
        if manifest["n_leaves"] != len(leaves_like):
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves, "
                f"expected {len(leaves_like)}")
        out = []
        for i, ref in enumerate(leaves_like):
            arr = z[f"leaf_{i}"]
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(
                    f"leaf {i}: checkpoint shape {arr.shape} != "
                    f"expected {ref.shape}")
            out.append(jax.numpy.asarray(arr, dtype=ref.dtype))
    return (jax.tree.unflatten(treedef, out), manifest["step"],
            manifest["meta"])


def latest(ckpt_dir: str) -> str | None:
    if not os.path.isdir(ckpt_dir):
        return None
    cands = [f for f in os.listdir(ckpt_dir) if f.endswith(".npz")]
    if not cands:
        return None
    return os.path.join(ckpt_dir, sorted(cands)[-1])
