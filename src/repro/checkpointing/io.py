"""Checkpointing: atomic save/restore of (params, opt_state, step) pytrees.

Single-host NPZ-based storage with an atomic rename — adequate for the
CPU-scale examples/tests here; a production multi-pod deployment would swap
in orbax/tensorstore behind the same interface (noted in DESIGN.md).

Format (manifest ``version`` 2): one array entry per pytree leaf
(``leaf_{i}`` in flatten order) plus a JSON ``__manifest__`` carrying the
step, user meta, leaf count, and per-leaf tree paths/shapes/dtypes.
``restore`` validates the checkpoint against the caller's ``like`` tree and
names the first mismatched leaf by its tree path — a resumed run can never
silently load state into the wrong slot. Version-1 checkpoints (no
``version`` / ``leaf_paths`` fields) are still readable; they get the same
count/shape validation with positional leaf names.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Tuple

import jax
import numpy as np

FORMAT_VERSION = 2


def leaf_paths(tree) -> list:
    """Per-leaf tree-path strings in flatten order — the structural
    fingerprint both the checkpoint manifest (v2) and the weight-publish
    manifest (serve/publish.py) embed, so a mismatched tree is named by
    path, not position."""
    paths_and_leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(path) for path, _ in paths_and_leaves]


def save(path: str, tree: Any, step: int = 0, meta: Dict | None = None):
    leaves, treedef = jax.tree.flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    ordered = [arrays[f"leaf_{i}"] for i in range(len(leaves))]
    payload = {
        "version": FORMAT_VERSION,
        "step": step,
        "meta": meta or {},
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "leaf_paths": leaf_paths(tree),
        "leaf_shapes": [list(a.shape) for a in ordered],
        "leaf_dtypes": [str(a.dtype) for a in ordered],
    }
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, __manifest__=json.dumps(payload), **arrays)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def read_manifest(path: str) -> Dict:
    """The checkpoint's JSON manifest alone (step, meta, leaf geometry) —
    no arrays materialized. Lets callers peek at e.g. the recorded fleet
    width (``meta['n_workers']``) before committing to a layout."""
    with np.load(path, allow_pickle=False) as z:
        return json.loads(str(z["__manifest__"]))


def restore(path: str, like: Any) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``like``.

    The manifest is validated against ``like`` before anything is
    materialized: leaf count, per-leaf tree paths (version >= 2), per-leaf
    shapes, and per-leaf dtypes (version >= 2) must all match, and the
    first mismatch raises a ``ValueError`` naming the offending leaf's
    tree path. A shape mismatch that looks like a DP-width change (the
    manifest records the saved fleet width and the leading worker dims
    disagree accordingly) names n -> m and points at ``repro.elastic``.
    """
    with np.load(path, allow_pickle=False) as z:
        manifest = json.loads(str(z["__manifest__"]))
        version = manifest.get("version", 1)
        if version > FORMAT_VERSION:
            raise ValueError(
                f"checkpoint {path!r} has format version {version}; this "
                f"build reads up to version {FORMAT_VERSION}")
        leaves_like, treedef = jax.tree.flatten(like)
        like_paths = leaf_paths(like)
        if manifest["n_leaves"] != len(leaves_like):
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves, expected "
                f"{len(leaves_like)} — the optimizer/model structure does "
                f"not match the checkpoint. A common cause is restoring "
                f"state saved under a different comm layout, e.g. a "
                f"per-leaf checkpoint into a bucketed (bucket_mb /"
                f" --bucket-mb) config or vice versa: the bucketed "
                f"exchange stores EF state and anchors per bucket, so the "
                f"state tree differs — resume with the layout the run was "
                f"saved under")
        ckpt_paths = manifest.get("leaf_paths")
        if ckpt_paths is not None:
            for i, (cp, lp) in enumerate(zip(ckpt_paths, like_paths)):
                if cp != lp:
                    raise ValueError(
                        f"checkpoint leaf {i} is {cp!r} but the target "
                        f"tree has {lp!r} at that position — tree "
                        f"structures diverge")
        # Shape validation: manifest against `like`, and the stored array
        # against the manifest (catches truncated/tampered payloads whose
        # manifest still matches); first mismatch names the leaf path.
        shapes = manifest.get("leaf_shapes")
        dtypes = manifest.get("leaf_dtypes")
        meta_n = (manifest.get("meta") or {}).get("n_workers")
        out = []
        for i, ref in enumerate(leaves_like):
            name = (ckpt_paths[i] if ckpt_paths is not None
                    else like_paths[i])
            stored = tuple(z[f"leaf_{i}"].shape)
            shape = tuple(shapes[i]) if shapes is not None else stored
            if shape != tuple(ref.shape):
                ref_shape = tuple(ref.shape)
                if (meta_n and shape and ref_shape
                        and shape[0] == meta_n and ref_shape[0] != meta_n):
                    raise ValueError(
                        f"leaf {i} ({name!r}): checkpoint shape {shape} != "
                        f"expected {ref_shape} — the checkpoint was saved "
                        f"at DP width n={meta_n} but the target tree is "
                        f"laid out for m={ref_shape[0]} workers. A width "
                        f"change re-chunks every comm view; restore "
                        f"through repro.elastic (restore_resharded, or "
                        f"reshard(state, n->m)) instead of loading the "
                        f"manifest directly")
                raise ValueError(
                    f"leaf {i} ({name!r}): checkpoint shape {shape} != "
                    f"expected {ref_shape}")
            if stored != shape:
                raise ValueError(
                    f"leaf {i} ({name!r}): stored array shape {stored} != "
                    f"manifest shape {shape} — corrupt checkpoint")
            if (dtypes is not None
                    and np.dtype(dtypes[i]) != np.dtype(ref.dtype)):
                raise ValueError(
                    f"leaf {i} ({name!r}): checkpoint dtype {dtypes[i]} != "
                    f"expected {np.dtype(ref.dtype).name} — restoring "
                    f"would silently cast optimizer state; rebuild the "
                    f"target tree with the checkpoint's dtypes (e.g. the "
                    f"state_dtype the run was saved under) or re-save")
            out.append(jax.numpy.asarray(z[f"leaf_{i}"], dtype=ref.dtype))
    return (jax.tree.unflatten(treedef, out), manifest["step"],
            manifest["meta"])


def latest(ckpt_dir: str) -> str | None:
    if not os.path.isdir(ckpt_dir):
        return None
    cands = [f for f in os.listdir(ckpt_dir) if f.endswith(".npz")]
    if not cands:
        return None
    return os.path.join(ckpt_dir, sorted(cands)[-1])
