from repro.serve.engine import Server
from repro.serve.publish import (Publisher, PublishConfig, Subscriber,
                                 WeightUpdate, load_update, save_update)
from repro.serve.scheduler import Request, Scheduler

__all__ = ["Server", "Publisher", "PublishConfig", "Subscriber",
           "WeightUpdate", "load_update", "save_update",
           "Request", "Scheduler"]
