from repro.serve.engine import Server

__all__ = ["Server"]
