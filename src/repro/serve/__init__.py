from repro.serve.engine import Server
