"""Continuous batching over the serve engine: slot scheduler + weight swap.

The engine (`serve/engine.py`) exposes prefill + single-token decode over a
fixed batch. This module grows that into a serving loop: a FIFO request
queue feeds a fixed set of batch *slots*; each tick admits queued requests
into free slots (prefill-on-admit), then decodes one token for every active
slot in a single batched step, and evicts slots whose requests completed.
Per-slot decode positions differ, so the batched step is a ``vmap`` over the
cache's batch axis (axis 1 on every cache leaf) with per-slot scalar
positions — numerically the same computation as running each request alone,
which `tests/test_serve.py` pins token-for-token.

Weight refresh: when a :class:`~repro.serve.publish.Subscriber` is attached
and has a pending update, it is applied at the tick boundary (never mid-
decode), so all slots always decode under one consistent parameter set.
Params enter the jitted step functions as arguments, so a swap never
recompiles.

Prefill compiles per distinct prompt length. ``T.prefill`` returns only the
last position's logits, so padding prompts to a shared length would lose
the first sampled token; exact-length prefill keeps the batched path
bitwise-comparable to the unbatched reference. Serving stacks with heavy
prompt-length churn would bucket lengths; the configs here have few.

KV-cache quantization (``kv_quant="qint8"``): cache pages of ``kv_page``
positions are quantized in place (max-abs scale per page per slot, qint8
codes with the same hash-dither stochastic rounding the wire codec uses)
exactly once, when the page fills — never requantized, so storage error is
bounded by one quantization step and does not accumulate as decode
proceeds. Applies to seq-indexed cache leaves (``shape[2] == max_seq``);
ring-buffer and SSM state leaves stay full precision.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codecs import _hash_dither  # same dither as the wire codec
from repro.core.comm import NullComm
from repro.models import transformer as T
from repro.serve.engine import Server


@dataclasses.dataclass
class Request:
    """One generation request: prompt token ids + a new-token budget.

    ``output`` accumulates generated ids (greedy argmax over the real
    vocab); ``done`` flips when ``max_new_tokens`` ids are out or
    ``eos_id`` is produced.
    """

    rid: Any
    prompt: Sequence[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False

    def __post_init__(self):
        if len(self.prompt) < 1:
            raise ValueError(f"request {self.rid!r}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"request {self.rid!r}: max_new_tokens must be >= 1")


class Scheduler:
    """Slot-based continuous batcher over a :class:`Server`.

    ``server.batch`` fixes the slot count and ``server.max_seq`` the cache
    extent; a request needs ``len(prompt) + max_new_tokens <= max_seq``.
    Encoder-decoder configs are rejected (decode would need per-slot
    encoder output plumbing this scheduler does not carry).
    """

    def __init__(self, server: Server, params, *,
                 subscriber=None, kv_quant: Optional[str] = None,
                 kv_page: int = 64):
        cfg = server.cfg
        if cfg.enc_layers:
            raise ValueError("Scheduler does not serve encoder-decoder "
                             "configs (per-slot enc_out not supported)")
        if kv_quant not in (None, "qint8"):
            raise ValueError(f"kv_quant must be None or 'qint8', "
                             f"got {kv_quant!r}")
        if kv_quant and (kv_page < 1 or server.max_seq % kv_page != 0):
            raise ValueError(
                f"kv_page must divide max_seq ({server.max_seq}), "
                f"got {kv_page}")
        self.server = server
        self.cfg = cfg
        self.params = params
        self.subscriber = subscriber
        self.n_slots = server.batch
        self.max_seq = server.max_seq
        self.kv_quant = kv_quant
        self.kv_page = kv_page
        self._comm = NullComm() if server.is_moe else None
        self.cache = T.init_cache(cfg, self.n_slots, self.max_seq,
                                  server.cache_dtype)
        self.slots: List[Optional[Request]] = [None] * self.n_slots
        self._pos = np.zeros(self.n_slots, np.int32)
        self._last_tok = np.zeros(self.n_slots, np.int32)
        self._pages_done = np.zeros(self.n_slots, np.int32)
        self.queue: Deque[Request] = deque()
        self.stats: Dict[str, int] = {
            "prefills": 0, "decode_ticks": 0, "generated": 0,
            "weight_swaps": 0, "pages_quantized": 0}
        self._build()

    # ------------------------------------------------------------------ #
    def _build(self):
        cfg, comm = self.cfg, self._comm
        max_seq, dtype = self.max_seq, self.server.cache_dtype

        @jax.jit
        def prefill_one(params, tokens):            # tokens (1, L) int32
            cache = T.init_cache(cfg, 1, max_seq, dtype)
            logits, cache = T.prefill(params, cfg, {"tokens": tokens},
                                      cache, comm=comm)
            return jnp.argmax(logits[0, -1, :cfg.vocab]), cache

        @jax.jit
        def write_slot(big, small, slot):
            # every cache leaf carries batch at axis 1; the batch-1 prefill
            # cache spans the full max_seq extent, so this overwrites the
            # slot's lane completely (no residue from the previous tenant)
            return jax.tree.map(
                lambda b, s: jax.lax.dynamic_update_slice_in_dim(
                    b, s.astype(b.dtype), slot, axis=1), big, small)

        @jax.jit
        def decode_tick(params, cache, tokens, pos):
            def one(lane, tok, p):
                c = jax.tree.map(lambda x: jnp.expand_dims(x, 1), lane)
                logits, c = T.decode(params, cfg, tok[None, None], c, p,
                                     comm=comm)
                c = jax.tree.map(lambda x: jnp.squeeze(x, 1), c)
                return jnp.argmax(logits[0, 0, :cfg.vocab]), c

            return jax.vmap(one, in_axes=(1, 0, 0),
                            out_axes=(0, 1))(cache, tokens, pos)

        page = self.kv_page

        @jax.jit
        def quant_page(cache, slot, start):
            def f(x):
                if not (x.ndim >= 3 and x.shape[2] == max_seq
                        and jnp.issubdtype(x.dtype, jnp.floating)):
                    return x
                lane = jax.lax.dynamic_slice_in_dim(x, slot, 1, axis=1)
                pg = jax.lax.dynamic_slice_in_dim(lane, start, page, axis=2)
                z = pg.astype(jnp.float32)
                s = jnp.max(jnp.abs(z)) / 127.0
                q = jnp.clip(jnp.floor(z / jnp.where(s > 0, s, 1.0)
                                       + _hash_dither(z)), -127.0, 127.0)
                deq = (q * s).astype(x.dtype)
                lane = jax.lax.dynamic_update_slice_in_dim(lane, deq, start,
                                                           axis=2)
                return jax.lax.dynamic_update_slice_in_dim(x, lane, slot,
                                                           axis=1)

            return jax.tree.map(f, cache)

        self._prefill_one = prefill_one
        self._write_slot = write_slot
        self._decode_tick = decode_tick
        self._quant_page = quant_page

    # ------------------------------------------------------------------ #
    def submit(self, req: Request) -> Request:
        if len(req.prompt) + req.max_new_tokens > self.max_seq:
            raise ValueError(
                f"request {req.rid!r}: prompt ({len(req.prompt)}) + "
                f"max_new_tokens ({req.max_new_tokens}) exceeds max_seq "
                f"({self.max_seq})")
        self.queue.append(req)
        return req

    @property
    def active(self) -> int:
        return sum(r is not None for r in self.slots)

    @property
    def idle(self) -> bool:
        return not self.queue and self.active == 0

    # ------------------------------------------------------------------ #
    def _maybe_swap_weights(self):
        sub = self.subscriber
        if sub is not None and sub.has_pending():
            self.params = sub.apply_pending()
            self.stats["weight_swaps"] += 1

    def _finish(self, slot: int, tok: int) -> bool:
        """Record token ``tok`` for the slot's request; evict if done."""
        req = self.slots[slot]
        req.output.append(tok)
        self.stats["generated"] += 1
        if (len(req.output) >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id)):
            req.done = True
            self.slots[slot] = None
            self._pos[slot] = 0
            self._last_tok[slot] = 0
            return True
        self._last_tok[slot] = tok
        return False

    def _quantize_filled_pages(self, slot: int):
        if not self.kv_quant:
            return
        filled = int(self._pos[slot]) // self.kv_page
        while int(self._pages_done[slot]) < filled:
            start = int(self._pages_done[slot]) * self.kv_page
            self.cache = self._quant_page(self.cache, jnp.int32(slot),
                                          jnp.int32(start))
            self._pages_done[slot] += 1
            self.stats["pages_quantized"] += 1

    def _admit(self):
        for slot in range(self.n_slots):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
            tok0, small = self._prefill_one(self.params, prompt)
            self.cache = self._write_slot(self.cache, small,
                                          jnp.int32(slot))
            self.stats["prefills"] += 1
            self.slots[slot] = req
            self._pos[slot] = prompt.shape[1]
            self._pages_done[slot] = 0
            if not self._finish(slot, int(tok0)):
                self._quantize_filled_pages(slot)

    # ------------------------------------------------------------------ #
    def tick(self) -> int:
        """One scheduler step: swap weights, admit, batched decode, evict.

        Returns the number of tokens generated this tick.
        """
        self._maybe_swap_weights()
        self._admit()
        active = [i for i in range(self.n_slots)
                  if self.slots[i] is not None]
        if not active:
            return 0
        toks, self.cache = self._decode_tick(
            self.params, self.cache, jnp.asarray(self._last_tok),
            jnp.asarray(self._pos))
        toks = np.asarray(toks)
        self.stats["decode_ticks"] += 1
        produced = 0
        for i in active:
            self._pos[i] += 1
            if not self._finish(i, int(toks[i])):
                self._quantize_filled_pages(i)
            produced += 1
        return produced

    def run(self, requests: Optional[Sequence[Request]] = None,
            max_ticks: int = 100_000) -> List[Request]:
        """Submit ``requests`` (if given) and tick until the queue drains."""
        reqs = list(requests) if requests is not None else []
        for r in reqs:
            self.submit(r)
        for _ in range(max_ticks):
            if self.idle:
                break
            self.tick()
        if not self.idle:
            raise RuntimeError(f"scheduler did not drain in "
                               f"{max_ticks} ticks")
        return reqs
