"""Serving engine: prefill + single-token decode with sharded KV caches.

Non-MoE architectures serve under plain ``jit`` with GSPMD-auto sharding;
MoE architectures serve under the partial-manual ``shard_map`` so the
expert-parallel token exchange is the explicit a2a (same code path as
training). Cache sharding policy:

  * batch >= #workers: batch over the worker axes, sequence over 'model'
    (keeps the 32k x big-head caches on-chip);
  * batch == 1 (long_500k): sequence over ALL axes — decode of one token
    against a 512k-token cache is O(S) compute, sequence-sharded memory.

SSM/hybrid states shard their head axis over 'model'.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import compat
from repro.core.comm import NullComm, mesh_comm
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.layers import is_pd


def _div(n, k):
    return k > 0 and n % k == 0


class Server:
    def __init__(self, model_cfg: ModelConfig, *, mesh=None,
                 worker_axes: Tuple[str, ...] = ("data",),
                 batch: int = 1, max_seq: int = 2048,
                 cache_dtype=jnp.bfloat16):
        self.cfg = model_cfg
        self.mesh = mesh
        self.W = worker_axes
        self.batch = batch
        self.max_seq = max_seq
        self.cache_dtype = cache_dtype
        self.n_workers = 1
        if mesh is not None:
            for a in worker_axes:
                self.n_workers *= mesh.shape[a]
        self.is_moe = model_cfg.n_experts > 0
        # expert parallelism over the largest worker-axis suffix dividing E
        self.ep_axes, self.ep_degree = (), 1
        if self.is_moe and mesh is not None:
            names = list(worker_axes)
            sizes = [mesh.shape[a] for a in names]
            for start in range(len(names) + 1):
                deg = 1
                for s in sizes[start:]:
                    deg *= s
                if model_cfg.n_experts % deg == 0:
                    self.ep_axes, self.ep_degree = tuple(names[start:]), deg
                    break
        self.template = T.model_template(model_cfg,
                                         ep_workers=self.ep_degree)

    # ------------------------------------------------------------------ #
    def param_shardings(self):
        """Serving holds ONE copy of the params: dense leaves replicated
        over the worker axes + TP over model; EP leaves expert-sharded."""
        mesh = self.mesh

        def f(pd):
            entries = tuple(pd.spec) if pd.spec else (None,) * len(pd.shape)
            if (not pd.dp and pd.ep_axis is not None and self.is_moe
                    and self.ep_axes):
                ax = pd.ep_axis
                entries = (entries[:ax] + (self.ep_axes,)
                           + entries[ax + 1:])
            return NamedSharding(mesh, P(*entries))

        return jax.tree.map(f, self.template, is_leaf=is_pd)

    def abstract_params(self, dtype=jnp.bfloat16):
        def f(pd):
            return jax.ShapeDtypeStruct(tuple(pd.shape), dtype)

        return jax.tree.map(f, self.template, is_leaf=is_pd)

    # ------------------------------------------------------------------ #
    def cache_shardings(self):
        cfg, mesh, W = self.cfg, self.mesh, self.W
        B = self.batch
        batch_ok = B % self.n_workers == 0 and B >= self.n_workers
        seq_axes = "model" if batch_ok else tuple(mesh.axis_names)

        def kv(ndim_prefix):
            # (L?, B, S, K, hd) — prefix covers the layer/app axis
            if batch_ok:
                return P(*([None] * ndim_prefix), W, seq_axes, None, None)
            return P(*([None] * ndim_prefix), None, seq_axes, None, None)

        if cfg.family in ("ssm", "hybrid"):
            hshard = "model" if _div(cfg.ssm_heads, 16) else None
            sh = {"ssm": {
                "h": P(None, W if batch_ok else None, hshard, None, None),
                "conv_x": P(None, W if batch_ok else None, None, "model"),
                "conv_B": P(None, W if batch_ok else None, None, None),
                "conv_C": P(None, W if batch_ok else None, None, None),
            }}
            if cfg.attn_every:
                sh["shared"] = {"k": kv(1), "v": kv(1)}
            return jax.tree.map(lambda s: NamedSharding(mesh, s), sh,
                                is_leaf=lambda x: isinstance(x, P))
        if cfg.attn_type == "mla":
            sh = {"ckv": P(None, W if batch_ok else None, seq_axes, None),
                  "kr": P(None, W if batch_ok else None, seq_axes, None)}
        elif cfg.window_cache and cfg.sliding_window and cfg.global_every:
            # ring buffers are small: batch-shard only; global stack as kv()
            lkv = P(None, W if batch_ok else None, None, None, None)
            sh = {"local": {"k": lkv, "v": lkv},
                  "global": {"k": kv(1), "v": kv(1)}}
        else:
            sh = {"k": kv(1), "v": kv(1)}
        return jax.tree.map(lambda s: NamedSharding(mesh, s), sh,
                            is_leaf=lambda x: isinstance(x, P))

    def abstract_cache(self):
        return jax.eval_shape(
            lambda: T.init_cache(self.cfg, self.batch, self.max_seq,
                                 self.cache_dtype))

    # ------------------------------------------------------------------ #
    def _comm(self):
        if self.mesh is None or not self.is_moe:
            return NullComm() if self.is_moe else None
        return mesh_comm(self.W)

    def prefill_fn(self):
        cfg = self.cfg

        def run(params, batch, cache, comm=None):
            return T.prefill(params, cfg, batch, cache, comm=comm)

        if self.mesh is None:
            comm = NullComm() if self.is_moe else None
            return jax.jit(functools.partial(run, comm=comm),
                           donate_argnums=(2,))
        if not self.is_moe:
            ps = self.param_shardings()
            cs = self.cache_shardings()
            bs = self._batch_sharding(prefill=True)
            return jax.jit(run, in_shardings=(ps, bs, cs),
                           out_shardings=(None, cs), donate_argnums=(2,))
        # MoE: shard_map manual over worker axes for the EP dispatch
        comm = (mesh_comm(self.ep_axes) if self.ep_axes else NullComm())
        W = self.W

        def body(params, batch, cache):
            return T.prefill(params, cfg, batch, cache, comm=comm)

        ep = self.ep_axes
        pi = jax.tree.map(
            lambda pd: (P(*((None,) * (pd.ep_axis or 0)), ep)
                        if (not pd.dp and pd.ep_axis is not None and ep)
                        else P()),
            self.template, is_leaf=is_pd)
        ci = jax.tree.map(lambda _: P(None, W), self.abstract_cache())
        bi = P(W)
        shm = compat.shard_map(body, mesh=self.mesh,
                                in_specs=(pi, bi, ci),
                                out_specs=(P(W), ci),
                                axis_names=set(W))
        ps = self.param_shardings()
        cs = self.cache_shardings()
        bs = self._batch_sharding(prefill=True)
        return jax.jit(shm, in_shardings=(ps, bs, cs),
                       out_shardings=(None, cs), donate_argnums=(2,))

    def decode_fn(self):
        cfg = self.cfg

        def run(params, cache, tokens, pos, enc_out=None, comm=None):
            return T.decode(params, cfg, tokens, cache, pos, comm=comm,
                            enc_out=enc_out)

        if self.mesh is None:
            comm = NullComm() if self.is_moe else None
            return jax.jit(functools.partial(run, comm=comm),
                           donate_argnums=(1,))
        if not self.is_moe:
            ps = self.param_shardings()
            cs = self.cache_shardings()
            ins = (ps, cs, None, None) + ((None,) if cfg.enc_layers else ())
            return jax.jit(run, in_shardings=ins,
                           out_shardings=(None, cs), donate_argnums=(1,))
        comm = (mesh_comm(self.ep_axes) if self.ep_axes else NullComm())
        W = self.W

        def body(params, cache, tokens, pos):
            return T.decode(params, cfg, tokens, cache, pos, comm=comm)

        ep = self.ep_axes
        pi = jax.tree.map(
            lambda pd: (P(*((None,) * (pd.ep_axis or 0)), ep)
                        if (not pd.dp and pd.ep_axis is not None and ep)
                        else P()),
            self.template, is_leaf=is_pd)
        ci = jax.tree.map(lambda _: P(None, W), self.abstract_cache())
        shm = compat.shard_map(body, mesh=self.mesh,
                                in_specs=(pi, ci, P(W), P()),
                                out_specs=(P(W), ci),
                                axis_names=set(W))
        ps = self.param_shardings()
        cs = self.cache_shardings()
        return jax.jit(shm, in_shardings=(ps, cs, None, None),
                       out_shardings=(None, cs), donate_argnums=(1,))

    def _batch_sharding(self, prefill: bool):
        B = self.batch
        if B % self.n_workers == 0 and B >= self.n_workers:
            return NamedSharding(self.mesh, P(self.W))
        return NamedSharding(self.mesh, P())
