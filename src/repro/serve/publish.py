"""Codec-compressed delta weight publishing: trainer -> serving replicas.

The training side compresses its wire with the five :mod:`repro.core.codecs`
formats over bucketed flat layouts; this module reuses that exact machinery
to close the training->serving loop. A :class:`Publisher` snapshots trainer
parameters onto the bucketed flat layouts of
:func:`repro.core.bucketing.make_bucket_plan`, delta-encodes them against
the **last published anchor** — the same anchor discipline ``compressed_dp``
maintains for Algorithm-1 parameter recovery — and emits codec-compressed
payloads; a :class:`Subscriber` on the serving replica decodes payload +
anchor back into the engine's parameter tree, so a continuous-fine-tuning
trainer can refresh serving weights at a fraction of a full-f32 push.

Anchor / delta semantics (the EF discipline, applied to deployment):

* the publisher keeps ``anchor[k]`` = the exact buffer the subscriber holds
  for bucket ``k`` — both sides advance it by ``codec.decode(payload)``, the
  *same* floats, so publisher and subscriber can never drift apart;
* a **delta** publish encodes ``params - anchor``; the codec's quantization
  error is *not* lost — it is simply still present in the next delta
  (``params - anchor`` includes it), so reconstruction error is bounded by
  one quantization step of the *current* delta's scale and never
  accumulates across publishes;
* **snapshot** publishes (the first publish, every
  ``snapshot_every``-th one, or ``force_snapshot=True``) ship the raw f32
  buffers and reset the anchor to the exact parameters, bounding drift by
  construction. Exact codecs (``identity``: ``needs_ef=False``) always ship
  full buffers — a lossless delta would cost the same bytes as the
  snapshot, so there is nothing to delta-encode.

Every publish carries a **manifest** (format-versioned like the checkpoint
manifest v2, same leaf-path fingerprint via
:func:`repro.checkpointing.io.leaf_paths`): wire-layout geometry (codec,
``n_chunks``, ``bucket_mb``, ``pack_order``, ``scale_mode``, bucket count),
the per-leaf tree paths/shapes/dtypes of the parameter tree, the publish
sequence number and the anchor sequence a delta applies to. A subscriber
validates every field against its own plan before touching state, so a
stale delta, a different codec, or a different model fails loudly naming
the offending field — never a silent wrong-weights load.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpointing.io import leaf_paths
from repro.core import bucketing as B
from repro.core import compressor as C
from repro.core.codecs import Codec, IdentityCodec, make_codec
from repro.core.leafwise import make_plan

PUBLISH_FORMAT_VERSION = 1

#: bucket budget that degenerates to one (fused) bucket per leaf — the
#: "flat" per-leaf wire layout (budget computes to 1 element)
_PER_LEAF_MB = 2.0 ** -22

#: manifest fields a Subscriber must agree on before applying anything
_LAYOUT_FIELDS = ("codec", "codec_arg", "scale_mode", "n_chunks",
                  "bucket_mb", "pack_order", "n_buckets",
                  "leaf_shapes", "leaf_dtypes")


@dataclasses.dataclass(frozen=True)
class PublishConfig:
    """Wire-layout + cadence knobs shared by Publisher and Subscriber.

    ``n_chunks`` plays the role the worker count plays in training layouts:
    the bucket buffer is viewed as ``(n_chunks, bucket_elems/n_chunks)`` and
    codec scale granularity is per chunk row — more chunks, tighter scales,
    a few more scale bytes. ``bucket_mb=None`` keeps one bucket per leaf.
    """

    codec: Any = "qint8"
    codec_arg: Optional[float] = None
    scale_mode: str = "chunk"
    n_chunks: int = 16
    bucket_mb: Optional[float] = 4.0
    pack_order: str = "flat"
    snapshot_every: int = 16     # every k-th publish is a full snapshot

    def __post_init__(self):
        make_codec(self.codec, self.codec_arg)   # fail fast on bad names
        C.validate_scale_mode(self.scale_mode)
        if self.n_chunks < 1:
            raise ValueError(f"n_chunks must be >= 1, got {self.n_chunks}")
        if self.bucket_mb is not None and self.bucket_mb <= 0:
            raise ValueError(
                f"bucket_mb must be positive or None, got {self.bucket_mb}")
        if self.snapshot_every < 1:
            raise ValueError(
                f"snapshot_every must be >= 1, got {self.snapshot_every}")

    def make_codec(self) -> Codec:
        return make_codec(self.codec, self.codec_arg)


@dataclasses.dataclass
class WeightUpdate:
    """One published refresh: manifest + per-bucket payload trees."""

    manifest: Dict[str, Any]
    payloads: List[Dict[str, np.ndarray]]

    @property
    def kind(self) -> str:
        return self.manifest["kind"]

    @property
    def seq(self) -> int:
        return int(self.manifest["seq"])

    def nbytes(self) -> int:
        return int(sum(a.nbytes for p in self.payloads
                       for a in p.values()))


class _WirePlan:
    """The shared publisher/subscriber view of one parameter tree: a
    :class:`~repro.core.leafwise.LeafPlan` with ``n_chunks`` chunk rows and
    a bucket plan over it. Pure function of (abstract tree, config) — both
    sides derive it independently and the manifest proves they agree."""

    def __init__(self, abstract_params, cfg: PublishConfig):
        self.cfg = cfg
        self.abstract = abstract_params
        self.plan = make_plan(abstract_params, None, None, cfg.n_chunks)
        self.bp = B.make_bucket_plan(
            self.plan, cfg.bucket_mb if cfg.bucket_mb else _PER_LEAF_MB,
            pack_order=cfg.pack_order)
        self.codec = cfg.make_codec()
        self.leaf_dtypes = [np.dtype(l.dtype) for l in self.plan.leaves]
        self.masks = [C.pad_mask(b.layout) for b in self.bp.buckets]

    # -------------------------------------------------------------- #
    def bucketize(self, params) -> List[jnp.ndarray]:
        """Parameter tree -> per-bucket f32 view buffers."""
        leaves = self.plan.flat(params)
        bufs = []
        for b in self.bp.buckets:
            views = [C.to_view(leaves[i].astype(jnp.float32),
                               self.plan.layouts[i]) for i in b.members]
            bufs.append(B.gather_views(b, views))
        return bufs

    def unbucketize(self, bufs: List[jnp.ndarray]):
        """Per-bucket buffers -> parameter tree (leaf dtypes restored)."""
        leaves = [None] * len(self.plan.leaves)
        for b, buf in zip(self.bp.buckets, bufs):
            layouts = [self.plan.layouts[i] for i in b.members]
            for i, v in zip(b.members, B.scatter_views(b, buf, layouts)):
                leaves[i] = C.from_view(v, self.plan.layouts[i]).astype(
                    self.leaf_dtypes[i])
        return jax.tree.unflatten(self.plan.treedef, leaves)

    # -------------------------------------------------------------- #
    def manifest_base(self) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            "version": PUBLISH_FORMAT_VERSION,
            "codec": self.codec.name,
            "codec_arg": cfg.codec_arg,
            "scale_mode": cfg.scale_mode,
            "n_chunks": cfg.n_chunks,
            "bucket_mb": cfg.bucket_mb,
            "pack_order": cfg.pack_order,
            "n_buckets": len(self.bp.buckets),
            "leaf_paths": leaf_paths(self.abstract),
            "leaf_shapes": [list(l.shape) for l in self.plan.leaves],
            "leaf_dtypes": [str(np.dtype(l.dtype))
                            for l in self.plan.leaves],
        }

    def advance_anchors(self, anchors, payloads, kind: str):
        """Advance the anchor buffers by one applied update.

        Eager on purpose: Publisher and Subscriber both step their anchors
        through this exact op-by-op sequence. Inside ``jit`` the compiler
        may contract ``anchor + q * s`` into an FMA, and whether it does
        depends on the surrounding graph — so a jitted publisher-side
        advance and an eager subscriber-side one end up an ulp apart, and
        the bitwise lockstep the delta scheme relies on is gone."""
        if kind == "snapshot":
            return [jnp.asarray(p["values"]) for p in payloads]
        return [anchor + self.codec.decode(
                    {k: jnp.asarray(v) for k, v in p.items()}, b.layout)
                for anchor, p, b in zip(anchors, payloads, self.bp.buckets)]

    def wire_bytes(self, kind: str) -> int:
        """Declared bytes of one publish: per-chunk codec bytes summed over
        every bucket's chunk rows (``codec.wire_bytes`` is per chunk, the
        same accounting the training exchange uses)."""
        codec = IdentityCodec() if kind == "snapshot" else self.codec
        total = 0
        for b in self.bp.buckets:
            wb = codec.wire_bytes(b.layout, self.cfg.scale_mode)
            total += wb["scatter"] * b.layout.n
        return int(total)

    def full_f32_bytes(self) -> int:
        """Cost of the uncompressed baseline: pushing every true parameter
        element at f32 (no padding — the raw tree, not the wire view)."""
        return 4 * int(sum(b.true_elems for b in self.bp.buckets))


def _validate_manifest(mine: Dict[str, Any], theirs: Dict[str, Any]):
    """First mismatched field raises, naming it (and the leaf path when the
    mismatch is inside the per-leaf fingerprint)."""
    if theirs.get("version", 0) > PUBLISH_FORMAT_VERSION:
        raise ValueError(
            f"publish manifest field 'version': payload has "
            f"{theirs.get('version')}, this build reads up to "
            f"{PUBLISH_FORMAT_VERSION}")
    if mine["leaf_paths"] != theirs.get("leaf_paths"):
        a, b = mine["leaf_paths"], theirs.get("leaf_paths") or []
        for i in range(max(len(a), len(b))):
            pa = a[i] if i < len(a) else "<missing>"
            pb = b[i] if i < len(b) else "<missing>"
            if pa != pb:
                raise ValueError(
                    f"publish manifest field 'leaf_paths': leaf {i} is "
                    f"{pb!r} in the payload but {pa!r} on the subscriber "
                    f"— parameter trees diverge")
    for f in _LAYOUT_FIELDS:
        if mine[f] != theirs.get(f):
            detail = ""
            if f in ("leaf_shapes", "leaf_dtypes"):
                for i, (x, y) in enumerate(zip(mine[f], theirs.get(f))):
                    if x != y:
                        detail = (f" (leaf {mine['leaf_paths'][i]!r}: "
                                  f"payload {y} != subscriber {x})")
                        break
            raise ValueError(
                f"publish manifest field {f!r}: payload has "
                f"{theirs.get(f)!r}, subscriber expects {mine[f]!r}{detail}")


class Publisher:
    """Trainer-side: turn parameter trees into :class:`WeightUpdate`s.

    Stateful — owns the published-anchor buffers. One Publisher feeds any
    number of subscribers as long as they all apply every update in
    sequence (the manifest's ``seq``/``anchor_seq`` enforce it).
    """

    def __init__(self, params_like, cfg: PublishConfig = PublishConfig()):
        abstract = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(jnp.shape(x),
                                           jnp.result_type(x)), params_like)
        self.wire = _WirePlan(abstract, cfg)
        self.cfg = cfg
        self._anchor: Optional[List[jnp.ndarray]] = None
        self._seq = 0
        self._encode = jax.jit(self._encode_impl, static_argnames=("kind",))

    # -------------------------------------------------------------- #
    def _encode_impl(self, params, anchors, *, kind: str):
        wire = self.wire
        bufs = wire.bucketize(params)
        if kind == "snapshot":
            return [{"values": buf} for buf in bufs]
        codec = wire.codec
        payloads = []
        for buf, anchor, bkt, mask in zip(bufs, anchors, wire.bp.buckets,
                                          wire.masks):
            delta = buf - anchor
            payload, _ = codec.encode_worker(
                delta, jnp.zeros_like(delta), bkt.layout,
                wire.cfg.scale_mode, mask)
            payloads.append(payload)
        return payloads

    def publish(self, params, step: int = 0,
                force_snapshot: bool = False) -> WeightUpdate:
        """Encode the current parameters as the next update in sequence."""
        exact = not self.wire.codec.needs_ef
        kind = "snapshot" if (exact or force_snapshot
                              or self._anchor is None
                              or self._seq % self.cfg.snapshot_every == 0
                              ) else "delta"
        payloads = self._encode(
            params, self._anchor if kind == "delta" else None, kind=kind)
        payloads = [
            {k: np.asarray(v) for k, v in p.items()} for p in payloads]
        # advance the anchor by the *decoded emitted payload* — through
        # the same (eager) op sequence the subscriber runs, so the two
        # sides hold bitwise-identical anchors and the codec's
        # quantization error survives into the next delta instead of
        # being lost
        self._anchor = self.wire.advance_anchors(self._anchor, payloads,
                                                 kind)
        manifest = self.wire.manifest_base()
        manifest.update(kind=kind, seq=self._seq,
                        anchor_seq=self._seq - 1 if kind == "delta" else None,
                        step=int(step),
                        payload_bytes=self.wire.wire_bytes(kind))
        self._seq += 1
        update = WeightUpdate(manifest=manifest, payloads=payloads)
        if update.nbytes() != manifest["payload_bytes"]:
            raise AssertionError(
                f"publish wire accounting drift: payload arrays carry "
                f"{update.nbytes()} bytes, codec.wire_bytes declares "
                f"{manifest['payload_bytes']}")
        return update

    @property
    def seq(self) -> int:
        return self._seq


class Subscriber:
    """Replica-side: decode :class:`WeightUpdate`s into parameter trees.

    ``push`` is the transport stub (in-process queue); a deployment would
    feed ``apply``/``push`` from its pub-sub bus. ``shardings`` (e.g. the
    engine's ``param_shardings()``) places decoded leaves directly into the
    serving layout — the engine's compiled ``prefill_fn``/``decode_fn``
    never recompile on a weight refresh, because shapes, dtypes, and
    shardings are exactly those they were compiled for.
    """

    def __init__(self, params_like, cfg: PublishConfig = PublishConfig(),
                 shardings=None):
        abstract = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(jnp.shape(x),
                                           jnp.result_type(x)), params_like)
        self.wire = _WirePlan(abstract, cfg)
        self.cfg = cfg
        self.shardings = shardings
        self._anchor: Optional[List[jnp.ndarray]] = None
        self._seq: Optional[int] = None
        self._pending: List[WeightUpdate] = []
        self._applied = 0

    # ------------------------------------------------------------------ #
    def push(self, update: WeightUpdate):
        self._pending.append(update)

    def has_pending(self) -> bool:
        return bool(self._pending)

    def apply_pending(self):
        """Apply every queued update in order; returns the final tree (or
        None if nothing was queued)."""
        params = None
        while self._pending:
            params = self.apply(self._pending.pop(0))
        return params

    # ------------------------------------------------------------------ #
    def _validate(self, manifest: Dict[str, Any]):
        _validate_manifest(self.wire.manifest_base(), manifest)
        kind = manifest.get("kind")
        if kind not in ("snapshot", "delta"):
            raise ValueError(
                f"publish manifest field 'kind': {kind!r} is not "
                f"'snapshot' or 'delta'")
        if kind == "delta":
            if self._anchor is None:
                raise ValueError(
                    "publish manifest field 'anchor_seq': got a delta "
                    "update but this subscriber holds no anchor yet "
                    "(no snapshot has been applied)")
            if manifest.get("anchor_seq") != self._seq:
                raise ValueError(
                    f"publish manifest field 'anchor_seq': delta applies "
                    f"to anchor seq {manifest.get('anchor_seq')!r} but "
                    f"this subscriber is at seq {self._seq!r} — updates "
                    f"must be applied in publish order")

    def apply(self, update: WeightUpdate):
        """Validate + decode one update; returns the full parameter tree."""
        self._validate(update.manifest)
        nbytes = int(sum(a.nbytes for p in update.payloads
                         for a in p.values()))
        if nbytes != update.manifest["payload_bytes"]:
            raise ValueError(
                f"publish manifest field 'payload_bytes': declares "
                f"{update.manifest['payload_bytes']} but payload arrays "
                f"carry {nbytes} — truncated or tampered update")
        wire = self.wire
        self._anchor = wire.advance_anchors(self._anchor, update.payloads,
                                            update.kind)
        self._seq = update.seq
        self._applied += 1
        params = wire.unbucketize(self._anchor)
        if self.shardings is not None:
            params = jax.device_put(params, self.shardings)
        return params

    @property
    def seq(self) -> Optional[int]:
        return self._seq

    @property
    def applied(self) -> int:
        return self._applied


# ---------------------------------------------------------------------------
# File transport (same atomic-npz idiom as checkpointing.io)
# ---------------------------------------------------------------------------

def save_update(path: str, update: WeightUpdate):
    """Serialize one update to an npz (atomic rename, manifest as JSON)."""
    arrays = {}
    for k, payload in enumerate(update.payloads):
        for name, arr in payload.items():
            arrays[f"b{k}__{name}"] = np.asarray(arr)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, __manifest__=json.dumps(update.manifest), **arrays)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_update(path: str) -> WeightUpdate:
    with np.load(path, allow_pickle=False) as z:
        manifest = json.loads(str(z["__manifest__"]))
        payloads: List[Dict[str, np.ndarray]] = [
            {} for _ in range(int(manifest["n_buckets"]))]
        for key in z.files:
            if key == "__manifest__":
                continue
            bucket, name = key.split("__", 1)
            payloads[int(bucket[1:])][name] = z[key]
    return WeightUpdate(manifest=manifest, payloads=payloads)
