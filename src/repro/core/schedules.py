"""Step-index policies T_v (variance freezing) and T_u (local steps).

Both policies are expressed as small carried-state machines over jnp scalars
so the entire training step stays jit-compiled with no host round-trips.

Paper policies (§6):

* **T_v (adaptive variance freezing)** — the j-th and (j+1)-th variance
  updates are ``2^{floor(j/κ)}`` steps apart (κ=16). Additionally, variance
  updates stop permanently once the local-step interval exceeds 1 ("we
  additionally stop updating variance when t_{j+1} − t_j > 1").
* **T_u (learning-rate-proportional local steps)** — sync every step during
  lr warmup; afterwards the sync interval doubles every ``double_every``
  steps (tracking the lr half-life), clipped at ``max_interval`` (H=16).

Baseline policies: ``every step`` (original Adam / ablations) and
``first T0 steps`` (the 1-bit Adam two-stage split, Algorithm 4).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


def _i32(x):
    return jnp.asarray(x, jnp.int32)


# ---------------------------------------------------------------------------
# Variance-update policies (T_v)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdaptiveFreezePolicy:
    """Paper's exponentially-spaced T_v: k_{j+1} - k_j = 2^{floor(j/kappa)}."""

    kappa: int = 16
    max_interval_pow: int = 30  # safety clamp on the exponent

    def init(self):
        # (next update step, j = number of updates done, stopped flag)
        return (_i32(0), _i32(0), jnp.asarray(False))

    def step(self, state, t, local_interval):
        nxt, j, stopped = state
        stopped = jnp.logical_or(stopped, local_interval > 1)
        fire = jnp.logical_and(t == nxt, jnp.logical_not(stopped))
        expo = jnp.minimum(j // self.kappa, self.max_interval_pow)
        gap = jnp.left_shift(_i32(1), expo.astype(jnp.int32))
        nxt = jnp.where(fire, t + gap, nxt)
        j = jnp.where(fire, j + 1, j)
        return fire, (nxt, j, stopped)


@dataclasses.dataclass(frozen=True)
class FixedWarmupPolicy:
    """T_v = {0, ..., T0-1}: 1-bit Adam's full-precision stage (Alg. 4)."""

    t0: int

    def init(self):
        return ()

    def step(self, state, t, local_interval):
        del local_interval
        return t < self.t0, state


@dataclasses.dataclass(frozen=True)
class EveryStepVariancePolicy:
    """T_v = all steps: original Adam behaviour."""

    def init(self):
        return ()

    def step(self, state, t, local_interval):
        del local_interval
        return jnp.asarray(True), state


# ---------------------------------------------------------------------------
# Sync (local step) policies (T_u)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LrProportionalSyncPolicy:
    """Interval 1 through warmup, then doubling every ``double_every`` steps.

    interval(t) = 1                                   if t < warmup
                = min(2^{floor((t-warmup)/double_every)}, max_interval)

    The sync fires when ``t`` reaches the scheduled next sync step; the next
    sync is then ``interval(t)`` steps away.
    """

    warmup_steps: int
    double_every: int
    max_interval: int = 16

    def interval(self, t):
        past = jnp.maximum(t - self.warmup_steps, 0)
        expo = jnp.minimum(past // self.double_every, 30)
        iv = jnp.left_shift(_i32(1), expo.astype(jnp.int32))
        iv = jnp.minimum(iv, self.max_interval)
        return jnp.where(t < self.warmup_steps, _i32(1), iv)

    def init(self):
        return (_i32(0),)  # next sync step

    def step(self, state, t):
        (nxt,) = state
        fire = t >= nxt
        nxt = jnp.where(fire, t + self.interval(t), nxt)
        return fire, (nxt,), self.interval(t)


@dataclasses.dataclass(frozen=True)
class EveryStepSyncPolicy:
    """T_u = all steps (no communication skipping; Fig. 5 ablation)."""

    def init(self):
        return ()

    def step(self, state, t):
        return jnp.asarray(True), state, _i32(1)


# ---------------------------------------------------------------------------
# Learning-rate schedules (training substrate)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LinearWarmupExpDecay:
    """The paper's BERT schedule: linear warmup, then ×decay every period."""

    peak_lr: float
    warmup_steps: int
    decay: float = 0.99
    decay_period: int = 520

    def __call__(self, t):
        t = jnp.asarray(t, jnp.float32)
        w = jnp.maximum(self.warmup_steps, 1)
        warm = self.peak_lr * (t + 1) / w
        k = jnp.floor(jnp.maximum(t - self.warmup_steps, 0) / self.decay_period)
        decayed = self.peak_lr * jnp.power(self.decay, k)
        return jnp.where(t < self.warmup_steps, warm, decayed)


@dataclasses.dataclass(frozen=True)
class LinearWarmupCosine:
    """The paper's GPT-2 schedule: linear warmup + single-cycle cosine."""

    peak_lr: float
    warmup_steps: int
    total_steps: int
    min_lr: float = 1e-5

    def __call__(self, t):
        t = jnp.asarray(t, jnp.float32)
        w = jnp.maximum(self.warmup_steps, 1)
        warm = self.peak_lr * (t + 1) / w
        frac = jnp.clip((t - self.warmup_steps) /
                        jnp.maximum(self.total_steps - self.warmup_steps, 1),
                        0.0, 1.0)
        cos = self.min_lr + 0.5 * (self.peak_lr - self.min_lr) * (
            1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(t < self.warmup_steps, warm, cos)


@dataclasses.dataclass(frozen=True)
class ConstantLr:
    lr: float

    def __call__(self, t):
        return jnp.full((), self.lr, jnp.float32)
