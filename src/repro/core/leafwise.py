"""Per-leaf comm planning shared by every optimizer in ``repro.core``.

Historically ``adam.py`` / ``one_bit_adam.py`` / ``zero_one_adam.py`` each
re-derived the same construction-time plumbing — flatten the param tree,
align specs and the DP mask, normalize the hierarchy, build a
:class:`~repro.core.compressor.LeafLayout` and view-spec entries per leaf,
assemble the AllReduce config. :class:`LeafPlan` is that boilerplate,
factored out once; the composed :mod:`repro.core.compressed` optimizer and
the legacy reference classes both build on it, so the two code paths can
never drift on layout geometry.

The hierarchy is normalized here (``norm_hierarchy``) and nowhere else on
the optimizer side: every consumer reads ``plan.hierarchy``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

import jax

from repro.core import compressor as C
from repro.core import onebit_allreduce as AR
from repro.core.comm import Hierarchy, norm_hierarchy


@dataclasses.dataclass(frozen=True)
class LeafPlan:
    """Static per-leaf communication plan for one parameter tree."""

    n: int                          # worker count
    hierarchy: Optional[Hierarchy]  # normalized (None when flat / n == 1)
    model_axes: Tuple[str, ...]     # manual tensor-parallel axes
    treedef: Any
    leaves: List[Any]               # abstract leaves (shape/dtype)
    specs: List[Any]                # tensor-parallel PartitionSpecs
    dp_mask: List[bool]
    layouts: List[C.LeafLayout]
    vspecs: List[Any]               # view-shaped spec entries per leaf

    def flat(self, tree):
        return self.treedef.flatten_up_to(tree)


def make_plan(param_shapes, specs, dp_mask, n_workers: int,
              model_axis_sizes=None,
              hierarchy: Optional[Hierarchy] = None) -> LeafPlan:
    if specs is None:
        specs = jax.tree.map(lambda _: None, param_shapes)
    if dp_mask is None:
        dp_mask = jax.tree.map(lambda _: True, param_shapes)
    model_axis_sizes = model_axis_sizes or {}
    hierarchy = norm_hierarchy(hierarchy, n_workers)
    leaves, treedef = jax.tree.flatten(param_shapes)
    specs_f = treedef.flatten_up_to(specs)
    dp_f = treedef.flatten_up_to(dp_mask)
    layouts = [
        C.make_layout(l.shape, s, n_workers,
                      rest_factor=C.spec_model_factor(s, model_axis_sizes),
                      force_flatten=bool(model_axis_sizes),
                      n_inner=hierarchy.inner if hierarchy else 1)
        for l, s in zip(leaves, specs_f)]
    vspecs = [C.view_spec_entries(lo, sp)
              for lo, sp in zip(layouts, specs_f)]
    return LeafPlan(n=n_workers, hierarchy=hierarchy,
                    model_axes=tuple(model_axis_sizes.keys()),
                    treedef=treedef, leaves=leaves, specs=specs_f,
                    dp_mask=dp_f, layouts=layouts, vspecs=vspecs)


def make_ar_cfg(plan: LeafPlan, *, scale_mode, quantize, use_pallas,
                comm_dtype, codec=None, codec_arg=None) -> AR.OneBitConfig:
    """Algorithm-2 exchange config bound to a plan's topology.

    ``codec`` is a wire-format name or instance (``repro.core.codecs``);
    ``None`` keeps the historical rule: sign1bit, or identity when
    ``quantize`` is False. A name is resolved here with ``codec_arg``
    applied, so callers holding an unresolved (name, arg) pair — the
    legacy optimizer classes — don't silently drop the arg."""
    if codec is not None:
        from repro.core.codecs import make_codec
        codec = make_codec(codec, codec_arg)
    return AR.OneBitConfig(scale_mode=scale_mode, quantize=quantize,
                           codec=codec,
                           model_axes=plan.model_axes,
                           use_pallas=use_pallas,
                           hierarchy=plan.hierarchy,
                           comm_dtype=comm_dtype)
