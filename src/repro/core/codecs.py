"""Pluggable compression codecs for the error-feedback exchange.

The Algorithm-2 sync machinery (worker EF-compress -> all_to_all -> server
average + EF-compress -> all_gather, see ``onebit_allreduce``) is agnostic
to the *wire format* of what it exchanges: 1-bit-BytePS (Zhong et al.) and
APMSqueeze (Tang et al.) run the same schedule over sign bits, top-k
sparsification, and low-bit integer quantization. This module factors that
wire format out as a first-class :class:`Codec`:

* ``encode_worker(z, err, layout, mode, mask, ...) -> (payload, err')`` —
  one EF compression pass over this worker's buffer (the full comm view on
  a flat topology, the owned reduce-scatter slice on a hierarchy). The
  *payload* is a pytree of arrays whose leading axis enumerates the outer
  chunks, so the exchange can map collectives over its leaves without
  knowing the format.
* ``encode_server(avg, err, layout, mode, mask, widx, ...) -> (payload,
  err')`` — the server-side pass over the single chunk this worker serves
  (payload leaves carry leading dim 1 for the tiled all_gather).
* ``decode(payload, layout, dtype) -> dense`` — payload -> dense values,
  leading chunk axis preserved.
* ``wire_bytes(layout, mode) -> {"scatter": int, "gather": int}`` — bytes
  of ONE chunk's payload in each exchange phase, feeding the static
  data-volume accounting (``compressor.compressed_bytes_levels``).

Capability flags: ``needs_ef`` (identity is exact — no error-feedback
state is touched) and ``has_pallas`` (only the sign-1-bit format has fused
Pallas kernels; other codecs stay on the jnp path — see
``kernels.dispatch.kernel_codec``).

Implementations:

* ``sign1bit`` — the paper's compressor (packed sign bits + L1-mean
  scales), extracted from the pre-refactor exchange bit-identically; the
  default everywhere.
* ``topk`` — EF sparsification: the ``density`` fraction of largest-|z|
  elements per chunk ship as (int32 index, f32 value) pairs; everything
  else stays in the error buffer.
* ``qint8`` / ``qint4`` — integer quantization with one max-abs scale per
  chunk and deterministic-dither stochastic rounding (the dither is a hash
  of the value bits, so runs are reproducible); qint4 packs two codes per
  byte.
* ``identity`` — the exact mean at full precision (absorbs the legacy
  ``quantize=False`` knob; the degenerate-equivalence tests and the
  no-compression ablation).

Every codec is EF-compatible: ``decode(encode(z)) + err' == z`` restricted
to real (non-padded) elements, and padded positions contribute exactly
zero to payloads, scales, and errors.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compressor as C


def _ident(x):
    return x


def _chunk_elems(layout: C.LeafLayout) -> int:
    return int(np.prod(layout.chunk_shape)) if layout.chunk_shape else 1


class Codec:
    """Base class / protocol for exchange wire formats (see module doc)."""

    name: str = "?"
    has_pallas: bool = False   # fused Pallas kernels exist for this format
    needs_ef: bool = True      # False -> exact codec, EF state untouched

    def encode_worker(self, z, err, layout: C.LeafLayout, mode: str, mask,
                      model_axes=(), inner_index=None, use_pallas=False,
                      cst=None, vspec=None
                      ) -> Tuple[Dict[str, jnp.ndarray], Any]:
        raise NotImplementedError

    def encode_server(self, avg, err, layout: C.LeafLayout, mode: str, mask,
                      worker_index, model_axes=(), use_pallas=False,
                      cst=None, vspec=None
                      ) -> Tuple[Dict[str, jnp.ndarray], Any]:
        raise NotImplementedError

    def decode(self, payload, layout: C.LeafLayout, dtype=jnp.float32,
               use_pallas=False, vspec=None) -> jnp.ndarray:
        raise NotImplementedError

    def wire_bytes(self, layout: C.LeafLayout, mode: str) -> Dict[str, int]:
        raise NotImplementedError

    def payload_spec(self, layout: C.LeafLayout
                     ) -> Dict[str, Tuple[Tuple[str, Any], ...]]:
        """Declared wire-format metadata: ``{"scatter": ..., "gather": ...}``
        with ordered ``(leaf name, wire dtype)`` pairs per exchange phase.

        The order is the payload's collective emission order (``jax.tree``
        traversal of the payload dict = sorted leaf names), so the IR
        auditor (:mod:`repro.analysis.ir_audit`) can check the lowered
        collective schedule — and each collective's operand dtype — against
        this declaration without running the codec. A codec whose traced
        payloads disagree with its own ``payload_spec`` fails the audit.
        """
        raise NotImplementedError


# ---------------------------------------------------------------------------
# sign1bit — the paper's compressor, extracted bit-identically
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Sign1BitCodec(Codec):
    """Packed sign bits + L1-mean magnitudes (paper Eq. 4 / Algorithm 2).

    Payload: ``{"packed": uint8 bit-packed signs, "scales": f32}`` with the
    scales broadcast to one row per chunk so both leaves route through the
    same all_to_all. Scale granularity follows ``scale_mode`` exactly as
    the pre-refactor exchange did (including the 2-D-view row-mode
    degeneracies on each side).
    """

    name = "sign1bit"
    has_pallas = True

    def encode_worker(self, z, err, layout, mode, mask, model_axes=(),
                      inner_index=None, use_pallas=False, cst=None,
                      vspec=None):
        cst = cst or _ident
        if use_pallas:
            from repro.kernels import dispatch as K
            packed, scales, err_w = K.ef_compress_view(
                z, err.astype(z.dtype), layout, mode, model_axes,
                inner_index=inner_index, vspec=vspec)
        else:
            zw = cst(z + err.astype(z.dtype))
            if inner_index is None:
                packed, scales, err_w = C.ef_compress(zw, layout, mode,
                                                      mask, model_axes)
            else:
                packed, scales, err_w = C.ef_compress_slice(
                    zw, layout, mode, mask, inner_index, model_axes)
        # broadcast "tensor"/"chunk" scales to chunk rows so each receiver
        # gets the proper per-sender magnitude after the all_to_all
        bscales = jnp.broadcast_to(
            scales, (z.shape[0],) + scales.shape[1:]).astype(jnp.float32)
        return {"packed": packed, "scales": bscales}, err_w

    def encode_server(self, avg, err, layout, mode, mask, worker_index,
                      model_axes=(), use_pallas=False, cst=None,
                      vspec=None):
        cst = cst or _ident
        k_ok = use_pallas and not (mode == "row"
                                   and len(layout.view_shape) == 2)
        if k_ok:
            from repro.kernels import dispatch as K
            packed_s, scales_s, err_s = K.server_compress_view(
                cst(avg[None]), err.astype(avg.dtype)[None], layout, mode,
                worker_index, model_axes, vspec=vspec)
        else:
            y = avg + err.astype(avg.dtype)
            packed_s, scales_s, err_s = _server_compress(
                cst(y[None]), layout, mode, mask, model_axes)
        return ({"packed": packed_s, "scales": scales_s.astype(jnp.float32)},
                cst(err_s)[0])

    def decode(self, payload, layout, dtype=jnp.float32, use_pallas=False,
               vspec=None):
        packed, scales = payload["packed"], payload["scales"]
        # row granularity on 2-D (flatten) views degenerates to per-element
        # scales on the server side (trailing dim > 1); the fused kernel
        # consumes per-row scales only, so that case stays on jnp — the
        # same split the pre-refactor k_server flag made.
        if use_pallas and scales.shape[-1] == 1:
            from repro.kernels import dispatch as K
            return K.decompress_view(packed, scales, layout, dtype,
                                     vspec=vspec)
        vals = C.unpack_signs(packed, layout.pack_count, dtype)
        return vals * scales.astype(dtype)

    def payload_spec(self, layout):
        leaves = (("packed", jnp.uint8), ("scales", jnp.float32))
        return {"scatter": leaves, "gather": leaves}

    def wire_bytes(self, layout, mode):
        chunk_packed = _chunk_elems(layout) // 8
        if mode in ("tensor", "chunk"):
            scatter_scales = gather_scales = 1
        elif len(layout.view_shape) == 2:
            # row granularity degenerates on flatten views: the worker side
            # falls back to chunk scales (see compressor._scales), the
            # server side to per-element scales (see _server_compress).
            scatter_scales, gather_scales = 1, layout.view_shape[1]
        else:
            scatter_scales = gather_scales = layout.view_shape[1]
        return {"scatter": chunk_packed + 4 * scatter_scales,
                "gather": chunk_packed + 4 * gather_scales}


def _server_compress(y, layout, mode, mask, model_axes=()):
    """EF-compress one server chunk (leading dim 1) — sign-1-bit format.

    The chunk shares the leaf layout but its scale granularity reuses the
    chunk level of the configured mode (one scale for tensor/chunk, one
    per row for row mode — degenerating to per-element on 2-D views).
    """
    az = jnp.abs(y)
    if mask is not None:
        az = az * mask
    rest = layout.rest_factor
    for s in y.shape[2:]:
        rest *= s
    if mode == "row":
        axes = tuple(range(2, y.ndim))
        cnt = max(rest, 1)
        s = (C._psum_model(az.sum(axis=axes), model_axes) / cnt
             if y.ndim > 2 else az)
        scales = s.reshape(y.shape[:2] + (1,) * (y.ndim - 2))
    else:  # tensor / chunk -> one scale for this chunk
        denom = (az.size * layout.rest_factor if mask is None
                 else jnp.maximum(mask.sum() * rest, 1.0))
        denom = jnp.asarray(denom, y.dtype)
        scales = (C._psum_model(az.sum(), model_axes)
                  / denom).reshape((1,) * y.ndim)
    packed = C.pack_signs(y)
    signs = jnp.where(y >= 0, 1.0, -1.0).astype(y.dtype)
    err = y - signs * scales.astype(y.dtype)
    if mask is not None:
        err = err * mask.astype(err.dtype)
    return packed, scales, err


def resolve_with_quantize(codec, quantize: bool):
    """The shared ``quantize=False`` back-compat rule (ONE place, called
    from both ``CompressedDP.__post_init__`` and
    ``OneBitConfig.__post_init__`` so the composed and legacy paths can
    never disagree): ``None`` resolves to the default for the flag;
    the deprecated ``quantize=False`` forces the exact mean unless a
    NON-default codec is set — an explicit ``"sign1bit"``, by name or
    instance, is indistinguishable from the default and is rewritten too,
    since the old knob always meant "exact mean"."""
    if codec is None:
        return "sign1bit" if quantize else "identity"
    if not quantize and getattr(codec, "name", codec) == "sign1bit":
        return "identity"
    return codec


# ---------------------------------------------------------------------------
# identity — exact mean (absorbs the legacy quantize=False branch)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class IdentityCodec(Codec):
    """Exact (uncompressed) exchange: payload is the raw buffer.

    ``needs_ef=False``: the exchange leaves the EF state untouched, exactly
    like the historical ``quantize=False`` branch it replaces."""

    name = "identity"
    needs_ef = False

    def encode_worker(self, z, err, layout, mode, mask, model_axes=(),
                      inner_index=None, use_pallas=False, cst=None,
                      vspec=None):
        return {"values": z}, None

    def encode_server(self, avg, err, layout, mode, mask, worker_index,
                      model_axes=(), use_pallas=False, cst=None,
                      vspec=None):
        return {"values": avg[None]}, None

    def decode(self, payload, layout, dtype=jnp.float32, use_pallas=False,
               vspec=None):
        # deliberately NOT cast: the exact mean accumulates in the buffer's
        # own dtype (the exchange casts the final result to compute_dtype),
        # matching the pre-refactor quantize=False branch bitwise
        return payload["values"]

    def payload_spec(self, layout):
        leaves = (("values", jnp.float32),)
        return {"scatter": leaves, "gather": leaves}

    def wire_bytes(self, layout, mode):
        ce = _chunk_elems(layout) * 4          # f32 wire
        return {"scatter": ce, "gather": ce}


# ---------------------------------------------------------------------------
# dense-EF codecs: topk sparsification, qint8/qint4 quantization
# ---------------------------------------------------------------------------

class _DenseEFCodec(Codec):
    """Shared EF wrapper for codecs defined by a plain masked
    ``_encode(z, layout, mask) -> (payload, err)`` over a (lead, *chunk)
    buffer: the worker pass folds the incoming error into the buffer, the
    server pass additionally adds the chunk-leading axis. A third dense-EF
    codec only implements ``_encode`` / ``decode`` / ``wire_bytes``."""

    def _encode(self, z, layout, mask):
        raise NotImplementedError

    def encode_worker(self, z, err, layout, mode, mask, model_axes=(),
                      inner_index=None, use_pallas=False, cst=None,
                      vspec=None):
        return self._encode(z + err.astype(z.dtype), layout, mask)

    def encode_server(self, avg, err, layout, mode, mask, worker_index,
                      model_axes=(), use_pallas=False, cst=None,
                      vspec=None):
        y = (avg + err.astype(avg.dtype))[None]
        payload, e = self._encode(y, layout, mask)
        return payload, e[0]


@dataclasses.dataclass(frozen=True)
class TopKCodec(_DenseEFCodec):
    """Ship the ``density`` fraction of largest-magnitude elements per
    chunk as (index, value) pairs; the rest stays in the error buffer.

    ``k`` is static per layout (``ceil(density * chunk_elems)``), so shapes
    and byte counts are compile-time constants. Padded positions are masked
    to zero before selection — they can only be picked when a chunk has
    fewer than ``k`` real elements, and then carry exact zeros."""

    density: float = 0.01
    name = "topk"

    def __post_init__(self):
        if not 0.0 < self.density <= 1.0:
            raise ValueError(
                f"topk density must be in (0, 1], got {self.density}")

    def k_for(self, layout: C.LeafLayout) -> int:
        ce = _chunk_elems(layout)
        return max(1, min(ce, int(math.ceil(self.density * ce))))

    def _encode(self, z, layout, mask):
        lead, ce = z.shape[0], _chunk_elems(layout)
        if mask is not None:
            z = z * mask.astype(z.dtype)
        zf = z.reshape(lead, ce)
        k = self.k_for(layout)
        _, idx = jax.lax.top_k(jnp.abs(zf), k)
        val = jnp.take_along_axis(zf, idx, axis=1)
        # the residual is zf with the shipped elements zeroed — one
        # scatter, no dense decode buffer
        err = zf.at[jnp.arange(lead)[:, None], idx].set(0.0).reshape(z.shape)
        return {"idx": idx.astype(jnp.int32), "val": val}, err

    def decode(self, payload, layout, dtype=jnp.float32, use_pallas=False,
               vspec=None):
        idx, val = payload["idx"], payload["val"]
        lead, ce = idx.shape[0], _chunk_elems(layout)
        dense = jnp.zeros((lead, ce), dtype).at[
            jnp.arange(lead)[:, None], idx].set(val.astype(dtype))
        return dense.reshape((lead,) + layout.chunk_shape)

    def payload_spec(self, layout):
        leaves = (("idx", jnp.int32), ("val", jnp.float32))
        return {"scatter": leaves, "gather": leaves}

    def wire_bytes(self, layout, mode):
        per = self.k_for(layout) * (4 + 4)      # int32 index + f32 value
        return {"scatter": per, "gather": per}


# ---------------------------------------------------------------------------
# qint8 / qint4 — low-bit integer quantization with stochastic rounding
# ---------------------------------------------------------------------------

def _hash_dither(x: jnp.ndarray) -> jnp.ndarray:
    """Deterministic U[0,1) dither from the value's own bits (Knuth
    multiplicative hash + xor-fold). Stochastic rounding without threading
    a PRNG key through the exchange; exact zeros dither to exactly 0, so
    padded positions stay bit-zero through the quantizer."""
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    h = bits * jnp.uint32(2654435761)
    h = h ^ (h >> jnp.uint32(16))
    return (h >> jnp.uint32(8)).astype(jnp.float32) * (1.0 / (1 << 24))


@dataclasses.dataclass(frozen=True)
class QIntCodec(_DenseEFCodec):
    """Integer quantization: one max-abs scale per chunk, codes in
    ``[-qmax, qmax]`` via stochastic rounding (``floor(z/s + dither)``,
    error < 1 ulp of the scale, bias absorbed by EF). ``bits=4`` packs two
    offset-binary codes per byte."""

    bits: int = 8

    def __post_init__(self):
        if self.bits not in (4, 8):
            raise ValueError(f"qint bits must be 4 or 8, got {self.bits}")

    @property
    def name(self):
        return f"qint{self.bits}"

    @property
    def qmax(self) -> int:
        return 127 if self.bits == 8 else 7

    def _encode(self, z, layout, mask):
        lead, ce = z.shape[0], _chunk_elems(layout)
        if mask is not None:
            z = z * mask.astype(z.dtype)
        zf = z.reshape(lead, ce).astype(jnp.float32)
        qmax = float(self.qmax)
        s = jnp.max(jnp.abs(zf), axis=1, keepdims=True) / qmax
        s_safe = jnp.where(s > 0, s, 1.0)
        q = jnp.clip(jnp.floor(zf / s_safe + _hash_dither(zf)), -qmax, qmax)
        err = (zf - q * s).astype(z.dtype).reshape(z.shape)
        if self.bits == 8:
            payload = {"q": q.astype(jnp.int8), "scale": s}
        else:
            u = (q + qmax).astype(jnp.uint8)       # offset-binary in [0, 14]
            pair = u.reshape(lead, ce // 2, 2)
            payload = {"q": pair[..., 0] * 16 + pair[..., 1], "scale": s}
        return payload, err

    def decode(self, payload, layout, dtype=jnp.float32, use_pallas=False,
               vspec=None):
        q, s = payload["q"], payload["scale"]
        lead = q.shape[0]
        if self.bits == 4:
            hi, lo = q // 16, q % 16
            q = jnp.stack([hi, lo], axis=-1).reshape(lead, -1)
            q = q.astype(jnp.float32) - float(self.qmax)
        return (q.astype(dtype) * s.astype(dtype)).reshape(
            (lead,) + layout.chunk_shape)

    def payload_spec(self, layout):
        qdt = jnp.int8 if self.bits == 8 else jnp.uint8
        leaves = (("q", qdt), ("scale", jnp.float32))
        return {"scatter": leaves, "gather": leaves}

    def wire_bytes(self, layout, mode):
        ce = _chunk_elems(layout)
        per = (ce if self.bits == 8 else ce // 2) + 4   # codes + f32 scale
        return {"scatter": per, "gather": per}


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_FACTORIES = {
    "sign1bit": lambda arg: Sign1BitCodec(),
    "topk": lambda arg: TopKCodec(density=0.01 if arg is None
                                  else float(arg)),
    "qint8": lambda arg: QIntCodec(bits=8),
    "qint4": lambda arg: QIntCodec(bits=4),
    "identity": lambda arg: IdentityCodec(),
}

CODEC_NAMES = tuple(sorted(_FACTORIES))

# which codecs accept a ``codec_arg`` (and what it means)
CODEC_ARGS = {"topk": "density in (0, 1] (default 0.01)"}


def make_codec(spec, arg: Optional[float] = None) -> Codec:
    """Resolve a codec name (plus optional argument) or pass through an
    instance. Raises ``ValueError`` naming the registry on a bad name, and
    on a ``codec_arg`` given to a codec that takes none. An instance plus
    an ``arg`` re-parameterizes through the registry (so
    ``codec=TopKCodec(), codec_arg=0.5`` means density 0.5, never a
    silently ignored arg)."""
    if isinstance(spec, Codec):
        if arg is None:
            return spec
        if spec.name in _FACTORIES and spec.name in CODEC_ARGS:
            return _FACTORIES[spec.name](arg)
        raise ValueError(
            f"codec {spec.name!r} takes no codec_arg (got {arg!r}); only "
            f"{sorted(CODEC_ARGS)} are parameterized: {CODEC_ARGS}")
    if not isinstance(spec, str) or spec not in _FACTORIES:
        raise ValueError(
            f"unknown codec {spec!r}; choose from {list(CODEC_NAMES)}")
    if arg is not None and spec not in CODEC_ARGS:
        raise ValueError(
            f"codec {spec!r} takes no codec_arg (got {arg!r}); only "
            f"{sorted(CODEC_ARGS)} are parameterized: {CODEC_ARGS}")
    return _FACTORIES[spec](arg)
