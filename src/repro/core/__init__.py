"""The paper's primary contribution: 0/1 Adam and its communication substrate.

Public surface:
  - compressed_dp + base steps (composable optimizer API)
                                            (compressed.py / base_steps.py)
  - build_optimizer / make_optimizer (shim) / OptimizerConfig / REGISTRY_NAMES
                                            (api.py)
  - Comm / sim_comm / mesh_comm             (comm.py)
  - schedules: T_v / T_u policies + lr      (schedules.py)
  - onebit_allreduce_view (Algorithm 2)     (onebit_allreduce.py)
  - pluggable exchange codecs               (codecs.py)
  - 1-bit EF compressor + comm-view layouts (compressor.py)
"""
from repro.core.api import (OptimizerConfig, make_optimizer, build_optimizer,
                            transform_from_config, comm_accounting,
                            REGISTRY_NAMES, LEGACY_NAMES)
from repro.core.codecs import (Codec, CODEC_NAMES, make_codec)
from repro.core.base_steps import (adam_base, lamb_base, momentum_sgd_base,
                                   AdamBase, LambBase, MomentumSgdBase)
from repro.core.compressed import (CompressedDP, CompressedDPState,
                                   compressed_dp)
from repro.core.comm import (Comm, Hierarchy, mesh_comm, sim_comm,
                             run_simulated)
from repro.core import schedules
from repro.core import bucketing
from repro.core import codecs
from repro.core import compressor
from repro.core import onebit_allreduce

__all__ = [
    "OptimizerConfig", "make_optimizer", "build_optimizer",
    "transform_from_config", "comm_accounting", "REGISTRY_NAMES",
    "LEGACY_NAMES",
    "Codec", "CODEC_NAMES", "make_codec", "codecs",
    "adam_base", "lamb_base", "momentum_sgd_base",
    "AdamBase", "LambBase", "MomentumSgdBase",
    "CompressedDP", "CompressedDPState", "compressed_dp",
    "Comm", "Hierarchy", "mesh_comm", "sim_comm", "run_simulated",
    "schedules", "bucketing", "compressor", "onebit_allreduce",
]
