"""The paper's primary contribution: 0/1 Adam and its communication substrate.

Public surface:
  - make_optimizer / OptimizerConfig        (api.py)
  - Comm / sim_comm / mesh_comm             (comm.py)
  - schedules: T_v / T_u policies + lr      (schedules.py)
  - onebit_allreduce_view (Algorithm 2)     (onebit_allreduce.py)
  - 1-bit EF compressor + comm-view layouts (compressor.py)
"""
from repro.core.api import OptimizerConfig, make_optimizer, comm_accounting
from repro.core.comm import (Comm, Hierarchy, mesh_comm, sim_comm,
                             run_simulated)
from repro.core import schedules
from repro.core import compressor
from repro.core import onebit_allreduce

__all__ = [
    "OptimizerConfig", "make_optimizer", "comm_accounting",
    "Comm", "Hierarchy", "mesh_comm", "sim_comm", "run_simulated",
    "schedules", "compressor", "onebit_allreduce",
]
