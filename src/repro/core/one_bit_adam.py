"""1-bit Adam baseline (Tang et al. 2021) = paper Algorithm 4 with
``T_v = {0..T0-1}``: a full-precision stage that pre-conditions the variance,
then a compression stage with frozen variance and error-feedback 1-bit
AllReduce of the gradients.

.. deprecated:: Superseded by the composable API —
   ``compressed_dp(adam_base(...), style="gradient",
   var_policy=FixedWarmupPolicy(T0), ...)`` reproduces this class bitwise
   (tests/test_composed_equivalence.py). Retained as the frozen reference
   implementation those equivalence tests pin against.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import compressor as C
from repro.core import leafwise
from repro.core import onebit_allreduce as AR
from repro.core.comm import Comm


class OneBitAdamState(NamedTuple):
    step: jnp.ndarray
    m: list          # view shapes
    v: list          # view shapes
    err_w: list      # view shapes (None for non-DP leaves)
    err_s: list      # chunk shapes (None for non-DP leaves)


class OneBitAdam:
    def __init__(self, cfg, param_shapes, specs, dp_mask, n_workers,
                 model_axis_sizes=None):
        self.cfg = cfg
        plan = leafwise.make_plan(param_shapes, specs, dp_mask, n_workers,
                                  model_axis_sizes, cfg.hierarchy)
        self.n = plan.n
        self.model_axes = plan.model_axes
        self.hierarchy = plan.hierarchy
        self.treedef = plan.treedef
        self.specs = plan.specs
        self.dp_mask = plan.dp_mask
        self.layouts = plan.layouts
        self.vspecs = plan.vspecs
        self.ar_cfg = leafwise.make_ar_cfg(
            plan, scale_mode=cfg.scale_mode, quantize=cfg.quantize,
            codec=cfg.codec, codec_arg=cfg.codec_arg,
            use_pallas=cfg.use_pallas, comm_dtype=cfg.comm_dtype)

    def flat(self, tree):
        return self.treedef.flatten_up_to(tree)

    def init(self, params) -> OneBitAdamState:
        ps = self.flat(params)
        sd = self.cfg.state_dtype

        def zst(p, lo, dp):
            return jnp.zeros(lo.view_shape if dp else p.shape, sd)

        return OneBitAdamState(
            step=jnp.zeros((), jnp.int32),
            m=[zst(p, lo, dp) for p, lo, dp in
               zip(ps, self.layouts, self.dp_mask)],
            v=[zst(p, lo, dp) for p, lo, dp in
               zip(ps, self.layouts, self.dp_mask)],
            err_w=[jnp.zeros(lo.ef_worker_shape, sd) if dp else None
                   for lo, dp in zip(self.layouts, self.dp_mask)],
            err_s=[jnp.zeros(lo.chunk_shape, sd) if dp else None
                   for lo, dp in zip(self.layouts, self.dp_mask)],
        )

    def step(self, comm: Comm, params, grads, state: OneBitAdamState,
             worker_index=None):
        cfg = self.cfg
        t = state.step
        lr = cfg.lr(t).astype(jnp.float32)
        warm = t < cfg.onebit_warmup

        xs, gs = self.flat(params), self.flat(grads)
        gv = [C.constrain(C.to_view(g.astype(jnp.float32), lo), vs) if dp
              else g.astype(jnp.float32)
              for g, lo, dp, vs in zip(gs, self.layouts, self.dp_mask,
                                       self.vspecs)]

        dp_idx = [i for i, dp in enumerate(self.dp_mask) if dp]

        def full_branch(op):
            gs_dp, ew, es = op
            out = [AR.fullprec_allreduce_view(comm, g, cfg.comm_dtype,
                                              vspec=self.vspecs[i],
                                              hierarchy=self.hierarchy,
                                              layout=self.layouts[i])
                   for g, i in zip(gs_dp, dp_idx)]
            return out, ew, es

        def onebit_branch(op):
            gs_dp, ew, es = op
            outs, news_w, news_s = [], [], []
            for g, w, s, i in zip(gs_dp, ew, es, dp_idx):
                lo = self.layouts[i]
                o, ef = AR.onebit_allreduce_view(
                    comm, g, AR.EFState(w, s), lo, self.ar_cfg,
                    vspec=self.vspecs[i], worker_index=worker_index)
                outs.append(o.astype(jnp.float32))
                news_w.append(ef.err_worker)
                news_s.append(ef.err_server)
            return outs, news_w, news_s

        op = ([gv[i] for i in dp_idx],
              [state.err_w[i] for i in dp_idx],
              [state.err_s[i] for i in dp_idx])
        agg_dp, new_ew_dp, new_es_dp = jax.lax.cond(
            warm, full_branch, onebit_branch, op)

        gbar = list(gv)
        new_ew, new_es = list(state.err_w), list(state.err_s)
        for k, i in enumerate(dp_idx):
            gbar[i] = agg_dp[k]
            new_ew[i] = new_ew_dp[k]
            new_es[i] = new_es_dp[k]

        new_x, new_m, new_v = [], [], []
        for x, g, m, v, lo, dp in zip(xs, gbar, state.m, state.v,
                                      self.layouts, self.dp_mask):
            m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
            nm = cfg.beta1 * m32 + (1 - cfg.beta1) * g
            if dp:
                nv = jnp.where(warm,
                               cfg.beta2 * v32 + (1 - cfg.beta2) * g * g, v32)
            else:  # local leaves: plain Adam, v every step
                nv = cfg.beta2 * v32 + (1 - cfg.beta2) * g * g
            delta = lr * nm / jnp.sqrt(v32 + cfg.eps)
            if dp:
                delta = C.from_view(delta, lo)
            new_x.append((x.astype(jnp.float32) - delta).astype(x.dtype))
            new_m.append(nm.astype(m.dtype))
            new_v.append(nv.astype(v.dtype))

        metrics = {"lr": lr, "synced": jnp.asarray(True), "var_round": warm,
                   "interval": jnp.ones((), jnp.int32)}
        return (jax.tree.unflatten(self.treedef, new_x),
                OneBitAdamState(step=t + 1, m=new_m, v=new_v,
                                err_w=new_ew, err_s=new_es), metrics)
