"""0/1 Adam (paper Algorithm 1) — the paper's primary contribution.

Per step t (per worker i, with v frozen between refreshes):

    m_{t+½} = β₁ m_t + (1−β₁) g_t                      (local)
    x_{t+½} = x_t − γ_t · m_{t+½} / √(v_t+ε)           (local)
    u_{t+½} = u_t + γ_t · m_{t+½}                      (local)
    if t ∈ T_u:   ū = 1bit-AllReduce(u_{t+½})          (Algorithm 2)
                  m_{t+1} = ū / Σ_{h=t'+1}^t γ_h        (momentum approx)
                  x_{t+1} = x_{t'} − ū / √(v_t+ε)       (sync to mean)
                  u_{t+1} = 0 ; t' = t
    if t ∈ T_v:   ḡ = AllReduce(g_t) ;  v_{t+1} = β₂ v_t + (1−β₂) ḡ²

Indexing note: Algorithm 1 as printed writes ``m_t`` on lines 4–5 and
``Σ_{h=t'}`` on line 8; the appendix analysis (Lemma 8 accumulates momenta
over steps k+1..t and divides by t−k) and the requirement that
``T_u = every step`` + lossless compression recover *distributed Adam
exactly* pin down the intended indexing used here: the freshly-updated
momentum enters x and u, and the denominator sums γ over the steps since
(exclusive) the last sync. Under that convention the degenerate-config
equivalence with Adam is exact — asserted in tests/test_optimizers.py.

Implementation notes:

* **Anchor handling.** Line 9 needs x_{t'}. Default (``store_anchor=True``)
  keeps the synced copy so workers agree bitwise after every sync. The
  memory-optimized mode exploits the schedule guarantee that v is frozen
  whenever the sync interval exceeds 1 (the paper's own policy), so
  ``x_{t+½} = x_{t'} − u_{t+½}/√(v+ε)`` holds exactly and
  ``x_{t+1} = x_{t+½} + (u_{t+½} − ū)/√(v+ε)`` recovers the sync without a
  second parameter copy, at the cost of ~1e-6 rounding drift per sync.
* All optimizer state except the parameters lives in *comm view* shape
  (see compressor.py), so elementwise math and the sync path share layout
  and nothing ever reshards across the tensor-parallel axis.
* Leaves with ``dp_mask=False`` (expert-parallel params that exist once per
  worker axis) run plain local Adam — they have no DP gradient exchange for
  the paper's technique to compress (see DESIGN §Arch-applicability).

.. deprecated:: Superseded by the composable API —
   ``compressed_dp(adam_base(...), style="accumulate", ...)`` reproduces
   this class bitwise (tests/test_composed_equivalence.py). Retained as the
   frozen reference implementation those equivalence tests pin against;
   ``make_optimizer(name="zero_one_adam")`` now returns the composed
   pipeline, not this class.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import compressor as C
from repro.core import leafwise
from repro.core import onebit_allreduce as AR
from repro.core.comm import Comm


class ZeroOneAdamState(NamedTuple):
    step: jnp.ndarray
    gamma_acc: jnp.ndarray       # Σ γ_h since last sync (inclusive scheme)
    sync_pstate: tuple           # T_u policy carried state
    var_pstate: tuple            # T_v policy carried state
    m: list                      # view shapes
    v: list                      # view shapes (replicated-consistent)
    u: list                      # view shapes (None for non-DP leaves)
    err_w: list                  # view shapes (None for non-DP leaves)
    err_s: list                  # chunk shapes (None for non-DP leaves)
    anchor: list                 # x_{t'} copies (None unless store_anchor)


class ZeroOneAdam:
    def __init__(self, cfg, param_shapes, specs, dp_mask, n_workers,
                 model_axis_sizes=None):
        self.cfg = cfg
        plan = leafwise.make_plan(param_shapes, specs, dp_mask, n_workers,
                                  model_axis_sizes, cfg.hierarchy)
        self.n = plan.n
        self.model_axes = plan.model_axes
        self.hierarchy = plan.hierarchy
        self.treedef = plan.treedef
        self.specs = plan.specs
        self.dp_mask = plan.dp_mask
        self.layouts = plan.layouts
        self.vspecs = plan.vspecs
        self.ar_cfg = leafwise.make_ar_cfg(
            plan, scale_mode=cfg.scale_mode, quantize=cfg.quantize,
            codec=cfg.codec, codec_arg=cfg.codec_arg,
            use_pallas=cfg.use_pallas, comm_dtype=cfg.comm_dtype)

    def flat(self, tree):
        return self.treedef.flatten_up_to(tree)

    def init(self, params) -> ZeroOneAdamState:
        """DP leaves store state in comm-view shape; expert-parallel
        (dp=False) leaves store natural-shape state so their sharding
        matches the parameter's (worker axes on the expert dim)."""
        sd = self.cfg.state_dtype
        los, dps = self.layouts, self.dp_mask
        ps = self.flat(params)

        def zst(p, lo, dp):
            return jnp.zeros(lo.view_shape if dp else p.shape, sd)

        return ZeroOneAdamState(
            step=jnp.zeros((), jnp.int32),
            gamma_acc=jnp.zeros((), jnp.float32),
            sync_pstate=self.cfg.sync_policy.init(),
            var_pstate=self.cfg.var_policy.init(),
            m=[zst(p, lo, dp) for p, lo, dp in zip(ps, los, dps)],
            v=[zst(p, lo, dp) for p, lo, dp in zip(ps, los, dps)],
            u=[jnp.zeros(lo.view_shape, sd) if dp else None
               for lo, dp in zip(los, dps)],
            err_w=[jnp.zeros(lo.ef_worker_shape, sd) if dp else None
                   for lo, dp in zip(los, dps)],
            err_s=[jnp.zeros(lo.chunk_shape, sd) if dp else None
                   for lo, dp in zip(los, dps)],
            anchor=[(p * 1.0).astype(p.dtype)
                    if (dp and self.cfg.store_anchor) else None
                    for p, dp in zip(ps, dps)],
        )

    def step(self, comm: Comm, params, grads, state: ZeroOneAdamState,
             worker_index=None):
        cfg = self.cfg
        t = state.step
        lr = cfg.lr(t).astype(jnp.float32)

        do_sync, sync_ps, interval = cfg.sync_policy.step(state.sync_pstate, t)
        do_var, var_ps = cfg.var_policy.step(state.var_pstate, t, interval)

        los, dps = self.layouts, self.dp_mask
        xs, gs = self.flat(params), self.flat(grads)
        gv = [C.constrain(C.to_view(g.astype(jnp.float32), lo), vs) if dp
              else g.astype(jnp.float32)
              for g, lo, dp, vs in zip(gs, los, dps, self.vspecs)]
        gamma_total = state.gamma_acc + lr     # Σ γ over [t', t] inclusive

        # --- local half-step for every leaf --------------------------------
        # DP leaves with use_pallas route the elementwise chain through the
        # fused kernel (one VMEM pass); the unfused jnp chain is f32-identical.
        if cfg.use_pallas:
            from repro.kernels import dispatch as K
        x_half, m_half, u_half = [], [], []
        for x, g, m, v, u, lo, dp, vs in zip(xs, gv, state.m, state.v,
                                             state.u, los, dps, self.vspecs):
            m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
            if dp and cfg.use_pallas and K.kernel_safe(vs):
                mh, u_new, delta = K.fused_local_step_view(
                    g, m32, u.astype(jnp.float32), v32, lr, cfg.beta1,
                    cfg.eps, lo)
                delta_nat = C.from_view(delta, lo)
            else:
                mh = cfg.beta1 * m32 + (1 - cfg.beta1) * g
                delta = lr * mh / jnp.sqrt(v32 + cfg.eps)
                delta_nat = C.from_view(delta, lo) if dp else delta
                u_new = (u.astype(jnp.float32) + lr * mh) if dp else None
            x_half.append((x.astype(jnp.float32) - delta_nat).astype(x.dtype))
            m_half.append(mh)
            u_half.append(u_new)

        dp_idx = [i for i, dp in enumerate(dps) if dp]

        # --- T_u branch: 1-bit sync of the accumulated buffer --------------
        use_anchor = cfg.store_anchor

        def sync_branch(op):
            xh, mh, uh, ew, es, anc = op
            nx, nm, nu, nw, ns = list(xh), list(mh), [None] * len(uh), \
                list(ew), list(es)
            na = list(anc)
            for k, i in enumerate(dp_idx):
                lo = self.layouts[i]
                ubar, ef = AR.onebit_allreduce_view(
                    comm, uh[k], AR.EFState(ew[k], es[k]), lo, self.ar_cfg,
                    vspec=self.vspecs[i], worker_index=worker_index)
                ubar = ubar.astype(jnp.float32)
                nm[k] = ubar / gamma_total
                # sync-only: the per-step half-step doesn't need √(v+ε) as a
                # standalone array (the fused kernel divides internally)
                denom = jnp.sqrt(state.v[i].astype(jnp.float32) + cfg.eps)
                if use_anchor:
                    # x_{t+1} = x_{t'} - ū/√(v+ε): bitwise identical on all
                    # workers (ū and the anchor are replicated).
                    nx[k] = (anc[k].astype(jnp.float32)
                             - C.from_view(ubar / denom, lo)
                             ).astype(xh[k].dtype)
                    na[k] = nx[k]
                else:
                    corr = (uh[k] - ubar) / denom
                    nx[k] = (xh[k].astype(jnp.float32)
                             + C.from_view(corr, lo)).astype(xh[k].dtype)
                nu[k] = jnp.zeros_like(uh[k])
                nw[k], ns[k] = ef.err_worker, ef.err_server
            return nx, nm, nu, nw, ns, na

        def local_branch(op):
            xh, mh, uh, ew, es, anc = op
            return (list(xh), list(mh), list(uh), list(ew), list(es),
                    list(anc))

        op = ([x_half[i] for i in dp_idx],
              [m_half[i] for i in dp_idx],
              [u_half[i] for i in dp_idx],
              [state.err_w[i] for i in dp_idx],
              [state.err_s[i] for i in dp_idx],
              [state.anchor[i] for i in dp_idx])
        sx, sm, su, sw, ss, sa = jax.lax.cond(do_sync, sync_branch,
                                              local_branch, op)

        new_x, new_m = list(x_half), list(m_half)
        new_u = list(u_half)
        new_ew, new_es = list(state.err_w), list(state.err_s)
        new_anchor = list(state.anchor)
        for k, i in enumerate(dp_idx):
            new_x[i], new_m[i], new_u[i] = sx[k], sm[k], su[k]
            new_ew[i], new_es[i] = sw[k], ss[k]
            new_anchor[i] = sa[k]

        # --- T_v branch: full-precision variance refresh --------------------
        def var_branch(op):
            vs = op
            out = []
            for k, i in enumerate(dp_idx):
                gbar = AR.fullprec_allreduce_view(comm, gv[i],
                                                  cfg.comm_dtype,
                                                  vspec=self.vspecs[i],
                                                  hierarchy=self.hierarchy,
                                                  layout=self.layouts[i])
                out.append(cfg.beta2 * vs[k].astype(jnp.float32)
                           + (1 - cfg.beta2) * gbar * gbar)
            return out

        def keep_branch(op):
            return [v.astype(jnp.float32) for v in op]

        v_dp = jax.lax.cond(do_var, var_branch, keep_branch,
                            [state.v[i] for i in dp_idx])

        new_v = list(state.v)
        for k, i in enumerate(dp_idx):
            new_v[i] = v_dp[k].astype(state.v[i].dtype)

        # --- non-DP leaves: plain local Adam (v every step) -----------------
        for i, dp in enumerate(dps):
            if dp:
                continue
            v32 = state.v[i].astype(jnp.float32)
            new_v[i] = (cfg.beta2 * v32
                        + (1 - cfg.beta2) * gv[i] * gv[i]).astype(
                            state.v[i].dtype)

        new_gamma = jnp.where(do_sync, 0.0, gamma_total)
        sd = cfg.state_dtype
        new_state = ZeroOneAdamState(
            step=t + 1,
            gamma_acc=new_gamma,
            sync_pstate=sync_ps,
            var_pstate=var_ps,
            m=[m.astype(sd) for m in new_m],
            v=new_v,
            u=[u.astype(sd) if u is not None else None for u in new_u],
            err_w=[w.astype(sd) if w is not None else None for w in new_ew],
            err_s=[s.astype(sd) if s is not None else None for s in new_es],
            anchor=new_anchor,
        )
        metrics = {"lr": lr, "synced": do_sync, "var_round": do_var,
                   "interval": interval}
        return jax.tree.unflatten(self.treedef, new_x), new_state, metrics
