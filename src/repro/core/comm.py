"""Named-axis communication abstraction for the worker (data-parallel) axes.

The optimizer algorithms in this package are written *per worker*: they see the
local shard of every tensor and perform cross-worker exchange exclusively
through a :class:`Comm`. A ``Comm`` is a thin wrapper over ``jax.lax``
collectives bound to one or more mesh axis names, which means the identical
algorithm code runs in two regimes:

* **production** — inside a partial-manual ``jax.shard_map`` whose manual axes
  are the worker axes (``("pod", "data")`` on the production mesh);
* **simulation** — under ``jax.vmap(..., axis_name=...)`` on a single device,
  with the worker axis materialized as a leading array axis. This is how the
  unit tests exercise n=8 workers on CPU.

Only collectives used by the paper's algorithms are exposed.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Comm:
    """Collectives over the worker axes.

    Attributes:
      axes: mesh/vmap axis name(s) forming the logical worker axis. When more
        than one name is given they are treated as a single flattened axis
        (``pod`` major), matching how ``jax.lax`` collectives accept tuples.
    """

    axes: Tuple[str, ...]

    @property
    def axis_name(self):
        return self.axes if len(self.axes) > 1 else self.axes[0]

    def size(self) -> int:
        n = 1
        for a in self.axes:
            n *= jax.lax.axis_size(a)
        return n

    def index(self):
        return jax.lax.axis_index(self.axes)

    def psum(self, x):
        return jax.lax.psum(x, self.axis_name)

    def pmean(self, x):
        return jax.lax.pmean(x, self.axis_name)

    def pmax(self, x):
        return jax.lax.pmax(x, self.axis_name)

    def all_gather(self, x, axis: int = 0, tiled: bool = True):
        return jax.lax.all_gather(x, self.axis_name, axis=axis, tiled=tiled)

    def all_to_all(self, x, split_axis: int = 0, concat_axis: int = 0):
        return jax.lax.all_to_all(
            x, self.axis_name, split_axis=split_axis, concat_axis=concat_axis,
            tiled=True)


class NullComm(Comm):
    """Single-worker comm: every collective is the identity (n=1).

    Lets the same optimizer/MoE code run un-mapped on one device (CPU smoke
    tests, single-host debugging).
    """

    def __init__(self):
        object.__setattr__(self, "axes", ())

    def size(self) -> int:
        return 1

    def index(self):
        return jnp.zeros((), jnp.int32)

    def psum(self, x):
        return x

    def pmean(self, x):
        return x

    def pmax(self, x):
        return x

    def all_gather(self, x, axis: int = 0, tiled: bool = True):
        return x if tiled else jnp.expand_dims(x, axis)

    def all_to_all(self, x, split_axis: int = 0, concat_axis: int = 0):
        return x


def sim_comm(axis_name: str = "workers") -> Comm:
    """Comm for vmap-simulated workers (tests / CPU benchmarks)."""
    return Comm(axes=(axis_name,))


def mesh_comm(axes: Sequence[str]) -> Comm:
    """Comm over real mesh axes (inside shard_map)."""
    return Comm(axes=tuple(axes))


def run_simulated(fn, n_workers: int, axis_name: str = "workers"):
    """Wrap ``fn(comm, *per_worker_args)`` to run with vmap-simulated workers.

    Every argument must carry a leading ``n_workers`` axis. Returns outputs
    with the same leading axis.
    """
    comm = sim_comm(axis_name)

    def wrapped(*args):
        return jax.vmap(lambda *a: fn(comm, *a), axis_name=axis_name)(*args)

    return wrapped
