"""Named-axis communication abstraction for the worker (data-parallel) axes.

The optimizer algorithms in this package are written *per worker*: they see the
local shard of every tensor and perform cross-worker exchange exclusively
through a :class:`Comm`. A ``Comm`` is a thin wrapper over ``jax.lax``
collectives bound to one or more mesh axis names, which means the identical
algorithm code runs in two regimes:

* **production** — inside a partial-manual ``jax.shard_map`` whose manual axes
  are the worker axes (``("pod", "data")`` on the production mesh);
* **simulation** — under ``jax.vmap(..., axis_name=...)`` on a single device,
  with the worker axis materialized as a leading array axis. This is how the
  unit tests exercise n=8 workers on CPU.

Only collectives used by the paper's algorithms are exposed.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Hierarchy:
    """Two-level worker topology: slow inter-pod axes × fast intra-pod axes.

    The hierarchical 1-bit AllReduce reduces *uncompressed* inside the fast
    (``inner``) domain and runs Algorithm 2's EF-compressed exchange only
    across the slow (``outer``) domain. ``inner`` is the static intra-pod
    worker count (needed at optimizer-init time, before any axis context
    exists, to size per-level EF state); the axis names match the mesh axes
    in production and the nested-vmap axis names in simulation, so one
    config value drives both regimes.
    """

    inner: int                                  # workers per pod
    outer_axes: Tuple[str, ...] = ("pod",)      # inter-pod (slow) axes
    inner_axes: Tuple[str, ...] = ("data",)     # intra-pod (fast) axes

    @property
    def axes(self) -> Tuple[str, ...]:
        return tuple(self.outer_axes) + tuple(self.inner_axes)


@dataclasses.dataclass(frozen=True)
class Comm:
    """Collectives over the worker axes.

    Attributes:
      axes: mesh/vmap axis name(s) forming the logical worker axis. When more
        than one name is given they are treated as a single flattened axis
        (``pod`` major), matching how ``jax.lax`` collectives accept tuples.
    """

    axes: Tuple[str, ...]

    @property
    def axis_name(self):
        return self.axes if len(self.axes) > 1 else self.axes[0]

    def size(self) -> int:
        from repro.core.compat import axis_size
        return axis_size(self.axes)

    def index(self):
        return jax.lax.axis_index(self.axes)

    def psum(self, x):
        return jax.lax.psum(x, self.axis_name)

    def pmean(self, x):
        return jax.lax.pmean(x, self.axis_name)

    def pmax(self, x):
        return jax.lax.pmax(x, self.axis_name)

    def all_gather(self, x, axis: int = 0, tiled: bool = True):
        if len(self.axes) <= 1:
            return jax.lax.all_gather(x, self.axis_name, axis=axis,
                                      tiled=tiled)
        # Flattened axis tuples: decompose into per-axis gathers, innermost
        # first — concatenation is then outer-major, exactly the flattened-
        # axis order of the native tuple call. (vmap's all_gather batching
        # rule rejects tuples — the simulation / GSPMD-vmap regime — and the
        # decomposition is collective-equivalent on a mesh: same payload,
        # one ring per topology level.)
        if not tiled:
            x = jnp.expand_dims(x, axis)
        for a in reversed(self.axes):
            x = jax.lax.all_gather(x, a, axis=axis, tiled=True)
        return x

    def all_to_all(self, x, split_axis: int = 0, concat_axis: int = 0):
        return jax.lax.all_to_all(
            x, self.axis_name, split_axis=split_axis, concat_axis=concat_axis,
            tiled=True)

    def split(self, outer_axes: Sequence[str], inner_axes: Sequence[str]):
        """(outer_comm, inner_comm) over grouped sub-axes of this comm.

        ``outer_axes + inner_axes`` must equal ``self.axes`` in order (the
        flattened worker index is outer-major, so contiguous groups of the
        flat index land in the inner domain). Works identically under
        shard_map (mesh sub-axes) and nested vmap (simulation). An empty
        group degenerates to a :class:`NullComm`.
        """
        outer, inner = tuple(outer_axes), tuple(inner_axes)
        if outer + inner != self.axes:
            raise ValueError(
                f"cannot split axes {self.axes} into {outer} + {inner}")
        return (Comm(outer) if outer else NullComm(),
                Comm(inner) if inner else NullComm())


class NullComm(Comm):
    """Single-worker comm: every collective is the identity (n=1).

    Lets the same optimizer/MoE code run un-mapped on one device (CPU smoke
    tests, single-host debugging).
    """

    def __init__(self):
        object.__setattr__(self, "axes", ())

    def size(self) -> int:
        return 1

    def index(self):
        return jnp.zeros((), jnp.int32)

    def psum(self, x):
        return x

    def pmean(self, x):
        return x

    def pmax(self, x):
        return x

    def all_gather(self, x, axis: int = 0, tiled: bool = True):
        return x if tiled else jnp.expand_dims(x, axis)

    def all_to_all(self, x, split_axis: int = 0, concat_axis: int = 0):
        return x


def norm_hierarchy(h: "Hierarchy | None", n_workers: int):
    """Validate a Hierarchy against the worker count; None when it cannot
    apply (single worker) so callers fall back to the flat path."""
    if h is None or n_workers <= 1:
        return None
    if n_workers % h.inner:
        raise ValueError(
            f"hierarchy.inner={h.inner} must divide n_workers={n_workers}")
    return h


def sim_comm(axis_name: str = "workers") -> Comm:
    """Comm for vmap-simulated workers (tests / CPU benchmarks)."""
    return Comm(axes=(axis_name,))


def mesh_comm(axes: Sequence[str]) -> Comm:
    """Comm over real mesh axes (inside shard_map)."""
    return Comm(axes=tuple(axes))


def run_simulated(fn, n_workers: int, axis_name: str = "workers"):
    """Wrap ``fn(comm, *per_worker_args)`` to run with vmap-simulated workers.

    Every argument must carry a leading ``n_workers`` axis. Returns outputs
    with the same leading axis.
    """
    comm = sim_comm(axis_name)

    def wrapped(*args):
        return jax.vmap(lambda *a: fn(comm, *a), axis_name=axis_name)(*args)

    return wrapped
