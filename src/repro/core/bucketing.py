"""Fused communication buckets for the Algorithm-2 exchange.

The per-leaf exchange (:mod:`repro.core.leafwise`) launches one codec
encode + one pair of collectives per parameter *leaf*; a transformer with
hundreds of small leaves pays hundreds of dispatch/collective fixed costs
per sync (the regime ``benchmarks/bench_fixed_cost.py`` measures). This
module coalesces those leaves into a small number of fixed-budget
(``bucket_mb``) flat buckets, Bagua/DeepSpeed-fusion style, so EF state,
anchors, codec payloads, and collectives all operate per *bucket*.

Design: a fused bucket repacks its member leaves' **true (unpadded)
elements** contiguously — member ``m``'s elements occupy the flat range
``[offsets[m], offsets[m] + sizes[m])`` of the bucket — and pads the
single tail to the ``n * 128`` frame quantum. That makes every bucket an
ordinary flatten :class:`~repro.core.compressor.LeafLayout`: the pad-exact
masks/row-counts, the frame/lane contract of the Pallas kernels, the
hierarchical slice bookkeeping, and every codec work on buckets without
change. A bucket holding exactly one leaf has *the same* padded size,
view shape, and true counts as that leaf's own flatten layout, which is
what makes the one-leaf-per-bucket configuration bitwise-identical to the
per-leaf path (asserted in tests/test_bucketing.py).

Only leaves that are safe to repack are fused: flatten layouts with
``rest_factor == 1`` and no tensor-parallel sharding on the comm view
(repacking moves elements across chunk boundaries, which is only legal
when the view is unsharded and unstructured), sharing one dtype per
bucket. Every other DP leaf — GSPMD-structured views, fully-manual TP
shards — becomes a *singleton* bucket that keeps the leaf's own layout and
vspec, so the exchange code path is uniformly per-bucket while the
semantics of those leaves are untouched.

Semantics note (documented in README "Bucketed exchange & overlap"): codec
scale/threshold granularities are defined over the codec's buffer — with
multi-leaf buckets, "tensor" scale means one scale per *bucket* and chunks
mix member leaves. With one leaf per bucket the semantics (and bits) are
exactly the per-leaf ones; the ``identity`` codec is transport-exact either
way.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import compressor as C
from repro.core.leafwise import LeafPlan


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One exchange unit: either a fused repack of several flatten leaves
    or a singleton carrying one (possibly structured) leaf unchanged."""

    members: Tuple[int, ...]        # flat leaf indices, bucket order
    layout: C.LeafLayout            # comm layout of the bucket buffer
    fused: bool                     # True -> flat repack of true elements
    offsets: Tuple[int, ...]        # per-member start in bucket flat order
    sizes: Tuple[int, ...]          # per-member true element count
    spec: Any                       # natural-leaf TP spec (singleton only)
    vspec: Tuple                    # TP entries of the bucket view shape

    @property
    def true_elems(self) -> int:
        return int(sum(self.sizes))


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """Static bucket assignment for one :class:`LeafPlan`."""

    bucket_mb: float
    buckets: Tuple[Bucket, ...]
    leaf_bucket: Tuple[Optional[int], ...]   # flat leaf idx -> bucket idx
                                             # (None for non-DP leaves)

    @property
    def n_fused(self) -> int:
        return sum(1 for b in self.buckets if b.fused)


def _true_size(layout: C.LeafLayout) -> int:
    return int(np.prod(layout.shape)) if layout.shape else 1


def fusable(layout: C.LeafLayout, vspec) -> bool:
    """Whether a leaf's comm view may be repacked into a fused bucket.

    Repacking reassigns elements to chunk rows, so it is only legal for
    flatten views with no tensor-parallel structure: ``rest_factor > 1``
    means the view is a TP-local shard whose scales psum over model axes,
    and a sharded vspec means GSPMD owns the element placement.
    """
    if not layout.flatten or layout.rest_factor != 1:
        return False
    return vspec is None or all(e is None for e in tuple(vspec))


def make_bucket_plan(plan: LeafPlan, bucket_mb: float,
                     vspecs=None) -> BucketPlan:
    """Greedy in-order packing of the plan's DP leaves into buckets.

    ``bucket_mb`` is the f32 element budget per fused bucket; a single
    leaf larger than the budget still gets its own (fused) bucket, so the
    budget bounds *fusion*, never splits a leaf. Packing is by flat leaf
    order — deterministic, so the plan (and therefore the optimizer state
    layout) is a pure function of (param tree, specs, n, bucket_mb).
    """
    if bucket_mb is None or bucket_mb <= 0:
        raise ValueError(f"bucket_mb must be positive, got {bucket_mb!r}")
    vspecs = vspecs if vspecs is not None else plan.vspecs
    budget = max(1, int(float(bucket_mb) * 2**20) // 4)
    n_inner = plan.hierarchy.inner if plan.hierarchy else 1

    buckets: List[Bucket] = []
    leaf_bucket: List[Optional[int]] = [None] * len(plan.leaves)
    pend: List[int] = []        # member leaf indices of the open fused bucket
    pend_elems = 0

    def close_fused():
        nonlocal pend, pend_elems
        if not pend:
            return
        sizes = tuple(_true_size(plan.layouts[i]) for i in pend)
        offsets, off = [], 0
        for s in sizes:
            offsets.append(off)
            off += s
        lo = C.make_layout((off,), None, plan.n, n_inner=n_inner)
        bi = len(buckets)
        buckets.append(Bucket(members=tuple(pend), layout=lo, fused=True,
                              offsets=tuple(offsets), sizes=sizes,
                              spec=None,
                              vspec=(None,) * len(lo.view_shape)))
        for i in pend:
            leaf_bucket[i] = bi
        pend, pend_elems = [], 0

    for i, (lo, dp) in enumerate(zip(plan.layouts, plan.dp_mask)):
        if not dp:
            continue
        if not fusable(lo, vspecs[i]):
            close_fused()
            bi = len(buckets)
            buckets.append(Bucket(
                members=(i,), layout=lo, fused=False,
                offsets=(0,), sizes=(_true_size(lo),),
                spec=plan.specs[i], vspec=vspecs[i]))
            leaf_bucket[i] = bi
            continue
        size = _true_size(lo)
        dtype = getattr(plan.leaves[i], "dtype", None)
        pend_dtype = (getattr(plan.leaves[pend[0]], "dtype", None)
                      if pend else None)
        if pend and (pend_elems + size > budget or dtype != pend_dtype):
            close_fused()
        pend.append(i)
        pend_elems += size
        if pend_elems >= budget:
            close_fused()
    close_fused()
    return BucketPlan(bucket_mb=float(bucket_mb), buckets=tuple(buckets),
                      leaf_bucket=tuple(leaf_bucket))


# ---------------------------------------------------------------------------
# view <-> bucket transport (chip-local gathers/scatters, exact inverses)
# ---------------------------------------------------------------------------

def gather_views(bucket: Bucket, views: List[jnp.ndarray]) -> jnp.ndarray:
    """Member comm views -> the bucket buffer (bucket view shape).

    Fused buckets drop each member's pad tail (flatten views pad the tail
    of the flat element order), concatenate the true elements in member
    order, and zero-pad the single bucket tail — so every real element
    lands in exactly one bucket slot and pad garbage in member views can
    never reach the wire. Singletons pass through.
    """
    if not bucket.fused:
        (v,) = views
        return v
    parts = [v.reshape(-1)[:s] for v, s in zip(views, bucket.sizes)]
    flat = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
    pad = bucket.layout.padded - bucket.true_elems
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(bucket.layout.view_shape)


def scatter_views(bucket: Bucket, buf: jnp.ndarray,
                  layouts: List[C.LeafLayout]) -> List[jnp.ndarray]:
    """Bucket buffer -> member comm views (exact inverse of
    :func:`gather_views` on the true elements; re-padded with zeros)."""
    if not bucket.fused:
        return [buf]
    flat = buf.reshape(-1)
    out = []
    for off, size, lo in zip(bucket.offsets, bucket.sizes, layouts):
        seg = flat[off:off + size]
        if lo.pad:
            seg = jnp.pad(seg, (0, lo.pad))
        out.append(seg.reshape(lo.view_shape))
    return out


def bucket_accounting(plan: BucketPlan) -> dict:
    """Static dispatch-count numbers: exchange units and true-element
    conservation (bucket-sum == leaf-sum, asserted by the property
    tests)."""
    true_total = sum(b.true_elems for b in plan.buckets)
    return {
        "n_buckets": len(plan.buckets),
        "n_fused": plan.n_fused,
        "true_elems": true_total,
        "padded_elems": sum(b.layout.padded for b in plan.buckets),
    }
