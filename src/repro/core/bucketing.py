"""Fused communication buckets for the Algorithm-2 exchange.

The per-leaf exchange (:mod:`repro.core.leafwise`) launches one codec
encode + one pair of collectives per parameter *leaf*; a transformer with
hundreds of small leaves pays hundreds of dispatch/collective fixed costs
per sync (the regime ``benchmarks/bench_fixed_cost.py`` measures). This
module coalesces those leaves into a small number of fixed-budget
(``bucket_mb``) flat buckets, Bagua/DeepSpeed-fusion style, so EF state,
anchors, codec payloads, and collectives all operate per *bucket*.

Design: a fused bucket repacks its member leaves' **true (unpadded)
elements** contiguously — member ``m``'s elements occupy the flat range
``[offsets[m], offsets[m] + sizes[m])`` of the bucket — and pads the
single tail to the ``n * 128`` frame quantum. That makes every bucket an
ordinary flatten :class:`~repro.core.compressor.LeafLayout`: the pad-exact
masks/row-counts, the frame/lane contract of the Pallas kernels, the
hierarchical slice bookkeeping, and every codec work on buckets without
change. A bucket holding exactly one leaf has *the same* padded size,
view shape, and true counts as that leaf's own flatten layout, which is
what makes the one-leaf-per-bucket configuration bitwise-identical to the
per-leaf path (asserted in tests/test_bucketing.py).

Only leaves that are safe to repack are fused — in two regimes:

* **Unsharded flatten leaves** (``rest_factor == 1``, trivial vspec) fuse
  freely: repacking moves elements across chunk boundaries, which is
  legal because the view is unsharded and unstructured.
* **Tensor-parallel-local flatten shards** (``rest_factor > 1`` with the
  canonical manual-TP vspec ``(None, ax)``) fuse with same-vspec,
  same-``rest_factor``, same-dtype peers into a *sharded* fused bucket: a
  per-shard flat repack whose bucket layout keeps the members' shared
  ``rest_factor`` and carries spec ``P(ax)``, so its scales still psum
  over the model axes with global denominators and the bucket's sharded
  state leaves derive their specs through ``view_spec_entries``
  unchanged. Repacking within one model shard never crosses a shard
  boundary — every worker holds the same local geometry (SPMD), so the
  pack is a pure per-shard permutation.

One dtype per bucket, always. Remaining DP leaves — GSPMD-structured
views, mixed/non-canonical TP specs — become *singleton* buckets that
keep the leaf's own layout and vspec, so the exchange code path is
uniformly per-bucket while the semantics of those leaves are untouched.

Semantics note (documented in README "Bucketed exchange & overlap"): codec
scale/threshold granularities are defined over the codec's buffer — with
multi-leaf buckets, "tensor" scale means one scale per *bucket* and chunks
mix member leaves. With one leaf per bucket the semantics (and bits) are
exactly the per-leaf ones; the ``identity`` codec is transport-exact either
way.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, NamedTuple, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import compressor as C
from repro.core.leafwise import LeafPlan

#: Packing / issue orders for the exchange units. ``flat`` is flat-leaf
#: order; ``reverse_backward`` reverses it — the last parameters of the
#: flat order are (to first approximation) the first whose gradients
#: finalize during the backward pass, so issuing units in reverse order
#: lets early exchanges overlap the rest of the backward. Both are pure
#: functions of the plan inputs, so optimizer state layout stays
#: deterministic.
PACK_ORDERS = ("flat", "reverse_backward")


def _check_pack_order(pack_order: str) -> None:
    if pack_order not in PACK_ORDERS:
        raise ValueError(
            f"pack_order must be one of {PACK_ORDERS}, got {pack_order!r}")


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One exchange unit: either a fused repack of several flatten leaves
    or a singleton carrying one (possibly structured) leaf unchanged."""

    members: Tuple[int, ...]        # flat leaf indices, bucket order
    layout: C.LeafLayout            # comm layout of the bucket buffer
    fused: bool                     # True -> flat repack of true elements
    offsets: Tuple[int, ...]        # per-member start in bucket flat order
    sizes: Tuple[int, ...]          # per-member true element count
    spec: Any                       # TP spec: the leaf's own for singletons,
                                    # the canonical P(ax) for sharded fused
                                    # buckets, None for unsharded fused ones
    vspec: Tuple                    # TP entries of the bucket view shape

    @property
    def true_elems(self) -> int:
        return int(sum(self.sizes))


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """Static bucket assignment for one :class:`LeafPlan`."""

    bucket_mb: float
    buckets: Tuple[Bucket, ...]
    leaf_bucket: Tuple[Optional[int], ...]   # flat leaf idx -> bucket idx
                                             # (None for non-DP leaves)

    @property
    def n_fused(self) -> int:
        return sum(1 for b in self.buckets if b.fused)


def _true_size(layout: C.LeafLayout) -> int:
    return int(np.prod(layout.shape)) if layout.shape else 1


def fusable(layout: C.LeafLayout, vspec) -> bool:
    """Whether a leaf's comm view may be repacked into a fused bucket.

    Repacking reassigns elements to chunk rows, so it needs a flatten view
    (GSPMD-structured views keep element placement with the partitioner).
    Unsharded flatten views (``rest_factor == 1``, trivial vspec) always
    qualify. TP-local flatten shards (``rest_factor > 1``) qualify when
    they carry the canonical manual-TP vspec ``(None, ax)`` — the repack
    then happens *within* one model shard, and same-vspec peers share it
    (grouping by (dtype, rest_factor, vspec) is ``make_bucket_plan``'s
    job); any other sharded vspec stays a singleton.
    """
    if not layout.flatten:
        return False
    if layout.rest_factor == 1:
        return vspec is None or all(e is None for e in tuple(vspec))
    if vspec is None:
        return False
    ent = tuple(vspec)
    return len(ent) == 2 and ent[0] is None and ent[1] is not None


def make_bucket_plan(plan: LeafPlan, bucket_mb: float,
                     vspecs=None, pack_order: str = "flat") -> BucketPlan:
    """Greedy in-order packing of the plan's DP leaves into buckets.

    ``bucket_mb`` is the f32 element budget per fused bucket; a single
    leaf larger than the budget still gets its own (fused) bucket, so the
    budget bounds *fusion*, never splits a leaf. Packing is by
    ``pack_order`` (flat leaf order, or its reverse ≈ backward readiness
    order) — deterministic, so the plan (and therefore the optimizer
    state layout) is a pure function of (param tree, specs, n, bucket_mb,
    pack_order).
    """
    if bucket_mb is None or bucket_mb <= 0:
        raise ValueError(f"bucket_mb must be positive, got {bucket_mb!r}")
    _check_pack_order(pack_order)
    vspecs = vspecs if vspecs is not None else plan.vspecs
    budget = max(1, int(float(bucket_mb) * 2**20) // 4)
    n_inner = plan.hierarchy.inner if plan.hierarchy else 1

    buckets: List[Bucket] = []
    leaf_bucket: List[Optional[int]] = [None] * len(plan.leaves)
    pend: List[int] = []        # member leaf indices of the open fused bucket
    pend_elems = 0

    def _leaf_dtype(i) -> np.dtype:
        """The element dtype of DP leaf i — resolved strictly: two
        dtype-less leaves must never silently fuse across genuinely
        different element types (they'd both compare equal as None)."""
        dt = getattr(plan.leaves[i], "dtype", None)
        if dt is None:
            raise ValueError(
                f"cannot resolve the element dtype of DP leaf {i} "
                f"(type {type(plan.leaves[i]).__name__}, layout shape "
                f"{plan.layouts[i].shape}): fused buckets hold one dtype, "
                f"so every bucketable leaf must be an array or "
                f"ShapeDtypeStruct-like aval with a .dtype")
        return np.dtype(dt)

    def _fuse_key(i):
        """(dtype, rest_factor, vspec) — leaves fuse only within one key.
        The vspec component is the canonical ``(None, ax)`` for TP-local
        shards (rest_factor > 1) and None for unsharded leaves."""
        lo = plan.layouts[i]
        vkey = tuple(vspecs[i]) if lo.rest_factor > 1 else None
        return (_leaf_dtype(i), lo.rest_factor, vkey)

    def close_fused():
        nonlocal pend, pend_elems
        if not pend:
            return
        sizes = tuple(_true_size(plan.layouts[i]) for i in pend)
        offsets, off = [], 0
        for s in sizes:
            offsets.append(off)
            off += s
        rf = plan.layouts[pend[0]].rest_factor
        if rf > 1:
            # sharded fused bucket: per-shard flat repack over the members'
            # shared model axes — layout keeps rest_factor so the scale
            # denominators stay global, spec/vspec carry the model axes
            from jax.sharding import PartitionSpec as P
            ax = tuple(vspecs[pend[0]])[1]
            spec = P(ax)
            lo = C.make_layout((off,), spec, plan.n, rest_factor=rf,
                               force_flatten=True, n_inner=n_inner)
            vspec = C.view_spec_entries(lo, spec)
        else:
            spec = None
            lo = C.make_layout((off,), None, plan.n, n_inner=n_inner)
            vspec = (None,) * len(lo.view_shape)
        bi = len(buckets)
        buckets.append(Bucket(members=tuple(pend), layout=lo, fused=True,
                              offsets=tuple(offsets), sizes=sizes,
                              spec=spec, vspec=vspec))
        for i in pend:
            leaf_bucket[i] = bi
        pend, pend_elems = [], 0

    order = range(len(plan.leaves))
    if pack_order == "reverse_backward":
        order = reversed(order)
    for i in order:
        lo, dp = plan.layouts[i], plan.dp_mask[i]
        if not dp:
            continue
        if not fusable(lo, vspecs[i]):
            close_fused()
            bi = len(buckets)
            buckets.append(Bucket(
                members=(i,), layout=lo, fused=False,
                offsets=(0,), sizes=(_true_size(lo),),
                spec=plan.specs[i], vspec=vspecs[i]))
            leaf_bucket[i] = bi
            continue
        size = _true_size(lo)
        key = _fuse_key(i)
        pend_key = _fuse_key(pend[0]) if pend else None
        if pend and (pend_elems + size > budget or key != pend_key):
            close_fused()
        pend.append(i)
        pend_elems += size
        if pend_elems >= budget:
            close_fused()
    close_fused()
    return BucketPlan(bucket_mb=float(bucket_mb), buckets=tuple(buckets),
                      leaf_bucket=tuple(leaf_bucket))


# ---------------------------------------------------------------------------
# view <-> bucket transport (chip-local gathers/scatters, exact inverses)
# ---------------------------------------------------------------------------

def gather_views(bucket: Bucket, views: List[jnp.ndarray]) -> jnp.ndarray:
    """Member comm views -> the bucket buffer (bucket view shape).

    Fused buckets drop each member's pad tail (flatten views pad the tail
    of the flat element order), concatenate the true elements in member
    order, and zero-pad the single bucket tail — so every real element
    lands in exactly one bucket slot and pad garbage in member views can
    never reach the wire. Singletons pass through.
    """
    if not bucket.fused:
        (v,) = views
        return v
    parts = [v.reshape(-1)[:s] for v, s in zip(views, bucket.sizes)]
    flat = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
    pad = bucket.layout.padded - bucket.true_elems
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(bucket.layout.view_shape)


def scatter_views(bucket: Bucket, buf: jnp.ndarray,
                  layouts: List[C.LeafLayout]) -> List[jnp.ndarray]:
    """Bucket buffer -> member comm views (exact inverse of
    :func:`gather_views` on the true elements; re-padded with zeros)."""
    if not bucket.fused:
        return [buf]
    flat = buf.reshape(-1)
    out = []
    for off, size, lo in zip(bucket.offsets, bucket.sizes, layouts):
        seg = flat[off:off + size]
        if lo.pad:
            seg = jnp.pad(seg, (0, lo.pad))
        out.append(seg.reshape(lo.view_shape))
    return out


def bucket_accounting(plan: BucketPlan) -> dict:
    """Static dispatch-count numbers: exchange units and true-element
    conservation (bucket-sum == leaf-sum, asserted by the property
    tests)."""
    true_total = sum(b.true_elems for b in plan.buckets)
    return {
        "n_buckets": len(plan.buckets),
        "n_fused": plan.n_fused,
        "true_elems": true_total,
        "padded_elems": sum(b.layout.padded for b in plan.buckets),
    }


# ---------------------------------------------------------------------------
# Declared collective schedule (the manifest repro.analysis.ir_audit checks
# the lowered step against)
# ---------------------------------------------------------------------------

class ExpectedCollective(NamedTuple):
    """One declared collective of the exchange schedule.

    ``level`` names a topology level, not concrete mesh axes — the auditor
    resolves it against the trainer's worker axes (``flat`` = the full
    worker-axis tuple, ``inner``/``outer`` = the hierarchy's intra-/
    inter-pod axes). ``shape``/``dtype`` describe the collective's *operand*
    as emitted (before any per-axis decomposition of multi-axis gathers).
    """

    op: str                   # "all_to_all" | "all_gather"
    level: str                # "flat" | "inner" | "outer"
    phase: str                # "reduce_scatter" | "scatter" | "gather"
    round: str                #   | "broadcast";  round: "sync" | "fullprec"
    unit: int                 # exchange-unit ordinal (bucket / DP leaf)
    unit_label: str           # "bucket[k]" or "leaf[i]"
    leaf: str                 # payload leaf name, "raw" for uncompressed
    dtype: str                # canonical dtype name of the operand
    shape: Tuple[int, ...]    # operand shape

    @property
    def nbytes(self) -> int:
        n = 1
        for s in self.shape:
            n *= int(s)
        return n * np.dtype(self.dtype).itemsize

    @property
    def inter_pod(self) -> bool:
        return self.level == "outer"


def exchange_units(plan: LeafPlan, bucket_plan: Optional[BucketPlan] = None,
                   pack_order: str = "flat"
                   ) -> List[Tuple[C.LeafLayout, Any, str]]:
    """``(layout, vspec, label)`` per exchange unit, in issue order:
    buckets when a bucket plan is set (the bucket plan's own order already
    reflects its ``pack_order``), the DP leaves in ``pack_order``
    otherwise — exactly the iteration order of ``ComposedOptimizer``'s
    per-unit sync/fullprec issue loop."""
    _check_pack_order(pack_order)
    if bucket_plan is not None:
        return [(b.layout, b.vspec, f"bucket[{k}]")
                for k, b in enumerate(bucket_plan.buckets)]
    idx = [i for i, dp in enumerate(plan.dp_mask) if dp]
    if pack_order == "reverse_backward":
        idx = idx[::-1]
    return [(plan.layouts[i], plan.vspecs[i], f"leaf[{i}]") for i in idx]


def _payload_shapes(layout: C.LeafLayout, ar_cfg):
    """Abstract (worker payload, server payload) trees of one exchange
    unit, derived by ``jax.eval_shape`` over the *actual* encode helpers of
    :mod:`repro.core.onebit_allreduce` — the manifest's shapes can never
    drift from what the exchange really emits."""
    import jax
    from repro.core import onebit_allreduce as AR
    hier = ar_cfg.hierarchy is not None
    # kernels dispatch / TP psums don't change payload shapes; keep the
    # abstract eval off those paths
    cfg0 = dataclasses.replace(ar_cfg, use_pallas=False, model_axes=())

    def f(z, ew, es):
        ef = AR.EFState(ew, es)
        j = jnp.zeros((), jnp.int32)
        if hier:
            payload, _, mask, _ = AR._hier_worker_encode(
                z, ef, layout, cfg0, None, j)
            payload_s, _ = AR._hier_server_encode(
                payload, ef, layout, cfg0, None, mask, False, j)
        else:
            payload, _, mask, _ = AR._flat_worker_encode(
                z, ef, layout, cfg0, None)
            payload_s, _ = AR._flat_server_encode(
                payload, ef, layout, cfg0, None, mask, False, j)
        return payload, payload_s

    z = jax.ShapeDtypeStruct(layout.slice_shape if hier
                             else layout.view_shape, ar_cfg.compute_dtype)
    ew = jax.ShapeDtypeStruct(layout.ef_worker_shape, jnp.float32)
    es = jax.ShapeDtypeStruct(layout.chunk_shape, jnp.float32)
    return jax.eval_shape(f, z, ew, es)


def _unit_payload_entries(unit, label, layout, ar_cfg):
    """Per-unit (scatter entries, gather entries) of the compressed
    exchange. Shapes come from the traced encode helpers; dtypes from the
    codec's *declared* ``payload_spec`` — a codec that lies about its wire
    dtypes produces a manifest the lowered step can't match."""
    codec = ar_cfg.codec
    level = "outer" if ar_cfg.hierarchy is not None else "flat"
    wp, sp = _payload_shapes(layout, ar_cfg)
    spec = codec.payload_spec(layout)
    out = {}
    for phase, tree in (("scatter", wp), ("gather", sp)):
        names = sorted(tree)  # jax.tree traversal order of the payload dict
        declared = tuple(spec[phase])
        if tuple(n for n, _ in declared) != tuple(names):
            raise ValueError(
                f"codec {codec.name!r} payload_spec names "
                f"{[n for n, _ in declared]} != traced payload leaves "
                f"{names} ({phase} phase, {label})")
        op = "all_to_all" if phase == "scatter" else "all_gather"
        out[phase] = [
            ExpectedCollective(op, level, phase, "sync", unit, label, name,
                               np.dtype(dt).name, tuple(tree[name].shape))
            for name, dt in declared]
    return out["scatter"], out["gather"]


def _hier_raw_entries(unit, label, layout, ar_cfg):
    """(intra-pod reduce-scatter, intra-pod broadcast) entries of the
    hierarchical sync — the uncompressed wire-dtype phases."""
    ni, no, ck = layout.n_inner, layout.n_outer, layout.chunk_shape
    cd = np.dtype(ar_cfg.comm_dtype).name
    rs = ExpectedCollective("all_to_all", "inner", "reduce_scatter", "sync",
                            unit, label, "raw", cd, (ni, no) + ck)
    bc = ExpectedCollective("all_gather", "inner", "broadcast", "sync",
                            unit, label, "raw", cd, (1, no) + ck)
    return rs, bc


def expected_sync_schedule(plan: LeafPlan, ar_cfg,
                           bucket_plan: Optional[BucketPlan] = None,
                           pack_order: str = "flat"
                           ) -> List[ExpectedCollective]:
    """The declared collective schedule of ONE compressed (Algorithm-2)
    sync round: one contiguous block per exchange unit, in issue order —
    flat: ``[scatter, gather]``; hierarchical: ``[intra-pod
    reduce-scatter, inter-pod scatter, inter-pod gather, intra-pod
    broadcast]``. Each unit's exchange is issued under its own per-unit
    cond in ``ComposedOptimizer`` the moment its member leaves' gradients
    are final, so the emission order is uniform per unit regardless of
    bucketing (the old software-pipelined interleavings are gone)."""
    units = exchange_units(plan, bucket_plan, pack_order)
    hier = ar_cfg.hierarchy is not None
    out: List[ExpectedCollective] = []
    for u, (lo, _, label) in enumerate(units):
        sc, ga = _unit_payload_entries(u, label, lo, ar_cfg)
        raw = (_hier_raw_entries(u, label, lo, ar_cfg)
               if hier and lo.n_inner > 1 else None)
        if raw:
            out.append(raw[0])
        out += sc + ga
        if raw:
            out.append(raw[1])
    return out


def expected_fullprec_schedule(plan: LeafPlan, ar_cfg,
                               bucket_plan: Optional[BucketPlan] = None,
                               pack_order: str = "flat"
                               ) -> List[ExpectedCollective]:
    """The declared schedule of ONE full-precision (T_v / mean) round:
    ``fullprec_allreduce_view`` per exchange unit, in issue order."""
    units = exchange_units(plan, bucket_plan, pack_order)
    cd = np.dtype(ar_cfg.comm_dtype).name
    hier = ar_cfg.hierarchy is not None
    out: List[ExpectedCollective] = []
    for u, (lo, _, label) in enumerate(units):
        ck = lo.chunk_shape
        if hier and lo.n_inner > 1:
            ni, no = lo.n_inner, lo.n_outer
            out += [
                ExpectedCollective("all_to_all", "inner", "reduce_scatter",
                                   "fullprec", u, label, "raw", cd,
                                   (ni, no) + ck),
                ExpectedCollective("all_to_all", "outer", "scatter",
                                   "fullprec", u, label, "raw", cd,
                                   (no,) + ck),
                ExpectedCollective("all_gather", "outer", "gather",
                                   "fullprec", u, label, "raw", cd,
                                   (1,) + ck),
                ExpectedCollective("all_gather", "inner", "broadcast",
                                   "fullprec", u, label, "raw", cd,
                                   (1, no) + ck),
            ]
        else:
            out += [
                ExpectedCollective("all_to_all", "flat", "scatter",
                                   "fullprec", u, label, "raw", cd,
                                   tuple(lo.view_shape)),
                ExpectedCollective("all_gather", "flat", "gather",
                                   "fullprec", u, label, "raw", cd,
                                   (1,) + ck),
            ]
    return out
