"""Baseline distributed Adam (full-precision AllReduce every step).

Standard Adam update without bias correction (the paper's Eq. 3 convention,
shared by all three optimizers here so comparisons are step-for-step clean).

All optimizers operate over flattened leaf lists (treedef captured at
construction) so that heterogeneous per-leaf auxiliary state (layouts, error
feedback, DP masks) never has to align as a pytree.

.. deprecated:: Superseded by the composable API —
   ``compressed_dp(adam_base(...), style="mean", ...)`` is the same
   distributed Adam (tests/test_composed_equivalence.py). Retained as the
   frozen reference implementation those equivalence tests pin against.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import leafwise
from repro.core.comm import Comm


class AdamState(NamedTuple):
    step: jnp.ndarray
    m: list
    v: list


class Adam:
    def __init__(self, cfg, param_shapes, specs, dp_mask, n_workers,
                 model_axis_sizes=None):
        self.cfg = cfg
        plan = leafwise.make_plan(param_shapes, specs, dp_mask, n_workers,
                                  model_axis_sizes, cfg.hierarchy)
        self.n = plan.n
        self.model_axes = plan.model_axes
        self.treedef = plan.treedef
        self.specs = plan.specs
        self.dp_mask = plan.dp_mask
        self.layouts = plan.layouts
        self.vspecs = plan.vspecs

    def flat(self, tree):
        return self.treedef.flatten_up_to(tree)

    def init(self, params) -> AdamState:
        ps = self.flat(params)
        sd = self.cfg.state_dtype
        return AdamState(step=jnp.zeros((), jnp.int32),
                         m=[jnp.zeros(p.shape, sd) for p in ps],
                         v=[jnp.zeros(p.shape, sd) for p in ps])

    def step(self, comm: Comm, params, grads, state: AdamState,
             worker_index=None):
        cfg = self.cfg
        t = state.step
        lr = cfg.lr(t).astype(jnp.float32)
        from repro.core import compressor as C
        from repro.core import onebit_allreduce as AR
        xs, gs = self.flat(params), self.flat(grads)
        new_x, new_m, new_v = [], [], []
        for i, (x, g, m, v, dp, lo) in enumerate(
                zip(xs, gs, state.m, state.v, self.dp_mask, self.layouts)):
            g = g.astype(jnp.float32)
            if dp:
                gv = C.to_view(g, lo)
                gv = AR.fullprec_allreduce_view(comm, gv, cfg.comm_dtype,
                                                vspec=self.vspecs[i])
                g = C.from_view(gv.astype(jnp.float32), lo)
            m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
            nm = cfg.beta1 * m32 + (1 - cfg.beta1) * g
            nv = cfg.beta2 * v32 + (1 - cfg.beta2) * g * g
            delta = lr * nm / jnp.sqrt(v32 + cfg.eps)
            if cfg.weight_decay:
                delta = delta + lr * cfg.weight_decay * x.astype(jnp.float32)
            new_x.append((x.astype(jnp.float32) - delta).astype(x.dtype))
            new_m.append(nm.astype(m.dtype))
            new_v.append(nv.astype(v.dtype))
        metrics = {"lr": lr, "synced": jnp.asarray(True),
                   "var_round": jnp.asarray(True),
                   "interval": jnp.ones((), jnp.int32)}
        return (jax.tree.unflatten(self.treedef, new_x),
                AdamState(step=t + 1, m=new_m, v=new_v), metrics)
