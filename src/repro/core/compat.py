"""JAX version compatibility shims.

The code targets the current public API (``jax.shard_map`` with
``axis_names``/``check_vma``, ``jax.make_mesh(..., axis_types=...)``,
``jax.lax.axis_size``); this module maps each call onto the jax 0.4.x
equivalents (``jax.experimental.shard_map.shard_map`` with ``auto``/
``check_rep``, typeless meshes, ``jax.core.axis_frame``) so the same
trainer/server code runs on both. Every shim resolves the API at call
time, so an upgraded jax is picked up without code changes.
"""
from __future__ import annotations

from typing import Sequence

import jax


def axis_size(name) -> int:
    """Static size of one vmap/mesh axis (or a tuple: product)."""
    names = name if isinstance(name, (tuple, list)) else (name,)
    n = 1
    for a in names:
        if hasattr(jax.lax, "axis_size"):
            n *= jax.lax.axis_size(a)
        else:
            f = jax.core.axis_frame(a)
            n *= f if isinstance(f, int) else f.size
    return n


def shard_map(f, *, in_specs, out_specs, axis_names, mesh=None,
              check: bool = False):
    """``jax.shard_map`` with manual ``axis_names``, on any jax.

    On jax < 0.5 the explicit ``mesh`` is required (the old API cannot
    pick it up from an ambient abstract mesh) and the manual-axis set is
    translated into its complement ``auto`` set.
    """
    if hasattr(jax, "shard_map"):
        kw = dict(in_specs=in_specs, out_specs=out_specs,
                  axis_names=set(axis_names), check_vma=check)
        if mesh is not None:
            kw["mesh"] = mesh
        return jax.shard_map(f, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    if mesh is None:
        raise ValueError("jax<0.5 shard_map needs an explicit mesh")
    auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _sm(f, mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check, auto=auto)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """GSPMD-auto mesh; ``axis_types`` only exists on newer jax."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            tuple(shape), tuple(axes),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def abstract_mesh(axes: Sequence[str], sizes: Sequence[int]):
    """Device-free mesh for ``shard_map`` traces (``jax.make_jaxpr`` only —
    an abstract mesh never reaches the compiler). Public on jax >= 0.5,
    private on 0.4.x."""
    try:
        from jax.sharding import AbstractMesh  # jax >= 0.5
    except ImportError:
        from jax._src.mesh import AbstractMesh
    return AbstractMesh(tuple(zip(tuple(axes), tuple(sizes))))
