"""Base optimizer steps for the composable optimizer API.

A *base step* describes the local, per-leaf half of an optimizer — the
momentum update, the preconditioner that maps an accumulated buffer to a
parameter movement, and the (optional) second-moment refresh — while the
:func:`repro.core.compressed.compressed_dp` combinator owns everything
distributed: comm-view layouts, error-feedback state, the T_u/T_v policy
machines, anchors, hierarchy, and the Algorithm-2 compressed exchange.

The contract that makes 0/1-style local stepping work for any base is
*linearity of the preconditioner in its buffer argument* while the carried
slots are frozen between syncs:

    precond(a·x + b·y, slots) == a·precond(x, slots) + b·precond(y, slots)

Under that contract ``x_{t+1/2} = x_{t'} − precond(u_{t+1/2})`` holds
exactly between syncs, which is what lets the combinator sync the
accumulated buffer ``u`` instead of the parameters (paper Algorithm 1,
generalized). Adam satisfies it with ``buf / sqrt(v+eps)`` (v frozen by
T_v), momentum-SGD trivially with the identity, and LAMB with a per-leaf
trust-ratio scalar that is refreshed only at syncs (the 1-bit LAMB trick of
freezing the layerwise scaling factors between full exchanges).

Bases are plain frozen dataclasses — hashable, jit-static, and comparable,
so they can key kernel dispatch (``kind``) and live inside combinator
configs.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Dict, Tuple

import jax.numpy as jnp

from repro.core import compressor as C


def _global_l2(x, model_axes) -> jnp.ndarray:
    """L2 norm of a (natural-shape) leaf, correct under manual TP sharding."""
    sq = jnp.sum(x.astype(jnp.float32) * x.astype(jnp.float32))
    return jnp.sqrt(C._psum_model(sq, model_axes))


@dataclasses.dataclass(frozen=True)
class AdamBase:
    """Adam's local half-step (no bias correction — paper Eq. 3 convention)."""

    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8

    kind: ClassVar[str] = "adam"
    has_variance: ClassVar[bool] = True      # participates in T_v refreshes
    has_trust: ClassVar[bool] = False        # layerwise trust-ratio scaling
    needs_anchor: ClassVar[bool] = False
    sync_slot_names: ClassVar[Tuple[str, ...]] = ()

    def slot_specs(self) -> Dict[str, Tuple[str, float]]:
        """name -> (shape kind, init value). ``view``: comm-view (DP) or
        natural (non-DP) array per leaf; ``scalar``: per-leaf scalar,
        DP leaves only."""
        return {"m": ("view", 0.0), "v": ("view", 0.0)}

    def precond_raw(self, buf, slots):
        """Trust-free linear preconditioner (shared by every style)."""
        return buf / jnp.sqrt(slots["v"] + self.eps)

    def precond(self, buf, slots):
        """Map a momentum-like buffer to a parameter movement. Linear in
        ``buf``; uses only frozen slots."""
        return self.precond_raw(buf, slots)

    def update_variance(self, v, g):
        return self.beta2 * v + (1 - self.beta2) * g * g

    def refresh_sync_slots(self, slots, anchor_nat, ubar_view, gamma_total,
                           layout, model_axes) -> Dict[str, jnp.ndarray]:
        """Slot updates applied at a sync, before the synced movement is
        taken with :meth:`precond` (e.g. LAMB's trust refresh). Default:
        nothing."""
        del slots, anchor_nat, ubar_view, gamma_total, layout, model_axes
        return {}


@dataclasses.dataclass(frozen=True)
class LambBase(AdamBase):
    """LAMB: Adam preconditioning scaled by a layerwise trust ratio
    ``clip(||x|| / ||update||)`` (You et al., 2020; 1-bit LAMB: Li et al.,
    2021).

    In one-shot styles (``mean`` / ``gradient``) the trust ratio is
    recomputed every step from the current parameters — plain (1-bit) LAMB.
    In the ``accumulate`` (0/1) style it is a carried per-leaf slot frozen
    between syncs and refreshed at each sync from the anchor ``x_{t'}`` and
    the *rate-normalized* aggregate ``ū/(Σγ·sqrt(v+eps))`` — normalizing by
    ``Σγ`` keeps the lr schedule in charge of the step size (otherwise the
    trust ratio would cancel the accumulated lr). Requires
    ``store_anchor=True``.
    """

    min_trust: float = 0.0
    max_trust: float = 10.0

    kind: ClassVar[str] = "lamb"
    has_trust: ClassVar[bool] = True
    needs_anchor: ClassVar[bool] = True
    sync_slot_names: ClassVar[Tuple[str, ...]] = ("trust",)

    def slot_specs(self):
        return {"m": ("view", 0.0), "v": ("view", 0.0),
                "trust": ("scalar", 1.0)}

    def precond(self, buf, slots):
        return slots["trust"] * self.precond_raw(buf, slots)

    def trust_ratio(self, x_nat, upd_nat, model_axes):
        """phi(||x||)/||upd|| clipped; 1.0 whenever either norm vanishes."""
        xn = _global_l2(x_nat, model_axes)
        un = _global_l2(upd_nat, model_axes)
        ratio = jnp.clip(xn / jnp.where(un > 0, un, 1.0),
                         self.min_trust, self.max_trust)
        return jnp.where((xn > 0) & (un > 0), ratio, jnp.ones_like(ratio))

    def refresh_sync_slots(self, slots, anchor_nat, ubar_view, gamma_total,
                           layout, model_axes):
        r = ubar_view / jnp.sqrt(slots["v"] + self.eps)
        upd_nat = C.from_view(r, layout) / gamma_total
        return {"trust": self.trust_ratio(anchor_nat, upd_nat, model_axes)}


@dataclasses.dataclass(frozen=True)
class MomentumSgdBase:
    """Momentum SGD: the APMSqueeze/1-bit-SGD family's base step. No second
    moment — composing it with ``compressed_dp`` skips T_v entirely (zero
    variance AllReduce traffic)."""

    beta1: float = 0.9

    kind: ClassVar[str] = "sgd"
    has_variance: ClassVar[bool] = False
    has_trust: ClassVar[bool] = False
    needs_anchor: ClassVar[bool] = False
    sync_slot_names: ClassVar[Tuple[str, ...]] = ()

    def slot_specs(self):
        return {"m": ("view", 0.0)}

    def precond_raw(self, buf, slots):
        del slots
        return buf

    def precond(self, buf, slots):
        del slots
        return buf

    def refresh_sync_slots(self, slots, anchor_nat, ubar_view, gamma_total,
                           layout, model_axes):
        del slots, anchor_nat, ubar_view, gamma_total, layout, model_axes
        return {}


def adam_base(beta1: float = 0.9, beta2: float = 0.999,
              eps: float = 1e-8) -> AdamBase:
    return AdamBase(beta1=beta1, beta2=beta2, eps=eps)


def lamb_base(beta1: float = 0.9, beta2: float = 0.999, eps: float = 1e-8,
              min_trust: float = 0.0, max_trust: float = 10.0) -> LambBase:
    return LambBase(beta1=beta1, beta2=beta2, eps=eps,
                    min_trust=min_trust, max_trust=max_trust)


def momentum_sgd_base(beta1: float = 0.9) -> MomentumSgdBase:
    return MomentumSgdBase(beta1=beta1)
