"""Optimizer factory + shared plumbing.

All optimizers in this package share one interface::

    opt = make_optimizer(cfg, param_shapes, specs=..., dp_mask=..., n_workers=n)
    state = opt.init(params)                       # or jax.eval_shape(opt.init, ...)
    params', state', metrics = opt.step(comm, params, grads, state)

``step`` is written *per worker*: inside a partial-manual ``shard_map`` the
worker axes are the manual mesh axes and ``comm`` wraps real collectives;
under ``jax.vmap(axis_name=...)`` the same code runs n simulated workers on
one device (how the tests exercise the algorithms).

``dp_mask`` marks which leaves are data-parallel replicated (True, default):
those participate in the paper's compressed sync + variance AllReduce.
Leaves marked False (e.g. expert-parallel MoE experts, which exist exactly
once across the worker axis and therefore have no DP gradient exchange to
compress) are updated with plain local Adam; their gradients are pre-scaled
by 1/n to match the global-mean-loss convention (see train/step.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import compressor as C
from repro.core import schedules as S
from repro.core.comm import Comm, Hierarchy


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "zero_one_adam"         # adam | one_bit_adam | zero_one_adam
    lr: Callable = S.ConstantLr(1e-3)
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    # 0/1 Adam policies
    var_policy: Any = S.AdaptiveFreezePolicy(kappa=16)
    sync_policy: Any = S.LrProportionalSyncPolicy(
        warmup_steps=12500, double_every=32768, max_interval=16)
    # 1-bit Adam full-precision stage length
    onebit_warmup: int = 16000
    # compression
    scale_mode: C.ScaleMode = "tensor"   # paper-faithful; "row" = optimized
    quantize: bool = True                # False -> exact chunked allreduce
    store_anchor: bool = True            # True: keep x_{t'} copy -> bitwise
                                         # worker consensus at syncs. False:
                                         # recover the anchor from u (saves a
                                         # params copy; workers agree only up
                                         # to f32 rounding, a ~1e-6 random
                                         # walk per sync).
    comm_dtype: Any = jnp.bfloat16       # wire dtype for full-precision rounds
    state_dtype: Any = jnp.float32
    use_pallas: bool = False             # route the EF-compress/decompress
                                         # hot loop and the local half-step
                                         # through the fused Pallas kernels
                                         # (repro.kernels.dispatch); f32-
                                         # identical to the unfused XLA path
    hierarchy: Optional[Hierarchy] = None  # two-level (intra-pod x inter-pod)
                                         # topology: reduce uncompressed over
                                         # the fast inner axes, run the 1-bit
                                         # EF exchange only across pods. None
                                         # = flat (single-level) exchange.


def tree_layouts(shapes, specs, n: int):
    """Per-leaf comm layouts. ``shapes`` is a tree of arrays or ShapeDtypeStructs."""
    def mk(x, spec):
        return C.make_layout(x.shape, spec, n)
    return jax.tree.map(mk, shapes, specs,
                        is_leaf=lambda x: hasattr(x, "shape"))


def fill_like(tree, value):
    return jax.tree.map(lambda _: value, tree)


def make_optimizer(cfg: OptimizerConfig, param_shapes, *, specs=None,
                   dp_mask=None, n_workers: int, model_axis_sizes=None):
    from repro.core import adam, one_bit_adam, zero_one_adam
    if specs is None:
        specs = fill_like(param_shapes, None)
    if dp_mask is None:
        dp_mask = fill_like(param_shapes, True)
    ctors = {
        "adam": adam.Adam,
        "one_bit_adam": one_bit_adam.OneBitAdam,
        "zero_one_adam": zero_one_adam.ZeroOneAdam,
    }
    if cfg.name not in ctors:
        raise ValueError(f"unknown optimizer {cfg.name!r}; "
                         f"choose from {sorted(ctors)}")
    return ctors[cfg.name](cfg, param_shapes, specs, dp_mask, n_workers,
                           model_axis_sizes)


# ---------------------------------------------------------------------------
# Static communication accounting (feeds the Fig. 3/4 benchmarks)
# ---------------------------------------------------------------------------

def comm_accounting(opt) -> Dict[str, float]:
    """Static bytes-per-round numbers for the optimizer's parameter tree.

    ``*_inner`` / ``*_outer`` split every round into its topology levels:
    ``inner`` is the uncompressed intra-pod traffic (zero for flat layouts),
    ``outer`` crosses the inter-pod links — the compressed exchange for
    syncs, the owned-slice exchange for full-precision rounds. The headline
    ``fullprec_bytes_per_round`` keeps the historical true-parameter ring
    convention for flat layouts and becomes the per-level sum (padded-view
    based, like every other number here) when a hierarchy is configured.
    """
    import numpy as np
    layouts = jax.tree.leaves(opt.layouts)
    masks = jax.tree.leaves(opt.dp_mask)
    wire = jnp.dtype(opt.cfg.comm_dtype).itemsize
    total_params = 0
    comp_inner = comp_outer = 0
    full_inner = full_outer = 0
    n_inner = 1
    for lo, dp in zip(layouts, masks):
        if not dp:
            continue
        total_params += int(np.prod(lo.shape)) if lo.shape else 1
        lv = C.compressed_bytes_levels(lo, opt.cfg.scale_mode,
                                       inner_itemsize=wire)
        comp_inner += lv["inner"]
        comp_outer += lv["outer"]
        fv = C.fullprec_bytes_levels(lo, wire)
        full_inner += fv["inner"]
        full_outer += fv["outer"]
        n_inner = max(n_inner, lo.n_inner)
    # Ring/chunked allreduce (scatter-mean + all-gather) moves 2*(n-1)/n of
    # the payload per worker — same transport convention as compressed_bytes,
    # so the compression ratios the Fig. 3/4 benches derive are unbiased.
    ring = 2.0 * (opt.n - 1) / max(opt.n, 1)
    full = (full_inner + full_outer if n_inner > 1
            else ring * total_params * wire)
    compressed = comp_inner + comp_outer
    return {
        "dp_params": float(total_params),
        "compressed_bytes_per_sync": float(compressed),
        "compressed_bytes_per_sync_inner": float(comp_inner),
        "compressed_bytes_per_sync_outer": float(comp_outer),
        "fullprec_bytes_per_round": float(full),
        "fullprec_bytes_per_round_inner": float(full_inner),
        "fullprec_bytes_per_round_outer": float(full_outer),
        "bits_per_param_sync": 8.0 * compressed / max(total_params, 1),
        "n_inner": float(n_inner),
        "n_outer": float(opt.n // n_inner),
    }
