"""Optimizer registry, config, and shared accounting.

The canonical way to build an optimizer is the composable transform API::

    from repro.core import compressed_dp, adam_base, lamb_base

    opt = compressed_dp(lamb_base(), lr=..., sync_policy=..., var_policy=...)(
        param_shapes, specs=..., dp_mask=..., n_workers=n)
    state = opt.init(params)                       # or jax.eval_shape(...)
    params', state', metrics = opt.step(comm, params, grads, state)

``step`` is written *per worker*: inside a partial-manual ``shard_map`` the
worker axes are the manual mesh axes and ``comm`` wraps real collectives;
under ``jax.vmap(axis_name=...)`` the same code runs n simulated workers on
one device (how the tests exercise the algorithms).

Name-based construction goes through the registry: ``build_optimizer``
accepts either an unbound :class:`~repro.core.compressed.CompressedDP`
transform or an :class:`OptimizerConfig` whose ``name`` selects a composed
pipeline (see ``REGISTRY_NAMES``). ``make_optimizer`` is kept as a
deprecation shim: the legacy names ("adam", "one_bit_adam",
"zero_one_adam") still work but emit a ``DeprecationWarning`` pointing at
the compositional spelling; they return the composed equivalent (bitwise
for the compressed pipelines — see tests/test_composed_equivalence.py).

``dp_mask`` marks which leaves are data-parallel replicated (True, default):
those participate in the paper's compressed sync + variance AllReduce.
Leaves marked False (e.g. expert-parallel MoE experts, which exist exactly
once across the worker axis and therefore have no DP gradient exchange to
compress) are updated with plain local base steps; their gradients are
pre-scaled by 1/n to match the global-mean-loss convention (see
train/step.py).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import compressor as C
from repro.core import schedules as S
from repro.core.base_steps import adam_base, lamb_base, momentum_sgd_base
from repro.core.comm import Hierarchy
from repro.core.compressed import CompressedDP, compressed_dp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "zero_one_adam"         # any REGISTRY_NAMES entry
    lr: Callable = S.ConstantLr(1e-3)
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    # 0/1 policies (T_u local steps / T_v variance freezing)
    var_policy: Any = S.AdaptiveFreezePolicy(kappa=16)
    sync_policy: Any = S.LrProportionalSyncPolicy(
        warmup_steps=12500, double_every=32768, max_interval=16)
    # 1-bit full-precision stage length
    onebit_warmup: int = 16000
    # compression
    scale_mode: C.ScaleMode = "tensor"   # paper-faithful; "row" = optimized
    quantize: bool = True                # deprecated: False -> the identity
                                         # codec (exact chunked allreduce);
                                         # emits a DeprecationWarning when the
                                         # optimizer is built
    codec: Any = "sign1bit"              # wire format of the EF exchange: any
                                         # repro.core.codecs.CODEC_NAMES entry
                                         # (sign1bit | topk | qint8 | qint4 |
                                         # identity) or a Codec instance
    codec_arg: Optional[float] = None    # parameter for parameterized codecs
                                         # (topk: density, default 0.01)
    store_anchor: bool = True            # True: keep x_{t'} copy -> bitwise
                                         # worker consensus at syncs. False:
                                         # recover the anchor from u (saves a
                                         # params copy; workers agree only up
                                         # to f32 rounding, a ~1e-6 random
                                         # walk per sync).
    comm_dtype: Any = jnp.bfloat16       # wire dtype for full-precision rounds
    state_dtype: Any = jnp.float32
    use_pallas: bool = False             # route the EF-compress/decompress
                                         # hot loop and the local half-step
                                         # through the fused Pallas kernels
                                         # (repro.kernels.dispatch); f32-
                                         # identical to the unfused XLA path
    hierarchy: Optional[Hierarchy] = None  # two-level (intra-pod x inter-pod)
                                         # topology: reduce uncompressed over
                                         # the fast inner axes, run the
                                         # compressed EF exchange only across
                                         # pods. None = flat (single-level)
                                         # exchange.
    bucket_mb: Optional[float] = None    # fuse the per-leaf exchange into
                                         # fixed-budget flat buckets of this
                                         # many MiB of f32 elements each
                                         # (repro.core.bucketing): EF state,
                                         # anchors, payloads, and collectives
                                         # then run per bucket. None = the
                                         # historical per-leaf exchange.
    pack_order: str = "flat"             # exchange-unit packing/issue order
                                         # (bucketing.PACK_ORDERS): "flat" or
                                         # "reverse_backward" (reverse
                                         # flat-leaf order ≈ backward
                                         # readiness, so early units overlap
                                         # the tail of the backward pass)

    def __post_init__(self):
        if self.bucket_mb is not None and self.bucket_mb <= 0:
            raise ValueError(
                f"bucket_mb must be positive (MiB per fused bucket), got "
                f"{self.bucket_mb!r}")
        from repro.core.bucketing import PACK_ORDERS
        if self.pack_order not in PACK_ORDERS:
            raise ValueError(
                f"pack_order must be one of {PACK_ORDERS}, got "
                f"{self.pack_order!r}")
        # fail fast, with the valid options listed, instead of deep inside
        # _scales / the exchange (ScaleMode is a plain str; a typo like
        # "rows" used to surface steps later)
        C.validate_scale_mode(self.scale_mode)
        from repro.core.codecs import make_codec
        make_codec(self.codec, self.codec_arg)   # validates name + arg


# ---------------------------------------------------------------------------
# Registry: name -> composed transform
# ---------------------------------------------------------------------------

def _shared_kwargs(cfg: OptimizerConfig) -> Dict[str, Any]:
    return dict(lr=cfg.lr, weight_decay=cfg.weight_decay,
                scale_mode=cfg.scale_mode, quantize=cfg.quantize,
                codec=cfg.codec, codec_arg=cfg.codec_arg,
                store_anchor=cfg.store_anchor, comm_dtype=cfg.comm_dtype,
                state_dtype=cfg.state_dtype, use_pallas=cfg.use_pallas,
                hierarchy=cfg.hierarchy, bucket_mb=cfg.bucket_mb,
                pack_order=cfg.pack_order)


def _adam(cfg):
    return adam_base(cfg.beta1, cfg.beta2, cfg.eps)


def _lamb(cfg):
    return lamb_base(cfg.beta1, cfg.beta2, cfg.eps)


def _zero_one(base_fn):
    def build(cfg):
        return compressed_dp(base_fn(cfg), style="accumulate",
                             sync_policy=cfg.sync_policy,
                             var_policy=cfg.var_policy,
                             **_shared_kwargs(cfg))
    return build


def _one_bit(base_fn):
    def build(cfg):
        return compressed_dp(base_fn(cfg), style="gradient",
                             var_policy=S.FixedWarmupPolicy(
                                 cfg.onebit_warmup),
                             **_shared_kwargs(cfg))
    return build


def _mean(base_fn):
    def build(cfg):
        return compressed_dp(base_fn(cfg), style="mean",
                             **_shared_kwargs(cfg))
    return build


_BUILDERS: Dict[str, Callable[[OptimizerConfig], CompressedDP]] = {
    # uncompressed DP baselines (full-precision mean every step)
    "adam": _mean(_adam),
    "lamb": _mean(_lamb),
    "momentum_sgd": _mean(lambda c: momentum_sgd_base(c.beta1)),
    # 1-bit two-stage (full-precision warmup, then EF-compressed gradients)
    "one_bit_adam": _one_bit(_adam),
    "one_bit_lamb": _one_bit(_lamb),
    # 0/1 local-step pipelines (paper Algorithm 1 over each base)
    "zero_one_adam": _zero_one(_adam),
    "zero_one_lamb": _zero_one(_lamb),
    "zero_one_sgd": _zero_one(lambda c: momentum_sgd_base(c.beta1)),
}

REGISTRY_NAMES = tuple(sorted(_BUILDERS))

# names predating the composable API; make_optimizer warns on these
LEGACY_NAMES = ("adam", "one_bit_adam", "zero_one_adam")

_LEGACY_SPELLING = {
    "adam": 'compressed_dp(adam_base(...), style="mean", ...)',
    "one_bit_adam": ('compressed_dp(adam_base(...), style="gradient", '
                     'var_policy=FixedWarmupPolicy(T0), ...)'),
    "zero_one_adam": 'compressed_dp(adam_base(...), ...)',
}


def transform_from_config(cfg: OptimizerConfig) -> CompressedDP:
    """Resolve a registry name to its unbound composed transform."""
    if cfg.name not in _BUILDERS:
        raise ValueError(f"unknown optimizer {cfg.name!r}; "
                         f"choose from {list(REGISTRY_NAMES)}")
    return _BUILDERS[cfg.name](cfg)


def tree_layouts(shapes, specs, n: int):
    """Per-leaf comm layouts. ``shapes`` is a tree of arrays or ShapeDtypeStructs."""
    def mk(x, spec):
        return C.make_layout(x.shape, spec, n)
    return jax.tree.map(mk, shapes, specs,
                        is_leaf=lambda x: hasattr(x, "shape"))


def fill_like(tree, value):
    return jax.tree.map(lambda _: value, tree)


def build_optimizer(cfg, param_shapes, *, specs=None, dp_mask=None,
                    n_workers: int, model_axis_sizes=None,
                    codec=None, codec_arg=None):
    """Bind a transform (or a registry-named config) to a parameter tree.

    ``cfg`` is either an unbound ``compressed_dp(...)`` transform or an
    :class:`OptimizerConfig`. Never warns — this is the entry point the
    trainer and new code use.

    ``codec`` / ``codec_arg`` override the config's wire format in place,
    so every registry optimizer runs over any codec without rebuilding the
    config: ``build_optimizer(cfg, ..., codec="topk", codec_arg=0.01)``.
    A ``codec_arg`` alone re-parameterizes the config's codec; a ``codec``
    alone keeps the stored ``codec_arg`` only when it names the same codec
    (switching codecs resets the arg to that codec's default).
    """
    if codec is not None or codec_arg is not None:
        old_codec = getattr(cfg, "codec", None)
        old_name = getattr(old_codec, "name", old_codec)  # instance -> name
        repl = {}
        if codec is None:
            # codec_arg-only: re-parameterize the configured codec
            codec = old_name
        else:
            if codec_arg is None and codec == old_name:
                # same-name override: keep the configured codec itself —
                # an instance carries its parameters (TopKCodec(0.2))
                # even when the codec_arg field is None
                codec = old_codec
                codec_arg = getattr(cfg, "codec_arg", None)
            # an override HERE is unambiguously explicit (unlike a config
            # field, where "sign1bit" is indistinguishable from the
            # default), so it also clears the deprecated quantize=False
            # flag — otherwise the config's __post_init__ would rewrite an
            # explicit sign1bit override to identity
            if not getattr(cfg, "quantize", True):
                warnings.warn(
                    f"quantize=False is deprecated and overridden by the "
                    f"explicit codec={codec!r} argument",
                    DeprecationWarning, stacklevel=2)
                repl["quantize"] = True
        cfg = dataclasses.replace(cfg, codec=codec, codec_arg=codec_arg,
                                  **repl)
    transform = (cfg if isinstance(cfg, CompressedDP)
                 else transform_from_config(cfg))
    return transform(param_shapes, specs=specs, dp_mask=dp_mask,
                     n_workers=n_workers, model_axis_sizes=model_axis_sizes)


def make_optimizer(cfg, param_shapes, *, specs=None, dp_mask=None,
                   n_workers: int, model_axis_sizes=None):
    """Deprecation shim for name-based construction.

    Legacy names keep working but emit a ``DeprecationWarning`` pointing at
    the composed spelling; the returned optimizer *is* the composed
    equivalent (bitwise-identical trajectories for the compressed
    pipelines). New code should call :func:`build_optimizer` or the
    combinator directly.
    """
    if isinstance(cfg, CompressedDP):
        return build_optimizer(cfg, param_shapes, specs=specs,
                               dp_mask=dp_mask, n_workers=n_workers,
                               model_axis_sizes=model_axis_sizes)
    if cfg.name in LEGACY_NAMES:
        warnings.warn(
            f"make_optimizer(name={cfg.name!r}) is deprecated; build the "
            f"composed transform instead: {_LEGACY_SPELLING[cfg.name]} "
            f"(see repro.core.compressed)", DeprecationWarning,
            stacklevel=2)
    return build_optimizer(cfg, param_shapes, specs=specs, dp_mask=dp_mask,
                           n_workers=n_workers,
                           model_axis_sizes=model_axis_sizes)


# ---------------------------------------------------------------------------
# Static communication accounting (feeds the Fig. 3/4 benchmarks)
# ---------------------------------------------------------------------------

def comm_accounting(opt) -> Dict[str, float]:
    """Static bytes-per-round numbers for the optimizer's parameter tree.

    ``*_inner`` / ``*_outer`` split every round into its topology levels:
    ``inner`` is the uncompressed intra-pod traffic (zero for flat layouts),
    ``outer`` crosses the inter-pod links — the compressed exchange for
    syncs, the owned-slice exchange for full-precision rounds. The headline
    ``fullprec_bytes_per_round`` keeps the historical true-parameter ring
    convention for flat layouts and becomes the per-level sum (padded-view
    based, like every other number here) when a hierarchy is configured.

    Sync volume delegates to the optimizer's codec (``codec.wire_bytes``),
    so the numbers stay honest per wire format; ``codec`` in the returned
    dict names it.

    Volumes (and the dispatch counts ``exchange_units`` /
    ``collectives_per_sync``) are computed over the optimizer's *exchange
    units* — per-bucket layouts when ``bucket_mb`` is set, per-leaf layouts
    otherwise — so bucketed configs report per-bucket scale overhead and
    the reduced collective count. ``collectives_per_sync`` counts collective
    *phases* per unit (2 flat — scatter + gather — or 4 hierarchical,
    including the two uncompressed intra-pod phases); payload pytrees with
    several leaves (e.g. sign1bit's packed bits + scales) multiply the raw
    HLO op count but not the round count.
    """
    import numpy as np
    layouts = jax.tree.leaves(opt.layouts)
    masks = jax.tree.leaves(opt.dp_mask)
    wire = jnp.dtype(opt.cfg.comm_dtype).itemsize
    codec = getattr(getattr(opt, "ar_cfg", None), "codec", None)
    total_params = 0
    dp_leaves = 0
    for lo, dp in zip(layouts, masks):
        if not dp:
            continue
        dp_leaves += 1
        total_params += int(np.prod(lo.shape)) if lo.shape else 1
    bplan = getattr(opt, "bucket_plan", None)
    if bplan is not None:
        units = [b.layout for b in bplan.buckets]
    else:
        units = [lo for lo, dp in zip(layouts, masks) if dp]
    comp_inner = comp_outer = 0
    full_inner = full_outer = 0
    n_inner = 1
    for lo in units:
        lv = C.compressed_bytes_levels(lo, opt.cfg.scale_mode,
                                       inner_itemsize=wire, codec=codec)
        comp_inner += lv["inner"]
        comp_outer += lv["outer"]
        fv = C.fullprec_bytes_levels(lo, wire)
        full_inner += fv["inner"]
        full_outer += fv["outer"]
        n_inner = max(n_inner, lo.n_inner)
    # Ring/chunked allreduce (scatter-mean + all-gather) moves 2*(n-1)/n of
    # the payload per worker — same transport convention as compressed_bytes,
    # so the compression ratios the Fig. 3/4 benches derive are unbiased.
    ring = 2.0 * (opt.n - 1) / max(opt.n, 1)
    full = (full_inner + full_outer if n_inner > 1
            else ring * total_params * wire)
    compressed = comp_inner + comp_outer
    from repro.core.codecs import make_codec
    return {
        "dp_params": float(total_params),
        "codec": make_codec("sign1bit" if codec is None else codec).name,
        "compressed_bytes_per_sync": float(compressed),
        "compressed_bytes_per_sync_inner": float(comp_inner),
        "compressed_bytes_per_sync_outer": float(comp_outer),
        "fullprec_bytes_per_round": float(full),
        "fullprec_bytes_per_round_inner": float(full_inner),
        "fullprec_bytes_per_round_outer": float(full_outer),
        "bits_per_param_sync": 8.0 * compressed / max(total_params, 1),
        "n_inner": float(n_inner),
        "n_outer": float(opt.n // n_inner),
        "dp_leaves": float(dp_leaves),
        "exchange_units": float(len(units)),
        "collectives_per_sync": float(
            len(units) * (4 if n_inner > 1 else 2)),
        "bucket_mb": (float(bplan.bucket_mb) if bplan is not None
                      else None),
    }
