"""``compressed_dp``: compressed data-parallel sync as a composable transform.

The paper's 0/1 Adam recipe — stale-state linearization + error-feedback
1-bit sync + local steps — is not Adam-specific. This module factors the
recipe into a combinator over *base steps* (:mod:`repro.core.base_steps`):

    opt = compressed_dp(adam_base(), lr=..., sync_policy=..., var_policy=...)(
        param_shapes, specs=specs, dp_mask=dp_mask, n_workers=n)
    state = opt.init(params)
    params, state, metrics = opt.step(comm, params, grads, state)

Every bound optimizer implements the same **GradientTransform protocol**
(``init`` / ``step`` written per worker, exactly like the legacy classes),
so trainers, checkpointing, and the benchmarks are base-agnostic.

Three sync styles, all owning the same layouts / EF state / hierarchy:

* ``"accumulate"`` — paper Algorithm 1 generalized: local linearized
  half-steps accumulate ``u``; on T_u steps ``u`` is 1-bit AllReduced
  (Algorithm 2) and parameters re-anchor; on T_v steps the variance is
  refreshed from a full-precision gradient mean. With ``adam_base`` this is
  bitwise-identical to the legacy ``ZeroOneAdam`` (asserted in
  tests/test_composed_equivalence.py); with ``lamb_base`` / ``momentum_sgd_base``
  it yields 0/1-LAMB and 0/1-SGD.
* ``"gradient"`` — the 1-bit Adam two-stage schedule (Algorithm 4):
  full-precision gradient AllReduce while ``var_policy`` fires (the warmup
  stage), EF-1-bit gradient AllReduce with frozen variance afterwards.
  Bitwise-identical to the legacy ``OneBitAdam`` with
  ``var_policy=FixedWarmupPolicy(onebit_warmup)`` at ``weight_decay=0``
  (the legacy class never applied decay; this style does).
* ``"mean"`` — the uncompressed baseline: full-precision gradient mean every
  step, variance every step. ``compressed_dp(adam_base(), style="mean")``
  is distributed Adam; with the other bases, distributed LAMB /
  momentum-SGD.

State is carried per leaf in comm-view shape for DP leaves (natural shape
for ``dp_mask=False`` leaves, which take plain local base steps). The
``slots`` dict holds whatever the base declares ("m", optionally "v",
optionally per-leaf "trust" scalars), so one state type serves every base.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import bucketing as BK
from repro.core import codecs as CODECS
from repro.core import compressor as C
from repro.core import leafwise
from repro.core import onebit_allreduce as AR
from repro.core import schedules as S
from repro.core.comm import Comm, Hierarchy

STYLES = ("accumulate", "gradient", "mean")


class CompressedDPState(NamedTuple):
    step: jnp.ndarray
    gamma_acc: jnp.ndarray    # sum of gamma since the last sync (accumulate)
    sync_pstate: tuple        # T_u policy carried state (accumulate)
    var_pstate: tuple         # T_v policy carried state
    slots: Dict[str, list]    # base slots: "m" (+"v", +"trust"), per leaf
    u: list                   # accumulated update views (accumulate style)
    err_w: list               # worker-side EF (layout.ef_worker_shape)
    err_s: list               # server-side EF (chunk shape)
    anchor: list              # x_{t'} copies (accumulate + store_anchor)

    # Convenience accessors so slot-based state reads like the legacy one.
    @property
    def m(self):
        return self.slots["m"]

    @property
    def v(self):
        return self.slots.get("v")


@dataclasses.dataclass(frozen=True)
class StateKind:
    """Tag describing one optimizer-state leaf, for generic sharding-spec /
    abstract-shape derivation (see train/sharding.py).

    tags: ``scalar`` (replicated scalar), ``view`` (comm view for DP leaves,
    natural for non-DP), ``chunk`` (server chunk, DP only), ``natural``
    (param-shaped, DP only — anchors), ``leaf_scalar`` (per-worker scalar,
    DP only — trust ratios). ``leaf`` indexes the flat param leaf.

    With a bucketed exchange (``bucket_mb`` set) the EF/anchor state lives
    per *bucket* instead of per leaf: ``bucket_view`` / ``bucket_chunk``
    mirror ``view`` / ``chunk`` with ``leaf`` indexing
    ``opt.bucket_plan.buckets`` (always DP — buckets only cover DP
    leaves)."""

    tag: str
    leaf: Optional[int] = None

    @property
    def bucketed(self) -> bool:
        return self.tag in ("bucket_view", "bucket_chunk")


_SCALAR = StateKind("scalar")


@dataclasses.dataclass(frozen=True)
class CompressedDP:
    """Unbound transform: a base step plus the distributed-sync policy.

    Calling it on a parameter tree returns the bound
    :class:`ComposedOptimizer` (the GradientTransform). Field defaults are
    the paper's production values, mirroring ``OptimizerConfig``.
    """

    base: Any
    style: str = "accumulate"
    lr: Callable = S.ConstantLr(1e-3)
    sync_policy: Any = S.LrProportionalSyncPolicy(
        warmup_steps=12500, double_every=32768, max_interval=16)
    var_policy: Any = S.AdaptiveFreezePolicy(kappa=16)
    weight_decay: float = 0.0
    scale_mode: C.ScaleMode = "tensor"
    quantize: bool = True               # deprecated: False -> codec="identity"
    codec: Any = "sign1bit"             # wire format of the EF exchange —
                                        # a registry name (codecs.CODEC_NAMES)
                                        # or a Codec instance
    codec_arg: Optional[float] = None   # parameter for parameterized codecs
                                        # (topk density)
    store_anchor: bool = True
    comm_dtype: Any = jnp.bfloat16
    state_dtype: Any = jnp.float32
    use_pallas: bool = False
    hierarchy: Optional[Hierarchy] = None
    bucket_mb: Optional[float] = None   # fuse the per-leaf exchange into
                                        # fixed-budget flat buckets (MiB of
                                        # f32 elements per bucket; see
                                        # repro.core.bucketing). None keeps
                                        # the historical per-leaf exchange.

    def __post_init__(self):
        if self.style not in STYLES:
            raise ValueError(f"style={self.style!r}; choose from {STYLES}")
        if self.bucket_mb is not None and self.bucket_mb <= 0:
            raise ValueError(
                f"bucket_mb must be positive (MiB per fused bucket), got "
                f"{self.bucket_mb!r}")
        C.validate_scale_mode(self.scale_mode)
        codec = self.codec
        if not self.quantize:
            warnings.warn(
                "quantize=False is deprecated; use codec=\"identity\" "
                "instead (the exact-mean exchange is now the identity "
                "codec — see repro.core.codecs)", DeprecationWarning,
                stacklevel=3)
        # precedence (shared with OneBitConfig via
        # codecs.resolve_with_quantize, so the legacy and composed paths
        # can never disagree): the deprecated knob forces identity unless
        # a NON-default codec is set — an explicit "sign1bit", name or
        # instance, is indistinguishable from the default and is
        # rewritten; any other explicit codec wins.
        codec = CODECS.resolve_with_quantize(codec, self.quantize)
        # resolve once, at config-build time: a bad codec name / codec_arg
        # fails here with the registry listed, not deep inside the exchange
        object.__setattr__(self, "codec",
                           CODECS.make_codec(codec, self.codec_arg))
        if (self.style == "accumulate" and self.base.needs_anchor
                and not self.store_anchor):
            raise ValueError(
                f"{type(self.base).__name__} refreshes slots at syncs and "
                f"therefore requires store_anchor=True in the accumulate "
                f"style (the anchor recovery path assumes a fixed "
                f"preconditioner between syncs)")
        if self.style == "accumulate" and self.weight_decay:
            raise ValueError(
                "weight_decay is not supported in the accumulate style: a "
                "decay term makes the local step affine in x, breaking the "
                "u-linearization that lets syncs exchange the accumulated "
                "buffer (x_{t+1/2} = x_{t'} - precond(u) no longer holds). "
                "Use decoupled decay outside the optimizer, or the "
                "gradient/mean styles.")

    def __call__(self, param_shapes, *, specs=None, dp_mask=None,
                 n_workers: int, model_axis_sizes=None):
        return ComposedOptimizer(self, param_shapes, specs, dp_mask,
                                 n_workers, model_axis_sizes)


def compressed_dp(base, **kwargs) -> CompressedDP:
    """Compose a base step with the compressed-DP sync machinery."""
    return CompressedDP(base=base, **kwargs)


class ComposedOptimizer:
    """``compressed_dp(...)`` bound to a parameter tree (GradientTransform)."""

    def __init__(self, cfg: CompressedDP, param_shapes, specs, dp_mask,
                 n_workers, model_axis_sizes=None):
        self.cfg = cfg
        self.base = cfg.base
        plan = leafwise.make_plan(param_shapes, specs, dp_mask, n_workers,
                                  model_axis_sizes, cfg.hierarchy)
        self.plan = plan
        self.n = plan.n
        self.hierarchy = plan.hierarchy
        self.model_axes = plan.model_axes
        self.treedef = plan.treedef
        self.specs = plan.specs
        self.dp_mask = plan.dp_mask
        self.layouts = plan.layouts
        self.vspecs = plan.vspecs
        self.ar_cfg = leafwise.make_ar_cfg(
            plan, scale_mode=cfg.scale_mode, quantize=cfg.quantize,
            codec=cfg.codec, use_pallas=cfg.use_pallas,
            comm_dtype=cfg.comm_dtype)
        self.codec = self.ar_cfg.codec
        # Bucketed exchange: EF state / anchors / codec payloads /
        # collectives operate per bucket (repro.core.bucketing) instead of
        # per leaf. None keeps the historical per-leaf exchange.
        self.bucket_plan = (BK.make_bucket_plan(plan, cfg.bucket_mb,
                                                self.vspecs)
                            if cfg.bucket_mb is not None else None)
        self._slot_specs = self.base.slot_specs()
        self._use_sync_policy = cfg.style == "accumulate"
        self._use_var_policy = (cfg.style in ("accumulate", "gradient")
                                and self.base.has_variance)
        self._has_u = cfg.style == "accumulate"
        self._has_ef = cfg.style in ("accumulate", "gradient")
        self._has_anchor = self._has_u and cfg.store_anchor

    def flat(self, tree):
        return self.treedef.flatten_up_to(tree)

    # ------------------------------------------------------------------ #
    # state
    # ------------------------------------------------------------------ #
    def init(self, params) -> CompressedDPState:
        cfg = self.cfg
        sd = cfg.state_dtype
        los, dps = self.layouts, self.dp_mask
        ps = self.flat(params)

        def slot(skind, init_val, p, lo, dp):
            if skind == "scalar":
                return (jnp.full((), init_val, jnp.float32) if dp else None)
            return jnp.full(lo.view_shape if dp else p.shape, init_val, sd)

        slots = {name: [slot(sk, iv, p, lo, dp)
                        for p, lo, dp in zip(ps, los, dps)]
                 for name, (sk, iv) in self._slot_specs.items()}
        bp = self.bucket_plan
        if bp is None:
            err_w = [jnp.zeros(lo.ef_worker_shape, sd)
                     if (dp and self._has_ef) else None
                     for lo, dp in zip(los, dps)]
            err_s = [jnp.zeros(lo.chunk_shape, sd)
                     if (dp and self._has_ef) else None
                     for lo, dp in zip(los, dps)]
            anchor = [(p * 1.0).astype(p.dtype)
                      if (dp and self._has_anchor) else None
                      for p, dp in zip(ps, dps)]
        else:
            # per-bucket EF / anchors: the bucket buffer is what the codec
            # compresses, so its error state (and the re-anchored params)
            # live in bucket shape
            err_w = [jnp.zeros(b.layout.ef_worker_shape, sd)
                     if self._has_ef else None for b in bp.buckets]
            err_s = [jnp.zeros(b.layout.chunk_shape, sd)
                     if self._has_ef else None for b in bp.buckets]
            anchor = [self._gather_bucket(
                          b, [(ps[i] * 1.0).astype(ps[i].dtype)
                              for i in b.members])
                      if self._has_anchor else None for b in bp.buckets]
        return CompressedDPState(
            step=jnp.zeros((), jnp.int32),
            gamma_acc=jnp.zeros((), jnp.float32),
            sync_pstate=(cfg.sync_policy.init()
                         if self._use_sync_policy else ()),
            var_pstate=(cfg.var_policy.init()
                        if self._use_var_policy else ()),
            slots=slots,
            u=[jnp.zeros(lo.view_shape, sd) if (dp and self._has_u) else None
               for lo, dp in zip(los, dps)],
            err_w=err_w,
            err_s=err_s,
            anchor=anchor,
        )

    def _gather_bucket(self, bucket, leaves_nat):
        """Natural member leaves -> bucket buffer (via their comm views)."""
        views = [C.to_view(x, self.layouts[i])
                 for x, i in zip(leaves_nat, bucket.members)]
        return BK.gather_views(bucket, views)

    def state_kinds(self) -> CompressedDPState:
        """Pytree mirroring the state treedef with :class:`StateKind`
        leaves (same ``None`` placements as :meth:`init`)."""
        cfg = self.cfg
        dps = self.dp_mask
        slots = {}
        for name, (sk, _) in self._slot_specs.items():
            if sk == "scalar":
                slots[name] = [StateKind("leaf_scalar", i) if dp else None
                               for i, dp in enumerate(dps)]
            else:
                slots[name] = [StateKind("view", i)
                               for i in range(len(dps))]
        bp = self.bucket_plan
        if bp is None:
            err_w = [StateKind("view", i) if (dp and self._has_ef) else None
                     for i, dp in enumerate(dps)]
            err_s = [StateKind("chunk", i) if (dp and self._has_ef) else None
                     for i, dp in enumerate(dps)]
            anchor = [StateKind("natural", i)
                      if (dp and self._has_anchor) else None
                      for i, dp in enumerate(dps)]
        else:
            err_w = [StateKind("bucket_view", bi) if self._has_ef else None
                     for bi in range(len(bp.buckets))]
            err_s = [StateKind("bucket_chunk", bi) if self._has_ef else None
                     for bi in range(len(bp.buckets))]
            anchor = [StateKind("bucket_view", bi)
                      if self._has_anchor else None
                      for bi in range(len(bp.buckets))]
        return CompressedDPState(
            step=_SCALAR, gamma_acc=_SCALAR,
            sync_pstate=tuple(_SCALAR for _ in (
                cfg.sync_policy.init() if self._use_sync_policy else ())),
            var_pstate=tuple(_SCALAR for _ in (
                cfg.var_policy.init() if self._use_var_policy else ())),
            slots=slots,
            u=[StateKind("view", i) if (dp and self._has_u) else None
               for i, dp in enumerate(dps)],
            err_w=err_w,
            err_s=err_s,
            anchor=anchor,
        )

    def _slots32(self, slots, i):
        return {name: (slots[name][i].astype(jnp.float32)
                       if slots[name][i] is not None else None)
                for name in slots}

    def _fullprec_dp(self, comm, bufs_dp):
        """Full-precision mean of the DP leaves' view buffers, one
        collective pair per exchange unit (leaf, or bucket when bucketing
        is on). The full-precision transport is elementwise, so bucketing
        it is value-preserving per element — only the dispatch count
        changes."""
        cfg = self.cfg
        bp = self.bucket_plan
        dp_idx = [i for i, dp in enumerate(self.dp_mask) if dp]
        if bp is None:
            return [AR.fullprec_allreduce_view(
                        comm, g, cfg.comm_dtype, vspec=self.vspecs[i],
                        hierarchy=self.hierarchy, layout=self.layouts[i])
                    for g, i in zip(bufs_dp, dp_idx)]
        dp_pos = {i: k for k, i in enumerate(dp_idx)}
        out = [None] * len(bufs_dp)
        for b in bp.buckets:
            z = BK.gather_views(b, [bufs_dp[dp_pos[i]] for i in b.members])
            o = AR.fullprec_allreduce_view(
                comm, z, cfg.comm_dtype, vspec=b.vspec,
                hierarchy=self.hierarchy, layout=b.layout)
            for i, v in zip(b.members,
                            BK.scatter_views(
                                b, o, [self.layouts[i]
                                       for i in b.members])):
                out[dp_pos[i]] = v
        return out

    # ------------------------------------------------------------------ #
    # step
    # ------------------------------------------------------------------ #
    def step(self, comm: Comm, params, grads, state: CompressedDPState,
             worker_index=None):
        if self.cfg.style == "accumulate":
            return self._step_accumulate(comm, params, grads, state,
                                         worker_index)
        return self._step_sync(comm, params, grads, state, worker_index)

    # --- accumulate: paper Algorithm 1, generalized over bases ---------- #
    def _step_accumulate(self, comm, params, grads, state, worker_index):
        cfg, base = self.cfg, self.base
        t = state.step
        lr = cfg.lr(t).astype(jnp.float32)

        do_sync, sync_ps, interval = cfg.sync_policy.step(state.sync_pstate,
                                                          t)
        if self._use_var_policy:
            do_var, var_ps = cfg.var_policy.step(state.var_pstate, t,
                                                 interval)
        else:
            do_var, var_ps = jnp.asarray(False), state.var_pstate

        los, dps = self.layouts, self.dp_mask
        xs, gs = self.flat(params), self.flat(grads)
        gv = [C.constrain(C.to_view(g.astype(jnp.float32), lo), vs) if dp
              else g.astype(jnp.float32)
              for g, lo, dp, vs in zip(gs, los, dps, self.vspecs)]
        gamma_total = state.gamma_acc + lr     # sum of gamma over [t', t]

        # --- local half-step for every leaf ----------------------------
        # DP leaves with use_pallas route the elementwise chain through the
        # fused kernel (keyed on the base kind); the unfused jnp chain is
        # f32-identical.
        if cfg.use_pallas:
            from repro.kernels import dispatch as K
        x_half, m_half, u_half = [], [], []
        for i, (x, g, lo, dp, vs) in enumerate(zip(xs, gv, los, dps,
                                                   self.vspecs)):
            s32 = self._slots32(state.slots, i)
            m32 = s32["m"]
            u = state.u[i]
            if dp and cfg.use_pallas and K.kernel_safe(vs):
                mh, u_new, delta = K.fused_local_step_view(
                    g, m32, u.astype(jnp.float32), s32.get("v"), lr,
                    base.beta1, getattr(base, "eps", 0.0), lo,
                    kind=base.kind)
                if base.has_trust:
                    delta = s32["trust"] * delta
                delta_nat = C.from_view(delta, lo)
            else:
                mh = base.beta1 * m32 + (1 - base.beta1) * g
                if not dp and base.has_trust:
                    # non-DP leaves never sync: plain local base step with a
                    # per-step trust ratio (ordinary LAMB behaviour)
                    upd = base.precond_raw(mh, s32)
                    trust = base.trust_ratio(x.astype(jnp.float32), upd,
                                             self.model_axes)
                    delta = lr * trust * upd
                else:
                    delta = base.precond(lr * mh, s32)
                delta_nat = C.from_view(delta, lo) if dp else delta
                u_new = (u.astype(jnp.float32) + lr * mh) if dp else None
            x_half.append((x.astype(jnp.float32) - delta_nat).astype(x.dtype))
            m_half.append(mh)
            u_half.append(u_new)

        dp_idx = [i for i, dp in enumerate(dps) if dp]
        dp_pos = {i: k for k, i in enumerate(dp_idx)}
        use_anchor = cfg.store_anchor
        sync_names = tuple(base.sync_slot_names)
        bp = self.bucket_plan

        def post_sync_leaf(k, i, ubar, anc32, xh, uh, nm, nx, nu, nextra):
            """Per-leaf post-exchange update shared by the per-leaf and
            bucketed sync paths: momentum refresh, slot refresh, the
            re-anchored (or corrected) parameter, u reset."""
            lo = self.layouts[i]
            nm[k] = ubar / gamma_total
            s32 = self._slots32(state.slots, i)
            s32 = {**s32, **base.refresh_sync_slots(
                s32, anc32, ubar, gamma_total, lo, self.model_axes)}
            if use_anchor:
                # x_{t+1} = x_{t'} - precond(ubar): bitwise identical on
                # all workers (ubar, the anchor, and the slots are
                # replicated).
                nx[k] = (anc32
                         - C.from_view(base.precond(ubar, s32), lo)
                         ).astype(xh[k].dtype)
            else:
                corr = base.precond(uh[k] - ubar, s32)
                nx[k] = (xh[k].astype(jnp.float32)
                         + C.from_view(corr, lo)).astype(xh[k].dtype)
            nu[k] = jnp.zeros_like(uh[k])
            for j, name in enumerate(sync_names):
                nextra[j][k] = s32[name]

        # --- T_u branch: 1-bit sync of the accumulated buffer ----------
        def sync_branch(op):
            xh, mh, uh, ew, es, anc = op[:6]
            extra_in = op[6:]
            nx, nm, nu, nw, ns = list(xh), list(mh), [None] * len(uh), \
                list(ew), list(es)
            na = list(anc)
            nextra = [list(lst) for lst in extra_in]
            if bp is None:
                for k, i in enumerate(dp_idx):
                    lo = self.layouts[i]
                    ubar, ef = AR.onebit_allreduce_view(
                        comm, uh[k], AR.EFState(ew[k], es[k]), lo,
                        self.ar_cfg, vspec=self.vspecs[i],
                        worker_index=worker_index)
                    ubar = ubar.astype(jnp.float32)
                    anc32 = (anc[k].astype(jnp.float32)
                             if use_anchor else None)
                    post_sync_leaf(k, i, ubar, anc32, xh, uh, nm, nx, nu,
                                   nextra)
                    if use_anchor:
                        na[k] = nx[k]
                    nw[k], ns[k] = ef.err_worker, ef.err_server
                return tuple([nx, nm, nu, nw, ns, na] + nextra)
            # bucketed: one overlapped Algorithm-2 exchange per bucket
            zs = [BK.gather_views(b, [uh[dp_pos[i]] for i in b.members])
                  for b in bp.buckets]
            outs, nefs = AR.onebit_allreduce_buckets(
                comm, zs, [AR.EFState(w, s) for w, s in zip(ew, es)],
                [b.layout for b in bp.buckets], self.ar_cfg,
                vspecs=[b.vspec for b in bp.buckets],
                worker_index=worker_index)
            for bi, b in enumerate(bp.buckets):
                mlo = [self.layouts[i] for i in b.members]
                ubars = BK.scatter_views(b, outs[bi].astype(jnp.float32),
                                         mlo)
                ancs = (BK.scatter_views(b, anc[bi], mlo) if use_anchor
                        else [None] * len(b.members))
                new_xv = []
                for ub, av, i, lo in zip(ubars, ancs, b.members, mlo):
                    k = dp_pos[i]
                    anc32 = (C.from_view(av.astype(jnp.float32), lo)
                             if use_anchor else None)
                    post_sync_leaf(k, i, ub.astype(jnp.float32), anc32,
                                   xh, uh, nm, nx, nu, nextra)
                    new_xv.append(C.to_view(nx[k], lo))
                nw[bi], ns[bi] = nefs[bi].err_worker, nefs[bi].err_server
                if use_anchor:
                    na[bi] = BK.gather_views(b, new_xv).astype(
                        anc[bi].dtype)
            return tuple([nx, nm, nu, nw, ns, na] + nextra)

        def local_branch(op):
            return tuple(list(lst) for lst in op)

        if bp is None:
            ew_op = [state.err_w[i] for i in dp_idx]
            es_op = [state.err_s[i] for i in dp_idx]
            anc_op = [state.anchor[i] for i in dp_idx]
        else:  # EF/anchor state is already a per-bucket list
            ew_op, es_op = list(state.err_w), list(state.err_s)
            anc_op = list(state.anchor)
        op = tuple([[x_half[i] for i in dp_idx],
                    [m_half[i] for i in dp_idx],
                    [u_half[i] for i in dp_idx],
                    ew_op, es_op, anc_op]
                   + [[state.slots[name][i].astype(jnp.float32)
                       for i in dp_idx] for name in sync_names])
        res = jax.lax.cond(do_sync, sync_branch, local_branch, op)
        sx, sm, su, sw, ss, sa = res[:6]
        s_extra = res[6:]

        new_x, new_m = list(x_half), list(m_half)
        new_u = list(u_half)
        if bp is None:
            new_ew, new_es = list(state.err_w), list(state.err_s)
            new_anchor = list(state.anchor)
        else:
            new_ew, new_es, new_anchor = list(sw), list(ss), list(sa)
        new_sync_slots = {name: list(state.slots[name])
                          for name in sync_names}
        for k, i in enumerate(dp_idx):
            new_x[i], new_m[i], new_u[i] = sx[k], sm[k], su[k]
            if bp is None:
                new_ew[i], new_es[i] = sw[k], ss[k]
                new_anchor[i] = sa[k]
            for j, name in enumerate(sync_names):
                new_sync_slots[name][i] = s_extra[j][k]

        # --- T_v branch: full-precision variance refresh ----------------
        if base.has_variance:
            def var_branch(vop):
                gbars = self._fullprec_dp(comm, [gv[i] for i in dp_idx])
                return [base.update_variance(v.astype(jnp.float32), gbar)
                        for v, gbar in zip(vop, gbars)]

            def keep_branch(vop):
                return [v.astype(jnp.float32) for v in vop]

            v_dp = jax.lax.cond(do_var, var_branch, keep_branch,
                                [state.slots["v"][i] for i in dp_idx])
            new_v = list(state.slots["v"])
            for k, i in enumerate(dp_idx):
                new_v[i] = v_dp[k].astype(state.slots["v"][i].dtype)
            # non-DP leaves: plain local base step (v every step)
            for i, dp in enumerate(dps):
                if dp:
                    continue
                v32 = state.slots["v"][i].astype(jnp.float32)
                new_v[i] = base.update_variance(v32, gv[i]).astype(
                    state.slots["v"][i].dtype)
        else:
            new_v = None

        new_gamma = jnp.where(do_sync, 0.0, gamma_total)
        sd = cfg.state_dtype
        new_slots = dict(state.slots)
        new_slots["m"] = [m.astype(sd) for m in new_m]
        if new_v is not None:
            new_slots["v"] = new_v
        for name in sync_names:
            new_slots[name] = new_sync_slots[name]
        new_state = CompressedDPState(
            step=t + 1,
            gamma_acc=new_gamma,
            sync_pstate=sync_ps,
            var_pstate=var_ps,
            slots=new_slots,
            u=[u.astype(sd) if u is not None else None for u in new_u],
            err_w=[w.astype(sd) if w is not None else None for w in new_ew],
            err_s=[s.astype(sd) if s is not None else None for s in new_es],
            anchor=new_anchor,
        )
        metrics = {"lr": lr, "synced": do_sync, "var_round": do_var,
                   "interval": interval}
        return jax.tree.unflatten(self.treedef, new_x), new_state, metrics

    # --- gradient / mean: sync the gradient itself every step ----------- #
    def _step_sync(self, comm, params, grads, state, worker_index):
        cfg, base = self.cfg, self.base
        t = state.step
        lr = cfg.lr(t).astype(jnp.float32)

        los, dps = self.layouts, self.dp_mask
        xs, gs = self.flat(params), self.flat(grads)
        gv = [C.constrain(C.to_view(g.astype(jnp.float32), lo), vs) if dp
              else g.astype(jnp.float32)
              for g, lo, dp, vs in zip(gs, los, dps, self.vspecs)]
        dp_idx = [i for i, dp in enumerate(dps) if dp]
        dp_pos = {i: k for k, i in enumerate(dp_idx)}
        bp = self.bucket_plan

        def full(gs_dp):
            return self._fullprec_dp(comm, gs_dp)

        if cfg.style == "gradient":
            if self._use_var_policy:
                do_var, var_ps = cfg.var_policy.step(
                    state.var_pstate, t, jnp.ones((), jnp.int32))
            else:
                do_var, var_ps = jnp.asarray(False), state.var_pstate

            def full_branch(op):
                gs_dp, ew, es = op
                return full(gs_dp), ew, es

            def onebit_branch(op):
                gs_dp, ew, es = op
                if bp is None:
                    outs, news_w, news_s = [], [], []
                    for g, w, s, i in zip(gs_dp, ew, es, dp_idx):
                        o, ef = AR.onebit_allreduce_view(
                            comm, g, AR.EFState(w, s), self.layouts[i],
                            self.ar_cfg, vspec=self.vspecs[i],
                            worker_index=worker_index)
                        outs.append(o.astype(jnp.float32))
                        news_w.append(ef.err_worker)
                        news_s.append(ef.err_server)
                    return outs, news_w, news_s
                # bucketed: one overlapped exchange per bucket
                zs = [BK.gather_views(b, [gs_dp[dp_pos[i]]
                                          for i in b.members])
                      for b in bp.buckets]
                outs_b, nefs = AR.onebit_allreduce_buckets(
                    comm, zs, [AR.EFState(w, s) for w, s in zip(ew, es)],
                    [b.layout for b in bp.buckets], self.ar_cfg,
                    vspecs=[b.vspec for b in bp.buckets],
                    worker_index=worker_index)
                outs = [None] * len(gs_dp)
                for b, o in zip(bp.buckets, outs_b):
                    views = BK.scatter_views(
                        b, o, [self.layouts[i] for i in b.members])
                    for i, v in zip(b.members, views):
                        outs[dp_pos[i]] = v.astype(jnp.float32)
                return (outs, [ef.err_worker for ef in nefs],
                        [ef.err_server for ef in nefs])

            if bp is None:
                ew_op = [state.err_w[i] for i in dp_idx]
                es_op = [state.err_s[i] for i in dp_idx]
            else:
                ew_op, es_op = list(state.err_w), list(state.err_s)
            op = ([gv[i] for i in dp_idx], ew_op, es_op)
            agg_dp, new_ew_dp, new_es_dp = jax.lax.cond(
                do_var, full_branch, onebit_branch, op)
            if bp is None:
                new_ew, new_es = list(state.err_w), list(state.err_s)
                for k, i in enumerate(dp_idx):
                    new_ew[i], new_es[i] = new_ew_dp[k], new_es_dp[k]
            else:
                new_ew, new_es = list(new_ew_dp), list(new_es_dp)
        else:  # mean: uncompressed baseline, no EF state at all
            do_var = jnp.asarray(base.has_variance)
            var_ps = state.var_pstate
            agg_dp = full([gv[i] for i in dp_idx])
            new_ew, new_es = list(state.err_w), list(state.err_s)

        gbar = list(gv)
        for k, i in enumerate(dp_idx):
            gbar[i] = agg_dp[k]

        wd = cfg.weight_decay
        new_x = []
        new_slots = {name: list(vals) for name, vals in state.slots.items()}
        for i, (x, g, lo, dp) in enumerate(zip(xs, gbar, los, dps)):
            s32 = self._slots32(state.slots, i)
            m32 = s32["m"]
            nm = base.beta1 * m32 + (1 - base.beta1) * g
            if base.has_variance:
                v32 = s32["v"]
                if dp and cfg.style == "gradient":
                    nv = jnp.where(do_var, base.update_variance(v32, g), v32)
                else:  # mean style / local leaves: v every step
                    nv = base.update_variance(v32, g)
                new_slots["v"][i] = nv.astype(state.slots["v"][i].dtype)
            x32 = x.astype(jnp.float32)
            if base.has_trust:
                # LAMB: trust ratio from the *unscaled* update so the lr
                # schedule keeps control of the step size
                upd = base.precond_raw(nm, s32)
                upd = C.from_view(upd, lo) if dp else upd
                if wd:
                    upd = upd + wd * x32
                trust = base.trust_ratio(x32, upd, self.model_axes)
                delta = lr * trust * upd
            else:
                delta = base.precond(lr * nm, s32)
                delta = C.from_view(delta, lo) if dp else delta
                if wd:
                    delta = delta + lr * wd * x32
            new_x.append((x32 - delta).astype(x.dtype))
            new_slots["m"][i] = nm.astype(state.slots["m"][i].dtype)

        metrics = {"lr": lr, "synced": jnp.asarray(True),
                   "var_round": do_var,
                   "interval": jnp.ones((), jnp.int32)}
        new_state = CompressedDPState(
            step=t + 1, gamma_acc=state.gamma_acc,
            sync_pstate=state.sync_pstate, var_pstate=var_ps,
            slots=new_slots, u=list(state.u), err_w=new_ew, err_s=new_es,
            anchor=list(state.anchor))
        return jax.tree.unflatten(self.treedef, new_x), new_state, metrics
