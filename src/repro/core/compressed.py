"""``compressed_dp``: compressed data-parallel sync as a composable transform.

The paper's 0/1 Adam recipe — stale-state linearization + error-feedback
1-bit sync + local steps — is not Adam-specific. This module factors the
recipe into a combinator over *base steps* (:mod:`repro.core.base_steps`):

    opt = compressed_dp(adam_base(), lr=..., sync_policy=..., var_policy=...)(
        param_shapes, specs=specs, dp_mask=dp_mask, n_workers=n)
    state = opt.init(params)
    params, state, metrics = opt.step(comm, params, grads, state)

Every bound optimizer implements the same **GradientTransform protocol**
(``init`` / ``step`` written per worker, exactly like the legacy classes),
so trainers, checkpointing, and the benchmarks are base-agnostic.

Three sync styles, all owning the same layouts / EF state / hierarchy:

* ``"accumulate"`` — paper Algorithm 1 generalized: local linearized
  half-steps accumulate ``u``; on T_u steps ``u`` is 1-bit AllReduced
  (Algorithm 2) and parameters re-anchor; on T_v steps the variance is
  refreshed from a full-precision gradient mean. With ``adam_base`` this is
  bitwise-identical to the legacy ``ZeroOneAdam`` (asserted in
  tests/test_composed_equivalence.py); with ``lamb_base`` / ``momentum_sgd_base``
  it yields 0/1-LAMB and 0/1-SGD.
* ``"gradient"`` — the 1-bit Adam two-stage schedule (Algorithm 4):
  full-precision gradient AllReduce while ``var_policy`` fires (the warmup
  stage), EF-1-bit gradient AllReduce with frozen variance afterwards.
  Bitwise-identical to the legacy ``OneBitAdam`` with
  ``var_policy=FixedWarmupPolicy(onebit_warmup)`` at ``weight_decay=0``
  (the legacy class never applied decay; this style does).
* ``"mean"`` — the uncompressed baseline: full-precision gradient mean every
  step, variance every step. ``compressed_dp(adam_base(), style="mean")``
  is distributed Adam; with the other bases, distributed LAMB /
  momentum-SGD.

State is carried per leaf in comm-view shape for DP leaves (natural shape
for ``dp_mask=False`` leaves, which take plain local base steps). The
``slots`` dict holds whatever the base declares ("m", optionally "v",
optionally per-leaf "trust" scalars), so one state type serves every base.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import bucketing as BK
from repro.core import codecs as CODECS
from repro.core import compressor as C
from repro.core import leafwise
from repro.core import onebit_allreduce as AR
from repro.core import schedules as S
from repro.core.comm import Comm, Hierarchy

STYLES = ("accumulate", "gradient", "mean")


class CompressedDPState(NamedTuple):
    step: jnp.ndarray
    gamma_acc: jnp.ndarray    # sum of gamma since the last sync (accumulate)
    sync_pstate: tuple        # T_u policy carried state (accumulate)
    var_pstate: tuple         # T_v policy carried state
    slots: Dict[str, list]    # base slots: "m" (+"v", +"trust"), per leaf
    u: list                   # accumulated update views (accumulate style)
    err_w: list               # worker-side EF (layout.ef_worker_shape)
    err_s: list               # server-side EF (chunk shape)
    anchor: list              # x_{t'} copies (accumulate + store_anchor)

    # Convenience accessors so slot-based state reads like the legacy one.
    @property
    def m(self):
        return self.slots["m"]

    @property
    def v(self):
        return self.slots.get("v")


@dataclasses.dataclass(frozen=True)
class StateKind:
    """Tag describing one optimizer-state leaf, for generic sharding-spec /
    abstract-shape derivation (see train/sharding.py).

    tags: ``scalar`` (replicated scalar), ``view`` (comm view for DP leaves,
    natural for non-DP), ``chunk`` (server chunk, DP only), ``natural``
    (param-shaped, DP only — anchors), ``leaf_scalar`` (per-worker scalar,
    DP only — trust ratios). ``leaf`` indexes the flat param leaf.

    With a bucketed exchange (``bucket_mb`` set) the EF/anchor state lives
    per *bucket* instead of per leaf: ``bucket_view`` / ``bucket_chunk``
    mirror ``view`` / ``chunk`` with ``leaf`` indexing
    ``opt.bucket_plan.buckets`` (always DP — buckets only cover DP
    leaves)."""

    tag: str
    leaf: Optional[int] = None

    @property
    def bucketed(self) -> bool:
        return self.tag in ("bucket_view", "bucket_chunk")


_SCALAR = StateKind("scalar")


class _ExchangeUnit(NamedTuple):
    """One unit of the per-unit issue schedule: a bucket, or a single DP
    leaf when bucketing is off. Each unit's exchange (T_u sync, 1-bit
    gradient, and full-precision T_v alike) is issued under its own
    ``lax.cond`` whose operands are only the unit's member leaves and its
    EF/anchor state — so the collective depends on nothing but those
    leaves' gradients, and XLA's latency-hiding scheduler can start it
    while the rest of the backward/accumulation compute is still running.

    ``state_idx`` indexes the per-leaf EF/anchor lists when bucketing is
    off (flat leaf index) and ``bucket_plan.buckets`` otherwise;
    ``members`` are flat leaf indices in unit-buffer order."""

    state_idx: int
    members: tuple
    layout: Any
    vspec: Any
    bucket: Any               # bucketing.Bucket | None (per-leaf unit)


@dataclasses.dataclass(frozen=True)
class CompressedDP:
    """Unbound transform: a base step plus the distributed-sync policy.

    Calling it on a parameter tree returns the bound
    :class:`ComposedOptimizer` (the GradientTransform). Field defaults are
    the paper's production values, mirroring ``OptimizerConfig``.
    """

    base: Any
    style: str = "accumulate"
    lr: Callable = S.ConstantLr(1e-3)
    sync_policy: Any = S.LrProportionalSyncPolicy(
        warmup_steps=12500, double_every=32768, max_interval=16)
    var_policy: Any = S.AdaptiveFreezePolicy(kappa=16)
    weight_decay: float = 0.0
    scale_mode: C.ScaleMode = "tensor"
    quantize: bool = True               # deprecated: False -> codec="identity"
    codec: Any = "sign1bit"             # wire format of the EF exchange —
                                        # a registry name (codecs.CODEC_NAMES)
                                        # or a Codec instance
    codec_arg: Optional[float] = None   # parameter for parameterized codecs
                                        # (topk density)
    store_anchor: bool = True
    comm_dtype: Any = jnp.bfloat16
    state_dtype: Any = jnp.float32
    use_pallas: bool = False
    hierarchy: Optional[Hierarchy] = None
    bucket_mb: Optional[float] = None   # fuse the per-leaf exchange into
                                        # fixed-budget flat buckets (MiB of
                                        # f32 elements per bucket; see
                                        # repro.core.bucketing). None keeps
                                        # the historical per-leaf exchange.
    pack_order: str = "flat"            # exchange-unit packing/issue order
                                        # (bucketing.PACK_ORDERS):
                                        # "reverse_backward" issues units in
                                        # reverse flat-leaf order ≈ backward
                                        # readiness order, so early units'
                                        # exchanges overlap the tail of the
                                        # backward pass.

    def __post_init__(self):
        if self.style not in STYLES:
            raise ValueError(f"style={self.style!r}; choose from {STYLES}")
        if self.bucket_mb is not None and self.bucket_mb <= 0:
            raise ValueError(
                f"bucket_mb must be positive (MiB per fused bucket), got "
                f"{self.bucket_mb!r}")
        if self.pack_order not in BK.PACK_ORDERS:
            raise ValueError(
                f"pack_order must be one of {BK.PACK_ORDERS}, got "
                f"{self.pack_order!r}")
        C.validate_scale_mode(self.scale_mode)
        codec = self.codec
        if not self.quantize:
            warnings.warn(
                "quantize=False is deprecated; use codec=\"identity\" "
                "instead (the exact-mean exchange is now the identity "
                "codec — see repro.core.codecs)", DeprecationWarning,
                stacklevel=3)
        # precedence (shared with OneBitConfig via
        # codecs.resolve_with_quantize, so the legacy and composed paths
        # can never disagree): the deprecated knob forces identity unless
        # a NON-default codec is set — an explicit "sign1bit", name or
        # instance, is indistinguishable from the default and is
        # rewritten; any other explicit codec wins.
        codec = CODECS.resolve_with_quantize(codec, self.quantize)
        # resolve once, at config-build time: a bad codec name / codec_arg
        # fails here with the registry listed, not deep inside the exchange
        object.__setattr__(self, "codec",
                           CODECS.make_codec(codec, self.codec_arg))
        if (self.style == "accumulate" and self.base.needs_anchor
                and not self.store_anchor):
            raise ValueError(
                f"{type(self.base).__name__} refreshes slots at syncs and "
                f"therefore requires store_anchor=True in the accumulate "
                f"style (the anchor recovery path assumes a fixed "
                f"preconditioner between syncs)")
        if self.style == "accumulate" and self.weight_decay:
            raise ValueError(
                "weight_decay is not supported in the accumulate style: a "
                "decay term makes the local step affine in x, breaking the "
                "u-linearization that lets syncs exchange the accumulated "
                "buffer (x_{t+1/2} = x_{t'} - precond(u) no longer holds). "
                "Use decoupled decay outside the optimizer, or the "
                "gradient/mean styles.")

    def __call__(self, param_shapes, *, specs=None, dp_mask=None,
                 n_workers: int, model_axis_sizes=None):
        return ComposedOptimizer(self, param_shapes, specs, dp_mask,
                                 n_workers, model_axis_sizes)


def compressed_dp(base, **kwargs) -> CompressedDP:
    """Compose a base step with the compressed-DP sync machinery."""
    return CompressedDP(base=base, **kwargs)


class ComposedOptimizer:
    """``compressed_dp(...)`` bound to a parameter tree (GradientTransform)."""

    def __init__(self, cfg: CompressedDP, param_shapes, specs, dp_mask,
                 n_workers, model_axis_sizes=None):
        self.cfg = cfg
        self.base = cfg.base
        plan = leafwise.make_plan(param_shapes, specs, dp_mask, n_workers,
                                  model_axis_sizes, cfg.hierarchy)
        self.plan = plan
        self.n = plan.n
        self.hierarchy = plan.hierarchy
        self.model_axes = plan.model_axes
        self.treedef = plan.treedef
        self.specs = plan.specs
        self.dp_mask = plan.dp_mask
        self.layouts = plan.layouts
        self.vspecs = plan.vspecs
        self.ar_cfg = leafwise.make_ar_cfg(
            plan, scale_mode=cfg.scale_mode, quantize=cfg.quantize,
            codec=cfg.codec, use_pallas=cfg.use_pallas,
            comm_dtype=cfg.comm_dtype)
        self.codec = self.ar_cfg.codec
        # Bucketed exchange: EF state / anchors / codec payloads /
        # collectives operate per bucket (repro.core.bucketing) instead of
        # per leaf. None keeps the historical per-leaf exchange.
        self.bucket_plan = (BK.make_bucket_plan(plan, cfg.bucket_mb,
                                                self.vspecs, cfg.pack_order)
                            if cfg.bucket_mb is not None else None)
        if self.bucket_plan is not None:
            self.units = tuple(
                _ExchangeUnit(bi, b.members, b.layout, b.vspec, b)
                for bi, b in enumerate(self.bucket_plan.buckets))
        else:
            idx = [i for i, dp in enumerate(plan.dp_mask) if dp]
            if cfg.pack_order == "reverse_backward":
                idx = idx[::-1]
            self.units = tuple(
                _ExchangeUnit(i, (i,), plan.layouts[i], plan.vspecs[i],
                              None)
                for i in idx)
        self._slot_specs = self.base.slot_specs()
        self._use_sync_policy = cfg.style == "accumulate"
        self._use_var_policy = (cfg.style in ("accumulate", "gradient")
                                and self.base.has_variance)
        self._has_u = cfg.style == "accumulate"
        self._has_ef = cfg.style in ("accumulate", "gradient")
        self._has_anchor = self._has_u and cfg.store_anchor

    def flat(self, tree):
        return self.treedef.flatten_up_to(tree)

    def exchange_units(self):
        """``(layout, vspec, label)`` per exchange unit, in issue order —
        the single source the audit / accounting layers use so the
        declared schedule can never drift from the step's issue loop."""
        return BK.exchange_units(self.plan, self.bucket_plan,
                                 self.cfg.pack_order)

    # ------------------------------------------------------------------ #
    # state
    # ------------------------------------------------------------------ #
    def init(self, params) -> CompressedDPState:
        cfg = self.cfg
        sd = cfg.state_dtype
        los, dps = self.layouts, self.dp_mask
        ps = self.flat(params)

        def slot(skind, init_val, p, lo, dp):
            if skind == "scalar":
                return (jnp.full((), init_val, jnp.float32) if dp else None)
            return jnp.full(lo.view_shape if dp else p.shape, init_val, sd)

        slots = {name: [slot(sk, iv, p, lo, dp)
                        for p, lo, dp in zip(ps, los, dps)]
                 for name, (sk, iv) in self._slot_specs.items()}
        bp = self.bucket_plan
        if bp is None:
            err_w = [jnp.zeros(lo.ef_worker_shape, sd)
                     if (dp and self._has_ef) else None
                     for lo, dp in zip(los, dps)]
            err_s = [jnp.zeros(lo.chunk_shape, sd)
                     if (dp and self._has_ef) else None
                     for lo, dp in zip(los, dps)]
            anchor = [(p * 1.0).astype(p.dtype)
                      if (dp and self._has_anchor) else None
                      for p, dp in zip(ps, dps)]
        else:
            # per-bucket EF / anchors: the bucket buffer is what the codec
            # compresses, so its error state (and the re-anchored params)
            # live in bucket shape
            err_w = [jnp.zeros(b.layout.ef_worker_shape, sd)
                     if self._has_ef else None for b in bp.buckets]
            err_s = [jnp.zeros(b.layout.chunk_shape, sd)
                     if self._has_ef else None for b in bp.buckets]
            anchor = [self._gather_bucket(
                          b, [(ps[i] * 1.0).astype(ps[i].dtype)
                              for i in b.members])
                      if self._has_anchor else None for b in bp.buckets]
        return CompressedDPState(
            step=jnp.zeros((), jnp.int32),
            gamma_acc=jnp.zeros((), jnp.float32),
            sync_pstate=(cfg.sync_policy.init()
                         if self._use_sync_policy else ()),
            var_pstate=(cfg.var_policy.init()
                        if self._use_var_policy else ()),
            slots=slots,
            u=[jnp.zeros(lo.view_shape, sd) if (dp and self._has_u) else None
               for lo, dp in zip(los, dps)],
            err_w=err_w,
            err_s=err_s,
            anchor=anchor,
        )

    def _gather_bucket(self, bucket, leaves_nat):
        """Natural member leaves -> bucket buffer (via their comm views)."""
        views = [C.to_view(x, self.layouts[i])
                 for x, i in zip(leaves_nat, bucket.members)]
        return BK.gather_views(bucket, views)

    def state_kinds(self) -> CompressedDPState:
        """Pytree mirroring the state treedef with :class:`StateKind`
        leaves (same ``None`` placements as :meth:`init`)."""
        cfg = self.cfg
        dps = self.dp_mask
        slots = {}
        for name, (sk, _) in self._slot_specs.items():
            if sk == "scalar":
                slots[name] = [StateKind("leaf_scalar", i) if dp else None
                               for i, dp in enumerate(dps)]
            else:
                slots[name] = [StateKind("view", i)
                               for i in range(len(dps))]
        bp = self.bucket_plan
        if bp is None:
            err_w = [StateKind("view", i) if (dp and self._has_ef) else None
                     for i, dp in enumerate(dps)]
            err_s = [StateKind("chunk", i) if (dp and self._has_ef) else None
                     for i, dp in enumerate(dps)]
            anchor = [StateKind("natural", i)
                      if (dp and self._has_anchor) else None
                      for i, dp in enumerate(dps)]
        else:
            err_w = [StateKind("bucket_view", bi) if self._has_ef else None
                     for bi in range(len(bp.buckets))]
            err_s = [StateKind("bucket_chunk", bi) if self._has_ef else None
                     for bi in range(len(bp.buckets))]
            anchor = [StateKind("bucket_view", bi)
                      if self._has_anchor else None
                      for bi in range(len(bp.buckets))]
        return CompressedDPState(
            step=_SCALAR, gamma_acc=_SCALAR,
            sync_pstate=tuple(_SCALAR for _ in (
                cfg.sync_policy.init() if self._use_sync_policy else ())),
            var_pstate=tuple(_SCALAR for _ in (
                cfg.var_policy.init() if self._use_var_policy else ())),
            slots=slots,
            u=[StateKind("view", i) if (dp and self._has_u) else None
               for i, dp in enumerate(dps)],
            err_w=err_w,
            err_s=err_s,
            anchor=anchor,
        )

    def _slots32(self, slots, i):
        return {name: (slots[name][i].astype(jnp.float32)
                       if slots[name][i] is not None else None)
                for name in slots}

    def _unit_gather(self, unit, views):
        """Member comm views -> the unit's exchange buffer."""
        if unit.bucket is None:
            (v,) = views
            return v
        return BK.gather_views(unit.bucket, views)

    def _unit_scatter(self, unit, buf):
        """Unit exchange buffer -> member comm views (inverse of
        :meth:`_unit_gather` on the true elements)."""
        if unit.bucket is None:
            return [buf]
        return BK.scatter_views(unit.bucket, buf,
                                [self.layouts[i] for i in unit.members])

    def _fullprec_unit(self, comm, unit, bufs):
        """Full-precision mean of ONE exchange unit's member view buffers
        (the T_v / mean-round transport). Elementwise, so fusing members
        into a bucket is value-preserving per element."""
        z = self._unit_gather(unit, bufs)
        o = AR.fullprec_allreduce_view(
            comm, z, self.cfg.comm_dtype, vspec=unit.vspec,
            hierarchy=self.hierarchy, layout=unit.layout)
        return self._unit_scatter(unit, o)

    def _fullprec_dp(self, comm, bufs_dp):
        """Full-precision mean of the DP leaves' view buffers, one
        collective pair per exchange unit (leaf, or bucket when bucketing
        is on), issued in unit order."""
        dp_idx = [i for i, dp in enumerate(self.dp_mask) if dp]
        dp_pos = {i: k for k, i in enumerate(dp_idx)}
        out = [None] * len(bufs_dp)
        for unit in self.units:
            res = self._fullprec_unit(
                comm, unit, [bufs_dp[dp_pos[i]] for i in unit.members])
            for i, v in zip(unit.members, res):
                out[dp_pos[i]] = v
        return out

    # ------------------------------------------------------------------ #
    # step
    # ------------------------------------------------------------------ #
    def step(self, comm: Comm, params, grads, state: CompressedDPState,
             worker_index=None):
        if self.cfg.style == "accumulate":
            return self._step_accumulate(comm, params, grads, state,
                                         worker_index)
        return self._step_sync(comm, params, grads, state, worker_index)

    # --- accumulate: paper Algorithm 1, generalized over bases ---------- #
    def _step_accumulate(self, comm, params, grads, state, worker_index):
        cfg, base = self.cfg, self.base
        t = state.step
        lr = cfg.lr(t).astype(jnp.float32)

        do_sync, sync_ps, interval = cfg.sync_policy.step(state.sync_pstate,
                                                          t)
        if self._use_var_policy:
            do_var, var_ps = cfg.var_policy.step(state.var_pstate, t,
                                                 interval)
        else:
            do_var, var_ps = jnp.asarray(False), state.var_pstate

        los, dps = self.layouts, self.dp_mask
        xs, gs = self.flat(params), self.flat(grads)
        gv = [C.constrain(C.to_view(g.astype(jnp.float32), lo), vs) if dp
              else g.astype(jnp.float32)
              for g, lo, dp, vs in zip(gs, los, dps, self.vspecs)]
        gamma_total = state.gamma_acc + lr     # sum of gamma over [t', t]

        # --- local half-step for every leaf ----------------------------
        # DP leaves with use_pallas route the elementwise chain through the
        # fused kernel (keyed on the base kind); the unfused jnp chain is
        # f32-identical.
        if cfg.use_pallas:
            from repro.kernels import dispatch as K
        x_half, m_half, u_half = [], [], []
        for i, (x, g, lo, dp, vs) in enumerate(zip(xs, gv, los, dps,
                                                   self.vspecs)):
            s32 = self._slots32(state.slots, i)
            m32 = s32["m"]
            u = state.u[i]
            if dp and cfg.use_pallas and K.kernel_safe(
                    vs, lo, self.ar_cfg.model_axes):
                mh, u_new, delta = K.fused_local_step_view(
                    g, m32, u.astype(jnp.float32), s32.get("v"), lr,
                    base.beta1, getattr(base, "eps", 0.0), lo,
                    kind=base.kind, vspec=vs)
                if base.has_trust:
                    delta = s32["trust"] * delta
                delta_nat = C.from_view(delta, lo)
            else:
                mh = base.beta1 * m32 + (1 - base.beta1) * g
                if not dp and base.has_trust:
                    # non-DP leaves never sync: plain local base step with a
                    # per-step trust ratio (ordinary LAMB behaviour)
                    upd = base.precond_raw(mh, s32)
                    trust = base.trust_ratio(x.astype(jnp.float32), upd,
                                             self.model_axes)
                    delta = lr * trust * upd
                else:
                    delta = base.precond(lr * mh, s32)
                delta_nat = C.from_view(delta, lo) if dp else delta
                u_new = (u.astype(jnp.float32) + lr * mh) if dp else None
            x_half.append((x.astype(jnp.float32) - delta_nat).astype(x.dtype))
            m_half.append(mh)
            u_half.append(u_new)

        use_anchor = cfg.store_anchor
        sync_names = tuple(base.sync_slot_names)

        def post_sync_leaf(i, ubar, anc32, xh_i, uh_i):
            """Per-leaf post-exchange update shared by per-leaf and
            bucketed units: momentum refresh, slot refresh, the
            re-anchored (or corrected) parameter, u reset. Returns
            ``(nx, nm, nu, extras)``."""
            lo = self.layouts[i]
            nm = ubar / gamma_total
            s32 = self._slots32(state.slots, i)
            s32 = {**s32, **base.refresh_sync_slots(
                s32, anc32, ubar, gamma_total, lo, self.model_axes)}
            if use_anchor:
                # x_{t+1} = x_{t'} - precond(ubar): bitwise identical on
                # all workers (ubar, the anchor, and the slots are
                # replicated).
                nx = (anc32
                      - C.from_view(base.precond(ubar, s32), lo)
                      ).astype(xh_i.dtype)
            else:
                corr = base.precond(uh_i - ubar, s32)
                nx = (xh_i.astype(jnp.float32)
                      + C.from_view(corr, lo)).astype(xh_i.dtype)
            nu = jnp.zeros_like(uh_i)
            return nx, nm, nu, tuple(s32[name] for name in sync_names)

        # --- T_u: ONE Algorithm-2 exchange per unit, each under its own
        # cond whose operands are only that unit's member leaves + its
        # EF/anchor state. The exchange's collectives therefore depend on
        # nothing but those leaves' accumulated gradients, so with the
        # peeled last microbatch (train/step.py) XLA can issue unit k's
        # collective while later units' member gradients are still being
        # computed. Per-unit math is identical to the old monolithic
        # branch — bitwise, pinned by the golden-trajectory suite.
        def unit_sync_cond(unit):
            si = unit.state_idx
            op = (tuple(x_half[i] for i in unit.members),
                  tuple(m_half[i] for i in unit.members),
                  tuple(u_half[i] for i in unit.members),
                  state.err_w[si], state.err_s[si], state.anchor[si],
                  tuple(tuple(state.slots[name][i].astype(jnp.float32)
                              for name in sync_names)
                        for i in unit.members))

            def sync_b(op):
                xh_m, mh_m, uh_m, ew, es, anc, _ = op
                z = self._unit_gather(unit, list(uh_m))
                ubar_u, ef = AR.onebit_allreduce_view(
                    comm, z, AR.EFState(ew, es), unit.layout, self.ar_cfg,
                    vspec=unit.vspec, worker_index=worker_index)
                ubars = self._unit_scatter(unit,
                                           ubar_u.astype(jnp.float32))
                if not use_anchor:
                    anc32s = [None] * len(unit.members)
                elif unit.bucket is None:
                    anc32s = [anc.astype(jnp.float32)]
                else:
                    anc32s = [C.from_view(av.astype(jnp.float32),
                                          self.layouts[i])
                              for av, i in zip(self._unit_scatter(unit,
                                                                  anc),
                                               unit.members)]
                nx_m, nm_m, nu_m, nex_m = [], [], [], []
                for k, i in enumerate(unit.members):
                    nx, nm, nu, nex = post_sync_leaf(
                        i, ubars[k].astype(jnp.float32), anc32s[k],
                        xh_m[k], uh_m[k])
                    nx_m.append(nx)
                    nm_m.append(nm)
                    nu_m.append(nu)
                    nex_m.append(nex)
                if not use_anchor:
                    na = anc
                elif unit.bucket is None:
                    na = nx_m[0]
                else:
                    na = self._unit_gather(
                        unit, [C.to_view(nx, self.layouts[i])
                               for nx, i in zip(nx_m, unit.members)]
                        ).astype(anc.dtype)
                return (tuple(nx_m), tuple(nm_m), tuple(nu_m),
                        ef.err_worker, ef.err_server, na, tuple(nex_m))

            def keep_b(op):
                return op

            return jax.lax.cond(do_sync, sync_b, keep_b, op)

        new_x, new_m = list(x_half), list(m_half)
        new_u = list(u_half)
        new_ew, new_es = list(state.err_w), list(state.err_s)
        new_anchor = list(state.anchor)
        new_sync_slots = {name: list(state.slots[name])
                          for name in sync_names}
        for unit in self.units:
            nx_m, nm_m, nu_m, nw, ns, na, nex_m = unit_sync_cond(unit)
            for k, i in enumerate(unit.members):
                new_x[i], new_m[i], new_u[i] = nx_m[k], nm_m[k], nu_m[k]
                for j, name in enumerate(sync_names):
                    new_sync_slots[name][i] = nex_m[k][j]
            new_ew[unit.state_idx] = nw
            new_es[unit.state_idx] = ns
            new_anchor[unit.state_idx] = na

        # --- T_v: full-precision variance refresh, also per unit -------
        if base.has_variance:
            def unit_var_cond(unit):
                def var_b(vs_m):
                    gbars = self._fullprec_unit(
                        comm, unit, [gv[i] for i in unit.members])
                    return tuple(
                        base.update_variance(v.astype(jnp.float32), gb)
                        for v, gb in zip(vs_m, gbars))

                def keep_b(vs_m):
                    return tuple(v.astype(jnp.float32) for v in vs_m)

                return jax.lax.cond(
                    do_var, var_b, keep_b,
                    tuple(state.slots["v"][i] for i in unit.members))

            new_v = list(state.slots["v"])
            for unit in self.units:
                nv_m = unit_var_cond(unit)
                for k, i in enumerate(unit.members):
                    new_v[i] = nv_m[k].astype(state.slots["v"][i].dtype)
            # non-DP leaves: plain local base step (v every step)
            for i, dp in enumerate(dps):
                if dp:
                    continue
                v32 = state.slots["v"][i].astype(jnp.float32)
                new_v[i] = base.update_variance(v32, gv[i]).astype(
                    state.slots["v"][i].dtype)
        else:
            new_v = None

        new_gamma = jnp.where(do_sync, 0.0, gamma_total)
        sd = cfg.state_dtype
        new_slots = dict(state.slots)
        new_slots["m"] = [m.astype(sd) for m in new_m]
        if new_v is not None:
            new_slots["v"] = new_v
        for name in sync_names:
            new_slots[name] = new_sync_slots[name]
        new_state = CompressedDPState(
            step=t + 1,
            gamma_acc=new_gamma,
            sync_pstate=sync_ps,
            var_pstate=var_ps,
            slots=new_slots,
            u=[u.astype(sd) if u is not None else None for u in new_u],
            err_w=[w.astype(sd) if w is not None else None for w in new_ew],
            err_s=[s.astype(sd) if s is not None else None for s in new_es],
            anchor=new_anchor,
        )
        metrics = {"lr": lr, "synced": do_sync, "var_round": do_var,
                   "interval": interval}
        return jax.tree.unflatten(self.treedef, new_x), new_state, metrics

    # --- gradient / mean: sync the gradient itself every step ----------- #
    def _step_sync(self, comm, params, grads, state, worker_index):
        cfg, base = self.cfg, self.base
        t = state.step
        lr = cfg.lr(t).astype(jnp.float32)

        los, dps = self.layouts, self.dp_mask
        xs, gs = self.flat(params), self.flat(grads)
        gv = [C.constrain(C.to_view(g.astype(jnp.float32), lo), vs) if dp
              else g.astype(jnp.float32)
              for g, lo, dp, vs in zip(gs, los, dps, self.vspecs)]
        dp_idx = [i for i, dp in enumerate(dps) if dp]

        if cfg.style == "gradient":
            if self._use_var_policy:
                do_var, var_ps = cfg.var_policy.step(
                    state.var_pstate, t, jnp.ones((), jnp.int32))
            else:
                do_var, var_ps = jnp.asarray(False), state.var_pstate

            # One cond per exchange unit (see _step_accumulate): the
            # warmup round's full-precision exchange and the 1-bit round
            # both issue unit-by-unit, each depending only on that unit's
            # member gradients.
            def unit_grad_cond(unit):
                si = unit.state_idx
                op = (tuple(gv[i] for i in unit.members),
                      state.err_w[si], state.err_s[si])

                def full_b(op):
                    gs_m, ew, es = op
                    outs = self._fullprec_unit(comm, unit, list(gs_m))
                    return (tuple(o.astype(jnp.float32) for o in outs),
                            ew, es)

                def onebit_b(op):
                    gs_m, ew, es = op
                    z = self._unit_gather(unit, list(gs_m))
                    o, ef = AR.onebit_allreduce_view(
                        comm, z, AR.EFState(ew, es), unit.layout,
                        self.ar_cfg, vspec=unit.vspec,
                        worker_index=worker_index)
                    outs = self._unit_scatter(unit, o)
                    return (tuple(v.astype(jnp.float32) for v in outs),
                            ef.err_worker, ef.err_server)

                return jax.lax.cond(do_var, full_b, onebit_b, op)

            gbar = list(gv)
            new_ew, new_es = list(state.err_w), list(state.err_s)
            for unit in self.units:
                outs_m, nw, ns = unit_grad_cond(unit)
                for k, i in enumerate(unit.members):
                    gbar[i] = outs_m[k]
                new_ew[unit.state_idx] = nw
                new_es[unit.state_idx] = ns
        else:  # mean: uncompressed baseline, no EF state at all
            do_var = jnp.asarray(base.has_variance)
            var_ps = state.var_pstate
            agg_dp = self._fullprec_dp(comm, [gv[i] for i in dp_idx])
            new_ew, new_es = list(state.err_w), list(state.err_s)
            gbar = list(gv)
            for k, i in enumerate(dp_idx):
                gbar[i] = agg_dp[k]

        wd = cfg.weight_decay
        new_x = []
        new_slots = {name: list(vals) for name, vals in state.slots.items()}
        for i, (x, g, lo, dp) in enumerate(zip(xs, gbar, los, dps)):
            s32 = self._slots32(state.slots, i)
            m32 = s32["m"]
            nm = base.beta1 * m32 + (1 - base.beta1) * g
            if base.has_variance:
                v32 = s32["v"]
                if dp and cfg.style == "gradient":
                    nv = jnp.where(do_var, base.update_variance(v32, g), v32)
                else:  # mean style / local leaves: v every step
                    nv = base.update_variance(v32, g)
                new_slots["v"][i] = nv.astype(state.slots["v"][i].dtype)
            x32 = x.astype(jnp.float32)
            if base.has_trust:
                # LAMB: trust ratio from the *unscaled* update so the lr
                # schedule keeps control of the step size
                upd = base.precond_raw(nm, s32)
                upd = C.from_view(upd, lo) if dp else upd
                if wd:
                    upd = upd + wd * x32
                trust = base.trust_ratio(x32, upd, self.model_axes)
                delta = lr * trust * upd
            else:
                delta = base.precond(lr * nm, s32)
                delta = C.from_view(delta, lo) if dp else delta
                if wd:
                    delta = delta + lr * wd * x32
            new_x.append((x32 - delta).astype(x.dtype))
            new_slots["m"][i] = nm.astype(state.slots["m"][i].dtype)

        metrics = {"lr": lr, "synced": jnp.asarray(True),
                   "var_round": do_var,
                   "interval": jnp.ones((), jnp.int32)}
        new_state = CompressedDPState(
            step=t + 1, gamma_acc=state.gamma_acc,
            sync_pstate=state.sync_pstate, var_pstate=var_ps,
            slots=new_slots, u=list(state.u), err_w=new_ew, err_s=new_es,
            anchor=list(state.anchor))
        return jax.tree.unflatten(self.treedef, new_x), new_state, metrics
