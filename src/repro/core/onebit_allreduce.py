"""Error-feedback compressed AllReduce (paper Algorithm 2), TPU-native.

DeepSpeed implements Algorithm 2 as a custom two-phase NCCL/Gloo collective.
The TPU-idiomatic equivalent used here is a chunked scatter-reduce /
all-gather over the mesh worker axes, exchanging codec *payloads* (pytrees
of arrays — bit-packed uint8 for the default sign-1-bit codec):

  worker side   z = u + δ_w ;  (payload, δ_w') = codec.encode_worker(z)
  scatter       all_to_all of payload leaves: worker j receives every
                worker's chunk j                  — "send to server"
  server side   avg = mean_i decode(payload_i) ;  y = avg + δ_s ;
                (payload', δ_s') = codec.encode_server(y)
  gather        all_gather of the compressed chunk results — "broadcast"

With the default ``sign1bit`` codec per-worker traffic is ≈ d/8 + d/8
bytes versus 4·d for a bf16 ring AllReduce: the 32× volume reduction of
the paper, visible verbatim in the lowered HLO as uint8 collectives (this
is what the roofline's collective term reads). Other codecs
(:mod:`repro.core.codecs`: top-k, qint8/qint4, identity) trade volume for
fidelity on the same schedule; ``codec.wire_bytes`` keeps the accounting
honest per format.

All chunk bookkeeping is static (see ``compressor.make_layout``); every op
other than the two collectives is chip-local.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import codecs as CODECS
from repro.core import compressor as C
from repro.core.codecs import _server_compress  # noqa: F401 (moved to
                                                # codecs with the sign1bit
                                                # codec; alias kept for the
                                                # kernel-parity tests)
from repro.core.comm import Comm, Hierarchy


class EFState(NamedTuple):
    """Per-leaf error-feedback state for the compressed level.

    Both errors live at the level that quantizes: with a flat topology the
    worker error covers the whole comm view; with a hierarchy it covers the
    inner reduce-scatter slice this worker owns (the only buffer it ever
    compresses), and the server error the single outer chunk this pod
    serves. The uncompressed intra-pod exchanges carry no error feedback —
    they are exact up to the wire dtype.
    """

    err_worker: jnp.ndarray   # layout.ef_worker_shape (n_outer, A/n, *rest)
    err_server: jnp.ndarray   # chunk shape (A/n, *rest)


def init_ef_state(layout: C.LeafLayout, dtype=jnp.float32) -> EFState:
    return EFState(
        err_worker=jnp.zeros(layout.ef_worker_shape, dtype),
        err_server=jnp.zeros(layout.chunk_shape, dtype),
    )


@dataclasses.dataclass(frozen=True)
class OneBitConfig:
    scale_mode: C.ScaleMode = "tensor"   # paper-faithful default
    compute_dtype: jnp.dtype = jnp.float32
    quantize: bool = True                # deprecated alias: False forces the
                                         # identity codec (exact chunked mean)
    codec: Any = None                    # Codec instance or registry name;
                                         # None -> "sign1bit" (resolved at
                                         # construction, see __post_init__)
    model_axes: tuple = ()               # manual tensor-parallel axes when the
                                         # optimizer runs fully-manual (scales
                                         # psum over these)
    use_pallas: bool = False             # route EF-compress/decompress through
                                         # the fused kernels (repro.kernels);
                                         # only effective when codec.has_pallas
    hierarchy: Optional[Hierarchy] = None  # two-level topology: reduce
                                         # uncompressed over hierarchy.inner_axes,
                                         # compress only over outer_axes
    comm_dtype: jnp.dtype = jnp.bfloat16  # wire dtype of the uncompressed
                                         # intra-pod phases (hierarchy only)

    def __post_init__(self):
        C.validate_scale_mode(self.scale_mode)
        # quantize=False back-compat precedence lives in ONE place
        # (codecs.resolve_with_quantize), shared with CompressedDP so the
        # legacy and composed paths can never disagree
        codec = CODECS.resolve_with_quantize(self.codec, self.quantize)
        object.__setattr__(self, "codec", CODECS.make_codec(codec))


def _use_kernels(cfg: OneBitConfig, vspec, layout=None) -> bool:
    if not cfg.use_pallas:
        return False
    from repro.kernels import dispatch as K
    return K.kernel_codec(cfg.codec) and K.kernel_safe(vspec, layout,
                                                       cfg.model_axes)


def _flat_worker_encode(z_view, ef: EFState, layout, cfg, vspec):
    """Flat worker phase: codec encode of this worker's full view.

    Returns ``(payload, err_w, mask, use_k)`` — the mask and kernel flag
    are reused by the server phase so both phases agree on dispatch.
    """
    codec = cfg.codec
    cst = lambda x: C.constrain(x, vspec)
    mask = (C.pad_mask(layout, dtype=z_view.dtype)
            if codec.needs_ef else None)
    # Kernel dispatch: only codecs with fused kernels (sign1bit).
    # Model-sharded views run the kernels per shard under the manual
    # shard_map partitioning rule (dispatch.shard_context) when one
    # applies; otherwise dispatch.kernel_safe keeps them on the
    # constrained jnp path. The sign1bit server side of row-granularity
    # on 2-D (flatten) views also stays on jnp — it degenerates to
    # per-element scales (handled inside the codec).
    use_k = _use_kernels(cfg, vspec, layout)
    payload, err_w = codec.encode_worker(
        cst(z_view), ef.err_worker if codec.needs_ef else None, layout,
        cfg.scale_mode, mask, cfg.model_axes, use_pallas=use_k, cst=cst,
        vspec=vspec)
    return payload, err_w, mask, use_k


def _flat_server_encode(recv, ef: EFState, layout, cfg, vspec, mask, use_k,
                        widx):
    """Flat server phase: decode the received chunks, average, re-encode
    the chunk this worker serves. Returns ``(payload_s, err_s)``."""
    codec = cfg.codec
    cst = lambda x: C.constrain(x, vspec)
    vals = codec.decode(recv, layout, cfg.compute_dtype, use_pallas=use_k,
                        vspec=vspec)
    avg = cst(vals).mean(axis=0)                              # (A/n, *rest)
    s_mask = None if mask is None else mask[widx][None]
    return codec.encode_server(
        avg, ef.err_server if codec.needs_ef else None, layout,
        cfg.scale_mode, s_mask, widx, cfg.model_axes, use_pallas=use_k,
        cst=cst, vspec=vspec)


def _map_a2a(comm, payload, vspec):
    # every payload leaf carries the chunk axis first -> rows become the
    # sender index after the all_to_all.
    cst = lambda x: C.constrain(x, vspec)
    return jax.tree.map(
        lambda p: cst(comm.all_to_all(cst(p), split_axis=0, concat_axis=0)),
        payload)


def _map_gather(comm, payload, vspec):
    cst = lambda x: C.constrain(x, vspec)
    return jax.tree.map(
        lambda p: cst(comm.all_gather(cst(p), axis=0, tiled=True)),
        payload)


def onebit_allreduce_view(comm: Comm, z_view: jnp.ndarray, ef: EFState,
                          layout: C.LeafLayout, cfg: OneBitConfig,
                          vspec=None, worker_index=None):
    """Algorithm 2 over one leaf's comm view. Returns (mean estimate, EFState).

    ``z_view``: this worker's buffer in view shape (n, A/n, *rest).
    ``vspec``: tensor-parallel PartitionSpec entries of the view — threaded
    through every shape-changing op so the compressed pipeline stays
    model-sharded (see compressor.constrain).
    The returned value estimates ``mean_i z_view^{(i)}`` in view shape.

    With ``cfg.hierarchy`` set the same estimate is produced by the
    topology-aware two-level schedule (:func:`_hier_allreduce_view`); the
    flat code below is its exact ``n_inner == 1`` degenerate case.

    The wire format is ``cfg.codec``'s (sign-1-bit by default): payloads
    are pytrees whose leaves all carry the chunk-enumeration axis first, so
    the two collectives simply map over them. Exact codecs
    (``needs_ef=False``) leave the EF state untouched.
    """
    if cfg.hierarchy is not None:
        assert layout.n_inner == cfg.hierarchy.inner, (layout, cfg.hierarchy)
        return _hier_allreduce_view(comm, z_view, ef, layout, cfg, vspec)
    codec = cfg.codec
    cst = lambda x: C.constrain(x, vspec)

    # --- worker side -------------------------------------------------------
    payload, err_w, mask, use_k = _flat_worker_encode(z_view, ef, layout,
                                                      cfg, vspec)

    # --- scatter: worker j collects chunk j from everyone ------------------
    recv = _map_a2a(comm, payload, vspec)

    # --- server side (this worker serves its chunk) -------------------------
    widx = comm.index() if worker_index is None else worker_index
    payload_s, err_s = _flat_server_encode(recv, ef, layout, cfg, vspec,
                                           mask, use_k, widx)

    # --- gather: broadcast compressed chunk results -------------------------
    gathered = _map_gather(comm, payload_s, vspec)
    out = cst(codec.decode(gathered, layout, cfg.compute_dtype,
                           use_pallas=use_k, vspec=vspec))
    if codec.needs_ef:
        ef = EFState(err_worker=cst(err_w).astype(ef.err_worker.dtype),
                     err_server=err_s.astype(ef.err_server.dtype))
    return out.astype(cfg.compute_dtype), ef


def _hier_reduce_scatter(inner, z_view, layout, cfg, vspec):
    """Hier step 1: intra-pod reduce-scatter. Returns ``(own slice, j)``."""
    ni, no = layout.n_inner, layout.n_outer
    vs = layout.view_shape
    cst = lambda x: C.constrain(x, vspec)
    zr = z_view.reshape((ni, no) + vs[1:])
    if ni > 1:
        recv = inner.all_to_all(zr.astype(cfg.comm_dtype),
                                split_axis=0, concat_axis=0)
        own = recv.astype(jnp.float32).mean(axis=0)        # (no, A/n, *rest)
        j = inner.index()
    else:
        own = zr[0]
        j = jnp.zeros((), jnp.int32)
    return cst(own.astype(cfg.compute_dtype)), j


def _hier_worker_encode(own, ef: EFState, layout, cfg, vspec, j):
    """Hier step 2a: codec encode of the owned slice.

    Returns ``(payload, err_w, mask_full, use_k)``."""
    codec = cfg.codec
    ni, no = layout.n_inner, layout.n_outer
    cst = lambda x: C.constrain(x, vspec)
    mask_full = (C.pad_mask(layout, dtype=own.dtype)
                 if codec.needs_ef else None)
    if mask_full is not None:
        m_slice = jnp.take(
            mask_full.reshape((ni, no) + mask_full.shape[1:]), j, axis=0)
    else:
        m_slice = None
    use_k = _use_kernels(cfg, vspec, layout)
    payload, err_w = codec.encode_worker(
        own, ef.err_worker if codec.needs_ef else None, layout,
        cfg.scale_mode, m_slice, cfg.model_axes, inner_index=j,
        use_pallas=use_k, cst=cst, vspec=vspec)
    return payload, err_w, mask_full, use_k


def _hier_server_encode(recv, ef: EFState, layout, cfg, vspec, mask_full,
                        use_k, widx):
    """Hier step 2c: server-average + re-encode of full-view chunk
    ``widx = j * n_outer + k``. Returns ``(payload_s, err_s)``."""
    codec = cfg.codec
    cst = lambda x: C.constrain(x, vspec)
    vals = codec.decode(recv, layout, cfg.compute_dtype, use_pallas=use_k,
                        vspec=vspec)
    avg = cst(vals).mean(axis=0)                           # (A/n, *rest)
    s_mask = None if mask_full is None else mask_full[widx][None]
    return codec.encode_server(
        avg, ef.err_server if codec.needs_ef else None, layout,
        cfg.scale_mode, s_mask, widx, cfg.model_axes, use_pallas=use_k,
        cst=cst, vspec=vspec)


def _hier_gather_out(inner, out_slice, layout, cfg, vspec):
    """Hier step 3: intra-pod all_gather rebuilds the full view."""
    cst = lambda x: C.constrain(x, vspec)
    vs = layout.view_shape
    if layout.n_inner > 1:
        out = inner.all_gather(out_slice.astype(cfg.comm_dtype)[None],
                               axis=0, tiled=True).reshape(vs)
    else:
        out = out_slice.reshape(vs)
    return cst(out).astype(cfg.compute_dtype)


def _hier_allreduce_view(comm: Comm, z_view: jnp.ndarray, ef: EFState,
                         layout: C.LeafLayout, cfg: OneBitConfig,
                         vspec=None):
    """Topology-aware two-level AllReduce (intra-pod × inter-pod).

    Schedule, per worker (inner index j, outer index k):

      1. **intra-pod reduce-scatter** (uncompressed, wire dtype): all_to_all
         over the fast inner axes of the view reshaped (n_inner, n_outer,
         A/n, *rest); the mean over senders leaves this worker owning the
         pod-mean of slice j.
      2. **inter-pod Algorithm 2** on the owned slice: codec encode (worker
         error), all_to_all the payload across pods, server-average +
         codec encode the chunk this pod serves (server error), all_gather
         the compressed results. Identical to the flat path with n→n_outer.
      3. **intra-pod all_gather** of the decoded slice rebuilds the
         full view.

    Only step 2 crosses the slow inter-pod links — at the codec's wire
    rate — while the bulky uncompressed traffic of steps 1/3 stays inside
    the pod. With ``n_inner == 1`` steps 1/3 are skipped entirely and
    step 2 *is* the flat path (bitwise, including scale denominators),
    which the degenerate-equivalence tests pin down.
    """
    codec = cfg.codec
    h = cfg.hierarchy
    no = layout.n_outer
    cst = lambda x: C.constrain(x, vspec)
    outer, inner = comm.split(h.outer_axes, h.inner_axes)

    own, j = _hier_reduce_scatter(inner, z_view, layout, cfg, vspec)
    payload, err_w, mask_full, use_k = _hier_worker_encode(
        own, ef, layout, cfg, vspec, j)

    # --- 2b: inter-pod scatter: pod k collects sub-chunk k -------------------
    recv = _map_a2a(outer, payload, vspec)

    widx = j * no + outer.index()
    payload_s, err_s = _hier_server_encode(recv, ef, layout, cfg, vspec,
                                           mask_full, use_k, widx)

    # --- 2d: inter-pod gather of the compressed chunk results ---------------
    gathered = _map_gather(outer, payload_s, vspec)
    out_slice = cst(codec.decode(gathered, layout, cfg.compute_dtype,
                                 use_pallas=use_k, vspec=vspec))
    if codec.needs_ef:
        new_ef = EFState(err_worker=cst(err_w).astype(ef.err_worker.dtype),
                         err_server=err_s.astype(ef.err_server.dtype))
    else:
        new_ef = ef

    return _hier_gather_out(inner, out_slice, layout, cfg, vspec), new_ef


def fullprec_allreduce_view(comm: Comm, z_view: jnp.ndarray,
                            comm_dtype=jnp.bfloat16,
                            vspec=None, hierarchy: Optional[Hierarchy] = None,
                            layout: Optional[C.LeafLayout] = None
                            ) -> jnp.ndarray:
    """Full-precision mean over workers (used on T_v steps) at the wire
    dtype, as the paper does with fp16 training.

    Implemented as the chunked scatter-mean/all-gather (reduce-scatter +
    all-gather decomposition of a ring AllReduce: identical per-device
    traffic, ~2·d bytes). Besides matching the 1-bit path's transport, this
    sidesteps an XLA CPU-backend crash on bf16 ``all-reduce`` inside
    partial-manual shard_map (bf16 a2a/all-gather are fine; TPU unaffected).

    With ``hierarchy`` (and its ``layout``) the same mean runs the two-level
    schedule: intra-pod reduce-scatter, inter-pod exchange of the owned
    slice (1/n_inner of the traffic crosses the slow links), intra-pod
    all_gather — mirroring the 1-bit path's transport level for level.
    """
    acc = z_view.dtype
    cst = lambda x: C.constrain(x, vspec)
    if hierarchy is not None and layout is not None and layout.n_inner > 1:
        ni, no = layout.n_inner, layout.n_outer
        outer, inner = comm.split(hierarchy.outer_axes, hierarchy.inner_axes)
        zr = z_view.astype(comm_dtype).reshape((ni, no) + layout.chunk_shape)
        recv = inner.all_to_all(zr, split_axis=0, concat_axis=0)
        own = recv.astype(jnp.float32).mean(axis=0).astype(comm_dtype)
        recv2 = cst(outer.all_to_all(own, split_axis=0, concat_axis=0))
        avg = recv2.astype(jnp.float32).mean(axis=0).astype(comm_dtype)
        g1 = cst(outer.all_gather(avg[None], axis=0, tiled=True))
        out = inner.all_gather(g1[None], axis=0, tiled=True)
        return out.reshape(z_view.shape).astype(acc)
    zc = cst(z_view.astype(comm_dtype))
    recv = cst(comm.all_to_all(zc, split_axis=0, concat_axis=0))
    avg = recv.astype(jnp.float32).mean(axis=0).astype(comm_dtype)
    out = cst(comm.all_gather(avg[None], axis=0, tiled=True))
    return out.astype(acc)
