"""Error-feedback 1-bit compression (paper Eq. 4 + Algorithm 2 building blocks).

The compressor operates on a *comm view* of each parameter leaf:

    natural leaf (.., A, ..)  --pad/transpose/reshape-->  view (n, A_pad/n, *rest)

where ``n`` is the worker count and the leading axis enumerates the chunks of
the chunked AllReduce (worker *j* is the "server" for chunk *j*). The view
transform is chosen per-leaf at init time (:func:`make_layout`) so that:

* the chunk-split axis is never a tensor-parallel ('model') sharded axis —
  every op below is local to a chip except the worker-axis collectives
  themselves;
* sign bits are packed along the last axis of the view, which is always a
  multiple of 8 elements per model shard.

Compression follows the paper: ``C[a] = (‖a‖₁/d) · sign(a)`` with error
feedback. ``scale_mode`` controls the granularity of the magnitude:

* ``"tensor"`` — one scale per leaf (paper-faithful, Eq. 4);
* ``"chunk"``  — one scale per worker chunk (what DeepSpeed's chunked NCCL
  backend effectively does);
* ``"row"``    — one scale per view row (beyond-paper refinement; strictly
  tighter error feedback at negligible extra traffic).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

ScaleMode = str  # "tensor" | "chunk" | "row"

SCALE_MODES = ("tensor", "chunk", "row")


def validate_scale_mode(mode: ScaleMode) -> ScaleMode:
    """Fail fast on a bad scale mode, at config-build time.

    ``ScaleMode`` is a plain string, so a typo like ``"rows"`` would
    otherwise only surface deep inside ``_scales`` (or silently misroute a
    branch that only checks equality). Every config object validates
    through here in its ``__post_init__``.
    """
    if mode not in SCALE_MODES:
        raise ValueError(
            f"unknown scale_mode {mode!r}; choose from {list(SCALE_MODES)}")
    return mode


# ---------------------------------------------------------------------------
# Leaf layouts
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LeafLayout:
    """Static description of how one leaf maps to its comm view.

    The view's leading axis enumerates the ``n`` chunks of the chunked
    AllReduce. With a two-level hierarchy (``n_inner > 1``) those chunks are
    grouped two ways at once: contiguous blocks of ``n_outer`` rows form the
    **inner reduce-scatter chunk** (the slice a worker owns after the
    full-precision intra-pod reduce-scatter, shape ``(n_outer, *chunk)``),
    and each single row stays the **outer 1-bit chunk** (what one pod serves
    during the compressed inter-pod exchange, shape ``chunk``). The flat
    layout is the exact ``n_inner == 1`` degenerate case.
    """

    shape: Tuple[int, ...]        # natural (unpadded) leaf shape
    n: int                        # worker count (number of chunks)
    flatten: bool                 # True -> treat leaf as 1-D of prod(shape)
    split_axis: int               # axis chunked across workers (after flatten)
    padded: int                   # split axis size after padding
    view_shape: Tuple[int, ...]   # (n, padded//n, *rest)
    rest_factor: int = 1          # global/local element ratio when the leaf
                                  # is tensor-parallel sharded and the layout
                                  # was built on the model-LOCAL shard
    n_inner: int = 1              # intra-pod worker count (1 = flat)

    @property
    def pad(self) -> int:
        base = int(np.prod(self.shape)) if self.flatten else self.shape[self.split_axis]
        return self.padded - base

    @property
    def chunk_shape(self) -> Tuple[int, ...]:
        return self.view_shape[1:]

    @property
    def pack_count(self) -> int:
        """Number of elements packed along the last view axis."""
        return self.view_shape[-1]

    @property
    def n_outer(self) -> int:
        """Pod count (size of the compressed exchange)."""
        return self.n // self.n_inner

    @property
    def slice_shape(self) -> Tuple[int, ...]:
        """Shape of the inner reduce-scatter slice one worker owns."""
        return (self.n_outer,) + self.chunk_shape

    @property
    def ef_worker_shape(self) -> Tuple[int, ...]:
        """Worker-side EF state shape: the buffer actually compressed —
        the full view when flat, the owned slice when hierarchical."""
        return self.slice_shape


def _is_sharded(spec, axis: int) -> bool:
    if spec is None:
        return False
    entries = tuple(spec)
    if axis >= len(entries):
        return False
    return entries[axis] is not None


def spec_model_factor(spec, axis_sizes) -> int:
    """Product of mesh-axis sizes referenced by a PartitionSpec."""
    if spec is None or not axis_sizes:
        return 1
    f = 1
    for e in tuple(spec):
        if e is None:
            continue
        for name in (e if isinstance(e, tuple) else (e,)):
            f *= axis_sizes.get(name, 1)
    return f


def make_layout(shape: Sequence[int], spec, n: int,
                rest_factor: int = 1,
                force_flatten: bool = False,
                n_inner: int = 1) -> LeafLayout:
    """Choose the comm view for a leaf with the given model-sharding spec.

    ``spec`` is a ``PartitionSpec`` (or None) describing tensor-parallel
    sharding only; the worker axis is implicit.

    ``force_flatten`` is set when the optimizer runs in the fully-manual
    domain (nested shard_map over 'model'): leaf shapes are then
    tensor-parallel-LOCAL shards, so the uniform flat view is always valid —
    there is no GSPMD resharding to avoid.

    ``n_inner`` enables the two-level (intra-pod × inter-pod) chunking: the
    view geometry is unchanged, but the layout records how its ``n`` chunk
    rows group into ``n_inner`` reduce-scatter slices of ``n // n_inner``
    outer 1-bit chunks each (see :class:`LeafLayout`).
    """
    shape = tuple(int(s) for s in shape)
    if n_inner < 1 or n % n_inner:
        raise ValueError(f"n_inner={n_inner} must divide n={n}")
    replicated = spec is None or all(e is None for e in tuple(spec))
    # Flatten views pad to an n*128 quantum (not just the n*8 bit-packing
    # minimum) so the kernel frame's column width is always a multiple of
    # the 128-lane TPU register width, folded or not. Costs < n*128 extra
    # elements per leaf; scales/EF stay pad-exact via masks/row counts.
    # Deliberately mode-independent (not gated on use_pallas): state and
    # wire layouts must match between the fused and unfused paths so the
    # modes stay drop-in interchangeable, checkpoints included.
    if len(shape) == 0:
        padded = _round_up(1, n * 128)
        return LeafLayout(shape=(), n=n, flatten=True, split_axis=0,
                          padded=padded, view_shape=(n, padded // n),
                          rest_factor=1, n_inner=n_inner)
    if replicated or force_flatten:
        total = int(np.prod(shape))
        padded = _round_up(total, n * 128)
        return LeafLayout(shape=shape, n=n, flatten=True, split_axis=0,
                          padded=padded, view_shape=(n, padded // n),
                          rest_factor=rest_factor if not replicated else 1,
                          n_inner=n_inner)
    # Sharded leaf under GSPMD-auto: split along the largest unsharded axis.
    candidates = [a for a in range(len(shape)) if not _is_sharded(spec, a)]
    if not candidates:
        raise ValueError(
            f"leaf {shape} with spec {spec} has no replicated axis to chunk over")
    split_axis = max(candidates, key=lambda a: shape[a])
    rest = [shape[a] for a in range(len(shape)) if a != split_axis]
    if rest:
        if rest[-1] % 8 != 0:
            raise ValueError(
                f"leaf {shape} spec {spec}: last view dim {rest[-1]} not a "
                f"multiple of 8; cannot bit-pack without resharding")
        padded = _round_up(shape[split_axis], n)
    else:
        padded = _round_up(shape[split_axis], n * 8)
    view_shape = (n, padded // n, *rest)
    return LeafLayout(shape=shape, n=n, flatten=False, split_axis=split_axis,
                      padded=padded, view_shape=view_shape,
                      rest_factor=rest_factor, n_inner=n_inner)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def to_view(x: jnp.ndarray, layout: LeafLayout) -> jnp.ndarray:
    """Natural leaf -> comm view (n, padded//n, *rest). Purely local ops."""
    if layout.flatten:
        flat = x.reshape(-1)
        if layout.pad:
            flat = jnp.pad(flat, (0, layout.pad))
        return flat.reshape(layout.view_shape)
    if layout.pad:
        pads = [(0, 0)] * x.ndim
        pads[layout.split_axis] = (0, layout.pad)
        x = jnp.pad(x, pads)
    x = jnp.moveaxis(x, layout.split_axis, 0)
    return x.reshape(layout.view_shape)


def from_view(v: jnp.ndarray, layout: LeafLayout) -> jnp.ndarray:
    """Comm view -> natural leaf shape (drops padding)."""
    if layout.flatten:
        flat = v.reshape(-1)
        total = int(np.prod(layout.shape)) if layout.shape else 1
        flat = flat[:total]
        return flat.reshape(layout.shape)
    rest = [layout.shape[a] for a in range(len(layout.shape))
            if a != layout.split_axis]
    x = v.reshape((layout.padded, *rest))
    x = jnp.moveaxis(x, 0, layout.split_axis)
    if layout.pad:
        sl = [slice(None)] * x.ndim
        sl[layout.split_axis] = slice(0, layout.shape[layout.split_axis])
        x = x[tuple(sl)]
    return x


def pad_mask(layout: LeafLayout, dtype=jnp.float32) -> Optional[jnp.ndarray]:
    """Mask over the view that is 0 at padded positions, or None if no pad.

    Broadcastable against the view: shape (n, padded//n) + (1,)*len(rest).
    """
    if layout.pad == 0:
        return None
    a = np.arange(layout.padded).reshape(layout.view_shape[0], layout.view_shape[1])
    base = (int(np.prod(layout.shape)) if layout.flatten
            else layout.shape[layout.split_axis])
    m = (a < base).astype(np.float32)
    m = m.reshape(m.shape + (1,) * (len(layout.view_shape) - 2))
    return jnp.asarray(m, dtype=dtype)


def ambient_auto_mesh():
    """(axis->size) for GSPMD-*auto* axes of the ambient mesh, or None.

    Inside a partial-manual shard_map body the abstract mesh reports the
    manual worker axes as Manual — constraints must only mention Auto axes.
    """
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and am.axis_names:
            n2t = dict(zip(am.axis_names, am.axis_types))
            return {a: int(am.shape[a]) for a in am.axis_names
                    if "Auto" in str(n2t[a])}
    except Exception:
        pass
    try:
        from jax.interpreters.pxla import thread_resources
        m = thread_resources.env.physical_mesh
        if not m.empty:
            return {a: int(s) for a, s in zip(m.axis_names, m.devices.shape)}
    except Exception:
        pass
    return None


def constrain(x, entries) -> jnp.ndarray:
    """with_sharding_constraint that degrades to a no-op off-mesh.

    Keeps the optimizer's comm pipeline (views, packed bits, chunk buffers)
    sharded over the tensor-parallel axis — without these GSPMD loses the
    last-dim sharding across packbits/collective boundaries and re-gathers
    full views over 'model' (observed: 18 GiB all-gathers per leaf).
    """
    if entries is None:
        return x
    auto = ambient_auto_mesh()
    if not auto:
        return x
    from jax.sharding import PartitionSpec as P
    ents = tuple(entries)[:x.ndim]
    ents = ents + (None,) * (x.ndim - len(ents))
    ok = []
    for dim, name in zip(x.shape, ents):
        if name is None:
            ok.append(None)
            continue
        names = name if isinstance(name, tuple) else (name,)
        if all(n in auto for n in names):
            size = 1
            for n in names:
                size *= auto[n]
            ok.append(name if dim % size == 0 else None)
        else:
            ok.append(None)
    if all(e is None for e in ok):
        return x
    try:
        return jax.lax.with_sharding_constraint(x, P(*ok))
    except Exception:
        return x


def view_spec_entries(layout: LeafLayout, spec) -> Tuple:
    """PartitionSpec entries (model axes only) for the comm-view shape.

    * GSPMD-auto structured views keep the original non-split-axis entries
      (the split axis is unsharded): view (n, A/n, *rest).
    * Fully-manual flattened views of a tensor-parallel leaf
      (rest_factor > 1): the flat dim is declared sharded over the leaf's
      model axes — each shard stores its own flat segment.
    * Replicated flattened leaves: replicated.
    """
    if layout.flatten:
        if layout.rest_factor > 1 and spec is not None:
            names = []
            for e in tuple(spec):
                if e is None:
                    continue
                names.extend(e if isinstance(e, tuple) else (e,))
            if names:
                ax = names[0] if len(names) == 1 else tuple(names)
                return (None, ax)
        return (None,) * len(layout.view_shape)
    entries = tuple(spec) if spec is not None else ()
    entries = entries + (None,) * (len(layout.shape) - len(entries))
    rest = tuple(e for a, e in enumerate(entries) if a != layout.split_axis)
    return (None, None, *rest)


def chunk_spec_entries(layout: LeafLayout, spec) -> Tuple:
    """PartitionSpec entries for the server-chunk shape (A/n, *rest)."""
    return view_spec_entries(layout, spec)[1:]


# ---------------------------------------------------------------------------
# View <-> 2-D adapter (the kernels' tile contract)
#
# The Pallas kernels in repro.kernels operate on 2-D (rows, cols) tiles.
# Every comm view (n, A/n, *rest) maps onto that frame by collapsing all
# leading axes into rows and keeping the last axis as cols:
#
#     view (n, A/n, r0, .., rk, C)  <->  2-D (n * A/n * r0 * .. * rk, C)
#
# This is a pure reshape (no data movement): the last view axis is already
# the bit-packing axis and a multiple of 8, so packed bytes produced on the
# 2-D frame are byte-identical to ``pack_signs`` on the view. Padding is
# always expressible per 2-D row as a true-element *count* (flatten views
# pad the tail of the flat element order -> tail columns of the last rows;
# structured views pad whole chunk rows -> whole 2-D rows), which is what
# :func:`view_row_counts` precomputes for the kernels' mask-aware scales.
# ---------------------------------------------------------------------------

# Max frame width handed to the kernels. Tiles are (block_rows, cols), so
# cols bounds VMEM per tile (~6 f32 operands x 8 rows x cols = 192*cols
# bytes at 8192 -> ~1.6 MB, comfortably under the ~16 MB/core budget).
# Flatten views of big leaves (cols = leaf_size/n) are refolded to respect
# it; structured views keep their (bounded, model-local) last dim.
FRAME_MAX_COLS = 8192


def view_rows_cols(layout: LeafLayout) -> Tuple[int, int]:
    """(rows, cols) of the kernel-facing 2-D frame of a comm view.

    For flatten views wider than FRAME_MAX_COLS the frame folds each chunk
    row into ``k`` sub-rows (still a pure reshape of the flat element
    order; every chunk stays a contiguous, equal block of frame rows, so
    scale-group reductions reshape cleanly and padding remains a tail
    expressible as per-row counts).
    """
    vs = layout.view_shape
    rows, cols = int(np.prod(vs[:-1])), int(vs[-1])
    if layout.flatten and cols > FRAME_MAX_COLS:
        # fold in 128-lane units so folded cols stay register-aligned;
        # worst case is a 128-wide frame, never narrower
        assert cols % 128 == 0, layout  # flatten views pad to n*128
        m = cols // 128
        k = -(-m // (FRAME_MAX_COLS // 128))  # smallest split under the cap
        while m % k:
            k += 1
        rows, cols = rows * k, 128 * (m // k)
    return rows, cols


def view_to_2d(v: jnp.ndarray, layout: LeafLayout) -> jnp.ndarray:
    """Comm view -> (rows, cols) kernel frame. Pure reshape."""
    rows, cols = view_rows_cols(layout)
    return v.reshape(rows, cols)


def view_from_2d(a2d: jnp.ndarray, layout: LeafLayout) -> jnp.ndarray:
    """Kernel frame -> comm view. The last dim is inferred so the same
    helper restores values and packed bytes, framed or not."""
    return a2d.reshape(layout.view_shape[:-1] + (-1,))


def view_row_counts(layout: LeafLayout) -> np.ndarray:
    """True (unpadded) element count per 2-D frame row, int32 (rows,).

    Agrees with ``pad_mask`` broadcast over the view, reshaped to the frame
    and row-summed; the kernels rebuild the elementwise mask as
    ``iota(cols) < count``.
    """
    rows, cols = view_rows_cols(layout)
    if layout.flatten:
        base = int(np.prod(layout.shape)) if layout.shape else 1
        starts = np.arange(rows, dtype=np.int64) * cols
        cnt = np.clip(base - starts, 0, cols)
    else:
        base = layout.shape[layout.split_axis]
        vs = layout.view_shape
        group = int(np.prod(vs[2:-1], dtype=np.int64)) if len(vs) > 3 else 1
        pos = np.arange(layout.n * vs[1], dtype=np.int64)  # split positions
        cnt = np.repeat((pos < base).astype(np.int64), group) * cols
    return cnt.astype(np.int32)


def chunk_row_counts(layout: LeafLayout) -> np.ndarray:
    """Per-worker-chunk row counts, int32 (n, rows // n): row counts of the
    server chunk that worker j owns (``view_row_counts`` regrouped)."""
    rows, _ = view_rows_cols(layout)
    return view_row_counts(layout).reshape(layout.n, rows // layout.n)


def slice_row_counts(layout: LeafLayout) -> np.ndarray:
    """Per-slice 2-D frame row counts, int32 (n_inner, rows // n_inner).

    Row ``j`` holds the true-element counts of the frame rows of the inner
    reduce-scatter slice owned by intra-pod worker ``j`` (the slices are
    contiguous equal blocks of frame rows, so this is ``view_row_counts``
    regrouped — exactly like :func:`chunk_row_counts` one level up).
    """
    rows, _ = view_rows_cols(layout)
    return view_row_counts(layout).reshape(layout.n_inner,
                                           rows // layout.n_inner)


def slice_true_counts(layout: LeafLayout) -> Tuple[np.ndarray, np.ndarray]:
    """Static per-slice element counts for the hierarchical worker compress.

    Returns ``(totals (n_inner,), per_chunk (n_inner, n_outer))`` — the
    float64 true-element counts of each inner slice and of each outer chunk
    within it. ``slice_true_counts(flat_layout)`` is ``true_counts`` with a
    leading length-1 axis, which is what makes the ``n_inner == 1``
    hierarchical path bitwise-identical to the flat one.
    """
    _, per_chunk = true_counts(layout)
    grouped = per_chunk.reshape(layout.n_inner, layout.n_outer)
    return grouped.sum(axis=1), grouped


def true_counts(layout: LeafLayout) -> Tuple[float, np.ndarray]:
    """(#real elements per leaf, #real elements per chunk row array (n, A/n))."""
    rest = int(np.prod(layout.view_shape[2:])) if len(layout.view_shape) > 2 else 1
    a = np.arange(layout.padded)
    base = (int(np.prod(layout.shape)) if layout.flatten
            else layout.shape[layout.split_axis])
    rows = (a < base).astype(np.float64).reshape(layout.view_shape[0],
                                                 layout.view_shape[1])
    per_chunk = rows.sum(axis=1) * rest          # (n,)
    total = float(per_chunk.sum())
    return total, per_chunk


# ---------------------------------------------------------------------------
# Sign packing
# ---------------------------------------------------------------------------

def pack_signs(v: jnp.ndarray) -> jnp.ndarray:
    """Pack sign bits (>= 0) along the last axis; last dim must be %8==0."""
    bits = (v >= 0).astype(jnp.uint8)
    return jnp.packbits(bits, axis=-1, bitorder="big")


def unpack_signs(p: jnp.ndarray, count: int, dtype=jnp.float32) -> jnp.ndarray:
    """Unpack to ±1 values of the given last-axis length."""
    bits = jnp.unpackbits(p, axis=-1, count=count, bitorder="big")
    return bits.astype(dtype) * 2.0 - 1.0


# ---------------------------------------------------------------------------
# 1-bit compression with error feedback
# ---------------------------------------------------------------------------

def _psum_model(x, model_axes):
    if not model_axes:
        return x
    return jax.lax.psum(x, model_axes if len(model_axes) > 1  # audit-ok: raw-collective
                        else model_axes[0])


def _scales(z: jnp.ndarray, layout: LeafLayout, mode: ScaleMode,
            mask: Optional[jnp.ndarray], model_axes=()) -> jnp.ndarray:
    """L1-mean magnitudes at the requested granularity (pad-exact).

    When the layout was built on a tensor-parallel-local shard
    (``rest_factor > 1``) the local sums are psum'd over the model axes and
    the denominators use the GLOBAL element counts so every shard agrees on
    the same scale (fully-manual optimizer region).
    """
    az = jnp.abs(z)
    if mask is not None:
        az = az * mask
    total, per_chunk = true_counts(layout)
    rf = layout.rest_factor
    if mode == "tensor":
        s = _psum_model(az.sum(), model_axes) / (total * rf)
        return s.reshape((1,) * z.ndim)
    if mode == "chunk":
        axes = tuple(range(1, z.ndim))
        cnt = jnp.asarray(np.maximum(per_chunk * rf, 1.0), dtype=z.dtype)
        s = _psum_model(az.sum(axis=axes), model_axes) / cnt
        return s.reshape((z.shape[0],) + (1,) * (z.ndim - 1))
    if mode == "row":
        axes = tuple(range(2, z.ndim))
        rest = (int(np.prod(z.shape[2:])) if z.ndim > 2 else 1) * rf
        if z.ndim > 2:
            s = _psum_model(az.sum(axis=axes), model_axes) / rest
        else:
            # (n, A/n): row scale degenerates to |value|; fall back to chunk
            return _scales(z, layout, "chunk", mask, model_axes)
        return s.reshape(z.shape[:2] + (1,) * (z.ndim - 2))
    raise ValueError(f"unknown scale mode {mode!r}")


def ef_compress(z: jnp.ndarray, layout: LeafLayout, mode: ScaleMode,
                mask: Optional[jnp.ndarray], model_axes=()):
    """One error-feedback compression pass over a comm view.

    Returns (packed uint8, scales, residual error). ``z`` already includes the
    incoming error (caller adds it): this computes ``ẑ = C[z]``, ``err = z−ẑ``.
    """
    scales = _scales(z, layout, mode, mask, model_axes)
    packed = pack_signs(z)
    signs = jnp.where(z >= 0, 1.0, -1.0).astype(z.dtype)
    zhat = signs * scales.astype(z.dtype)
    err = z - zhat
    if mask is not None:
        err = err * mask.astype(err.dtype)
    return packed, scales, err


def _slice_scales(z: jnp.ndarray, layout: LeafLayout, mode: ScaleMode,
                  mask: Optional[jnp.ndarray], inner_index,
                  model_axes=()) -> jnp.ndarray:
    """:func:`_scales` for one inner reduce-scatter slice (n_outer, *chunk).

    Denominators come from the statically precomputed per-slice true counts
    selected by the (traced) intra-pod worker index, so the padded tail —
    which always lands in the last slice — stays pad-exact. With
    ``n_inner == 1`` this selects the full-view counts and is bitwise
    identical to ``_scales`` on the whole view.
    """
    az = jnp.abs(z)
    if mask is not None:
        az = az * mask
    totals, per_chunk = slice_true_counts(layout)
    rf = layout.rest_factor
    if mode == "tensor":
        # unlike the flat path a whole slice can be padding (tiny leaves):
        # clamp so its all-zero sums produce a zero scale, not NaN
        denom = jnp.take(jnp.asarray(np.maximum(totals * rf, 1.0), z.dtype),
                         inner_index)
        s = _psum_model(az.sum(), model_axes) / denom
        return s.reshape((1,) * z.ndim)
    if mode == "chunk":
        axes = tuple(range(1, z.ndim))
        cnt = jnp.take(jnp.asarray(np.maximum(per_chunk * rf, 1.0), z.dtype),
                       inner_index, axis=0)
        s = _psum_model(az.sum(axis=axes), model_axes) / cnt
        return s.reshape((z.shape[0],) + (1,) * (z.ndim - 1))
    if mode == "row":
        if z.ndim <= 2:
            return _slice_scales(z, layout, "chunk", mask, inner_index,
                                 model_axes)
        # padding is whole split positions, so the (static) full rest extent
        # is the exact denominator — same as _scales on the flat view
        axes = tuple(range(2, z.ndim))
        rest = int(np.prod(z.shape[2:])) * rf
        s = _psum_model(az.sum(axis=axes), model_axes) / rest
        return s.reshape(z.shape[:2] + (1,) * (z.ndim - 2))
    raise ValueError(f"unknown scale mode {mode!r}")


def ef_compress_slice(z: jnp.ndarray, layout: LeafLayout, mode: ScaleMode,
                      mask: Optional[jnp.ndarray], inner_index,
                      model_axes=()):
    """Worker-side EF compression of one inner reduce-scatter slice.

    ``z`` is the pod-mean slice plus the incoming worker error, shape
    ``layout.slice_shape``; ``mask`` the matching slice of the pad mask.
    Same contract as :func:`ef_compress`, with per-slice denominators.
    """
    scales = _slice_scales(z, layout, mode, mask, inner_index, model_axes)
    packed = pack_signs(z)
    signs = jnp.where(z >= 0, 1.0, -1.0).astype(z.dtype)
    err = z - signs * scales.astype(z.dtype)
    if mask is not None:
        err = err * mask.astype(err.dtype)
    return packed, scales, err


def decompress(packed: jnp.ndarray, scales: jnp.ndarray, count: int,
               dtype=jnp.float32) -> jnp.ndarray:
    """Inverse of the quantizer: scale · sign."""
    signs = unpack_signs(packed, count, dtype)
    return signs * scales.astype(dtype)


def compressed_bytes_levels(layout: LeafLayout, mode: ScaleMode,
                            inner_itemsize: int = 2, codec=None) -> dict:
    """Per-level bytes one worker SENDS on one hierarchical sync.

    ``inner``: the full-precision intra-pod phases — the reduce-scatter
    all_to_all ships (n_inner − 1) of the n_inner view slices, and the final
    intra-pod all_gather broadcasts the decompressed owned slice to the
    n_inner − 1 pod-mates, both at the wire dtype (``inner_itemsize``).

    ``outer``: Algorithm 2's compressed exchange across pods over the owned
    slice — scatter keeps the own chunk local, so (n_outer − 1) encoded
    chunks go out, and the gather broadcasts this pod's compressed server
    chunk to the n_outer − 1 peers: the same (n_outer − 1) payloads again.
    The payload size of one chunk in each phase is the *codec*'s
    (``codec.wire_bytes``; default sign1bit: ``elems/8`` packed sign bytes
    plus the scale-granularity-dependent f32 scales — one per chunk for
    tensor/chunk granularity, one per view row for row granularity).

    A flat layout (``n_inner == 1``) has ``inner == 0`` and ``outer`` equal
    to the historical flat-path accounting.
    """
    from repro.core.codecs import make_codec   # lazy: codecs imports us
    codec = make_codec("sign1bit" if codec is None else codec)
    chunk_elems = int(np.prod(layout.chunk_shape))
    ni, no = layout.n_inner, layout.n_outer
    inner = 2 * (ni - 1) * no * chunk_elems * inner_itemsize
    wb = codec.wire_bytes(layout, mode)
    outer = (no - 1) * (wb["scatter"] + wb["gather"])
    return {"inner": inner, "outer": outer}


def compressed_bytes(layout: LeafLayout, mode: ScaleMode,
                     inner_itemsize: int = 2, codec=None) -> int:
    """Total bytes per worker SENT on one sync, across both levels (the
    flat path is the ``inner == 0`` special case)."""
    lv = compressed_bytes_levels(layout, mode, inner_itemsize, codec)
    return lv["inner"] + lv["outer"]


def fullprec_bytes_levels(layout: LeafLayout, itemsize: int) -> dict:
    """Per-level bytes one worker sends on a full-precision round.

    Flat: the chunked scatter-mean/all-gather moves 2·(n−1)/n of the view.
    Hierarchical: the intra-pod reduce-scatter + all_gather move
    2·(n_inner−1)/n_inner of the view, the inter-pod exchange
    2·(n_outer−1)/n_outer of the owned slice (1/n_inner of the view).
    """
    ni, no = layout.n_inner, layout.n_outer
    elems = int(np.prod(layout.view_shape))
    inner = 2 * (ni - 1) * (elems // ni) * itemsize
    outer = 2 * (no - 1) * (elems // ni // no) * itemsize
    return {"inner": inner, "outer": outer}
