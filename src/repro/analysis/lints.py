"""AST-level repo-invariant lints (stdlib only — no ruff dependency).

Rules — each enforces an invariant the IR audit relies on:

``raw-collective``
    ``jax.lax.psum`` / ``pmean`` / ``all_to_all`` / … called outside
    ``core/comm.py``. All collectives must route through :class:`Comm`
    so the auditor (and later partitioning work) sees one choke point.
``comm-view-reshape``
    ``.reshape(...)`` fed a ``LeafLayout`` shape attribute
    (``view_shape`` / ``slice_shape`` / ``chunk_shape`` /
    ``ef_worker_shape``) outside the core modules that own the layout
    contract — hand-rolled view reshapes bypass the pad-exact helpers.
``statekind-registry``
    ``StateKind(...)`` constructed outside ``core/compressed.py`` (the
    registry). State globalization is driven by these tags; ad-hoc tags
    would silently mis-stack state.
``float64-literal``
    a bare ``jnp.float64`` in source. The step must stay f64-free (the
    IR audit enforces the traced side; this catches it at the source).

A finding is waived by an inline ``# audit-ok: <rule>`` comment on the
offending line. Run as ``python -m repro.analysis.lints [paths...]``
(non-zero exit on findings) or via :func:`run_lints` from tests.
"""
from __future__ import annotations

import ast
import dataclasses
import sys
from pathlib import Path
from typing import List, Optional, Sequence

_COLLECTIVE_NAMES = {
    "psum", "pmean", "pmax", "pmin", "ppermute", "pbroadcast",
    "all_gather", "all_to_all", "psum_scatter", "all_reduce",
}
_VIEW_SHAPE_ATTRS = {
    "view_shape", "slice_shape", "chunk_shape", "ef_worker_shape",
}

# files allowed to break a rule without a waiver comment (repo-relative,
# forward slashes)
_ALLOWED = {
    "raw-collective": ("core/comm.py",),
    "comm-view-reshape": ("core/compressor.py", "core/onebit_allreduce.py",
                          "core/bucketing.py", "core/codecs.py",
                          "kernels/dispatch.py", "elastic/reshard.py"),
    "statekind-registry": ("core/compressed.py",),
    "float64-literal": (),
}


@dataclasses.dataclass(frozen=True)
class LintFinding:
    rule: str
    path: str
    line: int
    message: str

    def __str__(self):
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _is_allowed(rule: str, path: str) -> bool:
    p = path.replace("\\", "/")
    return any(p.endswith(suffix) for suffix in _ALLOWED[rule])


def _attr_chain(node) -> Optional[str]:
    """Dotted name of an attribute chain ('jax.lax.psum'), or None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _mentions_view_attr(node) -> Optional[str]:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr in _VIEW_SHAPE_ATTRS:
            return sub.attr
    return None


def _lint_source(path: str, src: str) -> List[LintFinding]:
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [LintFinding("syntax", path, e.lineno or 0, str(e))]
    lines = src.splitlines()

    def waived(rule: str, lineno: int) -> bool:
        if 1 <= lineno <= len(lines):
            return f"audit-ok: {rule}" in lines[lineno - 1]
        return False

    out: List[LintFinding] = []

    def add(rule, lineno, msg):
        if not _is_allowed(rule, path) and not waived(rule, lineno):
            out.append(LintFinding(rule, path, lineno, msg))

    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            chain = _attr_chain(node.func)
            if chain:
                tail = chain.rsplit(".", 1)[-1]
                if tail in _COLLECTIVE_NAMES and (
                        chain.startswith("jax.lax.")
                        or chain.startswith("lax.")):
                    add("raw-collective", node.lineno,
                        f"raw collective {chain}() — route it through "
                        f"core.comm.Comm")
                if tail == "reshape":
                    attr = _mentions_view_attr(node)
                    if attr:
                        add("comm-view-reshape", node.lineno,
                            f".reshape(...{attr}...) — use the LeafLayout "
                            f"view helpers in core.compressor")
            if isinstance(node.func, ast.Name) \
                    and node.func.id == "StateKind":
                add("statekind-registry", node.lineno,
                    "StateKind(...) constructed outside the registry "
                    "(core/compressed.py)")
        elif isinstance(node, ast.Attribute) and node.attr == "float64":
            chain = _attr_chain(node)
            if chain in ("jnp.float64", "jax.numpy.float64"):
                add("float64-literal", node.lineno,
                    f"bare {chain} — the train step must stay f64-free")
    return out


_DEFAULT_ROOTS = ("src", "benchmarks")


def run_lints(paths: Optional[Sequence[str]] = None,
              root: Optional[str] = None) -> List[LintFinding]:
    """Lint ``paths`` (files or directories; default: the repo's ``src``
    and ``benchmarks`` under ``root`` or the import location)."""
    if root is None:
        # .../src/repro/analysis/lints.py -> repo root
        root = str(Path(__file__).resolve().parents[3])
    targets: List[Path] = []
    for p in (paths or [str(Path(root) / r) for r in _DEFAULT_ROOTS]):
        pp = Path(p)
        if pp.is_dir():
            targets.extend(sorted(pp.rglob("*.py")))
        elif pp.suffix == ".py":
            targets.append(pp)
    out: List[LintFinding] = []
    for t in targets:
        out.extend(_lint_source(str(t), t.read_text()))
    return out


def main(argv=None) -> int:
    findings = run_lints(argv if argv else None)
    for f in findings:
        print(f)
    if findings:
        print(f"{len(findings)} lint finding(s)")
        return 1
    print("lints: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
