"""Static analysis of the repo's lowered train step and source tree.

Two independent passes:

* :mod:`repro.analysis.ir_audit` — traces a configured train step through
  ``shard_map`` over an abstract (device-free) mesh and verifies the
  collective schedule, wire bytes, and dtype discipline of the jaxpr
  against the declared contract (``bucketing.expected_*_schedule``,
  ``codec.wire_bytes`` / ``codec.payload_spec``).
* :mod:`repro.analysis.lints` — stdlib-only AST rules enforcing repo
  invariants (no raw collectives outside ``core/comm.py``, no hand-rolled
  comm-view reshapes, ``StateKind`` construction only in the registry, no
  bare float64 literals).
"""
from repro.analysis.ir_audit import (AuditReport, Violation, audit_trainer,
                                     build_manifests, check_schedule,
                                     check_wire_bytes, concretize_manifest,
                                     trace_collectives)
from repro.analysis.lints import run_lints

__all__ = [
    "AuditReport",
    "Violation",
    "audit_trainer",
    "build_manifests",
    "check_schedule",
    "check_wire_bytes",
    "concretize_manifest",
    "trace_collectives",
    "run_lints",
]
