"""IR-level communication audit of the lowered train step.

The repo's headline numbers — 1-bit inter-pod volume, the bucketed
collective count, hierarchy routing — are declared analytically
(``comm_accounting``, ``codec.wire_bytes``). This module verifies them
against what actually lowers: the per-worker step is traced through
``shard_map`` over an **abstract mesh** (no devices needed — works on a
1-CPU container for any worker count), and every collective equation of
the jaxpr is extracted and checked against the declared contract:

1. **Schedule** — the collectives of each control-flow region (cond
   branches fork regions) must match, in count and order, exactly one of
   the declared manifests (:func:`bucketing.expected_sync_schedule` /
   ``expected_fullprec_schedule``), with op kind, axes, operand dtype and
   shape all equal. Anything else must be an *allowed* extra (scalar
   control/metric reductions, expert-parallel dispatch); in particular a
   full-precision collective smuggled across the inter-pod axes outside
   the declared T_v/mean rounds is a violation.
2. **Wire bytes** — each unit's declared payload bytes must match
   ``codec.wire_bytes(layout, mode)`` (padding is already inside the
   layout's chunk quantum; a one-f32-per-chunk tolerance absorbs scale
   broadcast degeneracies).
3. **Dtype discipline** — no float64 anywhere in the traced step, and no
   weak-type or f64 leaf in the optimizer-state outputs.

Entry point: :func:`audit_trainer`. The building blocks
(:func:`trace_collectives`, :func:`build_manifests`,
:func:`concretize_manifest`, :func:`check_schedule`) are public so tests
can seed violations into any single stage.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import bucketing as BK
from repro.core import compat
from repro.core.comm import Comm

# collective primitives, normalized ("psum2" is how psum binds on newer
# tracers; "all_reduce"/"reduce_scatter" appear via shard_map rewrites)
_COLLECTIVE_PRIMS = {
    "psum": "psum", "psum2": "psum", "pmax": "pmax", "pmin": "pmin",
    "ppermute": "ppermute", "pbroadcast": "pbroadcast",
    "all_to_all": "all_to_all", "all_gather": "all_gather",
    "reduce_scatter": "reduce_scatter", "all_reduce": "all_reduce",
    "pgather": "pgather",
}

# reductions of at most this many elements are treated as control/metric
# scalars (loss pmean, policy flags, trust-ratio norms) and allowed
# anywhere
_SMALL_ELEMS = 64


@dataclasses.dataclass(frozen=True)
class TracedCollective:
    """One collective equation extracted from the lowered step."""

    op: str                    # normalized primitive name
    axes: Tuple[str, ...]      # mesh/vmap axis names it runs over
    dtype: str                 # operand dtype
    shape: Tuple[int, ...]     # operand shape (largest operand)
    elems: int                 # total operand elements (all operands)
    nbytes: int                # total operand bytes (all operands)
    region: str                # control-flow region ("top", "cond@i/b1", ..)
    order: int                 # global emission order within the walk
    in_loop: bool              # inside scan/while (repeated per iteration)
    weak_type: bool

    def describe(self) -> str:
        return (f"{self.op} over {self.axes} {self.dtype}{self.shape} "
                f"(eqn #{self.order} in {self.region})")


@dataclasses.dataclass(frozen=True)
class Violation:
    code: str      # "schedule" | "undeclared-collective" | "interpod-bytes"
    #              # | "payload-dtype" | "wire-bytes" | "f64" | "weak-type"
    message: str

    def to_dict(self):
        return {"code": self.code, "message": self.message}


@dataclasses.dataclass
class AuditReport:
    ok: bool
    violations: List[Violation]
    collectives: List[TracedCollective]
    summary: Dict[str, Any]

    def to_dict(self):
        return {
            "ok": self.ok,
            "violations": [v.to_dict() for v in self.violations],
            "n_collectives": len(self.collectives),
            "summary": self.summary,
        }

    def to_json(self, **kw):
        return json.dumps(self.to_dict(), **kw)


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------

def _abstract_mesh(axes, sizes):
    try:
        from jax.sharding import AbstractMesh  # jax >= 0.5
    except ImportError:
        from jax._src.mesh import AbstractMesh
    return AbstractMesh(tuple(zip(axes, sizes)))


def worker_axes_sizes(trainer) -> Tuple[Tuple[str, ...], Tuple[int, ...]]:
    """The worker axis names/sizes the per-worker step runs under — the
    same selection ``sim_step_fn`` / the mesh paths make."""
    if trainer.mesh is not None:
        W = tuple(trainer.tc.worker_axes)
        return W, tuple(trainer.mesh.shape[a] for a in W)
    h = trainer.hierarchy
    if h is not None:
        return tuple(h.axes), (trainer.n_workers // h.inner, h.inner)
    return ("workers",), (trainer.n_workers,)


def _abstract_batch(trainer, batch: int, seq: int):
    cfg = trainer.model_cfg
    b = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
         "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    if cfg.enc_layers:
        b["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.enc_frames, cfg.d_model), jnp.bfloat16)
    if cfg.vision_tokens:
        b["vision_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    if not cfg.causal:
        b["loss_mask"] = jax.ShapeDtypeStruct((batch, seq), jnp.float32)
    return b


def _sub_jaxprs(eqn):
    for v in eqn.params.values():
        items = v if isinstance(v, (tuple, list)) else (v,)
        for idx, item in enumerate(items):
            inner = getattr(item, "jaxpr", item)
            if hasattr(inner, "eqns") and hasattr(inner, "outvars"):
                yield idx, inner


def _eqn_axes(eqn) -> Tuple[str, ...]:
    ax = eqn.params.get("axis_name", eqn.params.get("axes", ()))
    if isinstance(ax, (tuple, list)):
        return tuple(a for a in ax if isinstance(a, str))
    return (ax,) if isinstance(ax, str) else ()


def _walk_jaxpr(jaxpr, region, in_loop, out, counter, f64_hits):
    for eqn in jaxpr.eqns:
        counter[0] += 1
        name = eqn.primitive.name
        avals = [v.aval for v in list(eqn.invars) + list(eqn.outvars)
                 if hasattr(v, "aval") and hasattr(v.aval, "dtype")]
        for a in avals:
            if str(a.dtype) == "float64":
                f64_hits.append(
                    f"{name} (eqn #{counter[0]} in {region}): "
                    f"float64 aval {a.shape}")
        if name in _COLLECTIVE_PRIMS:
            op_avals = [v.aval for v in eqn.invars
                        if hasattr(v, "aval") and hasattr(v.aval, "shape")]
            if op_avals:
                big = max(op_avals, key=lambda a: a.size)
                out.append(TracedCollective(
                    op=_COLLECTIVE_PRIMS[name],
                    axes=_eqn_axes(eqn),
                    dtype=str(big.dtype),
                    shape=tuple(big.shape),
                    elems=int(sum(a.size for a in op_avals)),
                    nbytes=int(sum(a.size * a.dtype.itemsize
                                   for a in op_avals)),
                    region=region,
                    order=counter[0],
                    in_loop=in_loop,
                    weak_type=bool(getattr(big, "weak_type", False)),
                ))
        fork = name == "cond"
        loop = in_loop or name in ("scan", "while")
        eqn_id = counter[0]
        for idx, sub in _sub_jaxprs(eqn):
            sub_region = (f"{region}/cond@{eqn_id}.b{idx}" if fork
                          else region)
            _walk_jaxpr(sub, sub_region, loop, out, counter, f64_hits)


@dataclasses.dataclass
class Trace:
    collectives: List[TracedCollective]
    f64_hits: List[str]
    state_avals: List[Tuple[str, Any]]   # (path, aval) of state outputs
    axes: Tuple[str, ...]
    sizes: Tuple[int, ...]
    jaxpr: Any


def trace_collectives(trainer, *, seq: int = 16,
                      batch_per_worker: Optional[int] = None,
                      wrap_step=None) -> Trace:
    """Trace the trainer's per-worker step under ``shard_map`` over an
    abstract mesh of its worker axes; return every collective eqn plus the
    dtype bookkeeping. ``wrap_step`` (tests) wraps the per-worker fn to
    seed violations."""
    axes, sizes = worker_axes_sizes(trainer)
    b = batch_per_worker or trainer.tc.micro_batches
    if b % trainer.tc.micro_batches:
        raise ValueError(f"batch_per_worker={b} must be divisible by "
                         f"micro_batches={trainer.tc.micro_batches}")
    # The per-worker step is traced the way the mesh path nests it: the
    # outer region is manual over the WORKER axes only, so the forward
    # sees model-GLOBAL leaves; the optimizer's own nested shard_map
    # (``Trainer._per_worker_step``) then enters the manual-'model'
    # domain with TP-local shapes, and its model-axis psums trace there.
    params_inner = jax.tree.unflatten(
        trainer.treedef, list(jax.tree.leaves(trainer.inner_abstract)))
    params_i = jax.tree.unflatten(
        trainer.treedef, list(jax.tree.leaves(trainer.local_abstract)))
    state_i = jax.eval_shape(trainer.opt.init, params_inner)
    model_sizes = dict(getattr(trainer, "model_sizes", {}) or {})
    if model_sizes:
        # worker-local / model-global state, as the outer region holds it
        ms = trainer.tree_specs.state_model_specs()

        def grow(x, s):
            if not hasattr(x, "shape"):
                return x
            shape = trainer._grow_model(
                x.shape, tuple(s) if s is not None else None)
            return jax.ShapeDtypeStruct(shape, x.dtype)

        state_i = jax.tree.map(grow, state_i, ms)
    batch_i = _abstract_batch(trainer, b, seq)

    comm = Comm(axes)
    one = trainer._one_worker_fn(comm)
    if wrap_step is not None:
        one = wrap_step(one)

    P = jax.sharding.PartitionSpec
    # bind TP model axes too (if any) — auto in the outer region
    mesh_axes, mesh_sizes = list(axes), list(sizes)
    for a, s in model_sizes.items():
        mesh_axes.append(a)
        mesh_sizes.append(s)
    mesh = _abstract_mesh(tuple(mesh_axes), tuple(mesh_sizes))
    f = compat.shard_map(one, in_specs=P(), out_specs=P(),
                         axis_names=set(axes), mesh=mesh, check=False)
    closed, out_shape = jax.make_jaxpr(f, return_shape=True)(
        params_i, state_i, batch_i)

    collectives: List[TracedCollective] = []
    f64_hits: List[str] = []
    _walk_jaxpr(closed.jaxpr, "top", False, collectives, [0], f64_hits)

    # optimizer-state output avals, named by tree path
    _, state_out, _ = out_shape
    n_params = len(jax.tree.leaves(out_shape[0]))
    flat_state, _ = jax.tree_util.tree_flatten_with_path(state_out)
    out_avals = closed.out_avals
    state_avals = []
    for k, (path, _) in enumerate(flat_state):
        state_avals.append((jax.tree_util.keystr(path),
                            out_avals[n_params + k]))
    return Trace(collectives, f64_hits, state_avals, axes, sizes,
                 closed)


# ---------------------------------------------------------------------------
# manifests
# ---------------------------------------------------------------------------

def build_manifests(opt) -> Tuple[List[BK.ExpectedCollective],
                                  List[BK.ExpectedCollective]]:
    """(sync manifest, fullprec manifest) declared by a composed
    optimizer's config — empty where the style never emits that round.
    The mean style syncs full-precision every step (no compressed round);
    the accumulate style only builds the T_v branch when the base tracks a
    variance; the gradient style traces both branches of its cond."""
    cfg = opt.cfg
    pack_order = getattr(cfg, "pack_order", "flat")
    sync = ([] if cfg.style == "mean"
            else BK.expected_sync_schedule(opt.plan, opt.ar_cfg,
                                           opt.bucket_plan, pack_order))
    has_fp = (cfg.style == "mean" or cfg.style == "gradient"
              or (cfg.style == "accumulate" and opt.base.has_variance))
    fullprec = (BK.expected_fullprec_schedule(opt.plan, opt.ar_cfg,
                                              opt.bucket_plan, pack_order)
                if has_fp else [])
    return sync, fullprec


@dataclasses.dataclass(frozen=True)
class ConcreteCollective:
    """A manifest entry resolved onto the trainer's worker axes, one eqn
    per entry (multi-axis all_gathers decompose into per-axis eqns with
    growing leading dim, matching ``Comm.all_gather``)."""

    op: str
    axes: Tuple[str, ...]
    dtype: str
    shape: Tuple[int, ...]
    source: BK.ExpectedCollective

    def describe(self) -> str:
        s = self.source
        return (f"{self.op} over {self.axes} {self.dtype}{self.shape} "
                f"[{s.round} {s.phase}, {s.unit_label}, leaf '{s.leaf}']")


def _level_axes(trainer) -> Dict[str, Tuple[str, ...]]:
    axes, _ = worker_axes_sizes(trainer)
    h = trainer.hierarchy
    levels = {"flat": axes}
    if h is not None:
        levels["outer"] = tuple(h.outer_axes)
        levels["inner"] = tuple(h.inner_axes)
    return levels


def concretize_manifest(entries, trainer) -> List[ConcreteCollective]:
    levels = _level_axes(trainer)
    axes, sizes = worker_axes_sizes(trainer)
    size_of = dict(zip(axes, sizes))
    out: List[ConcreteCollective] = []
    for e in entries:
        lv = levels.get(e.level)
        if lv is None:
            raise ValueError(f"manifest entry at level {e.level!r} but the "
                             f"trainer has levels {sorted(levels)}")
        if e.op == "all_to_all" or len(lv) == 1:
            out.append(ConcreteCollective(e.op, lv, e.dtype, e.shape, e))
            continue
        # multi-axis all_gather: one eqn per axis, innermost first
        shape = tuple(e.shape)
        for a in reversed(lv):
            out.append(ConcreteCollective("all_gather", (a,), e.dtype,
                                          shape, e))
            shape = (shape[0] * size_of[a],) + shape[1:]
    return out


# ---------------------------------------------------------------------------
# checks
# ---------------------------------------------------------------------------

def _allowance(c: TracedCollective, trainer) -> Optional[str]:
    """Why an off-manifest collective is acceptable, or None."""
    if c.op in ("psum", "pmax", "pmin", "pbroadcast") \
            and c.elems <= _SMALL_ELEMS:
        return "control/metric scalar"
    # EP token routing lives inside the decoder layer scan; the optimizer
    # exchange issues from per-unit cond regions outside any loop. The
    # in_loop discriminator keeps this allowance from swallowing the whole
    # exchange when the EP suffix covers every worker axis (deepseek /
    # llama4 smokes: n_experts % n_workers == 0 -> ep_axes == worker axes).
    ep = set(trainer.ep_axes)
    if ep and set(c.axes) <= ep and c.in_loop:
        return "expert-parallel dispatch"
    if (trainer.ep_degree > 1 and c.op == "psum"
            and set(c.axes) <= set(trainer._residual_axes())):
        return "EP residual-axis gradient mean"
    model = set(getattr(trainer, "model_axes", ()) or ())
    if model and set(c.axes) <= model:
        return "tensor-parallel reduction"
    return None


def _entry_eq(got: TracedCollective, exp: ConcreteCollective) -> bool:
    return (got.op == exp.op and tuple(got.axes) == tuple(exp.axes)
            and got.dtype == exp.dtype
            and tuple(got.shape) == tuple(exp.shape))


def _match_prefix(seq: List[TracedCollective],
                  rest: List[ConcreteCollective]
                  ) -> Optional[Tuple[int, str, bool]]:
    """None if ``seq`` equals the next ``len(seq)`` entries of ``rest``;
    else ``(prefix_len, message, dtype_only)`` locating the first
    divergence, ``dtype_only`` True when the operand dtype is the sole
    mismatch (a codec payload-dtype lie rather than a reordered/extra
    collective)."""
    for k, got in enumerate(seq):
        if k >= len(rest):
            return (k, f"{len(seq)} collectives but only {k} left in the "
                       f"declared schedule; first extra: {got.describe()}",
                    False)
        exp = rest[k]
        problems = []
        if got.op != exp.op:
            problems.append(f"op {got.op} != {exp.op}")
        if tuple(got.axes) != tuple(exp.axes):
            problems.append(f"axes {got.axes} != {exp.axes}")
        if got.dtype != exp.dtype:
            problems.append(f"dtype {got.dtype} != declared {exp.dtype}")
        if tuple(got.shape) != tuple(exp.shape):
            problems.append(f"shape {got.shape} != {exp.shape}")
        if problems:
            dtype_only = (len(problems) == 1
                          and problems[0].startswith("dtype"))
            return (k, f"position {k}: expected {exp.describe()}, found "
                       f"{got.describe()} ({'; '.join(problems)})",
                    dtype_only)
    return None


def _dtype_bits(dtype: str) -> int:
    import numpy as np
    return int(np.dtype(dtype).itemsize) * 8


def check_schedule(trace: Trace, sync: List[ConcreteCollective],
                   fullprec: List[ConcreteCollective],
                   trainer) -> List[Violation]:
    """Match the control-flow regions' collectives against the declared
    manifests, in issue order.

    The per-unit exchange forks one cond region per unit, so each
    manifest is no longer carried by a single region: the regions, taken
    in trace order, must consume the sync and fullprec manifests as
    ordered contiguous prefixes — every payload region is one unit's sync
    block, one unit's fullprec block, or (mean style) a run of fullprec
    units, and both manifests must be fully consumed. Matching uses
    backtracking over (region, sync position, fullprec position): with
    byte-identical sync and fullprec blocks (identity codec), a greedy
    sync-first choice could mis-claim a fullprec region and cascade into
    false violations. Any other payload-sized collective is a violation —
    with a dedicated code when it crosses the inter-pod axes at full
    precision."""
    out: List[Violation] = []
    regions: Dict[str, List[TracedCollective]] = {}
    for c in trace.collectives:
        regions.setdefault(c.region, []).append(c)
    for r in regions:
        regions[r].sort(key=lambda c: c.order)

    h = trainer.hierarchy
    outer = set(h.outer_axes) if h is not None else set()

    def flag_undeclared(c: TracedCollective, context: str):
        if outer and (set(c.axes) & outer) \
                and _dtype_bits(c.dtype) * c.elems > 8 * c.elems \
                and c.elems > _SMALL_ELEMS:
            out.append(Violation(
                "interpod-bytes",
                f"undeclared full-precision collective crosses the "
                f"inter-pod axes {sorted(outer)}: {c.describe()} "
                f"({context})"))
        else:
            out.append(Violation(
                "undeclared-collective",
                f"collective not in any declared schedule: "
                f"{c.describe()} ({context})"))

    # Payload sequences per region, in trace (= issue) order. Manifests
    # contain only all_to_all / all_gather — any other payload-sized op is
    # undeclared by construction (the smuggled-psum case) and must not
    # poison the sequence match.
    ordered: List[Tuple[str, List[TracedCollective]]] = []
    for region, seq in regions.items():
        payload = [c for c in seq if _allowance(c, trainer) is None]
        for c in payload:
            if c.op not in ("all_to_all", "all_gather"):
                flag_undeclared(c, f"region {region}")
        payload = [c for c in payload
                   if c.op in ("all_to_all", "all_gather")]
        if payload:
            ordered.append((region, payload))
    ordered.sort(key=lambda rp: rp[1][0].order)

    # --- backtracking assignment -------------------------------------
    seqs = [p for _, p in ordered]
    memo: Dict[Tuple[int, int, int], bool] = {}

    def assign(ri: int, s: int, f: int) -> bool:
        if ri == len(seqs):
            return s == len(sync) and f == len(fullprec)
        key = (ri, s, f)
        if key in memo:
            return memo[key]
        seq = seqs[ri]
        k = len(seq)
        ok = False
        if (s + k <= len(sync)
                and all(_entry_eq(c, e)
                        for c, e in zip(seq, sync[s:s + k]))):
            ok = assign(ri + 1, s + k, f)
        if (not ok and f + k <= len(fullprec)
                and all(_entry_eq(c, e)
                        for c, e in zip(seq, fullprec[f:f + k]))):
            ok = assign(ri + 1, s, f + k)
        memo[key] = ok
        return ok

    if assign(0, 0, 0):
        return out

    # --- diagnostics: greedy replay locating the first divergence -----
    s = f = 0
    diagnosed = False
    for region, payload in ordered:
        k = len(payload)
        res_s = (_match_prefix(payload, sync[s:])
                 if sync else (0, "no sync schedule declared", False))
        res_f = (_match_prefix(payload, fullprec[f:])
                 if fullprec else (0, "no fullprec schedule declared",
                                   False))
        if res_s is None:
            s += k
            continue
        if res_f is None:
            f += k
            continue
        if not sync and not fullprec:
            for c in payload:
                flag_undeclared(c, f"region {region}")
            continue
        # report against the closest manifest (longest matching prefix);
        # a dtype-only divergence gets its own code so the seeded codec
        # fixture is distinguishable from a reordering
        (plen, msg, dtype_only), name = max(
            ((res_s, "sync"), (res_f, "fullprec")),
            key=lambda t: t[0][0])
        out.append(Violation(
            "payload-dtype" if dtype_only else "schedule",
            f"region {region} does not match the declared {name} "
            f"schedule: {msg}"))
        diagnosed = True
        # consume the better prefix so later regions diagnose against
        # sensible offsets
        if name == "sync":
            s += min(k, len(sync) - s)
        else:
            f += min(k, len(fullprec) - f)
    for name, manifest, pos in (("sync", sync, s),
                                ("fullprec", fullprec, f)):
        if pos < len(manifest) and not diagnosed:
            out.append(Violation(
                "schedule",
                f"no region matches the declared {name} schedule "
                f"({len(manifest) - pos} collectives unconsumed, first: "
                f"{manifest[pos].describe()})"))
    return out


def check_wire_bytes(opt, tol_per_chunk: int = 4) -> List[Violation]:
    """Declared payload bytes vs ``codec.wire_bytes(layout, mode)`` per
    exchange unit and phase, within ``tol_per_chunk`` bytes per chunk."""
    out: List[Violation] = []
    ar_cfg = opt.ar_cfg
    codec = ar_cfg.codec
    hier = ar_cfg.hierarchy is not None
    pack_order = getattr(opt.cfg, "pack_order", "flat")
    sync = BK.expected_sync_schedule(opt.plan, ar_cfg, opt.bucket_plan,
                                     pack_order) \
        if opt.cfg.style != "mean" else []
    if not sync:
        return out
    units = BK.exchange_units(opt.plan, opt.bucket_plan, pack_order)
    for u, (lo, _, label) in enumerate(units):
        wire = codec.wire_bytes(lo, ar_cfg.scale_mode)
        for phase, lead in (("scatter", lo.n_outer if hier else lo.n),
                            ("gather", 1)):
            got = sum(e.nbytes for e in sync
                      if e.unit == u and e.phase == phase)
            want = lead * wire[phase]
            if abs(got - want) > tol_per_chunk * lead:
                out.append(Violation(
                    "wire-bytes",
                    f"{label} {phase} payload is {got} bytes but "
                    f"codec.wire_bytes declares {want} "
                    f"({lead} chunks x {wire[phase]} B; codec "
                    f"{codec.name}, mode {ar_cfg.scale_mode})"))
    return out


def check_dtypes(trace: Trace) -> List[Violation]:
    out = [Violation("f64", f"float64 promotion in the traced step: {m}")
           for m in trace.f64_hits[:8]]
    for path, aval in trace.state_avals:
        if str(aval.dtype) == "float64":
            out.append(Violation(
                "f64", f"optimizer state leaf {path} is float64"))
        if getattr(aval, "weak_type", False):
            out.append(Violation(
                "weak-type",
                f"optimizer state leaf {path} has a weak type "
                f"({aval.dtype}) — a python-scalar promotion leaked into "
                f"carried state"))
    for c in trace.collectives:
        if c.weak_type:
            out.append(Violation(
                "weak-type",
                f"collective operand is weakly typed: {c.describe()}"))
    return out


# ---------------------------------------------------------------------------
# top-level entry
# ---------------------------------------------------------------------------

def audit_trainer(trainer, *, seq: int = 16,
                  batch_per_worker: Optional[int] = None,
                  wrap_step=None) -> AuditReport:
    """Run the full IR audit on a built Trainer (sim or mesh mode)."""
    opt = trainer.opt
    if not hasattr(opt, "ar_cfg") or not hasattr(opt, "plan"):
        raise TypeError(
            f"audit_trainer needs a composed optimizer with a declared "
            f"plan/ar_cfg; got {type(opt).__name__}")
    trace = trace_collectives(trainer, seq=seq,
                              batch_per_worker=batch_per_worker,
                              wrap_step=wrap_step)
    sync_m, fp_m = build_manifests(opt)
    sync_c = concretize_manifest(sync_m, trainer)
    fp_c = concretize_manifest(fp_m, trainer)
    violations = (check_schedule(trace, sync_c, fp_c, trainer)
                  + check_wire_bytes(opt)
                  + check_dtypes(trace))
    axes, sizes = worker_axes_sizes(trainer)
    summary = {
        "arch": trainer.model_cfg.name,
        "axes": dict(zip(axes, sizes)),
        "n_workers": trainer.n_workers,
        "hierarchy_inner": (trainer.hierarchy.inner
                            if trainer.hierarchy else 0),
        "codec": opt.ar_cfg.codec.name,
        "style": opt.cfg.style,
        "bucketed": opt.bucket_plan is not None,
        "pack_order": getattr(opt.cfg, "pack_order", "flat"),
        "exchange_units": len(BK.exchange_units(
            opt.plan, opt.bucket_plan,
            getattr(opt.cfg, "pack_order", "flat"))),
        "collectives_traced": len(trace.collectives),
        "sync_collectives_declared": len(sync_c),
        "fullprec_collectives_declared": len(fp_c),
        "sync_payload_bytes": int(sum(e.nbytes for e in sync_m)),
        "fullprec_payload_bytes": int(sum(e.nbytes for e in fp_m)),
        "interpod_sync_bytes": int(sum(e.nbytes for e in sync_m
                                       if e.inter_pod)),
    }
    return AuditReport(ok=not violations, violations=violations,
                       collectives=trace.collectives, summary=summary)
