"""Elastic data-parallelism: DP-width as a runtime variable.

``reshard`` re-chunks worker-stacked 0/1 Adam state (EF residuals,
server chunks, anchors, accumulated updates) from n workers to m as a
pure index remap over the comm-view layouts — bitwise the identity at
m = n, mass-conserving residual folds at m != n. ``FleetSim`` drives
kill / shrink / rejoin / grow fault injection over the sim trainer, and
``restore_resharded`` loads an n-worker checkpoint into an m-worker
trainer. See reshard.py's module docstring for the carry-vs-reset
policy table.
"""
from repro.elastic.checkpoint import restore_resharded
from repro.elastic.reshard import (reshard, reshard_report,
                                   reshard_trainer, resize_opt,
                                   worker_origin)
from repro.elastic.simulate import FleetSim, ResizeEvent, parity_gap

__all__ = [
    "FleetSim", "ResizeEvent", "parity_gap", "reshard", "reshard_report",
    "reshard_trainer", "resize_opt", "restore_resharded", "worker_origin",
]
