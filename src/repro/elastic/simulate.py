"""FleetSim: fault-injected elastic training over the sim trainer.

Drives the nested-vmap sim trainer through a schedule of
:class:`ResizeEvent`\\ s — kill a worker and shrink, continue, rejoin and
grow — rebuilding the Trainer at each new width and routing (params,
state) through :func:`repro.elastic.reshard_trainer`. The loss curve and
per-resize geometry/latency records come back for the convergence-parity
gate (benchmarks/bench_convergence.PARITY_TOL) and BENCH_elastic.json.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import DataConfig, SyntheticLM
from repro.elastic.reshard import reshard_report, reshard_trainer
from repro.train import Trainer, TrainerConfig

__all__ = ["ResizeEvent", "FleetSim", "parity_gap"]


@dataclasses.dataclass(frozen=True)
class ResizeEvent:
    """Resize the fleet to ``workers`` before running step ``step``.

    ``survivors`` lists the source workers that keep a slot (in
    destination-slot order); None keeps the first ``min(n, m)``. A kill
    is expressed by omitting the dead worker from ``survivors``.
    """

    step: int
    workers: int
    survivors: Optional[Tuple[int, ...]] = None


class FleetSim:
    """Elastic sim-mode training loop with in-run DP resizes."""

    def __init__(self, model_cfg, opt_cfg, n_workers: int, *,
                 trainer_cfg: Optional[TrainerConfig] = None, seed: int = 0):
        self.model_cfg = model_cfg
        self.opt_cfg = opt_cfg
        self.n0 = n_workers
        self.tc = trainer_cfg or TrainerConfig()
        self.seed = seed

    def _batch_extras(self, batch, global_batch, seq):
        cfg = self.model_cfg
        if cfg.enc_layers:
            batch["frames"] = jnp.zeros((global_batch, cfg.enc_frames,
                                         cfg.d_model))
        if cfg.vision_tokens:
            batch["vision_embeds"] = jnp.zeros(
                (global_batch, cfg.vision_tokens, cfg.d_model))
        if not cfg.causal:
            batch["loss_mask"] = jnp.ones((global_batch, seq))
        return batch

    def run(self, steps: int, *, global_batch: int = 8, seq: int = 16,
            events: Sequence[ResizeEvent] = ()) -> dict:
        ev_by_step = {}
        for ev in events:
            if not 0 <= ev.step < steps:
                raise ValueError(f"resize at step {ev.step} is outside the "
                                 f"{steps}-step run")
            if ev.step in ev_by_step:
                raise ValueError(f"two resizes scheduled at step {ev.step}")
            ev_by_step[ev.step] = ev
        for w in [self.n0] + [ev.workers for ev in events]:
            if global_batch % w:
                raise ValueError(
                    f"global_batch={global_batch} must divide over every "
                    f"fleet width in the schedule (got width {w})")

        tr = Trainer(self.model_cfg, self.opt_cfg, n_workers=self.n0,
                     trainer_cfg=self.tc)
        params, state = tr.sim_init(jax.random.PRNGKey(self.seed))
        fn = tr.sim_step_fn()
        data = SyntheticLM(DataConfig(vocab=self.model_cfg.vocab,
                                      seq_len=seq,
                                      global_batch=global_batch,
                                      seed=self.seed))
        losses, resizes = [], []
        for t in range(steps):
            ev = ev_by_step.get(t)
            if ev is not None:
                dst = Trainer(self.model_cfg, self.opt_cfg,
                              n_workers=ev.workers, trainer_cfg=self.tc)
                rep = reshard_report(tr.opt, dst.opt,
                                     survivors=ev.survivors)
                t0 = time.perf_counter()
                params, state = reshard_trainer(tr, dst, params, state,
                                                survivors=ev.survivors)
                jax.block_until_ready(state.step)
                rep["step"] = t
                rep["reshard_ms"] = (time.perf_counter() - t0) * 1e3
                resizes.append(rep)
                tr, fn = dst, dst.sim_step_fn()
            batch = self._batch_extras(data.batch(t), global_batch, seq)
            params, state, met = fn(params, state, batch)
            losses.append(float(np.asarray(met["loss"]).reshape(-1)[0]))
        return {"losses": losses, "resizes": resizes, "params": params,
                "state": state, "trainer": tr}


def parity_gap(losses: Sequence[float], baseline: Sequence[float],
               tail: int = 10) -> float:
    """One-sided final-loss gap (nats, avg of the last ``tail`` steps) of
    an interrupted run vs its uninterrupted baseline — the same statistic
    benchmarks/bench_convergence gates at ``PARITY_TOL``."""
    k = min(tail, len(losses), len(baseline))
    return (float(np.mean(np.asarray(losses[-k:])))
            - float(np.mean(np.asarray(baseline[-k:]))))
