"""Elastic data-parallelism: reshard 0/1 Adam state across DP widths.

A DP-width change (n -> m workers) re-chunks every comm view: the view's
leading axis enumerates worker-owned chunks (``core/compressor.py``), so
the per-worker EF residuals, server chunks, accumulated-update buffers
and bucket-shaped anchors are all laid out *for a specific n*. This
module turns that layout dependence into a pure index remap: the true
(unpadded) elements of every buffer are invariant under the width, so a
buffer resharded through its natural leaf shape lands pad-exact in the
new width's layout, and at m = n the transform is bitwise the identity.

Carry-vs-reset policy (what is mathematically safe to carry and why):

==================  ======  ================================================
state               policy  rationale
==================  ======  ================================================
params / anchors    carry   anchors are replicated (x_{t'}); survivors keep
                            their local drift, joiners clone a survivor and
                            re-converge bitwise at the next re-anchoring.
momentum ``m``      carry   replicated between syncs (refreshed from ubar);
                            joiners clone a survivor.
variance ``v``      carry   NEVER reset: the paper's variance freeze means v
                            is *already* stale by design — the resize is just
                            one more step of staleness within the kappa
                            tolerance. Resetting would restart warmup.
``u`` (local acc.)  carry   survivors keep their unsynced local work; joiners
                            start at zero (they have done no local steps). A
                            killed worker's unsynced u is lost — equivalent
                            to its last microbatches never having run.
``err_s`` (server)  carry   attached to chunk *positions*, not workers: the
                            pure index remap re-chunks it to the new owners.
``err_w`` (worker)  carry / the pending correction enters the next sync as
                    fold    (1/n_e)·sum(err). When the chunk quantum divides
                            evenly (m_e == n_e and no pod died) the remap is
                            positional and bitwise; otherwise the residuals
                            are folded into the carried entities with scale
                            m_e/n_e (+ the dead entities' mass spread over
                            the survivors) so the total pending correction
                            folded into the next sync's gradient is exactly
                            conserved: (1/m_e)·sum(err') == (1/n_e)·sum(err).
step / schedules    carry   replicated scalars; policies are step-indexed.
==================  ======  ================================================

Hierarchy: with a two-level exchange the EF "entity" is the pod (the
inner level reduces full-precision; compression state belongs to pods),
so ``n_e = n / inner``. Flat layouts are the ``inner == 1`` degenerate
case where entity == worker. Survivor sets must be pod-aligned — a
destination pod drawing from two source pods has no well-defined
residual and raises.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compressor as C
from repro.core.compressed import ComposedOptimizer, CompressedDPState

__all__ = ["reshard", "reshard_trainer", "resize_opt", "worker_origin",
           "reshard_report"]


# --------------------------------------------------------------------- #
# origin maps
# --------------------------------------------------------------------- #

def worker_origin(n: int, m: int,
                  survivors: Optional[Sequence[int]] = None) -> Tuple[int, ...]:
    """Destination-worker -> source-worker map for a resize n -> m.

    ``survivors`` lists the source workers that are still alive, in the
    order they occupy destination slots (default: the first ``min(n, m)``
    source workers). Destination slots beyond the survivors are joiners,
    marked ``-1``.
    """
    if survivors is None:
        survivors = tuple(range(min(n, m)))
    sv = tuple(int(s) for s in survivors)
    if len(sv) != len(set(sv)):
        raise ValueError(f"survivors contains duplicates: {sv}")
    for s in sv:
        if not 0 <= s < n:
            raise ValueError(
                f"survivor {s} is not a worker of the n={n} source fleet")
    if len(sv) > min(n, m):
        raise ValueError(
            f"{len(sv)} survivors do not fit a resize {n}->{m} "
            f"(at most {min(n, m)} source workers can keep a slot)")
    return sv + (-1,) * (m - len(sv))


def _entity_origin(origin, n, m, ni_src, ni_dst):
    """Pod-level origin map (EF entities). Raises unless each destination
    pod draws its survivors from at most one source pod, and no source
    pod is carried twice (both would break residual-mass conservation)."""
    n_e, m_e = n // ni_src, m // ni_dst
    pod_origin = []
    for e in range(m_e):
        members = origin[e * ni_dst:(e + 1) * ni_dst]
        pods = {w // ni_src for w in members if w >= 0}
        if len(pods) > 1:
            raise ValueError(
                f"survivor set is not pod-aligned: destination pod {e} "
                f"draws workers from source pods {sorted(pods)} — the EF "
                f"residual belongs to the pod as a whole, so survivors "
                f"must keep pod-mates together (hierarchy inner="
                f"{ni_src}->{ni_dst})")
        pod_origin.append(pods.pop() if pods else -1)
    carried = [p for p in pod_origin if p >= 0]
    if len(carried) != len(set(carried)):
        raise ValueError(
            f"survivor set carries one source pod into several destination "
            f"pods ({pod_origin}) — duplicating an EF residual breaks "
            f"mass conservation; choose a pod-aligned survivor set")
    dead = sorted(set(range(n_e)) - set(carried))
    return tuple(pod_origin), tuple(dead), n_e, m_e


def _owner_of_rows(n: int, n_inner: int) -> np.ndarray:
    """Stacked worker serving each view row: row ``r = i*n_outer + o`` is
    served by worker ``(o, i)``, stacked (outer-major) at ``o*n_inner + i``
    (see onebit_allreduce: ``widx = j * n_outer + k``)."""
    no = n // n_inner
    r = np.arange(n)
    return (r % no) * n_inner + r // no


def _rows_of_workers(n: int, n_inner: int) -> np.ndarray:
    """Inverse of :func:`_owner_of_rows`: the view row served by each
    stacked worker ``w = o*n_inner + i``."""
    no = n // n_inner
    w = np.arange(n)
    return (w % n_inner) * no + w // n_inner


# --------------------------------------------------------------------- #
# buffer remaps
# --------------------------------------------------------------------- #

def _remap_fn(src_lo, dst_lo):
    """View-buffer remap src layout -> dst layout through the natural
    leaf (pad-exact both ways). Identity when the layouts agree, so the
    m = n round trip is bitwise even if pad slots held garbage."""
    if src_lo == dst_lo:
        return lambda v: v
    return lambda v: C.to_view(C.from_view(v, src_lo), dst_lo)


def ep_merge(x, ax):
    """Worker-stacked EP leaf (n, ..., E/n@ax+1, ...) -> global leaf."""
    x = jnp.moveaxis(x, 0, ax)
    shp = x.shape
    return x.reshape(shp[:ax] + (shp[ax] * shp[ax + 1],) + shp[ax + 2:])


def ep_split(x, ax, m):
    """Global EP leaf -> worker-stacked (m, ..., E/m@ax+1, ...)."""
    shp = x.shape
    x = x.reshape(shp[:ax] + (m, shp[ax] // m) + shp[ax + 1:])
    return jnp.moveaxis(x, ax, 0)


class _Ctx:
    """One resize's static plumbing, shared by every buffer."""

    def __init__(self, src, dst, survivors):
        self.n, self.m = src.n, dst.n
        self.ni_s = src.hierarchy.inner if src.hierarchy else 1
        self.ni_d = dst.hierarchy.inner if dst.hierarchy else 1
        self.origin = worker_origin(self.n, self.m, survivors)
        (self.pod_origin, self.dead_e,
         self.n_e, self.m_e) = _entity_origin(
            self.origin, self.n, self.m, self.ni_s, self.ni_d)
        self.carried_e = [p for p in self.pod_origin if p >= 0]
        # fold only when the entity count changes or residual mass died —
        # the m_e == n_e no-deaths path must stay bitwise
        self.fold = (self.m_e != self.n_e) or bool(self.dead_e)
        S = max(len(self.carried_e), 1)
        self.alpha = self.m_e / self.n_e
        self.beta = self.m_e / (self.n_e * S)
        fill = next((o for o in self.origin if o >= 0), 0)
        self.idx = jnp.asarray([o if o >= 0 else fill for o in self.origin])
        self.joiners = [k for k, o in enumerate(self.origin) if o < 0]
        self.jmask = (np.asarray([o >= 0 for o in self.origin])
                      if self.joiners else None)

    def carry(self, x, remap=None, joiner="clone"):
        """Per-worker stacked (n, ...) -> (m, ...): origin gather, optional
        per-row remap, joiners cloned from a survivor or zeroed."""
        g = x[self.idx]
        if remap is not None:
            g = jax.vmap(remap)(g)
        if joiner == "zero" and self.jmask is not None:
            mk = jnp.asarray(self.jmask).reshape((self.m,)
                                                 + (1,) * (g.ndim - 1))
            g = jnp.where(mk, g, jnp.zeros((), g.dtype))
        return g


def _reshard_err_s(es, lo_s, lo_d):
    """Server-side EF: one chunk row per worker, attached to the chunk
    *position*. Assemble the full view in serving order, remap the
    elements to the new geometry, re-slice to the new owners."""
    full = es[jnp.asarray(_owner_of_rows(lo_s.n, lo_s.n_inner))]
    full = _remap_fn(lo_s, lo_d)(full)
    return full[jnp.asarray(_rows_of_workers(lo_d.n, lo_d.n_inner))]


def _reshard_err_w(ew, lo_s, lo_d, ctx: _Ctx):
    """Worker-side EF: pod-level entity carry with mass-conserving fold.

    Each pod's workers hold inner-slices of the pod's full-view residual
    (slice i = view rows [i*n_outer, (i+1)*n_outer)); assemble per-pod
    full views, remap each to the new geometry, fold, re-slice.
    """
    n_e, m_e = ctx.n_e, ctx.m_e
    R = ew.reshape((n_e, lo_s.n_inner) + lo_s.ef_worker_shape)
    R = R.reshape((n_e,) + lo_s.view_shape)
    R = jax.vmap(_remap_fn(lo_s, lo_d))(R)      # (n_e,) + dst view_shape
    dead_sum = None
    if ctx.dead_e:
        dead_sum = sum(R[d].astype(jnp.float32) for d in ctx.dead_e)
    rows = []
    for e in range(m_e):
        p = ctx.pod_origin[e]
        if p < 0:
            rows.append(jnp.zeros(lo_d.view_shape, ew.dtype))
            continue
        r = R[p]
        if ctx.fold:
            r32 = r.astype(jnp.float32) * ctx.alpha
            if dead_sum is not None:
                r32 = r32 + ctx.beta * dead_sum
            r = r32.astype(ew.dtype)
        rows.append(r)
    out = jnp.stack(rows)
    out = out.reshape((m_e, lo_d.n_inner) + lo_d.ef_worker_shape)
    return out.reshape((lo_d.n,) + lo_d.ef_worker_shape)


# --------------------------------------------------------------------- #
# the transform
# --------------------------------------------------------------------- #

def _require_composed(opt, which):
    if not isinstance(opt, ComposedOptimizer):
        raise TypeError(
            f"reshard needs a composed optimizer (repro.core.compressed."
            f"ComposedOptimizer) as the {which} plan; legacy optimizer "
            f"classes do not expose the layout geometry — rebuild via "
            f"compressed_dp(...) / build_optimizer(...)")


def _validate_pair(src, dst):
    if src.treedef != dst.treedef:
        raise ValueError("source and destination optimizers are bound to "
                         "different parameter trees")
    for i, (a, b) in enumerate(zip(src.layouts, dst.layouts)):
        if a.shape != b.shape:
            raise ValueError(
                f"leaf {i}: natural shape {a.shape} != {b.shape} — "
                f"reshard changes the worker count, never the model")
    if list(src.dp_mask) != list(dst.dp_mask):
        raise ValueError("source and destination dp_mask differ")
    sbp, dbp = src.bucket_plan, dst.bucket_plan
    if (sbp is None) != (dbp is None):
        raise ValueError(
            "bucketing must match across the resize (bucket_mb on both "
            "sides or neither) — switching exchange granularity is a "
            "different state tree, not a width change")
    if sbp is not None:
        if len(sbp.buckets) != len(dbp.buckets):
            raise ValueError(
                f"bucket plans diverge across the resize "
                f"({len(sbp.buckets)} vs {len(dbp.buckets)} buckets); "
                f"bucket membership should be width-independent")
        for k, (a, b) in enumerate(zip(sbp.buckets, dbp.buckets)):
            if a.members != b.members or a.sizes != b.sizes:
                raise ValueError(
                    f"bucket {k} membership diverges across the resize "
                    f"({a.members} vs {b.members})")


def reshard(state: CompressedDPState, src: ComposedOptimizer,
            dst: ComposedOptimizer, *, survivors=None, pd_leaves=None
            ) -> CompressedDPState:
    """Remap worker-stacked optimizer state from ``src`` (n workers) to
    ``dst`` (m workers) under the module's carry-vs-reset policy.

    ``state`` is the sim-layout stacked state (leading worker axis on
    every per-worker leaf, as produced by ``Trainer.sim_init``).
    ``pd_leaves`` (the trainer's flat leaf metadata) is only needed when
    the tree has non-DP (expert-parallel) leaves; prefer
    :func:`reshard_trainer`, which supplies it and reshards the
    parameters too.
    """
    _require_composed(src, "source")
    _require_composed(dst, "destination")
    if not isinstance(state, CompressedDPState):
        raise TypeError(
            f"reshard operates on CompressedDPState, got "
            f"{type(state).__name__}")
    _validate_pair(src, dst)
    n, m = src.n, dst.n
    if state.step.ndim != 1 or state.step.shape[0] != n:
        raise ValueError(
            f"expected worker-stacked state with leading dim {n} (sim "
            f"layout); state.step has shape {tuple(state.step.shape)}")
    ctx = _Ctx(src, dst, survivors)

    def ep(x, i, what):
        if n == m:
            return x
        if pd_leaves is None:
            raise ValueError(
                f"leaf {i} is expert-parallel (dp_mask False) and its "
                f"'{what}' buffer is split on the expert axis; pass "
                f"pd_leaves= or use reshard_trainer(...)")
        ax = pd_leaves[i].ep_axis or 0
        merged = ep_merge(x, ax)
        if merged.shape[ax] % m:
            raise ValueError(
                f"leaf {i}: expert axis of size {merged.shape[ax]} does "
                f"not divide over m={m} workers")
        return ep_split(merged, ax, m)

    slot_specs = src.base.slot_specs()
    new_slots = {}
    for name, vals in state.slots.items():
        kind = slot_specs[name][0]
        outs = []
        for i, x in enumerate(vals):
            if x is None:
                outs.append(None)
            elif kind == "scalar":
                outs.append(ctx.carry(x))
            elif not src.dp_mask[i]:
                outs.append(ep(x, i, name))
            else:
                outs.append(ctx.carry(
                    x, _remap_fn(src.layouts[i], dst.layouts[i])))
        new_slots[name] = outs

    new_u = []
    for i, x in enumerate(state.u):
        if x is None:
            new_u.append(None)
        else:
            new_u.append(ctx.carry(
                x, _remap_fn(src.layouts[i], dst.layouts[i]),
                joiner="zero"))

    sbp, dbp = src.bucket_plan, dst.bucket_plan
    new_ew, new_es, new_anchor = [], [], []
    if sbp is not None:
        for bs, bd, ew, es, anc in zip(sbp.buckets, dbp.buckets,
                                       state.err_w, state.err_s,
                                       state.anchor):
            lo_s, lo_d = bs.layout, bd.layout
            new_ew.append(None if ew is None
                          else _reshard_err_w(ew, lo_s, lo_d, ctx))
            new_es.append(None if es is None
                          else _reshard_err_s(es, lo_s, lo_d))
            new_anchor.append(None if anc is None
                              else ctx.carry(anc, _remap_fn(lo_s, lo_d)))
    else:
        for i, (ew, es, anc) in enumerate(zip(state.err_w, state.err_s,
                                              state.anchor)):
            lo_s, lo_d = src.layouts[i], dst.layouts[i]
            new_ew.append(None if ew is None
                          else _reshard_err_w(ew, lo_s, lo_d, ctx))
            new_es.append(None if es is None
                          else _reshard_err_s(es, lo_s, lo_d))
            # per-leaf anchors are natural-shaped: width-independent
            new_anchor.append(None if anc is None else ctx.carry(anc))

    return CompressedDPState(
        step=ctx.carry(state.step),
        gamma_acc=ctx.carry(state.gamma_acc),
        sync_pstate=jax.tree.map(ctx.carry, state.sync_pstate),
        var_pstate=jax.tree.map(ctx.carry, state.var_pstate),
        slots=new_slots,
        u=new_u,
        err_w=new_ew,
        err_s=new_es,
        anchor=new_anchor,
    )


def reshard_trainer(src_tr, dst_tr, params, state, *, survivors=None):
    """Reshard stacked (params, state) from one Trainer's width to
    another's. DP params carry per worker (joiners clone a survivor and
    re-converge bitwise at the next re-anchoring); EP params merge their
    expert axis and re-split over the new fleet."""
    n, m = src_tr.n_workers, dst_tr.n_workers
    ctx = _Ctx(src_tr.opt, dst_tr.opt, survivors)
    pl = src_tr.treedef.flatten_up_to(params)
    out = []
    for i, (x, pd) in enumerate(zip(pl, src_tr.pd_leaves)):
        if pd.dp:
            out.append(ctx.carry(x))
        else:
            ax = pd.ep_axis or 0
            merged = ep_merge(x, ax)
            if merged.shape[ax] % m:
                raise ValueError(
                    f"param leaf {i}: expert axis of size "
                    f"{merged.shape[ax]} does not divide over m={m} "
                    f"workers")
            out.append(x if n == m else ep_split(merged, ax, m))
    params_m = jax.tree.unflatten(src_tr.treedef, out)
    state_m = reshard(state, src_tr.opt, dst_tr.opt, survivors=survivors,
                      pd_leaves=src_tr.pd_leaves)
    return params_m, state_m


def resize_opt(opt: ComposedOptimizer, m: int, model_axis_sizes=None
               ) -> ComposedOptimizer:
    """Rebind a composed optimizer's unbound transform at a new worker
    count (same parameter tree, specs and dp_mask)."""
    _require_composed(opt, "source")
    shapes = jax.tree.unflatten(opt.treedef, list(opt.plan.leaves))
    specs = jax.tree.unflatten(opt.treedef, list(opt.specs))
    dpm = jax.tree.unflatten(opt.treedef, list(opt.dp_mask))
    return opt.cfg(shapes, specs=specs, dp_mask=dpm, n_workers=m,
                   model_axis_sizes=model_axis_sizes)


def reshard_report(src: ComposedOptimizer, dst: ComposedOptimizer, *,
                   survivors=None) -> dict:
    """Static geometry of one resize — pure function of the two plans, no
    arrays touched (dryrun --resize-to and BENCH_elastic both record it,
    and check_bench re-derives it)."""
    _require_composed(src, "source")
    _require_composed(dst, "destination")
    _validate_pair(src, dst)
    ctx = _Ctx(src, dst, survivors)
    src_units = list(src.units)
    dst_units = list(dst.units)
    true_elems = sum(C.true_counts(u.layout)[0] for u in src_units)
    return {
        "n_from": src.n, "n_to": dst.n,
        "inner_from": ctx.ni_s, "inner_to": ctx.ni_d,
        "entities_from": ctx.n_e, "entities_to": ctx.m_e,
        "carried_entities": len(ctx.carried_e),
        "dead_entities": len(ctx.dead_e),
        "joiner_workers": len(ctx.joiners),
        "ef_fold": bool(ctx.fold),
        "dp_leaves": sum(1 for dp in src.dp_mask if dp),
        "exchange_units": len(src_units),
        "true_elems": int(true_elems),
        "padded_elems_from": int(sum(u.layout.padded for u in src_units)),
        "padded_elems_to": int(sum(u.layout.padded for u in dst_units)),
    }
