"""Width-agnostic checkpoint restore: n-worker manifests into m-worker
trainers, routed through :func:`repro.elastic.reshard`.

``checkpointing.io.restore`` stays strict — it validates the manifest
against the caller's tree and refuses any mismatch. This module sits on
top: it reads the manifest's recorded fleet width, rebuilds the *source*
trainer at that width, restores into its (abstract-derived) layout, and
reshards the result into the destination trainer's width.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax

from repro.checkpointing import io as ckpt_io
from repro.elastic.reshard import reshard_trainer
from repro.train import Trainer

__all__ = ["restore_resharded"]


def _abstract_like(tr: Trainer):
    """ShapeDtypeStruct (params, state) trees of one trainer's sim layout
    — io.restore only reads .shape/.dtype from the reference leaves, so
    nothing is materialized for the source-width tree."""
    params, state = jax.eval_shape(tr.sim_init, jax.random.PRNGKey(0))
    return {"params": params, "state": state}


def restore_resharded(path: str, trainer: Trainer, *,
                      survivors: Optional[Sequence[int]] = None,
                      src_workers: Optional[int] = None):
    """Restore a checkpoint saved at any DP width into ``trainer``.

    The source width comes from the manifest's ``meta["n_workers"]``
    (written by launch/train.py --save) or the ``src_workers`` override.
    Returns ``(params, state, step, meta)`` in the trainer's width.
    """
    manifest = ckpt_io.read_manifest(path)
    n = src_workers or (manifest.get("meta") or {}).get("n_workers")
    if not n:
        raise ValueError(
            f"checkpoint {path!r} does not record its fleet width "
            f"(meta['n_workers']); pass src_workers= explicitly")
    n = int(n)
    if n == trainer.n_workers:
        tree, step, meta = ckpt_io.restore(path, _abstract_like(trainer))
        return tree["params"], tree["state"], step, meta
    src_tr = Trainer(trainer.model_cfg, trainer.opt_cfg, n_workers=n,
                     trainer_cfg=trainer.tc)
    tree, step, meta = ckpt_io.restore(path, _abstract_like(src_tr))
    params, state = reshard_trainer(src_tr, trainer, tree["params"],
                                    tree["state"], survivors=survivors)
    return params, state, step, meta
